"""Serving-fleet unit tests: arrival processes, the admission
controller's policy logic, shed-aware stream stats, the SLO-debt
arbiter's integrator, and the observe→actuate calibration helper.

Engine-level differential coverage (both engines, sanitizer, tracer
invariance, fault composition) lives in ``test_engine_equiv.py``; this
file pins the fleet layer's own semantics.
"""
import math

import pytest

from repro.fleet import (
    ADMISSION_POLICIES,
    AdmissionController,
    DiurnalArrivals,
    FleetTenant,
    MMPPArrivals,
    PoissonArrivals,
    TraceArrivals,
    calibrate_admission,
    fleet_tenant_specs,
    fleet_traffic,
    unit_of_group,
)
from repro.tenancy import SloDebtArbiter, TenantSpec
from repro.topology import make_table2_topologies

TOPO = make_table2_topologies()["2D-SW_SW"]
COSTS = dict(prefill_bytes=64e6, decode_bytes=2e6,
             prefill_s=1e-3, decode_s=1e-4, prefill_ops=2, gen_tokens=3)


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------
def test_arrival_bounds_validation():
    p = PoissonArrivals(10.0)
    with pytest.raises(ValueError, match="needs n=, horizon_s="):
        p.times()
    with pytest.raises(ValueError, match="n must be >= 0"):
        p.times(n=-1)
    with pytest.raises(ValueError, match="horizon_s must be >= 0"):
        p.times(horizon_s=-1.0)
    with pytest.raises(ValueError, match="rate_rps must be > 0"):
        PoissonArrivals(0.0)
    assert p.times(n=0) == []
    assert len(p.times(n=5)) == 5
    assert all(t <= 2.0 for t in p.times(horizon_s=2.0))


def test_poisson_mean_rate_is_plausible():
    # 2000 expected arrivals: the realized rate must sit within ~10%.
    ts = PoissonArrivals(100.0, seed=1).times(horizon_s=20.0)
    assert len(ts) == pytest.approx(2000, rel=0.1)


def test_diurnal_rate_modulation_and_validation():
    with pytest.raises(ValueError, match="amplitude"):
        DiurnalArrivals(10.0, amplitude=1.0)
    d = DiurnalArrivals(100.0, amplitude=0.9, period_s=4.0, seed=2)
    assert d.rate_at(1.0) == pytest.approx(190.0)   # sin peak
    assert d.rate_at(3.0) == pytest.approx(10.0)    # sin trough
    ts = d.times(horizon_s=40.0)
    # Arrivals concentrate in peak half-cycles: count arrivals with
    # instantaneous rate above vs below the mean.
    hi = sum(1 for t in ts if d.rate_at(t) > 100.0)
    assert hi / len(ts) > 0.7


def test_mmpp_burstiness_and_validation():
    with pytest.raises(ValueError, match=">= 2 states"):
        MMPPArrivals((10.0,), (1.0,))
    with pytest.raises(ValueError, match="entries for"):
        MMPPArrivals((10.0, 20.0), (1.0,))
    with pytest.raises(ValueError, match="at least one state rate"):
        MMPPArrivals((0.0, 0.0), (1.0, 1.0))
    m = MMPPArrivals((5.0, 500.0), (0.5, 0.5), seed=3)
    ts = m.times(horizon_s=20.0)
    # A 100x rate ratio with equal dwell: inter-arrival gaps are strongly
    # bimodal; the count sits well above the calm-only expectation and
    # well below the burst-only one.
    assert 0.3 * 20 * 5 < len(ts) < 20 * 500
    gaps = sorted(b - a for a, b in zip(ts, ts[1:]))
    assert gaps[len(gaps) // 10] < 0.01              # bursty clumps exist
    assert sum(1 for x in gaps if x > 0.05) >= 10    # so do calm stretches


def test_mmpp_silent_state_produces_gaps():
    m = MMPPArrivals((0.0, 200.0), (0.1, 0.1), seed=4)
    ts = m.times(horizon_s=2.0)
    assert ts                                 # burst states still emit
    gaps = [b - a for a, b in zip(ts, ts[1:])]
    assert max(gaps) > 0.05                   # silent dwells show up


def test_trace_arrivals_replay_and_validation():
    with pytest.raises(ValueError, match="ascending"):
        TraceArrivals((2.0, 1.0))
    tr = TraceArrivals((0.1, 0.5, 0.9, 1.5), start_s=1.0)
    assert tr.times(horizon_s=1.0) == [1.1, 1.5, 1.9]
    assert tr.times(n=2) == [1.1, 1.5]


# ---------------------------------------------------------------------------
# fleet_traffic assembly
# ---------------------------------------------------------------------------
def _tenants():
    return [
        FleetTenant("web", PoissonArrivals(50.0, seed=1),
                    serving=dict(COSTS), weight=2.0, slo_slowdown=3.0),
        FleetTenant("batch", PoissonArrivals(30.0, seed=2),
                    serving=dict(COSTS), priority=-1),
    ]


def test_fleet_traffic_tags_streams_tenants_and_units():
    g = fleet_traffic(_tenants(), horizon_s=0.2)
    streams = {n.stream_tag for n in g.nodes}
    assert {"web/decode", "web/prefill", "batch/decode"} <= streams
    tenants = {n.tenant_tag for n in g.nodes}
    assert tenants == {"web", "batch"}
    uo, up = unit_of_group(g)
    # one unit per request chain; groups of a unit share its tenant
    n_req = sum(1 for n in g.nodes if n.name.endswith("prefill-compute"))
    assert max(uo) + 1 == n_req
    for g_id, u in enumerate(uo):
        assert g.nodes[g_id].tenant_tag in ("web", "batch")
    # unit priority comes from request nodes, not the neutral compute gate
    web_units = {uo[i] for i, n in enumerate(g.nodes)
                 if n.tenant_tag == "web"}
    batch_units = {uo[i] for i, n in enumerate(g.nodes)
                   if n.tenant_tag == "batch"}
    assert all(up[u] == 0 for u in web_units)
    assert all(up[u] == -1 for u in batch_units)


def test_fleet_traffic_empty_bounds_raise():
    with pytest.raises(ValueError, match="no tenant produced arrivals"):
        fleet_traffic(_tenants(), horizon_s=0.0)


def test_fleet_tenant_specs_match_tags():
    specs = fleet_tenant_specs(_tenants())
    assert [s.name for s in specs] == ["web", "batch"]
    assert specs[0].weight == 2.0 and specs[0].slo_slowdown == 3.0
    assert specs[1].priority == -1


# ---------------------------------------------------------------------------
# AdmissionController policy logic (driven directly, no engine)
# ---------------------------------------------------------------------------
def _ctl(n_units, groups_per_unit=1, **kw):
    unit_of = [u for u in range(n_units) for _ in range(groups_per_unit)]
    ctl = AdmissionController(unit_of, **kw)
    ctl.begin(len(unit_of), "unit")
    return ctl


def test_admission_validation():
    with pytest.raises(ValueError, match="unknown admission policy"):
        AdmissionController([0], policy="lifo")
    with pytest.raises(ValueError, match="capacity must be >= 1"):
        AdmissionController([0], capacity=0)
    with pytest.raises(ValueError, match="needs unit_priority"):
        AdmissionController([0], policy="shed-lowest-priority")
    with pytest.raises(ValueError, match="needs deadline_s"):
        AdmissionController([0], policy="deadline-aware")
    with pytest.raises(ValueError, match="covers 1 groups"):
        AdmissionController([0]).begin(2, "unit")
    assert ADMISSION_POLICIES == ("reject-newest", "shed-lowest-priority",
                                  "deadline-aware")


def test_reject_newest_sheds_arrivals_past_capacity():
    ctl = _ctl(4, policy="reject-newest", capacity=2)
    assert ctl.on_ready(0, 0.0) == ()
    assert ctl.on_ready(1, 1.0) == ()
    assert ctl.on_ready(2, 2.0) == (2,)       # full: newest shed
    ctl.on_finish(0, 3.0)                     # unit 0 leaves
    assert ctl.on_ready(3, 4.0) == ()         # slot freed
    assert ctl.n_admitted == 3 and ctl.n_shed == 1
    assert ctl.shed_units == [2]
    assert ctl.on_ready(2, 5.0) is None       # already decided


def test_shed_lowest_priority_evicts_queued_victim():
    ctl = _ctl(3, policy="shed-lowest-priority", capacity=1,
               unit_priority={0: -1, 1: 5, 2: -7})
    assert ctl.on_ready(0, 0.0) == ()
    # higher-priority arrival evicts the queued low-priority unit
    assert ctl.on_ready(1, 1.0) == (0,)
    # lower-priority arrival against a queued high-priority one: self-shed
    assert ctl.on_ready(2, 2.0) == (2,)
    assert ctl.shed_units == [0, 2]


def test_shed_lowest_priority_ties_break_to_newest():
    ctl = _ctl(2, policy="shed-lowest-priority", capacity=1,
               unit_priority={0: 0, 1: 0})
    assert ctl.on_ready(0, 0.0) == ()
    assert ctl.on_ready(1, 1.0) == (1,)       # equal prio -> reject-newest


def test_shed_serving_unit_is_never_a_victim():
    ctl = _ctl(2, policy="shed-lowest-priority", capacity=1,
               unit_priority={0: -9, 1: 5})
    assert ctl.on_ready(0, 0.0) == ()
    ctl.on_serving(0, 0.5)                    # unit 0 now in flight
    assert ctl.on_ready(1, 1.0) == (1,)       # cannot evict; self-shed


def test_deadline_aware_expires_and_drops_at_the_door():
    ctl = _ctl(5, policy="deadline-aware", capacity=1,
               deadline_s=1.0, est_service_s=0.6)
    assert ctl.on_ready(0, 0.0) == ()         # backlog 0: projected 0s
    assert ctl.on_ready(1, 0.1) == ()         # projected 0.6s <= 1.0s
    assert ctl.on_ready(2, 0.2) == (2,)       # projected 1.2s > 1.0s
    ctl.on_serving(0, 0.3)                    # in flight: expiry-proof
    # queued unit 1 expires (1.1 <= 1.5), freeing room for the arrival
    assert ctl.on_ready(3, 1.5) == (1,)
    assert ctl.on_ready(4, 1.6) == (4,)       # backlog too deep again
    assert ctl.shed_units == [2, 1, 4]


def test_multi_group_units_decide_once_and_finish_once():
    ctl = _ctl(2, groups_per_unit=3, policy="reject-newest", capacity=1)
    assert ctl.on_ready(0, 0.0) == ()
    assert ctl.on_ready(3, 0.1) == (3, 4, 5)  # whole unit shed together
    assert ctl.on_ready(4, 0.2) is None       # unit already decided
    assert ctl.on_ready(2, 0.3) is None       # same unit as group 0
    for g in (0, 1):
        ctl.on_finish(g, 1.0)
        ctl.on_finish(g, 1.0)                 # idempotent
    assert ctl._occupancy == 1                # not done yet
    ctl.on_finish(2, 2.0)
    assert ctl._occupancy == 0


# ---------------------------------------------------------------------------
# Shed-aware stream stats (the all-dead sentinel)
# ---------------------------------------------------------------------------
def test_stream_stats_survive_fully_shed_streams():
    from repro.traffic.engine import simulate_traffic

    g = fleet_traffic(_tenants(), horizon_s=0.2)
    uo, _ = unit_of_group(g)
    # capacity 1 + an absurd deadline policy: shed everything after the
    # first unit -> some streams may lose every group.
    ctl = AdmissionController(uo, policy="reject-newest", capacity=1)
    res, _ = simulate_traffic(TOPO, g, admission=ctl,
                              check_invariants=True)
    assert res.shed_groups
    stats = res.stream_stats()
    for tag, st in stats.items():
        assert st.n_live >= 0                 # sentinel armed (dead exist)
        assert not math.isnan(st.latency_mean)
        assert not math.isnan(st.latency_p99)
        assert st.finish >= 0.0
        if st.n_live == 0:
            assert st.latency_mean == 0.0 and st.latency_max == 0.0
    # an admission-free run keeps the -1 "no dead groups" sentinel
    res2, _ = simulate_traffic(TOPO, g)
    assert all(st.n_live == -1 for st in res2.stream_stats().values())


def test_simulate_validates_admission_arguments():
    from repro.core.simulator import simulate

    ctl = AdmissionController([0])
    with pytest.raises(ValueError, match="admission requires deps"):
        simulate(TOPO, [], admission=ctl)


# ---------------------------------------------------------------------------
# SloDebtArbiter: the debted integrator
# ---------------------------------------------------------------------------
def _debt_arb(**kw):
    specs = [TenantSpec("a", slo_slowdown=2.0), TenantSpec("b")]
    return SloDebtArbiter(specs, isolated_latency={"a": 1.0}, **kw)


def test_slo_debt_validation():
    with pytest.raises(ValueError, match="horizon_s"):
        _debt_arb(horizon_s=0.0)
    with pytest.raises(ValueError, match="gain"):
        _debt_arb(gain=-1.0)
    with pytest.raises(ValueError, match="alpha"):
        _debt_arb(alpha=0.0)
    with pytest.raises(ValueError, match="deadband"):
        _debt_arb(deadband=-0.1)
    assert _debt_arb().policy == "weighted-fair"


def test_slo_debt_boost_integrates_and_decays():
    arb = _debt_arb(horizon_s=10.0, gain=1.0, alpha=1.0, deadband=0.0)
    arb.on_enqueued(0, "a", 1.0)
    assert arb.boost("a") == 1.0              # no violations yet
    arb.on_group_finish(0, "a", 5.0)          # slowdown 5 > slo 2: debt 3
    assert arb.debt("a") == pytest.approx(3.0)
    assert arb.boost("a") == pytest.approx(4.0)       # 1 + gain*debt
    assert arb.effective_weight("a") == pytest.approx(4.0)
    # horizon passes: the observation ages out and the boost releases
    arb.on_enqueued(0, "a", 20.0)
    assert arb.debt("a") == 0.0
    assert arb.boost("a") == pytest.approx(1.0)
    # tenant without an SLO never boosts
    arb.on_group_finish(1, "b", 100.0)
    assert arb.boost("b") == 1.0


def test_slo_debt_damping_and_deadband():
    arb = _debt_arb(horizon_s=100.0, gain=1.0, alpha=0.5, deadband=0.0)
    arb.on_enqueued(0, "a", 1.0)
    arb.on_group_finish(0, "a", 4.0)          # target 3, EMA half-steps
    assert arb.boost("a") == pytest.approx(2.0)
    arb.on_enqueued(0, "a", 1.1)
    assert arb.boost("a") == pytest.approx(2.5)
    # a wide deadband freezes small updates (hysteresis)
    frozen = _debt_arb(horizon_s=100.0, alpha=0.3, deadband=0.9)
    frozen.on_enqueued(0, "a", 1.0)
    frozen.on_group_finish(0, "a", 2.2)       # tiny debt: update < deadband
    assert frozen.boost("a") == 1.0


def test_slo_debt_max_boost_clamp_and_state():
    arb = _debt_arb(horizon_s=100.0, gain=10.0, max_boost=3.0, alpha=1.0,
                    deadband=0.0)
    arb.on_enqueued(0, "a", 1.0)
    arb.on_group_finish(0, "a", 50.0)
    assert arb.boost("a") == pytest.approx(3.0)
    state = arb.discipline_state()
    assert state["policy"] == "weighted-fair"  # verify consumers unbroken
    assert state["discipline"] == "slo-debt"
    assert state["boosts"]["a"] == pytest.approx(3.0)


# ---------------------------------------------------------------------------
# calibrate_admission (observe -> actuate)
# ---------------------------------------------------------------------------
def test_calibrate_admission_from_traced_run():
    from repro.obs import BwTimeline, Tracer
    from repro.traffic.engine import simulate_traffic

    g = fleet_traffic(_tenants(), horizon_s=0.2)
    trc = Tracer()
    res, _ = simulate_traffic(TOPO, g, tracer=trc)
    tl = BwTimeline.from_tracer(trc)
    n_req = sum(1 for n in g.nodes if n.name.endswith("prefill-compute"))
    out = calibrate_admission(tl, window_s=res.makespan / 8,
                              n_requests=n_req,
                              target_depth=2.0, chunks_per_unit=64.0 * 5)
    assert out["capacity"] >= 1
    assert out["est_service_s"] == pytest.approx(tl.makespan / n_req)
    assert out["peak_depth"] > 0
    assert 0 < out["busiest_dim_share"] <= 1.0 + 1e-9
    with pytest.raises(ValueError, match="n_requests"):
        calibrate_admission(tl, window_s=1.0, n_requests=0)
    with pytest.raises(ValueError, match="chunks_per_unit"):
        calibrate_admission(tl, window_s=1.0, n_requests=1,
                            chunks_per_unit=0.0)
    ctl = AdmissionController(
        [0] * len(g.nodes), capacity=int(out["capacity"]))
    assert ctl.capacity == out["capacity"]
