"""Latency model (Sec. 4.4): stage transitions, A/B terms, Ideal bound."""
import pytest

from repro.core.latency_model import LatencyModel, stage_transition
from repro.topology import Phase, make_table2_topologies
from repro.topology.algorithms import DIRECT, HALVING_DOUBLING, RING

TOPOS = make_table2_topologies()


def test_stage_transition_rs_shrinks_ag_grows():
    wire, after = stage_transition(Phase.RS, 4, 64.0)
    assert wire == pytest.approx(48.0)        # (P-1)/P * 64
    assert after == pytest.approx(16.0)
    wire, after = stage_transition(Phase.AG, 4, 16.0)
    assert wire == pytest.approx(48.0)        # symmetric (Fig. 5)
    assert after == pytest.approx(64.0)


def test_fig5_stage_latency_ratios():
    """Paper Fig. 5: on a 4x4 with BW1=2*BW2, stage2 runs 2x faster."""
    from repro.topology.topology import NetworkDim, Topology, TopoKind

    topo = Topology("fig5", (
        NetworkDim(4, TopoKind.SWITCH, 16, 1, 0.0),
        NetworkDim(4, TopoKind.SWITCH, 8, 1, 0.0),
    ))
    lm = LatencyModel(topo)
    s0 = 64e6
    w1, s1 = lm.stage_wire_bytes(0, Phase.RS, s0)
    w2, _ = lm.stage_wire_bytes(1, Phase.RS, s1)
    t1 = lm.wire_time(0, w1)
    t2 = lm.wire_time(1, w2)
    assert t1 / t2 == pytest.approx(2.0)


def test_algorithm_steps():
    assert RING.steps(16, Phase.RS) == 15
    assert DIRECT.steps(8, Phase.RS) == 1
    assert HALVING_DOUBLING.steps(16, Phase.RS) == 4
    assert RING.steps(1, Phase.AG) == 0


def test_fixed_delay_ar_sums_rs_and_ag():
    topo = TOPOS["3D-FC_Ring_SW"]
    lm = LatencyModel(topo)
    for k in range(3):
        assert lm.fixed_delay(k, "AR") == pytest.approx(
            lm.fixed_delay(k, "RS") + lm.fixed_delay(k, "AG"))


def test_total_wire_bytes_schedule_invariant():
    """Sum over dims of per-NPU wire bytes is the same for ANY dim order."""
    topo = TOPOS["3D-SW_SW_SW_hetero"]
    lm = LatencyModel(topo)
    import itertools

    size = 1e8
    totals = []
    for perm in itertools.permutations(range(3)):
        sched = [(Phase.RS, d) for d in perm] + [(Phase.AG, d) for d in perm[::-1]]
        wire = 0.0
        s = size
        for ph, d in sched:
            w, s = lm.stage_wire_bytes(d, ph, s)
            wire += w
        totals.append(wire)
    assert max(totals) == pytest.approx(min(totals))
    assert totals[0] == pytest.approx(lm.total_wire_bytes("AR", size))


def test_ideal_time_formula():
    topo = TOPOS["2D-SW_SW"]
    lm = LatencyModel(topo)
    p = topo.total_npus
    want = 2 * (p - 1) / p * 1e9 / topo.total_bw_bytes
    assert lm.ideal_time("AR", 1e9) == pytest.approx(want)
    assert lm.ideal_time("RS", 1e9) == pytest.approx(want / 2)
