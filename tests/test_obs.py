"""Unit tests for the observability layer (``repro.obs``): flight-recorder
timelines, windowed share/queue series, the metrics registry + scheduler
decision log, and the satellite fixes that rode along (typed
``ServiceInterval``, empty-run ``avg_bw_utilization``)."""
import math

import pytest

from repro.core.requests import CollectiveRequest
from repro.core.scheduler import ThemisScheduler
from repro.core.latency_model import LatencyModel
from repro.core.simulator import ServiceInterval, SimResult, simulate_requests
from repro.obs import (
    BwTimeline,
    MetricsRegistry,
    Tracer,
    current_registry,
    disable_global,
    enable_global,
)
from repro.tenancy import (
    FabricArbiter,
    TenantSpec,
    simulate_fabric,
    synthetic_requests,
)
from repro.topology import make_table2_topologies

TOPOS = make_table2_topologies()
MB = 1e6


def _traced_arbiter_run(topo_name="2D-SW_SW"):
    """A multi-tenant run with real contention (and preemption) to derive
    timelines from."""
    topo = TOPOS[topo_name]
    specs = [TenantSpec("heavy", weight=1.0),
             TenantSpec("light", weight=1.0, priority=1, slo_slowdown=1.5)]
    reqs = (synthetic_requests("heavy", "AR", 200 * MB, 2)
            + synthetic_requests("light", "AR", 8 * MB, 6,
                                 gap_s=0.0004, start_s=0.0002))
    arb = FabricArbiter("weighted-fair", specs,
                        isolated_latency={"light": 0.001})
    trc = Tracer()
    res, _ = simulate_fabric(topo, reqs, arbiter=arb,
                             chunks_per_collective=8, tracer=trc)
    return topo, res, trc


# ---------------------------------------------------------------------------
# Satellite fixes: ServiceInterval type + empty-run utilization
# ---------------------------------------------------------------------------
def test_service_interval_is_tuple_compatible():
    si = ServiceInterval(1.0, 2.0, (3,))
    assert si == (1.0, 2.0, (3,))          # equality with the old bare tuple
    s, e, g = si                            # unpacking still works
    assert (s, e, g) == (1.0, 2.0, (3,))
    assert si[1] == si.end == 2.0           # index and field access agree
    assert si.start == 1.0 and si.groups == (3,)
    assert si.op == (3,)                    # historical alias for the payload


def test_engine_emits_typed_service_intervals():
    res, _ = simulate_requests(TOPOS["2D-SW_SW"],
                               [CollectiveRequest("AR", 8 * MB)],
                               chunks_per_collective=4)
    for per_dim in res.dim_services:
        for si in per_dim:
            assert isinstance(si, ServiceInterval)
            assert si.end >= si.start


def test_avg_bw_utilization_is_zero_for_empty_runs():
    empty = SimResult(makespan=0.0, dim_busy=[0.0, 0.0],
                      dim_wire_bytes=[0.0, 0.0], dim_activity=[[], []],
                      dim_op_order=[[], []])
    assert empty.avg_bw_utilization(TOPOS["2D-SW_SW"]) == 0.0
    # and through the public entry point with an empty stream
    res, groups = simulate_requests(TOPOS["2D-SW_SW"], [],
                                    chunks_per_collective=4)
    assert groups == [] and res.makespan == 0.0
    assert res.avg_bw_utilization(TOPOS["2D-SW_SW"]) == 0.0
    assert BwTimeline.from_result(res, TOPOS["2D-SW_SW"]) \
        .avg_bw_utilization() == 0.0


# ---------------------------------------------------------------------------
# BwTimeline: aggregate fidelity + windowed series
# ---------------------------------------------------------------------------
def test_timeline_from_result_matches_simresult_expressions():
    topo = TOPOS["3D-SW_SW_SW_homo"]
    reqs = [CollectiveRequest("AR", 50 * MB, issue_time=i * 1e-4)
            for i in range(6)]
    res, _ = simulate_requests(topo, reqs, chunks_per_collective=8)
    tl = BwTimeline.from_result(res, topo)
    assert tl.avg_bw_utilization() == res.avg_bw_utilization(topo)
    for d in range(topo.num_dims):
        assert tl.activity_rate(d) == res.activity_rate(d)
    with pytest.raises(ValueError, match="from_tracer"):
        tl.per_dim_utilization(tl.makespan / 4)  # needs service events


def test_windowed_utilization_integrates_to_aggregate():
    topo, res, trc = _traced_arbiter_run()
    tl = BwTimeline.from_tracer(trc)
    assert tl.avg_bw_utilization() == pytest.approx(
        res.avg_bw_utilization(topo), rel=1e-12)
    for n_win in (1, 3, 10):
        win = res.makespan / n_win
        wins = tl.windows(win)
        per_dim = tl.per_dim_utilization(win)
        for d in range(topo.num_dims):
            integ = sum(u * (w1 - w0)
                        for u, (w0, w1) in zip(per_dim[d], wins))
            assert integ == pytest.approx(
                tl.dim_utilization(d) * res.makespan, rel=1e-9)


def test_per_tenant_shares_partition_dim_utilization():
    topo, res, trc = _traced_arbiter_run()
    tl = BwTimeline.from_tracer(trc)
    win = res.makespan / 5
    shares = tl.per_dim_shares(win)
    assert set(shares) == {"heavy", "light"}
    per_dim = tl.per_dim_utilization(win)
    for d in range(topo.num_dims):
        for w in range(len(tl.windows(win))):
            total = sum(shares[t][d][w] for t in shares)
            assert total == pytest.approx(per_dim[d][w], rel=1e-9,
                                          abs=1e-15)


def test_queue_depth_is_nonnegative_and_drains():
    topo, res, trc = _traced_arbiter_run()
    tl = BwTimeline.from_tracer(trc)
    depth = tl.queue_depth(res.makespan / 8)
    assert len(depth) == topo.num_dims
    for series in depth:
        assert all(v >= -1e-9 for v in series)
    # conservation: every arrival is either served in a (possibly amended)
    # service record or was a preemption requeue that arrived again
    n_enq = len(trc.enq_times)
    n_served = sum(len(rec[2]) for per_dim in trc.services
                   for rec in per_dim)
    n_requeued = sum(len(cut_ops)
                     for (_, _, _, _, cut_ops, _, _) in trc.preempts)
    assert n_enq == n_served + n_requeued


def test_windows_tile_and_validate():
    topo, res, trc = _traced_arbiter_run()
    tl = BwTimeline.from_tracer(trc)
    wins = tl.windows(res.makespan / 4)
    assert wins[0][0] == 0.0 and wins[-1][1] == pytest.approx(res.makespan)
    for (a0, a1), (b0, b1) in zip(wins, wins[1:]):
        assert a1 == pytest.approx(b0)
    with pytest.raises(ValueError, match="window"):
        tl.windows(0.0)


# ---------------------------------------------------------------------------
# Metrics registry + scheduler decision log
# ---------------------------------------------------------------------------
def test_registry_counters_spans_and_decision_bound():
    reg = MetricsRegistry(max_decisions=3)
    reg.inc("x")
    reg.inc("x", 4)
    with reg.span("s"):
        pass
    snap = reg.snapshot()
    assert snap["counters"] == {"x": 5}
    assert snap["spans"]["s"]["count"] == 1
    from repro.obs import ScheduleDecision

    for i in range(5):
        reg.log_decision(ScheduleDecision(
            collective="AR", tenant="t", policy="themis",
            chunk_order=(0, 1), rank_signature=("AR",), cache_hit=False,
            num_chunks=i))
    assert len(reg.decisions) == 3                    # FIFO-bounded
    assert [d.num_chunks for d in reg.decisions] == [2, 3, 4]
    assert any("counter" in line for line in reg.report_rows())


def test_global_registry_captures_scheduler_decisions():
    topo = TOPOS["3D-SW_SW_SW_hetero"]
    reqs = [CollectiveRequest(["AR", "RS", "AG"][i % 3], (4 + i) * MB,
                              issue_time=i * 1e-4) for i in range(8)]
    reg = enable_global()
    try:
        assert current_registry() is reg
        simulate_requests(topo, reqs, chunks_per_collective=8)
        assert reg.counters["scheduler.requests_scheduled"] == 8
        assert len(reg.decisions) == 8
        hits = reg.counters.get("scheduler.greedy_cache.hit", 0)
        misses = reg.counters.get("scheduler.greedy_cache.miss", 0)
        assert hits + misses > 0 and misses >= 1
        assert "simulate.indexed" in reg.spans
        assert "scheduler.schedule_pass" in reg.spans
        for d in reg.decisions:
            assert d.collective in ("AR", "RS", "AG")
            assert d.num_chunks == 8 and len(d.chunk_order) > 0
    finally:
        disable_global()
    assert current_registry() is None


def test_explicit_registry_on_scheduler_wins_over_global():
    topo = TOPOS["2D-SW_SW"]
    mine = MetricsRegistry()
    other = enable_global()
    try:
        sched = ThemisScheduler(LatencyModel.for_topology(topo), "themis",
                                metrics=mine)
        sched.schedule_request(CollectiveRequest("AR", 8 * MB), 4)
        assert mine.counters["scheduler.requests_scheduled"] == 1
        assert "scheduler.requests_scheduled" not in other.counters
    finally:
        disable_global()


def test_metrics_off_by_default_keeps_scheduler_clean():
    topo = TOPOS["2D-SW_SW"]
    sched = ThemisScheduler(LatencyModel.for_topology(topo), "themis")
    assert sched.metrics is None
    sched.schedule_request(CollectiveRequest("AR", 8 * MB), 4)


# ---------------------------------------------------------------------------
# Tracer bookkeeping details
# ---------------------------------------------------------------------------
def test_tracer_event_counts_and_enqueue_property():
    topo, res, trc = _traced_arbiter_run()
    counts = trc.event_counts()
    assert counts["services"] == sum(len(s) for s in res.dim_services)
    assert counts["preempts"] == len(trc.preempts) > 0
    assert counts["enqueues"] == len(trc.enqueues)
    for dim, t in trc.enqueues[:5]:
        assert 0 <= dim < topo.num_dims and t >= 0.0


def test_preempted_service_records_match_engine_intervals():
    """After preemption amends records in place, every trace record must
    still mirror the engine's own (start, end) service log."""
    topo, res, trc = _traced_arbiter_run()
    for d in range(topo.num_dims):
        for rec, si in zip(trc.services[d], res.dim_services[d]):
            assert rec[0] == si.start and rec[1] == si.end
            assert math.isfinite(rec[5]) and rec[5] >= 0.0


def test_windowed_series_survive_zero_capacity_dims():
    """Satellite fix: a dim whose BW budget is zero (a full outage in a
    fault run, or a degenerate topology) must yield 0.0 utilization and
    shares, not a ZeroDivisionError."""
    tl = BwTimeline(
        num_dims=2,
        makespan=1.0,
        dim_bw=[0.0, 100.0],
        dim_wire=[0.0, 50.0],
        dim_busy=[0.0, 0.5],
        activity=[[], [(0.0, 0.5)]],
        services=[[], [[0.0, 0.5, [((0, 0), 0)], (0,), "t0", 50.0]]],
        enqueues=[],
    )
    assert tl.dim_utilization(0) == 0.0
    assert tl.dim_utilization(1) == pytest.approx(0.5)
    per_dim = tl.per_dim_utilization(0.5)
    assert per_dim[0] == [0.0, 0.0]
    assert per_dim[1][0] == pytest.approx(1.0)
    shares = tl.per_dim_shares(0.5)
    assert all(v == 0.0 for v in shares["t0"][0])
    assert shares["t0"][1][0] == pytest.approx(1.0)


def test_windowed_series_survive_zero_width_final_window():
    """A makespan that lands exactly on a window boundary produces a
    zero-width final window in no case — but a zero makespan produces the
    degenerate [(0, 0)] tiling, which must yield 0.0, not divide."""
    tl = BwTimeline(
        num_dims=1, makespan=0.0, dim_bw=[100.0], dim_wire=[0.0],
        dim_busy=[0.0], activity=[[]], services=[[]], enqueues=[])
    assert tl.per_dim_utilization(1.0) == [[0.0]]
    assert tl.per_dim_shares(1.0) == {}
