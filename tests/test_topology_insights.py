"""Table 2 topology data + Sec. 6.3 provisioning analysis."""
import pytest

from repro.core.insights import analyze, baseline_utilization_bound, classify_pair
from repro.topology import GBPS, make_current_topology, make_table2_topologies

TOPOS = make_table2_topologies()


def test_table2_sizes_and_npus():
    expect = {
        "2D-SW_SW": "16x64",
        "3D-SW_SW_SW_homo": "16x8x8",
        "3D-SW_SW_SW_hetero": "16x8x8",
        "3D-FC_Ring_SW": "8x16x8",
        "4D-Ring_SW_SW_SW": "4x4x8x8",
        "4D-Ring_FC_Ring_SW": "4x8x4x8",
    }
    for name, size in expect.items():
        assert TOPOS[name].size_str() == size
        assert TOPOS[name].total_npus == 1024


def test_table2_aggregate_bw():
    # paper's Aggr BW/NPU column (Gb/s): 2D-SW_SW = (1200, 800)
    t = TOPOS["2D-SW_SW"]
    assert t.dims[0].aggr_bw_bytes == pytest.approx(1200 * GBPS)
    assert t.dims[1].aggr_bw_bytes == pytest.approx(800 * GBPS)
    t = TOPOS["4D-Ring_FC_Ring_SW"]
    assert [d.aggr_bw_bytes / GBPS for d in t.dims] == pytest.approx(
        [3000, 1400, 1200, 800])


def test_provisioning_classification():
    # current 2D system: BW1=1200, P1=16, BW2=100 -> ratio 1200/1600 < 1
    cur = make_current_topology()
    v = classify_pair(cur, 0, 1, tol=0.3)
    assert v.ratio == pytest.approx(1200 / (16 * 100))
    # 3D homo: BW1=800 vs 16*800 -> heavily over-provisioned dim2
    v = classify_pair(TOPOS["3D-SW_SW_SW_homo"], 0, 1)
    assert v.verdict == "over-provisioned"
    assert v.ratio < 0.1


def test_baseline_bound_matches_paper_intuition():
    """Paper Sec. 3: current-2D near full util; 3D-homo ~35%."""
    assert baseline_utilization_bound(make_current_topology()) > 0.9
    b = baseline_utilization_bound(TOPOS["3D-SW_SW_SW_homo"])
    assert 0.3 < b < 0.4


def test_analyze_covers_all_pairs():
    t = TOPOS["4D-Ring_SW_SW_SW"]
    assert len(analyze(t)) == 6  # C(4,2)
