"""Minimal stand-in for the subset of hypothesis the suite uses.

The container image does not ship ``hypothesis`` (see requirements-dev.txt
for the real dependency).  This shim keeps the property tests running as
deterministic randomized sweeps: ``@given`` draws ``max_examples`` samples
from a seeded PRNG, so failures are reproducible, though without
hypothesis's shrinking or adaptive search.
"""
from __future__ import annotations

import functools
import random


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def sample(self, rnd: random.Random):
        return self._draw(rnd)


def integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value, max_value):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements))


def booleans():
    return _Strategy(lambda r: r.random() < 0.5)


def composite(fn):
    """``@st.composite``: the wrapped fn's first arg is ``draw``."""

    @functools.wraps(fn)
    def builder(*args, **kw):
        return _Strategy(lambda r: fn(lambda strat: strat.sample(r), *args, **kw))

    return builder


class strategies:
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    sampled_from = staticmethod(sampled_from)
    booleans = staticmethod(booleans)
    composite = staticmethod(composite)


def settings(max_examples: int = 10, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(*arg_strats, **kw_strats):
    def deco(fn):
        n = getattr(fn, "_shim_max_examples", 10)

        def runner():
            rnd = random.Random(0xC0FFEE)
            for _ in range(n):
                args = [s.sample(rnd) for s in arg_strats]
                kw = {k: s.sample(rnd) for k, s in kw_strats.items()}
                fn(*args, **kw)

        # intentionally not functools.wraps: pytest must see a zero-arg
        # signature, or it treats the strategy params as fixtures
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner

    return deco
