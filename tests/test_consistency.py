"""Sec. 4.6 chunk-schedule consistency: deterministic intra-dim ordering."""
from repro.core.consistency import fix_intra_dim_order, verify_consistent_execution
from repro.core.scheduler import schedule_collective
from repro.core.simulator import simulate
from repro.topology import make_table2_topologies

TOPOS = make_table2_topologies()
MB = 1e6


def test_offline_order_is_deterministic():
    topo = TOPOS["3D-SW_SW_SW_hetero"]
    chunks = schedule_collective(topo, "AR", 200 * MB, 32, "themis")
    o1 = fix_intra_dim_order(topo, [chunks])
    o2 = fix_intra_dim_order(topo, [chunks])
    assert o1 == o2


def test_enforced_order_immune_to_jitter():
    """With the mandated order enforced, runtime jitter cannot reorder
    per-dim execution (the deadlock-avoidance property)."""
    topo = TOPOS["3D-SW_SW_SW_homo"]
    chunks = schedule_collective(topo, "AR", 100 * MB, 16, "themis")
    assert verify_consistent_execution(topo, [chunks], jitter=0.5, trials=4)


def test_unenforced_jitter_can_reorder():
    """Sanity: without enforcement, jitter does perturb the order for at
    least one seed (otherwise the previous test is vacuous)."""
    topo = TOPOS["3D-SW_SW_SW_homo"]
    chunks = schedule_collective(topo, "AR", 100 * MB, 16, "themis")
    base = simulate(topo, [chunks], intra="SCF").dim_op_order
    seen_diff = False
    for seed in range(1, 8):
        r = simulate(topo, [chunks], intra="SCF", jitter=0.8, seed=seed)
        if r.dim_op_order != base:
            seen_diff = True
            break
    assert seen_diff


def test_all_ops_execute_exactly_once():
    topo = TOPOS["2D-SW_SW"]
    chunks = schedule_collective(topo, "AR", 100 * MB, 8, "themis")
    res = simulate(topo, [chunks], intra="SCF")
    seen = [op for dim in res.dim_op_order for op in dim]
    assert len(seen) == len(set(seen)) == 8 * 4  # 8 chunks x 2D stages
