"""Indexed-engine equivalence and scaling tests.

The indexed simulation engine (struct-of-arrays + indexed priority queues)
must be *bit-identical* to the reference engine — same makespans, per-dim
wire bytes/busy time/service logs/op orders, and per-request finish times —
across scheduling policies, intra-dim disciplines, arbiters (including
preemption and re-arm penalties), enforced orders, jitter, and fusion.
"""
import random
import time

import pytest

from repro.core.batch import (
    BatchCaches,
    Scenario,
    build_task_arrays_vectorized,
    simulate_batch,
    simulate_scenario,
)
from repro.core.latency_model import LatencyModel
from repro.core.requests import CollectiveRequest
from repro.core.scheduler import POLICIES, ThemisScheduler, schedule_collective
from repro.core.simulator import build_task_arrays, simulate, simulate_requests
from repro.tenancy import (
    FabricArbiter,
    TenantSpec,
    simulate_fabric,
    synthetic_requests,
)
from repro.topology import make_table2_topologies

TOPOS = make_table2_topologies()
MB = 1e6


def assert_same(res_idx, res_ref):
    # diff_fields covers every SimResult field, including future ones.
    assert res_idx.diff_fields(res_ref) == []


def _rand_requests(rng, n, tenants=("default",)):
    return [
        CollectiveRequest(
            rng.choice(("AR", "RS", "AG")),
            rng.uniform(1, 60) * MB,
            issue_time=rng.uniform(0, 3e-3),
            priority=rng.choice((0, 0, 1)),
            tenant=rng.choice(tenants),
            stream=f"s{i % 3}",
        )
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# Randomized differential tests: policies x disciplines x topologies
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICIES)
def test_engines_agree_across_policies(policy):
    # Seeded by list position, not hash(): reproducible across processes.
    rng = random.Random(100 + POLICIES.index(policy))
    for tname in ("2D-SW_SW", "3D-SW_SW_SW_hetero", "4D-Ring_FC_Ring_SW"):
        topo = TOPOS[tname]
        reqs = _rand_requests(rng, 12)
        for intra in ("SCF", "FIFO"):
            kw = dict(policy=policy, chunks_per_collective=8, intra=intra)
            ri, gi = simulate_requests(topo, reqs, engine="indexed", **kw)
            rr, gr = simulate_requests(topo, reqs, engine="reference", **kw)
            assert_same(ri, rr)
            assert [[c.schedule for c in g] for g in gi] == [
                [c.schedule for c in g] for g in gr]


def test_engines_agree_with_jitter_fusion_and_water_filling():
    rng = random.Random(7)
    topo = TOPOS["3D-SW_SW_SW_homo"]
    for fusion in (True, False):
        for jitter in (0.0, 0.15):
            reqs = _rand_requests(rng, 10)
            groups = [
                schedule_collective(topo, r.collective, r.size_bytes, 8,
                                    "themis", water_filling=True)
                for r in reqs
            ]
            kw = dict(issue_times=[r.issue_time for r in reqs],
                      fusion=fusion, jitter=jitter, seed=11)
            ri = simulate(topo, groups, engine="indexed", **kw)
            rr = simulate(topo, groups, engine="reference", **kw)
            assert_same(ri, rr)


ARB_POLICIES = ("fifo", "strict-priority", "weighted-fair", "slo-aware")


@pytest.mark.parametrize("arb_policy", ARB_POLICIES)
def test_engines_agree_under_arbiters(arb_policy):
    rng = random.Random(200 + ARB_POLICIES.index(arb_policy))
    specs = [TenantSpec("a", weight=2.0),
             TenantSpec("b", weight=1.0, priority=1, slo_slowdown=1.5)]
    for tname in ("2D-SW_SW", "3D-SW_SW_SW_hetero"):
        topo = TOPOS[tname]
        reqs = _rand_requests(rng, 14, tenants=("a", "b"))
        out = {}
        arbs = {}
        for eng in ("indexed", "reference"):
            arb = FabricArbiter(arb_policy, specs,
                                isolated_latency={"b": 0.001})
            arbs[eng] = arb
            out[eng], _ = simulate_fabric(topo, reqs, arbiter=arb,
                                          chunks_per_collective=8, engine=eng)
        assert_same(out["indexed"], out["reference"])
        # arbiter-side bookkeeping must match too (vt/serves/preemptions)
        assert (arbs["indexed"].preempt_count
                == arbs["reference"].preempt_count)
        for t in ("a", "b"):
            assert arbs["indexed"].served_bytes(t) == pytest.approx(
                arbs["reference"].served_bytes(t), rel=1e-12)


def test_custom_order_key_subclass_falls_back_to_reference():
    """A FabricArbiter subclass overriding order_key cannot be bucket-
    indexed; the default engine must auto-fall back to the reference loop
    so the override is honored."""

    class LargestFirst(FabricArbiter):
        def order_key(self, task, dim, now):
            return (-task.wire_bytes, task.arrival_seq)

    specs = [TenantSpec("a"), TenantSpec("b")]
    rng = random.Random(42)
    reqs = _rand_requests(rng, 10, tenants=("a", "b"))
    out = {}
    for eng in ("indexed", "reference"):
        arb = LargestFirst("weighted-fair", specs)
        out[eng], _ = simulate_fabric(TOPOS["2D-SW_SW"], reqs, arbiter=arb,
                                      chunks_per_collective=8, engine=eng)
    # both engine selections ran the reference loop -> identical, and the
    # custom key visibly reorders service vs the stock arbiter
    assert_same(out["indexed"], out["reference"])
    stock = FabricArbiter("weighted-fair", specs)
    res_stock, _ = simulate_fabric(TOPOS["2D-SW_SW"], reqs, arbiter=stock,
                                   chunks_per_collective=8)
    assert res_stock.dim_op_order != out["indexed"].dim_op_order


@pytest.mark.parametrize("jitter", [0.0, 0.15])
def test_engines_agree_with_preemption_heavy_scenario(jitter):
    """The scenario from test_tenancy that genuinely preempts multi-chunk
    services: engines must split identically — including under service-time
    jitter, which pins the RNG consumption order on the preemption path."""
    specs = [TenantSpec("heavy"), TenantSpec("light")]
    heavy = synthetic_requests("heavy", "AR", 300 * MB, 1)
    light = synthetic_requests("light", "AR", 4 * MB, 3,
                               gap_s=2e-4, start_s=5e-4)
    reqs = heavy + light
    from repro.tenancy import schedule_tenant_requests

    groups = schedule_tenant_requests(TOPOS["2D-SW_SW"], reqs,
                                      chunks_per_collective=8)
    out = {}
    for eng in ("indexed", "reference"):
        arb = FabricArbiter("weighted-fair", specs, quantum_chunks=8)
        out[eng] = simulate(
            TOPOS["2D-SW_SW"], groups,
            issue_times=[r.issue_time for r in reqs],
            tenants=[r.tenant for r in reqs], arbiter=arb,
            jitter=jitter, seed=5, engine=eng)
        assert arb.preempt_count > 0
    assert_same(out["indexed"], out["reference"])


# ---------------------------------------------------------------------------
# Enforced per-dim service order (Sec. 4.6.2)
# ---------------------------------------------------------------------------
def test_engines_agree_under_enforced_order():
    topo = TOPOS["3D-SW_SW_SW_homo"]
    chunks = schedule_collective(topo, "AR", 80 * MB, 12, "themis")
    base = simulate(topo, [chunks], engine="reference")
    enforced = base.dim_op_order
    ri = simulate(topo, [chunks], enforced_order=enforced, engine="indexed")
    rr = simulate(topo, [chunks], enforced_order=enforced, engine="reference")
    assert_same(ri, rr)
    assert ri.dim_op_order == enforced  # the mandated order was obeyed


# ---------------------------------------------------------------------------
# Preemption re-arm penalty
# ---------------------------------------------------------------------------
def test_preempt_penalty_charges_requeued_chunks():
    specs = [TenantSpec("heavy"), TenantSpec("light")]
    heavy = synthetic_requests("heavy", "AR", 300 * MB, 1)
    light = synthetic_requests("light", "AR", 4 * MB, 1, start_s=5e-4)
    reqs = heavy + light
    lm = LatencyModel(TOPOS["2D-SW_SW"])
    want_bytes = sum(lm.total_wire_bytes(r.collective, r.size_bytes)
                     for r in reqs)
    finishes = {}
    for penalty in (0.0, 2e-3):
        out = {}
        for eng in ("indexed", "reference"):
            arb = FabricArbiter("weighted-fair", specs, quantum_chunks=8,
                                preempt_penalty_s=penalty)
            out[eng], _ = simulate_fabric(
                TOPOS["2D-SW_SW"], reqs, arbiter=arb,
                chunks_per_collective=8, engine=eng)
            assert arb.preempt_count > 0
            # bytes conserved: requeued chunks are served exactly once
            assert sum(out[eng].dim_wire_bytes) == pytest.approx(
                want_bytes, rel=1e-9)
        assert_same(out["indexed"], out["reference"])
        finishes[penalty] = out["indexed"].finish_time()
    # charging a re-arm latency can only delay the drain point
    assert finishes[2e-3] > finishes[0.0]


@pytest.mark.parametrize("arb_policy", ARB_POLICIES)
@pytest.mark.parametrize("penalty", [0.0, 1e-3])
def test_preemption_conserves_bytes_under_all_disciplines(arb_policy, penalty):
    """Bytes conservation + re-arm across every discipline x penalty x
    engine, with the runtime invariant sanitizer armed — the sanitizer
    re-audits conservation, interval ordering, work conservation, and the
    arbiter ledger inside the run itself."""
    specs = [TenantSpec("heavy", weight=1.0),
             TenantSpec("light", weight=4.0, priority=5, slo_slowdown=1.2)]
    heavy = synthetic_requests("heavy", "AR", 300 * MB, 1)
    light = synthetic_requests("light", "AR", 4 * MB, 3,
                               gap_s=2e-4, start_s=5e-4)
    reqs = heavy + light
    lm = LatencyModel(TOPOS["2D-SW_SW"])
    want_bytes = sum(lm.total_wire_bytes(r.collective, r.size_bytes)
                     for r in reqs)
    out = {}
    arbs = {}
    for eng in ("indexed", "reference"):
        arb = FabricArbiter(arb_policy, specs, quantum_chunks=8,
                            preempt_penalty_s=penalty,
                            isolated_latency={"light": 0.001})
        arbs[eng] = arb
        out[eng], _ = simulate_fabric(
            TOPOS["2D-SW_SW"], reqs, arbiter=arb,
            chunks_per_collective=8, engine=eng, check_invariants=True)
        assert sum(out[eng].dim_wire_bytes) == pytest.approx(
            want_bytes, rel=1e-9)
    assert_same(out["indexed"], out["reference"])
    assert (arbs["indexed"].preempt_count
            == arbs["reference"].preempt_count)
    if arb_policy != "fifo":  # fifo never preempts; the rest must here
        assert arbs["indexed"].preempt_count > 0


@pytest.mark.parametrize("arb_policy",
                         ["strict-priority", "weighted-fair", "slo-aware"])
def test_preempt_penalty_rearm_delays_drain(arb_policy):
    """A positive re-arm penalty can only push the drain point out, and the
    penalized runs must stay bit-identical across engines with the
    sanitizer armed (work conservation knows re-arming chunks are not
    ready, so the idle gap is legitimate)."""
    specs = [TenantSpec("heavy", weight=1.0),
             TenantSpec("light", weight=4.0, priority=5, slo_slowdown=1.2)]
    reqs = (synthetic_requests("heavy", "AR", 300 * MB, 1)
            + synthetic_requests("light", "AR", 4 * MB, 3,
                                 gap_s=2e-4, start_s=5e-4))
    finishes = {}
    for penalty in (0.0, 2e-3):
        out = {}
        for eng in ("indexed", "reference"):
            arb = FabricArbiter(arb_policy, specs, quantum_chunks=8,
                                preempt_penalty_s=penalty,
                                isolated_latency={"light": 0.001})
            out[eng], _ = simulate_fabric(
                TOPOS["2D-SW_SW"], reqs, arbiter=arb,
                chunks_per_collective=8, engine=eng, check_invariants=True)
            assert arb.preempt_count > 0
        assert_same(out["indexed"], out["reference"])
        finishes[penalty] = out["indexed"].finish_time()
    assert finishes[2e-3] > finishes[0.0]


def test_sanitizer_is_a_noop_on_clean_runs_and_raises_on_corruption():
    """check_invariants=True must not change results; the checks must
    actually fire when fed a corrupted state."""
    from repro.core.invariants import (
        InvariantViolation,
        check_final,
        check_work_conserving,
    )

    rng = random.Random(1234)
    reqs = _rand_requests(rng, 10)
    for eng in ("indexed", "reference"):
        plain, _ = simulate_requests(TOPOS["2D-SW_SW"], reqs,
                                     chunks_per_collective=6, engine=eng)
        checked, _ = simulate_requests(TOPOS["2D-SW_SW"], reqs,
                                       chunks_per_collective=6, engine=eng,
                                       check_invariants=True)
        assert_same(plain, checked)

    # idle dim with queued work -> work-conservation violation
    with pytest.raises(InvariantViolation, match="work conservation"):
        check_work_conserving(0, 1.0, queue_len=2, busy_until=0.5,
                              inflight=None, engine="unit")
    # a lost chunk and a wire-byte mismatch -> final-check violations
    base = dict(engine="unit", num_dims=1,
                dim_busy=[1.0], dim_services=[[(0.0, 1.0, (0,))]],
                group_finish=[1.0], resolved_issue=[0.0], makespan=1.0)
    with pytest.raises(InvariantViolation, match="lost chunks"):
        check_final(tasks=[((0, 0), 0, 8.0, "t"), ((1, 0), 0, 8.0, "t")],
                    dim_wire=[16.0], dim_order=[[(0, 0)]], **base)
    with pytest.raises(InvariantViolation, match="conservation violated"):
        check_final(tasks=[((0, 0), 0, 8.0, "t")],
                    dim_wire=[9.0], dim_order=[[(0, 0)]], **base)


def test_preempt_penalty_validation_and_default():
    with pytest.raises(ValueError):
        FabricArbiter("weighted-fair", [], preempt_penalty_s=-1.0)
    assert FabricArbiter("weighted-fair", []).preempt_penalty_s == 0.0
    # the explicit simulate() argument is validated too
    with pytest.raises(ValueError):
        simulate(TOPOS["2D-SW_SW"], [], preempt_penalty_s=-1e-4)


# ---------------------------------------------------------------------------
# Argument validation (flat chunk list)
# ---------------------------------------------------------------------------
def test_flat_chunk_list_raises_clear_typeerror():
    topo = TOPOS["2D-SW_SW"]
    chunks = schedule_collective(topo, "AR", 10 * MB, 4, "themis")
    with pytest.raises(TypeError, match=r"wrap it in \[chunks\]"):
        simulate(topo, chunks)
    # the documented fix works
    assert simulate(topo, [chunks]).makespan > 0


def test_unknown_engine_rejected():
    topo = TOPOS["2D-SW_SW"]
    with pytest.raises(ValueError, match="unknown engine"):
        simulate(topo, [], engine="warp")


# ---------------------------------------------------------------------------
# Batch/fleet layer: simulate_batch must match standalone engine="indexed"
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICIES)
def test_simulate_batch_matches_standalone_across_policies(policy):
    rng = random.Random(300 + POLICIES.index(policy))
    scenarios = []
    for tname in ("2D-SW_SW", "3D-SW_SW_SW_hetero"):
        reqs = tuple(_rand_requests(rng, 9))
        for intra in ("SCF", "FIFO"):
            for jitter, seed in ((0.0, 0), (0.12, rng.randrange(100))):
                scenarios.append(Scenario(
                    TOPOS[tname], reqs, policy=policy,
                    chunks_per_collective=6, intra=intra,
                    jitter=jitter, seed=seed))
    for rb, sc in zip(simulate_batch(scenarios), scenarios):
        assert_same(rb, simulate_scenario(sc))


@pytest.mark.parametrize("arb_policy", ARB_POLICIES)
def test_simulate_batch_matches_standalone_under_arbiters(arb_policy):
    rng = random.Random(400 + ARB_POLICIES.index(arb_policy))
    specs = [TenantSpec("a", weight=2.0),
             TenantSpec("b", weight=1.0, priority=1, slo_slowdown=1.5)]
    scenarios = []
    for tname in ("2D-SW_SW", "3D-SW_SW_SW_hetero"):
        reqs = tuple(_rand_requests(rng, 12, tenants=("a", "b")))
        factory = (lambda p=arb_policy: FabricArbiter(
            p, specs, quantum_chunks=4, isolated_latency={"b": 0.001}))
        for jitter, seed in ((0.0, 0), (0.1, 7)):
            scenarios.append(Scenario(
                TOPOS[tname], reqs, chunks_per_collective=8,
                jitter=jitter, seed=seed, arbiter_factory=factory))
    for rb, sc in zip(simulate_batch(scenarios), scenarios):
        assert_same(rb, simulate_scenario(sc))


def test_simulate_batch_water_filling_and_cache_reuse():
    """Shared BatchCaches across successive batches (the topology-search
    usage) must not change results; water-filling exercises multi-class
    chunk groups in the vectorized builder."""
    rng = random.Random(17)
    reqs = tuple(_rand_requests(rng, 8))
    scenarios = [
        Scenario(TOPOS["3D-SW_SW_SW_homo"], reqs, chunks_per_collective=8,
                 water_filling=True, jitter=0.05, seed=s)
        for s in range(4)
    ]
    caches = BatchCaches()
    first = simulate_batch(scenarios, caches=caches)
    again = simulate_batch(scenarios, caches=caches)  # fully warm replay
    for ra, rb, sc in zip(first, again, scenarios):
        assert_same(ra, rb)
        assert_same(ra, simulate_scenario(sc))


def test_vectorized_task_build_matches_scalar():
    rng = random.Random(23)
    for tname in ("2D-SW_SW", "4D-Ring_FC_Ring_SW"):
        topo = TOPOS[tname]
        reqs = _rand_requests(rng, 7, tenants=("a", "b"))
        for wf in (False, True):
            _, groups = simulate_requests(topo, reqs,
                                          chunks_per_collective=5,
                                          water_filling=wf)
            lm = LatencyModel.for_topology(topo)
            pri = [r.priority for r in reqs]
            ten = [r.tenant for r in reqs]
            a = build_task_arrays(lm, groups, pri, ten)
            b = build_task_arrays_vectorized(lm, groups, pri, ten)
            for f in ("n_tasks", "chunk", "stage", "dim", "wire", "fixed",
                      "group", "prio", "tenant", "last", "first_handles",
                      "group_wire"):
                assert getattr(a, f) == getattr(b, f), (tname, wf, f)


def test_vectorized_build_handles_empty_groups():
    topo = TOPOS["2D-SW_SW"]
    lm = LatencyModel.for_topology(topo)
    chunks = schedule_collective(topo, "AR", 8 * MB, 3, "themis")
    groups = [[], chunks, []]
    a = build_task_arrays(lm, groups, [0, 0, 0], ["x", "y", "z"])
    b = build_task_arrays_vectorized(lm, groups, [0, 0, 0], ["x", "y", "z"])
    assert a.group_wire == b.group_wire and a.chunk == b.chunk
    assert a.group == b.group == [1] * a.n_tasks


# ---------------------------------------------------------------------------
# Dependency-gated streams (repro.traffic): engines + batch must agree
# ---------------------------------------------------------------------------
def _rand_graph(rng, n_nodes, tenants=("default",)):
    """Random DAG: request and compute nodes with random back-edges and
    compute delays — the adversarial shape for release-order lockstep."""
    from repro.traffic import TrafficGraph, TrafficNode

    nodes = []
    for i in range(n_nodes):
        n_deps = rng.randrange(0, min(i, 3) + 1) if i else 0
        deps = tuple(f"n{j}" for j in sorted(rng.sample(range(i), n_deps)))
        if rng.random() < 0.25:
            nodes.append(TrafficNode(
                f"n{i}", compute_s=rng.uniform(0, 5e-4), deps=deps,
                start_s=rng.uniform(0, 1e-3) if not deps else 0.0,
                tenant=rng.choice(tenants)))
        else:
            req = CollectiveRequest(
                rng.choice(("AR", "RS", "AG")), rng.uniform(1, 40) * MB,
                priority=rng.choice((0, 0, 1)), stream=f"s{i % 3}",
                tenant=rng.choice(tenants))
            nodes.append(TrafficNode(
                f"n{i}", request=req, compute_s=rng.uniform(0, 2e-4),
                deps=deps,
                start_s=rng.uniform(0, 1e-3) if not deps else 0.0))
    return TrafficGraph(tuple(nodes))


@pytest.mark.parametrize("policy", POLICIES)
def test_engines_agree_on_dependency_graphs(policy):
    from repro.traffic import simulate_traffic

    rng = random.Random(500 + POLICIES.index(policy))
    for tname in ("2D-SW_SW", "3D-SW_SW_SW_hetero", "4D-Ring_FC_Ring_SW"):
        topo = TOPOS[tname]
        graph = _rand_graph(rng, 14)
        for intra in ("SCF", "FIFO"):
            kw = dict(policy=policy, chunks_per_collective=6, intra=intra)
            ri, gi = simulate_traffic(topo, graph, engine="indexed", **kw)
            rr, gr = simulate_traffic(topo, graph, engine="reference", **kw)
            assert_same(ri, rr)
            assert [[c.schedule for c in g] for g in gi] == [
                [c.schedule for c in g] for g in gr]


@pytest.mark.parametrize("arb_policy", ARB_POLICIES)
def test_engines_agree_on_dependency_graphs_under_arbiters(arb_policy):
    from repro.traffic import simulate_traffic

    rng = random.Random(600 + ARB_POLICIES.index(arb_policy))
    specs = [TenantSpec("a", weight=2.0),
             TenantSpec("b", weight=1.0, priority=1, slo_slowdown=1.5)]
    topo = TOPOS["3D-SW_SW_SW_hetero"]
    graph = _rand_graph(rng, 16, tenants=("a", "b"))
    out = {}
    arbs = {}
    for eng in ("indexed", "reference"):
        arb = FabricArbiter(arb_policy, specs, quantum_chunks=4,
                            isolated_latency={"b": 0.001})
        arbs[eng] = arb
        out[eng], _ = simulate_traffic(topo, graph, chunks_per_collective=6,
                                       arbiter=arb, engine=eng)
    assert_same(out["indexed"], out["reference"])
    assert (arbs["indexed"].preempt_count
            == arbs["reference"].preempt_count)


def test_engines_agree_on_dependency_graphs_with_jitter_and_straggler():
    from repro.topology import make_tpu_pod_topology
    from repro.traffic import simulate_traffic

    rng = random.Random(77)
    topo = make_tpu_pod_topology(2, 4, 4, dcn_straggler_sigma=0.4)
    for seed in (0, 3):
        graph = _rand_graph(rng, 12)
        kw = dict(chunks_per_collective=5, jitter=0.1, seed=seed)
        ri, _ = simulate_traffic(topo, graph, engine="indexed", **kw)
        rr, _ = simulate_traffic(topo, graph, engine="reference", **kw)
        assert_same(ri, rr)


def test_simulate_batch_matches_standalone_for_traffic_scenarios():
    from repro.traffic import simulate_traffic

    rng = random.Random(91)
    specs = [TenantSpec("a", weight=2.0), TenantSpec("b")]
    scenarios = []
    for tname in ("2D-SW_SW", "3D-SW_SW_SW_hetero"):
        graph = _rand_graph(rng, 12, tenants=("a", "b"))
        factory = lambda: FabricArbiter("weighted-fair", specs)  # noqa: E731
        for jitter, seed in ((0.0, 0), (0.1, 5)):
            scenarios.append(Scenario(
                TOPOS[tname], traffic=graph, chunks_per_collective=6,
                jitter=jitter, seed=seed))
            scenarios.append(Scenario(
                TOPOS[tname], traffic=graph, chunks_per_collective=6,
                jitter=jitter, seed=seed, arbiter_factory=factory))
    caches = BatchCaches()
    for rb, sc in zip(simulate_batch(scenarios, caches=caches), scenarios):
        assert_same(rb, simulate_scenario(sc))
    # warm replay across batches must not drift either
    for rb, sc in zip(simulate_batch(scenarios, caches=caches), scenarios):
        assert_same(rb, simulate_scenario(sc))
    # standalone traffic path equals an explicit simulate_traffic call
    sc0 = scenarios[0]
    res, _ = simulate_traffic(sc0.topology, sc0.traffic,
                              chunks_per_collective=6)
    assert_same(res, simulate_scenario(sc0))


def test_scenario_rejects_both_requests_and_traffic():
    from repro.traffic import from_requests

    reqs = (CollectiveRequest("AR", MB),)
    with pytest.raises(ValueError, match="not both"):
        Scenario(TOPOS["2D-SW_SW"], reqs, traffic=from_requests(reqs))
    with pytest.raises(ValueError, match="requests or traffic"):
        Scenario(TOPOS["2D-SW_SW"])  # neither is an empty sweep point


# ---------------------------------------------------------------------------
# Scheduler reuse contract (simulate_requests(scheduler=...))
# ---------------------------------------------------------------------------
def test_shared_scheduler_is_bit_identical_and_does_not_leak_state():
    topo = TOPOS["3D-SW_SW_SW_hetero"]
    rng = random.Random(5)
    streams = [_rand_requests(rng, 8) for _ in range(3)]
    fresh = [simulate_requests(topo, reqs, chunks_per_collective=6)
             for reqs in streams]
    shared = ThemisScheduler(LatencyModel.for_topology(topo), "themis")
    # Pre-load the caller's tracker: the calls below must not disturb it.
    shared.tracker.begin_collective("AR")
    caller_loads = shared.tracker.get_loads()
    reused = [simulate_requests(topo, reqs, chunks_per_collective=6,
                                scheduler=shared)
              for reqs in streams]
    for (rf, gf), (rr, gr) in zip(fresh, reused):
        assert_same(rf, rr)
        assert [[c.schedule for c in g] for g in gf] == [
            [c.schedule for c in g] for g in gr]
    assert shared.tracker.get_loads() == caller_loads
    # memo caches actually persisted across the calls (the point of reuse)
    assert shared._delta_cache


def test_shared_scheduler_rejects_foreign_topology():
    sched = ThemisScheduler(
        LatencyModel.for_topology(TOPOS["2D-SW_SW"]), "themis")
    with pytest.raises(ValueError, match="built for topology"):
        simulate_requests(TOPOS["3D-SW_SW_SW_homo"],
                          [CollectiveRequest("AR", MB)], scheduler=sched)


# ---------------------------------------------------------------------------
# Scaling smoke: 4x stage-ops must cost <= ~6x wall time
# ---------------------------------------------------------------------------
def test_indexed_engine_scales_near_linearly():
    topo = TOPOS["3D-SW_SW_SW_homo"]

    def run_stream(n_req, n_chunk):
        reqs = [CollectiveRequest("AR", 20 * MB, issue_time=i * 1e-4)
                for i in range(n_req)]
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            simulate_requests(topo, reqs, chunks_per_collective=n_chunk,
                              engine="indexed")
            best = min(best, time.perf_counter() - t0)
        return best

    # Wall-clock gates flake on loaded shared runners; re-measure once
    # before failing so only a *persistent* superlinear blowup trips it.
    for attempt in range(2):
        t_small = run_stream(64, 16)
        t_big = run_stream(128, 32)  # 4x the stage-ops
        if t_big / t_small <= 6.0:
            break
    assert t_big / t_small <= 6.0, (
        f"4x stage-ops cost {t_big / t_small:.1f}x wall time "
        f"({t_small * 1e3:.1f}ms -> {t_big * 1e3:.1f}ms)")


# ---------------------------------------------------------------------------
# Observability: an armed flight recorder must not perturb the simulation
# ---------------------------------------------------------------------------
def _assert_trace_faithful(trc, res, topo):
    """The trace must reproduce the engine's own bookkeeping.  Wire/busy
    use isclose: preemption amends a trace record with one fused
    ``(w - cut)`` subtraction where the engine does ``+= w`` then
    ``-= cut``, so sums agree to ulps, not bits."""
    wire = trc.service_wire()
    busy = trc.service_busy()
    for d in range(topo.num_dims):
        assert wire[d] == pytest.approx(res.dim_wire_bytes[d],
                                        rel=1e-12, abs=1e-12)
        assert busy[d] == pytest.approx(res.dim_busy[d],
                                        rel=1e-12, abs=1e-12)
        assert trc.ops_served(d) == res.dim_op_order[d]
        assert len(trc.services[d]) == len(res.dim_services[d])


@pytest.mark.parametrize("policy", ("baseline", "themis"))
def test_tracing_is_bit_identical_across_engines(policy):
    from repro.obs import Tracer

    rng = random.Random(700 + len(policy))
    for tname in ("2D-SW_SW", "3D-SW_SW_SW_hetero"):
        topo = TOPOS[tname]
        reqs = _rand_requests(rng, 12)
        for eng in ("indexed", "reference"):
            for intra in ("SCF", "FIFO"):
                kw = dict(policy=policy, chunks_per_collective=8,
                          intra=intra, engine=eng)
                plain, _ = simulate_requests(topo, reqs, **kw)
                trc = Tracer()
                traced, _ = simulate_requests(topo, reqs, tracer=trc, **kw)
                assert_same(plain, traced)
                assert trc.engine == eng and trc.finished
                _assert_trace_faithful(trc, traced, topo)


@pytest.mark.parametrize("arb_policy", ARB_POLICIES)
def test_tracing_is_bit_identical_under_arbiters(arb_policy):
    """Arbiter scenarios exercise the preemption amend path and grant
    events; traced runs must still match untraced bit-for-bit on both
    engines, and the two engines' traces must tell the same story."""
    from repro.obs import Tracer

    rng = random.Random(800 + ARB_POLICIES.index(arb_policy))
    specs = [TenantSpec("a", weight=2.0),
             TenantSpec("b", weight=1.0, priority=1, slo_slowdown=1.5)]
    topo = TOPOS["2D-SW_SW"]
    reqs = _rand_requests(rng, 14, tenants=("a", "b"))
    traces = {}
    for eng in ("indexed", "reference"):
        kw = dict(chunks_per_collective=8, engine=eng)
        arb = FabricArbiter(arb_policy, specs, isolated_latency={"b": 0.001})
        plain, _ = simulate_fabric(topo, reqs, arbiter=arb, **kw)
        arb = FabricArbiter(arb_policy, specs, isolated_latency={"b": 0.001})
        trc = Tracer()
        traced, _ = simulate_fabric(topo, reqs, arbiter=arb, tracer=trc, **kw)
        assert_same(plain, traced)
        _assert_trace_faithful(trc, traced, topo)
        # one grant per service start while an arbiter is installed
        assert len(trc.grants) == sum(len(s) for s in trc.services)
        traces[eng] = trc
    for field in ("grants", "preempts", "enqueues", "releases"):
        assert getattr(traces["indexed"], field) == pytest.approx(
            getattr(traces["reference"], field))


def test_tracing_on_dependency_graphs_records_edges_and_releases():
    from repro.obs import Tracer
    from repro.traffic import simulate_traffic

    rng = random.Random(900)
    topo = TOPOS["3D-SW_SW_SW_hetero"]
    graph = _rand_graph(rng, 14)
    n_edges = sum(len(n.deps) for n in graph.nodes)
    for eng in ("indexed", "reference"):
        kw = dict(chunks_per_collective=6, engine=eng)
        plain, _ = simulate_traffic(topo, graph, **kw)
        trc = Tracer()
        traced, _ = simulate_traffic(topo, graph, tracer=trc, **kw)
        assert_same(plain, traced)
        assert len(trc.dep_edges) == n_edges
        # every node (request or compute) is released exactly once
        assert sorted(g for g, _ in trc.releases) == list(
            range(len(graph.nodes)))
        _assert_trace_faithful(trc, traced, topo)


def test_trace_schema_round_trips_through_chrome_export(tmp_path):
    """Export -> JSON file -> parse: event counts must match the
    recording SimResult's bookkeeping."""
    from repro.obs import Tracer, parse_chrome_trace
    from repro.traffic import simulate_traffic

    rng = random.Random(910)
    topo = TOPOS["2D-SW_SW"]
    graph = _rand_graph(rng, 12, tenants=("a", "b"))
    specs = [TenantSpec("a", weight=2.0), TenantSpec("b")]
    arb = FabricArbiter("weighted-fair", specs, quantum_chunks=4)
    trc = Tracer()
    res, _ = simulate_traffic(topo, graph, chunks_per_collective=6,
                              arbiter=arb, tracer=trc, engine="indexed")
    path = tmp_path / "run.trace.json"
    trc.save(path)
    parsed = parse_chrome_trace(path)
    assert parsed == parse_chrome_trace(trc.to_chrome_trace())
    assert parsed["groups"] == len(res.group_finish)
    assert parsed["dims"] == topo.num_dims
    for d in range(topo.num_dims):
        assert parsed["services_per_dim"][d] == len(res.dim_services[d])
    assert parsed["grants"] == len(trc.grants)
    assert parsed["preempts"] == len(trc.preempts)
    assert parsed["flows"] == len(trc.dep_edges)


def test_tracer_refuses_reuse_and_unfinished_export():
    from repro.obs import BwTimeline, Tracer

    trc = Tracer()
    reqs = [CollectiveRequest("AR", 4 * MB)]
    simulate_requests(TOPOS["2D-SW_SW"], reqs, chunks_per_collective=4,
                      tracer=trc)
    with pytest.raises(RuntimeError, match="one Tracer records one"):
        simulate_requests(TOPOS["2D-SW_SW"], reqs, chunks_per_collective=4,
                          tracer=trc)
    fresh = Tracer()
    with pytest.raises(RuntimeError, match="finished run"):
        fresh.to_chrome_trace()
    with pytest.raises(ValueError, match="finished run"):
        BwTimeline.from_tracer(fresh)


def test_batch_tracer_factory_arms_one_tracer_per_scenario():
    from repro.obs import Tracer

    rng = random.Random(920)
    reqs = tuple(_rand_requests(rng, 8))
    tracers = []

    def factory():
        t = Tracer()
        tracers.append(t)
        return t

    scenarios = [
        Scenario(TOPOS[tname], reqs, chunks_per_collective=6,
                 tracer_factory=factory)
        for tname in ("2D-SW_SW", "3D-SW_SW_SW_hetero")
    ]
    results = simulate_batch(scenarios)
    plain = simulate_batch([
        Scenario(TOPOS[tname], reqs, chunks_per_collective=6)
        for tname in ("2D-SW_SW", "3D-SW_SW_SW_hetero")])
    assert len(tracers) == 2
    for res, ref, trc, sc in zip(results, plain, tracers, scenarios):
        assert_same(res, ref)
        assert trc.finished
        _assert_trace_faithful(trc, res, sc.topology)


# ---------------------------------------------------------------------------
# Faults x dependency-gated streams: release lockstep across retry/preempt
# ---------------------------------------------------------------------------
def test_dependency_release_survives_retried_predecessor():
    """A successor must release only after its predecessor's last chunk
    actually finishes — including when that predecessor's chunks timed out
    on a dead dim and retried (satellite: faults x deps release)."""
    from repro.faults import DimOutage, FaultSchedule, RetryPolicy
    from repro.traffic import TrafficGraph, TrafficNode, simulate_traffic

    topo = TOPOS["2D-SW_SW"]
    graph = TrafficGraph(tuple(
        [TrafficNode("head", request=CollectiveRequest("AR", 16 * MB),
                     start_s=0.0)]
        + [TrafficNode(f"tail{i}",
                       request=CollectiveRequest("AR", 4 * MB),
                       deps=("head",), compute_s=1e-5)
           for i in range(3)]))
    faults = FaultSchedule(
        events=(DimOutage(dim=1, start=5e-5, end=6e-4),),
        retry=RetryPolicy(timeout_s=4e-5, backoff_s=2e-5, max_attempts=20))
    out = {}
    for eng in ("indexed", "reference"):
        out[eng], _ = simulate_traffic(
            topo, graph, chunks_per_collective=6, engine=eng,
            check_invariants=True, faults=faults)
    assert_same(out["indexed"], out["reference"])
    res = out["indexed"]
    assert sum(res.group_retries) > 0          # the outage bit the head
    assert not res.failed_groups
    head_finish = res.group_finish[0]
    assert head_finish > 6e-4                  # head stalled on the outage
    for i in range(1, 4):                      # tails released after it
        assert res.group_issue[i] == pytest.approx(head_finish + 1e-5)
        assert res.group_finish[i] >= res.group_issue[i]


def test_dependency_release_survives_failed_predecessor():
    """Retry exhaustion on a predecessor must not deadlock its
    successors' release bookkeeping — the chain fails transitively and
    both engines account it identically."""
    from repro.faults import DimOutage, FaultSchedule, RetryPolicy
    from repro.traffic import TrafficGraph, TrafficNode, simulate_traffic

    topo = TOPOS["2D-SW_SW"]
    graph = TrafficGraph((
        TrafficNode("head", request=CollectiveRequest("AR", 16 * MB),
                    start_s=0.0),
        TrafficNode("mid", request=CollectiveRequest("AR", 4 * MB),
                    deps=("head",)),
        TrafficNode("leaf", request=CollectiveRequest("AR", 4 * MB),
                    deps=("mid",)),
        TrafficNode("free", request=CollectiveRequest("AR", 4 * MB),
                    start_s=0.0),
    ))
    faults = FaultSchedule(
        events=(DimOutage(dim=1, start=5e-5),),   # permanent
        retry=RetryPolicy(timeout_s=4e-5, backoff_s=2e-5, max_attempts=2))
    out = {}
    for eng in ("indexed", "reference"):
        out[eng], _ = simulate_traffic(
            topo, graph, chunks_per_collective=6, engine=eng,
            check_invariants=True, faults=faults)
    assert_same(out["indexed"], out["reference"])
    failed = {g for g, _ in out["indexed"].failed_groups}
    assert 0 in failed                          # the head exhausted retries
    assert {1, 2} <= failed                     # the chain failed with it


@pytest.mark.parametrize("arb_policy", ["weighted-fair", "strict-priority"])
def test_dependency_release_survives_preempted_predecessor(arb_policy):
    """Faults x arbiter preemption x deps: a predecessor whose service is
    preempted (and re-rated by a mid-flight degradation) still releases
    its successors in lockstep across engines."""
    from repro.faults import BwDegradation, FaultSchedule
    from repro.traffic import simulate_traffic

    rng = random.Random(41)
    topo = TOPOS["2D-SW_SW"]
    graph = _rand_graph(rng, 12, tenants=("a", "b"))
    specs = [TenantSpec("a", weight=1.0),
             TenantSpec("b", weight=3.0, priority=2)]
    faults = FaultSchedule(events=(
        BwDegradation(dim=1, start=1e-4, end=8e-4, factor=0.2),
        BwDegradation(dim=0, start=2e-4, end=6e-4, factor=0.5),
    ))
    out = {}
    arbs = {}
    for eng in ("indexed", "reference"):
        arb = FabricArbiter(arb_policy, specs, quantum_chunks=3,
                            preemption=True)
        arbs[eng] = arb
        out[eng], _ = simulate_traffic(
            topo, graph, chunks_per_collective=6, arbiter=arb, engine=eng,
            check_invariants=True, faults=faults)
    assert_same(out["indexed"], out["reference"])
    assert (arbs["indexed"].preempt_count
            == arbs["reference"].preempt_count)


# ---------------------------------------------------------------------------
# Serving fleet (repro.fleet): open-loop arrivals + admission control
# ---------------------------------------------------------------------------
_FLEET_COSTS = dict(prefill_bytes=2e9, decode_bytes=64e6,
                    prefill_s=5e-3, decode_s=2e-4, prefill_ops=2)


def _fleet_graph(rate=250.0, horizon=0.2, seed=5):
    from repro.fleet import FleetTenant, MMPPArrivals, PoissonArrivals
    from repro.fleet import fleet_traffic

    tenants = [
        FleetTenant("web", PoissonArrivals(rate, seed=seed),
                    serving=dict(gen_tokens=6, **_FLEET_COSTS), weight=2.0),
        FleetTenant("batch",
                    MMPPArrivals((0.2 * rate, 2.0 * rate), (0.04, 0.04),
                                 seed=seed + 1),
                    serving=dict(gen_tokens=4, **_FLEET_COSTS), priority=-1),
    ]
    return fleet_traffic(tenants, horizon_s=horizon)


def test_arrival_processes_are_seed_deterministic_and_restateable():
    """Same seed -> bit-identical draws, both across fresh instances and
    across repeated times() calls on one instance (the generators keep no
    RNG state between calls)."""
    from repro.fleet import DiurnalArrivals, MMPPArrivals, PoissonArrivals

    procs = [
        PoissonArrivals(120.0, seed=3),
        DiurnalArrivals(120.0, amplitude=0.7, period_s=0.5, seed=4),
        MMPPArrivals((40.0, 400.0), (0.05, 0.02), seed=5),
    ]
    for p in procs:
        a = p.times(horizon_s=0.4)
        assert a == p.times(horizon_s=0.4)          # re-callable
        assert all(t2 > t1 for t1, t2 in zip(a, a[1:]))
    assert (PoissonArrivals(120.0, seed=3).times(horizon_s=0.4)
            != PoissonArrivals(120.0, seed=9).times(horizon_s=0.4))


def test_fleet_traffic_is_bit_identical_across_engines():
    from repro.traffic import simulate_traffic

    graph = _fleet_graph()
    assert graph.nodes == _fleet_graph().nodes      # graph build determinism
    ri, _ = simulate_traffic(TOPOS["2D-SW_SW"], graph, engine="indexed",
                             check_invariants=True)
    rr, _ = simulate_traffic(TOPOS["2D-SW_SW"], graph, engine="reference",
                             check_invariants=True)
    assert_same(ri, rr)


@pytest.mark.parametrize("adm_policy", ["reject-newest",
                                        "shed-lowest-priority",
                                        "deadline-aware"])
def test_engines_agree_under_admission_control(adm_policy):
    """Overload scenarios that genuinely shed must stay bit-identical
    indexed vs reference with the sanitizer armed — including the shed
    log itself (covered by diff_fields)."""
    from repro.fleet import AdmissionController, unit_of_group
    from repro.traffic import simulate_traffic

    graph = _fleet_graph(rate=350.0)
    uo, up = unit_of_group(graph)
    kw = dict(policy=adm_policy, capacity=3, unit_priority=up)
    if adm_policy == "deadline-aware":
        kw.update(deadline_s=0.05, est_service_s=0.01)
    out = {}
    for eng in ("indexed", "reference"):
        adm = AdmissionController(uo, **kw)
        out[eng], _ = simulate_traffic(
            TOPOS["2D-SW_SW"], graph, engine=eng, admission=adm,
            check_invariants=True)
        assert adm.n_shed > 0                      # overload engaged it
    assert_same(out["indexed"], out["reference"])
    assert out["indexed"].shed_groups              # first-class shed log


def test_admission_decisions_invariant_under_tracer():
    """Arming the flight recorder must not move a single admission
    decision (hooks append only; no seq/RNG consumption), and the trace
    must record every shed and one admit per admitted unit."""
    from repro.fleet import AdmissionController, unit_of_group
    from repro.obs import Tracer
    from repro.traffic import simulate_traffic

    graph = _fleet_graph(rate=350.0)
    uo, _up = unit_of_group(graph)
    for eng in ("indexed", "reference"):
        adm = AdmissionController(uo, policy="reject-newest", capacity=3)
        plain, _ = simulate_traffic(TOPOS["2D-SW_SW"], graph, engine=eng,
                                    admission=adm)
        adm_t = AdmissionController(uo, policy="reject-newest", capacity=3)
        trc = Tracer()
        traced, _ = simulate_traffic(TOPOS["2D-SW_SW"], graph, engine=eng,
                                     admission=adm_t, tracer=trc)
        assert_same(plain, traced)
        assert adm_t.n_shed == adm.n_shed
        assert adm_t.shed_units == adm.shed_units
        assert len(trc.sheds) == len(traced.shed_groups)
        assert len(trc.admits) == adm_t.n_admitted
        counts = trc.event_counts()
        assert counts["sheds"] == len(trc.sheds)
        assert counts["admits"] == len(trc.admits)


def test_admission_composes_with_faults_across_engines():
    """Overload x outage: demand-side sheds and fabric-side retries in
    one run, bit-identical across engines with the sanitizer armed, and
    the two loss ledgers stay disjoint."""
    from repro.faults import DimOutage, FaultSchedule, RetryPolicy
    from repro.fleet import AdmissionController, unit_of_group
    from repro.traffic import simulate_traffic

    graph = _fleet_graph(rate=350.0)
    uo, _up = unit_of_group(graph)
    faults = FaultSchedule(
        events=(DimOutage(dim=1, start=0.03, end=0.06),),
        retry=RetryPolicy(timeout_s=0.02, backoff_s=0.005, max_attempts=6))
    out = {}
    for eng in ("indexed", "reference"):
        adm = AdmissionController(uo, policy="reject-newest", capacity=3)
        out[eng], _ = simulate_traffic(
            TOPOS["2D-SW_SW"], graph, engine=eng, admission=adm,
            faults=faults, check_invariants=True)
    assert_same(out["indexed"], out["reference"])
    res = out["indexed"]
    assert res.shed_groups and sum(res.group_retries) > 0
    shed = {g for g, _ in res.shed_groups}
    assert shed.isdisjoint({g for g, _ in res.failed_groups})


# ---------------------------------------------------------------------------
# Compiled (cohort-vectorized) engine: bit-identity, fallback, jit kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICIES)
def test_compiled_agrees_across_policies_and_topologies(policy):
    """Numpy-cohort path vs indexed: bit-identical on randomized online
    streams across every policy and a spread of fabric shapes."""
    rng = random.Random(7000 + POLICIES.index(policy))
    for tname in ("2D-SW_SW", "3D-SW_SW_SW_hetero", "4D-Ring_FC_Ring_SW"):
        topo = TOPOS[tname]
        reqs = _rand_requests(rng, 12)
        for intra in ("SCF", "FIFO"):
            kw = dict(policy=policy, chunks_per_collective=6, intra=intra)
            ri, _ = simulate_requests(topo, reqs, engine="indexed", **kw)
            rc, _ = simulate_requests(topo, reqs, engine="compiled", **kw)
            assert_same(ri, rc)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_compiled_agrees_with_jitter_seeds(seed):
    """Jitter + DCN-straggler draws come off the same RNG points, so even
    stochastic runs stay bit-identical per seed."""
    from repro.topology import make_tpu_pod_topology

    topo = make_tpu_pod_topology(2, 4, 4, dcn_straggler_sigma=0.4)
    rng = random.Random(7100 + seed)
    reqs = _rand_requests(rng, 10)
    for intra in ("SCF", "FIFO"):
        kw = dict(chunks_per_collective=6, intra=intra)
        ri, _ = simulate_requests(topo, reqs, engine="indexed", **kw)
        rc, _ = simulate_requests(topo, reqs, engine="compiled", **kw)
        assert_same(ri, rc)
        a = simulate(topo, [schedule_collective(topo, "AR", 8 * MB, 8,
                                                "themis")],
                     jitter=0.07, seed=seed, intra=intra, engine="indexed")
        b = simulate(topo, [schedule_collective(topo, "AR", 8 * MB, 8,
                                                "themis")],
                     jitter=0.07, seed=seed, intra=intra, engine="compiled")
        assert_same(a, b)


def test_compiled_agrees_on_dependency_dags():
    """Dependency gating is on the compiled fast path (not a fallback):
    random DAGs must match the indexed engine field-for-field."""
    from repro.traffic import simulate_traffic

    rng = random.Random(7200)
    for tname in ("2D-SW_SW", "3D-SW_SW_SW_hetero"):
        topo = TOPOS[tname]
        graph = _rand_graph(rng, 14)
        for intra in ("SCF", "FIFO"):
            kw = dict(chunks_per_collective=6, intra=intra)
            ri, gi = simulate_traffic(topo, graph, engine="indexed", **kw)
            rc, gc = simulate_traffic(topo, graph, engine="compiled", **kw)
            assert_same(ri, rc)
            assert [[c.schedule for c in g] for g in gi] == [
                [c.schedule for c in g] for g in gc]


def test_simulate_batch_compiled_matches_standalone():
    """Scenario.engine="compiled" rides the shared-cache batch machinery
    and still matches both the standalone call and the indexed engine."""
    rng = random.Random(7300)
    reqs = tuple(_rand_requests(rng, 10))
    topo = TOPOS["3D-SW_SW_SW_hetero"]
    scs = [Scenario(topology=topo, requests=reqs, seed=s, jitter=0.05,
                    engine=eng)
           for s in (0, 1) for eng in ("compiled", "indexed")]
    batch = simulate_batch(scs, caches=BatchCaches())
    for sc, res in zip(scs, batch):
        assert_same(res, simulate_scenario(sc))
    # same seed, different engine -> identical fields
    assert_same(batch[0], batch[1])
    assert_same(batch[2], batch[3])


def test_compiled_fallback_signal_is_deterministic_and_warning_free():
    """Features off the fast path fall back to indexed: bit-identical
    result, no warning, and exactly one documented signal."""
    import warnings

    from repro.core import engine_compiled as ec

    topo = TOPOS["2D-SW_SW"]
    groups = [schedule_collective(topo, "AR", 10 * MB, 6, "themis")]
    ec.reset_fallbacks()
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ri = simulate(topo, groups, engine="indexed", check_invariants=True)
        rc = simulate(topo, groups, engine="compiled", check_invariants=True)
    assert_same(ri, rc)
    assert ec.LAST_FALLBACK == "check_invariants"
    assert ec.FALLBACK_COUNTS == {"check_invariants": 1}
    # an eligible run leaves the signal untouched
    ec.reset_fallbacks()
    simulate(topo, groups, engine="compiled")
    assert ec.LAST_FALLBACK is None and ec.FALLBACK_COUNTS == {}
    # blocker precedence is documented check order
    assert ec.fast_path_blocker(tracer=object(),
                                check_invariants=True) == "tracer"


def test_unknown_engine_error_lists_valid_engines():
    topo = TOPOS["2D-SW_SW"]
    with pytest.raises(ValueError) as ei:
        simulate(topo, [], engine="turbo")
    for name in ("indexed", "compiled", "reference"):
        assert name in str(ei.value)


def test_wave_kernel_matches_compiled_within_tolerance():
    """The jax.jit wave kernel is numeric, not bit-exact: on a wave-
    shaped stream its done times must agree with the compiled engine
    within JIT_RTOL."""
    from repro.core import engine_compiled as ec

    if not ec.jit_available():
        pytest.skip("jax not importable")
    # Wave-shaped stream: baseline RS visits each dim exactly once in one
    # fixed order, so every rank maps to a distinct dim and the kernel's
    # rank barriers are exact (the engine is then the oracle up to the
    # kernel's float32 accumulation).
    topo = TOPOS["3D-SW_SW_SW_hetero"]
    groups = [[c] for c in
              schedule_collective(topo, "RS", 24 * MB, 12, "baseline")]
    issue = [0.0] * len(groups)
    res = simulate(topo, groups, engine="compiled", issue_times=issue,
                   fusion=False)
    done = ec.wave_done_times(*ec.wave_arrays(topo, groups, issue))
    assert done.shape == (len(groups),)
    for g, t in enumerate(res.group_finish):
        assert done[g] == pytest.approx(t, rel=ec.JIT_RTOL)
