"""Tests for the fault-injection fabric (``repro.faults``): timeline
validation, BW degradation / outage / flap / straggler semantics in both
engines, retry + failure accounting, Themis re-planning under degraded
bandwidth, and the tracer's fault-event round trip."""
import math
import random

import pytest

from repro.core.requests import CollectiveRequest
from repro.core.chunking import Chunk
from repro.core.simulator import simulate, simulate_requests
from repro.faults import (
    BwDegradation,
    DimOutage,
    FaultSchedule,
    LinkFlap,
    RetryPolicy,
    StragglerBurst,
    degraded_topology,
    make_replanner,
)
from repro.obs import Tracer
from repro.obs.tracer import parse_chrome_trace
from repro.topology import make_table2_topologies

TOPOS = make_table2_topologies()
MB = 1e6


def assert_same(res_idx, res_ref):
    assert res_idx.diff_fields(res_ref) == []


def _reqs(n=4, size=8.0 * MB, gap=2e-4):
    return [CollectiveRequest("AR", size, issue_time=i * gap)
            for i in range(n)]


def _run(topo, reqs, eng, **kw):
    res, _ = simulate_requests(topo, reqs, chunks_per_collective=8,
                               engine=eng, check_invariants=True, **kw)
    return res


# ---------------------------------------------------------------------------
# FaultSchedule validation
# ---------------------------------------------------------------------------
def test_event_window_validation():
    with pytest.raises(ValueError, match="negative start"):
        BwDegradation(dim=0, start=-1.0, end=1.0, factor=0.5)
    with pytest.raises(ValueError, match="empty or inverted"):
        BwDegradation(dim=0, start=1.0, end=1.0, factor=0.5)
    with pytest.raises(ValueError, match="NaN"):
        DimOutage(dim=0, start=float("nan"))
    with pytest.raises(ValueError, match="factor"):
        BwDegradation(dim=0, start=0.0, end=1.0, factor=0.0)
    with pytest.raises(ValueError, match="factor"):
        BwDegradation(dim=0, start=0.0, end=1.0, factor=1.5)
    with pytest.raises(ValueError, match="sigma"):
        StragglerBurst(dim=0, start=0.0, end=1.0, sigma=0.0)
    with pytest.raises(ValueError, match="period_s"):
        LinkFlap(dim=0, start=0.0, down_s=2.0, period_s=1.0, count=2)
    with pytest.raises(ValueError, match="count"):
        LinkFlap(dim=0, start=0.0, down_s=1.0, period_s=2.0, count=0)
    with pytest.raises(ValueError, match="timeout_s"):
        RetryPolicy(timeout_s=0.0)
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)


def test_compile_rejects_out_of_range_dims_and_overlaps():
    with pytest.raises(ValueError, match="out of range"):
        FaultSchedule(events=(
            BwDegradation(dim=5, start=0.0, end=1.0, factor=0.5),
        )).compile(2)
    # overlapping BW-family windows on one dim (degradation x outage)
    with pytest.raises(ValueError, match="overlapping BW"):
        FaultSchedule(events=(
            BwDegradation(dim=0, start=0.0, end=1.0, factor=0.5),
            DimOutage(dim=0, start=0.5, end=0.7),
        )).compile(2)
    # straggler bursts may not overlap each other...
    with pytest.raises(ValueError, match="overlapping straggler"):
        FaultSchedule(events=(
            StragglerBurst(dim=0, start=0.0, end=1.0, sigma=0.1),
            StragglerBurst(dim=0, start=0.5, end=2.0, sigma=0.2),
        )).compile(2)
    # ...but a burst may overlap a BW window, touching windows are fine,
    # and different dims never conflict
    flt = FaultSchedule(events=(
        BwDegradation(dim=0, start=0.0, end=1.0, factor=0.5),
        BwDegradation(dim=0, start=1.0, end=2.0, factor=0.25),
        StragglerBurst(dim=0, start=0.5, end=1.5, sigma=0.1),
        DimOutage(dim=1, start=0.5, end=0.7),
    )).compile(2)
    assert flt.num_dims == 2
    assert [b.t for b in flt.boundaries] == sorted(
        b.t for b in flt.boundaries)


def test_retry_policy_backoff_grows():
    rp = RetryPolicy(timeout_s=1.0, backoff_s=0.5, multiplier=2.0,
                     jitter=0.0)
    assert rp.delay(1) == pytest.approx(0.5)
    assert rp.delay(3) == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# simulate() input validation (satellite)
# ---------------------------------------------------------------------------
def test_simulate_rejects_bad_issue_times_and_sizes():
    topo = TOPOS["2D-SW_SW"]
    from repro.core.scheduler import schedule_collective

    chunks = schedule_collective(topo, "AR", 4 * MB, 4, "themis")
    with pytest.raises(ValueError, match="issue_times"):
        simulate(topo, [chunks], issue_times=[-1e-6])
    with pytest.raises(ValueError, match="issue_times"):
        simulate(topo, [chunks], issue_times=[float("nan")])
    bad = [Chunk(index=0, size_bytes=float("nan"))]
    with pytest.raises(ValueError, match="size_bytes"):
        simulate(topo, [bad])


def test_simulate_rejects_inconsistent_fault_arguments():
    topo = TOPOS["2D-SW_SW"]
    faults = FaultSchedule(events=(
        BwDegradation(dim=0, start=1e-4, end=1.0, factor=0.5),))
    with pytest.raises(ValueError, match="replanner requires faults"):
        simulate(topo, [], replanner=lambda now, f, p: {})
    with pytest.raises(ValueError, match="mutually exclusive"):
        simulate(topo, [], faults=faults, enforced_order=[[] for _ in
                                                          topo.dims])
    with pytest.raises(ValueError, match="compiled for"):
        simulate(topo, [], faults=faults.compile(3))
    with pytest.raises(ValueError, match="replan=True requires faults"):
        simulate_requests(topo, _reqs(1), replan=True)


# ---------------------------------------------------------------------------
# Degradation / outage / straggler semantics, differentially
# ---------------------------------------------------------------------------
def test_degradation_slows_run_and_engines_agree():
    topo = TOPOS["2D-SW_SW"]
    reqs = _reqs()
    clean = _run(topo, reqs, "indexed")
    faults = FaultSchedule(events=(
        BwDegradation(dim=1, start=1e-4, end=1.0, factor=0.25),))
    ri = _run(topo, reqs, "indexed", faults=faults)
    rr = _run(topo, reqs, "reference", faults=faults)
    assert_same(ri, rr)
    assert ri.makespan > clean.makespan        # it got slower...
    assert not ri.failed_groups                # ...but everything finished
    # bytes conservation across re-rating is asserted by the armed
    # sanitizer; spot-check the accounting is unchanged
    assert ri.dim_wire_bytes == pytest.approx(clean.dim_wire_bytes)


def test_degradation_that_ends_mid_run_rerates_back_up():
    topo = TOPOS["2D-SW_SW"]
    reqs = _reqs()
    forever = FaultSchedule(events=(
        BwDegradation(dim=1, start=1e-4, end=1.0, factor=0.25),))
    brief = FaultSchedule(events=(
        BwDegradation(dim=1, start=1e-4, end=4e-4, factor=0.25),))
    res_forever = _run(topo, reqs, "indexed", faults=forever)
    res_brief_i = _run(topo, reqs, "indexed", faults=brief)
    res_brief_r = _run(topo, reqs, "reference", faults=brief)
    assert_same(res_brief_i, res_brief_r)
    assert res_brief_i.makespan < res_forever.makespan


def test_outage_retries_then_recovers():
    topo = TOPOS["2D-SW_SW"]
    reqs = _reqs()
    faults = FaultSchedule(
        events=(DimOutage(dim=1, start=1e-4, end=6e-4),),
        retry=RetryPolicy(timeout_s=5e-5, backoff_s=2e-5, max_attempts=10))
    ri = _run(topo, reqs, "indexed", faults=faults)
    rr = _run(topo, reqs, "reference", faults=faults)
    assert_same(ri, rr)
    assert sum(ri.group_retries) > 0           # timeouts fired
    assert not ri.failed_groups                # but the outage ended in time
    assert len(ri.group_finish) == len(reqs)


def test_permanent_outage_exhausts_retries_and_fails_groups():
    topo = TOPOS["2D-SW_SW"]
    reqs = _reqs()
    faults = FaultSchedule(
        events=(DimOutage(dim=1, start=1e-4),),   # end=inf: never recovers
        retry=RetryPolicy(timeout_s=5e-5, backoff_s=2e-5, max_attempts=3))
    ri = _run(topo, reqs, "indexed", faults=faults)
    rr = _run(topo, reqs, "reference", faults=faults)
    assert_same(ri, rr)
    assert ri.failed_groups                     # retry exhaustion
    for g, t in ri.failed_groups:
        assert 0 <= g < len(reqs) and t >= 1e-4
        assert ri.group_retries[g] >= 3


def test_straggler_burst_is_deterministic_and_engines_agree():
    topo = TOPOS["2D-SW_SW"]
    reqs = _reqs()
    faults = FaultSchedule(events=(
        StragglerBurst(dim=0, start=0.0, end=1.0, sigma=0.5),))
    a = _run(topo, reqs, "indexed", faults=faults)
    b = _run(topo, reqs, "indexed", faults=faults)
    assert_same(a, b)                           # same seed -> same draws
    r = _run(topo, reqs, "reference", faults=faults)
    assert_same(a, r)
    clean = _run(topo, reqs, "indexed")
    assert a.makespan != clean.makespan


def test_link_flap_outage_windows_fire_in_sequence():
    topo = TOPOS["2D-SW_SW"]
    reqs = _reqs(6)
    faults = FaultSchedule(
        events=(LinkFlap(dim=1, start=1e-4, down_s=5e-5, period_s=3e-4,
                         count=3),),
        retry=RetryPolicy(timeout_s=3e-5, backoff_s=2e-5, max_attempts=20))
    ri = _run(topo, reqs, "indexed", faults=faults)
    rr = _run(topo, reqs, "reference", faults=faults)
    assert_same(ri, rr)
    assert not ri.failed_groups


# ---------------------------------------------------------------------------
# Re-planning under degraded bandwidth
# ---------------------------------------------------------------------------
def test_degraded_topology_scales_link_bw():
    topo = TOPOS["2D-SW_SW"]
    deg = degraded_topology(topo, [1.0, 0.25])
    assert deg.num_dims == topo.num_dims
    assert deg.dims[0].link_gbps == pytest.approx(topo.dims[0].link_gbps)
    assert deg.dims[1].link_gbps == pytest.approx(
        0.25 * topo.dims[1].link_gbps)
    # a fully-dead dim is floored, not zeroed (latency math stays finite)
    floored = degraded_topology(topo, [0.0, 1.0])
    assert floored.dims[0].link_gbps > 0


def test_replanning_beats_no_replanning_under_degradation():
    """The paper's Algorithm-1 payoff: re-ordering RS/AG stages against
    post-fault BW places the slow dim where chunks are smallest."""
    topo = TOPOS["2D-SW_SW"]
    reqs = [CollectiveRequest("AR", float(1 << 26), issue_time=i * 1e-4)
            for i in range(6)]
    faults = FaultSchedule(events=(
        BwDegradation(dim=1, start=1.5e-4, end=1.0, factor=0.1),))

    def run(eng, replan):
        res, _ = simulate_requests(
            topo, reqs, chunks_per_collective=16, engine=eng,
            check_invariants=True, faults=faults, replan=replan)
        return res

    plain = run("indexed", False)
    replanned_i = run("indexed", True)
    replanned_r = run("reference", True)
    assert_same(replanned_i, replanned_r)
    assert plain.makespan / replanned_i.makespan > 1.15


def test_make_replanner_reschedules_pending_groups():
    topo = TOPOS["2D-SW_SW"]
    from repro.core.scheduler import schedule_collective

    chunks = schedule_collective(topo, "AR", float(1 << 24), 8, "themis")
    rp = make_replanner(topo, "themis")
    out = rp(1e-4, [1.0, 0.1], [(0, 2e-4, chunks)])
    assert set(out) == {0}
    assert len(out[0]) == len(chunks)
    for oc, nc in zip(chunks, out[0]):
        assert nc.size_bytes == oc.size_bytes
        assert len(nc.schedule) == len(oc.schedule)


def test_replan_against_empty_pending_is_noop():
    rp = make_replanner(TOPOS["2D-SW_SW"], "themis")
    assert rp(0.0, [0.5, 1.0], []) == {}


# ---------------------------------------------------------------------------
# Tracer round trip
# ---------------------------------------------------------------------------
def test_tracer_records_fault_events_and_chrome_roundtrip(tmp_path):
    topo = TOPOS["2D-SW_SW"]
    reqs = _reqs(6)
    faults = FaultSchedule(
        events=(BwDegradation(dim=1, start=1e-4, end=5e-4, factor=0.25),
                DimOutage(dim=0, start=2e-4, end=5e-4),),
        retry=RetryPolicy(timeout_s=5e-5, backoff_s=2e-5, max_attempts=10))
    trc = Tracer()
    res, _ = simulate_requests(
        topo, reqs, chunks_per_collective=8, engine="indexed",
        check_invariants=True, faults=faults, replan=True, tracer=trc)
    counts = trc.event_counts()
    assert counts["faults"] >= 4                # two windows = four edges
    assert counts["retries"] == sum(res.group_retries)
    assert counts["replans"] >= 1
    path = tmp_path / "faults.trace.json"
    trc.save(path)
    parsed = parse_chrome_trace(path)
    for key in ("faults", "retries", "replans", "aborts", "rerates",
                "group_fails"):
        assert parsed[key] == counts[key], key


def test_tracer_counts_group_failures():
    topo = TOPOS["2D-SW_SW"]
    reqs = _reqs()
    faults = FaultSchedule(
        events=(DimOutage(dim=1, start=1e-4),),
        retry=RetryPolicy(timeout_s=5e-5, backoff_s=2e-5, max_attempts=2))
    trc = Tracer()
    res, _ = simulate_requests(
        topo, reqs, chunks_per_collective=8, engine="indexed",
        faults=faults, tracer=trc)
    assert trc.event_counts()["group_fails"] == len(res.failed_groups) > 0


# ---------------------------------------------------------------------------
# Fault-free identity + randomized chaos differential
# ---------------------------------------------------------------------------
def test_faults_none_is_the_default_path():
    topo = TOPOS["2D-SW_SW"]
    reqs = _reqs()
    base = _run(topo, reqs, "indexed")
    withkw = _run(topo, reqs, "indexed", faults=None)
    assert_same(base, withkw)
    assert base.group_retries == [] and base.failed_groups == []


@pytest.mark.parametrize("seed", range(6))
def test_chaos_differential_engines_agree(seed):
    rng = random.Random(9000 + seed)
    topo = TOPOS["2D-SW_SW"]
    horizon = 2e-3
    events = []
    for dim in (0, 1):
        t0 = rng.uniform(0.1, 0.5) * horizon
        kind = rng.choice(("degrade", "outage", "burst"))
        if kind == "degrade":
            events.append(BwDegradation(
                dim=dim, start=t0, end=t0 + 0.4 * horizon,
                factor=rng.uniform(0.1, 0.8)))
        elif kind == "outage":
            events.append(DimOutage(dim=dim, start=t0,
                                    end=t0 + 0.15 * horizon))
        else:
            events.append(StragglerBurst(
                dim=dim, start=t0, end=t0 + 0.4 * horizon,
                sigma=rng.uniform(0.05, 0.4)))
    faults = FaultSchedule(
        events=tuple(events),
        retry=RetryPolicy(timeout_s=5e-5, backoff_s=2e-5,
                          max_attempts=rng.choice((2, 10))))
    reqs = [CollectiveRequest(
        rng.choice(("AR", "RS", "AG")), rng.uniform(2, 20) * MB,
        issue_time=rng.uniform(0, 1e-3)) for _ in range(8)]
    ri = _run(topo, reqs, "indexed", faults=faults)
    rr = _run(topo, reqs, "reference", faults=faults)
    assert_same(ri, rr)
