"""Multi-device checks (run as a subprocess with 8 virtual CPU devices).

Covers: chunked hierarchical AR correctness with mixed per-chunk orders,
int8-on-the-wire RS, manual Themis ZeRO-2 step vs GSPMD reference,
pipeline-parallel loss equality, serve-path sharded prefill/decode.
Exits non-zero on any failure; the pytest wrapper asserts the exit code.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.comms.hierarchical import (  # noqa: E402
    chunked_all_reduce,
    int8_reduce_scatter_axis,
)
from repro.comms.schedule_bridge import themis_axis_orders  # noqa: E402
from repro.configs import ParallelConfig, TrainConfig, get_arch  # noqa: E402
from repro.launch.compat import shard_map_compat  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402


def check_chunked_all_reduce():
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    n = 1234
    orders = themis_axis_orders({"pod": 2, "data": 2, "model": 2}, n * 4, 6,
                                "themis")
    # force diverse orders incl. non-baseline
    orders[0] = ("pod", "model", "data")
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, n)),
                    jnp.float32)

    f = jax.jit(shard_map_compat(
        lambda xl: chunked_all_reduce(xl[0], [tuple(o) for o in orders],
                                      mean=False)[None],
        mesh=mesh, in_specs=P(("pod", "data", "model")),
        out_specs=P(("pod", "data", "model")), check=False))
    out = np.asarray(f(x))
    want = np.asarray(x).sum(0)
    for row in out:
        # fp32 8-way sums: hierarchical reduction order differs from numpy
        np.testing.assert_allclose(row, want, rtol=1e-3, atol=1e-3)
    print("chunked_all_reduce OK")


def check_int8_rs():
    mesh = make_mesh((8,), ("data",))
    n = 64 * 8
    x = jnp.asarray(np.random.default_rng(1).standard_normal((8, n)),
                    jnp.float32)

    f = jax.jit(shard_map_compat(
        lambda xl: int8_reduce_scatter_axis(xl[0], "data")[None],
        mesh=mesh, in_specs=P("data"), out_specs=P("data"), check=False))
    out = np.asarray(f(x)).reshape(-1)
    want = np.asarray(x).sum(0)
    rel = np.abs(out - want) / (np.abs(want) + 1e-3)
    assert rel.mean() < 0.05, f"int8 RS error too large: {rel.mean()}"
    print("int8_reduce_scatter OK (mean rel err %.4f)" % rel.mean())


def check_themis_step_matches_gspmd():
    from repro.train.step import (
        gspmd_init_state,
        make_gspmd_train_step,
        make_themis_train_step,
    )

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_arch("qwen2.5-3b", reduced=True).replace(remat=False)
    api = build_model(cfg)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10,
                       weight_decay=0.0)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32),
    }
    step_t, init_t, orders = make_themis_train_step(
        api, mesh, ParallelConfig(data=2, model=2, pods=2, dp_sync="themis",
                                  chunks_per_collective=4), tcfg)
    pt, ot = init_t(0)
    step_g, *_ = make_gspmd_train_step(
        api, mesh, ParallelConfig(data=2, model=2, pods=2), tcfg)
    pg, og = gspmd_init_state(api, mesh,
                              ParallelConfig(data=2, model=2, pods=2))
    for i in range(2):
        pt, ot, mt = step_t(pt, ot, batch)
        pg, og, mg = step_g(pg, og, batch)
    lt, lg = float(mt["loss"]), float(mg["loss"])
    assert abs(lt - lg) < 0.05, f"themis {lt} vs gspmd {lg}"
    assert len(set(orders)) >= 1
    print(f"themis-vs-gspmd OK (loss {lt:.4f} vs {lg:.4f}; "
          f"{len(set(orders))} distinct orders)")


def check_int8_themis_step_trains():
    from repro.train.step import make_themis_train_step

    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = get_arch("llama3-8b", reduced=True).replace(remat=False)
    api = build_model(cfg)
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=1, total_steps=10,
                       weight_decay=0.0)
    step_t, init_t, _ = make_themis_train_step(
        api, mesh, ParallelConfig(data=2, model=4, dp_sync="themis",
                                  chunks_per_collective=2,
                                  compression="int8"), tcfg)
    p, o = init_t(0)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32),
    }
    losses = []
    for i in range(6):
        p, o, m = step_t(p, o, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], f"int8 training diverged: {losses}"
    print(f"int8 themis step OK ({losses[0]:.3f} -> {losses[-1]:.3f})")


def check_pipeline_parallel():
    from repro.models import transformer as tr
    from repro.train.pipeline import make_pipeline_loss

    cfg = get_arch("llama3-8b", reduced=True).replace(num_layers=4,
                                                      remat=False)
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    mesh = make_mesh((4,), ("pipe",))
    loss_fn = make_pipeline_loss(cfg, mesh, n_micro=4)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32)
    labs = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32)
    lp = float(jax.jit(loss_fn)(params, toks, labs))
    lref = float(tr.loss_fn(params, {"tokens": toks, "labels": labs}, cfg))
    assert abs(lp - lref) < 1e-3, f"pipeline {lp} vs ref {lref}"
    g = jax.jit(jax.grad(lambda p: loss_fn(p, toks, labs)))(params)
    gn = sum(float(jnp.sum(x.astype(jnp.float32) ** 2))
             for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    print(f"pipeline-parallel OK (loss {lp:.4f} == {lref:.4f})")


def check_sharded_serving():
    from repro.configs import ShapeConfig
    from repro.train.serve import make_serve_fns

    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = get_arch("llama3-8b", reduced=True).replace(remat=False)
    api = build_model(cfg)
    shape = ShapeConfig("serve", 32, 4, "decode")
    jit_prefill, jit_decode, sh = make_serve_fns(
        api, mesh, ParallelConfig(data=2, model=4), shape)
    params = api.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                                   jnp.int32)}
    logits, caches = jit_prefill(params, batch)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    logits2, caches = jit_decode(params, caches, tok,
                                 jnp.asarray(32, jnp.int32))
    assert bool(jnp.isfinite(logits2).all())
    print("sharded serving OK")


if __name__ == "__main__":
    check_chunked_all_reduce()
    check_int8_rs()
    check_themis_step_matches_gspmd()
    check_int8_themis_step_trains()
    check_pipeline_parallel()
    check_sharded_serving()
    print("ALL MULTIDEVICE CHECKS PASSED")
