"""Event-driven multi-rail simulator tests."""
import pytest

from repro.core.latency_model import LatencyModel
from repro.core.simulator import simulate, simulate_scheduled
from repro.core.scheduler import schedule_collective
from repro.topology import make_table2_topologies
from repro.topology.topology import NetworkDim, Topology, TopoKind

TOPOS = make_table2_topologies()
MB = 1e6


def single_dim_topo(p=4, gbps=80.0):
    return Topology("1d", (NetworkDim(p, TopoKind.RING, gbps, 1, 0.0),))


def test_single_dim_rs_time_is_wire_over_bw():
    topo = single_dim_topo()
    lm = LatencyModel(topo)
    res, chunks = simulate_scheduled(topo, "RS", 100 * MB, policy="baseline",
                                     chunks_per_collective=1)
    want = lm.wire_time(0, 0.75 * 100 * MB)
    assert res.makespan == pytest.approx(want, rel=1e-6)


def test_chunking_does_not_change_single_dim_bw_bound_time():
    topo = single_dim_topo()
    r1, _ = simulate_scheduled(topo, "AR", 100 * MB, policy="baseline",
                               chunks_per_collective=1)
    r64, _ = simulate_scheduled(topo, "AR", 100 * MB, policy="baseline",
                                chunks_per_collective=64)
    assert r64.makespan == pytest.approx(r1.makespan, rel=1e-3)


def test_pipelining_overlaps_dims():
    """With 2 dims and many chunks, makespan ~ slowest dim's serial load,
    not the sum of both dims."""
    topo = TOPOS["2D-SW_SW"]
    lm = LatencyModel(topo)
    res, chunks = simulate_scheduled(topo, "AR", 500 * MB, policy="baseline",
                                     chunks_per_collective=64)
    dim0_serial = sum(
        lm.calc_loads(c.size_bytes, c.schedule).get(0, 0.0) for c in chunks
    )
    assert res.makespan < dim0_serial * 1.1


def test_wire_bytes_conservation():
    topo = TOPOS["3D-SW_SW_SW_homo"]
    lm = LatencyModel(topo)
    size = 250 * MB
    for policy in ("baseline", "themis"):
        res, _ = simulate_scheduled(topo, "AR", size, policy=policy)
        assert sum(res.dim_wire_bytes) == pytest.approx(
            lm.total_wire_bytes("AR", size), rel=1e-9)


def test_themis_beats_baseline_on_overprovisioned():
    for name in ("3D-SW_SW_SW_homo", "4D-Ring_FC_Ring_SW"):
        topo = TOPOS[name]
        rb, _ = simulate_scheduled(topo, "AR", 500 * MB, policy="baseline",
                                   intra="FIFO")
        rt, _ = simulate_scheduled(topo, "AR", 500 * MB, policy="themis",
                                   intra="SCF")
        assert rt.makespan < rb.makespan
        assert rt.avg_bw_utilization(topo) > rb.avg_bw_utilization(topo)


def test_utilization_never_exceeds_one():
    for name, topo in TOPOS.items():
        for policy in ("baseline", "themis"):
            res, _ = simulate_scheduled(topo, "AR", 100 * MB, policy=policy)
            assert 0.0 < res.avg_bw_utilization(topo) <= 1.0 + 1e-9


def test_makespan_at_least_ideal():
    for name, topo in TOPOS.items():
        lm = LatencyModel(topo)
        res, _ = simulate_scheduled(topo, "AR", 1e9, policy="themis")
        assert res.makespan >= lm.ideal_time("AR", 1e9) * 0.999


def test_activity_rates_bounded():
    topo = TOPOS["3D-SW_SW_SW_homo"]
    res, _ = simulate_scheduled(topo, "AR", 1e9, policy="themis")
    for k in range(topo.num_dims):
        assert 0.0 <= res.activity_rate(k) <= 1.0 + 1e-9


def test_scf_orders_smallest_first_within_dim():
    topo = TOPOS["2D-SW_SW"]
    chunks = schedule_collective(topo, "AR", 100 * MB, 16, "themis")
    res = simulate(topo, [chunks], intra="SCF", fusion=False)
    assert all(len(o) > 0 for o in res.dim_op_order)
