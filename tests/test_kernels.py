"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Shape/dtype sweeps via hypothesis + fixed allclose cases per kernel.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as fa_raw
from repro.kernels.rglru import rglru_scan as rg_raw
from repro.kernels.rmsnorm import rmsnorm as rn_raw

RNG = np.random.default_rng(42)


def t(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# -- flash attention ----------------------------------------------------------
@pytest.mark.parametrize("b,s,h,kv,d,tk,win", [
    (2, 128, 4, 2, 64, 128, 0),
    (1, 200, 8, 1, 64, 200, 0),       # MQA + ragged seq
    (2, 96, 4, 4, 32, 96, 32),        # sliding window
    (1, 64, 2, 2, 128, 256, 0),       # cross-length kv
    (1, 257, 3, 3, 16, 257, 64),      # odd sizes
])
def test_flash_attention_matches_oracle(b, s, h, kv, d, tk, win):
    q, k, v = t((b, s, h, d)), t((b, tk, kv, d)), t((b, tk, kv, d))
    out = fa_raw(q, k, v, causal=True, window=win, block_q=64, block_k=64,
                 interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=win)
    np.testing.assert_allclose(out, want, atol=5e-6, rtol=5e-5)


@given(
    b=st.integers(1, 2), s=st.sampled_from([17, 64, 130]),
    h=st.sampled_from([2, 4]), groups=st.sampled_from([1, 2]),
    d=st.sampled_from([16, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    causal=st.booleans(),
)
@settings(max_examples=12, deadline=None)
def test_flash_attention_hypothesis_sweep(b, s, h, groups, d, dtype, causal):
    kv = h // groups
    q, k, v = t((b, s, h, d), dtype), t((b, s, kv, d), dtype), t((b, s, kv, d), dtype)
    out = fa_raw(q, k, v, causal=causal, block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32), atol=tol(dtype),
        rtol=tol(dtype))


def test_flash_attention_grad_via_ops():
    q, k, v = t((1, 64, 4, 32)), t((1, 64, 2, 32)), t((1, 64, 2, 32))
    g1 = jax.grad(lambda q: ops.flash_attention(q, k, v).sum())(q)
    g2 = jax.grad(lambda q: ref.flash_attention_ref(q, k, v).sum())(q)
    np.testing.assert_allclose(g1, g2, atol=5e-6, rtol=5e-5)


# -- RG-LRU scan ---------------------------------------------------------------
@pytest.mark.parametrize("b,s,c,bt,bc", [
    (2, 100, 96, 32, 32),
    (1, 257, 64, 64, 64),
    (3, 16, 300, 16, 128),
])
def test_rglru_matches_oracle(b, s, c, bt, bc):
    a = jnp.asarray(RNG.uniform(0.2, 0.999, (b, s, c)), jnp.float32)
    bb = t((b, s, c))
    h0 = t((b, c))
    out = rg_raw(a, bb, h0, block_c=bc, block_t=bt, interpret=True)
    want = ref.rglru_scan_ref(a, bb, h0)
    np.testing.assert_allclose(out, want, atol=1e-5, rtol=1e-5)


@given(
    b=st.integers(1, 3), s=st.sampled_from([1, 33, 128]),
    c=st.sampled_from([8, 130]), h0none=st.booleans(),
)
@settings(max_examples=10, deadline=None)
def test_rglru_hypothesis_sweep(b, s, c, h0none):
    a = jnp.asarray(RNG.uniform(0.0, 1.0, (b, s, c)), jnp.float32)
    bb = t((b, s, c))
    h0 = None if h0none else t((b, c))
    out = rg_raw(a, bb, h0, block_c=64, block_t=64, interpret=True)
    want = ref.rglru_scan_ref(a, bb, h0)
    np.testing.assert_allclose(out, want, atol=1e-5, rtol=1e-5)


# -- RMSNorm ---------------------------------------------------------------------
@pytest.mark.parametrize("shape,dtype", [
    ((4, 37, 128), jnp.bfloat16),
    ((8, 256), jnp.float32),
    ((1, 1, 512), jnp.float32),
])
def test_rmsnorm_matches_oracle(shape, dtype):
    x = t(shape, dtype)
    w = t(shape[-1:])
    out = rn_raw(x, w, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32),
        atol=tol(dtype), rtol=tol(dtype))


@given(rows=st.integers(1, 70), d=st.sampled_from([32, 128, 384]),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
@settings(max_examples=10, deadline=None)
def test_rmsnorm_hypothesis_sweep(rows, d, dtype):
    x = t((rows, d), dtype)
    w = t((d,))
    out = rn_raw(x, w, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32),
        atol=tol(dtype), rtol=tol(dtype))


# -- xla flash (model path) ------------------------------------------------------
def test_xla_flash_fwd_bwd_vs_naive():
    from repro.models.common import flash_attention_xla, naive_attention

    q, k, v = t((2, 100, 4, 32)), t((2, 100, 2, 32)), t((2, 100, 2, 32))
    for win in (0, 16):
        out = flash_attention_xla(q, k, v, causal=True, window=win,
                                  block_q=32, block_k=32)
        want = naive_attention(q, k, v, causal=True, window=win)
        np.testing.assert_allclose(out, want, atol=5e-6, rtol=5e-5)
        gf = jax.grad(lambda a, b, c: flash_attention_xla(
            a, b, c, causal=True, window=win, block_q=32, block_k=32).sum(),
            argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(lambda a, b, c: naive_attention(
            a, b, c, causal=True, window=win).sum(), argnums=(0, 1, 2))(q, k, v)
        for x, y in zip(gf, gr):
            np.testing.assert_allclose(x, y, atol=1e-5, rtol=1e-4)
