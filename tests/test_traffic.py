"""Dependency-aware traffic IR: validation, semantics, builders, tenancy.

Engine bit-equivalence for dependency-gated streams lives in
``test_engine_equiv.py``; this file covers the IR itself and the timing
semantics the simulator must honor.
"""
import math
import random

import pytest

from repro.core.requests import CollectiveRequest
from repro.core.simulator import simulate, simulate_requests
from repro.core.workloads import make_resnet152
from repro.tenancy import FabricArbiter, TenantJob, TenantSpec, tenant_traffic
from repro.topology import make_table2_topologies, make_tpu_pod_topology
from repro.traffic import (
    TrafficGraph,
    TrafficNode,
    from_requests,
    merge_graphs,
    pipeline_traffic,
    retag,
    serving_costs_from_arch,
    serving_traffic,
    simulate_traffic,
    training_traffic,
)

TOPOS = make_table2_topologies()
MB = 1e6


# ---------------------------------------------------------------------------
# IR validation
# ---------------------------------------------------------------------------
def test_graph_rejects_duplicate_names():
    with pytest.raises(ValueError, match="duplicate node name"):
        TrafficGraph((TrafficNode("a"), TrafficNode("a")))


def test_graph_rejects_unknown_dep():
    with pytest.raises(ValueError, match="unknown node"):
        TrafficGraph((TrafficNode("a", deps=("ghost",)),))


def test_graph_rejects_cycles_including_self():
    with pytest.raises(ValueError, match="cycle"):
        TrafficGraph((TrafficNode("a", deps=("b",)),
                      TrafficNode("b", deps=("a",))))
    with pytest.raises(ValueError, match="cycle"):
        TrafficGraph((TrafficNode("a", deps=("a",)),))


def test_graph_allows_forward_references():
    g = TrafficGraph((TrafficNode("late", deps=("early",)),
                      TrafficNode("early", compute_s=1.0)))
    assert g.topo_order == (1, 0)
    est_issue, _ = g.estimate_times()
    assert est_issue == [1.0, 1.0]


def test_node_validation():
    with pytest.raises(ValueError):
        TrafficNode("x", compute_s=-1.0)
    with pytest.raises(ValueError):
        TrafficNode("x", start_s=-1.0)
    with pytest.raises(ValueError):
        TrafficNode("")
    # an embedded request issue_time that start_s does not honor is a
    # silent-migration trap — reject it (from_requests sets both)
    with pytest.raises(ValueError, match="issue_time"):
        TrafficNode("x", request=CollectiveRequest("AR", MB, issue_time=5.0))
    TrafficNode("x", request=CollectiveRequest("AR", MB, issue_time=5.0),
                start_s=5.0)  # agreeing times are fine


def test_simulate_validates_dep_arguments():
    topo = TOPOS["2D-SW_SW"]
    with pytest.raises(ValueError, match="requires deps"):
        simulate(topo, [[]], dep_delay_s=[0.0])
    with pytest.raises(ValueError, match="invalid dependency"):
        simulate(topo, [[], []], deps=[(), (5,)])
    with pytest.raises(ValueError, match="invalid dependency"):
        simulate(topo, [[]], deps=[(0,)])  # self-dependency
    with pytest.raises(ValueError, match="must match"):
        simulate(topo, [[], []], deps=[()])
    with pytest.raises(ValueError, match="mutually exclusive"):
        simulate(topo, [[]], deps=[()], enforced_order=[[]])


# ---------------------------------------------------------------------------
# Fixed-time streams through the IR reproduce today's results exactly
# ---------------------------------------------------------------------------
def test_fixed_time_graph_bit_identical_to_simulate_requests():
    rng = random.Random(11)
    for tname in ("2D-SW_SW", "3D-SW_SW_SW_hetero"):
        topo = TOPOS[tname]
        reqs = [
            CollectiveRequest(rng.choice(("AR", "RS", "AG")),
                              rng.uniform(1, 50) * MB,
                              issue_time=rng.uniform(0, 2e-3),
                              priority=rng.choice((0, 1)),
                              stream=f"s{i % 3}", tenant=f"t{i % 2}")
            for i in range(12)
        ]
        r0, g0 = simulate_requests(topo, reqs, chunks_per_collective=6)
        r1, g1 = simulate_traffic(topo, from_requests(reqs),
                                  chunks_per_collective=6)
        assert r1.diff_fields(r0) == []
        assert [[c.schedule for c in g] for g in g0] == [
            [c.schedule for c in g] for g in g1]


# ---------------------------------------------------------------------------
# Dependency-gating semantics
# ---------------------------------------------------------------------------
def test_dependent_group_issues_at_parent_finish_plus_delay():
    topo = TOPOS["2D-SW_SW"]
    delay = 3e-4
    g = TrafficGraph((
        TrafficNode("a", request=CollectiveRequest("AR", 20 * MB)),
        TrafficNode("b", request=CollectiveRequest("AR", 20 * MB),
                    compute_s=delay, deps=("a",)),
    ))
    res, _ = simulate_traffic(topo, g, chunks_per_collective=4)
    ia, ib = g.index_of("a"), g.index_of("b")
    assert res.group_issue[ib] == res.group_finish[ia] + delay
    assert res.group_finish[ib] > res.group_issue[ib]


def test_start_floor_bounds_dependent_issue():
    topo = TOPOS["2D-SW_SW"]
    g = TrafficGraph((
        TrafficNode("a", request=CollectiveRequest("AR", 1 * MB)),
        TrafficNode("b", request=CollectiveRequest("AR", 1 * MB),
                    deps=("a",), start_s=1.0),  # floor far beyond a's finish
    ))
    res, _ = simulate_traffic(topo, g, chunks_per_collective=2)
    assert res.group_issue[g.index_of("b")] == 1.0
    assert res.makespan >= 1.0


def test_compute_only_chain_accumulates_delays():
    topo = TOPOS["2D-SW_SW"]
    g = TrafficGraph((
        TrafficNode("c0", compute_s=0.5, start_s=0.25),
        TrafficNode("c1", compute_s=0.5, deps=("c0",)),
        TrafficNode("c2", compute_s=0.5, deps=("c1",)),
    ))
    res, _ = simulate_traffic(topo, g)
    assert res.group_finish == [0.75, 1.25, 1.75]
    assert res.makespan == 1.75  # trailing compute advances the makespan


def test_multi_parent_gate_waits_for_latest():
    topo = TOPOS["2D-SW_SW"]
    g = TrafficGraph((
        TrafficNode("fast", compute_s=0.1),
        TrafficNode("slow", compute_s=0.9),
        TrafficNode("join", request=CollectiveRequest("AR", 4 * MB),
                    deps=("fast", "slow")),
    ))
    res, _ = simulate_traffic(topo, g, chunks_per_collective=2)
    assert res.group_issue[g.index_of("join")] == 0.9


def test_root_request_with_compute_issues_after_compute():
    topo = TOPOS["2D-SW_SW"]
    g = TrafficGraph((
        TrafficNode("r", request=CollectiveRequest("AR", 4 * MB),
                    compute_s=0.2, start_s=0.1),
    ))
    res, _ = simulate_traffic(topo, g, chunks_per_collective=2)
    assert res.group_issue[0] == pytest.approx(0.3)


# ---------------------------------------------------------------------------
# Stream percentiles (serving SLO reporting)
# ---------------------------------------------------------------------------
def test_stream_stats_percentiles():
    topo = TOPOS["2D-SW_SW"]
    reqs = [CollectiveRequest("AR", (i + 1) * 4 * MB, issue_time=i * 0.05,
                              stream="s")
            for i in range(10)]
    res, _ = simulate_requests(topo, reqs, chunks_per_collective=4)
    st = res.stream_stats()["s"]
    lats = sorted(res.group_finish[i] - res.group_issue[i]
                  for i in range(10))
    # linear-interpolation percentiles over the 10 latencies
    assert st.latency_p50 == pytest.approx(
        lats[4] + 0.5 * (lats[5] - lats[4]))
    assert st.latency_p99 == pytest.approx(
        lats[8] + 0.91 * (lats[9] - lats[8]))
    assert st.latency_p50 <= st.latency_p95 <= st.latency_p99
    assert st.latency_p99 <= st.latency_max


def test_tenant_percentiles_exclude_compute_nodes():
    """A training tenant's graph is mostly compute nodes (gates, spines,
    barriers) with zero latency; per-tenant latency aggregates must only
    count the wire-moving groups or the percentiles collapse to ~0."""
    wl = make_resnet152()
    topo = make_tpu_pod_topology(2, 4, 4)
    g = retag(training_traffic(wl, n_buckets=8, iterations=2),
              name_prefix="train/", tenant="train")
    res, _ = simulate_traffic(topo, g, chunks_per_collective=8)
    st = res.stream_stats(by="tenant")["train"]
    req_lats = sorted(res.group_finish[i] - res.group_issue[i]
                      for i, n in enumerate(g.nodes) if n.request is not None)
    assert st.latency_p50 >= req_lats[0] > 0
    assert st.latency_mean == pytest.approx(sum(req_lats) / len(req_lats))
    # compute-only streams still aggregate (over their zero latencies)
    assert res.stream_stats()["compute"].latency_max == 0.0


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------
def test_training_traffic_matches_fixed_stream_when_uncontended():
    """One iteration on an idle fabric: the dependency-gated bucket stream
    must issue each bucket exactly where dp_bucket_requests puts it
    (fwd compute + the bucket's backward-retirement instant)."""
    from repro.core.workloads import dp_bucket_requests

    wl = make_resnet152()
    topo = make_tpu_pod_topology(1, 8, 8)
    g = training_traffic(wl, n_buckets=8, iterations=1)
    res, _ = simulate_traffic(topo, g, chunks_per_collective=8)
    base = dp_bucket_requests(wl, 8)
    got = sorted(res.group_issue[i] for i, n in enumerate(g.nodes)
                 if n.request is not None)
    want = sorted(wl.compute_fwd_s + r.issue_time for r in base)
    assert got == pytest.approx(want)


def test_training_traffic_multi_iteration_is_closed_loop():
    """Iteration i+1's forward must start only after iteration i's slowest
    gradient collective drained — under contention that is later than the
    fixed-gap stream's clocked start."""
    wl = make_resnet152()
    topo = make_tpu_pod_topology(2, 4, 4)
    g = training_traffic(wl, n_buckets=8, iterations=3)
    res, _ = simulate_traffic(topo, g, chunks_per_collective=8)
    for it in range(2):
        step_fin = res.group_finish[g.index_of(f"{wl.name}/it{it}/step")]
        nxt = res.group_issue[g.index_of(f"{wl.name}/it{it + 1}/start")]
        assert nxt == step_fin
        reqs_fin = max(res.group_finish[i]
                       for i, n in enumerate(g.nodes)
                       if n.request is not None
                       and n.name.startswith(f"{wl.name}/it{it}/"))
        assert step_fin >= reqs_fin


def test_pipeline_traffic_1f1b_structure():
    S, M, fwd = 4, 6, 1e-3
    g = pipeline_traffic(stages=S, microbatches=M, fwd_s=fwd, bwd_s=2e-3,
                         act_bytes=8 * MB, grad_ar_bytes=40 * MB,
                         n_grad_buckets=4)
    topo = TOPOS["3D-SW_SW_SW_homo"]
    res, _ = simulate_traffic(topo, g, chunks_per_collective=4)
    # Pipeline fill: stage s's first forward cannot complete before
    # (s+1) forward computes plus s activation transfers have happened.
    for s in range(S):
        fin = res.group_finish[g.index_of(f"pp/s{s}/f0")]
        assert fin >= (s + 1) * fwd
    # The last stage's first backward follows its first forward (1F1B).
    assert (res.group_issue[g.index_of(f"pp/s{S - 1}/b0")]
            >= res.group_finish[g.index_of(f"pp/s{S - 1}/f0")])
    # Every stage serializes M forwards + M backwards of compute.
    assert res.makespan >= M * (1e-3 + 2e-3)
    # DP gradient buckets ride behind each stage's last backward.
    for s in range(S):
        assert (res.group_issue[g.index_of(f"pp/s{s}/dp-ar0")]
                >= res.group_finish[g.index_of(f"pp/s{s}/b{M - 1}")])
    st = res.stream_stats()
    assert {"pp-act", "pp-grad", "pp-dp", "pp-compute"} <= set(st)


def test_serving_traffic_decode_chain_is_sequential():
    topo = make_tpu_pod_topology(1, 8, 8)
    dec_s = 2e-4
    g = serving_traffic(prefill_bytes=32 * MB, decode_bytes=1 * MB,
                        prefill_s=1e-3, decode_s=dec_s, gen_tokens=8,
                        n_requests=2, arrival_gap_s=5e-3)
    res, _ = simulate_traffic(topo, g, chunks_per_collective=4)
    for r in range(2):
        prev_fin = None
        for t in range(8):
            i = g.index_of(f"serve/r{r}/decode{t}")
            if prev_fin is not None:
                assert res.group_issue[i] == pytest.approx(prev_fin + dec_s)
            prev_fin = res.group_finish[i]
        # prefill burst: all ops share one eligibility instant
        burst = [res.group_issue[g.index_of(f"serve/r{r}/prefill{j}")]
                 for j in range(4)]
        assert len(set(burst)) == 1
    assert res.stream_stats()["decode"].n == 16


def test_serving_costs_from_arch_are_sane():
    costs = serving_costs_from_arch("llama3-8b", batch=4, prompt_len=256,
                                    tp=8)
    assert costs["prefill_bytes"] > costs["decode_bytes"] > 0
    assert costs["prefill_s"] > costs["decode_s"] > 0
    # decode moves ~2 collectives/layer of one token's activations
    assert costs["decode_bytes"] < 64 * MB


# ---------------------------------------------------------------------------
# retag / merge / tenancy integration
# ---------------------------------------------------------------------------
def test_retag_namespaces_and_offsets():
    g = serving_traffic(prefill_bytes=8 * MB, decode_bytes=MB,
                        prefill_s=1e-3, decode_s=1e-4, gen_tokens=2)
    t = retag(g, name_prefix="svc/", tenant="svc", stream_prefix="svc/",
              priority=2, start_offset_s=0.5)
    assert all(n.name.startswith("svc/") for n in t.nodes)
    assert all(n.tenant_tag == "svc" for n in t.nodes)
    req_nodes = [n for n in t.nodes if n.request is not None]
    assert all(n.request.priority == 2 for n in req_nodes)
    assert all(n.stream_tag.startswith("svc/") for n in t.nodes)
    root = t.node("svc/serve/r0/prefill-compute")
    assert root.start_s == pytest.approx(0.5)
    # a node-level tenant set by a builder must not survive the override
    g2 = TrafficGraph((TrafficNode(
        "a", request=CollectiveRequest("AR", MB), tenant="builder-set"),))
    t2 = retag(g2, tenant="t1")
    assert t2.nodes[0].tenant_tag == "t1"
    assert t2.nodes[0].request.tenant == "t1"
    # retag shifts start_s past an embedded issue_time without tripping
    # the node validation (the stale request time is dropped)
    g3 = from_requests([CollectiveRequest("AR", MB, issue_time=0.25)])
    t3 = retag(g3, start_offset_s=1.0)
    assert t3.nodes[0].start_s == pytest.approx(1.25)
    assert t3.nodes[0].request.issue_time == 0.0


def test_merge_graphs_rejects_collisions():
    g = serving_traffic(prefill_bytes=MB, decode_bytes=MB, prefill_s=0.0,
                        decode_s=0.0, gen_tokens=1)
    with pytest.raises(ValueError, match="duplicate"):
        merge_graphs(g, g)


def test_mixed_training_serving_tenants_under_arbiter():
    topo = make_tpu_pod_topology(2, 8, 8)
    train = TenantJob(TenantSpec("train", iterations=2, n_buckets=8),
                      make_resnet152())
    serve = TenantJob(
        TenantSpec("serve", weight=2.0, slo_slowdown=1.2),
        traffic_builder=lambda job: serving_traffic(
            prefill_bytes=48 * MB, decode_bytes=1.5 * MB, prefill_s=2e-3,
            decode_s=2e-4, gen_tokens=10, n_requests=2, arrival_gap_s=2e-3))
    graph = tenant_traffic([train, serve])
    specs = [train.spec, serve.spec]
    finishes = {}
    for pol in ("fifo", "weighted-fair"):
        res, _ = simulate_traffic(topo, graph, chunks_per_collective=8,
                                  arbiter=FabricArbiter(pol, specs))
        by_tenant = res.stream_stats(by="tenant")
        assert {"train", "serve"} <= set(by_tenant)
        st = res.stream_stats()["serve/decode"]
        assert st.n == 20 and st.latency_p99 >= st.latency_p50 > 0
        finishes[pol] = res.finish_time()
    assert all(math.isfinite(v) for v in finishes.values())


def test_tenant_job_backward_compat_and_guards():
    job = TenantJob(TenantSpec("t", iterations=2), make_resnet152())
    assert len(job.requests()) > 0  # fixed-time path unchanged
    assert job.traffic().n_requests > 0
    bare = TenantJob(TenantSpec("bare"))
    with pytest.raises(ValueError, match="no training workload"):
        bare.requests()
    with pytest.raises(ValueError, match="no training workload"):
        bare.traffic()


# ---------------------------------------------------------------------------
# DCN straggler jitter
# ---------------------------------------------------------------------------
def test_dcn_straggler_is_seeded_and_pod_scoped():
    wl = make_resnet152()
    g = training_traffic(wl, n_buckets=8, iterations=1)
    base = make_tpu_pod_topology(2, 4, 4)
    jit = make_tpu_pod_topology(2, 4, 4, dcn_straggler_sigma=0.5)
    assert jit.dims[-1].straggler_sigma == 0.5
    assert all(d.straggler_sigma == 0.0 for d in jit.dims[:-1])
    r0, _ = simulate_traffic(base, g, chunks_per_collective=8, seed=7)
    a, _ = simulate_traffic(jit, g, chunks_per_collective=8, seed=7)
    b, _ = simulate_traffic(jit, g, chunks_per_collective=8, seed=7)
    c, _ = simulate_traffic(jit, g, chunks_per_collective=8, seed=8)
    assert a.diff_fields(b) == []          # same seed -> identical
    assert a.makespan != c.makespan        # seed moves the draw
    assert a.makespan != r0.makespan       # sigma=0 topo is unperturbed
    with pytest.raises(ValueError):
        make_tpu_pod_topology(dcn_straggler_sigma=-0.1)
    with pytest.raises(ValueError, match="pods > 1"):
        make_tpu_pod_topology(1, 8, 8, dcn_straggler_sigma=0.5)
