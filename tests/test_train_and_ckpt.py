"""Train-loop integration + fault tolerance (single device)."""
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import AsyncCheckpointer, latest_step, restore, save
from repro.configs import ParallelConfig, TrainConfig, get_arch
from repro.data import Prefetcher, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.train.step import gspmd_init_state, make_gspmd_train_step


def _setup(tmp_path, steps=12):
    cfg = get_arch("llama3-8b", reduced=True).replace(remat=False)
    api = build_model(cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    parallel = ParallelConfig(data=1, model=1)
    tcfg = TrainConfig(learning_rate=1e-2, total_steps=steps, warmup_steps=2,
                       checkpoint_dir=str(tmp_path))
    step_fn, *_ = make_gspmd_train_step(api, mesh, parallel, tcfg)
    params, opt = gspmd_init_state(api, mesh, parallel)
    ds = SyntheticLM(cfg.vocab_size, global_batch=4, seq_len=32, seed=7)
    return api, mesh, step_fn, params, opt, ds


def test_loss_decreases_over_training(tmp_path):
    api, mesh, step_fn, params, opt, ds = _setup(tmp_path)
    losses = []
    for step in range(12):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step % 2).items()}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(l) for l in losses)


def test_checkpoint_restart_bitwise_resume(tmp_path):
    """Crash/restart: resuming from the checkpoint reproduces the exact same
    trajectory as the uninterrupted run (same data cursor, same state)."""
    api, mesh, step_fn, params, opt, ds = _setup(tmp_path)
    # the jit step donates its inputs: give each run its own buffers
    import copy as _copy
    snap = jax.tree.map(jnp.copy, (params, opt))

    # uninterrupted reference: 6 steps
    p_ref, o_ref = jax.tree.map(jnp.copy, snap)
    for step in range(6):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
        p_ref, o_ref, m_ref = step_fn(p_ref, o_ref, batch)

    # run 3 steps, checkpoint, "crash", restore, run 3 more
    p, o = jax.tree.map(jnp.copy, snap)
    for step in range(3):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
        p, o, _ = step_fn(p, o, batch)
    save(str(tmp_path), 3, {"params": p, "opt": o},
         extra={"next_step": 3, "seed": ds.seed})
    del p, o

    tmpl = jax.tree.map(jnp.copy, snap)
    restored, extra = restore(str(tmp_path), {"params": tmpl[0], "opt": tmpl[1]})
    p, o = restored["params"], restored["opt"]
    for step in range(extra["next_step"], 6):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
        p, o, m = step_fn(p, o, batch)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manifest_survives_partial_write(tmp_path):
    api, mesh, step_fn, params, opt, ds = _setup(tmp_path)
    save(str(tmp_path), 1, {"params": params})
    save(str(tmp_path), 2, {"params": params})
    # simulate a crash that wrote the manifest but not the data
    with open(os.path.join(tmp_path, "MANIFEST.json"), "w") as f:
        json.dump({"latest_step": 99}, f)
    assert latest_step(str(tmp_path)) == 2


def test_checkpoint_gc_keeps_n(tmp_path):
    api, mesh, step_fn, params, opt, ds = _setup(tmp_path)
    for s in range(5):
        save(str(tmp_path), s, {"p": jnp.zeros(3)}, keep=2)
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step-")]
    assert len(dirs) == 2


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    state = {"x": jnp.arange(10.0)}
    ck.save_async(5, state, extra={"next_step": 5})
    ck.wait()
    restored, extra = restore(str(tmp_path), state)
    np.testing.assert_array_equal(np.asarray(restored["x"]),
                                  np.arange(10.0))
    assert extra["next_step"] == 5


def test_prefetcher_is_deterministic_and_resumable(tmp_path):
    ds = SyntheticLM(101, 4, 16, seed=3)
    mesh = make_mesh((1, 1), ("data", "model"))
    pf = Prefetcher(ds, mesh, start_step=0)
    got = dict(next(pf) for _ in range(3))
    pf.close()
    pf2 = Prefetcher(ds, mesh, start_step=2)
    step, batch = next(pf2)
    pf2.close()
    assert step == 2
    np.testing.assert_array_equal(np.asarray(batch["tokens"]),
                                  np.asarray(got[2]["tokens"]))


def test_train_driver_end_to_end(tmp_path, monkeypatch, capsys):
    """The CLI driver trains a reduced model and reports decreasing loss."""
    from repro.launch import train as train_mod

    argv = ["train", "--arch", "qwen2.5-3b", "--reduced", "--steps", "10",
            "--batch", "4", "--seq", "32", "--mesh", "1x1", "--lr", "1e-2",
            "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "5",
            "--log-every", "5"]
    monkeypatch.setattr(sys, "argv", argv)
    losses = train_mod.main()
    assert len(losses) == 10
    # fresh uniform-random batches each step: loss plateaus at ~ln(vocab);
    # assert it stays finite and does not blow up.
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0] + 0.5
    assert latest_step(str(tmp_path / "ck")) == 10
