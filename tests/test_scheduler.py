"""Unit tests for Themis Algorithm 1 (scheduler, tracker, threshold)."""
import pytest

from repro.core.latency_model import LatencyModel
from repro.core.load_tracker import DimLoadTracker
from repro.core.scheduler import ThemisScheduler, baseline_order, schedule_collective
from repro.topology import Phase, make_table2_topologies

TOPOS = make_table2_topologies()
HOMO = TOPOS["3D-SW_SW_SW_homo"]
MB = 1e6


def test_baseline_order_is_static_hierarchical():
    sched = schedule_collective(HOMO, "AR", 256 * MB, 8, "baseline")
    want = baseline_order(3, "AR")
    assert all(c.schedule == want for c in sched)
    # RS dim1..dimD then AG dimD..dim1
    assert want[:3] == [(Phase.RS, 0), (Phase.RS, 1), (Phase.RS, 2)]
    assert want[3:] == [(Phase.AG, 2), (Phase.AG, 1), (Phase.AG, 0)]


def test_ar_ag_is_reverse_of_rs():
    for c in schedule_collective(HOMO, "AR", 512 * MB, 64, "themis"):
        rs = [d for p, d in c.schedule if p == Phase.RS]
        ag = [d for p, d in c.schedule if p == Phase.AG]
        assert ag == rs[::-1]  # Algorithm 1 line 8
        # every stage list is a permutation of all dims
        assert sorted(rs) == [0, 1, 2]


def test_rs_stages_precede_ag_stages():
    for c in schedule_collective(HOMO, "AR", 512 * MB, 64, "themis"):
        phases = [p for p, _ in c.schedule]
        assert phases == [Phase.RS] * 3 + [Phase.AG] * 3


def test_greedy_targets_least_loaded_dim():
    lm = LatencyModel(HOMO)
    s = ThemisScheduler(lm, "themis")
    s.tracker.reset("AR")
    # unbalance dim0 heavily; next chunk's RS must start at dim 1 or 2
    s.tracker.update({0: 1.0})
    order = s._greedy_order("AR", 64 * MB)
    assert order[0][1] != 0
    assert order[2][1] == 0  # heaviest dim goes last in RS


def test_threshold_reverts_to_baseline():
    lm = LatencyModel(HOMO)
    s = ThemisScheduler(lm, "themis")
    s.tracker.reset("RS")
    # perfectly equal loads -> below threshold -> baseline order
    s.tracker._loads = [1.0, 1.0, 1.0]
    assert s._greedy_order("RS", 64 * MB) == baseline_order(3, "RS")


def test_tracker_accumulates_predicted_loads():
    lm = LatencyModel(HOMO)
    tr = DimLoadTracker(lm)
    tr.reset("AR")
    base = tr.get_loads()
    assert base == [lm.fixed_delay(k, "AR") for k in range(3)]
    tr.update({0: 0.5, 2: 0.25})
    after = tr.get_loads()
    assert after[0] == pytest.approx(base[0] + 0.5)
    assert after[2] == pytest.approx(base[2] + 0.25)


def test_balanced_loads_after_themis_schedule():
    """Themis's whole point: final tracker loads are near-equal while
    baseline's are wildly skewed (3D homo: 16x shrink per dim)."""
    lm = LatencyModel(HOMO)

    def final_imbalance(policy):
        s = ThemisScheduler(lm, policy)
        chunks = s.schedule_collective("AR", 1e9, 64)
        loads = {k: 0.0 for k in range(3)}
        for c in chunks:
            for k, v in lm.calc_loads(c.size_bytes, c.schedule).items():
                loads[k] += v
        vals = list(loads.values())
        return max(vals) / max(min(vals), 1e-12)

    assert final_imbalance("baseline") > 50
    assert final_imbalance("themis") < 1.2


def test_lookahead_no_worse_than_greedy_makespan():
    lm = LatencyModel(HOMO)
    for cpc in (4, 16):
        def max_load(policy):
            s = ThemisScheduler(lm, policy)
            chunks = s.schedule_collective("AR", 1e8, cpc)
            loads = {k: 0.0 for k in range(3)}
            for c in chunks:
                for k, v in lm.calc_loads(c.size_bytes, c.schedule).items():
                    loads[k] += v
            return max(loads.values())

        assert max_load("lookahead") <= max_load("themis") * 1.05


def test_invalid_inputs():
    lm = LatencyModel(HOMO)
    with pytest.raises(ValueError):
        ThemisScheduler(lm, "nope")
    s = ThemisScheduler(lm, "themis")
    with pytest.raises(ValueError):
        s.schedule_collective("broadcast", 1e6, 4)


def test_guarded_greedy_never_below_baseline():
    """Beyond-paper: the guarded greedy fixes the plain greedy's regression
    on just-enough-provisioned networks and matches it elsewhere."""
    from repro.core.simulator import simulate_scheduled
    from repro.topology.topology import NetworkDim, Topology, TopoKind

    for bw2 in (50.0, 100.0, 800.0):
        topo = Topology("je", (
            NetworkDim(16, TopoKind.SWITCH, 800, 1, 7e-7),
            NetworkDim(8, TopoKind.SWITCH, bw2, 1, 1.7e-6),
        ))
        rb, _ = simulate_scheduled(topo, "AR", 5e8, policy="baseline",
                                   intra="FIFO")
        rg, _ = simulate_scheduled(topo, "AR", 5e8, policy="themis_guarded",
                                   intra="SCF")
        assert rg.makespan <= rb.makespan * 1.01
