"""Partition-rule unit tests (no multi-device needed: rules are pure)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ParallelConfig, TRAIN_4K, get_arch
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.sharding.specs import (
    batch_pspec,
    cache_pspec,
    opt_state_pspec,
    param_pspec,
)

MESH = make_mesh((1, 1), ("data", "model"))


class FakeMesh:
    """Shape-only stand-in so rules can be tested for a 16x16 mesh on CPU."""

    def __init__(self, **axes):
        self.shape = axes


M16 = FakeMesh(data=16, model=16)
PAR = ParallelConfig(data=16, model=16)
PAR_FSDP = ParallelConfig(data=16, model=16, fsdp=True)


def test_embed_rule():
    assert param_pspec("embed", (151936, 2048), M16, PAR) == P("model", None)
    assert param_pspec("embed", (151936, 2048), M16, PAR_FSDP) == P("model", "data")


def test_proj_rules():
    assert param_pspec("blocks/attn/wq", (32, 4096, 4096), M16, PAR) == \
        P(None, None, "model")
    assert param_pspec("blocks/attn/wo", (32, 4096, 4096), M16, PAR_FSDP) == \
        P(None, "model", "data")
    assert param_pspec("blocks/mlp/wi", (32, 4096, 14336), M16, PAR_FSDP) == \
        P(None, "data", "model")


def test_moe_expert_rule():
    # (L, E, D, F): experts over model, FSDP over d_model
    assert param_pspec("blocks/moe/wi", (94, 128, 4096, 1536), M16, PAR_FSDP) \
        == P(None, "model", "data", None)
    assert param_pspec("blocks/moe/wo", (94, 128, 1536, 4096), M16, PAR_FSDP) \
        == P(None, "model", None, "data")


def test_divisibility_safety():
    # kv-head projection of MQA (kv=1 -> 128 cols): still divisible; but a
    # 10-col output must drop the axis
    assert param_pspec("blocks/attn/wk", (32, 4096, 10), M16, PAR) == \
        P(None, None, None)
    # norm vectors replicate
    assert param_pspec("blocks/ln1", (32, 4096), M16, PAR) == P(None, None)


def test_opt_state_zero1_adds_data_axis():
    spec = opt_state_pspec(P(None, None, "model"), (32, 4096, 14336), M16, PAR)
    assert spec == P(None, "data", "model")
    # fsdp already shards over data -> unchanged
    spec = opt_state_pspec(P(None, "data", "model"), (32, 4096, 14336), M16,
                           PAR_FSDP)
    assert spec == P(None, "data", "model")


def test_batch_rule():
    assert batch_pspec((256, 4096), M16, 256) == P("data", None)
    m3 = FakeMesh(pod=2, data=16, model=16)
    assert batch_pspec((256, 4096), m3, 256) == P(("pod", "data"), None)
    # batch=1 (long_500k) cannot shard
    assert batch_pspec((1, 524288), m3, 1) == P(None, None)


def test_cache_rule():
    # (L, B, T, KV, hd): kv divisible -> heads sharded
    assert cache_pspec("k", (32, 128, 32768, 16, 128), M16, 128) == \
        P(None, "data", None, "model", None)
    # MQA kv=1 -> shard head_dim instead
    assert cache_pspec("k", (88, 128, 32768, 1, 128), M16, 128) == \
        P(None, "data", None, None, "model")


def test_param_shardings_cover_all_archs():
    from repro.sharding.specs import param_shardings

    for arch in ("llama3-8b", "qwen3-moe-235b-a22b", "recurrentgemma-2b",
                 "xlstm-1.3b", "whisper-medium"):
        cfg = get_arch(arch, reduced=True)
        api = build_model(cfg)
        tree = param_shardings(api.param_spec(), MESH,
                               ParallelConfig(data=1, model=1))
        assert len(jax.tree.leaves(tree)) == len(jax.tree.leaves(api.param_spec()))
