"""Serving features: int8 KV cache, microbatch picker, serve fns."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ParallelConfig, ShapeConfig, TRAIN_4K, get_arch
from repro.models import build_model
from repro.models import transformer as tr


def test_int8_kv_cache_decode_close_to_bf16():
    cfg = get_arch("qwen2.5-3b", reduced=True).replace(remat=False)
    cfg_q = cfg.replace(kv_quant=True)
    params = tr.init_lm(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    _, c0 = tr.prefill(params, toks, cfg, max_len=20)
    _, c1 = tr.prefill(params, toks, cfg_q, max_len=20)
    nxt = jnp.asarray([5, 9], jnp.int32)
    l0, _ = tr.decode_step(params, c0, nxt, jnp.asarray(16, jnp.int32), cfg)
    l1, _ = tr.decode_step(params, c1, nxt, jnp.asarray(16, jnp.int32), cfg_q)
    assert float(jnp.abs(l0 - l1).max()) < 0.3   # int8 quantization noise
    # memory layout: int8 cache is ~half the bf16 cache
    def nbytes(c):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c))
    assert nbytes(c1) < 0.6 * nbytes(c0)


def test_int8_kv_argmax_stable():
    cfg = get_arch("llama3-8b", reduced=True).replace(remat=False)
    params = tr.init_lm(jax.random.key(1), cfg)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24)), jnp.int32)
    outs = {}
    for quant in (False, True):
        c = cfg.replace(kv_quant=quant)
        logits, caches = tr.prefill(params, toks, c, max_len=32)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        ids = [tok]
        for i in range(4):
            logits, caches = tr.decode_step(
                params, caches, tok, jnp.asarray(24 + i, jnp.int32), c)
            tok = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
            ids.append(tok)
        outs[quant] = np.stack([np.asarray(t) for t in ids])
    # greedy decode should rarely flip under int8 KV; require full agreement
    # on this seed (validated stable)
    np.testing.assert_array_equal(outs[False], outs[True])


def test_pick_microbatch_heuristic():
    from repro.launch.dryrun import pick_microbatch

    cfg = get_arch("granite-34b")
    par_sp = ParallelConfig(data=16, model=16, seq_sharding=True)
    par_nosp = ParallelConfig(data=16, model=16, seq_sharding=False)
    axes = {"data": 16, "model": 16}
    n_sp = pick_microbatch(cfg, TRAIN_4K, axes, par_sp)
    n_nosp = pick_microbatch(cfg, TRAIN_4K, axes, par_nosp)
    assert n_nosp >= n_sp            # SP shrinks the carry -> fewer microbatches
    assert TRAIN_4K.global_batch % n_nosp == 0
    # small model needs no accumulation
    small = get_arch("qwen2.5-3b", reduced=True)
    assert pick_microbatch(small, TRAIN_4K, axes, par_sp) == 1


def test_microbatched_step_matches_unbatched():
    from repro.configs import TrainConfig
    from repro.launch.mesh import make_mesh
    from repro.train.step import gspmd_init_state, make_gspmd_train_step

    cfg = get_arch("llama3-8b", reduced=True).replace(remat=False)
    api = build_model(cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    par = ParallelConfig(data=1, model=1)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32),
    }
    outs = {}
    for micro in (1, 4):
        tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=1, total_steps=5,
                           weight_decay=0.0, microbatch=micro)
        step, *_ = make_gspmd_train_step(api, mesh, par, tcfg)
        p, o = gspmd_init_state(api, mesh, par)
        p, o, m = step(p, o, batch)
        outs[micro] = (float(m["loss"]), p)
    assert abs(outs[1][0] - outs[4][0]) < 2e-3
    deltas = [float(jnp.abs(a - b).max()) for a, b in
              zip(jax.tree.leaves(outs[1][1]), jax.tree.leaves(outs[4][1]))]
    assert max(deltas) < 3e-2  # identical up to Adam sign-noise on fp ties
