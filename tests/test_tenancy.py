"""Multi-tenant fabric subsystem tests: tenant-tagged request streams,
arbiter policies, preemption correctness (byte conservation per dim), and
the cross-tenant Themis shared-tracker mode."""
import pytest

from repro.core.latency_model import LatencyModel
from repro.core.requests import CollectiveRequest
from repro.core.simulator import simulate_requests
from repro.core.workloads import make_resnet152
from repro.tenancy import (
    FabricArbiter,
    TenantJob,
    TenantSpec,
    fairness_index,
    isolated_latencies,
    jain_index,
    schedule_tenant_requests,
    simulate_fabric,
    synthetic_requests,
    tenant_reports,
)
from repro.topology import make_table2_topologies

TOPOS = make_table2_topologies()
TOPO2D = TOPOS["2D-SW_SW"]
MB = 1e6


def _asym_scenario():
    """Heavy batch tenant (big ARs, first in line) + light latency tenant."""
    heavy = synthetic_requests("heavy", "AR", 300 * MB, 2)
    light = synthetic_requests("light", "AR", 8 * MB, 6,
                               gap_s=0.0004, start_s=0.0002)
    specs = [TenantSpec("heavy", weight=1.0),
             TenantSpec("light", weight=1.0, priority=1, slo_slowdown=1.5)]
    return specs, heavy + light


# --------------------------------------------------------------------------
# Tenant-tagged request streams
# --------------------------------------------------------------------------
def test_tenant_job_emits_tagged_iterated_stream():
    spec = TenantSpec("resnet", weight=2.0, iterations=3, n_buckets=4,
                      arrival_offset_s=0.01)
    job = TenantJob(spec, make_resnet152())
    reqs = job.requests()
    assert len(reqs) == 3 * 4
    assert all(r.tenant == "resnet" for r in reqs)
    assert all(r.stream.startswith("resnet/it") for r in reqs)
    # iterations shift monotonically; no request before the arrival offset
    assert min(r.issue_time for r in reqs) >= 0.01
    it0 = [r for r in reqs if r.stream.startswith("resnet/it0/")]
    it2 = [r for r in reqs if r.stream.startswith("resnet/it2/")]
    assert max(r.issue_time for r in it0) < min(r.issue_time for r in it2)
    # each iteration carries the full gradient mass
    grad = sum(b.size_bytes for b in it0)
    assert grad == pytest.approx(
        sum(o.size_bytes for o in job.workload.comm_ops), rel=1e-9)


def test_tenant_spec_validation():
    with pytest.raises(ValueError):
        TenantSpec("x", weight=0.0)
    with pytest.raises(ValueError):
        TenantSpec("x", slo_slowdown=0.5)
    with pytest.raises(ValueError):
        TenantSpec("x", iterations=0)
    with pytest.raises(ValueError):
        TenantSpec("x", n_buckets=0)


# --------------------------------------------------------------------------
# Arbiter policies
# --------------------------------------------------------------------------
def test_arbiter_policy_validation():
    with pytest.raises(ValueError):
        FabricArbiter("round-robin", [])
    with pytest.raises(ValueError):
        FabricArbiter("fifo", [], quantum_chunks=0)
    assert FabricArbiter("fifo", []).preemption is False  # FIFO never preempts


def test_weighted_fair_beats_fifo_for_light_tenant():
    """Under FIFO the light tenant drains after the heavy tenant's giant
    collectives; weighted-fair interleaves them, cutting the light tenant's
    latency and raising the Jain index over per-tenant slowdowns."""
    specs, reqs = _asym_scenario()
    spec_map = {s.name: s for s in specs}
    iso = isolated_latencies(TOPO2D, reqs, chunks_per_collective=8)
    stats = {}
    for policy in ("fifo", "weighted-fair"):
        arb = FabricArbiter(policy, specs)
        res, _ = simulate_fabric(TOPO2D, reqs, arbiter=arb,
                                 chunks_per_collective=8)
        reps = tenant_reports(res, reqs, iso, spec_map)
        stats[policy] = (reps, fairness_index(reps))
    fifo_reps, fifo_jain = stats["fifo"]
    wf_reps, wf_jain = stats["weighted-fair"]
    assert wf_reps["light"].mean_slowdown < fifo_reps["light"].mean_slowdown
    assert wf_jain > fifo_jain


def test_strict_priority_serves_high_priority_first():
    specs, reqs = _asym_scenario()  # light has priority=1
    iso = isolated_latencies(TOPO2D, reqs, chunks_per_collective=8)
    arb = FabricArbiter("strict-priority", specs)
    res, _ = simulate_fabric(TOPO2D, reqs, arbiter=arb,
                             chunks_per_collective=8)
    reps = tenant_reports(res, reqs, iso, {s.name: s for s in specs})
    arb_fifo = FabricArbiter("fifo", specs)
    res_f, _ = simulate_fabric(TOPO2D, reqs, arbiter=arb_fifo,
                               chunks_per_collective=8)
    reps_f = tenant_reports(res_f, reqs, iso, {s.name: s for s in specs})
    assert reps["light"].mean_slowdown < reps_f["light"].mean_slowdown
    assert arb.preempt_count > 0


def test_slo_boost_kicks_in_on_violation():
    spec = TenantSpec("t", weight=1.0, slo_slowdown=1.5)
    arb = FabricArbiter("slo-aware", [spec], isolated_latency={"t": 0.010})
    assert arb.slo_boost("t") == 1.0          # no observations yet
    arb.on_group_finish(0, "t", 0.030)        # slowdown 3.0 > slo 1.5
    assert arb.observed_slowdown("t") == pytest.approx(3.0)
    assert arb.slo_boost("t") == pytest.approx(2.0)
    assert arb.effective_weight("t") == pytest.approx(2.0)
    arb.on_group_finish(0, "t", 0.012)        # latest observation wins
    assert arb.slo_boost("t") == 1.0          # back under SLO


# --------------------------------------------------------------------------
# Preemption correctness
# --------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["weighted-fair", "strict-priority"])
def test_preemption_conserves_bytes(policy):
    """Preempted services requeue their un-drained chunks: total and
    per-dim wire bytes match the schedule-invariant baseline placement."""
    specs, reqs = _asym_scenario()
    lm = LatencyModel(TOPO2D)
    arb = FabricArbiter(policy, specs)
    res, _ = simulate_fabric(TOPO2D, reqs, arbiter=arb, policy="baseline",
                             chunks_per_collective=8)
    assert arb.preempt_count > 0  # the scenario genuinely preempts
    want_total = sum(lm.total_wire_bytes(r.collective, r.size_bytes)
                     for r in reqs)
    assert sum(res.dim_wire_bytes) == pytest.approx(want_total, rel=1e-9)
    # baseline chunk schedules are arrival-invariant -> per-dim totals equal
    # the sum of each tenant's solo run, preemption or not
    per_dim = [0.0] * TOPO2D.num_dims
    for tenant in ("heavy", "light"):
        solo, _ = simulate_fabric(
            TOPO2D, [r for r in reqs if r.tenant == tenant],
            policy="baseline", chunks_per_collective=8)
        for k in range(TOPO2D.num_dims):
            per_dim[k] += solo.dim_wire_bytes[k]
    for k in range(TOPO2D.num_dims):
        assert res.dim_wire_bytes[k] == pytest.approx(per_dim[k], rel=1e-9)
    # every request finishes after its issue time
    for g, r in enumerate(reqs):
        assert res.group_finish[g] > r.issue_time


def test_preemption_splits_inflight_service():
    """A light request arriving while the heavy tenant's 8-chunk service is
    in flight must not wait for the whole service to drain: preemption
    splits it at chunk granularity, so the light tenant finishes strictly
    earlier than without preemption, with no bytes lost."""
    specs = [TenantSpec("heavy"), TenantSpec("light")]
    heavy = synthetic_requests("heavy", "AR", 300 * MB, 1)
    solo, _ = simulate_fabric(TOPO2D, heavy, chunks_per_collective=8)
    light = synthetic_requests("light", "AR", 4 * MB, 1,
                               start_s=0.25 * solo.makespan)
    reqs = heavy + light
    lm = LatencyModel(TOPO2D)
    finishes = {}
    for preempt in (True, False):
        # quantum 8 -> the heavy collective's chunks coalesce into
        # multi-chunk services, the thing preemption exists to split
        arb = FabricArbiter("weighted-fair", specs, preemption=preempt,
                            quantum_chunks=8)
        res, _ = simulate_fabric(TOPO2D, reqs, arbiter=arb,
                                 chunks_per_collective=8)
        finishes[preempt] = res.group_finish[1]
        want = sum(lm.total_wire_bytes(r.collective, r.size_bytes)
                   for r in reqs)
        assert sum(res.dim_wire_bytes) == pytest.approx(want, rel=1e-9)
        if preempt:
            assert arb.preempt_count > 0
            assert any(res.groups_interleave_on(k)
                       for k in range(TOPO2D.num_dims))
    assert finishes[True] < finishes[False]


# --------------------------------------------------------------------------
# Cross-tenant Themis: shared vs per-tenant Dim Load Trackers
# --------------------------------------------------------------------------
def test_shared_tracker_sees_other_tenants_loads():
    """With the shared tracker, tenant B's chunk orders react to tenant A's
    in-flight load; with per-tenant trackers, B schedules as if alone."""
    a = synthetic_requests("a", "AR", 200 * MB, 1)
    b = synthetic_requests("b", "AR", 50 * MB, 1, start_s=1e-4)
    shared = schedule_tenant_requests(TOPO2D, a + b, shared_tracker=True,
                                      chunks_per_collective=8)
    per_t = schedule_tenant_requests(TOPO2D, a + b, shared_tracker=False,
                                     chunks_per_collective=8)
    b_solo = schedule_tenant_requests(TOPO2D, b, shared_tracker=True,
                                      chunks_per_collective=8)
    # blind mode schedules B exactly as if it ran alone
    assert [c.schedule for c in per_t[1]] == [c.schedule for c in b_solo[0]]
    # shared mode steers B differently (around A's residual load)
    assert ([c.schedule for c in shared[1]]
            != [c.schedule for c in per_t[1]])


def test_shared_tracker_helps_on_some_scenario():
    """The cross-tenant Themis (shared tracker) beats blind per-tenant
    trackers on makespan or mean slowdown for staggered contending
    tenants on at least one Table-2 topology."""
    wins = 0
    for tname in ("2D-SW_SW", "3D-SW_SW_SW_hetero"):
        topo = TOPOS[tname]
        specs = [TenantSpec(n) for n in ("a", "b", "c")]
        reqs = []
        for i, s in enumerate(specs):
            reqs += synthetic_requests(s.name, "AR", 200 * MB, 3,
                                       gap_s=0.003, start_s=i * 0.001)
        out = {}
        for shared in (True, False):
            arb = FabricArbiter("weighted-fair", specs)
            res, _ = simulate_fabric(topo, reqs, arbiter=arb,
                                     shared_tracker=shared,
                                     chunks_per_collective=32)
            out[shared] = res.finish_time()
        if out[True] < out[False]:
            wins += 1
    assert wins >= 1


# --------------------------------------------------------------------------
# SimResult per-stream/tenant aggregation
# --------------------------------------------------------------------------
def test_stream_stats_aggregation():
    reqs = (synthetic_requests("a", "AR", 40 * MB, 2)
            + synthetic_requests("b", "RS", 20 * MB, 3, gap_s=1e-4))
    res, _ = simulate_requests(TOPO2D, reqs, policy="themis",
                               chunks_per_collective=8)
    by_tenant = res.stream_stats(by="tenant")
    assert set(by_tenant) == {"a", "b"}
    assert by_tenant["a"].n == 2 and by_tenant["b"].n == 3
    for tag, st in by_tenant.items():
        gs = [g for g, r in enumerate(reqs) if r.tenant == tag]
        assert st.finish == pytest.approx(
            max(res.group_finish[g] for g in gs))
        assert st.latency_max >= st.latency_mean > 0
    # wire-byte attribution is exhaustive
    assert sum(s.wire_bytes for s in by_tenant.values()) == pytest.approx(
        sum(res.dim_wire_bytes), rel=1e-9)
    assert res.stream_finish("a", by="tenant") == by_tenant["a"].finish
    with pytest.raises(ValueError):
        res.stream_stats(by="nope")


def test_jain_index_basics():
    assert jain_index([]) == 1.0
    assert jain_index([2.0, 2.0, 2.0]) == pytest.approx(1.0)
    assert jain_index([1.0, 0.0, 0.0]) == pytest.approx(1 / 3)
    assert 0.5 < jain_index([1.0, 2.0]) < 1.0
