"""Topology search: budget conservation, pruning soundness, determinism,
and the LatencyModel/StageTables memoization satellite."""
import pytest

from repro.core.latency_model import LatencyModel
from repro.core.requests import CollectiveRequest
from repro.core.simulator import simulate, simulate_requests
from repro.core.workloads import dp_bucket_requests, make_resnet152
from repro.topology import (
    NetworkDim,
    SearchConfig,
    TopoKind,
    Topology,
    bw_split_topology,
    enumerate_bw_shares,
    make_table2_topologies,
    make_tpu_pod_topology,
    search_topologies,
    stream_lower_bound,
)

MB = 1e6
TOPOS = make_table2_topologies()


def _burst(n=6):
    return [CollectiveRequest("AR", r.size_bytes)
            for r in dp_bucket_requests(make_resnet152(), n)]


# ---------------------------------------------------------------------------
# Candidate construction
# ---------------------------------------------------------------------------
def test_enumerate_bw_shares_grid():
    shares = enumerate_bw_shares(3, 6)
    assert len(shares) == 10  # C(5, 2) compositions of 6 into 3 positives
    assert all(sum(s) == 6 and min(s) >= 1 for s in shares)
    assert shares == sorted(shares)  # deterministic lexicographic order
    with pytest.raises(ValueError, match="granularity"):
        enumerate_bw_shares(3, 2)


def test_bw_split_preserves_budget_shape_and_latency():
    base = make_tpu_pod_topology(2, 8, 8)
    cand = bw_split_topology(base, (0.5, 0.25, 0.25), perm=(2, 0, 1))
    assert cand.total_bw_bytes == pytest.approx(base.total_bw_bytes, rel=1e-12)
    assert cand.total_npus == base.total_npus
    # perm moved base dim 2 to the innermost position, kind/latency intact
    assert cand.dims[0].npus == base.dims[2].npus
    assert cand.dims[0].topo == base.dims[2].topo
    assert cand.dims[0].step_latency_s == base.dims[2].step_latency_s
    assert cand.dims[0].aggr_bw_bytes == pytest.approx(
        0.5 * base.total_bw_bytes)


def test_bw_split_validation():
    base = TOPOS["2D-SW_SW"]
    with pytest.raises(ValueError, match="one entry per dimension"):
        bw_split_topology(base, (1.0,))
    with pytest.raises(ValueError, match="permute"):
        bw_split_topology(base, (0.5, 0.5), perm=(0, 0))
    with pytest.raises(ValueError, match="positive"):
        bw_split_topology(base, (1.0, 0.0))


# ---------------------------------------------------------------------------
# Lower bound soundness (the pruning certificate)
# ---------------------------------------------------------------------------
def test_stream_lower_bound_is_sound():
    base = TOPOS["2D-SW_SW"]
    reqs = _burst(5) + [CollectiveRequest("RS", 30 * MB, issue_time=5e-4),
                        CollectiveRequest("AG", 24 * MB, issue_time=1e-3)]
    for shares in ((1, 7), (4, 4), (7, 1)):
        topo = bw_split_topology(base, tuple(s / 8 for s in shares))
        lb = stream_lower_bound(topo, reqs)
        res, _ = simulate_requests(topo, reqs, chunks_per_collective=8)
        assert lb <= res.makespan * (1 + 1e-12)
        # and with the schedule-insensitive baseline policy too
        res_b, _ = simulate_requests(topo, reqs, policy="baseline",
                                     chunks_per_collective=8)
        assert lb <= res_b.makespan * (1 + 1e-12)


def test_pruning_sound_and_skips_hopeless_candidates():
    base = TOPOS["2D-SW_SW"]
    reqs = _burst(5)
    kw = dict(granularity=24, rounds=0, search_dim_orders=False,
              chunks_per_collective=8)
    pruned_run = search_topologies(base, reqs, SearchConfig(**kw))
    full_run = search_topologies(base, reqs, SearchConfig(**kw, prune=False))
    assert pruned_run.pruned > 0
    assert full_run.pruned == 0
    # pruning must never change the winner
    assert pruned_run.best.makespan == full_run.best.makespan
    assert pruned_run.best.shares == full_run.best.shares
    assert pruned_run.scenarios_run < full_run.scenarios_run


# ---------------------------------------------------------------------------
# Search behavior
# ---------------------------------------------------------------------------
def test_search_is_deterministic_under_fixed_seed():
    base = make_tpu_pod_topology(2, 4, 4)
    reqs = _burst(4)
    cfg = SearchConfig(granularity=5, rounds=1, top_k=3, seeds=(0, 1),
                       jitter=0.08, chunks_per_collective=6)
    a = search_topologies(base, reqs, cfg)
    b = search_topologies(base, reqs, cfg)
    key = lambda r: [(c.shares, c.denom, c.perm, c.makespan,
                      c.bw_utilization) for c in r.evaluated]
    assert key(a) == key(b)
    assert a.best.topology == b.best.topology
    assert a.pruned == b.pruned


def test_search_beats_default_on_resnet_burst():
    base = TOPOS["2D-SW_SW"]
    res = search_topologies(
        base, _burst(6),
        SearchConfig(granularity=8, rounds=2, top_k=4,
                     chunks_per_collective=8))
    assert res.best.makespan < res.default.makespan
    assert res.improvement > 1.01  # observed ~1.017 (deterministic)
    # every candidate — grid *and* refinement mutations (including those
    # derived from the apportioned default) — conserved the BW budget
    for c in res.evaluated:
        assert sum(c.shares) == c.denom
        assert c.topology.total_bw_bytes == pytest.approx(
            base.total_bw_bytes, rel=1e-9)


def test_pareto_front_is_nondominated():
    res = search_topologies(
        TOPOS["2D-SW_SW"], _burst(5),
        SearchConfig(granularity=8, rounds=1, top_k=3,
                     chunks_per_collective=8))
    front = res.pareto
    assert front
    for i, a in enumerate(front):
        for b in front[i + 1:]:
            dominates = ((a.makespan <= b.makespan
                          and a.bw_utilization >= b.bw_utilization)
                         or (b.makespan <= a.makespan
                             and b.bw_utilization >= a.bw_utilization))
            strict = (a.makespan, a.bw_utilization) != (
                b.makespan, b.bw_utilization)
            assert not (dominates and strict)
    assert min(c.makespan for c in front) == res.best.makespan


# ---------------------------------------------------------------------------
# Satellite: StageTables built once per topology across simulate() loops
# ---------------------------------------------------------------------------
def test_stage_tables_memoized_across_simulate_calls():
    # A structurally unique topology so earlier tests can't have cached it.
    topo = Topology("memo-probe", (
        NetworkDim(16, TopoKind.SWITCH, 123.0, 3, 7e-7),
        NetworkDim(8, TopoKind.RING, 77.0, 2, 9e-7),
    ))
    reqs = [CollectiveRequest("AR", 4 * MB, issue_time=i * 1e-4)
            for i in range(3)]
    before = LatencyModel.stage_table_builds
    for _ in range(5):
        simulate_requests(topo, reqs, chunks_per_collective=4)
    built = LatencyModel.stage_table_builds - before
    assert built == 1, f"stage tables rebuilt {built} times in a loop of 5"
    # the reference engine shares the same memoized instance
    before = LatencyModel.stage_table_builds
    groups = [simulate_requests(topo, reqs, chunks_per_collective=4)[1][0]]
    simulate(topo, groups, engine="reference")
    assert LatencyModel.stage_table_builds == before


def test_for_topology_returns_shared_instance():
    t = TOPOS["2D-SW_SW"]
    assert LatencyModel.for_topology(t) is LatencyModel.for_topology(t)
    # equal-valued topologies share too (candidate fabrics are rebuilt)
    clone = Topology(t.name, t.dims)
    assert LatencyModel.for_topology(clone) is LatencyModel.for_topology(t)
