"""Tests for ``tools/lint_engine.py`` (engine-hygiene AST lint)."""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
LINT = REPO / "tools" / "lint_engine.py"

sys.path.insert(0, str(REPO / "tools"))
import lint_engine  # noqa: E402


def _violations(tmp_path, src: str) -> list[str]:
    f = tmp_path / "probe.py"
    f.write_text(src)
    return lint_engine.lint_file(f)


def test_flags_float_equality(tmp_path):
    out = _violations(tmp_path, "def f(x):\n    return x == 1.5\n")
    assert len(out) == 1 and "float equality" in out[0]
    # != and arithmetic/division operands count too
    out = _violations(tmp_path, "def f(x, y):\n    return x / 2 != y\n")
    assert len(out) == 1
    out = _violations(tmp_path, "def f(x):\n    return float(x) == 0\n")
    assert len(out) == 1


def test_integer_and_ordered_comparisons_are_fine(tmp_path):
    assert _violations(tmp_path, "def f(x):\n    return x == 3\n") == []
    assert _violations(tmp_path, "def f(x):\n    return x <= 1.5\n") == []
    assert _violations(tmp_path, "def f(x, y):\n    return x == y\n") == []


def test_flags_wall_clock_reads(tmp_path):
    src = ("import time\n"
           "from time import perf_counter\n"
           "def f():\n"
           "    return time.time() + perf_counter() + monotonic()\n")
    out = _violations(tmp_path, src)
    assert len(out) == 4  # the from-import plus three call sites
    assert any("perf_counter" in v for v in out)


def test_lint_allow_escape(tmp_path):
    src = ("def f(x):\n"
           "    return x == 1.5  # lint: allow\n")
    assert _violations(tmp_path, src) == []


def test_engine_trees_are_clean():
    """The real gate: the simulator and tenancy trees must pass."""
    proc = subprocess.run(
        [sys.executable, str(LINT)], capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_exits_nonzero_on_violation(tmp_path):
    f = tmp_path / "bad.py"
    f.write_text("import time\nx = time.time()\n")
    proc = subprocess.run(
        [sys.executable, str(LINT), str(f)], capture_output=True, text=True)
    assert proc.returncode == 1
    assert "wall-clock" in proc.stdout


def test_flags_unguarded_tracer_calls(tmp_path):
    out = _violations(tmp_path, "def f(trc, dim, now):\n"
                                "    trc.service_start(dim, now)\n")
    assert len(out) == 1 and "unguarded tracer call" in out[0]
    out = _violations(tmp_path, "def f(trc_enq, dim):\n"
                                "    trc_enq(dim)\n")
    assert len(out) == 1 and "'trc_enq'" in out[0]
    out = _violations(tmp_path, "def f(tracer):\n"
                                "    tracer.enq_dims.append(0)\n")
    assert len(out) == 1


def test_guarded_tracer_calls_are_fine(tmp_path):
    src = ("def f(trc, trc_enq, trc_enq_t, dim, now):\n"
           "    if trc is not None:\n"
           "        trc.service_start(dim, now)\n"
           "    if trc_enq is not None:\n"
           "        trc_enq(dim)\n"
           "        trc_enq_t(now)\n")  # sibling alias shares the branch
    assert _violations(tmp_path, src) == []
    # conditional-expression guards count too (the pre-bind idiom)
    src = ("def f(trc):\n"
           "    trc_enq = trc.enq_dims.append if trc is not None else None\n")
    assert _violations(tmp_path, src) == []
    # non-tracer names are not subject to the rule
    assert _violations(tmp_path, "def f(track):\n    track.emit(1)\n") == []


def test_tracer_guard_does_not_leak_outside_branch(tmp_path):
    src = ("def f(trc, dim):\n"
           "    if trc is not None:\n"
           "        pass\n"
           "    trc.grant(dim)\n")  # after the branch: unguarded again
    out = _violations(tmp_path, src)
    assert len(out) == 1 and out[0].endswith("branch)")


def test_tracer_rule_honors_lint_allow(tmp_path):
    src = ("def f(trc, dim):\n"
           "    trc.grant(dim)  # lint: allow\n")
    assert _violations(tmp_path, src) == []


def test_flags_unguarded_fault_calls(tmp_path):
    out = _violations(tmp_path, "def f(flt, dim, now):\n"
                                "    flt.compile(2)\n")
    assert len(out) == 1 and "unguarded fault-machinery call" in out[0]
    out = _violations(tmp_path, "def f(flt_enq, task, now):\n"
                                "    flt_enq(task, now)\n")
    assert len(out) == 1 and "'flt_enq'" in out[0]
    out = _violations(tmp_path, "def f(faults):\n"
                                "    faults.compile(2)\n")
    assert len(out) == 1


def test_guarded_fault_calls_are_fine(tmp_path):
    src = ("def f(flt, flt_enq, task, now):\n"
           "    if flt is not None:\n"
           "        flt_enq(task, now)\n")
    assert _violations(tmp_path, src) == []
    # the engines' nested-if pattern: fault-ish names may appear in an
    # if-test only inside an already-guarded body
    src = ("def f(flt, dim_down, dim, flt_recover, now):\n"
           "    if flt is not None:\n"
           "        if dim_down[dim]:\n"
           "            flt_recover(dim, now)\n")
    assert _violations(tmp_path, src) == []
    # non-fault names are not subject to the rule
    assert _violations(tmp_path, "def f(flow):\n    flow.emit(1)\n") == []


def test_fault_and_tracer_guards_are_independent(tmp_path):
    # a tracer guard does NOT license fault calls (and vice versa)
    src = ("def f(trc, flt_enq, task, now):\n"
           "    if trc is not None:\n"
           "        flt_enq(task, now)\n")
    out = _violations(tmp_path, src)
    assert len(out) == 1 and "fault-machinery" in out[0]
    src = ("def f(flt, trc, dim, now):\n"
           "    if flt is not None:\n"
           "        trc.fault(dim, now, 1.0, 0.0)\n")
    out = _violations(tmp_path, src)
    assert len(out) == 1 and "tracer" in out[0]
    # a combined test guards both
    src = ("def f(flt, trc, dim, now):\n"
           "    if flt is not None and trc is not None:\n"
           "        trc.fault(dim, now, 1.0, 0.0)\n")
    assert _violations(tmp_path, src) == []


# -- vector zones (compiled-engine hot sections) -----------------------------

def test_zone_flags_heapq_and_mutation(tmp_path):
    src = ("import heapq\n"
           "def f(events, out, xs):\n"
           "    # lint: vector-zone-begin\n"
           "    heapq.heappush(events, (0.0, 1))\n"
           "    heappop(events)\n"
           "    for x in xs:\n"
           "        out.append(x)\n"
           "    # lint: vector-zone-end\n")
    out = _violations(tmp_path, src)
    assert len(out) == 3
    assert sum("heapq call" in v for v in out) == 2
    assert any(".append()" in v for v in out)


def test_zone_rule_is_scoped_to_the_zone(tmp_path):
    # identical constructs outside the markers are untouched
    src = ("def f(events, out, xs):\n"
           "    heappush(events, (0.0, 1))\n"
           "    for x in xs:\n"
           "        out.append(x)\n"
           "    # lint: vector-zone-begin\n"
           "    y = xs * 2\n"
           "    # lint: vector-zone-end\n"
           "    out.extend(y)\n")
    assert _violations(tmp_path, src) == []


def test_zone_honors_lint_allow(tmp_path):
    src = ("def f(out, xs):\n"
           "    # lint: vector-zone-begin\n"
           "    out.extend(xs)  # lint: allow (bounded per-run)\n"
           "    # lint: vector-zone-end\n")
    assert _violations(tmp_path, src) == []


def test_zone_unbalanced_markers_are_violations(tmp_path):
    out = _violations(tmp_path, "x = 1\n# lint: vector-zone-begin\ny = 2\n")
    assert len(out) == 1 and "never closed" in out[0]
    out = _violations(tmp_path, "x = 1\n# lint: vector-zone-end\n")
    assert len(out) == 1 and "without a matching begin" in out[0]
    src = ("# lint: vector-zone-begin\n"
           "# lint: vector-zone-begin\n"
           "# lint: vector-zone-end\n")
    out = _violations(tmp_path, src)
    assert len(out) == 1 and "nested" in out[0]


def test_compiled_engine_zones_exist_and_pass():
    """The motivating gate: engine_compiled.py declares vector zones and
    its hot sections stay free of per-event scalar mutation."""
    eng = REPO / "src" / "repro" / "core" / "engine_compiled.py"
    src = eng.read_text()
    assert src.count("lint: vector-zone-begin") >= 3
    assert src.count("lint: vector-zone-begin") == \
        src.count("lint: vector-zone-end")
    assert lint_engine.lint_file(eng) == []
