"""Arrival-time-aware multi-collective engine tests.

Covers the online request API: staggered issue times, contention between
in-flight collectives, wire-byte conservation, the incremental
running-load scheduler path, and priority tie-breaking.
"""
import pytest

from repro.core.latency_model import LatencyModel
from repro.core.requests import CollectiveRequest
from repro.core.scheduler import ThemisScheduler
from repro.core.simulator import simulate, simulate_requests
from repro.topology import make_table2_topologies

TOPOS = make_table2_topologies()
TOPO2D = TOPOS["2D-SW_SW"]
MB = 1e6


def _solo_makespan(topo, req, policy, intra):
    res, _ = simulate_requests(topo, [req], policy=policy, intra=intra,
                               chunks_per_collective=16)
    return res.makespan


@pytest.mark.parametrize("policy,intra", [("baseline", "FIFO"),
                                          ("themis", "SCF")])
def test_staggered_collectives_contend_on_2d(policy, intra):
    """Two staggered collectives on a 2-dim topology: the joint makespan is
    strictly larger than either alone (shared dims serialize some work),
    and wire bytes are conserved, under both FIFO and SCF."""
    lm = LatencyModel(TOPO2D)
    first = CollectiveRequest("AR", 200 * MB, issue_time=0.0)
    solo1 = _solo_makespan(TOPO2D, first, policy, intra)
    # issue the second while the first is mid-flight
    second = CollectiveRequest("AR", 200 * MB, issue_time=0.3 * solo1)
    solo2 = _solo_makespan(TOPO2D, second, policy, intra)

    res, groups = simulate_requests(TOPO2D, [first, second], policy=policy,
                                    intra=intra, chunks_per_collective=16)
    assert res.makespan > solo1
    assert res.makespan > solo2
    # per-dim wire-byte totals are conserved across the joint run
    want_total = 2 * lm.total_wire_bytes("AR", 200 * MB)
    assert sum(res.dim_wire_bytes) == pytest.approx(want_total, rel=1e-9)
    # both requests complete, in a window consistent with their issue times
    assert res.group_finish[0] >= res.group_issue[0]
    assert res.group_finish[1] >= second.issue_time
    assert all(len(g) == 16 for g in groups)


def test_perdim_wire_conservation_vs_solo_baseline():
    """Under the static baseline schedule the per-dim byte placement is
    schedule-invariant, so the joint run's per-dim wire bytes equal the sum
    of the two solo runs' per-dim wire bytes exactly."""
    a = CollectiveRequest("AR", 150 * MB, issue_time=0.0)
    b = CollectiveRequest("AR", 90 * MB, issue_time=1e-4)
    ra, _ = simulate_requests(TOPO2D, [a], policy="baseline", intra="FIFO")
    rb, _ = simulate_requests(TOPO2D, [b], policy="baseline", intra="FIFO")
    rj, _ = simulate_requests(TOPO2D, [a, b], policy="baseline", intra="FIFO")
    for k in range(TOPO2D.num_dims):
        assert rj.dim_wire_bytes[k] == pytest.approx(
            ra.dim_wire_bytes[k] + rb.dim_wire_bytes[k], rel=1e-9)


def test_no_service_before_issue_time():
    req = CollectiveRequest("AR", 64 * MB, issue_time=0.005)
    res, _ = simulate_requests(TOPO2D, [req], policy="themis", intra="SCF")
    for k in range(TOPO2D.num_dims):
        for start, _end, _groups in res.dim_services[k]:
            assert start >= req.issue_time
    assert res.group_finish[0] > req.issue_time
    assert res.makespan >= req.issue_time


def test_issue_times_default_matches_legacy_t0():
    """simulate() without issue_times behaves exactly as all-issued-at-0."""
    sched = ThemisScheduler(LatencyModel(TOPO2D), "themis")
    g1 = sched.schedule_collective("AR", 100 * MB, 8)
    sched2 = ThemisScheduler(LatencyModel(TOPO2D), "themis")
    g2 = sched2.schedule_collective("AR", 100 * MB, 8)
    r_default = simulate(TOPO2D, [g1, g2], intra="SCF")
    r_zeros = simulate(TOPO2D, [g1, g2], issue_times=[0.0, 0.0], intra="SCF")
    assert r_default.makespan == pytest.approx(r_zeros.makespan, rel=1e-12)
    assert r_default.dim_wire_bytes == r_zeros.dim_wire_bytes


def test_fig12_style_bucket_stream_interleaves():
    """Calibrated (comm-bound) ResNet-152 bucket stream: per-dim service
    intervals from distinct bucket collectives interleave — real
    contention, not back-to-back execution."""
    from repro.core.workloads import (
        ALL_WORKLOADS,
        calibrate_compute,
        dp_bucket_requests,
        split_topology,
    )

    w = ALL_WORKLOADS["resnet152"]()
    calibrate_compute(w, list(TOPOS.values()), 1.54)
    for tname in ("2D-SW_SW", "3D-SW_SW_SW_homo"):
        _, dp_topo = split_topology(TOPOS[tname], w.mp_npus)
        reqs = dp_bucket_requests(w, 8)
        assert len(reqs) == 8
        assert all(r.issue_time <= w.compute_bwd_s for r in reqs)
        for policy, intra in (("baseline", "FIFO"), ("themis", "SCF")):
            res, _ = simulate_requests(dp_topo, reqs, policy=policy,
                                       intra=intra, chunks_per_collective=64)
            assert any(res.groups_interleave_on(k)
                       for k in range(dp_topo.num_dims)), (tname, policy)


def test_overlap_iteration_time_hides_comm():
    """Bucketed overlap can only help: exposed DP comm with buckets issued
    during bwd is <= the single-sync-point exposure."""
    from repro.core.workloads import ALL_WORKLOADS, iteration_time

    w = ALL_WORKLOADS["resnet152"]()
    for tname in ("2D-SW_SW", "3D-SW_SW_SW_homo"):
        topo = TOPOS[tname]
        sync = iteration_time(w, topo, "themis", intra="SCF")
        over = iteration_time(w, topo, "themis", intra="SCF",
                              overlap_buckets=8)
        assert over.exposed_dp_s <= sync.exposed_dp_s * 1.05
        assert over.total_s <= sync.total_s * 1.05


def test_schedule_request_keeps_running_loads():
    """The incremental path accumulates residual loads across requests
    instead of resetting, and drains them as the clock advances."""
    lm = LatencyModel(TOPO2D)
    sched = ThemisScheduler(lm, "themis")
    sched.schedule_request(CollectiveRequest("AR", 200 * MB, issue_time=0.0), 8)
    loads_mid = sched.tracker.get_loads()
    assert max(loads_mid) > max(lm.fixed_delay(k, "AR")
                                for k in range(TOPO2D.num_dims))
    # a request far in the future sees fully-drained dims (just its own A_K)
    sched.schedule_request(
        CollectiveRequest("RS", 1.0, issue_time=1e6), 1)
    drained = sched.tracker.get_loads()
    for k in range(TOPO2D.num_dims):
        assert drained[k] <= lm.fixed_delay(k, "RS") + lm.wire_time(k, 1.0) + 1e-12


def test_back_to_back_requests_accumulate_loads():
    lm = LatencyModel(TOPO2D)
    sched = ThemisScheduler(lm, "themis")
    sched.schedule_request(CollectiveRequest("AR", 100 * MB), 8)
    l1 = sum(sched.tracker.get_loads())
    sched.schedule_request(CollectiveRequest("AR", 100 * MB), 8)
    l2 = sum(sched.tracker.get_loads())
    assert l2 > l1  # no reset between requests


def test_priority_preempts_equal_size_request():
    """With equal sizes and issue times, the higher-priority request is
    served first within each dim's queue and finishes no later."""
    hi = CollectiveRequest("AR", 100 * MB, priority=1)
    lo = CollectiveRequest("AR", 100 * MB, priority=0)
    res, _ = simulate_requests(TOPO2D, [lo, hi], policy="baseline",
                               intra="FIFO", chunks_per_collective=8)
    assert res.group_finish[1] <= res.group_finish[0]


def test_request_validation():
    with pytest.raises(ValueError):
        CollectiveRequest("broadcast", 1e6)
    with pytest.raises(ValueError):
        CollectiveRequest("AR", -1.0)
    with pytest.raises(ValueError):
        CollectiveRequest("AR", 1e6, issue_time=-0.1)
