"""Scheduler -> JAX bridge + HLO collective audit."""
import pytest

from repro.comms.schedule_bridge import (
    collective_stats,
    predicted_axis_loads,
    themis_axis_orders,
    themis_axis_orders_stream,
    topology_from_axes,
)

AXES = {"model": 16, "data": 16, "pod": 2}


def test_topology_from_axes_order_innermost_first():
    topo, names = topology_from_axes(AXES)
    assert names == ["model", "data", "pod"]
    assert [d.npus for d in topo.dims] == [16, 16, 2]
    # ICI faster than DCN
    assert topo.dims[0].aggr_bw_bytes > topo.dims[2].aggr_bw_bytes


def test_baseline_orders_static():
    orders = themis_axis_orders(AXES, 1e9, 8, "baseline")
    assert all(o == ("model", "data", "pod") for o in orders)


def test_themis_orders_balance_loads():
    n = 64
    base = themis_axis_orders(AXES, 12e9, n, "baseline")
    them = themis_axis_orders(AXES, 12e9, n, "themis")
    lb = predicted_axis_loads(AXES, 12e9, base)
    lt = predicted_axis_loads(AXES, 12e9, them)

    def imbalance(loads):
        v = list(loads.values())
        return max(v) / max(min(v), 1e-12)

    assert imbalance(lt) < imbalance(lb)
    assert imbalance(lt) < 2.0
    assert len(set(them)) > 1  # chunks got distinct orders


def test_single_axis_degenerates():
    orders = themis_axis_orders({"data": 8}, 1e9, 4, "themis")
    assert all(o == ("data",) for o in orders)


def test_stream_orders_see_residual_loads():
    """Bucket k's orders are scheduled against buckets 0..k-1's residual
    loads: back-to-back buckets produce valid per-bucket permutations and
    the later bucket's leading-axis mix differs from an isolated schedule
    of the same bytes (the residual-load signature)."""
    n = 16
    per_bucket = themis_axis_orders_stream(AXES, [4e9, 4e9], n, "themis")
    assert len(per_bucket) == 2
    for orders in per_bucket:
        assert len(orders) == n
        for o in orders:
            assert sorted(o) == sorted(AXES)  # permutation of all axes
    fresh = themis_axis_orders(AXES, 4e9, n, "themis")

    def lead_counts(orders):
        out = {}
        for o in orders:
            out[o[0]] = out.get(o[0], 0) + 1
        return out

    assert lead_counts(per_bucket[1]) != lead_counts(fresh)


def test_stream_unsorted_issue_times_schedule_in_issue_order():
    """Out-of-order issue_times must not corrupt the running clock: the
    t=0 bucket is scheduled first (fresh tracker) even when listed last."""
    n = 8
    got = themis_axis_orders_stream(AXES, [4e9, 4e9], n, "themis",
                                    issue_times=[10.0, 0.0])
    want_first = themis_axis_orders(AXES, 4e9, n, "themis")
    assert got[1] == want_first  # t=0 bucket saw an empty fabric
    assert len(got[0]) == n


def test_stream_baseline_static():
    per_bucket = themis_axis_orders_stream(AXES, [1e9, 1e9], 4, "baseline")
    for orders in per_bucket:
        assert all(o == ("model", "data", "pod") for o in orders)


SAMPLE_HLO = """
  %ag = bf16[16,512]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar.1 = f32[1024]{0} all-reduce(%x), replica_groups={{0,1},{2,3}}, to_apply=%add
  %rs = f32[256]{0} reduce-scatter(%y), replica_groups={{0,1,2,3}}, dimensions={0}
  %arst = (f32[8]{0}, f32[8]{0}) all-reduce-start(%z), replica_groups={}
"""


def test_collective_stats_parses_hlo():
    s = collective_stats(SAMPLE_HLO)
    assert s["op_counts"]["all-gather"] == 1
    assert s["op_counts"]["all-reduce"] == 2  # ar.1 + all-reduce-start
    assert s["bytes_by_kind"]["all-gather"] == 16 * 512 * 2
    assert s["bytes_by_kind"]["reduce-scatter"] == 256 * 4
    assert s["bytes_by_group_size"][4] == 16 * 512 * 2 + 256 * 4
    assert s["total_bytes"] > 0
