"""Tests for the SMT verification subsystem (``repro.verify``).

Covers the expression layer, the encode/validate cross-check (engine
witness vs declarative model), the prover verdicts over the default
instance suite, counterexample replay on both engines, and the pinned
solver-derived regression for the weighted-fair virtual-time staleness
bug that ``FabricArbiter(vt_clamp=True)`` fixes.
"""
import pytest

from repro.tenancy.arbiter import FabricArbiter
from repro.tenancy.tenants import TenantSpec
from repro.verify import (
    ALL_PROPERTIES,
    FabricInstance,
    FreeVar,
    decide_property,
    default_instances,
    encode_assignment,
    replay_counterexample,
    validate_encoding,
    verify_suite,
)
from repro.verify import smt
from repro.verify.encode import RequestTemplate
from repro.verify.properties import bounded_slowdown
from repro.verify.smt import Abs, And, Const, Implies, Max, Min, Not, Var

MB = 1e6
PROPS = {p.name: p for p in ALL_PROPERTIES}


# ---------------------------------------------------------------------------
# Expression layer
# ---------------------------------------------------------------------------
def test_smt_evaluate_arithmetic_and_logic():
    env = {"x": 3.0, "y": -2.0}
    e = (Var("x") * 2 + Var("y")) / 4
    assert smt.evaluate(e, env) == pytest.approx(1.0)
    assert smt.evaluate(Max(Var("x"), Var("y"), 5), env) == 5.0
    assert smt.evaluate(Min(Var("x"), Var("y")), env) == -2.0
    assert smt.evaluate(Abs(Var("y")), env) == 2.0
    assert smt.evaluate(And(Var("x") > 0, Not(Var("y") > 0)), env)
    assert smt.evaluate(Implies(Var("x") > 10, Var("y") > 0), env)
    assert not smt.evaluate((Var("x")).eq(Var("y")), env)
    assert smt.free_vars(e) == {"x", "y"}


# ---------------------------------------------------------------------------
# Encoding: the engine witness must satisfy the declarative model
# ---------------------------------------------------------------------------
def test_every_default_instance_encodes_and_validates():
    insts = default_instances()
    assert len(insts) >= 3
    n_assignments = 0
    for inst in insts:
        for assignment in inst.assignments(quick=True):
            enc = encode_assignment(inst, assignment)
            validate_encoding(enc)  # model-vs-engine cross-check
            n_assignments += 1
            assert enc.constraints and enc.env
            # every constraint variable is pinned by the witness
            for c in enc.constraints:
                assert smt.free_vars(c) <= set(enc.env)
    assert n_assignments >= 6


def test_encoding_is_engine_agnostic():
    inst = default_instances()[0]
    assignment = inst.assignments()[0]
    e_ref = encode_assignment(inst, assignment, engine="reference")
    e_idx = encode_assignment(inst, assignment, engine="indexed")
    assert e_ref.result.diff_fields(e_idx.result) == []
    assert e_ref.env == e_idx.env  # identical traces -> identical witness


# ---------------------------------------------------------------------------
# Prover verdicts over the default suite
# ---------------------------------------------------------------------------
def test_suite_decides_all_properties_with_expected_verdicts():
    rep = verify_suite(quick=True)
    assert rep["n_instances"] >= 3
    assert len(rep["properties_decided"]) >= 4
    verdicts = {(v["instance"], v["property"]): v for v in rep["verdicts"]}
    # conservation / ordering / progress theorems hold everywhere
    for (inst, prop), v in verdicts.items():
        if prop in ("work_conservation", "bytes_conservation",
                    "no_lost_chunks", "starvation_freedom"):
            assert v["status"] == "proved", (inst, prop)
    # the SFQ clamp is what makes weighted sharing hold across idle gaps
    assert verdicts[("wf-rearrival-clamped", "bounded_slowdown")][
        "status"] == "proved"
    stale = verdicts[("wf-rearrival-stale", "bounded_slowdown")]
    assert stale["status"] == "refuted" and stale["counterexamples"]
    # fifo ignores weights: the weighted-share claim is refutable
    fifo = verdicts[("fifo-mixed", "bounded_slowdown")]
    assert fifo["status"] == "refuted"
    # every refutation carried a successful dual-engine replay
    for v in rep["verdicts"]:
        if v["status"] == "refuted":
            assert v["replays"], (v["instance"], v["property"])
            for r in v["replays"]:
                assert r["engines_bit_identical"]
                assert r["violated_on"] == ["indexed", "reference"]
                assert r["requests"]


def test_replay_counterexample_rejects_non_violating_assignment():
    insts = {i.name: i for i in default_instances()}
    with pytest.raises(AssertionError, match="did not reproduce"):
        replay_counterexample(
            insts["wf-rearrival-clamped"], {"rearrive": 3e-4},
            PROPS["bounded_slowdown"])


# ---------------------------------------------------------------------------
# The pinned solver-derived regression: weighted-fair vt staleness.
#
# The instance below is the exact counterexample the prover extracted from
# ``wf-rearrival-stale`` (free variable rearrive = 6e-4): tenant ``a``
# goes idle after one small request while ``b`` stays backlogged; when
# ``a`` re-arrives, its stale (low) virtual clock lets it monopolize the
# contended dim until the clock catches up.  ``vt_clamp=True`` (the fix,
# and the FabricArbiter default) clamps the re-arriving clock up to the
# dim's SFQ floor, restoring weight-proportional sharing.  Pinned as a
# permanent regression test independent of the default instance suite.
# ---------------------------------------------------------------------------
def _staleness_instance(vt_clamp: bool) -> FabricInstance:
    reqs = [RequestTemplate("a", 1 * MB, 0.0)]
    reqs += [RequestTemplate("b", 4 * MB, i * 1e-6) for i in range(8)]
    reqs += [RequestTemplate("a", 4 * MB, ("rearrive", i * 1e-6))
             for i in range(4)]
    return FabricInstance(
        name=f"pinned-vt-staleness-{'fixed' if vt_clamp else 'bug'}",
        tenants=(TenantSpec("a", weight=1.0), TenantSpec("b", weight=1.0)),
        requests=tuple(reqs),
        policy="weighted-fair",
        quantum_chunks=2,
        preemption=True,
        vt_clamp=vt_clamp,
        chunks_per_collective=2,
        free=(FreeVar("rearrive", (6e-4,)),),
        slowdown_window_start="rearrive",
        contended_dim=0,
        slowdown_slack_quanta=2.0,
    )


def test_vt_staleness_counterexample_is_pinned():
    cex = {"rearrive": 6e-4}
    # without the clamp the property is violated, identically on BOTH
    # engines (replay_counterexample asserts bit-equivalence internally)
    replay = replay_counterexample(
        _staleness_instance(vt_clamp=False), cex, PROPS["bounded_slowdown"])
    assert replay["violated_on"] == ["indexed", "reference"]
    # with the clamp the same workload satisfies bounded slowdown
    for eng in ("reference", "indexed"):
        enc = encode_assignment(_staleness_instance(vt_clamp=True), cex,
                                engine=eng)
        validate_encoding(enc)
        assert smt.evaluate(bounded_slowdown(enc), enc.env)


def test_vt_clamp_hooks_and_snapshot():
    specs = [TenantSpec("a"), TenantSpec("b")]
    arb = FabricArbiter("weighted-fair", specs)
    assert arb.vt_clamp  # the fix is the default

    class _T:
        def __init__(self, tenant, wire):
            self.tenant, self.wire_bytes = tenant, wire
            self.fixed_delay, self.op_id = 0.0, (0, 0)

    arb.on_served(0, [_T("b", 10.0)], now=0.0)          # floor -> 0, vt_b=10
    arb.on_served(0, [_T("b", 10.0)], now=1.0)          # floor -> 10, vt_b=20
    arb.on_enqueued(0, "a", now=2.0)                    # a re-arrives stale
    assert arb.virtual_time(0, "a") == pytest.approx(arb.vt_floor(0))
    assert arb.vt_floor(0) == pytest.approx(10.0)
    snap = arb.served_snapshot()
    assert snap[(0, "b")] == pytest.approx(20.0)
    # clamp off: the stale clock is left behind the floor
    arb2 = FabricArbiter("weighted-fair", specs, vt_clamp=False)
    arb2.on_served(0, [_T("b", 10.0)], now=0.0)
    arb2.on_served(0, [_T("b", 10.0)], now=1.0)
    arb2.on_enqueued(0, "a", now=2.0)
    assert arb2.virtual_time(0, "a") == 0.0


# ---------------------------------------------------------------------------
# Optional z3 backend: must agree with the native witness decision
# ---------------------------------------------------------------------------
def test_z3_backend_agrees_with_native_when_installed():
    pytest.importorskip("z3")
    insts = {i.name: i for i in default_instances()}
    for name, prop, want in (
            ("wf-rearrival-clamped", "bounded_slowdown", "proved"),
            ("wf-rearrival-stale", "bounded_slowdown", "refuted"),
            ("sp-preempt", "starvation_freedom", "proved")):
        v_native = decide_property(insts[name], PROPS[prop], quick=True,
                                   backend="native", replay=False)
        v_z3 = decide_property(insts[name], PROPS[prop], quick=True,
                               backend="z3", replay=False)
        assert v_native.status == want
        assert v_z3.status == want
        assert "z3" in v_z3.backends
