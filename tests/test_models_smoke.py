"""Per-architecture smoke tests (reduced configs, CPU, 1 device).

For every assigned arch: one forward/train step asserting output shapes and
finiteness; prefill + decode consistency against the parallel forward.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeConfig, get_arch, list_archs
from repro.models import build_model

# Per-arch compiles dominate suite wall time; the fast tier-1 gate skips
# them (pytest -m 'not slow'); the full gate still runs everything.
pytestmark = pytest.mark.slow

ARCHS = list_archs()
SMOKE = ShapeConfig("smoke", 48, 2, "train")


def make_batch(api, shape, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    for k, s in api.batch_spec(shape).items():
        if s.dtype == jnp.int32:
            batch[k] = jnp.asarray(
                rng.integers(0, api.cfg.vocab_size, s.shape), jnp.int32)
        else:
            batch[k] = jnp.asarray(rng.standard_normal(s.shape), s.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_forward_and_grad(arch):
    cfg = get_arch(arch, reduced=True)
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    batch = make_batch(api, SMOKE)
    loss, grads = jax.jit(jax.value_and_grad(api.loss_fn))(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    assert 0.0 < float(loss) < 20.0
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_reduces_loss(arch):
    """A few SGD steps on one repeated batch must reduce the loss."""
    cfg = get_arch(arch, reduced=True)
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    batch = make_batch(api, SMOKE)
    vg = jax.jit(jax.value_and_grad(api.loss_fn))
    # recurrent cells are step-size sensitive; dense tolerates larger steps
    lr = 0.05 if cfg.family in ("ssm", "hybrid") else 0.5
    l0 = None
    for i in range(5):
        loss, grads = vg(params, batch)
        if l0 is None:
            l0 = float(loss)
        params = jax.tree.map(
            lambda p, g: p - lr * g.astype(p.dtype), params, grads)
    l1 = float(vg(params, batch)[0])
    assert l1 < l0, f"{arch}: loss did not decrease ({l0} -> {l1})"
    assert np.isfinite(l1)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_shapes_and_finiteness(arch):
    cfg = get_arch(arch, reduced=True)
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    shape = ShapeConfig("serve", 32, 2, "prefill")
    batch = make_batch(api, shape)
    logits, caches = jax.jit(api.prefill)(params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.isfinite(logits).all())
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    pos = jnp.asarray(
        shape.seq_len + (cfg.num_patches if cfg.family == "vlm" else 0),
        jnp.int32)
    logits2, caches2 = jax.jit(api.decode_step)(params, caches, tok, pos)
    assert logits2.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ["llama3-8b", "qwen2.5-3b", "granite-34b"])
def test_decode_matches_teacher_forcing(arch):
    """Dense families: prefill(t[:n]) then decode(t[n]) must reproduce the
    full-sequence forward logits at position n (KV-cache correctness)."""
    from repro.models import transformer as tr

    cfg = get_arch(arch, reduced=True).replace(remat=False)
    api = build_model(cfg)
    params = api.init(jax.random.key(1))
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 17)), jnp.int32)
    full = tr.forward(params, toks, cfg)
    _, caches = tr.prefill(params, toks[:, :-1], cfg, max_len=17)
    step_logits, _ = tr.decode_step(
        params, caches, toks[:, -1], jnp.asarray(16, jnp.int32), cfg)
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0]), np.asarray(full[:, -1]),
        atol=3e-2, rtol=3e-2)


def test_recurrent_decode_matches_teacher_forcing():
    from repro.models import recurrent as rec

    cfg = get_arch("recurrentgemma-2b", reduced=True).replace(remat=False)
    params = rec.init_lm(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 21)), jnp.int32)
    full, _ = rec.forward(params, toks, cfg)
    _, caches = rec.prefill(params, toks[:, :-1], cfg, 21)
    step_logits, _ = rec.decode_step(params, caches, toks[:, -1],
                                     jnp.asarray(20, jnp.int32), cfg)
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0]), np.asarray(full[:, -1]),
        atol=1e-1, rtol=0)  # bf16 scan-order noise; abs tolerance only


def test_xlstm_decode_matches_teacher_forcing():
    from repro.models import xlstm

    cfg = get_arch("xlstm-1.3b", reduced=True).replace(remat=False)
    params = xlstm.init_lm(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 21)), jnp.int32)
    full, _ = xlstm.forward(params, toks, cfg)
    _, caches = xlstm.prefill(params, toks[:, :-1], cfg, 21)
    step_logits, _ = xlstm.decode_step(params, caches, toks[:, -1],
                                       jnp.asarray(20, jnp.int32), cfg)
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0]), np.asarray(full[:, -1]),
        atol=1e-1, rtol=0)  # bf16 scan-order noise; abs tolerance only


def test_moe_capacity_drop_free_matches_dense():
    """With capacity_factor high enough that nothing drops, the MoE layer
    equals the dense weighted mixture of expert MLPs."""
    from repro.models import moe as moe_mod

    cfg = get_arch("deepseek-moe-16b", reduced=True).replace(
        capacity_factor=100.0, num_shared_experts=0)
    p = moe_mod.init_moe(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    got = moe_mod.apply_moe(p, x, cfg)

    logits = x @ p["router"]
    gates = jax.nn.softmax(logits, -1)
    w, ids = jax.lax.top_k(gates, cfg.experts_per_token)
    w = w / w.sum(-1, keepdims=True)
    want = jnp.zeros_like(x)
    for e in range(cfg.num_experts):
        up = x @ p["wi"][e]
        gate = jax.nn.silu(x @ p["wg"][e]) * up
        out_e = gate @ p["wo"][e]
        sel = (ids == e).astype(x.dtype) * w
        want = want + out_e * sel.sum(-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
