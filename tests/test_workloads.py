"""Fig. 12 workload models + iteration engine."""
import pytest

from repro.core.workloads import (
    ALL_WORKLOADS,
    _coalesce_buckets,
    iteration_time,
    make_transformer_1t,
    resnet152_param_buckets,
    split_topology,
)
from repro.topology import make_table2_topologies

TOPOS = make_table2_topologies()


def test_resnet_bucket_total_matches_params():
    total = sum(resnet152_param_buckets()) / 2  # fp16 bytes -> params
    assert 55e6 < total < 65e6  # ~60.2M params


def test_coalesce_buckets_skewed_sizes_keep_count_and_mass():
    """Regression: a huge leading tensor used to overshoot the fixed target
    and collapse the bucket count; trailing zero-mass buckets were dropped.
    The coalescer must stay mass-preserving with a stable bucket count."""
    skewed = [100.0] + [1.0] * 12
    out = _coalesce_buckets(skewed, 4)
    assert len(out) == 4
    assert sum(out) == pytest.approx(sum(skewed))
    # huge tensor at the *end* (the trailing-bucket variant)
    out = _coalesce_buckets(list(reversed(skewed)), 4)
    assert len(out) == 4
    assert sum(out) == pytest.approx(sum(skewed))
    # stable count and mass across bucket counts on the real layer profile
    sizes = resnet152_param_buckets()
    for n in (1, 2, 7, 16, len(sizes), len(sizes) + 5):
        out = _coalesce_buckets(sizes, n)
        assert len(out) == min(n, len(sizes))
        assert sum(out) == pytest.approx(sum(sizes), rel=1e-12)
    with pytest.raises(ValueError):
        _coalesce_buckets(sizes, 0)


def test_split_topology_boundary_inside_dim():
    mp, dp = split_topology(TOPOS["2D-SW_SW"], 128)
    assert mp.size_str() == "16x8"
    assert dp.size_str() == "8"
    mp, dp = split_topology(TOPOS["4D-Ring_SW_SW_SW"], 128)
    assert mp.total_npus == 128
    assert dp.total_npus == 8


def test_split_topology_inner_outer_split_shares_fabric():
    """When the MP boundary falls inside a dimension, the split dim's inner
    (MP) and outer (DP) logical sub-dimensions keep the physical dim's link
    BW, per-NPU link count, and step latency — same fabric, shared."""
    topo = TOPOS["2D-SW_SW"]  # 16 x 64
    mp, dp = split_topology(topo, 128)  # boundary inside the 64-way dim
    split_src = topo.dims[1]
    inner, outer = mp.dims[1], dp.dims[0]
    assert inner.npus * outer.npus == split_src.npus
    for sub in (inner, outer):
        assert sub.topo == split_src.topo
        assert sub.link_gbps == split_src.link_gbps
        assert sub.links_per_npu == split_src.links_per_npu
        assert sub.step_latency_s == split_src.step_latency_s


@pytest.mark.parametrize("tname", sorted(TOPOS))
def test_split_topology_preserves_npu_count(tname):
    """mp.total_npus * dp.total_npus == total for every boundary that
    divides the NPU count along dim order."""
    topo = TOPOS[tname]
    mp_sizes = {1}
    prod = 1
    for d in topo.dims:  # all prefix products and in-dim powers of two
        for inner in (2, 4, d.npus):
            if d.npus % inner == 0:
                mp_sizes.add(prod * inner)
        prod *= d.npus
    for mp_npus in sorted(mp_sizes):
        mp, dp = split_topology(topo, mp_npus)
        assert mp.total_npus * dp.total_npus == topo.total_npus, mp_npus
        assert mp.total_npus == mp_npus or mp_npus == 1


def test_split_topology_edges():
    """mp_npus=1 -> empty MP topology, DP is the full fabric; mp_npus=total
    -> MP is the full fabric, DP empty."""
    topo = TOPOS["3D-SW_SW_SW_homo"]
    mp, dp = split_topology(topo, 1)
    assert mp.num_dims == 0 and mp.total_npus == 1
    assert dp.dims == topo.dims
    mp, dp = split_topology(topo, topo.total_npus)
    assert mp.total_npus == topo.total_npus
    assert dp.num_dims == 0 and dp.total_npus == 1


def test_iteration_ordering_baseline_ge_themis_ge_ideal():
    w = ALL_WORKLOADS["resnet152"]()
    for topo in TOPOS.values():
        b = iteration_time(w, topo, "baseline", intra="FIFO").total_s
        t = iteration_time(w, topo, "themis", intra="SCF").total_s
        i = iteration_time(w, topo, "ideal").total_s
        assert b >= t * 0.999
        assert t >= i * 0.98


def test_transformer_1t_dp_single_dim():
    """Paper: T-1T's DP comm uses only the last network dim -> baseline and
    Themis produce identical DP exposure."""
    w = make_transformer_1t()
    topo = TOPOS["3D-SW_SW_SW_homo"]
    b = iteration_time(w, topo, "baseline", intra="FIFO")
    t = iteration_time(w, topo, "themis", intra="SCF")
    assert b.exposed_dp_s == pytest.approx(t.exposed_dp_s, rel=0.02)
    assert t.exposed_mp_s < b.exposed_mp_s  # Themis helps the MP part


def test_all_workloads_construct():
    for name, maker in ALL_WORKLOADS.items():
        w = maker()
        assert w.compute_s > 0
        assert w.comm_ops
