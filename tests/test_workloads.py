"""Fig. 12 workload models + iteration engine."""
import pytest

from repro.core.workloads import (
    ALL_WORKLOADS,
    iteration_time,
    make_transformer_1t,
    resnet152_param_buckets,
    split_topology,
)
from repro.topology import make_table2_topologies

TOPOS = make_table2_topologies()


def test_resnet_bucket_total_matches_params():
    total = sum(resnet152_param_buckets()) / 2  # fp16 bytes -> params
    assert 55e6 < total < 65e6  # ~60.2M params


def test_split_topology_boundary_inside_dim():
    mp, dp = split_topology(TOPOS["2D-SW_SW"], 128)
    assert mp.size_str() == "16x8"
    assert dp.size_str() == "8"
    mp, dp = split_topology(TOPOS["4D-Ring_SW_SW_SW"], 128)
    assert mp.total_npus == 128
    assert dp.total_npus == 8


def test_iteration_ordering_baseline_ge_themis_ge_ideal():
    w = ALL_WORKLOADS["resnet152"]()
    for topo in TOPOS.values():
        b = iteration_time(w, topo, "baseline", intra="FIFO").total_s
        t = iteration_time(w, topo, "themis", intra="SCF").total_s
        i = iteration_time(w, topo, "ideal").total_s
        assert b >= t * 0.999
        assert t >= i * 0.98


def test_transformer_1t_dp_single_dim():
    """Paper: T-1T's DP comm uses only the last network dim -> baseline and
    Themis produce identical DP exposure."""
    w = make_transformer_1t()
    topo = TOPOS["3D-SW_SW_SW_homo"]
    b = iteration_time(w, topo, "baseline", intra="FIFO")
    t = iteration_time(w, topo, "themis", intra="SCF")
    assert b.exposed_dp_s == pytest.approx(t.exposed_dp_s, rel=0.02)
    assert t.exposed_mp_s < b.exposed_mp_s  # Themis helps the MP part


def test_all_workloads_construct():
    for name, maker in ALL_WORKLOADS.items():
        w = maker()
        assert w.compute_s > 0
        assert w.comm_ops
