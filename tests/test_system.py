"""End-to-end system behaviour: the paper's headline numbers reproduce."""
import statistics

import pytest

from repro.core.simulator import simulate_scheduled
from repro.topology import make_current_topology, make_table2_topologies

TOPOS = make_table2_topologies()
MB = 1e6
SIZES = [100 * MB, 500 * MB, 1000 * MB]


@pytest.fixture(scope="module")
def sweep():
    rows = []
    for topo in TOPOS.values():
        for s in SIZES:
            rb, _ = simulate_scheduled(topo, "AR", s, policy="baseline",
                                       intra="FIFO")
            rt, _ = simulate_scheduled(topo, "AR", s, policy="themis",
                                       intra="SCF")
            rows.append((topo, rb, rt))
    return rows


def test_paper_claim_ar_speedup(sweep):
    """Paper: Themis+SCF improves single-AR time by 1.72x avg (2.70x max)."""
    sp = [rb.makespan / rt.makespan for _, rb, rt in sweep]
    assert 1.5 < statistics.mean(sp) < 2.0
    assert 2.4 < max(sp) < 3.1


def test_paper_claim_bw_utilization(sweep):
    """Paper: 56.31% baseline vs 95.14% Themis+SCF average BW utilization."""
    ub = statistics.mean(rb.avg_bw_utilization(t) for t, rb, _ in sweep)
    ut = statistics.mean(rt.avg_bw_utilization(t) for t, _, rt in sweep)
    assert 0.50 < ub < 0.65
    assert ut > 0.90


def test_paper_claim_current_system_efficient():
    """Paper Sec. 3: today's 2D system reaches ~97.7% util with baseline
    scheduling (huge dim1/dim2 BW gap) — Themis is a next-gen problem."""
    cur = make_current_topology()
    rb, _ = simulate_scheduled(cur, "AR", 500 * MB, policy="baseline",
                               intra="FIFO")
    assert rb.avg_bw_utilization(cur) > 0.95
