"""Wrapper: run the 8-virtual-device checks in a subprocess (XLA device
count must be set before jax import, so they cannot run in-process)."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
REPO = os.path.dirname(HERE)


@pytest.mark.slow
@pytest.mark.timeout(900)
def test_multidevice_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "multidevice_checks.py")],
        env=env, capture_output=True, text=True, timeout=880,
    )
    sys.stdout.write(proc.stdout[-4000:])
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "multidevice checks failed"
    assert "ALL MULTIDEVICE CHECKS PASSED" in proc.stdout
