"""Hypothesis property tests on system invariants.

Falls back to the deterministic sweep shim when hypothesis is missing
(see requirements-dev.txt / tests/_hypothesis_shim.py).
"""
import math

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.latency_model import LatencyModel
from repro.core.scheduler import schedule_collective
from repro.core.simulator import simulate_scheduled
from repro.topology import Phase
from repro.topology.topology import NetworkDim, Topology, TopoKind

KINDS = [TopoKind.RING, TopoKind.FULLY_CONNECTED, TopoKind.SWITCH]


@st.composite
def topologies(draw):
    n_dims = draw(st.integers(2, 4))
    dims = []
    for _ in range(n_dims):
        p = draw(st.sampled_from([2, 4, 8, 16]))
        kind = draw(st.sampled_from(KINDS))
        gbps = draw(st.sampled_from([50, 100, 200, 400, 800, 1600]))
        links = draw(st.integers(1, 8))
        lat = draw(st.sampled_from([0.0, 1e-7, 1e-6]))
        dims.append(NetworkDim(p, kind, gbps, links, lat))
    return Topology("rand", tuple(dims))


@given(topologies(), st.sampled_from(["baseline", "themis", "themis_indep_ag",
                                      "lookahead"]),
       st.integers(1, 64), st.floats(1e6, 1e9))
@settings(max_examples=40, deadline=None)
def test_schedules_are_valid_permutations(topo, policy, cpc, size):
    chunks = schedule_collective(topo, "AR", size, cpc, policy)
    assert len(chunks) == cpc
    d = topo.num_dims
    for c in chunks:
        phases = [p for p, _ in c.schedule]
        assert phases == [Phase.RS] * d + [Phase.AG] * d  # RS before AG
        rs = [k for p, k in c.schedule if p == Phase.RS]
        ag = [k for p, k in c.schedule if p == Phase.AG]
        assert sorted(rs) == list(range(d))               # permutation
        assert sorted(ag) == list(range(d))
    assert sum(c.size_bytes for c in chunks) == abs(size) or math.isclose(
        sum(c.size_bytes for c in chunks), size, rel_tol=1e-9)


@given(topologies(), st.floats(1e7, 1e9))
@settings(max_examples=25, deadline=None)
def test_total_wire_invariant_across_policies(topo, size):
    """Total bytes on the wire are schedule-invariant (only placement of
    load across dims changes)."""
    lm = LatencyModel(topo)
    want = lm.total_wire_bytes("AR", size)
    for policy in ("baseline", "themis"):
        res, _ = simulate_scheduled(topo, "AR", size, policy=policy,
                                    chunks_per_collective=16)
        assert math.isclose(sum(res.dim_wire_bytes), want, rel_tol=1e-9)


@given(topologies(), st.floats(5e7, 1e9))
@settings(max_examples=25, deadline=None)
def test_themis_not_worse_than_baseline(topo, size):
    """Themis+SCF should never lose to baseline by more than the chunk
    quantum slack (it degenerates to baseline via the threshold guard)."""
    rb, _ = simulate_scheduled(topo, "AR", size, policy="baseline",
                               intra="FIFO", chunks_per_collective=64)
    rt, _ = simulate_scheduled(topo, "AR", size, policy="themis",
                               intra="SCF", chunks_per_collective=64)
    assert rt.makespan <= rb.makespan * 1.10


@given(topologies(), st.floats(1e7, 1e9),
       st.sampled_from(["baseline", "themis"]))
@settings(max_examples=25, deadline=None)
def test_makespan_bounds(topo, size, policy):
    """ideal <= makespan; utilization in (0, 1]."""
    lm = LatencyModel(topo)
    res, _ = simulate_scheduled(topo, "AR", size, policy=policy)
    assert res.makespan >= lm.ideal_time("AR", size) * 0.999
    u = res.avg_bw_utilization(topo)
    assert 0.0 < u <= 1.0 + 1e-9


@given(topologies(), st.integers(2, 32))
@settings(max_examples=20, deadline=None)
def test_water_filling_preserves_total_mass(topo, cpc):
    size = 3e8
    chunks = schedule_collective(topo, "AR", size, cpc, "themis",
                                 water_filling=True)
    assert math.isclose(sum(c.size_bytes for c in chunks), size, rel_tol=1e-6)
    assert len(chunks) <= cpc
