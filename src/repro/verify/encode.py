"""Encode small fabric instances as constraints over service variables.

A :class:`FabricInstance` is a tiny multi-tenant workload (2-3 tenants,
2 dims, a few chunks per collective) with one arbiter discipline and a
grid of *free variables* (re-arrival times, sizes, preemption penalties).
For each assignment of the free variables, :func:`encode_assignment`
produces an :class:`Encoding`: a constraint system over named real
variables that mirrors the engines' semantics —

  * ``S_d_k`` / ``F_d_k`` — start/finish of the k-th service on dim d,
    linked by the rate equation ``F == S + bytes/bw`` (preemption-shrunk
    services keep only the bytes that drained), per-dim non-overlap
    ``F_k <= S_{k+1}``, and chunk-chain readiness ``S >= F_prev + A``
    (a stage readies only after its predecessor's service drains plus the
    fixed latency; chunks cut by a preemption with ``preempt_penalty_s``
    re-ready only after the re-arm penalty);
  * ``C_g`` — completion of request g, the max over its chunks' final
    stage done-times;
  * ``VT_d_T_i`` / ``FL_d_j`` — the weighted-fair virtual-time chains and
    per-dim SFQ floor, advanced exactly as ``FabricArbiter`` advances
    them (service increments, preemption refunds, and — when ``vt_clamp``
    is on — the arrival clamp ``VT' == max(VT, FL)``), plus the
    discipline's order condition: at each fair service start the served
    tenant's virtual time is <= every other pending tenant's.

The *witness* for the system is the real engine's trace: the instance is
run through ``simulate_requests`` (with ``check_invariants=True``, so the
runtime sanitizer is armed during witness generation) under a
:class:`TraceRecorder` arbiter that logs every hook call.  The witness
values of all variables come from that trace; :func:`validate_encoding`
asserts the witness satisfies every constraint — this is the
model-vs-engine cross-check.  Because every variable is pinned by an
equality chain rooted in instance constants (the system is functionally
determined), a property can then be decided by witness evaluation alone;
with z3 installed the harness instead proves ``constraints => property``
in linear real arithmetic (see :mod:`repro.verify.smt`).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

from repro.core.latency_model import LatencyModel
from repro.core.requests import CollectiveRequest
from repro.core.simulator import SimResult, build_task_arrays, simulate_requests
from repro.tenancy.arbiter import FabricArbiter
from repro.tenancy.tenants import TenantSpec
from repro.topology.algorithms import TopoKind
from repro.topology.topology import NetworkDim, Topology
from repro.verify import smt
from repro.verify.smt import Const, Max, Min, Sum, Var

_EPS = 1e-12


def small_topology(name: str = "verify-2d", npus: tuple[int, int] = (4, 4),
                   gbps: tuple[float, float] = (200.0, 100.0)) -> Topology:
    """A tiny 2-dim switch topology for verification instances."""
    return Topology(name, (
        NetworkDim(npus[0], TopoKind.SWITCH, gbps[0], 1, 700e-9),
        NetworkDim(npus[1], TopoKind.SWITCH, gbps[1], 1, 1700e-9),
    ))


@dataclass(frozen=True)
class FreeVar:
    """One free variable of an instance: a name plus its finite domain."""

    name: str
    values: tuple[float, ...]


@dataclass(frozen=True)
class RequestTemplate:
    """A request whose size/issue time may be a constant, a free-variable
    name, or an offset ``(name, delta)`` from a free variable."""

    tenant: str
    size_bytes: float | str | tuple = 4e6
    issue_time: float | str | tuple = 0.0
    stream: str = ""
    priority: int = 0


def _resolve(v, assignment: dict) -> float:
    if isinstance(v, str):
        return assignment[v]
    if isinstance(v, tuple):
        name, delta = v
        return assignment[name] + delta
    return float(v)


@dataclass(frozen=True)
class FabricInstance:
    """One small verification instance (see module docstring)."""

    name: str
    tenants: tuple[TenantSpec, ...]
    requests: tuple[RequestTemplate, ...]
    policy: str = "weighted-fair"
    quantum_chunks: int = 2
    preemption: bool = True
    preempt_penalty_s: float | str = 0.0
    vt_clamp: bool = True
    chunks_per_collective: int = 2
    free: tuple[FreeVar, ...] = ()
    topology: Topology = field(default_factory=small_topology)
    # Fairness-window start for the bounded-slowdown property: a free-var
    # name (e.g. the re-arrival instant) or a constant; None starts at the
    # latest first-arrival among the audited tenant pair.
    slowdown_window_start: float | str | None = None
    # Contended dim the slowdown property audits (innermost by default).
    contended_dim: int = 0
    # Fairness slack multiplier (units of one quantum of max-size chunks
    # per unit weight); see properties.bounded_slowdown.
    slowdown_slack_quanta: float = 3.0
    notes: str = ""

    def assignments(self, quick: bool = False) -> list[dict]:
        """Every free-variable assignment on the grid (``quick`` keeps at
        most 4 by striding; grid corners are retained)."""
        if not self.free:
            return [{}]
        grids = [fv.values for fv in self.free]
        out = [dict(zip((fv.name for fv in self.free), combo))
               for combo in itertools.product(*grids)]
        if quick and len(out) > 4:
            stride = (len(out) - 1) / 3.0
            keep = sorted({round(i * stride) for i in range(4)})
            out = [out[i] for i in keep]
        return out

    def build_requests(self, assignment: dict) -> list[CollectiveRequest]:
        reqs = [CollectiveRequest(
            collective="AR",
            size_bytes=_resolve(t.size_bytes, assignment),
            issue_time=_resolve(t.issue_time, assignment),
            priority=t.priority,
            tenant=t.tenant,
            stream=t.stream or t.tenant,
        ) for t in self.requests]
        # simulate_requests schedules in list order; keep issue order so a
        # request's index is stable across assignments.
        reqs.sort(key=lambda r: (r.issue_time, r.tenant))
        return reqs

    def build_arbiter(self, assignment: dict,
                      recorder: bool = True) -> FabricArbiter:
        cls = TraceRecorder if recorder else FabricArbiter
        return cls(
            self.policy, self.tenants,
            preemption=self.preemption,
            quantum_chunks=self.quantum_chunks,
            preempt_penalty_s=_resolve(self.preempt_penalty_s, assignment),
            vt_clamp=self.vt_clamp,
        )

    def weight(self, tenant: str) -> float:
        for s in self.tenants:
            if s.name == tenant:
                return max(s.weight, 1e-12)
        return 1.0

    def priority(self, tenant: str) -> int:
        for s in self.tenants:
            if s.name == tenant:
                return s.priority
        return 0


class TraceRecorder(FabricArbiter):
    """A ``FabricArbiter`` that logs every simulator hook call.

    ``order_key`` is untouched, so the indexed engine still bucket-indexes
    this arbiter — recording is identical on both engines.  Events (in
    engine call order, which is deterministic):

      * ``("enq", dim, tenant, t, vt_after)``
      * ``("serve", dim, t, tenant, ops, bytes, fixed, vt_before, incs)``
      * ``("preempt", dim, t, tenant, cut_ops, refund)``
    """

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.events: list[tuple] = []
        self._serving: dict[int, str] = {}

    def on_enqueued(self, dim, tenant, now):
        super().on_enqueued(dim, tenant, now)
        self.events.append(
            ("enq", dim, tenant, now, self.virtual_time(dim, tenant)))

    def on_served(self, dim, batch, now):
        vt_before = self.virtual_time(dim, batch[0].tenant)
        super().on_served(dim, batch, now)
        self._serving[dim] = batch[0].tenant
        self.events.append((
            "serve", dim, now, batch[0].tenant,
            tuple(t.op_id for t in batch),
            tuple(t.wire_bytes for t in batch),
            tuple(t.fixed_delay for t in batch),
            vt_before, dict(self._inflight_inc.get(dim, {}))))

    def on_preempted(self, dim, cut, now):
        incs = self._inflight_inc.get(dim, {})
        refund = sum(incs.get(t.op_id, 0.0) for t in cut)
        super().on_preempted(dim, cut, now)
        self.events.append(("preempt", dim, now, self._serving.get(dim),
                            tuple(t.op_id for t in cut), refund))


@dataclass
class SvcRec:
    """One (possibly preemption-shrunk) service in the witness trace."""

    dim: int
    k: int                     # index within the dim's service sequence
    tenant: str
    ops: list                  # kept op ids, in batch order
    op_bytes: dict             # op id -> wire bytes
    op_fixed: dict             # op id -> fixed delay
    start: float
    end: float
    cuts: list                 # [(t_preempt, cut op ids)], chronological

    @property
    def a(self) -> float:      # done-event latency = max fixed over kept
        return max(self.op_fixed[o] for o in self.ops)

    @property
    def bytes(self) -> float:
        return sum(self.op_bytes[o] for o in self.ops)

    def svar(self) -> Var:
        return Var(f"S_{self.dim}_{self.k}")

    def fvar(self) -> Var:
        return Var(f"F_{self.dim}_{self.k}")


@dataclass
class Encoding:
    """The constraint system + witness for one (instance, assignment)."""

    instance: FabricInstance
    assignment: dict
    engine: str
    requests: list
    result: SimResult
    env: dict                       # witness: var name -> value
    constraints: list               # list[smt.Expr]
    services: list                  # per dim: list[SvcRec]
    op_service: dict                # op id -> SvcRec finally serving it
    op_ready: dict                  # op id -> ground ready time (latest)
    op_count: dict                  # op id -> times served across the run
    expected_ops: dict              # op id -> (dim, wire) for EVERY task of
    #                                 the scheduled groups (served or not —
    #                                 how a lost chunk becomes visible)
    expected_wire: list             # per-dim sum over expected_ops
    total_wire: list                # per-dim sum of kept task wire bytes
    bw: list                        # per-dim bytes/s
    penalty: float
    makespan: float

    def cvar(self, g: int) -> Var:
        return Var(f"C_{g}")

    def tenant_window_bytes(self, tenant: str, dim: int,
                            w0: float, w1: float) -> smt.Expr:
        """Bytes served to ``tenant`` on ``dim`` inside [w0, w1], as a
        symbolic sum of per-service window overlap * bw (a service
        straddling a window edge counts partially — the engines drain a
        batch at a constant rate)."""
        terms = []
        for svc in self.services[dim]:
            if svc.tenant != tenant or svc.end <= w0 or svc.start >= w1:
                continue
            overlap = (Min(svc.fvar(), Const(w1))
                       - Max(svc.svar(), Const(w0)))
            terms.append(Max(Const(0.0), overlap) * Const(self.bw[dim]))
        return Sum(terms)

    def tenant_span(self, tenant: str, dim: int) -> tuple[float, float]:
        """Ground [first ready, last finish] of the tenant's ops on dim."""
        lo, hi = float("inf"), 0.0
        for svc in self.services[dim]:
            if svc.tenant != tenant:
                continue
            for op in svc.ops:
                lo = min(lo, self.op_ready[op])
            hi = max(hi, svc.end)
        return lo, hi


class EncodingError(AssertionError):
    """The engine trace and the declarative model disagree — either an
    engine bug or an encoder bug; both must fail loudly."""


def encode_assignment(inst: FabricInstance, assignment: dict,
                      engine: str = "reference") -> Encoding:
    """Run the instance under a recording arbiter and build the
    constraint system (see module docstring)."""
    requests = inst.build_requests(assignment)
    arb = inst.build_arbiter(assignment, recorder=True)
    res, groups = simulate_requests(
        inst.topology, requests,
        policy="baseline",
        chunks_per_collective=inst.chunks_per_collective,
        arbiter=arb, engine=engine, check_invariants=True)

    num_dims = inst.topology.num_dims
    bw = [d.aggr_bw_bytes for d in inst.topology.dims]
    penalty = _resolve(inst.preempt_penalty_s, assignment)

    # ---- reconstruct per-dim services from the recorder ---------------------
    services: list[list[SvcRec]] = [[] for _ in range(num_dims)]
    op_count: dict = {}
    for ev in arb.events:
        if ev[0] == "serve":
            _, dim, t, tenant, ops, byts, fixeds, _vtb, _incs = ev
            services[dim].append(SvcRec(
                dim=dim, k=len(services[dim]), tenant=tenant,
                ops=list(ops), op_bytes=dict(zip(ops, byts)),
                op_fixed=dict(zip(ops, fixeds)),
                start=t, end=0.0, cuts=[]))
        elif ev[0] == "preempt":
            _, dim, t, _tenant, cut, _refund = ev
            svc = services[dim][-1]
            cut_set = set(cut)
            svc.ops = [o for o in svc.ops if o not in cut_set]
            svc.cuts.append((t, cut))
    for dim in range(num_dims):
        if len(services[dim]) != len(res.dim_services[dim]):
            raise EncodingError(
                f"{inst.name}: recorder saw {len(services[dim])} services "
                f"on dim {dim}, engine reports "
                f"{len(res.dim_services[dim])}")
        for svc, (s, e, _g) in zip(services[dim], res.dim_services[dim]):
            if abs(svc.start - s) > _EPS:
                raise EncodingError(
                    f"{inst.name}: service start mismatch on dim {dim}: "
                    f"recorder {svc.start!r} vs engine {s!r}")
            svc.end = e

    op_service: dict = {}
    total_wire = [0.0] * num_dims
    rearm: dict = {}
    for per_dim in services:
        for svc in per_dim:
            for t_cut, cut in svc.cuts:
                for op in cut:
                    rearm[op] = t_cut
            for op in svc.ops:
                op_service[op] = svc
                op_count[op] = op_count.get(op, 0) + 1
                total_wire[svc.dim] += svc.op_bytes[op]

    # Chunk chains and chunk -> group mapping (mirror of the engines'
    # global chunk-id offset scheme over the scheduled groups).
    chain_ops: dict[int, dict[int, tuple]] = {}
    for op in op_service:
        chain_ops.setdefault(op[0], {})[op[1]] = op
    chunk_group: dict[int, int] = {}
    offset = 0
    for g, group in enumerate(groups):
        for c in group:
            chunk_group[c.index + offset] = g
        if group:
            offset += max(c.index for c in group) + 1

    # The EXPECTED task set, built independently of the trace through the
    # engines' own SoA builder — a chunk stage the trace never served shows
    # up here and nowhere else (that is what "lost" means).
    ta = build_task_arrays(
        LatencyModel.for_topology(inst.topology), groups,
        [r.priority for r in requests], [r.tenant for r in requests])
    expected_ops: dict = {}
    expected_wire = [0.0] * num_dims
    for h in range(ta.n_tasks):
        expected_ops[(ta.chunk[h], ta.stage[h])] = (ta.dim[h], ta.wire[h])
        expected_wire[ta.dim[h]] += ta.wire[h]

    env: dict[str, float] = {}
    constraints: list = []

    # ---- service arithmetic -------------------------------------------------
    for dim in range(num_dims):
        for svc in services[dim]:
            env[svc.svar().name] = svc.start
            env[svc.fvar().name] = svc.end
            # Rate equation: only the kept bytes drain (no jitter in
            # verification instances, so rate == dim bandwidth).
            constraints.append(
                svc.fvar().eq(svc.svar() + Const(svc.bytes / bw[dim])))
        # Per-dim services never overlap and are start-ordered.
        for a, b in zip(services[dim], services[dim][1:]):
            constraints.append(a.fvar() <= b.svar())

    # ---- readiness chains ---------------------------------------------------
    op_ready: dict = {}

    def ready_of(op) -> tuple[smt.Expr, float]:
        cid, s = op
        if s == 0:
            t0 = res.group_issue[chunk_group[cid]]
            base: smt.Expr = Const(t0)
            ground = t0
        else:
            prev = chain_ops[cid][s - 1]
            psvc = op_service[prev]
            base = psvc.fvar() + Const(psvc.a)
            ground = psvc.end + psvc.a
        if op in rearm and penalty > 0:
            base = Max(base, Const(rearm[op] + penalty))
            ground = max(ground, rearm[op] + penalty)
        return base, ground

    for op, svc in op_service.items():
        expr, ground = ready_of(op)
        op_ready[op] = ground
        constraints.append(expr <= svc.svar())

    # ---- completion times ---------------------------------------------------
    for g in range(len(groups)):
        terms = []
        for cid, stages in chain_ops.items():
            if chunk_group[cid] != g:
                continue
            last = stages[max(stages)]
            lsvc = op_service[last]
            terms.append(lsvc.fvar() + Const(lsvc.a))
        if terms:
            env[f"C_{g}"] = res.group_finish[g]
            constraints.append(Var(f"C_{g}").eq(Max(*terms)))

    # Exact queue occupancy per (dim, tenant), replayed from the recorder's
    # event stream: an "enq" adds one task (preemption-cut chunks re-enqueue
    # and log again), a "serve" removes its batch.  Time-based pendingness
    # would be wrong — an op readied at the same timestamp as a serve sits
    # behind it in the event heap and was NOT a candidate at that decision.
    qcount: dict[tuple[int, str], int] = {}

    def _replay_queue(ev) -> None:
        if ev[0] == "enq":
            qcount[(ev[1], ev[2])] = qcount.get((ev[1], ev[2]), 0) + 1
        elif ev[0] == "serve":
            qcount[(ev[1], ev[3])] = qcount.get((ev[1], ev[3]), 0) - len(ev[4])

    # ---- virtual-time chains (fair policies), one interleaved pass ----------
    if inst.policy in ("weighted-fair", "slo-aware"):
        vt_idx: dict[tuple, int] = {}
        fl_idx: dict[int, int] = {}
        tenant_names = [s.name for s in inst.tenants]

        def vt_var(dim, tn) -> Var:
            return Var(f"VT_{dim}_{tn}_{vt_idx.get((dim, tn), 0)}")

        def vt_advance(dim, tn, value) -> Var:
            vt_idx[(dim, tn)] = vt_idx.get((dim, tn), 0) + 1
            v = vt_var(dim, tn)
            env[v.name] = value
            return v

        for d in range(num_dims):
            for tn in tenant_names:
                env[f"VT_{d}_{tn}_0"] = 0.0
                constraints.append(Var(f"VT_{d}_{tn}_0").eq(0.0))

        for ev in arb.events:
            if ev[0] == "enq":
                _, dim, tn, t, vt_after = ev
                if inst.vt_clamp and fl_idx.get(dim) is not None:
                    old = vt_var(dim, tn)
                    new = vt_advance(dim, tn, vt_after)
                    constraints.append(
                        new.eq(Max(old, Var(f"FL_{dim}_{fl_idx[dim]}"))))
            elif ev[0] == "serve":
                _, dim, t, tn, ops, byts, fixeds, vt_before, incs = ev
                cur = vt_var(dim, tn)
                # Discipline order condition: the served tenant's clock is
                # minimal among tenants with queued work at the decision.
                for other in tenant_names:
                    if other != tn and qcount.get((dim, other), 0) > 0:
                        constraints.append(cur <= vt_var(dim, other))
                # SFQ floor advances to this service's start tag.
                j = fl_idx[dim] = fl_idx.get(dim, -1) + 1
                flv = Var(f"FL_{dim}_{j}")
                env[flv.name] = vt_before
                constraints.append(flv.eq(cur))
                inc = sum(incs.values())
                new = vt_advance(dim, tn, vt_before + inc)
                constraints.append(new.eq(cur + Const(inc)))
            else:  # preempt: refund the cut chunks' virtual time
                _, dim, t, tn, cut, refund = ev
                cur = vt_var(dim, tn)
                new = vt_advance(dim, tn, env[cur.name] - refund)
                constraints.append(new.eq(cur - Const(refund)))
            _replay_queue(ev)
    elif inst.policy == "strict-priority":
        # Order condition: a served tenant's priority dominates every
        # queued tenant's at the decision instant (ground comparison —
        # priorities are instance constants).
        for ev in arb.events:
            if ev[0] == "serve":
                _, dim, t, tn, *_rest = ev
                for other in (s.name for s in inst.tenants):
                    if other == tn:
                        continue
                    if qcount.get((dim, other), 0) > 0:
                        constraints.append(
                            Const(inst.priority(other))
                            <= Const(inst.priority(tn)))
            _replay_queue(ev)

    return Encoding(
        instance=inst, assignment=dict(assignment), engine=engine,
        requests=requests, result=res, env=env, constraints=constraints,
        services=services, op_service=op_service, op_ready=op_ready,
        op_count=op_count, expected_ops=expected_ops,
        expected_wire=expected_wire, total_wire=total_wire, bw=bw,
        penalty=penalty, makespan=res.makespan)


def validate_encoding(enc: Encoding, tol: float = 1e-6) -> None:
    """Assert the engine witness satisfies every constraint.

    Comparisons get ``tol`` slack — the witness floats carry the engines'
    own accumulation order.  A failure means the declarative model and
    the implementation disagree, which is exactly the divergence this
    subsystem exists to catch.
    """
    for c in enc.constraints:
        if not _holds(c, enc.env, tol):
            raise EncodingError(
                f"{enc.instance.name} {enc.assignment}: engine witness "
                f"violates model constraint {c!r}")


def _holds(c, env, tol: float) -> bool:
    if isinstance(c, smt.Cmp):
        a = smt.evaluate(c.a, env)
        b = smt.evaluate(c.b, env)
        if c.op == "==":
            return abs(a - b) <= tol + 1e-9 * max(abs(a), abs(b))
        if c.op == "<=":
            return a <= b + tol
        return a < b + tol
    if isinstance(c, smt.NaryBool) and c.op == "and":
        return all(_holds(x, env, tol) for x in c.args)
    return bool(smt.evaluate(c, env))
