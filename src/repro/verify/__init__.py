"""Formal verification of fabric-arbiter scheduling (ROADMAP: SMT item).

Layout:

  * :mod:`repro.verify.smt` — a small typed expression AST with two
    backends: a pure-Python evaluator (always available) and an optional
    z3 lowering (used when ``z3-solver`` is installed, e.g. in CI).
  * :mod:`repro.verify.encode` — encodes a small fabric instance (2-3
    tenants, 2 dims, a few chunks, one arbiter discipline) as constraints
    over service start/finish/virtual-time variables mirroring the
    engines' semantics, with the real engine's trace as the witness.
  * :mod:`repro.verify.properties` — the theorems: starvation-freedom,
    bounded slowdown, bytes-conservation, no-lost-chunks, and
    work-conservation.
  * :mod:`repro.verify.harness` — proves/refutes each property per
    instance over the instance's free-variable grid, extracts
    counterexamples as concrete :class:`CollectiveRequest` streams, and
    replays them through ``simulate_requests`` on both engines.
"""
from repro.verify.encode import (  # noqa: F401
    FabricInstance,
    Encoding,
    FreeVar,
    TraceRecorder,
    encode_assignment,
    validate_encoding,
)
from repro.verify.harness import (  # noqa: F401
    PropertyVerdict,
    decide_property,
    default_instances,
    replay_counterexample,
    verify_suite,
)
from repro.verify.properties import ALL_PROPERTIES, Property  # noqa: F401
from repro.verify.smt import Expr, Var, solve_encoding, z3_available  # noqa: F401
