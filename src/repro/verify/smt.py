"""Tiny SMT expression layer with a native evaluator and a z3 lowering.

The verify subsystem states engine semantics and fairness theorems as
expressions over named real variables (service starts/finishes, virtual
times, completion times).  Two decision backends share the one AST:

  * ``evaluate(expr, env)`` — ground evaluation under a witness
    environment.  The constraint systems :mod:`repro.verify.encode` emits
    are *functionally determined* (every variable is pinned by an
    equality chain rooted in instance constants), so checking the witness
    is a complete decision procedure for them — provided the witness
    satisfies every constraint, which :func:`validate_encoding` asserts.
    This backend is always available; the container need not ship z3.
  * ``to_z3(expr)`` — compositional lowering to z3 reals, used when
    ``z3-solver`` is importable (CI installs it via requirements-dev).
    There the solver proves ``constraints => property`` outright instead
    of trusting functional determinism: ``solve_encoding`` asserts the
    constraint conjunction plus the property's negation and reads
    UNSAT as "proved".

Only the operations the encoder needs exist: +, -, *, /, comparisons,
And/Or/Not/Implies, Max/Min/Abs, and boolean/real constants.  Floats are
compared exactly in ``==`` expressions on purpose — the encoder only
emits equalities between values produced by the *same* float computation
(see the tolerance notes in :mod:`repro.core.invariants` for why looser
comparisons would hide accounting bugs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping


class Expr:
    """Base expression node.  Operator overloads build the tree."""

    def __add__(self, other): return BinOp("+", self, wrap(other))
    def __radd__(self, other): return BinOp("+", wrap(other), self)
    def __sub__(self, other): return BinOp("-", self, wrap(other))
    def __rsub__(self, other): return BinOp("-", wrap(other), self)
    def __mul__(self, other): return BinOp("*", self, wrap(other))
    def __rmul__(self, other): return BinOp("*", wrap(other), self)
    def __truediv__(self, other): return BinOp("/", self, wrap(other))
    def __le__(self, other): return Cmp("<=", self, wrap(other))
    def __lt__(self, other): return Cmp("<", self, wrap(other))
    def __ge__(self, other): return Cmp("<=", wrap(other), self)
    def __gt__(self, other): return Cmp("<", wrap(other), self)

    def eq(self, other) -> "Expr":
        return Cmp("==", self, wrap(other))


@dataclass(frozen=True)
class Const(Expr):
    value: float

    def __repr__(self):
        return f"{self.value:g}"


@dataclass(frozen=True)
class BoolConst(Expr):
    value: bool

    def __repr__(self):
        return str(self.value)


@dataclass(frozen=True)
class Var(Expr):
    """A named real variable (e.g. ``S[0][3]``, a service start)."""

    name: str

    def __repr__(self):
        return self.name


@dataclass(frozen=True)
class BinOp(Expr):
    op: str
    a: Expr
    b: Expr

    def __repr__(self):
        return f"({self.a!r} {self.op} {self.b!r})"


@dataclass(frozen=True)
class Cmp(Expr):
    op: str
    a: Expr
    b: Expr

    def __repr__(self):
        return f"({self.a!r} {self.op} {self.b!r})"


@dataclass(frozen=True)
class NaryBool(Expr):
    op: str  # "and" | "or"
    args: tuple

    def __repr__(self):
        sep = f" {self.op} "
        return "(" + sep.join(repr(a) for a in self.args) + ")"


@dataclass(frozen=True)
class Not(Expr):
    a: Expr

    def __repr__(self):
        return f"(not {self.a!r})"


@dataclass(frozen=True)
class NaryReal(Expr):
    op: str  # "max" | "min"
    args: tuple

    def __repr__(self):
        return f"{self.op}({', '.join(repr(a) for a in self.args)})"


@dataclass(frozen=True)
class Abs(Expr):
    a: Expr

    def __repr__(self):
        return f"|{self.a!r}|"


def wrap(v) -> Expr:
    if isinstance(v, Expr):
        return v
    if isinstance(v, bool):
        return BoolConst(v)
    return Const(float(v))


def And(*args) -> Expr:
    return NaryBool("and", tuple(wrap(a) for a in args))


def Or(*args) -> Expr:
    return NaryBool("or", tuple(wrap(a) for a in args))


def Implies(a, b) -> Expr:
    return Or(Not(wrap(a)), wrap(b))


def Max(*args) -> Expr:
    return NaryReal("max", tuple(wrap(a) for a in args))


def Min(*args) -> Expr:
    return NaryReal("min", tuple(wrap(a) for a in args))


def Sum(args) -> Expr:
    out: Expr = Const(0.0)
    for a in args:
        out = out + wrap(a)
    return out


# ---------------------------------------------------------------------------
# Backend 1: native evaluation under a witness environment.
# ---------------------------------------------------------------------------
def evaluate(expr: Expr, env: Mapping[str, float]):
    """Ground-evaluate ``expr`` with every Var bound by ``env``."""
    if isinstance(expr, Const):
        return expr.value
    if isinstance(expr, BoolConst):
        return expr.value
    if isinstance(expr, Var):
        return env[expr.name]
    if isinstance(expr, BinOp):
        a, b = evaluate(expr.a, env), evaluate(expr.b, env)
        if expr.op == "+":
            return a + b
        if expr.op == "-":
            return a - b
        if expr.op == "*":
            return a * b
        return a / b
    if isinstance(expr, Cmp):
        a, b = evaluate(expr.a, env), evaluate(expr.b, env)
        if expr.op == "<=":
            return a <= b
        if expr.op == "<":
            return a < b
        return a == b  # lint: allow — exact by design, see module docstring
    if isinstance(expr, NaryBool):
        vals = (evaluate(a, env) for a in expr.args)
        return all(vals) if expr.op == "and" else any(vals)
    if isinstance(expr, Not):
        return not evaluate(expr.a, env)
    if isinstance(expr, NaryReal):
        vals = [evaluate(a, env) for a in expr.args]
        return max(vals) if expr.op == "max" else min(vals)
    if isinstance(expr, Abs):
        return abs(evaluate(expr.a, env))
    raise TypeError(f"cannot evaluate {type(expr).__name__}")


def free_vars(expr: Expr, out: set | None = None) -> set:
    """The set of Var names referenced by ``expr``."""
    if out is None:
        out = set()
    if isinstance(expr, Var):
        out.add(expr.name)
    elif isinstance(expr, (BinOp, Cmp)):
        free_vars(expr.a, out)
        free_vars(expr.b, out)
    elif isinstance(expr, (NaryBool, NaryReal)):
        for a in expr.args:
            free_vars(a, out)
    elif isinstance(expr, (Not, Abs)):
        free_vars(expr.a, out)
    return out


# ---------------------------------------------------------------------------
# Backend 2: optional z3 lowering.
# ---------------------------------------------------------------------------
def z3_available() -> bool:
    try:
        import z3  # noqa: F401
        return True
    except ImportError:
        return False


def to_z3(expr: Expr, cache: dict):
    """Lower ``expr`` to a z3 expression; ``cache`` maps Var name -> z3
    Real (shared across constraints so variables unify)."""
    import z3

    if isinstance(expr, Const):
        return z3.RealVal(expr.value)
    if isinstance(expr, BoolConst):
        return z3.BoolVal(expr.value)
    if isinstance(expr, Var):
        v = cache.get(expr.name)
        if v is None:
            v = cache[expr.name] = z3.Real(expr.name)
        return v
    if isinstance(expr, BinOp):
        a, b = to_z3(expr.a, cache), to_z3(expr.b, cache)
        if expr.op == "+":
            return a + b
        if expr.op == "-":
            return a - b
        if expr.op == "*":
            return a * b
        return a / b
    if isinstance(expr, Cmp):
        a, b = to_z3(expr.a, cache), to_z3(expr.b, cache)
        if expr.op == "<=":
            return a <= b
        if expr.op == "<":
            return a < b
        return a == b
    if isinstance(expr, NaryBool):
        args = [to_z3(a, cache) for a in expr.args]
        return z3.And(*args) if expr.op == "and" else z3.Or(*args)
    if isinstance(expr, Not):
        return z3.Not(to_z3(expr.a, cache))
    if isinstance(expr, NaryReal):
        args = [to_z3(a, cache) for a in expr.args]
        out = args[0]
        for a in args[1:]:
            out = z3.If(a > out, a, out) if expr.op == "max" \
                else z3.If(a < out, a, out)
        return out
    if isinstance(expr, Abs):
        a = to_z3(expr.a, cache)
        return z3.If(a < 0, -a, a)
    raise TypeError(f"cannot lower {type(expr).__name__}")


def solve_encoding(constraints, prop: Expr, env: Mapping[str, float],
                   backend: str = "auto",
                   tol: float = 1e-6) -> tuple[bool, str]:
    """Decide whether ``constraints => prop``.

    Returns ``(holds, backend_used)``.

    * ``"native"`` — evaluate ``prop`` under the witness ``env`` (complete
      for functionally-determined systems; the caller must have validated
      the witness against the constraints first).
    * ``"z3"`` — assert the constraint conjunction (floats become exact
      rationals) plus ``Not(prop)``; UNSAT means proved.  Because z3
      re-derives the reals exactly while the witness carries float
      rounding, equalities are slackened to ``|a - b| <= tol`` before
      lowering — the engines' own float drift must not refute a theorem.
    * ``"auto"`` — z3 when importable, else native.
    """
    if backend == "auto":
        backend = "z3" if z3_available() else "native"
    if backend == "native":
        return bool(evaluate(prop, env)), "native"
    import z3

    cache: dict = {}

    def slacken(e: Expr) -> Expr:
        if isinstance(e, Cmp) and e.op == "==":
            return Abs(e.a - e.b) <= Const(tol)
        if isinstance(e, Cmp) and e.op == "<":
            # strict comparisons on witness floats: give tol of slack too
            return Cmp("<", e.a, e.b + Const(tol))
        if isinstance(e, Cmp) and e.op == "<=":
            return Cmp("<=", e.a, e.b + Const(tol))
        if isinstance(e, NaryBool):
            return NaryBool(e.op, tuple(slacken(a) for a in e.args))
        if isinstance(e, Not):
            return Not(slacken(e.a))
        return e

    s = z3.Solver()
    s.set("timeout", 30_000)
    for c in constraints:
        s.add(to_z3(slacken(c), cache))
    # Pin any variable the constraints leave free (instance constants that
    # only the property mentions) to its witness value.
    pinned = free_vars(prop) - set().union(
        *(free_vars(c) for c in constraints)) if constraints else free_vars(prop)
    for name in sorted(pinned):
        s.add(to_z3(Var(name), cache) == z3.RealVal(env[name]))
    s.add(z3.Not(to_z3(slacken(prop), cache)))
    res = s.check()
    if res == z3.unsat:
        return True, "z3"
    if res == z3.sat:
        return False, "z3"
    # timeout/unknown: fall back to the witness decision
    return bool(evaluate(prop, env)), "native"
