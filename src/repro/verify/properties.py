"""The theorems stated over :class:`repro.verify.encode.Encoding`s.

Each :class:`Property` maps an encoding (constraint system + witness) to
one closed formula over the encoding's variables.  The harness decides
``constraints => formula``: witness evaluation when z3 is absent (the
systems are functionally determined — see :mod:`repro.verify.smt`), a
real linear-arithmetic proof when it is installed.

  * **work_conservation** — no dim idles between consecutive services
    while a task it will serve later was already ready: ``S_{k+1} <=
    max(F_k, earliest ready among later-served ops)``.  Preemption re-arm
    (``preempt_penalty_s``) is not idleness: cut chunks are not ready
    until the penalty elapses, and their ready times say so.
  * **bytes_conservation** — per dim, the drained service time times the
    bandwidth equals the scheduled task bytes: ``sum_k (F_k - S_k) * bw
    == expected_wire`` — preemption splits must neither lose nor
    double-serve bytes.
  * **no_lost_chunks** — every scheduled chunk stage is served exactly
    once (its final service; cut-and-requeued chunks re-serve), and every
    request completes at or after its issue.
  * **starvation_freedom** — the designated victim tenant (lowest
    priority / weight) completes by a finite bound derived from total
    load: under strict-priority with finite high-priority load the
    victim cannot be starved forever.
  * **bounded_slowdown** — over a window where two tenants are both
    backlogged on the contended dim, their weight-normalized service
    differs by at most a few quanta:
    ``|B_T/w_T - B_U/w_U| <= slack``.  This is the property the
    weighted-fair virtual-time staleness bug breaks: with ``vt_clamp``
    off, a re-arriving tenant's stale clock lets it monopolize the dim
    (see the ``wf-rearrival-stale`` instance, whose counterexample is
    pinned as a regression test).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from repro.verify import smt
from repro.verify.encode import Encoding, FabricInstance
from repro.verify.smt import Abs, And, BoolConst, Const, Max


@dataclass(frozen=True)
class Property:
    name: str
    description: str
    formula: Callable[[Encoding], smt.Expr]

    def applies(self, inst: FabricInstance) -> bool:
        if self.name == "bounded_slowdown":
            # A fairness claim about weights: meaningful for the fair
            # policies (where it must hold) and for fifo (where it is
            # expected to be refuted — fifo ignores weights).
            return inst.policy in ("weighted-fair", "slo-aware", "fifo")
        return True


def work_conservation(enc: Encoding) -> smt.Expr:
    parts = []
    for dim in range(len(enc.services)):
        svcs = enc.services[dim]
        for k, svc in enumerate(svcs):
            later_ready = [enc.op_ready[op]
                           for later in svcs[k:] for op in later.ops]
            if not later_ready:
                continue
            r_min = Const(min(later_ready))
            if k == 0:
                parts.append(svc.svar() <= r_min)
            else:
                parts.append(svc.svar() <= Max(svcs[k - 1].fvar(), r_min))
    return And(*parts) if parts else BoolConst(True)


# Conservation compares an accumulated sum of (finish - start) * bandwidth
# against the scheduled byte total: the subtraction of ~1e-3-scale times
# blown up by ~1e10-scale bandwidth leaves ulp noise, so the theorem is
# stated to byte precision (same scale as invariants._ABS_B) rather than
# as exact equality — a real lost or double-served chunk is >= one chunk.
_BYTES_TOL = 1e-3


def bytes_conservation(enc: Encoding) -> smt.Expr:
    parts = []
    for dim in range(len(enc.services)):
        drained = smt.Sum([(svc.fvar() - svc.svar()) * Const(enc.bw[dim])
                           for svc in enc.services[dim]])
        parts.append(Abs(drained - Const(enc.expected_wire[dim]))
                     <= Const(_BYTES_TOL))
    return And(*parts)


def no_lost_chunks(enc: Encoding) -> smt.Expr:
    served_once = all(
        enc.op_count.get(op, 0) == 1 for op in enc.expected_ops)
    right_dim = all(
        enc.op_service[op].dim == enc.expected_ops[op][0]
        for op in enc.op_service)
    parts: list = [BoolConst(served_once and right_dim)]
    for g, req in enumerate(enc.requests):
        if f"C_{g}" in enc.env:
            parts.append(Const(req.issue_time) <= enc.cvar(g))
    return And(*parts)


def starvation_freedom(enc: Encoding) -> smt.Expr:
    """The victim tenant completes by a finite closed-form bound.

    Bound: last issue + total serialized drain time across dims + one
    fixed latency per served op + one penalty per possible preemption.
    Any discipline that eventually serves finite load beats it; a
    starved tenant blows past it as load grows.
    """
    inst = enc.instance
    victim = min(
        inst.tenants,
        key=lambda s: (s.priority, s.weight)).name
    drain = sum(enc.expected_wire[d] / enc.bw[d]
                for d in range(len(enc.bw)))
    n_ops = len(enc.expected_ops)
    max_a = max((svc.a for per in enc.services for svc in per),
                default=0.0)
    last_issue = max((r.issue_time for r in enc.requests), default=0.0)
    bound = last_issue + drain + n_ops * (max_a + enc.penalty)
    parts = []
    for g, req in enumerate(enc.requests):
        if req.tenant == victim and f"C_{g}" in enc.env:
            parts.append(enc.cvar(g) <= Const(bound))
    return And(*parts) if parts else BoolConst(True)


def bounded_slowdown(enc: Encoding) -> smt.Expr:
    inst = enc.instance
    dim = inst.contended_dim
    names = [s.name for s in inst.tenants]
    max_chunk = max((b for per in enc.services for svc in per
                     for b in svc.op_bytes.values()), default=0.0)
    parts = []
    for i, t1 in enumerate(names):
        for t2 in names[i + 1:]:
            lo1, hi1 = enc.tenant_span(t1, dim)
            lo2, hi2 = enc.tenant_span(t2, dim)
            if inst.slowdown_window_start is not None:
                w0 = (enc.assignment[inst.slowdown_window_start]
                      if isinstance(inst.slowdown_window_start, str)
                      else float(inst.slowdown_window_start))
            else:
                w0 = max(lo1, lo2)
            w1 = min(hi1, hi2)
            if w1 <= w0 or lo1 > w0 or lo2 > w0:
                continue  # pair never jointly backlogged over the window
            w1_ = max(s.weight for s in inst.tenants if s.name == t1)
            w2_ = max(s.weight for s in inst.tenants if s.name == t2)
            slack = (inst.slowdown_slack_quanta * inst.quantum_chunks
                     * max_chunk * (1.0 / w1_ + 1.0 / w2_))
            b1 = enc.tenant_window_bytes(t1, dim, w0, w1)
            b2 = enc.tenant_window_bytes(t2, dim, w0, w1)
            parts.append(
                Abs(b1 * Const(1.0 / w1_) - b2 * Const(1.0 / w2_))
                <= Const(slack))
    return And(*parts) if parts else BoolConst(True)


ALL_PROPERTIES: tuple[Property, ...] = (
    Property("work_conservation",
             "no dim idles while a task it serves later is ready",
             work_conservation),
    Property("bytes_conservation",
             "per-dim drained bytes equal scheduled task bytes",
             bytes_conservation),
    Property("no_lost_chunks",
             "every chunk stage served exactly once, on its dim",
             no_lost_chunks),
    Property("starvation_freedom",
             "the victim tenant completes within a finite load bound",
             starvation_freedom),
    Property("bounded_slowdown",
             "jointly-backlogged tenants get weight-proportional service",
             bounded_slowdown),
)
