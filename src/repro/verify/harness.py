"""Prove/refute fairness properties per instance; replay counterexamples.

``decide_property`` sweeps an instance's free-variable grid: each
assignment is encoded (:func:`encode_assignment` — which runs the real
engine with the runtime sanitizer armed), the witness is validated
against the constraint system, and ``constraints => property`` is
decided (witness evaluation, or a z3 linear-arithmetic proof when
installed).  A property is **proved** on the instance when it holds for
every assignment, **refuted** when some assignment violates it.

Every refutation round-trips: :func:`replay_counterexample` rebuilds the
violating assignment as a concrete ``CollectiveRequest`` stream and
replays it through ``simulate_requests`` on *both* engines, asserting
(a) the two engines agree bit-identically and (b) the property is
violated on each — so every counterexample the solver finds is
automatically a differential regression test (``tests/test_verify.py``
pins the virtual-time staleness one permanently).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.tenancy.tenants import TenantSpec
from repro.verify.encode import (
    Encoding,
    FabricInstance,
    FreeVar,
    RequestTemplate,
    encode_assignment,
    validate_encoding,
)
from repro.verify.properties import ALL_PROPERTIES, Property
from repro.verify.smt import solve_encoding, z3_available


@dataclass
class PropertyVerdict:
    instance: str
    prop: str
    status: str                      # "proved" | "refuted"
    n_assignments: int
    counterexamples: list = field(default_factory=list)
    backends: tuple = ()
    replays: list = field(default_factory=list)

    def as_dict(self) -> dict:
        return {
            "instance": self.instance,
            "property": self.prop,
            "status": self.status,
            "n_assignments": self.n_assignments,
            "counterexamples": self.counterexamples,
            "backends": sorted(self.backends),
            "replays": self.replays,
        }


def decide_property(inst: FabricInstance, prop: Property,
                    quick: bool = False, backend: str = "auto",
                    replay: bool = True,
                    encodings: list | None = None) -> PropertyVerdict:
    """Decide one property over the instance's assignment grid."""
    if encodings is None:
        encodings = build_encodings(inst, quick)
    cexs = []
    backends = set()
    for enc in encodings:
        holds, used = solve_encoding(
            enc.constraints, prop.formula(enc), enc.env, backend)
        backends.add(used)
        if not holds:
            cexs.append(dict(enc.assignment))
    verdict = PropertyVerdict(
        instance=inst.name, prop=prop.name,
        status="proved" if not cexs else "refuted",
        n_assignments=len(encodings),
        counterexamples=cexs, backends=tuple(backends))
    if cexs and replay:
        verdict.replays.append(replay_counterexample(inst, cexs[0], prop))
    return verdict


def build_encodings(inst: FabricInstance,
                    quick: bool = False) -> list[Encoding]:
    """Encode + validate every assignment on the instance's grid."""
    out = []
    for assignment in inst.assignments(quick):
        enc = encode_assignment(inst, assignment)
        validate_encoding(enc)
        out.append(enc)
    return out


def replay_counterexample(inst: FabricInstance, assignment: dict,
                          prop: Property) -> dict:
    """Round-trip a violating assignment into a ``simulate_requests``
    replay on both engines; assert the engines agree bit-identically and
    the property is violated on each."""
    from repro.verify.smt import evaluate

    encs = {eng: encode_assignment(inst, assignment, engine=eng)
            for eng in ("reference", "indexed")}
    diff = encs["reference"].result.diff_fields(encs["indexed"].result)
    violated = {eng: not bool(evaluate(prop.formula(enc), enc.env))
                for eng, enc in encs.items()}
    if diff:
        raise AssertionError(
            f"{inst.name} {assignment}: counterexample replay diverged "
            f"between engines on fields {diff}")
    if not all(violated.values()):
        raise AssertionError(
            f"{inst.name} {assignment}: counterexample did not reproduce "
            f"the {prop.name} violation on both engines: {violated}")
    req = encs["reference"].requests
    return {
        "assignment": dict(assignment),
        "requests": [
            {"tenant": r.tenant, "size_bytes": r.size_bytes,
             "issue_time": r.issue_time, "priority": r.priority}
            for r in req],
        "violated_on": sorted(k for k, v in violated.items() if v),
        "engines_bit_identical": True,
    }


def verify_suite(instances=None, properties=None, quick: bool = False,
                 backend: str = "auto", replay: bool = True) -> dict:
    """Decide every applicable (instance, property) pair; the report
    shape is what ``benchmarks/verify_study.py`` serializes."""
    if instances is None:
        instances = default_instances()
    if properties is None:
        properties = ALL_PROPERTIES
    verdicts = []
    for inst in instances:
        encodings = build_encodings(inst, quick)
        for prop in properties:
            if not prop.applies(inst):
                continue
            verdicts.append(decide_property(
                inst, prop, quick=quick, backend=backend, replay=replay,
                encodings=encodings))
    return {
        "z3_available": z3_available(),
        "quick": quick,
        "n_instances": len(instances),
        "n_decided": len(verdicts),
        "n_proved": sum(v.status == "proved" for v in verdicts),
        "n_refuted": sum(v.status == "refuted" for v in verdicts),
        "properties_decided": sorted({v.prop for v in verdicts}),
        "verdicts": [v.as_dict() for v in verdicts],
    }


# ---------------------------------------------------------------------------
# The default instance suite.
# ---------------------------------------------------------------------------
MB = 1e6


def _wf_rearrival(vt_clamp: bool) -> FabricInstance:
    """Weighted-fair, equal weights; tenant ``a`` idles then re-arrives
    with a burst while ``b`` stays backlogged.  With the SFQ clamp off,
    ``a``'s stale (low) virtual time lets it monopolize the fabric until
    its clock catches up — bounded_slowdown is refuted.  With the clamp
    on, ``a`` re-enters at the dim's current virtual clock and the
    tenants share by weight — proved."""
    suffix = "clamped" if vt_clamp else "stale"
    reqs = [RequestTemplate("a", 1 * MB, 0.0)]
    reqs += [RequestTemplate("b", 4 * MB, i * 1e-6) for i in range(8)]
    reqs += [RequestTemplate("a", 4 * MB, ("rearrive", i * 1e-6))
             for i in range(4)]
    return FabricInstance(
        name=f"wf-rearrival-{suffix}",
        tenants=(TenantSpec("a", weight=1.0), TenantSpec("b", weight=1.0)),
        requests=tuple(reqs),
        policy="weighted-fair",
        quantum_chunks=2,
        preemption=True,
        vt_clamp=vt_clamp,
        chunks_per_collective=2,
        free=(FreeVar("rearrive", (3e-4, 6e-4)),),
        slowdown_window_start="rearrive",
        contended_dim=0,
        slowdown_slack_quanta=2.0,
        notes="virtual-time staleness on idle->busy re-arrival",
    )


def _sp_preempt() -> FabricInstance:
    """Strict-priority with chunk-granularity preemption and a re-arm
    penalty grid: finite high-priority load must not starve the
    low-priority tenant, and preemption splits must conserve bytes."""
    reqs = [RequestTemplate("lo", 8 * MB, 0.0)]
    reqs += [RequestTemplate("hi", 1 * MB, 5e-5 + i * 1e-4)
             for i in range(3)]
    return FabricInstance(
        name="sp-preempt",
        tenants=(TenantSpec("lo", priority=0), TenantSpec("hi", priority=10)),
        requests=tuple(reqs),
        policy="strict-priority",
        quantum_chunks=4,
        preemption=True,
        preempt_penalty_s="penalty",
        chunks_per_collective=2,
        free=(FreeVar("penalty", (0.0, 2e-5)),),
        notes="preemption + re-arm penalty under strict priority",
    )


def _fifo_mixed() -> FabricInstance:
    """FIFO with unequal weights: arrival order ignores weights, so the
    weight-proportional-share property is expected to be refuted (the
    conservation and starvation properties still hold)."""
    reqs = []
    for i in range(4):
        reqs.append(RequestTemplate("a", 4 * MB, i * 2e-6))
        reqs.append(RequestTemplate("b", 4 * MB, 1e-6 + i * 2e-6))
    return FabricInstance(
        name="fifo-mixed",
        tenants=(TenantSpec("a", weight=1.0), TenantSpec("b", weight=4.0)),
        requests=tuple(reqs),
        policy="fifo",
        quantum_chunks=2,
        preemption=False,
        chunks_per_collective=2,
        slowdown_slack_quanta=2.0,
        contended_dim=0,
        notes="fifo ignores weights: fairness refuted, conservation holds",
    )


def _wf_preempt() -> FabricInstance:
    """Weighted-fair + preemption + penalty grid: the conservation and
    work-conservation theorems across preemption splits."""
    reqs = [RequestTemplate("big", 8 * MB, 0.0),
            RequestTemplate("big", 8 * MB, 1e-6)]
    reqs += [RequestTemplate("small", 1 * MB, 1e-4 + i * 2e-4)
             for i in range(4)]
    return FabricInstance(
        name="wf-preempt",
        tenants=(TenantSpec("big", weight=1.0),
                 TenantSpec("small", weight=4.0)),
        requests=tuple(reqs),
        policy="weighted-fair",
        quantum_chunks=4,
        preemption=True,
        preempt_penalty_s="penalty",
        chunks_per_collective=2,
        free=(FreeVar("penalty", (0.0, 1e-5, 2e-5)),),
        slowdown_slack_quanta=8.0,
        notes="byte conservation across weighted-fair preemption splits",
    )


def default_instances() -> list[FabricInstance]:
    return [
        _wf_rearrival(vt_clamp=True),
        _wf_rearrival(vt_clamp=False),
        _sp_preempt(),
        _fifo_mixed(),
        _wf_preempt(),
    ]
