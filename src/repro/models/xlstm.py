"""xLSTM: mLSTM (matrix-memory) + sLSTM blocks, xLSTM[7:1] layout.

mLSTM uses the chunkwise-parallel linear-recurrence form: within a chunk an
attention-like quadratic (L_c x L_c) with multiplicative gate decays; across
chunks a carried matrix state C (NH, dh, dh) and normalizer n (NH, dh):

    C_t = f_t C_{t-1} + i_t v_t k_t^T
    n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)

Simplification vs the paper (documented in DESIGN.md): sigmoid input/forget
gates instead of exponential gates with max-stabilizer — state shapes,
recurrence structure, chunkwise algorithm and FLOPs are preserved.
sLSTM is a per-head recurrent cell scanned over time (O(1) decode state).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (
    constrain,
    cross_entropy,
    dense_init,
    embed_init,
    remat_policy,
    rms_norm,
)
from repro.models.recurrent import causal_conv1d, conv1d_step

CHUNK = 256


def _dims(cfg: ModelConfig):
    di = int(cfg.proj_factor * cfg.d_model)
    nh = cfg.num_heads
    return di, nh, di // nh


# -- mLSTM --------------------------------------------------------------------
def init_mlstm(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    di, nh, dh = _dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "ln": jnp.ones((d,), dtype),
        "w_up": dense_init(ks[0], (d, 2 * di), 0, dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_width, di), 0, dtype),
        "wq": dense_init(ks[2], (nh, dh, dh), 1, dtype),
        "wk": dense_init(ks[3], (nh, dh, dh), 1, dtype),
        "wv": dense_init(ks[4], (nh, dh, dh), 1, dtype),
        "w_i": dense_init(ks[5], (di, nh), 0, dtype),
        "w_f": dense_init(ks[6], (di, nh), 0, dtype),
        "f_bias": jnp.full((nh,), 3.0, dtype),
        "gn": jnp.ones((di,), dtype),
        "w_down": dense_init(ks[7], (di, d), 0, dtype),
    }


def _mlstm_chunk_scan(q, k, v, i, logf, C0, n0):
    """Chunkwise mLSTM.  q,k,v: (B,S,NH,dh); i,logf: (B,S,NH).
    C0: (B,NH,dh,dh), n0: (B,NH,dh).  Returns (h (B,S,NH,dh), C, n)."""
    b, s, nh, dh = q.shape
    L = min(CHUNK, s)
    nc = -(-s // L)
    pad = nc * L - s
    if pad:
        zf = lambda x: jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
        q, k, v, i, logf = map(zf, (q, k, v, i, logf))

    def split(x):  # (B, NC*L, ...) -> (NC, B, L, ...)
        return x.reshape(b, nc, L, *x.shape[2:]).transpose(1, 0, 2, *range(3, x.ndim + 1))

    qs, ks_, vs, is_, lfs = map(split, (q, k, v, i, logf))
    mask = jnp.tril(jnp.ones((L, L), bool))

    def body(carry, xs):
        C, n = carry
        qc, kc, vc, ic, lfc = xs             # (B,L,NH,*)
        cl = jnp.cumsum(lfc, axis=1)          # (B,L,NH) log cumulative decay
        qk = jnp.einsum("blhd,bmhd->bhlm", qc, kc).astype(jnp.float32)
        decay = jnp.exp(cl.transpose(0, 2, 1)[:, :, :, None]
                        - cl.transpose(0, 2, 1)[:, :, None, :])
        A = qk * decay * ic.transpose(0, 2, 1)[:, :, None, :].astype(jnp.float32)
        A = jnp.where(mask[None, None], A, 0.0)
        h_intra = jnp.einsum("bhlm,bmhd->blhd", A.astype(qc.dtype), vc)
        d_intra = A.sum(-1).transpose(0, 2, 1)                     # (B,L,NH)
        ecl = jnp.exp(cl)                                          # (B,L,NH)
        h_inter = jnp.einsum("blhd,bhde->blhe", qc, C.astype(qc.dtype)) * \
            ecl[..., None].astype(qc.dtype)
        d_inter = jnp.einsum("blhd,bhd->blh", qc.astype(jnp.float32),
                             n) * ecl
        denom = jnp.maximum(jnp.abs(d_intra + d_inter), 1.0)
        h = (h_intra.astype(jnp.float32) + h_inter.astype(jnp.float32)) / \
            denom[..., None]
        e_end = jnp.exp(cl[:, -1])                                 # (B,NH)
        w_end = jnp.exp(cl[:, -1][:, None] - cl) * ic.astype(jnp.float32)
        C = e_end[:, :, None, None] * C + jnp.einsum(
            "blh,blhd,blhe->bhde", w_end, kc.astype(jnp.float32),
            vc.astype(jnp.float32))
        n = e_end[:, :, None] * n + jnp.einsum(
            "blh,blhd->bhd", w_end, kc.astype(jnp.float32))
        return (C, n), h.astype(qc.dtype)

    (C, n), hs = jax.lax.scan(body, (C0, n0), (qs, ks_, vs, is_, lfs))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(b, nc * L, nh, dh)
    return h[:, :s], C, n


def apply_mlstm(p, x, cfg: ModelConfig, *, state=None):
    dt = x.dtype
    b, s, d = x.shape
    di, nh, dh = _dims(cfg)
    h0 = rms_norm(x, p["ln"].astype(dt), cfg.norm_eps)
    up = h0 @ p["w_up"].astype(dt)
    up = constrain(up, "dp", None, "tp")
    xm, z = jnp.split(up, 2, axis=-1)

    new_conv = None
    if state is None:
        xc = jax.nn.silu(causal_conv1d(p["conv_w"], xm))
    elif s == 1:
        c_out, conv_state = conv1d_step(p["conv_w"], xm, state["conv"].astype(dt))
        xc = jax.nn.silu(c_out)
        new_conv = conv_state
    else:  # prefill from carried conv state
        cw = cfg.conv_width
        hist = jnp.concatenate([state["conv"].astype(dt), xm], axis=1)
        xc = jax.nn.silu(causal_conv1d(p["conv_w"], hist)[:, cw - 1:])
        new_conv = hist[:, -(cw - 1):]

    def headwise(w, src):
        hsrc = src.reshape(b, s, nh, dh)
        return jnp.einsum("blhd,hde->blhe", hsrc, w.astype(dt))

    q = headwise(p["wq"], xc)
    k = headwise(p["wk"], xc) / jnp.sqrt(jnp.float32(dh)).astype(dt)
    v = headwise(p["wv"], xm)
    gate_i = jax.nn.sigmoid((xm @ p["w_i"].astype(dt)).astype(jnp.float32))
    logf = jax.nn.log_sigmoid(
        (xm @ p["w_f"].astype(dt)).astype(jnp.float32) + p["f_bias"].astype(jnp.float32)
    )

    if state is None:
        C0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, nh, dh), jnp.float32)
    else:
        C0, n0 = state["C"], state["n"]

    if s == 1 and state is not None:
        f = jnp.exp(logf[:, 0])                                   # (B,NH)
        C = f[:, :, None, None] * C0 + gate_i[:, 0][:, :, None, None] * jnp.einsum(
            "bhd,bhe->bhde", k[:, 0].astype(jnp.float32), v[:, 0].astype(jnp.float32))
        n = f[:, :, None] * n0 + gate_i[:, 0][:, :, None] * k[:, 0].astype(jnp.float32)
        num = jnp.einsum("bhd,bhde->bhe", q[:, 0].astype(jnp.float32), C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh",
                                             q[:, 0].astype(jnp.float32), n)), 1.0)
        h = (num / den[..., None]).astype(dt)[:, None]
    else:
        h, C, n = _mlstm_chunk_scan(q, k, v, gate_i, logf, C0, n0)

    h = rms_norm(h.reshape(b, s, di), p["gn"].astype(dt), cfg.norm_eps)
    out = (h * jax.nn.silu(z)) @ p["w_down"].astype(dt)
    out = constrain(out, "dp", None, None)
    new_state = None
    if state is not None:
        new_state = {"C": C, "n": n, "conv": new_conv.astype(state["conv"].dtype)}
    return x + out, new_state


def init_mlstm_state(cfg: ModelConfig, batch: int):
    di, nh, dh = _dims(cfg)
    return {
        "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di), jnp.bfloat16),
    }


# -- sLSTM --------------------------------------------------------------------
def init_slstm(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    nh = cfg.num_heads
    dh = d // nh
    ks = jax.random.split(key, 3)
    return {
        "ln": jnp.ones((d,), dtype),
        "w_gates": dense_init(ks[0], (d, 4 * d), 0, dtype),
        "r_gates": dense_init(ks[1], (nh, dh, 4 * dh), 1, dtype),
        "gn": jnp.ones((d,), dtype),
        "w_out": dense_init(ks[2], (d, d), 0, dtype),
    }


def _slstm_cell(gx, h_prev, c_prev, r_gates, nh, dh):
    """gx: (B,4D) precomputed input gates; h/c: (B,D)."""
    b = gx.shape[0]
    hr = h_prev.reshape(b, nh, dh)
    gr = jnp.einsum("bhd,hde->bhe", hr, r_gates.astype(h_prev.dtype))
    g = gx + gr.reshape(b, -1)
    i, f, z, o = jnp.split(g, 4, axis=-1)
    c = jax.nn.sigmoid(f) * c_prev + jax.nn.sigmoid(i) * jnp.tanh(z)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


def apply_slstm(p, x, cfg: ModelConfig, *, state=None):
    dt = x.dtype
    b, s, d = x.shape
    nh = cfg.num_heads
    dh = d // nh
    xn = rms_norm(x, p["ln"].astype(dt), cfg.norm_eps)
    gx = xn @ p["w_gates"].astype(dt)                              # (B,S,4D)
    gx = constrain(gx, "dp", None, "tp")
    if state is None:
        h0 = jnp.zeros((b, d), dt)
        c0 = jnp.zeros((b, d), jnp.float32)
    else:
        h0, c0 = state["h"].astype(dt), state["c"]

    def step(carry, g_t):
        h, c = carry
        h2, c2 = _slstm_cell(g_t, h, c.astype(jnp.float32), p["r_gates"], nh, dh)
        return (h2.astype(dt), c2), h2.astype(dt)

    (hf, cf), hs = jax.lax.scan(step, (h0, c0), gx.transpose(1, 0, 2))
    hseq = hs.transpose(1, 0, 2)
    out = rms_norm(hseq, p["gn"].astype(dt), cfg.norm_eps) @ p["w_out"].astype(dt)
    out = constrain(out, "dp", None, None)
    new_state = {"h": hf, "c": cf} if state is not None else None
    return x + out, new_state


def init_slstm_state(cfg: ModelConfig, batch: int):
    return {
        "h": jnp.zeros((batch, cfg.d_model), jnp.bfloat16),
        "c": jnp.zeros((batch, cfg.d_model), jnp.float32),
    }


# -- model ----------------------------------------------------------------------
def _period(cfg: ModelConfig) -> int:
    return cfg.slstm_every


def init_lm(key, cfg: ModelConfig, dtype=jnp.float32):
    per = _period(cfg)
    n_periods = cfg.num_layers // per
    n_m = per - 1
    keys = jax.random.split(key, cfg.num_layers + 3)
    periods = []
    ki = 0
    for _ in range(n_periods):
        mls = [init_mlstm(keys[ki + j], cfg, dtype) for j in range(n_m)]
        ki += n_m
        sl = init_slstm(keys[ki], cfg, dtype)
        ki += 1
        periods.append({
            "mlstm": jax.tree.map(lambda *xs: jnp.stack(xs), *mls),
            "slstm": sl,
        })
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *periods)
    return {
        "embed": embed_init(keys[-1], (cfg.vocab_size, cfg.d_model), dtype),
        "periods": stacked,
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(keys[-2], (cfg.d_model, cfg.vocab_size), 0, dtype),
    }


def _apply_period(p_slot, x, cfg, *, caches=None):
    mc = caches["mlstm"] if caches is not None else None

    def mbody(h, layer):
        p_l, c_l = layer
        h2, nc = apply_mlstm(p_l, h, cfg, state=c_l)
        return h2, nc

    x, new_mc = jax.lax.scan(mbody, x, (p_slot["mlstm"], mc))
    sc = caches["slstm"] if caches is not None else None
    x, new_sc = apply_slstm(p_slot["slstm"], x, cfg, state=sc)
    new = {"mlstm": new_mc, "slstm": new_sc} if caches is not None else None
    return x, new


def forward(params, tokens, cfg: ModelConfig, *, caches=None):
    dt = jnp.dtype(cfg.dtype)
    x = constrain(params["embed"].astype(dt)[tokens], "dp", None, None)
    period_fn = partial(_apply_period, cfg=cfg)
    if cfg.remat:
        period_fn = jax.checkpoint(period_fn, policy=remat_policy(cfg))
    pc = caches if caches is not None else None

    def body(h, layer):
        p_l, c_l = layer
        h2, nc = period_fn(p_l, h, caches=c_l)
        return h2, nc

    x, new_caches = jax.lax.scan(body, x, (params["periods"], pc))
    x = rms_norm(x, params["ln_f"].astype(dt), cfg.norm_eps)
    logits = constrain(x @ params["lm_head"].astype(dt), "dp", None, "tp")
    return logits, new_caches


def loss_fn(params, batch, cfg: ModelConfig):
    logits, _ = forward(params, batch["tokens"], cfg)
    return cross_entropy(logits, batch["labels"], batch.get("mask"))


def init_caches(cfg: ModelConfig, batch: int):
    per = _period(cfg)
    n_periods = cfg.num_layers // per
    n_m = per - 1
    slot = {
        "mlstm": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_m,) + x.shape).copy(),
            init_mlstm_state(cfg, batch),
        ),
        "slstm": init_slstm_state(cfg, batch),
    }
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_periods,) + x.shape).copy(), slot
    )


def prefill(params, tokens, cfg: ModelConfig, max_len: int):
    caches = init_caches(cfg, tokens.shape[0])
    logits, caches = forward(params, tokens, cfg, caches=caches)
    return logits[:, -1:], caches


def decode_step(params, caches, token, pos, cfg: ModelConfig):
    logits, new_caches = forward(params, token[:, None], cfg, caches=caches)
    return logits, new_caches
