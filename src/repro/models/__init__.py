from repro.models.registry import ModelApi, build_model, count_params  # noqa: F401
