"""Mixture-of-Experts FFN with expert parallelism.

Sort-based, capacity-bounded token dispatch (MaxText/GShard "dropping"
style), formulated per batch row so every sort/scatter is *local to the
data shard* under GSPMD — the only cross-device movement is the
(B, E, C, D) buffer resharding from batch-sharded to expert-sharded layout,
which XLA lowers to the expert-parallel all-to-all.

Supports shared experts (DeepSeekMoE): ``num_shared_experts`` always-on
experts folded into one dense gated MLP of width shared*moe_d_ff.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import apply_mlp, constrain, dense_init, init_mlp


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    p = {
        "router": dense_init(ks[0], (d, e), 0, dtype),
        "wi": dense_init(ks[1], (e, d, f), 1, dtype),
        "wg": dense_init(ks[2], (e, d, f), 1, dtype),
        "wo": dense_init(ks[3], (e, f, d), 1, dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(
            ks[4], d, cfg.num_shared_experts * cfg.moe_d_ff, True, dtype
        )
    return p


def expert_capacity(cfg: ModelConfig, tokens_per_row: int) -> int:
    cap = int(tokens_per_row * cfg.experts_per_token * cfg.capacity_factor
              / cfg.num_experts)
    return max(cap, cfg.experts_per_token)


def apply_moe(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    c = expert_capacity(cfg, s)
    dt = x.dtype

    # --- routing (per token) ---
    logits = (x @ p["router"].astype(dt)).astype(jnp.float32)    # (B,S,E)
    gates = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(gates, k)                        # (B,S,k)
    weights = (weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)).astype(dt)

    # --- per-row dispatch: rank each assignment within its expert ---
    flat_ids = ids.reshape(b, s * k)                              # (B, A)
    order = jnp.argsort(flat_ids, axis=-1, stable=True)           # (B, A)
    sorted_ids = jnp.take_along_axis(flat_ids, order, axis=-1)
    # position within expert = index - first index of that expert id
    seg_start = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(e), side="left")
    )(sorted_ids)                                                 # (B, E)
    pos_in_e = jnp.arange(s * k)[None, :] - jnp.take_along_axis(
        seg_start, sorted_ids, axis=-1
    )
    keep = pos_in_e < c
    dest = jnp.where(keep, sorted_ids * c + pos_in_e, e * c)      # OOB -> dropped
    token_of = order // k                                         # source token idx

    # --- scatter tokens into the (B, E*C, D) expert buffer (local per row) ---
    src = jnp.take_along_axis(x, token_of[..., None], axis=1)     # (B, A, D)
    buf = jnp.zeros((b, e * c, d), dt)
    buf = jax.vmap(lambda bu, de, sr: bu.at[de].set(sr, mode="drop"))(buf, dest, src)
    buf = buf.reshape(b, e, c, d)
    # a2a: batch-sharded -> expert-sharded
    buf = constrain(buf, "dp", "tp", None, None)

    # --- expert compute (E sharded over model axis) ---
    up = jnp.einsum("becd,edf->becf", buf, p["wi"].astype(dt))
    gate = jnp.einsum("becd,edf->becf", buf, p["wg"].astype(dt))
    act = jax.nn.silu(gate) * up
    out = jnp.einsum("becf,efd->becd", act, p["wo"].astype(dt))
    out = constrain(out, "dp", "tp", None, None)

    # --- combine: gather back + weighted sum (local per row) ---
    out = constrain(out.reshape(b, e * c, d), "dp", None, None)   # a2a back
    picked = jax.vmap(lambda o, de: o.at[de].get(mode="fill", fill_value=0.0))(
        out, dest
    )                                                             # (B, A, D)
    w_sorted = jnp.take_along_axis(weights.reshape(b, s * k), order, axis=-1)
    picked = picked * (w_sorted * keep)[..., None]
    y = jnp.zeros((b, s, d), dt)
    y = jax.vmap(lambda yy, to, pk: yy.at[to].add(pk))(y, token_of, picked)

    if cfg.num_shared_experts:
        y = y + apply_mlp(p["shared"], x, gated=True)
    return constrain(y, "dp", None, None)
