"""Whisper-style encoder-decoder transformer (audio backbone).

The conv/mel frontend is a STUB per the assignment: the model consumes
precomputed frame embeddings (B, num_frames, d_model).  Sinusoidal position
encodings; MHA; decoder has causal self-attention (KV cache) + cross
attention to the encoder memory.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (
    apply_mlp,
    attention,
    constrain,
    cross_entropy,
    dense_init,
    embed_init,
    init_mlp,
    remat_policy,
    rms_norm,
    sinusoidal_positions,
)


def _init_proj(key, cfg, dtype):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.num_heads * hd), 0, dtype),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.num_kv_heads * hd), 0, dtype),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.num_kv_heads * hd), 0, dtype),
        "wo": dense_init(ks[3], (cfg.num_heads * hd, cfg.d_model), 0, dtype),
    }


def _proj_qkv(p, xq, xkv, cfg):
    b, s, _ = xq.shape
    t = xkv.shape[1]
    hd = cfg.resolved_head_dim
    dt = xq.dtype
    q = (xq @ p["wq"].astype(dt)).reshape(b, s, cfg.num_heads, hd)
    k = (xkv @ p["wk"].astype(dt)).reshape(b, t, cfg.num_kv_heads, hd)
    v = (xkv @ p["wv"].astype(dt)).reshape(b, t, cfg.num_kv_heads, hd)
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, "tp", None)
    v = constrain(v, "dp", None, "tp", None)
    return q, k, v


def init_enc_block(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": _init_proj(ks[0], cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, False, dtype),
    }


def apply_enc_block(p, x, cfg):
    h = rms_norm(x, p["ln1"].astype(x.dtype), cfg.norm_eps)
    q, k, v = _proj_qkv(p["attn"], h, h, cfg)
    o = attention(q, k, v, impl="xla_flash", causal=False)
    o = o.reshape(x.shape) if o.ndim == 3 else o.reshape(x.shape[0], x.shape[1], -1)
    x = x + constrain(o @ p["attn"]["wo"].astype(x.dtype), "dp", "sp", None)
    h = rms_norm(x, p["ln2"].astype(x.dtype), cfg.norm_eps)
    return x + apply_mlp(p["mlp"], h, gated=False)


def init_dec_block(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "self_attn": _init_proj(ks[0], cfg, dtype),
        "ln_x": jnp.ones((cfg.d_model,), dtype),
        "cross_attn": _init_proj(ks[1], cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, False, dtype),
    }


def apply_dec_block(p, x, cfg, *, memory, positions, cache=None):
    b, s, _ = x.shape
    dt = x.dtype
    # self attention (causal, cached)
    h = rms_norm(x, p["ln1"].astype(dt), cfg.norm_eps)
    q, k, v = _proj_qkv(p["self_attn"], h, h, cfg)
    new_cache = None
    if cache is not None:
        pos0 = positions[0]
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, pos0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, pos0, 0, 0))
        new_cache = {"k": ck, "v": cv}
        k, v = ck.astype(dt), cv.astype(dt)
        o = attention(q, k, v, impl="xla_flash", causal=True, q_offset=pos0)
    else:
        o = attention(q, k, v, impl="xla_flash", causal=True)
    x = x + constrain(o.reshape(b, s, -1) @ p["self_attn"]["wo"].astype(dt),
                      "dp", "sp", None)
    # cross attention to encoder memory
    h = rms_norm(x, p["ln_x"].astype(dt), cfg.norm_eps)
    q, k, v = _proj_qkv(p["cross_attn"], h, memory, cfg)
    o = attention(q, k, v, impl="xla_flash", causal=False)
    x = x + constrain(o.reshape(b, s, -1) @ p["cross_attn"]["wo"].astype(dt),
                      "dp", "sp", None)
    h = rms_norm(x, p["ln2"].astype(dt), cfg.norm_eps)
    return x + apply_mlp(p["mlp"], h, gated=False), new_cache


def init_model(key, cfg: ModelConfig, dtype=jnp.float32):
    n_enc, n_dec = cfg.encoder_layers, cfg.num_layers
    keys = jax.random.split(key, n_enc + n_dec + 3)
    enc = [init_enc_block(keys[i], cfg, dtype) for i in range(n_enc)]
    dec = [init_dec_block(keys[n_enc + i], cfg, dtype) for i in range(n_dec)]
    return {
        "enc_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "embed": embed_init(keys[-1], (cfg.vocab_size, cfg.d_model), dtype),
        "ln_enc": jnp.ones((cfg.d_model,), dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(keys[-2], (cfg.d_model, cfg.vocab_size), 0, dtype),
    }


def encode(params, frames, cfg: ModelConfig):
    """frames: (B, F, D) precomputed frame embeddings (stub frontend)."""
    dt = jnp.dtype(cfg.dtype)
    x = frames.astype(dt) + sinusoidal_positions(frames.shape[1], cfg.d_model).astype(dt)
    x = constrain(x, "dp", "sp", None)
    block = partial(apply_enc_block, cfg=cfg)
    if cfg.remat:
        block = jax.checkpoint(block, policy=remat_policy(cfg))

    def body(h, p_l):
        return block(p_l, h), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rms_norm(x, params["ln_enc"].astype(dt), cfg.norm_eps)


def decode(params, memory, tokens, cfg: ModelConfig, *, positions=None, caches=None):
    dt = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    if positions is None:
        positions = jnp.arange(s)
    pe = sinusoidal_positions(s, cfg.d_model, offset=0).astype(dt)
    x = params["embed"].astype(dt)[tokens]
    if caches is None:
        x = x + pe
    else:
        x = x + jnp.take(
            sinusoidal_positions(65536, cfg.d_model).astype(dt), positions, axis=0
        )
    x = constrain(x, "dp", "sp", None)
    block = partial(apply_dec_block, cfg=cfg, memory=memory, positions=positions)
    if cfg.remat:
        block = jax.checkpoint(block, policy=remat_policy(cfg))

    def body(h, layer):
        p_l, c_l = layer
        h2, nc = block(p_l, h, cache=c_l)
        return h2, nc

    x, new_caches = jax.lax.scan(body, x, (params["dec_blocks"], caches))
    x = rms_norm(x, params["ln_f"].astype(dt), cfg.norm_eps)
    logits = constrain(x @ params["lm_head"].astype(dt), "dp", "sp", "tp")
    return logits, new_caches


def loss_fn(params, batch, cfg: ModelConfig):
    memory = encode(params, batch["frames"], cfg)
    logits, _ = decode(params, memory, batch["tokens"], cfg)
    return cross_entropy(logits, batch["labels"], batch.get("mask"))


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, jnp.bfloat16), "v": jnp.zeros(shape, jnp.bfloat16)}


def prefill(params, frames, tokens, cfg: ModelConfig, max_len: int):
    memory = encode(params, frames, cfg)
    caches = init_caches(cfg, tokens.shape[0], max_len)
    logits, caches = decode(params, memory, tokens, cfg,
                            positions=jnp.arange(tokens.shape[1]), caches=caches)
    return logits[:, -1:], {"kv": caches, "memory": memory}


def decode_step(params, state, token, pos, cfg: ModelConfig):
    positions = jnp.arange(1) + pos
    logits, kv = decode(params, state["memory"], token[:, None], cfg,
                        positions=positions, caches=state["kv"])
    return logits, {"kv": kv, "memory": state["memory"]}
