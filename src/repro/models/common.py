"""Shared layers: norms, RoPE, attention (naive + XLA-flash), MLP, inits.

Sharding is expressed through ``constrain(x, *axes)`` which applies a
``with_sharding_constraint`` when a mesh context is active (set by the
launcher / train step) and is a no-op otherwise, keeping model code mesh-
agnostic.  Axis vocabulary: "dp" (batch: pod+data), "tp" (model), None.
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Any

# -- mesh context -----------------------------------------------------------
_CTX: dict = {"mesh": None, "dp_axes": ("data",), "tp_axis": "model",
              "sp": False}


@contextmanager
def mesh_context(mesh, dp_axes=("data",), tp_axis="model", sp: bool = False):
    """``sp=True`` enables sequence parallelism: the residual stream's seq
    dim ("sp" in constraint vocabulary) shards over the model axis between
    blocks, cutting the layer-carry memory TP-fold."""
    old = dict(_CTX)
    _CTX.update(mesh=mesh, dp_axes=tuple(dp_axes), tp_axis=tp_axis, sp=sp)
    try:
        yield
    finally:
        _CTX.update(old)


def _resolve(axis: str | None):
    if axis == "dp":
        a = _CTX["dp_axes"]
        return a if len(a) > 1 else a[0]
    if axis == "tp":
        return _CTX["tp_axis"]
    if axis == "sp":
        return _CTX["tp_axis"] if _CTX["sp"] else None
    return None


def constrain(x: jax.Array, *axes: str | None) -> jax.Array:
    """Apply a named-axis sharding constraint if a mesh is active.

    Divisibility-safe: an axis whose mesh size does not divide the dim is
    dropped (replicated) so MQA heads, odd vocab etc. never hard-fail."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    names = []
    used: set = set()
    for i, a in enumerate(axes):
        r = _resolve(a)
        if r is not None:
            sizes = [r] if isinstance(r, str) else list(r)
            need = 1
            for s in sizes:
                need *= mesh.shape.get(s, 1)
            if x.shape[i] % need != 0 or any(s in used for s in sizes):
                r = None
            else:
                used.update(sizes)
        names.append(r)
    spec = P(*names)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec)
    )


def remat_policy(cfg):
    """Activation-checkpoint policy for the per-layer remat wrapper.

    Default: save nothing inside a block — the layer-scan carry already
    checkpoints every layer input, so live activations are O(L x tokens x D)
    instead of O(L x tokens x d_ff) (saved-dots blew v5e HBM at 4k x 256).
    """
    policy = getattr(cfg, "remat_policy", "full")
    if policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None  # save nothing; recompute the whole block in backward


# -- inits ------------------------------------------------------------------
def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# -- norms --------------------------------------------------------------------
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * weight


# -- rotary embeddings --------------------------------------------------------
def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (math.log(theta) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int, offset=0) -> jax.Array:
    pos = jnp.arange(offset, offset + seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim))
    pe = jnp.zeros((seq, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# -- attention ----------------------------------------------------------------
def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    # (B, T, KV, hd) -> (B, T, KV*groups, hd)
    if groups == 1:
        return k
    b, t, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, t, kv, groups, hd)).reshape(
        b, t, kv * groups, hd
    )


def naive_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: jax.Array | int = 0,
) -> jax.Array:
    """Reference attention.  q: (B,S,H,hd); k,v: (B,T,KV,hd).

    ``q_offset``: absolute position of q[0] (for decode: T-1 typically).
    ``window`` > 0: sliding-window (local) attention of that width.
    Materializes (S,T) scores — only for small shapes / oracles.
    """
    b, s, h, hd = q.shape
    kv = k.shape[2]
    k = _repeat_kv(k, h // kv)
    v = _repeat_kv(v, h // kv)
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    qpos = jnp.arange(s) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((s, k.shape[1]), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    # fp32 prob-value contraction: keeps decode (this path) bit-consistent
    # with the blockwise fp32 accumulation of flash_attention_xla.
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _flash_blocks(q, k, v, block_q, block_k):
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    bq = min(block_q, s)
    bk = min(block_k, t)
    nq = -(-s // bq)
    nk = -(-t // bk)
    pad_q = nq * bq - s
    pad_k = nk * bk - t
    qf = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kf = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vf = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    qb = qf.reshape(b, nq, bq, h, hd).transpose(1, 0, 2, 3, 4)
    kb = kf.reshape(b, nk, bk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vb = vf.reshape(b, nk, bk, kvh, hd).transpose(1, 0, 2, 3, 4)
    return qb, kb, vb, (bq, bk, nq, nk)


def _block_mask(qpos, kpos, t, causal, window):
    mask = kpos[None, :] < t
    if causal:
        mask = mask & (qpos[:, None] >= kpos[None, :])
    if window > 0:
        mask = mask & (kpos[None, :] > (qpos[:, None] - window))
    return mask


def _flash_fwd_impl(q, k, v, q_offset, causal, window, block_q, block_k):
    """Returns (out (B,S,H,hd), lse (B,H,S))."""
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    groups = h // kvh
    scale = 1.0 / math.sqrt(hd)
    qb, kb, vb, (bq, bk, nq, nk) = _flash_blocks(q, k, v, block_q, block_k)

    def q_block(carry, inp):
        qi, qblk = inp
        qpos = qi * bq + jnp.arange(bq) + q_offset

        def kv_block(state, kv_in):
            m, l, acc = state
            ki, kblk, vblk = kv_in
            kr = _repeat_kv(kblk, groups)
            vr = _repeat_kv(vblk, groups)
            sc = jnp.einsum("bqhd,bkhd->bhqk", qblk, kr).astype(jnp.float32) * scale
            kpos = ki * bk + jnp.arange(bk)
            mask = _block_mask(qpos, kpos, t, causal, window)
            sc = jnp.where(mask[None, None], sc, -1e30)
            m_new = jnp.maximum(m, sc.max(-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vr.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, h, bq), -1e30, jnp.float32),
            jnp.zeros((b, h, bq), jnp.float32),
            jnp.zeros((b, h, bq, hd), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_block, init, (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return carry, (out.astype(q.dtype), lse)

    _, (outs, lses) = jax.lax.scan(q_block, None, (jnp.arange(nq), qb))
    # outs: (nq, B, H, bq, hd) -> (B, S, H, hd)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, nq * bq, h, hd)
    lse = lses.transpose(1, 2, 0, 3).reshape(b, h, nq * bq)
    return out[:, :s], lse[:, :, :s]


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _flash_attention_xla(q, k, v, q_offset, causal, window, block_q, block_k):
    out, _ = _flash_fwd_impl(q, k, v, q_offset, causal, window, block_q, block_k)
    return out


def _flash_vjp_fwd(q, k, v, q_offset, causal, window, block_q, block_k):
    out, lse = _flash_fwd_impl(q, k, v, q_offset, causal, window, block_q, block_k)
    return out, (q, k, v, q_offset, out, lse)


def _flash_vjp_bwd(causal, window, block_q, block_k, res, g):
    """Recompute-based flash backward: O(S) memory, no saved probabilities.

    Outer scan over q blocks; dk/dv accumulate in an fp32 carry; for each
    block the probabilities are recomputed from (q, k, lse).
    """
    q, k, v, q_offset, out, lse = res
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    groups = h // kvh
    scale = 1.0 / math.sqrt(hd)
    qb, kb, vb, (bq, bk, nq, nk) = _flash_blocks(q, k, v, block_q, block_k)
    pad_q = nq * bq - s
    gp = jnp.pad(g, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else g
    op = jnp.pad(out, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else out
    gq = gp.reshape(b, nq, bq, h, hd).transpose(1, 0, 2, 3, 4)  # (nq,B,bq,H,hd)
    ob = op.reshape(b, nq, bq, h, hd).transpose(1, 0, 2, 3, 4)
    lseb = jnp.pad(lse, ((0, 0), (0, 0), (0, pad_q))).reshape(
        b, h, nq, bq).transpose(2, 0, 1, 3)                     # (nq,B,H,bq)
    delta = (gq.astype(jnp.float32) * ob.astype(jnp.float32)).sum(-1)
    delta = delta.transpose(0, 1, 3, 2)                         # (nq,B,H,bq)

    def q_block(carry, inp):
        dk_acc, dv_acc = carry                                # (B,KV,T',hd) f32
        qi, qblk, gblk, lse_i, d_i = inp

        qpos = qi * bq + jnp.arange(bq) + q_offset

        def kv_block(state, kv_in):
            dk_a, dv_a, dq_b = state
            ki, kblk, vblk = kv_in
            kr = _repeat_kv(kblk, groups)                     # (B,bk,H,hd)
            vr = _repeat_kv(vblk, groups)
            sc = jnp.einsum("bqhd,bkhd->bhqk", qblk, kr).astype(jnp.float32) * scale
            kpos = ki * bk + jnp.arange(bk)
            mask = _block_mask(qpos, kpos, t, causal, window)
            sc = jnp.where(mask[None, None], sc, -1e30)
            p = jnp.exp(sc - lse_i[..., None])                # (B,H,bq,bk)
            dp = jnp.einsum("bqhd,bkhd->bhqk", gblk, vr).astype(jnp.float32)
            ds = p * (dp - d_i[..., None]) * scale
            dv_h = jnp.einsum("bhqk,bqhd->bkhd", p.astype(gblk.dtype), gblk)
            dk_h = jnp.einsum("bhqk,bqhd->bkhd", ds.astype(qblk.dtype), qblk)
            # fold GQA groups back onto kv heads
            dv_g = dv_h.reshape(b, bk, kvh, groups, hd).sum(3)
            dk_g = dk_h.reshape(b, bk, kvh, groups, hd).sum(3)
            dk_a = jax.lax.dynamic_update_slice(
                dk_a, dk_a_slice_add(dk_a, dk_g, ki, bk), (0, ki * bk, 0, 0))
            dv_a = jax.lax.dynamic_update_slice(
                dv_a, dk_a_slice_add(dv_a, dv_g, ki, bk), (0, ki * bk, 0, 0))
            dq_b = dq_b + jnp.einsum("bhqk,bkhd->bqhd", ds.astype(kr.dtype), kr
                                     ).astype(jnp.float32)
            return (dk_a, dv_a, dq_b), None

        def dk_a_slice_add(acc, add, ki, bk_):
            cur = jax.lax.dynamic_slice(
                acc, (0, ki * bk_, 0, 0), (b, bk_, kvh, hd))
            return cur + add.astype(jnp.float32)

        dq0 = jnp.zeros((b, bq, h, hd), jnp.float32)
        (dk_acc, dv_acc, dq_b), _ = jax.lax.scan(
            kv_block, (dk_acc, dv_acc, dq0), (jnp.arange(nk), kb, vb))
        return (dk_acc, dv_acc), dq_b

    zero_kv = jnp.zeros((b, nk * bk, kvh, hd), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(
        q_block, (zero_kv, zero_kv), (jnp.arange(nq), qb, gq, lseb, delta))
    dq = dqs.transpose(1, 0, 2, 3, 4).reshape(b, nq * bq, h, hd)[:, :s]
    return (dq.astype(q.dtype), dk[:, :t].astype(k.dtype),
            dv[:, :t].astype(v.dtype), jnp.zeros_like(q_offset))


_flash_attention_xla.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention_xla(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: jax.Array | int = 0,
    block_q: int = 512,
    block_k: int = 1024,
) -> jax.Array:
    """Blockwise online-softmax attention in pure XLA with a recompute-based
    custom VJP (O(S) memory in both passes — naive autodiff through the scan
    would save the O(S^2) probability blocks)."""
    return _flash_attention_xla(
        q, k, v, jnp.asarray(q_offset, jnp.int32), causal, window,
        block_q, block_k,
    )


def attention(
    q, k, v, *, impl: str = "xla_flash", causal=True, window=0, q_offset=0
):
    if impl == "naive" or q.shape[1] == 1:
        return naive_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)
    if impl == "pallas":
        from repro.kernels import ops

        return ops.flash_attention(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset)
    return flash_attention_xla(q, k, v, causal=causal, window=window, q_offset=q_offset)


# -- MLP ----------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {
        "wi": dense_init(ks[0], (d_model, d_ff), 0, dtype),
        "wo": dense_init(ks[1], (d_ff, d_model), 0, dtype),
    }
    if gated:
        p["wg"] = dense_init(ks[2], (d_model, d_ff), 0, dtype)
    return p


def apply_mlp(p: Params, x: jax.Array, gated: bool) -> jax.Array:
    h = x @ p["wi"].astype(x.dtype)
    h = constrain(h, "dp", None, "tp")
    if gated:
        h = jax.nn.silu(x @ p["wg"].astype(x.dtype)) * h
    else:
        h = jax.nn.gelu(h)
    out = h @ p["wo"].astype(x.dtype)
    return constrain(out, "dp", "sp", None)


# -- losses ---------------------------------------------------------------------
@jax.custom_vjp
def _ce_from_logits(logits: jax.Array, labels: jax.Array, weights: jax.Array):
    """Token-weighted cross entropy; memory-lean VJP.

    Saves only (bf16 logits, per-token lse) and recomputes the softmax in
    the backward — plain autodiff keeps three fp32 (tokens x vocab) buffers
    (cast, exp, grad) live, which dominated HBM at 151k vocab."""
    nll, _ = _ce_fwd_impl(logits, labels)
    return (nll * weights).sum()


def _ce_fwd_impl(logits, labels):
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return lse - gold, lse


def _ce_vjp_fwd(logits, labels, weights):
    nll, lse = _ce_fwd_impl(logits, labels)
    return (nll * weights).sum(), (logits, labels, weights, lse)


def _ce_vjp_bwd(res, g):
    logits, labels, weights, lse = res
    p = jnp.exp(logits.astype(jnp.float32) - lse[..., None])
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    dlogits = (p - onehot) * (g * weights)[..., None]
    return dlogits.astype(logits.dtype), None, None


_ce_from_logits.defvjp(_ce_vjp_fwd, _ce_vjp_bwd)


def cross_entropy(logits: jax.Array, labels: jax.Array, mask=None) -> jax.Array:
    """logits (B,S,V); labels (B,S) int32; mean over valid tokens."""
    if mask is None:
        weights = jnp.full(labels.shape, 1.0 / labels.size, jnp.float32)
    else:
        m = mask.astype(jnp.float32)
        weights = m / jnp.maximum(m.sum(), 1.0)
    return _ce_from_logits(logits, labels, weights)
