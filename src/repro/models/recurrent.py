"""RecurrentGemma / Griffin: RG-LRU recurrent blocks + local attention.

Block pattern (rec, rec, attn) repeats; layers are scanned per period with
an unscanned tail for layer counts not divisible by the period (26 = 8x3+2).

RG-LRU (arXiv:2402.19427):
    i_t = sigmoid(W_x x_t),  r_t = sigmoid(W_a x_t)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
Training uses an associative scan (parallel prefix); decode carries h.
Local attention uses a sliding window (2048) with a ring-buffer cache, so a
500k-token decode holds O(window) state — the sub-quadratic long_500k path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tr
from repro.models.common import (
    apply_mlp,
    constrain,
    cross_entropy,
    dense_init,
    embed_init,
    init_mlp,
    remat_policy,
    rms_norm,
)

RGLRU_C = 8.0


# -- RG-LRU ------------------------------------------------------------------
def init_rec(key, cfg: ModelConfig, dtype=jnp.float32):
    d, r, cw = cfg.d_model, cfg.d_rnn, cfg.conv_width
    ks = jax.random.split(key, 6)
    return {
        "linear_y": dense_init(ks[0], (d, r), 0, dtype),
        "linear_x": dense_init(ks[1], (d, r), 0, dtype),
        "conv_w": dense_init(ks[2], (cw, r), 0, dtype),
        "w_input_gate": dense_init(ks[3], (r, r), 0, dtype),
        "w_a_gate": dense_init(ks[4], (r, r), 0, dtype),
        "lam": jnp.linspace(0.5, 4.0, r).astype(dtype),   # Lambda init spread
        "linear_out": dense_init(ks[5], (r, d), 0, dtype),
    }


def _rglru_coeffs(p, x):
    """x: (B,S,R) -> (a, b) of the linear recurrence h = a*h + b."""
    dt = x.dtype
    i = jax.nn.sigmoid(x @ p["w_input_gate"].astype(dt))
    r = jax.nn.sigmoid(x @ p["w_a_gate"].astype(dt))
    log_a = (-RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32))) * r.astype(
        jnp.float32
    )
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i.astype(jnp.float32) * x.astype(jnp.float32)
    )
    return a, b


def rglru_scan(p, x, h0=None):
    """Parallel linear recurrence over time.  x: (B,S,R); h0: (B,R) fp32."""
    a, b = _rglru_coeffs(p, x)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(p, x, h_prev):
    """Single decode step.  x: (B,1,R); h_prev: (B,R) fp32."""
    a, b = _rglru_coeffs(p, x)
    h = a[:, 0] * h_prev + b[:, 0]
    return h.astype(x.dtype)[:, None], h


def causal_conv1d(w, x):
    """Per-channel causal conv.  w: (CW,R), x: (B,S,R)."""
    cw = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(
        pad[:, k : k + x.shape[1]] * w[k].astype(x.dtype) for k in range(cw)
    )
    return out


def conv1d_step(w, x, conv_state):
    """x: (B,1,R); conv_state: (B,CW-1,R) of previous inputs."""
    hist = jnp.concatenate([conv_state, x], axis=1)       # (B,CW,R)
    out = jnp.einsum("bkr,kr->br", hist, w.astype(x.dtype))[:, None]
    return out, hist[:, 1:]


def apply_rec(p, x, cfg: ModelConfig, *, state=None):
    """Recurrent module.  x: (B,S,D) -> (B,S,D); state carries (h, conv)."""
    dt = x.dtype
    s = x.shape[1]
    y = jax.nn.gelu(x @ p["linear_y"].astype(dt))
    xr = x @ p["linear_x"].astype(dt)
    xr = constrain(xr, "dp", None, "tp")
    if state is None:
        xc = causal_conv1d(p["conv_w"], xr)
        h, _ = rglru_scan(p, xc)
        new_state = None
    elif s == 1:
        xc, conv_state = conv1d_step(p["conv_w"], xr, state["conv"])
        h, h_raw = rglru_step(p, xc, state["h"])
        new_state = {"h": h_raw, "conv": conv_state.astype(state["conv"].dtype)}
    else:
        # Prefill: scan the prompt from the carried state, emit final state.
        cw = cfg.conv_width
        hist = jnp.concatenate([state["conv"].astype(dt), xr], axis=1)
        xc = causal_conv1d(p["conv_w"], hist)[:, cw - 1:]
        h, h_final = rglru_scan(p, xc, h0=state["h"])
        new_state = {
            "h": h_final,
            "conv": hist[:, -(cw - 1):].astype(state["conv"].dtype),
        }
    out = (h * y) @ p["linear_out"].astype(dt)
    return constrain(out, "dp", None, None), new_state


def init_rec_state(cfg: ModelConfig, batch: int):
    return {
        "h": jnp.zeros((batch, cfg.d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_rnn), jnp.bfloat16),
    }


# -- blocks -------------------------------------------------------------------
def init_griffin_block(key, cfg: ModelConfig, kind: str, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"ln1": jnp.ones((cfg.d_model,), dtype), "ln2": jnp.ones((cfg.d_model,), dtype)}
    if kind == "rec":
        p["rec"] = init_rec(ks[0], cfg, dtype)
    else:
        p["attn"] = tr.init_attn(ks[0], cfg, dtype)
    p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, True, dtype)
    return p


def apply_griffin_block(p, x, cfg: ModelConfig, kind: str, *, positions, cache=None):
    h = rms_norm(x, p["ln1"].astype(x.dtype), cfg.norm_eps)
    if kind == "rec":
        out, new_cache = apply_rec(p["rec"], h, cfg, state=cache)
    else:
        out, new_cache = tr.apply_attn(
            p["attn"], h, cfg, positions=positions, cache=cache,
            window=cfg.local_window,
        )
    x = x + out
    h = rms_norm(x, p["ln2"].astype(x.dtype), cfg.norm_eps)
    x = x + apply_mlp(p["mlp"], h, gated=True)
    return x, new_cache


# -- model --------------------------------------------------------------------
def _layer_kinds(cfg: ModelConfig) -> list[str]:
    pat = cfg.block_pattern
    return [pat[i % len(pat)] for i in range(cfg.num_layers)]


def init_lm(key, cfg: ModelConfig, dtype=jnp.float32):
    pat = cfg.block_pattern
    period = len(pat)
    n_periods = cfg.num_layers // period
    tail_kinds = _layer_kinds(cfg)[n_periods * period:]
    keys = jax.random.split(key, cfg.num_layers + 2)
    periods = []
    for i in range(n_periods):
        slot = {}
        for j, kind in enumerate(pat):
            slot[f"s{j}_{kind}"] = init_griffin_block(
                keys[i * period + j], cfg, kind, dtype
            )
        periods.append(slot)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *periods) if periods else {}
    tail = [
        init_griffin_block(keys[n_periods * period + j], cfg, kind, dtype)
        for j, kind in enumerate(tail_kinds)
    ]
    return {
        "embed": embed_init(keys[-1], (cfg.vocab_size, cfg.d_model), dtype),
        "periods": stacked,
        "tail": tail,
        "ln_f": jnp.ones((cfg.d_model,), dtype),
    }


def _apply_period(slot_params, x, cfg, *, positions, caches=None):
    new_caches = {}
    for j, kind in enumerate(cfg.block_pattern):
        name = f"s{j}_{kind}"
        c = caches.get(name) if caches else None
        x, nc = apply_griffin_block(
            slot_params[name], x, cfg, kind, positions=positions, cache=c
        )
        if caches is not None:
            new_caches[name] = nc
    return x, (new_caches if caches is not None else None)


def forward(params, tokens, cfg: ModelConfig, *, caches=None, positions=None):
    dt = jnp.dtype(cfg.dtype)
    x = constrain(params["embed"].astype(dt)[tokens], "dp", None, None)
    if positions is None:
        positions = jnp.arange(x.shape[1])

    period_fn = partial(_apply_period, cfg=cfg, positions=positions)
    if cfg.remat:
        period_fn = jax.checkpoint(period_fn, policy=remat_policy(cfg))

    pc = caches["periods"] if caches is not None else None

    def body(h, layer):
        p_l, c_l = layer
        h2, nc = period_fn(p_l, h, caches=c_l)
        return h2, nc

    if params["periods"]:
        x, new_pc = jax.lax.scan(body, x, (params["periods"], pc))
    else:
        new_pc = pc
    new_tail = []
    tail_kinds = _layer_kinds(cfg)[len(_layer_kinds(cfg)) - len(params["tail"]):]
    for j, (p_l, kind) in enumerate(zip(params["tail"], tail_kinds)):
        c = caches["tail"][j] if caches is not None else None
        x, nc = apply_griffin_block(p_l, x, cfg, kind, positions=positions, cache=c)
        new_tail.append(nc)
    x = rms_norm(x, params["ln_f"].astype(dt), cfg.norm_eps)
    logits = x @ params["embed"].T.astype(dt)  # tied embeddings
    logits = constrain(logits, "dp", None, "tp")
    new_caches = (
        {"periods": new_pc, "tail": new_tail} if caches is not None else None
    )
    return logits, new_caches


def loss_fn(params, batch, cfg: ModelConfig):
    logits, _ = forward(params, batch["tokens"], cfg)
    return cross_entropy(logits, batch["labels"], batch.get("mask"))


def init_caches(cfg: ModelConfig, batch: int):
    """Decode caches: ring-buffer KV for attn layers, (h, conv) for rec."""
    pat = cfg.block_pattern
    period = len(pat)
    n_periods = cfg.num_layers // period
    w = cfg.local_window
    hd = cfg.resolved_head_dim

    def one(kind):
        if kind == "rec":
            return init_rec_state(cfg, batch)
        return {
            "k": jnp.zeros((batch, w, cfg.num_kv_heads, hd), jnp.bfloat16),
            "v": jnp.zeros((batch, w, cfg.num_kv_heads, hd), jnp.bfloat16),
            "pos": jnp.full((batch, w), -1, jnp.int32),
        }

    slot = {f"s{j}_{k}": one(k) for j, k in enumerate(pat)}
    periods = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_periods,) + x.shape).copy(), slot
    )
    tail_kinds = _layer_kinds(cfg)[n_periods * period:]
    tail = [one(k) for k in tail_kinds]
    return {"periods": periods, "tail": tail}


def prefill(params, tokens, cfg: ModelConfig, max_len: int):
    """Prefill: one pass with caches active — recurrent states scan through
    the prompt; window KV caches fill with the last ``window`` positions."""
    b, _ = tokens.shape
    caches = init_caches(cfg, b)
    logits, caches = forward(params, tokens, cfg, caches=caches)
    return constrain(logits[:, -1:], "dp", None, "tp"), caches


def decode_step(params, caches, token, pos, cfg: ModelConfig):
    positions = jnp.arange(1) + pos
    logits, new_caches = forward(params, token[:, None], cfg, caches=caches,
                                 positions=positions)
    return logits, new_caches
