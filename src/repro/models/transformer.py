"""Decoder-only transformer LM (dense / MoE / VLM backbones).

Functional-JAX: params are pytrees; layers are stacked along a leading axis
and applied with ``jax.lax.scan`` (O(1) HLO size in depth) with optional
rematerialization.  Serving path: prefill + single-token decode with a
static KV cache.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe as moe_mod
from repro.models.common import (
    apply_mlp,
    apply_rope,
    attention,
    constrain,
    cross_entropy,
    dense_init,
    embed_init,
    init_mlp,
    remat_policy,
    rms_norm,
)


# -- per-layer ---------------------------------------------------------------
def init_attn(key, cfg: ModelConfig, dtype=jnp.float32):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.num_heads * hd), 0, dtype),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.num_kv_heads * hd), 0, dtype),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.num_kv_heads * hd), 0, dtype),
        "wo": dense_init(ks[3], (cfg.num_heads * hd, cfg.d_model), 0, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    return p


def _impl(cfg: ModelConfig, override: str | None) -> str:
    if override:
        return override
    return "xla_flash" if cfg.attention_impl == "reference" else cfg.attention_impl


def apply_attn(
    p,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    cache: dict | None = None,
    window: int = 0,
    impl: str | None = None,
):
    """Returns (out, new_cache).  x: (B,S,D)."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    dt = x.dtype

    def proj(w, bias, nh):
        y = x @ p[w].astype(dt)
        if bias in p:
            y = y + p[bias].astype(dt)
        return y.reshape(b, s, nh, hd)

    q = proj("wq", "bq", cfg.num_heads)
    k = proj("wk", "bk", cfg.num_kv_heads)
    v = proj("wv", "bv", cfg.num_kv_heads)
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, "tp", None)
    v = constrain(v, "dp", None, "tp", None)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        pos0 = positions[0] if positions.ndim == 1 else positions[0, 0]
        if window > 0:
            if s == 1:
                # Ring-buffer single-token decode step.
                slot = pos0 % window
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
                cp = jax.lax.dynamic_update_slice(
                    cache["pos"],
                    jnp.broadcast_to(positions[None, :], (b, 1)).astype(
                        cache["pos"].dtype),
                    (0, slot))
                new_cache = {"k": ck, "v": cv, "pos": cp}
                out = _window_cache_attention(
                    q, ck.astype(dt), cv.astype(dt), cp, pos0, window)
            else:
                # Prefill: windowed attention over the prompt, then fill the
                # ring buffer with the last min(S, window) keys/values.
                out = attention(q, k, v, impl=_impl(cfg, impl), causal=True,
                                window=window, q_offset=pos0)
                wlen = min(s, window)
                slots = (positions[-wlen:]) % window
                ck = cache["k"].at[:, slots].set(k[:, -wlen:].astype(cache["k"].dtype))
                cv = cache["v"].at[:, slots].set(v[:, -wlen:].astype(cache["v"].dtype))
                cp = cache["pos"].at[:, slots].set(
                    jnp.broadcast_to(positions[-wlen:][None, :], (b, wlen)).astype(
                        cache["pos"].dtype))
                new_cache = {"k": ck, "v": cv, "pos": cp}
            out = out.reshape(b, s, cfg.num_heads * hd)
            out = out @ p["wo"].astype(dt)
            return constrain(out, "dp", "sp", None), new_cache
        if cfg.kv_quant:
            # int8 KV cache with per-(token, head) max-abs bf16 scales.
            kq, ks = _kv_quantize(k)
            vq, vs = _kv_quantize(v)
            ck = jax.lax.dynamic_update_slice(cache["k"], kq, (0, pos0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], vq, (0, pos0, 0, 0))
            cks = jax.lax.dynamic_update_slice(cache["k_scale"], ks,
                                               (0, pos0, 0))
            cvs = jax.lax.dynamic_update_slice(cache["v_scale"], vs,
                                               (0, pos0, 0))
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
            k = ck.astype(dt) * cks.astype(dt)[..., None]
            v = cv.astype(dt) * cvs.astype(dt)[..., None]
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, pos0, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, pos0, 0, 0))
            new_cache = {"k": ck, "v": cv}
            k, v = ck.astype(dt), cv.astype(dt)
        q_offset = pos0
    else:
        q_offset = positions[0] if positions.ndim == 1 else 0

    out = attention(
        q, k, v, impl=_impl(cfg, impl), causal=True, window=window,
        q_offset=q_offset,
    )
    out = out.reshape(b, s, cfg.num_heads * hd)
    out = out @ p["wo"].astype(dt)
    return constrain(out, "dp", "sp", None), new_cache


def _window_cache_attention(q, k, v, kpos, cur_pos, window):
    """Attention over a ring-buffer cache with absolute-position masking."""
    import math

    b, s, h, hd = q.shape
    kvh = k.shape[2]
    if h != kvh:
        from repro.models.common import _repeat_kv

        k = _repeat_kv(k, h // kvh)
        v = _repeat_kv(v, h // kvh)
    sc = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) / math.sqrt(hd)
    valid = (kpos[:, None, None, :] <= cur_pos) & (
        kpos[:, None, None, :] > cur_pos - window
    )
    sc = jnp.where(valid, sc, -1e30)
    pr = jax.nn.softmax(sc, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", pr, v)


def init_block(key, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attn(ks[0], cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
    }
    if cfg.family == "moe":
        p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)
    return p


def apply_block(p, x, cfg: ModelConfig, *, positions, cache=None):
    h, new_cache = apply_attn(
        p["attn"], rms_norm(x, p["ln1"].astype(x.dtype), cfg.norm_eps), cfg,
        positions=positions, cache=cache,
    )
    x = x + h
    h = rms_norm(x, p["ln2"].astype(x.dtype), cfg.norm_eps)
    if cfg.family == "moe":
        x = x + moe_mod.apply_moe(p["moe"], h, cfg)
    else:
        x = x + apply_mlp(p["mlp"], h, gated=cfg.gated_mlp)
    return x, new_cache


# -- model -------------------------------------------------------------------
def init_lm(key, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(key, cfg.num_layers + 3)
    blocks = [init_block(ks[i], cfg, dtype) for i in range(cfg.num_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    p = {
        "embed": embed_init(ks[-1], (cfg.vocab_size, cfg.d_model), dtype),
        "blocks": stacked,
        "ln_f": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[-2], (cfg.d_model, cfg.vocab_size), 0, dtype)
    return p


def _scan_blocks(params, x, cfg: ModelConfig, *, positions, caches=None):
    block = partial(apply_block, cfg=cfg, positions=positions)
    if cfg.remat:
        block = jax.checkpoint(block, policy=remat_policy(cfg))

    if caches is None:
        def body(h, p_l):
            h2, _ = block(p_l, h)
            return h2, None

        x, _ = jax.lax.scan(body, x, params["blocks"])
        return x, None

    def body(h, layer):
        p_l, cache_l = layer
        h2, new_cache = block(p_l, h, cache=cache_l)
        return h2, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
    return x, new_caches


def _embed(params, tokens, cfg, dt):
    x = params["embed"].astype(dt)[tokens]
    return constrain(x, "dp", "sp", None)


def _logits(params, x, cfg: ModelConfig):
    x = rms_norm(x, params["ln_f"].astype(x.dtype), cfg.norm_eps)
    head = params.get("lm_head", None)
    w = head if head is not None else params["embed"].T
    logits = x @ w.astype(x.dtype)
    return constrain(logits, "dp", "sp", "tp")


def forward(params, tokens, cfg: ModelConfig, *, extra_embeds=None):
    """tokens (B,S) -> logits (B,S',V).  ``extra_embeds`` (B,P,D) prepended
    (VLM patches); logits returned for the token positions only."""
    dt = jnp.dtype(cfg.dtype)
    x = _embed(params, tokens, cfg, dt)
    n_extra = 0
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(dt), x], axis=1)
        n_extra = extra_embeds.shape[1]
    positions = jnp.arange(x.shape[1])
    x, _ = _scan_blocks(params, x, cfg, positions=positions)
    if n_extra:
        x = x[:, n_extra:]
    return _logits(params, x, cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch["tokens"], cfg,
                     extra_embeds=batch.get("patches"))
    return cross_entropy(logits, batch["labels"], batch.get("mask"))


# -- serving ------------------------------------------------------------------
def _kv_quantize(x):
    """(B,S,KV,hd) -> (int8 values, bf16 per-(B,S,KV) scales)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-6) / 127.0
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, hd)
    if cfg.kv_quant:
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:-1], jnp.bfloat16),
            "v_scale": jnp.zeros(shape[:-1], jnp.bfloat16),
        }
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill(params, tokens, cfg: ModelConfig, max_len: int, *, extra_embeds=None):
    """Run the prompt, fill the cache; returns (last_logits, cache)."""
    dt = jnp.dtype(cfg.dtype)
    x = _embed(params, tokens, cfg, dt)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(dt), x], axis=1)
    b, s, _ = x.shape
    caches = init_cache(cfg, b, max_len)
    positions = jnp.arange(s)
    x, new_caches = _scan_blocks(params, x, cfg, positions=positions, caches=caches)
    logits = _logits(params, x[:, -1:], cfg)
    return logits, new_caches


def decode_step(params, caches, token, pos, cfg: ModelConfig):
    """One decode step.  token (B,) int32, pos scalar int32."""
    dt = jnp.dtype(cfg.dtype)
    x = _embed(params, token[:, None], cfg, dt)
    positions = jnp.arange(1) + pos
    x, new_caches = _scan_blocks(params, x, cfg, positions=positions, caches=caches)
    return _logits(params, x, cfg), new_caches
