"""Uniform model API over all assigned architectures.

``build_model(cfg)`` returns a ``ModelApi`` whose members are pure functions
suitable for jit/lower: ``init``, ``loss_fn(params, batch)``,
``prefill(params, batch)`` and ``decode_step(params, caches, token, pos)``.
``*_spec`` members produce ShapeDtypeStruct stand-ins for every input of the
given shape cell — the multi-pod dry-run lowers against these without
allocating anything.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import recurrent, transformer, whisper, xlstm

Params = Any
SDS = jax.ShapeDtypeStruct


def _tok(shape, dtype=jnp.int32):
    return SDS(shape, dtype)


@dataclass
class ModelApi:
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable            # (params, batch) -> scalar
    prefill: Callable            # (params, batch) -> (logits, caches)
    decode_step: Callable        # (params, caches, token, pos) -> (logits, caches)
    batch_spec: Callable         # (ShapeConfig) -> batch pytree of SDS
    decode_spec: Callable        # (ShapeConfig) -> (caches, token, pos) SDS

    def param_spec(self, seed: int = 0):
        return jax.eval_shape(lambda: self.init(jax.random.key(seed)))


def _lm_batch_spec(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": _tok((b, s)), "labels": _tok((b, s))}
    if cfg.family == "vlm":
        p = cfg.num_patches
        batch = {
            "tokens": _tok((b, s - p)),
            "labels": _tok((b, s - p)),
            "patches": SDS((b, p, cfg.d_model), jnp.bfloat16),
        }
    if cfg.family == "audio":
        batch = {
            "tokens": _tok((b, s)),
            "labels": _tok((b, s)),
            "frames": SDS((b, cfg.num_frames, cfg.d_model), jnp.bfloat16),
        }
    return batch


def build_model(cfg: ModelConfig) -> ModelApi:
    fam = cfg.family

    pdt = jnp.dtype(cfg.param_dtype)

    if fam in ("dense", "moe", "vlm"):
        def init(key):
            return transformer.init_lm(key, cfg, dtype=pdt)

        def loss(params, batch):
            return transformer.loss_fn(params, batch, cfg)

        def pf(params, batch):
            return transformer.prefill(
                params, batch["tokens"], cfg, batch["tokens"].shape[1]
                + (cfg.num_patches if fam == "vlm" else 0),
                extra_embeds=batch.get("patches"),
            )

        def dec(params, caches, token, pos):
            return transformer.decode_step(params, caches, token, pos, cfg)

        def dspec(shape: ShapeConfig):
            caches = jax.eval_shape(
                lambda: transformer.init_cache(cfg, shape.global_batch, shape.seq_len)
            )
            return caches, _tok((shape.global_batch,)), SDS((), jnp.int32)

    elif fam == "hybrid":
        def init(key):
            return recurrent.init_lm(key, cfg, dtype=pdt)

        def loss(params, batch):
            return recurrent.loss_fn(params, batch, cfg)

        def pf(params, batch):
            return recurrent.prefill(params, batch["tokens"], cfg,
                                     batch["tokens"].shape[1])

        def dec(params, caches, token, pos):
            return recurrent.decode_step(params, caches, token, pos, cfg)

        def dspec(shape: ShapeConfig):
            caches = jax.eval_shape(
                lambda: recurrent.init_caches(cfg, shape.global_batch)
            )
            return caches, _tok((shape.global_batch,)), SDS((), jnp.int32)

    elif fam == "ssm":
        def init(key):
            return xlstm.init_lm(key, cfg, dtype=pdt)

        def loss(params, batch):
            return xlstm.loss_fn(params, batch, cfg)

        def pf(params, batch):
            return xlstm.prefill(params, batch["tokens"], cfg,
                                 batch["tokens"].shape[1])

        def dec(params, caches, token, pos):
            return xlstm.decode_step(params, caches, token, pos, cfg)

        def dspec(shape: ShapeConfig):
            caches = jax.eval_shape(lambda: xlstm.init_caches(cfg, shape.global_batch))
            return caches, _tok((shape.global_batch,)), SDS((), jnp.int32)

    elif fam == "audio":
        def init(key):
            return whisper.init_model(key, cfg, dtype=pdt)

        def loss(params, batch):
            return whisper.loss_fn(params, batch, cfg)

        def pf(params, batch):
            return whisper.prefill(params, batch["frames"], batch["tokens"], cfg,
                                   batch["tokens"].shape[1])

        def dec(params, caches, token, pos):
            return whisper.decode_step(params, caches, token, pos, cfg)

        def dspec(shape: ShapeConfig):
            b = shape.global_batch
            kv = jax.eval_shape(lambda: whisper.init_caches(cfg, b, shape.seq_len))
            mem = SDS((b, cfg.num_frames, cfg.d_model), jnp.dtype(cfg.dtype))
            caches = {"kv": kv, "memory": mem}
            return caches, _tok((b,)), SDS((), jnp.int32)

    else:  # pragma: no cover
        raise ValueError(f"unknown family {fam}")

    return ModelApi(
        cfg=cfg,
        init=init,
        loss_fn=loss,
        prefill=pf,
        decode_step=dec,
        batch_spec=lambda shape: _lm_batch_spec(cfg, shape),
        decode_spec=dspec,
    )


def count_params(spec) -> int:
    import math

    return sum(math.prod(l.shape) for l in jax.tree.leaves(spec))
