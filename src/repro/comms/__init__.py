from repro.comms.hierarchical import (  # noqa: F401
    chunked_all_gather,
    chunked_all_reduce,
    chunked_reduce_scatter,
    chunked_reduce_scatter_int8,
    int8_reduce_scatter_axis,
)
from repro.comms.schedule_bridge import (  # noqa: F401
    collective_stats,
    predicted_axis_loads,
    themis_axis_orders,
    topology_from_axes,
)
