"""Chunked hierarchical collectives over mesh axes (inside shard_map).

The TPU-native realization of the paper's multi-rail hierarchical algorithm
(Sec. 2.3): an All-Reduce over D mesh axes is a pipeline of per-axis
Reduce-Scatters followed by All-Gathers in reverse order; the gradient
buffer is split into chunks and **each chunk carries its own axis order** —
the Themis schedule (Sec. 4).  Because a chunk's AG order is the reverse of
its RS order (Algorithm 1 line 8), `psum_scatter`/`all_gather` pairs invert
each other exactly and the element layout round-trips with no index
bookkeeping.

These functions must run inside a ``shard_map`` that is *manual* over every
axis in the chunk orders.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.launch.compat import axis_size_compat

AxisOrder = tuple[str, ...]


def world_size(axes: tuple[str, ...]) -> int:
    return math.prod(axis_size_compat(a) for a in axes)


def pad_to_chunks(flat: jax.Array, n_chunks: int, axes: tuple[str, ...]):
    """Pad a flat vector so it splits into n_chunks divisible by the world."""
    world = world_size(axes)
    n = flat.shape[0]
    per = -(-n // (n_chunks * world)) * world
    padded = jnp.pad(flat, (0, n_chunks * per - n))
    return padded.reshape(n_chunks, per), n


def chunked_reduce_scatter(
    chunks: jax.Array, orders: list[AxisOrder]
) -> list[jax.Array]:
    """chunks: (C, L) local addends -> list of C shards (L/world each).

    Chunk i is reduce-scattered along ``orders[i]`` axis-by-axis; the final
    shard this device owns is the nested (order-lexicographic) block.
    """
    out = []
    for i, order in enumerate(orders):
        y = chunks[i]
        for ax in order:
            y = jax.lax.psum_scatter(y, ax, scatter_dimension=0, tiled=True)
        out.append(y)
    return out


def chunked_all_gather(
    shards: list[jax.Array], orders: list[AxisOrder]
) -> jax.Array:
    """Inverse of ``chunked_reduce_scatter`` (AG order = reverse RS order)."""
    out = []
    for y, order in zip(shards, orders):
        for ax in reversed(order):
            y = jax.lax.all_gather(y, ax, axis=0, tiled=True)
        out.append(y)
    return jnp.stack(out)  # (C, L)


def chunked_all_reduce(
    flat: jax.Array, orders: list[AxisOrder], *, mean: bool = True
) -> jax.Array:
    """Themis/baseline-scheduled hierarchical All-Reduce of a flat buffer."""
    axes = tuple(orders[0])
    chunks, n = pad_to_chunks(flat, len(orders), axes)
    shards = chunked_reduce_scatter(chunks, orders)
    if mean:
        w = world_size(axes)
        shards = [s / w for s in shards]
    gathered = chunked_all_gather(shards, orders)
    return gathered.reshape(-1)[:n]


# -- int8-on-the-wire reduce-scatter (beyond paper: gradient compression) ----
def _quantize(x: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_reduce_scatter_axis(y: jax.Array, axis: str):
    """Reduce-scatter with int8 payload on the wire.

    psum_scatter would carry fp32; instead: quantize, all_to_all the int8
    shards, de-quantize with gathered scales, and reduce locally.  4x less
    wire traffic per hop at ~0.4% relative quantization error (compensated
    globally by error feedback in the optimizer wrapper).
    """
    a = axis_size_compat(axis)
    q, scale = _quantize(y)
    qs = q.reshape(a, -1)
    recv = jax.lax.all_to_all(qs, axis, split_axis=0, concat_axis=0, tiled=False)
    scales = jax.lax.all_gather(scale, axis)
    deq = recv.astype(jnp.float32) * scales[:, None]
    return deq.sum(0)


def chunked_reduce_scatter_int8(chunks, orders):
    out = []
    for i, order in enumerate(orders):
        y = chunks[i]
        for ax in order:
            y = int8_reduce_scatter_axis(y, ax)
        out.append(y)
    return out
