"""Bridge: Themis scheduler -> static JAX collective program; HLO audits.

JAX programs are compiled once and replayed, and the paper itself computes
schedules once and reuses them (Sec. 4.6.2) — so Themis's greedy pass runs
at *trace time*: we model the mesh axes as a Themis topology (ICI axes
innermost, DCN 'pod' axis outermost), run Algorithm 1 over the gradient
buffer, and emit the per-chunk axis orders that ``chunked_all_reduce``
bakes into the compiled program.

Also provides the HLO collective audit used by the dry-run/roofline: total
bytes moved by each collective category, and the per-axis load balance
(the paper's Dim-Load metric recovered statically from the compiled HLO).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict

from repro.core.latency_model import LatencyModel
from repro.core.requests import CollectiveRequest
from repro.core.scheduler import ThemisScheduler, baseline_order
from repro.topology import Phase, make_tpu_pod_topology
from repro.topology.topology import NetworkDim, Topology, GBPS, TopoKind


def topology_from_axes(axis_sizes: dict[str, int]) -> tuple[Topology, list[str]]:
    """Mesh axes -> Themis topology (dims innermost-first: model, data, pod).

    ICI axes: ring, 2 x 400 Gb/s links (~100 GB/s aggregate); pod axis: DCN
    NIC, 200 Gb/s.  Returns (topology, axis name per dim index).
    """
    order = [a for a in ("model", "data", "pod") if axis_sizes.get(a, 1) > 1]
    dims = []
    for a in order:
        if a == "pod":
            dims.append(NetworkDim(axis_sizes[a], TopoKind.SWITCH, 200.0, 1, 2e-5))
        else:
            dims.append(NetworkDim(axis_sizes[a], TopoKind.RING, 400.0, 2, 1e-6))
    return Topology("mesh", tuple(dims)), order


def themis_axis_orders(
    axis_sizes: dict[str, int],
    nbytes: float,
    n_chunks: int,
    policy: str = "themis",
) -> list[tuple[str, ...]]:
    """Per-chunk RS axis orders for a gradient All-Reduce of ``nbytes``."""
    topo, names = topology_from_axes(axis_sizes)
    if topo.num_dims == 0:
        return [()] * n_chunks
    if policy in ("baseline", "hier_baseline"):
        rs = [d for ph, d in baseline_order(topo.num_dims, "RS")]
        return [tuple(names[d] for d in rs)] * n_chunks
    sched = ThemisScheduler(LatencyModel.for_topology(topo),
                            policy if policy != "themis_scf" else "themis")
    chunks = sched.schedule_collective("AR", nbytes, n_chunks)
    orders = []
    for c in chunks:
        rs = [d for ph, d in c.schedule if ph == Phase.RS]
        orders.append(tuple(names[d] for d in rs))
    return orders


def themis_axis_orders_stream(
    axis_sizes: dict[str, int],
    bucket_bytes: list[float],
    n_chunks: int,
    policy: str = "themis",
    issue_times: list[float] | None = None,
) -> list[list[tuple[str, ...]]]:
    """Per-chunk RS axis orders for a *stream* of gradient-bucket ARs.

    Unlike :func:`themis_axis_orders` (one fused collective, tracker reset),
    this runs ONE incremental scheduler across the whole bucket stream
    (``schedule_request``): bucket k's chunk orders account for the residual
    dim loads of buckets 0..k-1 still in flight — the trace-time analogue of
    overlapping backprop collectives.  ``issue_times`` defaults to
    back-to-back issue (all 0.0, i.e. maximum residual contention).
    Returns one order list per bucket, each with ``n_chunks`` entries.
    """
    topo, names = topology_from_axes(axis_sizes)
    if topo.num_dims == 0:
        return [[()] * n_chunks for _ in bucket_bytes]
    if policy in ("baseline", "hier_baseline"):
        rs = [d for ph, d in baseline_order(topo.num_dims, "RS")]
        return [[tuple(names[d] for d in rs)] * n_chunks for _ in bucket_bytes]
    if issue_times is None:
        issue_times = [0.0] * len(bucket_bytes)
    sched = ThemisScheduler(
        LatencyModel.for_topology(topo),
        policy if policy != "themis_scf" else "themis")
    out: list[list[tuple[str, ...]] | None] = [None] * len(bucket_bytes)
    # schedule in issue order (the tracker clock only moves forward) while
    # returning orders indexed like the input buckets
    for i in sorted(range(len(bucket_bytes)), key=lambda i: (issue_times[i], i)):
        chunks = sched.schedule_request(
            CollectiveRequest("AR", bucket_bytes[i], issue_time=issue_times[i]),
            n_chunks)
        out[i] = [
            tuple(names[d] for ph, d in c.schedule if ph == Phase.RS)
            for c in chunks
        ]
    return out


def predicted_axis_loads(
    axis_sizes: dict[str, int], nbytes: float, orders: list[tuple[str, ...]]
) -> dict[str, float]:
    """Dim-Load-Tracker view of a chunk-order assignment (seconds/axis)."""
    topo, names = topology_from_axes(axis_sizes)
    lm = LatencyModel.for_topology(topo)
    idx = {n: i for i, n in enumerate(names)}
    loads = {n: 0.0 for n in names}
    per_chunk = nbytes / max(len(orders), 1)
    for order in orders:
        sched = [(Phase.RS, idx[a]) for a in order] + [
            (Phase.AG, idx[a]) for a in reversed(order)
        ]
        for d, secs in lm.calc_loads(per_chunk, sched).items():
            loads[names[d]] += secs
    return loads


# -- HLO audit ----------------------------------------------------------------
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[\w\[\],{}\s]+?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")


def _line_output_bytes(line: str) -> int:
    """Bytes of the op's result shape(s) — the data a collective moves."""
    lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1]
    head = line.strip()
    # shapes appear right after '=' and before the op name
    m = _OP_RE.search(head)
    if not m:
        return 0
    pre = head[: m.start(1)]
    total = 0
    for dt, dims in _SHAPE_RE.findall(pre):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum collective bytes by category and by replica-group size."""
    by_kind: dict[str, float] = defaultdict(float)
    by_group: dict[int, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or "-done" in line:
            continue
        kind = m.group(1)
        nbytes = _line_output_bytes(line)
        by_kind[kind] += nbytes
        counts[kind] += 1
        g = _GROUPS_RE.search(line)
        if g:
            size = len(g.group(1).split(","))
            by_group[size] += nbytes
    return {
        "bytes_by_kind": dict(by_kind),
        "bytes_by_group_size": dict(by_group),
        "op_counts": dict(counts),
        "total_bytes": float(sum(by_kind.values())),
    }
