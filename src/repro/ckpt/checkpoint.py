"""Fault-tolerant checkpointing: atomic, resumable, elastic.

* Atomic: write to ``<dir>/tmp-<step>``, fsync, rename to ``step-<n>`` and
  update ``MANIFEST.json`` last — a crash mid-write never corrupts the
  latest valid checkpoint.
* Resumable: the manifest records step, data-pipeline cursor, rng seed and
  a schedule fingerprint (manual-Themis opt layouts are schedule-dependent).
* Elastic: ``restore`` device_puts every leaf with the *target* shardings —
  a checkpoint taken on one mesh restores onto any other mesh/device count
  (reshard-on-load), which is the restart path after node failure or
  elastic rescaling.
* Async: ``save_async`` snapshots to host then writes in a background
  thread so the train loop is not blocked.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _paths(tree: Any) -> list[str]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, _ in flat:
        out.append("/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path))
    return out


def save(ckpt_dir: str, step: int, state: dict, *, extra: dict | None = None,
         keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp-{step}")
    final = os.path.join(ckpt_dir, f"step-{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(state)
    host = [np.asarray(x) for x in leaves]
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"leaf_{i}": a for i, a in enumerate(host)})
    meta = {
        "step": step,
        "num_leaves": len(host),
        "paths": _paths(state),
        "time": time.time(),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    os.replace(tmp, final)
    _update_manifest(ckpt_dir, step)
    _gc(ckpt_dir, keep)
    return final


def _update_manifest(ckpt_dir: str, step: int) -> None:
    manifest = os.path.join(ckpt_dir, "MANIFEST.json")
    tmp = manifest + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"latest_step": step}, f)
    os.replace(tmp, manifest)


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir) if d.startswith("step-")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    manifest = os.path.join(ckpt_dir, "MANIFEST.json")
    if not os.path.exists(manifest):
        return None
    with open(manifest) as f:
        step = json.load(f)["latest_step"]
    if os.path.exists(os.path.join(ckpt_dir, f"step-{step:08d}")):
        return step
    # manifest ahead of data (partial write) -> fall back to newest valid dir
    steps = sorted(
        int(d.split("-")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step-")
    )
    return steps[-1] if steps else None


def restore(ckpt_dir: str, state_like: Any, *, step: int | None = None,
            shardings: Any = None) -> tuple[Any, dict]:
    """Load into the structure of ``state_like``; reshard onto ``shardings``
    (a matching tree of NamedSharding, or None for default placement)."""
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step-{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    arrays = np.load(os.path.join(d, "arrays.npz"))
    leaves, treedef = _flatten(state_like)
    if len(leaves) != meta["num_leaves"]:
        raise ValueError(
            f"checkpoint has {meta['num_leaves']} leaves, expected {len(leaves)}"
        )
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings, is_leaf=lambda x: x is None)[0]
        if shardings is not None else [None] * len(leaves)
    )
    out = []
    for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
        a = arrays[f"leaf_{i}"]
        a = a.astype(ref.dtype) if hasattr(ref, "dtype") else a
        out.append(jax.device_put(a, sh) if sh is not None else jax.device_put(a))
    return jax.tree_util.tree_unflatten(treedef, out), meta["extra"]


class AsyncCheckpointer:
    """Snapshot-to-host then write in a background thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, state: dict, extra: dict | None = None):
        self.wait()
        host = jax.tree.map(np.asarray, state)  # snapshot before mutation

        def work():
            save(self.ckpt_dir, step, host, extra=extra, keep=self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
