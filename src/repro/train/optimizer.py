"""AdamW in pure JAX, with LR schedule and global-norm clipping.

Works on arbitrary pytrees *or* on flat ZeRO-sharded buffers (the manual
Themis path keeps m/v in the reduce-scattered layout).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def lr_schedule(cfg: TrainConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


def adamw_init(params: Any) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    import copy

    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree: Any, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


def adamw_update(
    grads: Any, state: dict, params: Any, cfg: TrainConfig
) -> tuple[Any, dict, jax.Array]:
    """Returns (new_params, new_state, lr)."""
    count = state["count"] + 1
    lr = lr_schedule(cfg, count)
    b1, b2, eps, wd = cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m2 / c1
        vhat = v2 / c2
        step = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m2, v2

    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_p = jax.tree.leaves(params)
    treedef = jax.tree.structure(params)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        p2, m2, v2 = upd(g, m, v, p)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    return (
        jax.tree.unflatten(treedef, new_p),
        {
            "m": jax.tree.unflatten(treedef, new_m),
            "v": jax.tree.unflatten(treedef, new_v),
            "count": count,
        },
        lr,
    )
