from repro.train.optimizer import adamw_init, adamw_update, lr_schedule  # noqa: F401
from repro.train.step import (  # noqa: F401
    gspmd_init_state,
    make_gspmd_train_step,
    make_themis_train_step,
    make_train_step,
)
