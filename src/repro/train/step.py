"""Train-step builders.

Two data-parallel gradient-sync modes:

* ``gspmd``  — production default for all architectures: single ``jax.jit``
  with GSPMD shardings; XLA inserts all collectives (TP/EP/FSDP included).
* ``themis`` / ``hier_baseline`` — the paper's technique as a first-class
  feature: the entire step runs in a ``shard_map`` manual over every mesh
  axis (pure-DP ZeRO-2).  Gradients are flattened, chunked, and
  reduce-scattered with per-chunk axis orders from the Themis scheduler
  (trace-time Algorithm 1); the sharded AdamW update runs on each device's
  scattered shard against fp32 master shards; updated parameters are
  all-gathered chunk-by-chunk in reverse order (bf16 on the wire).
  ``hier_baseline`` pins the static dim1->dimD order for every chunk
  (paper Sec. 2.3) — the reproduction baseline.  Optional int8-on-the-wire
  reduce-scatter with per-device error feedback.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.compat import axis_size_compat, shard_map_compat
from repro.comms.hierarchical import (
    _quantize,
    chunked_all_gather,
    chunked_reduce_scatter,
    chunked_reduce_scatter_int8,
)
from repro.comms.schedule_bridge import themis_axis_orders
from repro.configs.base import ParallelConfig, TrainConfig
from repro.models.common import mesh_context
from repro.models.registry import ModelApi, count_params
from repro.sharding.specs import batch_pspec, opt_state_pspec, param_shardings
from repro.train.optimizer import adamw_init, adamw_update, clip_by_global_norm, lr_schedule


# --------------------------------------------------------------------------
# GSPMD mode
# --------------------------------------------------------------------------
def make_gspmd_train_step(
    api: ModelApi, mesh: Mesh, parallel: ParallelConfig, tcfg: TrainConfig
):
    """Returns (jit_step, param_shardings, opt_shardings, batch_sharding_fn)."""
    pspec_tree = api.param_spec()
    p_shard = param_shardings(pspec_tree, mesh, parallel)

    def opt_shard_of(leaf_spec, ns):
        return NamedSharding(
            mesh, opt_state_pspec(ns.spec, leaf_spec.shape, mesh, parallel)
        )

    mv = jax.tree.map(opt_shard_of, pspec_tree, p_shard)
    o_shard = {"m": mv, "v": mv, "count": NamedSharding(mesh, P())}

    n_micro = max(tcfg.microbatch, 1)

    def grads_of(params, batch):
        """Gradient accumulation: scan over n_micro microbatches so live
        activations are O(batch / n_micro) (compute/comm overlap: the DP
        collectives of microbatch i overlap microbatch i+1's backward under
        XLA's async scheduler)."""
        if n_micro == 1:
            return jax.value_and_grad(lambda p: api.loss_fn(p, batch))(params)
        micro = jax.tree.map(
            lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
            batch,
        )

        def body(acc, mb):
            loss_i, g_i = jax.value_and_grad(
                lambda p: api.loss_fn(p, mb)
            )(params)
            acc_loss, acc_g = acc
            return (acc_loss + loss_i,
                    jax.tree.map(jnp.add, acc_g, g_i)), None

        zero = (jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params))
        (loss_sum, g_sum), _ = jax.lax.scan(body, zero, micro)
        inv = 1.0 / n_micro
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)

    def step(params, opt_state, batch):
        with mesh_context(mesh, sp=parallel.seq_sharding):
            loss, grads = grads_of(params, batch)
            grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
            new_params, new_opt, lr = adamw_update(grads, opt_state, params, tcfg)
            return new_params, new_opt, {"loss": loss, "gnorm": gnorm, "lr": lr}

    jit_step = jax.jit(
        step,
        in_shardings=(p_shard, o_shard, None),
        out_shardings=(p_shard, o_shard, None),
        donate_argnums=(0, 1),
    )

    def batch_shardings(batch_spec_tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, batch_pspec(s.shape, mesh, s.shape[0])),
            batch_spec_tree,
        )

    return jit_step, p_shard, o_shard, batch_shardings


def gspmd_init_state(api: ModelApi, mesh: Mesh, parallel: ParallelConfig,
                     seed: int = 0):
    """Initialize params + optimizer state directly into sharded buffers."""
    pspec_tree = api.param_spec()
    p_shard = param_shardings(pspec_tree, mesh, parallel)
    params = jax.jit(api.init, out_shardings=p_shard)(jax.random.key(seed))
    opt = adamw_init(params)
    return params, opt


# --------------------------------------------------------------------------
# Manual Themis ZeRO-2 mode (pure DP over every mesh axis)
# --------------------------------------------------------------------------
def _local_shard(y: jax.Array, order: tuple[str, ...]) -> jax.Array:
    """This device's nested block of a replicated chunk (zero-comm slicing
    matching the psum_scatter ownership for the given axis order)."""
    for ax in order:
        a = axis_size_compat(ax)
        i = jax.lax.axis_index(ax)
        ln = y.shape[0] // a
        y = jax.lax.dynamic_slice(y, (i * ln,), (ln,))
    return y


def make_themis_train_step(
    api: ModelApi, mesh: Mesh, parallel: ParallelConfig, tcfg: TrainConfig
):
    """ZeRO-2 DP step with Themis-scheduled chunked RS/AG.

    All mesh axes act as DP dims (a D-dim hierarchical collective — the
    paper's exact setting).  Returns (jit_step, init_state_fn, orders);
    opt m/v/master live in the reduce-scattered layout.
    """
    axes = tuple(a for a in ("model", "data", "pod") if mesh.shape.get(a, 1) > 1)
    axis_sizes = {a: mesh.shape[a] for a in axes}
    world = math.prod(axis_sizes.values())

    n_params = count_params(api.param_spec())
    n_chunks = parallel.chunks_per_collective
    policy = "themis" if parallel.dp_sync == "themis" else "baseline"
    orders = [tuple(o) for o in
              themis_axis_orders(axis_sizes, n_params * 4, n_chunks, policy)]

    per_chunk = -(-n_params // (n_chunks * world)) * world
    shard_len = per_chunk // world
    pad_total = n_chunks * per_chunk - n_params
    use_int8 = parallel.compression == "int8"

    dp_axes = axes if len(axes) > 1 else axes[0]
    shard_spec = P(None, dp_axes)  # (C, per_chunk) scattered layout

    def step_shard(params, master, m, v, count, err, batch):
        loss, grads = jax.value_and_grad(lambda p: api.loss_fn(p, batch))(params)
        flat, unravel = ravel_pytree(grads)
        flat = flat.astype(jnp.float32)
        new_err = err
        if use_int8:
            flat = flat + err[0]
            q, s = _quantize(flat)
            new_err = (flat - q.astype(jnp.float32) * s)[None]
        chunks = jnp.pad(flat, (0, pad_total)).reshape(n_chunks, per_chunk)
        rs = (chunked_reduce_scatter_int8 if use_int8 else chunked_reduce_scatter)(
            chunks, orders
        )
        g_shard = jnp.stack(rs) / world                        # (C, shard_len)

        # global-norm clip across the scattered shards
        sq = jnp.sum(jnp.square(g_shard))
        for a in axes:
            sq = jax.lax.psum(sq, a)
        gnorm = jnp.sqrt(sq)
        g_shard = g_shard * jnp.minimum(1.0, tcfg.grad_clip / jnp.maximum(gnorm, 1e-9))

        # ZeRO-2 AdamW on fp32 master shards
        count2 = count + 1
        lr = lr_schedule(tcfg, count2)
        b1, b2 = tcfg.beta1, tcfg.beta2
        c1 = 1.0 - b1 ** count2.astype(jnp.float32)
        c2 = 1.0 - b2 ** count2.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g_shard
        v2 = b2 * v + (1 - b2) * jnp.square(g_shard)
        upd = (m2 / c1) / (jnp.sqrt(v2 / c2) + tcfg.eps) + tcfg.weight_decay * master
        master2 = master - lr * upd

        # all-gather updated params (compute dtype on the wire)
        p_dtype = jax.tree.leaves(params)[0].dtype
        gathered = chunked_all_gather(
            [master2[i].astype(p_dtype) for i in range(n_chunks)], orders
        )
        new_params = unravel(gathered.reshape(-1)[:n_params])
        for a in axes:
            loss = jax.lax.pmean(loss, a)
        return (new_params, master2, m2, v2, count2, new_err,
                {"loss": loss, "gnorm": gnorm, "lr": lr})

    err_spec = P(dp_axes, None) if use_int8 else P()
    shard_step = shard_map_compat(
        step_shard,
        mesh=mesh,
        in_specs=(P(), shard_spec, shard_spec, shard_spec, P(), err_spec,
                  P(dp_axes)),
        out_specs=(P(), shard_spec, shard_spec, shard_spec, P(), err_spec, P()),
        check=False,
    )

    def step(params, opt_state, batch):
        new_p, master2, m2, v2, c2, err2, metrics = shard_step(
            params, opt_state["master"], opt_state["m"], opt_state["v"],
            opt_state["count"], opt_state["err"], batch,
        )
        return new_p, {"master": master2, "m": m2, "v": v2, "count": c2,
                       "err": err2}, metrics

    def init_state(seed: int = 0):
        params = api.init(jax.random.key(seed))
        flat, _ = ravel_pytree(params)

        def build_master(pf):
            chunks = jnp.pad(pf.astype(jnp.float32), (0, pad_total)).reshape(
                n_chunks, per_chunk)
            return jnp.stack([_local_shard(chunks[i], orders[i])
                              for i in range(n_chunks)])

        master = jax.jit(
            shard_map_compat(build_master, mesh=mesh, in_specs=P(),
                          out_specs=shard_spec, check=False)
        )(flat)
        zeros = jnp.zeros_like(master)
        if use_int8:
            err = jax.device_put(
                jnp.zeros((world, n_params), jnp.float32),
                NamedSharding(mesh, P(dp_axes, None)))
        else:
            err = jnp.zeros((), jnp.float32)
        opt = {"master": master, "m": zeros, "v": jnp.copy(zeros),
               "count": jnp.zeros((), jnp.int32), "err": err}
        return params, opt

    jit_step = jax.jit(step, donate_argnums=(1,))
    return jit_step, init_state, orders


def make_train_step(api, mesh, parallel: ParallelConfig, tcfg: TrainConfig):
    if parallel.dp_sync == "gspmd":
        return make_gspmd_train_step(api, mesh, parallel, tcfg)
    return make_themis_train_step(api, mesh, parallel, tcfg)
