"""GPipe-style pipeline parallelism over a mesh axis (optional feature).

Stage s holds its slice of the layer stack; microbatches stream through
``collective_permute`` boundary transfers inside a ``shard_map`` manual
over the "pipe" axis.  Autodiff flows through the permutes (their transpose
is the reversed permute), giving 1F1B-equivalent semantics under XLA's
scheduler.  Demonstrated on the dense decoder family; intended for
cross-pod pipelining where DCN latency would dominate an FSDP/TP layout.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.compat import axis_size_compat, shard_map_compat
from repro.models import transformer as tr
from repro.models.common import cross_entropy, rms_norm


def pipeline_forward(params, tokens, cfg: ModelConfig, *, n_micro: int,
                     axis: str = "pipe"):
    """Runs inside shard_map manual over ``axis``.

    params: this stage's slice — blocks (L/S, ...) plus embed/head
    (replicated; stage 0 embeds, last stage projects logits).
    tokens: (B, S) local copy (replicated over the pipe axis).
    Returns per-token logits computed on the last stage (other stages
    return zeros — the loss is psum'd over the axis).
    """
    stage = jax.lax.axis_index(axis)
    n_stage = axis_size_compat(axis)
    dt = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    assert b % n_micro == 0
    mb = b // n_micro
    positions = jnp.arange(s)

    def run_stage(x_in, mtokens):
        h = jnp.where(stage == 0,
                      params["embed"].astype(dt)[mtokens], x_in)

        def body(carry, p_l):
            out, _ = tr.apply_block(p_l, carry, cfg, positions=positions)
            return out, None

        h, _ = jax.lax.scan(body, h, params["blocks"])
        return h

    # microbatch loop: ring-advance activations stage->stage+1
    micro = tokens.reshape(n_micro, mb, s)
    perm = [(i, (i + 1) % n_stage) for i in range(n_stage)]

    def step(carry, mtok):
        x_prev = carry                      # activation arriving from stage-1
        h = run_stage(x_prev, mtok)
        x_next = jax.lax.ppermute(h, axis, perm)
        return x_next, h

    x0 = jnp.zeros((mb, s, cfg.d_model), dt)
    # n_stage warmup cycles: every microbatch must traverse all stages.
    outs = []
    carry = x0
    for m in range(n_micro + n_stage - 1):
        mtok = micro[jnp.minimum(m, n_micro - 1)]
        carry, h = step(carry, mtok)
        outs.append(h)
    # last-stage outputs for microbatch m appear at cycle m + n_stage - 1
    hs = jnp.stack(outs[n_stage - 1:])       # (n_micro, mb, s, D)
    hs = hs.reshape(b, s, cfg.d_model)
    x = rms_norm(hs, params["ln_f"].astype(dt), cfg.norm_eps)
    logits = x @ params["lm_head"].astype(dt)
    return logits


def make_pipeline_loss(cfg: ModelConfig, mesh: Mesh, n_micro: int,
                       axis: str = "pipe"):
    """(stage_params, tokens, labels) -> scalar loss; shard_map'd."""

    def loss_shard(params, tokens, labels):
        n_stage = axis_size_compat(axis)
        stage = jax.lax.axis_index(axis)
        logits = pipeline_forward(params, tokens, cfg, n_micro=n_micro,
                                  axis=axis)
        l = cross_entropy(logits, labels)
        # only the last stage's logits are meaningful
        l = jnp.where(stage == n_stage - 1, l, 0.0)
        return jax.lax.psum(l, axis)

    return shard_map_compat(
        loss_shard, mesh=mesh,
        in_specs=({"embed": P(), "blocks": P(axis), "ln_f": P(),
                   "lm_head": P()}, P(), P()),
        out_specs=P(),
        check=False,
    )


def stage_split_params(params, n_stage: int):
    """Split a full LM param tree into per-stage stacked block slices."""
    blocks = params["blocks"]
    total = jax.tree.leaves(blocks)[0].shape[0]
    assert total % n_stage == 0
    return {
        "embed": params["embed"],
        "blocks": blocks,          # sharded over the pipe axis by in_specs
        "ln_f": params["ln_f"],
        "lm_head": params.get("lm_head", params["embed"].T),
    }
