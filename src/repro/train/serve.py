"""Serving-step builders: jit'd prefill + decode with GSPMD shardings."""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ParallelConfig, ShapeConfig
from repro.models.common import mesh_context
from repro.models.registry import ModelApi
from repro.sharding.specs import batch_pspec, cache_pspec, param_shardings, tree_shardings


def make_serve_fns(api: ModelApi, mesh: Mesh, parallel: ParallelConfig,
                   shape: ShapeConfig):
    """Returns (jit_prefill, jit_decode, shardings dict)."""
    p_shard = param_shardings(api.param_spec(), mesh, parallel)
    gb = shape.global_batch

    caches_spec, token_spec, pos_spec = api.decode_spec(shape)
    cache_shard = tree_shardings(
        caches_spec, mesh, lambda path, s: cache_pspec(path, s, mesh, gb)
    )
    token_shard = NamedSharding(mesh, batch_pspec(token_spec.shape, mesh, gb))

    def prefill(params, batch):
        with mesh_context(mesh):
            return api.prefill(params, batch)

    def decode(params, caches, token, pos):
        with mesh_context(mesh):
            return api.decode_step(params, caches, token, pos)

    jit_prefill = jax.jit(
        prefill, in_shardings=(p_shard, None),
        out_shardings=(None, cache_shard),
    )
    jit_decode = jax.jit(
        decode,
        in_shardings=(p_shard, cache_shard, token_shard, NamedSharding(mesh, P())),
        out_shardings=(None, cache_shard),
        donate_argnums=(1,),
    )
    return jit_prefill, jit_decode, {
        "params": p_shard, "caches": cache_shard, "token": token_shard,
    }
