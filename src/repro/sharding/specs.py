"""GSPMD partition rules: parameters, optimizer states, batches, caches.

Rules are name+rank based and *divisibility-safe*: an axis is only assigned
if the dim is divisible by the mesh-axis size (GSPMD could pad, but we keep
layouts exact so memory analysis is truthful).  Policy:

  * TP ("model"): last dim of input projections (wq/wk/wv/wi/wg/up/gates),
    first weight dim of output projections (wo/down/out); vocab dim of the
    embedding; expert dim of MoE expert stacks (expert parallelism).
  * FSDP ("data", optional): the complementary weight dim — XLA inserts
    just-in-time all-gathers (ZeRO-3-style storage sharding).
  * Optimizer states inherit the param spec; with ``zero >= 1`` an extra
    "data" axis is added to the largest unsharded dim (ZeRO-1).
  * Batches shard (pod, data) over the batch dim; KV caches shard batch +
    heads (or head_dim when head count is indivisible, e.g. MQA).
"""
from __future__ import annotations

import math
import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig

IN_PROJ = re.compile(
    r"(wq|wk|wv|wi|wg|w_up|linear_x|linear_y|w_gates|router|w_i|w_f|r_gates)$"
)
OUT_PROJ = re.compile(r"(wo|w_down|linear_out|w_out)$")
BIAS = re.compile(r"(bq|bk|bv)$")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape.get(name, 1)


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    sizes = [axis] if isinstance(axis, str) else list(axis)
    need = math.prod(_axis_size(mesh, a) for a in sizes)
    return dim % need == 0 and dim >= need


def _spec(shape, mesh, assign: dict[int, Any]) -> P:
    """assign: dim index (negative ok) -> axis name; divisibility-checked."""
    out = [None] * len(shape)
    for di, ax in assign.items():
        i = di % len(shape)
        if _fits(shape[i], mesh, ax):
            out[i] = ax
    return P(*out)


def param_pspec(path_str: str, shape: tuple[int, ...], mesh: Mesh,
                parallel: ParallelConfig) -> P:
    fsdp = "data" if parallel.fsdp else None
    name = path_str.rsplit("/", 1)[-1]
    rank = len(shape)

    if name == "embed":
        return _spec(shape, mesh, {0: "model", 1: fsdp})
    if name == "lm_head":
        return _spec(shape, mesh, {0: fsdp, 1: "model"})
    if "moe" in path_str and name in ("wi", "wg") and rank >= 3:
        # (..., E, D, F): expert parallelism + FSDP on d_model
        return _spec(shape, mesh, {-3: "model", -2: fsdp})
    if "moe" in path_str and name == "wo" and rank >= 3:
        return _spec(shape, mesh, {-3: "model", -1: fsdp})
    if BIAS.match(name):
        return _spec(shape, mesh, {-1: "model"})
    if IN_PROJ.search(name) and rank >= 2:
        return _spec(shape, mesh, {-1: "model", -2: fsdp})
    if OUT_PROJ.search(name) and rank >= 2:
        return _spec(shape, mesh, {-2: "model", -1: fsdp})
    return P(*([None] * rank))


def param_shardings(param_spec_tree, mesh: Mesh, parallel: ParallelConfig):
    """Tree of NamedSharding matching a tree of ShapeDtypeStructs."""

    def rule(path, leaf):
        ps = param_pspec(_path_str(path), leaf.shape, mesh, parallel)
        return NamedSharding(mesh, ps)

    return jax.tree_util.tree_map_with_path(rule, param_spec_tree)


def opt_state_pspec(pspec: P, shape, mesh: Mesh, parallel: ParallelConfig) -> P:
    """ZeRO-1: add a "data" axis to the largest unsharded dim if possible."""
    if parallel.zero < 1 or parallel.fsdp:
        return pspec  # fsdp already shards over data
    used = set()
    for e in pspec:
        if e is None:
            continue
        used.update([e] if isinstance(e, str) else list(e))
    if "data" in used:
        return pspec
    dims = list(pspec) + [None] * (len(shape) - len(pspec))
    # largest unsharded, divisible dim
    cands = [i for i in range(len(shape))
             if dims[i] is None and _fits(shape[i], mesh, "data")]
    if not cands:
        return pspec
    i = max(cands, key=lambda j: shape[j])
    dims[i] = "data"
    return P(*dims)


def batch_pspec(shape: tuple[int, ...], mesh: Mesh, global_batch: int) -> P:
    dp = tuple(a for a in ("pod", "data") if _axis_size(mesh, a) > 1)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    out = [None] * len(shape)
    for i, d in enumerate(shape):
        if d == global_batch and _fits(d, mesh, dp) and dp is not None:
            out[i] = dp
            break
    return P(*out)


def cache_pspec(path_str: str, shape, mesh: Mesh, global_batch: int) -> P:
    """KV caches / recurrent states: batch over dp, heads/hd over model."""
    spec = list(batch_pspec(shape, mesh, global_batch))
    name = path_str.rsplit("/", 1)[-1]
    if name in ("k", "v") and len(shape) >= 4:
        # (..., T, KV, hd)
        if _fits(shape[-2], mesh, "model") and shape[-2] > 1:
            spec[-2] = "model"
        elif _fits(shape[-1], mesh, "model"):
            spec[-1] = "model"
    elif name in ("C", "n", "h", "conv", "memory") and len(shape) >= 2:
        if spec[-1] is None and _fits(shape[-1], mesh, "model") and shape[-1] >= 64:
            spec[-1] = "model"
    return P(*spec)


def tree_shardings(spec_tree, mesh: Mesh, rule):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, rule(_path_str(path), leaf.shape)),
        spec_tree,
    )
