from repro.sharding.specs import (  # noqa: F401
    batch_pspec,
    cache_pspec,
    opt_state_pspec,
    param_pspec,
    param_shardings,
    tree_shardings,
)
