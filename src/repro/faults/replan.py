"""Graceful-degradation re-planning against a faulted fabric.

Themis's whole objective is load balance *against each dim's bandwidth* —
so when a fault changes a dim's effective BW mid-run, the chunk orders
computed for the healthy fabric are no longer balanced (a chunk that
fronts its ReduceScatter on a now-slow dim carries ~P x more wire bytes
over it than one that defers the dim to the end of the order).  The
re-planner recomputes the paper's objective on a *degraded topology*:
the same fabric with each dim's ``link_gbps`` scaled by the fault
timeline's current per-dim factor (fully-out dims clamped to a tiny
floor so the greedy scheduler steers everything it can away from them).

``make_replanner`` builds the closure the engines call at fault
boundaries; the heavy lifting is
:meth:`repro.core.scheduler.ThemisScheduler.replan_degraded`, which
re-plans only the **un-issued** chunks of **pending** (not-yet-started)
request groups — in-flight work is never rewritten, so conservation
invariants keep holding.  The hook is deterministic and consumes no RNG,
which keeps the two engines in lockstep.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Sequence

from repro.topology import Topology


def degraded_topology(base: Topology, factors: Sequence[float], *,
                      floor: float = 1e-6,
                      name: str | None = None) -> Topology:
    """``base`` with each dim's ``link_gbps`` scaled by ``factors[d]``.

    Fully-out dims (factor 0) are clamped to ``floor`` x nominal rather
    than zero: the latency model needs finite rates, and a near-zero BW
    makes the scheduler's water-filling push all movable load onto the
    surviving dims — which is exactly the re-planning objective.
    """
    if len(factors) != base.num_dims:
        raise ValueError(
            f"factors must have one entry per dim "
            f"({len(factors)} != {base.num_dims})")
    dims = []
    for d, f in zip(base.dims, factors):
        if not 0.0 <= f <= 1.0:
            raise ValueError(f"BW factor {f!r} out of range [0, 1]")
        dims.append(replace(d, link_gbps=d.link_gbps * max(f, floor)))
    label = name or f"{base.name}@degraded"
    return Topology(label, tuple(dims))


def make_replanner(topology: Topology, policy: str = "themis", *,
                   bw_floor: float = 1e-6):
    """Build the graceful-degradation hook for ``simulate(replanner=...)``.

    The returned callable has the engine-facing signature
    ``replanner(now, factors, pending) -> {group_id: chunks}`` where
    ``pending`` is ``[(group_id, issue_time, chunks), ...]`` in issue
    order and ``factors`` is the current per-dim BW multiplier vector.
    """
    from repro.core.latency_model import LatencyModel
    from repro.core.scheduler import ThemisScheduler

    base = ThemisScheduler(LatencyModel.for_topology(topology), policy)

    def replanner(now, factors, pending):
        return base.replan_degraded(pending, factors, bw_floor=bw_floor)

    return replanner
