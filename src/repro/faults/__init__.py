"""Fault-injection fabric: deterministic fault timelines, outage retry
semantics, and Themis re-planning under degraded bandwidth.

See :mod:`repro.faults.schedule` for the timeline model and
:mod:`repro.faults.replan` for the graceful-degradation hook.
"""
from repro.faults.replan import degraded_topology, make_replanner
from repro.faults.schedule import (
    BwDegradation,
    CompiledFaults,
    DimOutage,
    FaultBoundary,
    FaultSchedule,
    LinkFlap,
    RetryPolicy,
    StragglerBurst,
)

__all__ = [
    "BwDegradation",
    "CompiledFaults",
    "DimOutage",
    "FaultBoundary",
    "FaultSchedule",
    "LinkFlap",
    "RetryPolicy",
    "StragglerBurst",
    "degraded_topology",
    "make_replanner",
]
