"""Deterministic fault timelines for the simulation engines.

A :class:`FaultSchedule` is a declarative, seed-free description of what
goes wrong on the fabric and when: per-dim bandwidth degradation windows,
full dim outages, periodic link flaps (a train of short outages), and
NPU-straggler bursts that layer an *extra* lognormal sigma on top of the
PR-5 ``straggler_sigma`` baked into the topology.  The schedule itself is
pure data — frozen, hashable (so it can ride inside a frozen
:class:`repro.core.batch.Scenario`) and engine-agnostic.

``compile(num_dims)`` validates the schedule against a concrete topology
(dims in range, no overlapping windows of the same family on one dim) and
lowers it to a sorted list of :class:`FaultBoundary` *value-change events*
— the only representation the engines consume.  Each boundary carries the
dim's new (factor, sigma) state plus three precomputed transition flags,
so the engine event loops never re-derive float comparisons in the hot
path:

  * ``bw_change``  — the BW factor changed (includes to/from an outage);
  * ``down_start`` — the dim just went fully out (factor -> 0);
  * ``down_end``   — the dim just recovered (factor 0 -> up).

Outages use the :class:`RetryPolicy` attached to the schedule: a queued
collective chunk on a fully-out dim times out after ``timeout_s``, retries
with exponential backoff (jittered from the *simulation's* RNG stream, so
runs stay reproducible and both engines stay in lockstep), and after
``max_attempts`` the whole request group is marked failed
(``SimResult.failed_groups``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import NamedTuple, Union


def _check_window(name: str, start: float, end: float) -> None:
    if math.isnan(start) or math.isnan(end):
        raise ValueError(f"{name}: NaN window bound (start={start!r}, "
                         f"end={end!r})")
    if start < 0:
        raise ValueError(f"{name}: negative start time {start!r} "
                         "(fault times are simulation seconds >= 0)")
    if end <= start:
        raise ValueError(f"{name}: empty or inverted window "
                         f"[{start!r}, {end!r}) — end must exceed start")


@dataclass(frozen=True)
class BwDegradation:
    """Dim ``dim`` runs at ``factor`` x its nominal BW over [start, end)."""

    dim: int
    start: float
    end: float
    factor: float

    def __post_init__(self) -> None:
        _check_window("BwDegradation", self.start, self.end)
        if not (0.0 < self.factor <= 1.0) or math.isnan(self.factor):
            raise ValueError(
                f"BwDegradation: factor {self.factor!r} out of range "
                "(0, 1] — use DimOutage for a fully-out dim")

    def bw_windows(self):
        yield (self.start, self.end, self.factor)

    def sigma_windows(self):
        return ()


@dataclass(frozen=True)
class DimOutage:
    """Dim ``dim`` is fully out (no service starts, in-flight work cut and
    requeued under the retry policy) over [start, end).  ``end`` may be
    ``math.inf`` for a permanent outage."""

    dim: int
    start: float
    end: float = math.inf

    def __post_init__(self) -> None:
        _check_window("DimOutage", self.start, self.end)

    def bw_windows(self):
        yield (self.start, self.end, 0.0)

    def sigma_windows(self):
        return ()


@dataclass(frozen=True)
class LinkFlap:
    """A train of ``count`` short outages on ``dim``: down for ``down_s``
    at ``start + i * period_s`` for i in 0..count-1."""

    dim: int
    start: float
    down_s: float
    period_s: float
    count: int

    def __post_init__(self) -> None:
        if math.isnan(self.start) or self.start < 0:
            raise ValueError(f"LinkFlap: bad start time {self.start!r}")
        if not self.down_s > 0 or math.isnan(self.down_s):
            raise ValueError(f"LinkFlap: down_s {self.down_s!r} must be > 0")
        if self.period_s < self.down_s or math.isnan(self.period_s):
            raise ValueError(
                f"LinkFlap: period_s {self.period_s!r} must be >= down_s "
                f"{self.down_s!r} (flap windows may not overlap)")
        if self.count < 1:
            raise ValueError(f"LinkFlap: count {self.count!r} must be >= 1")

    def bw_windows(self):
        for i in range(self.count):
            t0 = self.start + i * self.period_s
            yield (t0, t0 + self.down_s, 0.0)

    def sigma_windows(self):
        return ()


@dataclass(frozen=True)
class StragglerBurst:
    """Extra lognormal straggler noise on ``dim`` over [start, end):
    service times drawn in the window are multiplied by an additional
    ``lognormvariate(0, sigma)`` on top of the topology's baseline
    ``straggler_sigma`` (the PR-5 DCN model)."""

    dim: int
    start: float
    end: float
    sigma: float

    def __post_init__(self) -> None:
        _check_window("StragglerBurst", self.start, self.end)
        if not self.sigma > 0 or math.isnan(self.sigma):
            raise ValueError(
                f"StragglerBurst: sigma {self.sigma!r} must be > 0")

    def bw_windows(self):
        return ()

    def sigma_windows(self):
        yield (self.start, self.end, self.sigma)


FaultEvent = Union[BwDegradation, DimOutage, LinkFlap, StragglerBurst]


@dataclass(frozen=True)
class RetryPolicy:
    """Timeout/backoff semantics for chunks queued on a fully-out dim.

    A chunk that has sat ``timeout_s`` in the queue of a down dim gives up
    its slot and re-arrives after ``backoff_s * multiplier**(attempt-1)``,
    optionally stretched by ``(1 + jitter * U[0,1))`` drawn from the
    simulation RNG.  ``max_attempts`` timeouts fail the chunk's whole
    request group.
    """

    timeout_s: float = 0.1
    backoff_s: float = 0.1
    multiplier: float = 2.0
    jitter: float = 0.25
    max_attempts: int = 5

    def __post_init__(self) -> None:
        if not self.timeout_s > 0 or math.isnan(self.timeout_s):
            raise ValueError(f"RetryPolicy: timeout_s {self.timeout_s!r} "
                             "must be > 0")
        if self.backoff_s < 0 or math.isnan(self.backoff_s):
            raise ValueError(f"RetryPolicy: backoff_s {self.backoff_s!r} "
                             "must be >= 0")
        if self.multiplier < 1.0 or math.isnan(self.multiplier):
            raise ValueError(f"RetryPolicy: multiplier {self.multiplier!r} "
                             "must be >= 1")
        if self.jitter < 0 or math.isnan(self.jitter):
            raise ValueError(f"RetryPolicy: jitter {self.jitter!r} "
                             "must be >= 0")
        if self.max_attempts < 1:
            raise ValueError(f"RetryPolicy: max_attempts "
                             f"{self.max_attempts!r} must be >= 1")

    def delay(self, attempt: int) -> float:
        """Base (un-jittered) backoff before re-arrival number ``attempt``."""
        return self.backoff_s * self.multiplier ** (attempt - 1)


class FaultBoundary(NamedTuple):
    """One value-change event on one dim (engine consumption form)."""

    t: float
    dim: int
    factor: float      # BW multiplier in effect from t (0.0 == fully out)
    sigma: float       # extra straggler sigma in effect from t
    bw_change: bool    # factor changed at t (incl. outage start/end)
    down_start: bool   # factor transitioned  >0 -> 0
    down_end: bool     # factor transitioned   0 -> >0


@dataclass(frozen=True)
class CompiledFaults:
    """``FaultSchedule.compile(num_dims)`` output: sorted boundaries plus
    the retry policy, ready for the engines."""

    boundaries: tuple[FaultBoundary, ...]
    retry: RetryPolicy
    num_dims: int


def _change_points(wins: list[tuple[float, float, float]],
                   base: float) -> list[tuple[float, float]]:
    """Lower sorted non-overlapping (start, end, value) windows over a
    ``base`` background into deduplicated (time, new_value) points."""
    pts: dict[float, float] = {}
    for _, end, _ in wins:
        if math.isfinite(end):
            pts[end] = base
    for start, _, v in wins:
        pts[start] = v  # a window starting where another ends wins the tie
    out: list[tuple[float, float]] = []
    prev = base
    for t in sorted(pts):
        v = pts[t]
        if v != prev:
            out.append((t, v))
            prev = v
    return out


@dataclass(frozen=True)
class FaultSchedule:
    """A declarative fault timeline: a set of fault events plus the retry
    policy applied during outages.  Validate + lower with
    :meth:`compile`; the engines only ever see the compiled form."""

    events: tuple[FaultEvent, ...] = ()
    retry: RetryPolicy = field(default_factory=RetryPolicy)

    def __post_init__(self) -> None:
        object.__setattr__(self, "events", tuple(self.events))
        for ev in self.events:
            if not isinstance(ev, (BwDegradation, DimOutage, LinkFlap,
                                   StragglerBurst)):
                raise ValueError(
                    f"FaultSchedule: unknown event type {type(ev).__name__}")

    def compile(self, num_dims: int) -> CompiledFaults:
        """Validate against a ``num_dims``-dim topology and lower to sorted
        :class:`FaultBoundary` events.

        Raises ``ValueError`` for out-of-range dims and for overlapping
        windows of the same family (BW-affecting events — degradations,
        outages, flaps — may not overlap each other on one dim; straggler
        bursts may not overlap each other; a burst may overlap a BW
        window).  Windows that merely touch (``a.end == b.start``) are
        fine.
        """
        bw_wins: dict[int, list[tuple[float, float, float]]] = {}
        sg_wins: dict[int, list[tuple[float, float, float]]] = {}
        for ev in self.events:
            if not 0 <= ev.dim < num_dims:
                raise ValueError(
                    f"{type(ev).__name__}: dim {ev.dim} out of range for a "
                    f"{num_dims}-dim topology")
            for w in ev.bw_windows():
                bw_wins.setdefault(ev.dim, []).append(w)
            for w in ev.sigma_windows():
                sg_wins.setdefault(ev.dim, []).append(w)
        for family, wins_by_dim in (("BW", bw_wins), ("straggler", sg_wins)):
            for dim, wins in wins_by_dim.items():
                wins.sort()
                for (s0, e0, _), (s1, e1, _) in zip(wins, wins[1:]):
                    if s1 < e0:
                        raise ValueError(
                            f"overlapping {family} fault windows on dim "
                            f"{dim}: [{s0!r}, {e0!r}) and [{s1!r}, {e1!r}) "
                            "— fault windows of one family must be "
                            "disjoint per dim")

        boundaries: list[FaultBoundary] = []
        for dim in sorted(set(bw_wins) | set(sg_wins)):
            f_pts = dict(_change_points(bw_wins.get(dim, []), 1.0))
            s_pts = dict(_change_points(sg_wins.get(dim, []), 0.0))
            f, s = 1.0, 0.0
            for t in sorted(set(f_pts) | set(s_pts)):
                nf = f_pts.get(t, f)
                ns = s_pts.get(t, s)
                boundaries.append(FaultBoundary(
                    t, dim, nf, ns,
                    bw_change=nf != f,
                    down_start=f > 0.0 and nf == 0.0,
                    down_end=f == 0.0 and nf > 0.0))
                f, s = nf, ns
        boundaries.sort(key=lambda b: (b.t, b.dim))
        return CompiledFaults(tuple(boundaries), self.retry, num_dims)
