"""jax version-compat shims.

The repo targets the newest jax APIs but must degrade gracefully on the
installed toolchain (jax 0.4.37 in the image):

  * ``jax.sharding.AxisType`` + the ``axis_types=`` kwarg of
    ``jax.make_mesh`` only exist from jax 0.5; older versions get a plain
    mesh (every axis behaves like the default/auto type).
  * ``jax.make_mesh`` itself appeared in 0.4.35; even older versions fall
    back to constructing ``Mesh`` from ``mesh_utils.create_device_mesh``.
  * ``jax.shard_map`` (with ``check_vma=``) is jax >= 0.6; 0.4.x spells it
    ``jax.experimental.shard_map.shard_map`` with ``check_rep=``.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x
    AxisType = None


def make_mesh_compat(shape: tuple[int, ...], axes: tuple[str, ...]):
    """``jax.make_mesh`` with auto axis types where the API supports them."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    from jax.experimental import mesh_utils
    from jax.sharding import Mesh

    return Mesh(mesh_utils.create_device_mesh(shape), axes)


def axis_size_compat(axis_name) -> "jax.Array | int":
    """``jax.lax.axis_size`` (jax >= 0.6); 0.4.x derives it via psum(1)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map_compat(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across the 0.4 -> 0.6 API rename."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map

    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check)
