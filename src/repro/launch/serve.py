"""Serving driver: batched prefill + autoregressive decode.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
        --batch 4 --prompt-len 32 --gen 16 --mesh 2x4 [--kv-quant]

Builds the sharded prefill/decode programs (train/serve.py), runs a batch
of synthetic requests through them, and reports per-token decode latency.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL")
    ap.add_argument("--kv-quant", action="store_true")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import ParallelConfig, ShapeConfig, get_arch
    from repro.launch.mesh import make_mesh
    from repro.models import build_model
    from repro.train.serve import make_serve_fns

    data, model = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((data, model), ("data", "model"))
    cfg = get_arch(args.arch, reduced=args.reduced)
    if args.kv_quant:
        cfg = cfg.replace(kv_quant=True)
    api = build_model(cfg)
    total = args.prompt_len + args.gen
    shape = ShapeConfig("serve", total, args.batch, "decode")
    jit_prefill, jit_decode, _ = make_serve_fns(
        api, mesh, ParallelConfig(data=data, model=model), shape)

    print(f"[serve] {args.arch} reduced={args.reduced} mesh={args.mesh} "
          f"kv_quant={args.kv_quant}")
    params = api.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.num_frames, cfg.d_model)),
            jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.num_patches, cfg.d_model)),
            jnp.bfloat16)

    t0 = time.time()
    logits, caches = jit_prefill(params, batch)
    logits.block_until_ready()
    print(f"[serve] prefill {args.batch}x{args.prompt_len}: "
          f"{(time.time()-t0)*1e3:.0f} ms (incl. compile)")

    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        logits, caches = jit_decode(params, caches, tok, pos)
        tok = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = (time.time() - t0) / args.gen
    print(f"[serve] decode: {dt*1e3:.1f} ms/token "
          f"({args.batch/dt:.1f} tok/s aggregate)")
    print(f"[serve] sample output ids: "
          f"{[int(t[0]) for t in out[:10]]}")


if __name__ == "__main__":
    main()
