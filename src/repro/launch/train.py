"""End-to-end training driver (fault-tolerant).

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
        --steps 200 --batch 8 --seq 256 --mesh 1x1 --reduced \
        --dp-sync gspmd --ckpt-dir runs/ckpt

Features: synthetic data pipeline with host prefetch, AdamW + cosine LR,
grad clipping, gradient accumulation, periodic atomic checkpoints with
async writer, resume-from-latest (exact data-cursor resume), Themis or
baseline hierarchical gradient sync (``--dp-sync``), optional int8
compression.  Survives SIGTERM/crash: rerun the same command and it
continues from the newest valid checkpoint (elastic: the mesh may differ).
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL[xPOD]")
    ap.add_argument("--dp-sync", default="gspmd",
                    choices=["gspmd", "themis", "hier_baseline"])
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compression", default="none", choices=["none", "int8"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.ckpt import AsyncCheckpointer, latest_step, restore
    from repro.configs import ParallelConfig, TrainConfig, get_arch
    from repro.data import Prefetcher, SyntheticLM
    from repro.launch.mesh import make_mesh
    from repro.models import build_model
    from repro.train.step import (
        gspmd_init_state,
        make_gspmd_train_step,
        make_themis_train_step,
    )

    dims = [int(x) for x in args.mesh.split("x")]
    while len(dims) < 3:
        dims.append(1)
    data, model, pods = dims
    names = ("pod", "data", "model") if pods > 1 else ("data", "model")
    shape = (pods, data, model) if pods > 1 else (data, model)
    mesh = make_mesh(shape, names)

    cfg = get_arch(args.arch, reduced=args.reduced)
    api = build_model(cfg)
    parallel = ParallelConfig(data=data, model=model, pods=pods,
                              dp_sync=args.dp_sync,
                              compression=args.compression)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 20, 1),
                       microbatch=args.microbatch,
                       checkpoint_every=args.ckpt_every,
                       checkpoint_dir=args.ckpt_dir)

    if args.dp_sync == "gspmd":
        jit_step, p_shard, o_shard, _ = make_gspmd_train_step(
            api, mesh, parallel, tcfg)
        params, opt = gspmd_init_state(api, mesh, parallel)
    else:
        jit_step, init_state, orders = make_themis_train_step(
            api, mesh, parallel, tcfg)
        params, opt = init_state()
        uniq = sorted(set(orders))
        print(f"[train] themis chunk orders ({len(orders)} chunks): "
              + ", ".join("->".join(o) for o in uniq))

    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = AsyncCheckpointer(args.ckpt_dir, keep=3)
        last = latest_step(args.ckpt_dir)
        if last is not None:
            (params, opt), extra = restore(
                args.ckpt_dir, (params, opt))
            start_step = extra.get("next_step", last)
            print(f"[train] resumed from step {last} "
                  f"(data cursor -> {start_step})")

    ds = SyntheticLM(cfg.vocab_size, args.batch, args.seq, seed=tcfg.seed)
    pf = Prefetcher(ds, mesh, start_step=start_step)

    t_last = time.time()
    losses = []
    for step, batch in pf:
        if step >= args.steps:
            break
        params, opt, metrics = jit_step(params, opt, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % args.log_every == 0:
            dt = (time.time() - t_last) / args.log_every
            t_last = time.time()
            print(f"[train] step {step+1:5d} loss={np.mean(losses[-args.log_every:]):.4f} "
                  f"gnorm={float(metrics['gnorm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f} ms/step")
        if ckpt and (step + 1) % tcfg.checkpoint_every == 0:
            ckpt.save_async(step + 1, (params, opt),
                            extra={"next_step": step + 1, "seed": tcfg.seed})
    pf.close()
    if ckpt:
        ckpt.wait()
    print(f"[train] done: {len(losses)} steps, "
          f"loss {losses[0]:.4f} -> {np.mean(losses[-10:]):.4f}")
    return losses


if __name__ == "__main__":
    main()
