"""Roofline model: compute / memory / collective terms per (arch x shape x mesh).

TPU v5e constants (targets; this container is CPU-only so terms are derived
from the compiled dry-run + closed-form architecture math, not wall time):

    peak      197 TFLOP/s bf16 per chip
    HBM BW    819 GB/s per chip
    ICI       ~50 GB/s per link (2 links usable per mesh axis)
    DCN       ~25 GB/s per host NIC (pod axis)

Terms (seconds, per the assignment):
    compute    = FLOPs / (chips x peak)
    memory     = HBM bytes / (chips x HBM BW)
    collective = per-axis wire bytes / link BW, summed over axes
                 (per-NPU bytes on each axis — the paper's N_K x B_K)

FLOPs/bytes are exact closed-form sums over the architecture's matmuls
(XLA's cost_analysis counts ``scan`` bodies once, so the compiled number is
cross-checked, not used directly — see EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 2 * 50e9          # 2 links per axis direction
DCN_BW = 25e9

BF16 = 2
FP32 = 4


# --------------------------------------------------------------------------
# Closed-form FLOPs
# --------------------------------------------------------------------------
def _layer_matmul_params(cfg: ModelConfig) -> float:
    """Weight-matmul params of ONE layer (active path for MoE)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    attn = 2 * d * hd * (cfg.num_heads + cfg.num_kv_heads)
    if cfg.family == "moe":
        e_act = cfg.experts_per_token * 3 * d * cfg.moe_d_ff
        shared = cfg.num_shared_experts * 3 * d * cfg.moe_d_ff
        router = d * cfg.num_experts
        return attn + e_act + shared + router
    if cfg.family == "hybrid":
        rec = 2 * d * cfg.d_rnn + 2 * cfg.d_rnn * cfg.d_rnn + cfg.d_rnn * d
        att = attn
        mlp = 3 * d * cfg.d_ff
        pat = cfg.block_pattern
        frac_rec = pat.count("rec") / len(pat)
        return frac_rec * rec + (1 - frac_rec) * att + mlp
    if cfg.family == "ssm":
        di = int(cfg.proj_factor * d)
        dh = di // cfg.num_heads
        mls = d * 2 * di + 3 * di * dh + di * d
        sls = d * 4 * d + 4 * d * (d // cfg.num_heads) + d * d
        per = cfg.slstm_every
        return ((per - 1) * mls + sls) / per
    mlp = (3 if cfg.gated_mlp else 2) * d * cfg.d_ff
    return attn + mlp


def _total_layer_params(cfg: ModelConfig) -> float:
    n = cfg.num_layers
    if cfg.is_encoder_decoder:
        d, hd = cfg.d_model, cfg.resolved_head_dim
        attn = 2 * d * hd * (cfg.num_heads + cfg.num_kv_heads)
        mlp = 2 * d * cfg.d_ff
        enc = cfg.encoder_layers * (attn + mlp)
        dec = cfg.num_layers * (2 * attn + mlp)
        return enc + dec
    return n * _layer_matmul_params(cfg)


def _attn_context(cfg: ModelConfig, t: int) -> float:
    """Effective attended context per query (window-aware)."""
    pat = cfg.block_pattern
    if cfg.family == "hybrid" and cfg.local_window:
        frac_attn = pat.count("attn") / len(pat)
        return frac_attn * min(t, cfg.local_window)
    if cfg.family == "ssm":
        return 0.0  # linear recurrences: no KV attention
    return t


def _attn_layers(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        pat = cfg.block_pattern
        return round(cfg.num_layers * pat.count("attn") / len(pat))
    if cfg.family == "ssm":
        return 0
    return cfg.num_layers


def analytic_fwd_flops(cfg: ModelConfig, batch: int, seq: int,
                       context: int | None = None) -> float:
    """Forward FLOPs for `batch` sequences of `seq` new tokens attending to
    `context` (defaults to seq, causal-halved when context == seq)."""
    tokens = batch * seq
    n_mm = _total_layer_params(cfg)
    flops = 2.0 * tokens * n_mm
    # lm head
    flops += 2.0 * tokens * cfg.d_model * cfg.vocab_size
    # attention score/值 FLOPs
    t = context if context is not None else seq
    eff = _attn_context(cfg, t)
    causal_half = 0.5 if (context is None and seq == t and cfg.family != "hybrid") else 1.0
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    flops += 4.0 * batch * seq * eff * h * hd * _attn_layers(cfg) * causal_half
    if cfg.is_encoder_decoder:
        f = cfg.num_frames
        flops += 2.0 * batch * f * _total_layer_params(cfg) * (
            cfg.encoder_layers / (cfg.encoder_layers + cfg.num_layers))
        flops += 4.0 * batch * seq * f * h * hd * cfg.num_layers  # cross attn
    if cfg.family == "ssm":
        di = int(cfg.proj_factor * cfg.d_model)
        dh = di // cfg.num_heads
        # chunk quadratic + state outer products per token
        flops += tokens * cfg.num_layers * (4.0 * 256 * di + 4.0 * di * dh)
    if cfg.family == "hybrid":
        flops += tokens * cfg.num_layers * 0.66 * 8.0 * cfg.d_rnn  # rglru elementwise
    return flops


def analytic_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    if shape.kind == "train":
        return 3.0 * analytic_fwd_flops(cfg, shape.global_batch, shape.seq_len)
    if shape.kind == "prefill":
        return analytic_fwd_flops(cfg, shape.global_batch, shape.seq_len)
    # decode: one token against a seq_len context
    return analytic_fwd_flops(cfg, shape.global_batch, 1, context=shape.seq_len)


def model_flops_6nd(cfg: ModelConfig, shape: ShapeConfig, n_params: int,
                    n_active: int | None = None) -> float:
    """The assignment's MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE)."""
    n = n_active if n_active is not None else n_params
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


def active_params(cfg: ModelConfig, n_params: int) -> int:
    if cfg.family != "moe":
        return n_params
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    expert_p = cfg.num_layers * e * 3 * d * f
    active_expert_p = cfg.num_layers * cfg.experts_per_token * 3 * d * f
    return n_params - expert_p + active_expert_p


# --------------------------------------------------------------------------
# Memory traffic (per device, per step)
# --------------------------------------------------------------------------
def analytic_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig, n_params: int,
                       parallel: ParallelConfig, chips: int) -> float:
    """Per-device HBM traffic; the roofline memory term uses bytes/chip."""
    tp = parallel.model
    dp = max(chips // tp, 1)
    param_shard = n_params / (tp * (dp if parallel.fsdp else 1))
    b_loc = max(shape.global_batch // dp, 1)
    d = cfg.d_model
    if shape.kind == "train":
        # fwd read + bwd read + grad write (+ optimizer read/write fp32 x4)
        pbytes = param_shard * FP32
        traffic = 3 * pbytes + 4 * pbytes
        # activations (remat: ~2x writes/reads of layer outputs)
        traffic += 4 * b_loc * shape.seq_len * d * BF16 * cfg.num_layers / 8
        return traffic
    if shape.kind == "prefill":
        traffic = param_shard * FP32
        traffic += 2 * b_loc * shape.seq_len * d * BF16 * cfg.num_layers / 8
        traffic += kv_cache_bytes(cfg, shape) / chips
        return traffic
    # decode: all params + whole KV cache stream per token
    return param_shard * FP32 + kv_cache_bytes(cfg, shape) / chips


def kv_cache_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    b, t = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    kv_bytes = 1 + 2.0 / hd if cfg.kv_quant else BF16  # int8 + bf16 scales
    if cfg.family == "ssm":
        di = int(cfg.proj_factor * cfg.d_model)
        dh = di // cfg.num_heads
        per = cfg.slstm_every
        n_m = cfg.num_layers * (per - 1) // per
        return b * n_m * cfg.num_heads * dh * dh * FP32
    if cfg.family == "hybrid":
        attn_l = _attn_layers(cfg)
        rec_l = cfg.num_layers - attn_l
        w = min(t, cfg.local_window)
        return (attn_l * b * w * cfg.num_kv_heads * hd * 2 * BF16
                + rec_l * b * cfg.d_rnn * FP32)
    layers = cfg.num_layers
    return layers * b * t * cfg.num_kv_heads * hd * 2 * kv_bytes


# --------------------------------------------------------------------------
# Collective traffic (per device wire bytes, per axis)
# --------------------------------------------------------------------------
def analytic_collective_bytes(
    cfg: ModelConfig, shape: ShapeConfig, n_params: int,
    parallel: ParallelConfig, mesh_axes: dict[str, int],
) -> dict[str, float]:
    """Per-NPU wire bytes per mesh axis (the paper's N_K)."""
    tp = mesh_axes.get("model", 1)
    data = mesh_axes.get("data", 1)
    pods = mesh_axes.get("pod", 1)
    dp = data * pods
    d = cfg.d_model
    b_loc = max(shape.global_batch // dp, 1)
    out: dict[str, float] = {a: 0.0 for a in mesh_axes if mesh_axes[a] > 1}

    def add(axis, nbytes):
        if axis in out:
            p = mesh_axes[axis]
            out[axis] += (p - 1) / p * nbytes

    if shape.kind == "train":
        # DP gradient sync: hierarchical RS+AG over (data, pod) of the
        # TP-sharded grad buffer (fp32) — chunk shrinks across dims like the
        # paper's Fig. 5.
        shard = n_params / tp * FP32
        add("data", 2 * shard)
        add("pod", 2 * shard / data)
        if parallel.fsdp:
            add("data", 3 * n_params / tp * BF16)  # AG fwd + AG bwd + RS grads
        # TP activation collectives: ~4 per layer (2 fwd + 2 bwd)
        act = b_loc * shape.seq_len * d * BF16
        add("model", 4 * cfg.num_layers * act)
        if cfg.family == "moe":
            # EP all-to-all: dispatch+combine, fwd+bwd
            a2a = b_loc * shape.seq_len * cfg.experts_per_token * d * BF16
            add("model", 4 * a2a)
    else:
        act = b_loc * shape.seq_len * d * BF16
        if shape.kind == "prefill":
            add("model", 2 * cfg.num_layers * act)
            if cfg.family == "moe":
                add("model", 2 * b_loc * shape.seq_len *
                    cfg.experts_per_token * d * BF16)
        else:  # decode: one token
            tok = b_loc * 1 * d * BF16
            add("model", 2 * cfg.num_layers * tok)
            if cfg.family == "moe":
                add("model", 2 * b_loc * cfg.experts_per_token * d * BF16)
    return out


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    per_axis_s: dict[str, float]
    model_flops: float
    analytic_flops: float
    hlo_flops: float
    useful_ratio: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound step time (the perf score):
        (MODEL_FLOPS / peak) / max(compute, memory, collective)."""
        return (self.compute_s / self.step_time_s) * (
            self.model_flops / self.analytic_flops)


def compute_roofline(
    cfg: ModelConfig, shape: ShapeConfig, n_params: int,
    parallel: ParallelConfig, mesh_axes: dict[str, int],
    hlo_flops: float = 0.0,
) -> Roofline:
    chips = 1
    for v in mesh_axes.values():
        chips *= v
    flops = analytic_flops(cfg, shape)
    n_act = active_params(cfg, n_params)
    mf = model_flops_6nd(cfg, shape, n_params, n_act)
    compute_s = flops / (chips * PEAK_FLOPS)
    mem = analytic_hbm_bytes(cfg, shape, n_params, parallel, chips)
    memory_s = mem / HBM_BW
    per_axis = analytic_collective_bytes(cfg, shape, n_params, parallel, mesh_axes)
    per_axis_s = {
        a: v / (DCN_BW if a == "pod" else ICI_BW) for a, v in per_axis.items()
    }
    collective_s = max(per_axis_s.values()) if per_axis_s else 0.0
    return Roofline(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        per_axis_s=per_axis_s, model_flops=mf, analytic_flops=flops,
        hlo_flops=hlo_flops,
        useful_ratio=mf / flops if flops else 0.0,
    )
