"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state.  Single pod: 16x16 = 256 chips ("data", "model"); multi-pod:
2x16x16 = 512 chips ("pod", "data", "model") — the "pod" axis crosses DCN.
"""
from __future__ import annotations

from repro.launch.compat import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return make_mesh_compat(shape, axes)


def axis_sizes(mesh) -> dict[str, int]:
    return dict(mesh.shape)
