"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state.  Single pod: 16x16 = 256 chips ("data", "model"); multi-pod:
2x16x16 = 512 chips ("pod", "data", "model") — the "pod" axis crosses DCN.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def axis_sizes(mesh) -> dict[str, int]:
    return dict(mesh.shape)
