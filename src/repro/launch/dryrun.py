import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16x16 single-pod or 2x16x16
multi-pod of host-platform placeholder devices), constructs the step
function (train_step for train shapes, serve prefill/decode for inference
shapes), lowers it against ShapeDtypeStruct inputs (zero allocation),
compiles it, and records:

  * memory_analysis()  — proves the cell fits (bytes per device),
  * cost_analysis()    — HLO FLOPs / bytes,
  * HLO collective stats (bytes by kind / replica-group size),
  * the analytic roofline terms (launch/roofline.py).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all [--out runs/dryrun]
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k \
      --dp-sync themis          # the paper-technique ZeRO-2 program
"""
import argparse
import json
import time
import traceback


def parallel_for(arch_name: str, cfg, mesh_axes: dict, dp_sync: str = "gspmd"):
    from repro.configs.base import ParallelConfig
    from repro.models import build_model, count_params

    n = count_params(build_model(cfg).param_spec())
    return ParallelConfig(
        data=mesh_axes.get("data", 1),
        model=mesh_axes.get("model", 1),
        pods=mesh_axes.get("pod", 1),
        fsdp=n >= 8e9,
        # SP between blocks for transformer-family residual streams; the
        # recurrent/ssm/moe paths operate on full rows (scan over time /
        # per-row dispatch sort) and use microbatching instead.
        seq_sharding=cfg.family in ("dense", "vlm", "audio"),
        zero=1,
        dp_sync=dp_sync,
    )


def pick_microbatch(cfg, shape, mesh_axes: dict, parallel) -> int:
    """Gradient-accumulation factor so the layer-carry stack fits HBM.

    carry ~= L x tokens_local x d_model x 2B (bf16), /tp when seq-sharded.
    Target <= 2 GiB per device."""
    dp = mesh_axes.get("data", 1) * mesh_axes.get("pod", 1)
    tp = mesh_axes.get("model", 1)
    b_loc = max(shape.global_batch // dp, 1)
    carry = cfg.num_layers * b_loc * shape.seq_len * cfg.d_model * 2
    if parallel.seq_sharding:
        carry /= tp
    target = 2 * 2**30
    n = 1
    while carry / n > target and n < b_loc and shape.global_batch % (2 * n) == 0:
        n *= 2
    return n


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                dp_sync: str = "gspmd", verbose: bool = True,
                kv_quant: bool = False,
                mesh_split: tuple[int, int] | None = None) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.configs.base import ALL_SHAPES, applicable_shapes
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import compute_roofline
    from repro.comms.schedule_bridge import collective_stats
    from repro.models import build_model, count_params
    from repro.models.common import mesh_context
    from repro.sharding.specs import (
        batch_pspec, cache_pspec, param_shardings, tree_shardings,
    )
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = get_arch(arch)
    if kv_quant:
        cfg = cfg.replace(kv_quant=True)
    shape = {s.name: s for s in ALL_SHAPES}[shape_name]
    if shape not in applicable_shapes(cfg):
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "long_500k requires sub-quadratic attention"}

    if mesh_split is not None:
        # Perf-iteration lever: re-balance the logical (data, model) split
        # over the same 256 chips (e.g. 32x8 for serving workloads).
        from repro.launch.mesh import make_mesh

        mesh = make_mesh(mesh_split, ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    axes = dict(mesh.shape)
    api = build_model(cfg)
    n_params = count_params(api.param_spec())
    parallel = parallel_for(arch, cfg, axes, dp_sync)

    t0 = time.time()
    if shape.kind == "train":
        if dp_sync == "gspmd":
            from repro.train.step import make_gspmd_train_step
            from repro.train.optimizer import adamw_init

            tcfg = _tcfg()
            import dataclasses
            tcfg = dataclasses.replace(
                tcfg, microbatch=pick_microbatch(cfg, shape, axes, parallel))
            jit_step, p_shard, o_shard, batch_sh = make_gspmd_train_step(
                api, mesh, parallel, tcfg)
            params_s = api.param_spec()
            opt_s = {"m": jax.eval_shape(adamw_init, params_s)["m"],
                     "v": jax.eval_shape(adamw_init, params_s)["v"],
                     "count": jax.ShapeDtypeStruct((), jnp.int32)}
            batch_s = api.batch_spec(shape)
            lowered = jit_step.lower(params_s, opt_s, batch_s)
        else:
            from repro.train.step import make_themis_train_step

            # Themis manual mode: pure DP over all axes; global batch must
            # cover the device count — use a world-sized batch.
            world = 1
            for v in axes.values():
                world *= v
            from repro.configs.base import ShapeConfig
            shape = ShapeConfig(shape.name, shape.seq_len,
                                max(shape.global_batch, world), shape.kind)
            jit_step, init_state, orders = make_themis_train_step(
                api, mesh, parallel, _tcfg())
            params_s = api.param_spec()
            opt_s = jax.eval_shape(lambda: _themis_opt_spec(
                api, mesh, parallel))
            batch_s = api.batch_spec(shape)
            lowered = jit_step.lower(params_s, opt_s, batch_s)
    elif shape.kind == "prefill":
        from repro.train.serve import make_serve_fns

        jit_prefill, _, _ = make_serve_fns(api, mesh, parallel, shape)
        params_s = api.param_spec()
        batch_s = api.batch_spec(shape)
        lowered = jit_prefill.lower(params_s, batch_s)
    else:  # decode
        from repro.train.serve import make_serve_fns

        _, jit_decode, _ = make_serve_fns(api, mesh, parallel, shape)
        params_s = api.param_spec()
        caches_s, token_s, pos_s = api.decode_spec(shape)
        lowered = jit_decode.lower(params_s, caches_s, token_s, pos_s)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    stats = collective_stats(hlo)
    rl = compute_roofline(cfg, shape, n_params, parallel, axes,
                          hlo_flops=float(cost.get("flops", 0.0)))

    chips = 1
    for v in axes.values():
        chips *= v
    result = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": "x".join(f"{k}={v}" for k, v in axes.items()),
        "chips": chips, "dp_sync": dp_sync, "status": "ok",
        "n_params": n_params,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "per_device_total_gib": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes)
                / 2**30, 3),
        },
        "cost": {k: cost.get(k) for k in
                 ("flops", "bytes accessed") if k in cost},
        "collectives_hlo": stats,
        "roofline": {
            "compute_s": rl.compute_s, "memory_s": rl.memory_s,
            "collective_s": rl.collective_s, "per_axis_s": rl.per_axis_s,
            "dominant": rl.dominant, "model_flops": rl.model_flops,
            "analytic_flops": rl.analytic_flops,
            "useful_ratio": rl.useful_ratio,
            "roofline_fraction": rl.roofline_fraction,
            "step_time_s": rl.step_time_s,
        },
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} mesh={result['mesh']} "
              f"dp_sync={dp_sync}: OK "
              f"compile={t_compile:.1f}s "
              f"mem/dev={result['memory']['per_device_total_gib']}GiB "
              f"dominant={rl.dominant} frac={rl.roofline_fraction:.3f}")
        print("  memory_analysis:", mem)
        print("  cost_analysis: flops=%.3e bytes=%.3e" % (
            float(cost.get("flops", 0)), float(cost.get("bytes accessed", 0))))
        print("  hlo collectives:", json.dumps(stats["bytes_by_kind"]))
    return result


def _tcfg():
    from repro.configs.base import TrainConfig

    return TrainConfig()


def _themis_opt_spec(api, mesh, parallel):
    # shape-only stand-in for the manual-mode optimizer state
    import jax.numpy as jnp
    import math
    from repro.models.registry import count_params

    axes = {a: s for a, s in mesh.shape.items() if s > 1}
    world = math.prod(axes.values())
    n = count_params(api.param_spec())
    n_chunks = parallel.chunks_per_collective
    per = -(-n // (n_chunks * world)) * world
    z = jnp.zeros((n_chunks, per), jnp.float32)
    return {"master": z, "m": z, "v": z,
            "count": jnp.zeros((), jnp.int32),
            "err": jnp.zeros((), jnp.float32)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dp-sync", default="gspmd",
                    choices=["gspmd", "themis", "hier_baseline"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--mesh-split", default="",
                    help="override single-pod logical split, e.g. 32x8")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="runs/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        from repro.configs import list_archs
        from repro.configs.base import ALL_SHAPES

        for a in list_archs():
            for s in ALL_SHAPES:
                for mp in (False, True):
                    cells.append((a, s.name, mp))
    else:
        cells.append((args.arch, args.shape, args.multi_pod))

    split = None
    if args.mesh_split:
        split = tuple(int(x) for x in args.mesh_split.split("x"))
    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}_{args.dp_sync}"
        if args.tag:
            tag += "_" + args.tag
        try:
            res = dryrun_cell(arch, shape, multi_pod=mp, dp_sync=args.dp_sync,
                              kv_quant=args.kv_quant, mesh_split=split)
        except Exception as e:
            traceback.print_exc()
            res = {"arch": arch, "shape": shape, "status": "FAIL",
                   "error": f"{type(e).__name__}: {e}"}
            failures += 1
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(res, f, indent=1, default=float)
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
