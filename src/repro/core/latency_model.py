"""Themis Latency Model (paper Sec. 4.4).

Total latency of network dimension K:

    Latency(dimK) = A_K + N_K * B_K + idle_K

- ``A_K``   fixed delay = number_of_steps * step_latency (collective-algorithm
            and system dependent; obtained offline).
- ``B_K``   per-byte latency = 1 / aggregate-BW of dimK.
- ``N_K``   total bytes each NPU sends on dimK = sum of per-chunk ``n_K^i``.
- ``idle_K`` minimized by SCF intra-dim scheduling (Sec. 4.3), not predicted.

The Latency Model predicts ``n_K^i * B_K`` as the load of chunk #i on dimK
(paper: "Since N_K only participates with B_K, the Latency Model only
considers n_K^i x B_K as the latency of chunk #i on dimK").
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property
from typing import ClassVar, Sequence

from repro.topology import Phase, Topology

# A stage of a chunk's schedule: which phase runs on which dimension index.
StageOp = tuple[Phase, int]


@dataclass(frozen=True)
class StageTables:
    """Flat per-dim factor arrays for allocation-free stage math.

    ``wire = rs_wire[k] * size`` (RS) / ``ag_wire[k] * size`` (AG) and the
    post-stage size is ``size / npus[k]`` / ``size * npus[k]`` — the exact
    expressions of :func:`stage_transition`, just precomputed per dim.
    """

    rs_wire: list[float]    # (P-1)/P per dim (0.0 when P <= 1)
    ag_wire: list[float]    # float(P-1) per dim (0.0 when P <= 1)
    npus: list[int]
    rs_step: list[float]    # step_delay(dim, RS)
    ag_step: list[float]    # step_delay(dim, AG)
    per_byte: list[float]   # 1 / aggr_bw_bytes
    bw: list[float]         # aggr_bw_bytes


def stage_transition(phase: Phase, npus: int, size_before: float) -> tuple[float, float]:
    """(wire_bytes_per_npu, size_after) for one RS/AG stage.

    ``size_before`` is the chunk's per-NPU resident bytes before the stage.
    RS shrinks the chunk P x; AG grows it P x.  Wire bytes are symmetric:
    a dimension moves (P-1)/P of the *large-end* size either way, matching
    the paper's Fig. 5 stage-latency accounting.
    """
    if npus <= 1:
        return 0.0, size_before
    if phase == Phase.RS:
        return (npus - 1) / npus * size_before, size_before / npus
    # AG: (P-1) * size_before == (P-1)/P * size_after
    return (npus - 1) * size_before, size_before * npus


@dataclass(frozen=True)
class LatencyModel:
    """Predicts per-chunk, per-dimension communication latency.

    Instances are cheap, but :attr:`stage_tables` is not free to rebuild in
    a loop of ``simulate()`` calls — use :meth:`for_topology` to share one
    memoized instance per topology (the simulator does this internally).
    """

    topology: Topology

    # Per-topology instance cache (for_topology).  Topology is a frozen
    # value type, so equality-keyed sharing is safe: a "changed" topology is
    # a different key, which is the invalidation rule.  Bounded so topology
    # searches generating thousands of candidates cannot grow it forever.
    _instances: ClassVar[dict[Topology, "LatencyModel"]] = {}
    _INSTANCE_CAP: ClassVar[int] = 1024
    # Monotonic count of StageTables builds — lets tests assert that loops
    # of simulate() calls stop rebuilding the flat factor tables.
    stage_table_builds: ClassVar[int] = 0

    @classmethod
    def for_topology(cls, topology: Topology) -> "LatencyModel":
        """Shared memoized instance for ``topology`` (stage tables built
        once per distinct topology, not once per ``simulate()`` call)."""
        d = cls._instances
        got = d.pop(topology, None)
        if got is None:
            if len(d) >= cls._INSTANCE_CAP:
                # evict the least-recently-used entry only — clearing
                # everything would drop hot topologies (the search's base/
                # incumbent fabrics) along with the candidate churn
                d.pop(next(iter(d)))
            got = cls(topology)
        # (re)insert at the end: dict order is the LRU recency order
        d[topology] = got
        return got

    # ---- fixed-delay term --------------------------------------------------
    def fixed_delay(self, dim_idx: int, collective: str) -> float:
        """A_K for running ``collective`` ('RS' | 'AG' | 'AR') on dimK."""
        d = self.topology.dims[dim_idx]
        if collective == "AR":
            steps = d.algorithm.steps(d.npus, Phase.RS) + d.algorithm.steps(
                d.npus, Phase.AG
            )
        else:
            steps = d.algorithm.steps(d.npus, Phase(collective))
        return steps * d.step_latency_s

    def step_delay(self, dim_idx: int, phase: Phase) -> float:
        """A-term of a single RS or AG stage on dimK."""
        d = self.topology.dims[dim_idx]
        return d.algorithm.steps(d.npus, phase) * d.step_latency_s

    # ---- bandwidth term ----------------------------------------------------
    def per_byte_latency(self, dim_idx: int) -> float:
        return 1.0 / self.topology.dims[dim_idx].aggr_bw_bytes

    def wire_time(self, dim_idx: int, wire_bytes: float) -> float:
        return wire_bytes * self.per_byte_latency(dim_idx)

    def stage_wire_bytes(
        self, dim_idx: int, phase: Phase, size_before: float
    ) -> tuple[float, float]:
        return stage_transition(phase, self.topology.dims[dim_idx].npus, size_before)

    # ---- flat per-dim tables for the hot paths ------------------------------
    @cached_property
    def stage_tables(self) -> "StageTables":
        """Precomputed per-dim factors so the simulator/scheduler hot loops
        run on flat arrays instead of method calls per stage.

        The factors are built with the *same* float expressions as
        :func:`stage_transition` / :meth:`step_delay`, so results computed
        from them are bit-identical to the method-call path (required by the
        indexed-engine equivalence gate).
        """
        LatencyModel.stage_table_builds += 1
        rs_wire, ag_wire, npus = [], [], []
        rs_step, ag_step, per_byte, bw = [], [], [], []
        for d in self.topology.dims:
            n = d.npus
            npus.append(n)
            rs_wire.append((n - 1) / n if n > 1 else 0.0)
            ag_wire.append(float(n - 1) if n > 1 else 0.0)
            rs_step.append(d.algorithm.steps(n, Phase.RS) * d.step_latency_s)
            ag_step.append(d.algorithm.steps(n, Phase.AG) * d.step_latency_s)
            per_byte.append(1.0 / d.aggr_bw_bytes)
            bw.append(d.aggr_bw_bytes)
        return StageTables(rs_wire, ag_wire, npus, rs_step, ag_step,
                           per_byte, bw)

    # ---- per-chunk load prediction (Algorithm 1 lines 28-29) ---------------
    def calc_loads(
        self, chunk_bytes: float, schedule: Sequence[StageOp]
    ) -> dict[int, float]:
        """Predicted BW-term load each dim receives from one chunk.

        ``schedule`` is the ordered list of (phase, dim) stages the chunk
        traverses; sizes evolve stage to stage.  Returns {dim_idx: seconds}.
        """
        loads: dict[int, float] = {}
        size = chunk_bytes
        for phase, dim_idx in schedule:
            wire, size = self.stage_wire_bytes(dim_idx, phase, size)
            loads[dim_idx] = loads.get(dim_idx, 0.0) + self.wire_time(dim_idx, wire)
        return loads

    def calc_loads_list(
        self, chunk_bytes: float, schedule: Sequence[StageOp]
    ) -> list[float]:
        """Dense variant of :meth:`calc_loads`: returns a per-dim load vector
        of length ``num_dims`` (0.0 for untouched dims).  Bit-identical per
        dim to the dict path; avoids a dict allocation per chunk."""
        t = self.stage_tables
        out = [0.0] * self.topology.num_dims
        size = chunk_bytes
        rs = Phase.RS
        for phase, k in schedule:
            n = t.npus[k]
            if n <= 1:
                continue
            if phase == rs:
                out[k] += t.rs_wire[k] * size * t.per_byte[k]
                size = size / n
            else:
                out[k] += t.ag_wire[k] * size * t.per_byte[k]
                size = size * n
        return out

    # ---- ideal bound (paper Table 3 'Ideal') --------------------------------
    def ideal_time(self, collective: str, size_bytes: float) -> float:
        """Communication latency at 100% BW utilization of every dimension."""
        p = self.topology.total_npus
        per_npu_bytes = (p - 1) / p * size_bytes
        if collective == "AR":
            per_npu_bytes *= 2.0  # RS + AG
        return per_npu_bytes / self.topology.total_bw_bytes

    def total_wire_bytes(self, collective: str, size_bytes: float) -> float:
        """Schedule-invariant total bytes per NPU summed over all dims."""
        p = self.topology.total_npus
        b = (p - 1) / p * size_bytes
        return 2.0 * b if collective == "AR" else b

    def dim_lower_bounds(self, collective: str, size_bytes: float) -> list[float]:
        """Per-dim busy-time lower bound (seconds) of one collective.

        The wire bytes a schedule places on dimK are minimized when dimK
        runs at the small end of the size evolution (last RS stage / first
        AG stage): ``(P_K - 1) * size / total_npus`` bytes, doubled for AR
        (RS + AG both cross the dim).  No schedule, fusion, arbiter, or
        preemption can put fewer bytes on the dim, and a dim is a serial
        BW resource, so the simulated makespan is >= every dim's bound —
        the pruning certificate used by the topology search.
        """
        p = self.topology.total_npus
        out = []
        for d in self.topology.dims:
            if d.npus <= 1:
                out.append(0.0)
                continue
            w = (d.npus - 1) * size_bytes / p
            if collective == "AR":
                w *= 2.0
            out.append(w / d.aggr_bw_bytes)
        return out
