"""Themis core — the paper's contribution.

Scheduling (Algorithm 1), latency model (Sec. 4.4), chunking, consistency
(Sec. 4.6), the multi-rail simulator used for evaluation, the Fig. 12
workload models and the Sec. 6.3 provisioning analysis.
"""
from repro.core.chunking import Chunk, coalesce_by_order, split_equal
from repro.core.consistency import fix_intra_dim_order, verify_consistent_execution
from repro.core.latency_model import LatencyModel, StageOp, stage_transition
from repro.core.load_tracker import DimLoadTracker
from repro.core.requests import CollectiveRequest
from repro.core.scheduler import (
    POLICIES,
    ThemisScheduler,
    baseline_order,
    schedule_collective,
)
from repro.core.simulator import (
    SimResult,
    simulate,
    simulate_requests,
    simulate_scheduled,
)

__all__ = [
    "Chunk",
    "CollectiveRequest",
    "DimLoadTracker",
    "LatencyModel",
    "POLICIES",
    "SimResult",
    "StageOp",
    "ThemisScheduler",
    "baseline_order",
    "coalesce_by_order",
    "fix_intra_dim_order",
    "schedule_collective",
    "simulate",
    "simulate_requests",
    "simulate_scheduled",
    "split_equal",
    "stage_transition",
    "verify_consistent_execution",
]
