"""Themis core — the paper's contribution.

Scheduling (Algorithm 1), latency model (Sec. 4.4), chunking, consistency
(Sec. 4.6), the multi-rail simulator used for evaluation, the Fig. 12
workload models and the Sec. 6.3 provisioning analysis.
"""
from repro.core.chunking import Chunk, coalesce_by_order, schedule_classes, split_equal
from repro.core.consistency import fix_intra_dim_order, verify_consistent_execution
from repro.core.latency_model import LatencyModel, StageOp, stage_transition
from repro.core.load_tracker import DimLoadTracker
from repro.core.requests import CollectiveRequest
from repro.core.scheduler import (
    POLICIES,
    ThemisScheduler,
    baseline_order,
    schedule_collective,
)
from repro.core.simulator import (
    SimResult,
    simulate,
    simulate_requests,
    simulate_scheduled,
)

def __getattr__(name):
    # The batch layer needs numpy; everything else in repro.core is
    # stdlib-only.  Lazy loading keeps `import repro.core` working in
    # numpy-less environments for users who never touch it (same pattern
    # as repro.topology's search symbols).
    if name in ("BatchCaches", "Scenario", "simulate_batch",
                "simulate_scenario"):
        from repro.core import batch

        return getattr(batch, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "BatchCaches",
    "Chunk",
    "CollectiveRequest",
    "DimLoadTracker",
    "LatencyModel",
    "POLICIES",
    "Scenario",
    "SimResult",
    "StageOp",
    "ThemisScheduler",
    "baseline_order",
    "coalesce_by_order",
    "fix_intra_dim_order",
    "schedule_classes",
    "schedule_collective",
    "simulate",
    "simulate_batch",
    "simulate_requests",
    "simulate_scenario",
    "simulate_scheduled",
    "split_equal",
    "stage_transition",
    "verify_consistent_execution",
]
