"""Event-driven multi-rail collective simulator (ASTRA-lite).

Models the 2xD-stage pipelined execution of chunked hierarchical collectives
on a multi-dimensional network (paper Sec. 2.3/5.1):

  * each network dimension is a serial bandwidth resource with a ready
    queue (FIFO or Smallest-Chunk-First discipline, Sec. 4.3);
  * a chunk's stage ops execute in schedule order (RS-before-AG is embedded
    in the schedule); a stage occupies its dimension for ``wire_bytes/BW``
    and *completes* (readying the chunk's next stage) after an additional
    fixed delay ``A_stage`` — successive chunks pipeline through a
    dimension's steps, so A is latency, not throughput (this matches
    Algorithm 1, which charges A_K once per collective in the tracker
    rather than per chunk);
  * optional small-chunk fusion: if a chunk op cannot saturate a dimension's
    BW (wire time < A), multiple ready ops are fused into one service
    (Sec. 4.3's provision, mirroring NCCL collective fusion);
  * optional enforced per-dim op order (Sec. 4.6.2 consistency) and random
    service-time jitter for consistency experiments.

The engine is *online and arrival-time-aware*: every collective (a "group"
of chunks) carries an issue time, so overlapping collectives — backprop
bucket streams, pipeline stages, multi-tenant jobs — contend for shared
dimensions exactly as they would on real hardware.  ``simulate_requests``
is the high-level entry: a stream of :class:`CollectiveRequest`s is
scheduled incrementally (``ThemisScheduler.schedule_request``, which keeps
the Dim Load Tracker running across requests) and simulated jointly.

Beyond fixed issue times, groups may be *dependency-gated* (``deps`` /
``dep_delay_s``): a group becomes eligible only once all its predecessor
groups have fully finished plus a compute delay — the structure pipeline
1F1B activation streams and serving decode chains need, where a send's
issue time is itself an output of the simulation (Rashidi et al.'s ACE,
arXiv 2007.00156: compute->comm dependencies determine overlap).  Groups
with an empty chunk list act as pure compute nodes: they finish at their
eligibility instant and only exist to gate (and delay) their dependents.
``repro.traffic`` builds these graphs; ``SimResult.group_issue`` reports
the *resolved* issue times.

Multi-tenant fabrics plug in through an *arbiter* (duck-typed; see
``repro.tenancy.FabricArbiter``): when present it replaces the per-dim
queue discipline (inter-tenant policies such as weighted-fair or
strict-priority), batches same-tenant chunks into multi-chunk services,
and may **preempt** an in-flight multi-chunk service at chunk granularity —
chunks whose data has not started draining are returned to the ready queue
so a higher-share tenant does not wait behind a 1 GB collective.  Byte
conservation holds across preemptions: every chunk stage is eventually
served exactly once.  A non-zero ``preempt_penalty_s`` charges a re-arm
latency: requeued chunks only become ready again ``penalty`` seconds after
the split (splitting is free by default for backward compatibility).

Two engines implement identical semantics:

  * ``engine="indexed"`` (default) — struct-of-arrays task storage with
    integer handles, per-dim indexed priority queues (heaps keyed by the
    active discipline) and per-(dim, tenant) bucket heaps for the arbiter's
    quantum batching, so a service start is O(batch x log n) instead of a
    full-queue sort + O(n) removes.  Near-linear in total stage-ops.
  * ``engine="reference"`` — the original list-sorting event loop, kept
    reachable as the differential-testing oracle.

Both engines consume the shared tie-break/jitter sequence in the same
order, so makespans, per-dim wire bytes, service orders and per-request
finish times are bit-identical (``benchmarks/sched_perf.py`` gates on it).

Outputs makespan, per-dim busy time / wire bytes, BW utilization (the
paper's weighted-average metric), per-dim activity timelines (Fig. 9),
per-request completion times, and per-dim service logs attributing every
service interval to the requests it carried.
"""
from __future__ import annotations

import heapq
import itertools
import math
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import NamedTuple

from repro.core.chunking import Chunk
from repro.core.invariants import (
    check_final,
    check_service_start,
    check_work_conserving,
)
from repro.core.latency_model import LatencyModel
from repro.core.requests import CollectiveRequest
from repro.obs.metrics import current_registry
from repro.topology import Phase, Topology

OpId = tuple[int, int]  # (chunk_id, stage_idx)


class ServiceInterval(NamedTuple):
    """One served batch on a dimension.

    A NamedTuple so equality, unpacking, and indexing behave exactly like
    the bare ``(start, end, groups)`` tuple it replaces — existing
    ``for start, end, groups in dim_services[k]`` loops and tuple-literal
    comparisons keep working unchanged.
    """

    start: float
    end: float
    groups: tuple[int, ...]

    @property
    def op(self) -> tuple[int, ...]:
        """Group ids this service carried (alias of ``groups``)."""
        return self.groups

ENGINES = ("indexed", "compiled", "reference")

# Arbiter policies the indexed engine can map onto per-(dim, tenant) bucket
# heaps.  Anything else (a custom duck-typed arbiter with its own order_key)
# falls back to the reference engine, which honors arbitrary keys.
_INDEXABLE_ARBITER_POLICIES = ("fifo", "strict-priority", "weighted-fair",
                               "slo-aware")


@dataclass
class StageTask:
    chunk_id: int
    stage_idx: int
    dim: int
    wire_bytes: float
    fixed_delay: float
    group: int = 0
    priority: int = 0
    arrival_seq: int = 0
    ready_time: float = 0.0
    tenant: str = "default"

    @property
    def op_id(self) -> OpId:
        return (self.chunk_id, self.stage_idx)


@dataclass
class _Service:
    """One in-flight batch on a dimension — the unit of preemption.

    ``batch`` holds :class:`StageTask`s in the reference engine and integer
    task handles in the indexed engine.
    """

    sid: int                   # event validity token; bumped on preemption
    dim: int
    start: float
    end: float
    rate: float                # effective drain rate, bytes/s (incl. jitter)
    batch: list
    svc_idx: int               # index of this service in dim_services[dim]


def _percentile(sorted_vals: list[float], q: float) -> float:
    """Linear-interpolation percentile of pre-sorted data (numpy's default
    method, without requiring numpy)."""
    if not sorted_vals:
        return 0.0
    k = (len(sorted_vals) - 1) * q
    lo = int(k)
    hi = min(lo + 1, len(sorted_vals) - 1)
    return sorted_vals[lo] + (sorted_vals[hi] - sorted_vals[lo]) * (k - lo)


@dataclass(frozen=True)
class StreamStats:
    """Aggregate metrics of one request stream (or tenant)."""

    n: int                     # number of requests carrying the tag
    issue_first: float         # earliest issue time
    finish: float              # latest finish time
    latency_mean: float        # mean issue-to-finish latency
    latency_max: float
    wire_bytes: float          # total wire bytes moved for the tag
    # Latency percentiles — serving SLOs are tail metrics (decode p99), and
    # means hide exactly the contention the arbiter policies differ on.
    latency_p50: float = 0.0
    latency_p95: float = 0.0
    latency_p99: float = 0.0
    # Groups that actually finished: ``n`` minus failed/shed members.  -1 is
    # the legacy sentinel (no failure/shed accounting ran); a tag whose
    # groups all died reports n_live=0 with zeroed latency/finish aggregates
    # instead of NaN/IndexError.
    n_live: int = -1


@dataclass
class SimResult:
    makespan: float
    dim_busy: list[float]
    dim_wire_bytes: list[float]
    dim_activity: list[list[tuple[float, float]]]  # intervals w/ pending work
    dim_op_order: list[list[OpId]]                 # service order per dim
    # -- arrival-time-aware extensions ---------------------------------------
    dim_services: list[list[ServiceInterval]] = field(default_factory=list)
    group_issue: list[float] = field(default_factory=list)
    group_finish: list[float] = field(default_factory=list)
    # -- per-group tags / attribution (populated by simulate_requests) -------
    group_streams: list[str] = field(default_factory=list)
    group_tenants: list[str] = field(default_factory=list)
    group_wire_bytes: list[float] = field(default_factory=list)
    # -- fault-injection accounting (populated only when faults= is given) ---
    failed_groups: list[tuple[int, float]] = field(default_factory=list)
    group_retries: list[int] = field(default_factory=list)
    # -- admission/load-shedding accounting (only when admission= is given) --
    shed_groups: list[tuple[int, float]] = field(default_factory=list)

    def avg_bw_utilization(self, topology: Topology) -> float:
        """Weighted average BW utilization (weights = per-dim BW budget).

        An empty/zero-makespan run moved no bytes over no time — that is
        zero utilization, not perfect utilization.
        """
        if self.makespan <= 0:
            return 0.0
        total_bw = topology.total_bw_bytes
        moved = sum(self.dim_wire_bytes)
        return moved / (self.makespan * total_bw)

    def activity_rate(self, dim: int) -> float:
        if self.makespan <= 0:
            return 0.0
        return sum(e - s for s, e in self.dim_activity[dim]) / self.makespan

    def group_span(self, group: int) -> float:
        """Issue-to-completion latency of one collective."""
        return self.group_finish[group] - self.group_issue[group]

    def _group_tags(self, by: str) -> list[str]:
        if by == "tenant":
            tags = self.group_tenants
        elif by == "stream":
            tags = self.group_streams
        else:
            raise ValueError(f"by must be 'stream' or 'tenant', got {by!r}")
        if not tags:  # plain simulate() call without request tags
            tags = ["default"] * len(self.group_finish)
        return tags

    def stream_stats(self, by: str = "stream") -> dict[str, StreamStats]:
        """Aggregate per-stream (or per-tenant, ``by='tenant'``) metrics:
        finish time, issue-to-finish latency, and wire bytes moved."""
        tags = self._group_tags(by)
        members: dict[str, list[int]] = {}
        for g, tag in enumerate(tags):
            members.setdefault(tag, []).append(g)
        wire = self.group_wire_bytes or [0.0] * len(tags)
        # Failed (faults) and shed (admission) groups never finished —
        # their stale finish==issue entries would read as zero latency and
        # poison the percentiles, so latency/finish aggregate over live
        # groups only.  A tag whose groups all died reports the explicit
        # n_live=0 sentinel with zeroed aggregates (no NaN / IndexError).
        dead = {g for g, _ in self.failed_groups}
        dead.update(g for g, _ in self.shed_groups)
        out: dict[str, StreamStats] = {}
        for tag, gs in members.items():
            live = [g for g in gs if g not in dead] if dead else gs
            # Pure compute groups (no wire moved) finish at their issue
            # instant; counting their zero latencies would drag a traffic
            # graph's per-tenant percentiles toward 0, so latency aggregates
            # only over wire-moving groups (all groups when none moved wire,
            # e.g. a compute-only stream or an untagged simulate() call).
            lat_gs = [g for g in live if wire[g] > 0] or live
            lat = [self.group_finish[g] - self.group_issue[g]
                   for g in lat_gs]
            lat_sorted = sorted(lat)
            out[tag] = StreamStats(
                n=len(gs),
                issue_first=min(self.group_issue[g] for g in gs),
                finish=max(self.group_finish[g] for g in live)
                if live else 0.0,
                latency_mean=sum(lat) / len(lat) if lat else 0.0,
                latency_max=lat_sorted[-1] if lat_sorted else 0.0,
                wire_bytes=sum(wire[g] for g in gs),
                latency_p50=_percentile(lat_sorted, 0.50),
                latency_p95=_percentile(lat_sorted, 0.95),
                latency_p99=_percentile(lat_sorted, 0.99),
                n_live=len(live) if dead else -1,
            )
        return out

    def stream_finish(self, tag: str, by: str = "stream") -> float:
        """Finish time of the last request carrying ``tag``."""
        return self.stream_stats(by)[tag].finish

    def finish_time(self) -> float:
        """Finish time of the last request (drain point of all streams)."""
        return max(self.group_finish) if self.group_finish else self.makespan

    def diff_fields(self, other: "SimResult") -> list[str]:
        """Names of fields that differ from ``other`` — the single source of
        truth for the engine bit-equivalence gate (benchmarks and tests both
        assert this returns [])."""
        import dataclasses

        return [f.name for f in dataclasses.fields(self)
                if getattr(self, f.name) != getattr(other, f.name)]

    def groups_interleave_on(self, dim: int) -> bool:
        """True if the service order on ``dim`` switches between distinct
        groups and back — i.e. collectives genuinely contend rather than
        running back-to-back.  A batch fusing several groups also counts."""
        seen_transitions: set[tuple[int, int]] = set()
        prev: int | None = None
        for _, _, groups in self.dim_services[dim]:
            if len(groups) > 1:
                return True
            g = groups[0]
            if prev is not None and g != prev:
                if (g, prev) in seen_transitions:
                    return True  # came back to an earlier group: A..B..A
                seen_transitions.add((prev, g))
            prev = g
        return False


class TaskArrays:
    """Struct-of-arrays task storage for the indexed engine.

    Everything here is immutable during a simulation run (the run-varying
    arrival-seq array is allocated per run), so one ``TaskArrays`` may be
    shared by many ``simulate()`` calls over the same chunk groups —
    ``repro.core.batch`` builds these once per scenario family and replays
    them across seeds/disciplines/arbiters.  ``group_wire`` is copied into
    each ``SimResult`` so callers can't corrupt the shared arrays.
    """

    __slots__ = ("n_tasks", "chunk", "stage", "dim", "wire", "fixed",
                 "group", "prio", "tenant", "last", "first_handles",
                 "group_wire", "fingerprint", "_validated_groups",
                 "_np_cols", "_pairs", "_cls_cache")

    def __init__(self, n_tasks, chunk, stage, dim, wire, fixed, group,
                 prio, tenant, last, first_handles, group_wire,
                 fingerprint=None):
        self.n_tasks = n_tasks
        self.chunk = chunk
        self.stage = stage
        self.dim = dim
        self.wire = wire
        self.fixed = fixed
        self.group = group
        self.prio = prio
        self.tenant = tenant
        self.last = last
        self.first_handles = first_handles
        self.group_wire = group_wire
        self.fingerprint = fingerprint
        self._validated_groups = None  # last chunk_groups that passed the
        #                                simulate() fingerprint check
        self._np_cols = None  # compiled-engine numpy column cache
        self._pairs = None  # compiled-engine (chunk, stage) tuple cache
        self._cls_cache = None  # compiled-engine size-class discovery cache


def task_arrays_fingerprint(
    chunk_groups: list[list[Chunk]],
    priorities: list[int],
    tenants: list[str],
) -> int:
    """Cheap content hash of everything a :class:`TaskArrays` is built
    from.  ``simulate(task_arrays=...)`` recomputes it to reject a replay
    against a *different* chunk-group family — counts alone would accept a
    same-shaped stream of different sizes/schedules and silently produce
    wrong results."""
    return hash((tuple(priorities), tuple(tenants),
                 tuple((c.index, c.size_bytes, tuple(c.schedule))
                       for g in chunk_groups for c in g)))


def stage_sequence(
    stage_tables, size_bytes: float, schedule
) -> tuple[list[int], list[float], list[float]]:
    """(dims, wire bytes, fixed delays) of one chunk's stages.

    THE scalar stage-transition float sequence — the same expressions as
    :func:`repro.core.latency_model.stage_transition`, evaluated in
    schedule order via the flat stage tables.  Both SoA builders (the
    scalar :func:`build_task_arrays` and the vectorized one in
    ``repro.core.batch``) call this single definition, which is what keeps
    them bit-identical; never duplicate this loop.
    """
    tbl = stage_tables
    rs_phase = Phase.RS
    dims: list[int] = []
    wires: list[float] = []
    fixeds: list[float] = []
    size = size_bytes
    for phase, dim in schedule:
        n = tbl.npus[dim]
        if n <= 1:
            wire = 0.0
        elif phase == rs_phase:
            wire = tbl.rs_wire[dim] * size
            size = size / n
        else:
            wire = tbl.ag_wire[dim] * size
            size = size * n
        dims.append(dim)
        wires.append(wire)
        fixeds.append(tbl.rs_step[dim] if phase == rs_phase
                      else tbl.ag_step[dim])
    return dims, wires, fixeds


def build_task_arrays(
    latency_model: LatencyModel,
    chunk_groups: list[list[Chunk]],
    priorities: list[int],
    tenants: list[str],
) -> TaskArrays:
    """Scalar SoA build — the exact float sequence of the indexed engine.

    One flat pass over every chunk stage (:func:`stage_sequence`), so wire
    bytes and fixed delays are bit-identical to the reference engine's
    :func:`_build_tasks`.  The vectorized equivalent lives in
    ``repro.core.batch``.
    """
    tbl = latency_model.stage_tables
    n_groups = len(chunk_groups)
    n_tasks = sum(len(c.schedule) for g in chunk_groups for c in g)
    t_chunk = [0] * n_tasks    # global chunk id
    t_stage = [0] * n_tasks
    t_dim = [0] * n_tasks
    t_wire = [0.0] * n_tasks
    t_fixed = [0.0] * n_tasks
    t_group = [0] * n_tasks
    t_prio = [0] * n_tasks
    t_tenant = [""] * n_tasks
    t_last = [False] * n_tasks  # final stage of its chunk's chain?
    first_handles: list[int] = []   # stage-0 handle per chunk, build order
    group_wire = [0.0] * n_groups
    h = 0
    offset = 0  # global chunk-id offset, same scheme as the reference engine
    for g, group in enumerate(chunk_groups):
        prio = priorities[g]
        tenant = tenants[g]
        gw = 0.0
        for chunk in group:
            sched = chunk.schedule
            cid = chunk.index + offset
            if sched:
                first_handles.append(h)
            dims, wires, fixeds = stage_sequence(tbl, chunk.size_bytes,
                                                 sched)
            for s in range(len(sched)):
                t_chunk[h] = cid
                t_stage[h] = s
                t_dim[h] = dims[s]
                wire = wires[s]
                t_wire[h] = wire
                t_fixed[h] = fixeds[s]
                t_group[h] = g
                t_prio[h] = prio
                t_tenant[h] = tenant
                gw += wire
                h += 1
            if sched:
                t_last[h - 1] = True
        group_wire[g] = gw
        if group:
            offset += max(c.index for c in group) + 1
    return TaskArrays(n_tasks, t_chunk, t_stage, t_dim, t_wire, t_fixed,
                      t_group, t_prio, t_tenant, t_last, first_handles,
                      group_wire,
                      task_arrays_fingerprint(chunk_groups, priorities,
                                              tenants))


def _build_tasks(
    latency_model: LatencyModel,
    chunks: list[Chunk],
    id_offset: int = 0,
    group: int = 0,
    priority: int = 0,
    tenant: str = "default",
) -> dict[OpId, StageTask]:
    tasks: dict[OpId, StageTask] = {}
    for chunk in chunks:
        size = chunk.size_bytes
        cid = chunk.index + id_offset
        for s, (phase, dim) in enumerate(chunk.schedule):
            wire, size = latency_model.stage_wire_bytes(dim, phase, size)
            tasks[(cid, s)] = StageTask(
                chunk_id=cid,
                stage_idx=s,
                dim=dim,
                wire_bytes=wire,
                fixed_delay=latency_model.step_delay(dim, phase),
                group=group,
                priority=priority,
                tenant=tenant,
            )
    return tasks


def _resolve_penalty(preempt_penalty_s: float | None, arbiter) -> float:
    """Explicit argument wins; otherwise the arbiter's attribute; else 0."""
    if preempt_penalty_s is None:
        preempt_penalty_s = getattr(arbiter, "preempt_penalty_s", 0.0) or 0.0
    if preempt_penalty_s < 0:
        raise ValueError("preempt_penalty_s must be >= 0")
    return preempt_penalty_s


def _arbiter_indexable(arbiter) -> bool:
    """Can the indexed engine replicate this arbiter's queue ordering?

    The indexed engine never calls ``order_key`` — it hardcodes each known
    policy's canonical key into its bucket heaps — so it may only take
    arbiters whose ``order_key`` is the stock ``FabricArbiter`` one.  A
    subclass overriding ``order_key`` (or any non-FabricArbiter duck type)
    falls back to the reference engine, which honors arbitrary keys.  The
    remaining hooks (``should_preempt``/``on_served``/...) are invoked on
    both engines, so overriding those stays indexable.
    """
    if getattr(arbiter, "policy", None) not in _INDEXABLE_ARBITER_POLICIES:
        return False
    # Lazy import: repro.tenancy depends on repro.core, not vice versa.
    from repro.tenancy.arbiter import FabricArbiter

    return (isinstance(arbiter, FabricArbiter)
            and type(arbiter).order_key is FabricArbiter.order_key)


def simulate(
    topology: Topology,
    chunk_groups: list[list[Chunk]],
    *,
    issue_times: list[float] | None = None,
    priorities: list[int] | None = None,
    intra: str = "SCF",
    fusion: bool = True,
    fusion_limit: int = 8,
    enforced_order: list[list[OpId]] | None = None,
    jitter: float = 0.0,
    seed: int = 0,
    tenants: list[str] | None = None,
    streams: list[str] | None = None,
    arbiter=None,
    preempt_penalty_s: float | None = None,
    engine: str = "indexed",
    task_arrays: TaskArrays | None = None,
    deps: list[tuple[int, ...]] | None = None,
    dep_delay_s: list[float] | None = None,
    check_invariants: bool = False,
    tracer=None,
    faults=None,
    replanner=None,
    admission=None,
) -> SimResult:
    """Simulate one or more collectives (``chunk_groups``).

    ``issue_times``: per-group arrival time (seconds); default all 0.0.
        A group's chunks become ready only once its collective is issued,
        so staggered groups overlap and contend on shared dims.
    ``priorities``: per-group service priority (higher first within a dim's
        ready queue; default all equal).
    ``intra``: 'FIFO' | 'SCF' intra-dimension discipline (Sec. 4.3).
    ``fusion``: fuse ops that cannot individually saturate a dim's BW.
    ``enforced_order``: per-dim list of op ids that must be served in order
        (Sec. 4.6.2); a dim idles rather than serving out of turn.
    ``jitter``: multiplicative service-time noise amplitude (consistency
        experiments; deterministic given ``seed``).
    ``tenants``/``streams``: per-group tags for multi-tenant attribution
        (``SimResult.stream_stats``).
    ``arbiter``: inter-tenant queue discipline + preemption policy (see
        ``repro.tenancy.FabricArbiter``).  When set it replaces the
        ``intra`` ordering, batches same-tenant chunks into multi-chunk
        services (up to ``arbiter.quantum_chunks``), and — if
        ``arbiter.preemption`` — may split an in-flight service at chunk
        granularity, requeueing chunks whose data has not started draining.
        Mutually exclusive with ``enforced_order``.
    ``preempt_penalty_s``: re-arm latency charged to preempted chunks — they
        re-arrive ``penalty`` seconds after the split instead of instantly.
        ``None`` defers to ``arbiter.preempt_penalty_s`` (default 0.0:
        splits are free, the pre-penalty behavior).
    ``engine``: 'indexed' (default; near-linear in stage-ops),
        'compiled' (the cohort-vectorized fast-path engine in
        ``repro.core.engine_compiled``; ~10x indexed throughput on
        no-preemption streams), or 'reference' (the original
        O(n^2)-per-dim loop, kept as the differential-testing oracle).
        All three produce bit-identical results on their shared domain.
        Fallbacks are automatic and warning-free: a custom arbiter the
        indexed engine cannot bucket-index falls back to 'reference',
        and a fast-path-ineligible feature (``arbiter``,
        ``enforced_order``, ``faults``, ``admission``, ``tracer``,
        ``replanner``, ``check_invariants``) with ``engine="compiled"``
        falls back to 'indexed' — the documented signal is
        ``repro.core.engine_compiled.LAST_FALLBACK`` /
        ``FALLBACK_COUNTS`` plus the ``simulate.compiled.fallback``
        metrics counter.  An unknown engine name raises ``ValueError``
        listing the valid engines.
    ``task_arrays``: advanced — a prebuilt :class:`TaskArrays` for exactly
        these ``chunk_groups``/``priorities``/``tenants`` (see
        :func:`build_task_arrays`).  ``repro.core.batch`` passes this to
        replay one SoA build across many scenarios; ignored when the
        reference engine runs (it rebuilds its own task dict).
    ``deps``: per-group tuple of predecessor group indices — dependency-
        gated issue.  A group with predecessors ignores its static issue
        time as a trigger: it becomes eligible at
        ``max(issue_times[g], latest predecessor finish + dep_delay_s[g])``
        once *all* its predecessors have fully finished (every chunk chain
        retired).  A group without predecessors issues at
        ``issue_times[g] + dep_delay_s[g]``.  Groups with an empty chunk
        list are pure compute nodes: they finish at their eligibility
        instant and exist only to gate dependents.  ``None`` (default) is
        the fixed-time mode — bit-identical to the pre-dependency engine,
        as is a ``deps`` list whose entries are all empty with zero delays.
        The graph must be acyclic (a cycle raises once the event stream
        drains).  ``SimResult.group_issue`` reports the resolved times.
    ``dep_delay_s``: per-group compute delay (seconds) between the gating
        event and the group's issue; requires ``deps``.
    ``check_invariants``: arm the runtime invariant sanitizer
        (``repro.core.invariants``) inside the event loop of either engine:
        bytes conservation across preemption splits, per-dim service
        ordering, work conservation at every event boundary, and (under an
        arbiter) the served-bytes ledger vs the engine's wire accounting —
        the ledger check assumes the arbiter's pre-existing state is the
        ``served_snapshot()`` taken at entry, so reuse across calls is
        fine.  Violations raise
        :class:`repro.core.invariants.InvariantViolation`.  Off (default)
        costs one branch per event.
    ``tracer``: arm the flight recorder (:class:`repro.obs.Tracer`) inside
        either engine.  Records every service start/finish/preempt, ready-
        queue arrival, arbiter grant, dependency-edge resolution and group
        release; export via ``tracer.to_chrome_trace()`` or derive
        timelines with ``repro.obs.BwTimeline.from_tracer``.  Hooks are
        append-only (no tie-break/RNG consumption), so a traced run's
        result is bit-identical to the untraced run; off (default) costs
        one branch per event, same contract as ``check_invariants``.  One
        tracer records exactly one run.
    ``faults``: a :class:`repro.faults.FaultSchedule` (or a pre-compiled
        ``CompiledFaults``) injected into either engine as a fourth event
        class.  At each fault boundary the affected dim's effective BW is
        rescaled: an in-flight service is *re-rated* (bytes already drained
        are conserved, the remainder continues at the new rate), future
        services start at the degraded rate, and straggler-burst windows
        layer extra lognormal sigma on service times.  A fully-out dim cuts
        its in-flight service at chunk granularity (undrained chunks
        requeue) and queued chunks follow the schedule's
        :class:`~repro.faults.RetryPolicy`: timeout, exponential backoff
        with jitter drawn from the simulation RNG, and after
        ``max_attempts`` the chunk's whole request group is marked failed
        (``SimResult.failed_groups``; its unserved work is abandoned and
        dependents of a failed group fail transitively).  ``None``
        (default) is byte-for-byte the fault-free engine.  Mutually
        exclusive with ``enforced_order``.
    ``replanner``: graceful-degradation hook (see
        :func:`repro.faults.make_replanner`), called at every BW-changing
        fault boundary with ``(now, factors, pending)`` where ``pending``
        lists the not-yet-started groups; it returns re-planned chunk
        schedules computed against the degraded fabric, which the engine
        applies to those groups' un-issued work.  Requires ``faults``.
    ``admission``: an admission controller / load shedder (see
        :class:`repro.fleet.AdmissionController`) consulted at each
        group's *first* ready event.  A shed group's queued chunks are
        purged, its unstarted work never issues, and dependents it gates
        are shed with it (shedding a request unit drops the whole unit);
        outcomes land in ``SimResult.shed_groups`` — demand-side losses,
        distinct from the fault fabric's ``failed_groups``.  The
        controller is driven identically (same call sites, same event
        order) by both engines and must consume no RNG, so admission
        runs stay bit-identical indexed vs reference.  Requires
        ``deps`` (admission units are dependency components); mutually
        exclusive with ``enforced_order`` for the same deadlock reason
        as faults.  ``None`` (default) is byte-for-byte the
        admission-free engine.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; want {ENGINES}")
    n_groups = len(chunk_groups)
    if n_groups and isinstance(chunk_groups[0], Chunk):
        raise TypeError(
            "simulate() expected a list of chunk groups (list[list[Chunk]]), "
            "got a flat chunk list; wrap it in [chunks]")
    if issue_times is None:
        issue_times = [0.0] * n_groups
    if priorities is None:
        priorities = [0] * n_groups
    if len(issue_times) != n_groups or len(priorities) != n_groups:
        raise ValueError("issue_times/priorities must match chunk_groups")
    if tenants is None:
        tenants = ["default"] * n_groups
    if streams is None:
        streams = ["default"] * n_groups
    if len(tenants) != n_groups or len(streams) != n_groups:
        raise ValueError("tenants/streams must match chunk_groups")
    for g, t in enumerate(issue_times):
        if not math.isfinite(t) or t < 0:
            raise ValueError(
                f"issue_times[{g}] = {t!r}: issue times must be finite "
                "and >= 0")
    for g, group in enumerate(chunk_groups):
        for c in group:
            if not math.isfinite(c.size_bytes) or c.size_bytes < 0:
                raise ValueError(
                    f"chunk_groups[{g}] chunk {c.index}: size_bytes "
                    f"{c.size_bytes!r} must be finite and >= 0")
    if arbiter is not None and enforced_order is not None:
        raise ValueError("arbiter and enforced_order are mutually exclusive")
    if faults is not None and enforced_order is not None:
        # An enforced per-dim order would deadlock against retry/abandon
        # reordering (a failed group's ops never arrive; the dim idles
        # forever waiting its turn).  No user needs the combination.
        raise ValueError("faults and enforced_order are mutually exclusive")
    if replanner is not None and faults is None:
        raise ValueError("replanner requires faults")
    if admission is not None and deps is None:
        # Admission units are weakly-connected dependency components; a
        # dep-free run has no request structure to admit or shed.
        raise ValueError("admission requires deps")
    if admission is not None and enforced_order is not None:
        # A shed group's ops never arrive; an enforced per-dim order would
        # idle forever waiting its turn (same deadlock as faults).
        raise ValueError("admission and enforced_order are mutually "
                         "exclusive")
    flt = None
    if faults is not None:
        compile_fn = getattr(faults, "compile", None)
        flt = compile_fn(topology.num_dims) if callable(compile_fn) else faults
        if getattr(flt, "num_dims", None) != topology.num_dims:
            raise ValueError(
                f"faults were compiled for {getattr(flt, 'num_dims', None)} "
                f"dims but the topology has {topology.num_dims}")
    if dep_delay_s is not None and deps is None:
        raise ValueError("dep_delay_s requires deps")
    if deps is not None and enforced_order is not None:
        # An enforced per-dim order can idle a dim waiting for an op whose
        # group is dep-gated behind that very dim — a deadlock the end-of-
        # run cycle check would misreport.  The combination has no user
        # today (enforced orders come from fixed-stream consistency runs).
        raise ValueError("deps and enforced_order are mutually exclusive")
    if deps is not None:
        if len(deps) != n_groups:
            raise ValueError("deps must match chunk_groups")
        if dep_delay_s is None:
            dep_delay_s = [0.0] * n_groups
        elif len(dep_delay_s) != n_groups:
            raise ValueError("dep_delay_s must match chunk_groups")
        if any(d < 0 for d in dep_delay_s):
            raise ValueError("dep_delay_s entries must be >= 0")
        for g, preds in enumerate(deps):
            for p in preds:
                if not 0 <= p < n_groups or p == g:
                    raise ValueError(
                        f"group {g} has an invalid dependency {p}")
    if task_arrays is not None:
        # Replays of the same chunk_groups object (the batch path: one
        # cached TaskArrays per scenario family, many seeds) skip the
        # O(stage-ops) rehash via identity; the strong reference keeps the
        # identity valid.  Per-group tags are covered because scenarios
        # sharing a cached family share the same request tuple.
        if task_arrays._validated_groups is not chunk_groups:
            if (len(task_arrays.group_wire) != n_groups
                    or task_arrays.fingerprint != task_arrays_fingerprint(
                        chunk_groups, priorities, tenants)):
                raise ValueError(
                    "task_arrays was built for a different chunk-group "
                    "family (group count or content fingerprint mismatch); "
                    "rebuild it with build_task_arrays for exactly these "
                    "chunk_groups/priorities/tenants")
            task_arrays._validated_groups = chunk_groups
    penalty = _resolve_penalty(preempt_penalty_s, arbiter)

    # Span timing lives behind the metrics registry (repro.obs); core never
    # reads the wall clock itself.  No registry installed -> nullcontext.
    reg = current_registry()
    if engine == "compiled":
        # Lazy import: engine_compiled imports this module at its top.
        from repro.core import engine_compiled as _ec
        blocker = _ec.fast_path_blocker(
            arbiter=arbiter, enforced_order=enforced_order, faults=faults,
            admission=admission, tracer=tracer, replanner=replanner,
            check_invariants=check_invariants)
        if blocker is None:
            with reg.span("simulate.compiled") if reg is not None \
                    else nullcontext():
                return _ec.simulate_compiled(
                    topology, chunk_groups, issue_times=issue_times,
                    priorities=priorities, intra=intra, fusion=fusion,
                    fusion_limit=fusion_limit, jitter=jitter, seed=seed,
                    tenants=tenants, streams=streams,
                    task_arrays=task_arrays, deps=deps,
                    dep_delay=dep_delay_s)
        _ec.record_fallback(blocker)
        engine = "indexed"
    if engine == "indexed" and (arbiter is None or _arbiter_indexable(arbiter)):
        with reg.span("simulate.indexed") if reg is not None \
                else nullcontext():
            return _simulate_indexed(
                topology, chunk_groups, issue_times=issue_times,
                priorities=priorities, intra=intra, fusion=fusion,
                fusion_limit=fusion_limit, enforced_order=enforced_order,
                jitter=jitter, seed=seed, tenants=tenants, streams=streams,
                arbiter=arbiter, penalty=penalty, task_arrays=task_arrays,
                deps=deps, dep_delay=dep_delay_s, chk=check_invariants,
                tracer=tracer, faults=flt, replanner=replanner,
                admission=admission)
    with reg.span("simulate.reference") if reg is not None else nullcontext():
        return _simulate_reference(
            topology, chunk_groups, issue_times=issue_times,
            priorities=priorities, intra=intra, fusion=fusion,
            fusion_limit=fusion_limit, enforced_order=enforced_order,
            jitter=jitter, seed=seed, tenants=tenants, streams=streams,
            arbiter=arbiter, penalty=penalty, deps=deps,
            dep_delay=dep_delay_s, chk=check_invariants, tracer=tracer,
            faults=flt, replanner=replanner, admission=admission)


# ---------------------------------------------------------------------------
# Reference engine — the original list-sorting event loop (oracle).
# ---------------------------------------------------------------------------
def _simulate_reference(
    topology: Topology,
    chunk_groups: list[list[Chunk]],
    *,
    issue_times: list[float],
    priorities: list[int],
    intra: str,
    fusion: bool,
    fusion_limit: int,
    enforced_order: list[list[OpId]] | None,
    jitter: float,
    seed: int,
    tenants: list[str],
    streams: list[str],
    arbiter,
    penalty: float,
    deps: list[tuple[int, ...]] | None = None,
    dep_delay: list[float] | None = None,
    chk: bool = False,
    tracer=None,
    faults=None,
    replanner=None,
    admission=None,
) -> SimResult:
    import random

    rng = random.Random(seed)
    lm = LatencyModel.for_topology(topology)
    num_dims = topology.num_dims
    n_groups = len(chunk_groups)

    # Flight recorder (repro.obs.Tracer).  Hooks are append-only and never
    # consume seq/RNG, so armed runs stay bit-identical to untraced ones.
    trc = tracer
    if trc is not None:
        trc.begin(num_dims, n_groups, "reference")
    trc_enq = trc.enq_dims.append if trc is not None else None
    trc_enq_t = trc.enq_times.append if trc is not None else None

    tasks: dict[OpId, StageTask] = {}
    group_of_chunk: dict[int, int] = {}
    group_wire = [0.0] * n_groups
    group_cid_offset = [0] * n_groups  # global chunk-id base per group
    offset = 0
    for g, group in enumerate(chunk_groups):
        group_cid_offset[g] = offset
        built = _build_tasks(lm, group, id_offset=offset, group=g,
                             priority=priorities[g], tenant=tenants[g])
        tasks.update(built)
        group_wire[g] += sum(t.wire_bytes for t in built.values())
        for c in group:
            group_of_chunk[c.index + offset] = g
        if group:
            offset += max(c.index for c in group) + 1

    # Chunk chains: stage s+1 becomes ready when stage s completes.
    chain_len: dict[int, int] = {}
    for cid, s in tasks:
        chain_len[cid] = max(chain_len.get(cid, 0), s + 1)

    queues: list[list[StageTask]] = [[] for _ in range(num_dims)]
    busy_until = [0.0] * num_dims
    dim_busy = [0.0] * num_dims
    dim_wire = [0.0] * num_dims
    dim_order: list[list[OpId]] = [[] for _ in range(num_dims)]
    dim_services: list[list[ServiceInterval]] = [[] for _ in range(num_dims)]
    activity: list[list[tuple[float, float]]] = [[] for _ in range(num_dims)]
    pending_since = [None] * num_dims  # type: list[float | None]
    enforced_pos = [0] * num_dims
    group_finish = [t for t in issue_times]  # empty groups finish at issue
    resolved_issue = list(issue_times)       # dep mode: actual issue times
    straggler = [d.straggler_sigma for d in topology.dims]
    seq = itertools.count()

    # In-flight services, keyed by validity token (sid).  Preemption bumps a
    # service's sid so its already-scheduled free/done events become stale.
    services: dict[int, _Service] = {}
    inflight: list[_Service | None] = [None] * num_dims
    use_enforced = enforced_order is not None

    # Arrival hook (the fair-policy virtual-time clamp) + sanitizer baseline.
    on_enq = getattr(arbiter, "on_enqueued", None)
    served_base = (arbiter.served_snapshot()
                   if chk and hasattr(arbiter, "served_snapshot") else None)

    # Event heap: (time, tiebreak, kind, payload)
    events: list[tuple[float, int, str, object]] = []

    def push_ready(task: StageTask, t: float) -> None:
        task.ready_time = t
        task.arrival_seq = next(seq)
        heapq.heappush(events, (t, task.arrival_seq, "ready", task))

    # -- fault injection (repro.faults) --------------------------------------
    # Every fault structure and closure lives behind this one guard; when
    # ``flt`` is None the engine touches none of it (the fault-free path is
    # byte-for-byte the pre-fault engine — no extra seq/RNG consumption).
    flt = faults
    if flt is not None:
        flt_retry = flt.retry
        flt_bounds = flt.boundaries
        cur_factor = [1.0] * num_dims   # current BW multiplier per dim
        cur_sigma = [0.0] * num_dims    # extra straggler sigma per dim
        dim_down = [False] * num_dims
        group_started = [False] * n_groups  # any ready event popped yet?
        group_failed = [False] * n_groups
        group_retries = [0] * n_groups
        failed_log: list[tuple[int, float]] = []
        flt_att: dict[OpId, int] = {}   # retry attempts per op
        flt_ep: dict[OpId, int] = {}    # queue-residency epoch per op

        def flt_enq(task: StageTask, now: float) -> None:
            # New queue residency: bump the op's epoch (invalidating any
            # armed timeout) and, on a down dim, arm the retry timeout.
            op = task.op_id
            ep = flt_ep.get(op, 0) + 1
            flt_ep[op] = ep
            if dim_down[task.dim]:
                heapq.heappush(events, (now + flt_retry.timeout_s,
                                        next(seq), "timeout", (task, ep)))

        def flt_fail(g0: int, now: float) -> None:
            # Exhausted retries: fail the group, purge its queued work, and
            # fail dependents transitively (they can never be released).
            work = [g0]
            while work:
                g = work.pop()
                if group_failed[g] or (adm is not None and group_shed[g]):
                    continue  # a shed group's work is already gone
                group_failed[g] = True
                failed_log.append((g, now))
                if trc is not None:
                    trc.group_failed(g, now)
                for d in range(num_dims):
                    q = queues[d]
                    kept = [t for t in q if t.group != g]
                    if len(kept) != len(q):
                        for t in q:
                            if t.group == g:
                                flt_ep[t.op_id] = flt_ep.get(t.op_id, 0) + 1
                        queues[d][:] = kept
                if use_deps:
                    work.extend(dep_children[g])

        def flt_requeue(cut: list, now: float) -> None:
            for t in cut:
                if group_failed[t.group]:
                    continue
                queues[t.dim].append(t)
                if trc_enq is not None:
                    trc_enq(t.dim)
                    trc_enq_t(now)
                if on_enq is not None:
                    on_enq(t.dim, t.tenant, now)
                flt_enq(t, now)

        def flt_abort(dim: int, svc: _Service, now: float) -> None:
            # Outage hit an in-flight service: chunks whose data already
            # drained complete, the rest are cut and requeued — the same
            # byte-conserving split rule as arbiter preemption, except the
            # keep set may be empty (nothing drained yet).
            nonlocal makespan
            elapsed_bytes = (now - svc.start) * svc.rate
            keep: list[StageTask] = []
            acc = 0.0
            for t in svc.batch:
                if acc + t.wire_bytes > elapsed_bytes:
                    break
                keep.append(t)
                acc += t.wire_bytes
            cut = svc.batch[len(keep):]
            if not cut:
                return
            makespan = max(makespan, now)
            cut_wire = sum(t.wire_bytes for t in cut)
            dim_busy[dim] -= svc.end - now
            dim_wire[dim] -= cut_wire
            busy_until[dim] = now
            cut_ids = {t.op_id for t in cut}
            dim_order[dim] = [o for o in dim_order[dim] if o not in cut_ids]
            s0 = dim_services[dim][svc.svc_idx][0]
            groups_kept = (tuple(sorted({t.group for t in keep})) if keep
                           else dim_services[dim][svc.svc_idx].groups)
            dim_services[dim][svc.svc_idx] = ServiceInterval(
                s0, now, groups_kept)
            if trc is not None:
                trc.service_abort(dim, svc.svc_idx, now, len(keep),
                                  tuple(t.op_id for t in cut), cut_wire)
            services.pop(svc.sid)
            if keep:
                svc.sid = next(seq)
                svc.end = now
                svc.batch = keep
                services[svc.sid] = svc
                a = max(t.fixed_delay for t in keep)
                heapq.heappush(events, (now, next(seq), "free",
                                        (dim, svc.sid)))
                heapq.heappush(events, (now + a, next(seq), "done",
                                        (dim, svc.sid)))
            else:
                inflight[dim] = None
            flt_requeue(cut, now)
            if arbiter is not None:
                arbiter.on_preempted(dim, cut, now)

        def flt_outage_start(dim: int, now: float) -> None:
            # Arm retry timeouts for chunks already queued on the dim (the
            # in-flight cut below re-enters through flt_requeue -> flt_enq,
            # which arms its own), then cut the in-flight service.
            for t in sorted(queues[dim], key=lambda t: t.arrival_seq):
                heapq.heappush(events, (now + flt_retry.timeout_s,
                                        next(seq), "timeout",
                                        (t, flt_ep.get(t.op_id, 0))))
            svc = inflight[dim]
            if svc is not None and svc.end > now:
                flt_abort(dim, svc, now)

        def flt_recover(dim: int, now: float) -> None:
            # Invalidate every armed timeout on the dim: its queued chunks
            # are serviceable again.
            for t in queues[dim]:
                flt_ep[t.op_id] = flt_ep.get(t.op_id, 0) + 1

        def flt_timeout(task: StageTask, ep: int, now: float) -> None:
            op = task.op_id
            if (flt_ep.get(op, 0) != ep or group_failed[task.group]
                    or not dim_down[task.dim]):
                return  # stale arm: the chunk moved, failed, or recovered
            att = flt_att.get(op, 0) + 1
            flt_att[op] = att
            group_retries[task.group] += 1
            if att >= flt_retry.max_attempts:
                if trc is not None:
                    trc.retry(task.dim, op, now, att, now)
                flt_fail(task.group, now)
                return
            queues[task.dim].remove(task)
            delay = flt_retry.backoff_s * flt_retry.multiplier ** (att - 1)
            if flt_retry.jitter > 0.0:
                delay *= 1.0 + flt_retry.jitter * rng.random()
            if trc is not None:
                trc.retry(task.dim, op, now, att, now + delay)
            push_ready(task, now + delay)

        def flt_rerate(dim: int, svc: _Service, now: float,
                       scale: float) -> None:
            # BW changed under an in-flight service: bytes already drained
            # are conserved (virtual-start shift), the remainder continues
            # at the new rate.  ``scale`` is old_factor / new_factor.
            new_end = now + (svc.end - now) * scale
            dim_busy[dim] += new_end - svc.end
            busy_until[dim] = new_end
            svc.start = now - (now - svc.start) * scale
            svc.rate = svc.rate / scale
            iv = dim_services[dim][svc.svc_idx]
            dim_services[dim][svc.svc_idx] = ServiceInterval(
                iv.start, new_end, iv.groups)
            if trc is not None:
                trc.service_rerate(dim, svc.svc_idx, now, new_end, scale)
            services.pop(svc.sid)
            svc.sid = next(seq)
            svc.end = new_end
            services[svc.sid] = svc
            a = max(t.fixed_delay for t in svc.batch)
            heapq.heappush(events, (new_end, next(seq), "free",
                                    (dim, svc.sid)))
            heapq.heappush(events, (new_end + a, next(seq), "done",
                                    (dim, svc.sid)))

        def flt_replan(now: float) -> None:
            # Graceful degradation: recompute the paper's load-balancing
            # objective for every not-yet-started group against the
            # current per-dim BW and rewrite those groups' stage tasks.
            # Deterministic, no seq/RNG — both engines stay in lockstep.
            pend = [g for g in range(n_groups)
                    if not group_started[g] and not group_failed[g]
                    and (adm is None or not group_shed[g])
                    and chunk_groups[g]]
            if not pend:
                return
            pend.sort(key=lambda g: (resolved_issue[g], g))
            new_map = replanner(
                now, list(cur_factor),
                [(g, resolved_issue[g], chunk_groups[g]) for g in pend])
            applied = []
            for g in pend:
                new_chunks = new_map.get(g)
                if new_chunks is None:
                    continue
                old = chunk_groups[g]
                if len(new_chunks) != len(old):
                    raise ValueError(
                        f"replanner changed group {g}'s chunk count "
                        f"({len(old)} -> {len(new_chunks)})")
                gw = 0.0
                for oc, nc in zip(old, new_chunks):
                    if len(nc.schedule) != len(oc.schedule):
                        raise ValueError(
                            f"replanner changed group {g} chunk "
                            f"{oc.index}'s stage count")
                    dims_, wires_, fixeds_ = stage_sequence(
                        lm.stage_tables, oc.size_bytes, nc.schedule)
                    cid = oc.index + group_cid_offset[g]
                    for s in range(len(dims_)):
                        t = tasks[(cid, s)]
                        t.dim = dims_[s]
                        t.wire_bytes = wires_[s]
                        t.fixed_delay = fixeds_[s]
                        gw += wires_[s]
                group_wire[g] = gw
                applied.append(g)
            if trc is not None and applied:
                trc.replan(now, tuple(applied), tuple(cur_factor))

        def flt_boundary(bi: int, now: float) -> None:
            b = flt_bounds[bi]
            d = b.dim
            old_f = cur_factor[d]
            cur_factor[d] = b.factor
            cur_sigma[d] = b.sigma
            if trc is not None:
                trc.fault(d, now, b.factor, b.sigma)
            if b.down_start:
                dim_down[d] = True
                flt_outage_start(d, now)
            elif b.down_end:
                dim_down[d] = False
                flt_recover(d, now)
            elif b.bw_change:
                svc = inflight[d]
                if svc is not None and svc.end > now:
                    flt_rerate(d, svc, now, old_f / b.factor)
            if replanner is not None and b.bw_change:
                flt_replan(now)
            if b.down_end:
                try_start(d, now)

        # Boundaries enter the heap before any ready push, so at equal
        # timestamps a fault is applied before arrivals are served — the
        # indexed engine pushes in the same order (lockstep tie-breaks).
        for bi in range(len(flt_bounds)):
            heapq.heappush(events, (flt_bounds[bi].t, next(seq),
                                    "fault", bi))

    # -- admission control / load shedding (repro.fleet) ---------------------
    # The controller is consulted at each group's *first* ready pop — ready
    # pops are time-ordered and identical across engines, and the controller
    # consumes no seq/RNG, so shed sets are bit-identical by construction.
    # Victims are always pure queue residents (their unit never reached
    # service), so shedding purges queues and skips future events — nothing
    # in flight is ever cut.  When ``adm`` is None none of this state exists.
    adm = admission
    if adm is not None:
        adm.begin(n_groups, "reference")
        group_shed = [False] * n_groups
        adm_started = [False] * n_groups   # first ready pop seen?
        adm_first_svc = [False] * n_groups  # first service seen?
        shed_log: list[tuple[int, float]] = []

        def adm_apply(victims, now: float) -> None:
            # Shed the victim groups, purge their queued chunks, and shed
            # dependents transitively (a gated dependent can never issue).
            work = list(victims)
            while work:
                g = work.pop()
                if group_shed[g] or (flt is not None and group_failed[g]):
                    continue
                group_shed[g] = True
                shed_log.append((g, now))
                if trc is not None:
                    trc.group_shed(g, now)
                for d in range(num_dims):
                    q = queues[d]
                    kept = [t for t in q if t.group != g]
                    if len(kept) != len(q):
                        if flt is not None:
                            # Invalidate any armed retry timeouts.
                            for t in q:
                                if t.group == g:
                                    flt_ep[t.op_id] = (
                                        flt_ep.get(t.op_id, 0) + 1)
                        queues[d][:] = kept
                work.extend(dep_children[g])

    use_deps = deps is not None
    if use_deps:
        # Dependency-gated release.  A group's chunks enter the event stream
        # only once every predecessor group has fully finished (all chunk
        # chains retired) plus the group's compute delay.  Empty groups are
        # pure compute nodes: they finish at their eligibility instant and
        # cascade to their dependents immediately.
        group_roots: list[list[StageTask]] = [[] for _ in range(n_groups)]
        for cid in chain_len:
            group_roots[group_of_chunk[cid]].append(tasks[(cid, 0)])
        dep_children: list[list[int]] = [[] for _ in range(n_groups)]
        n_parents = [len(preds) for preds in deps]
        for g, preds in enumerate(deps):
            for p in preds:
                dep_children[p].append(g)
        parent_fin = [0.0] * n_groups   # running max of predecessor finishes
        chains_left = [len(group_roots[g]) for g in range(n_groups)]

        def complete_group(g: int, t: float) -> None:
            """Group ``g`` fully finished at ``t``: release newly-eligible
            dependents (empty dependents finish instantly and cascade)."""
            work = [(g, t)]
            while work:
                gg, tt = work.pop(0)
                if adm is not None:
                    adm.on_finish(gg, tt)
                for c in dep_children[gg]:
                    if trc is not None:
                        trc.dep_resolved(gg, c, tt)
                    if parent_fin[c] < tt:
                        parent_fin[c] = tt
                    n_parents[c] -= 1
                    if n_parents[c]:
                        continue
                    te = max(issue_times[c], parent_fin[c] + dep_delay[c])
                    resolved_issue[c] = te
                    if trc is not None:
                        trc.release(c, te)
                    if chains_left[c]:
                        for task in group_roots[c]:
                            push_ready(task, te)
                    else:
                        group_finish[c] = te
                        work.append((c, te))

        for g in range(n_groups):
            if deps[g]:
                continue
            te = issue_times[g] + dep_delay[g]
            resolved_issue[g] = te
            if trc is not None:
                trc.release(g, te)
            if chains_left[g]:
                for task in group_roots[g]:
                    push_ready(task, te)
            else:
                group_finish[g] = te
                complete_group(g, te)
    else:
        for cid in chain_len:
            push_ready(tasks[(cid, 0)], issue_times[group_of_chunk[cid]])

    def select_batch(dim: int, now: float) -> list[StageTask]:
        q = queues[dim]
        if not q:
            return []
        if arbiter is not None:
            # Inter-tenant discipline: the arbiter orders the ready queue;
            # same-tenant chunks batch into one multi-chunk (preemptible)
            # service up to the arbiter's quantum.
            q.sort(key=lambda t: arbiter.order_key(t, dim, now))
            batch = [q[0]]
            limit = max(1, getattr(arbiter, "quantum_chunks", 1))
            for t in q[1:]:
                if len(batch) >= limit:
                    break
                if t.tenant == batch[0].tenant:
                    batch.append(t)
            for t in batch:
                q.remove(t)
            return batch
        if enforced_order is not None:
            order = enforced_order[dim]
            pos = enforced_pos[dim]
            if pos >= len(order):
                return []
            want = order[pos]
            head = [t for t in q if t.op_id == want]
            if not head:
                return []  # idle until the mandated op arrives
            batch = [head[0]]
        else:
            if intra == "SCF":
                q.sort(key=lambda t: (-t.priority, t.wire_bytes, t.arrival_seq))
            else:  # FIFO
                q.sort(key=lambda t: (-t.priority, t.arrival_seq))
            batch = [q[0]]
        if fusion:
            bw = topology.dims[dim].aggr_bw_bytes
            sat_bytes = batch[0].fixed_delay * bw  # wire time < A  => unsaturated
            total = batch[0].wire_bytes
            if total < sat_bytes:
                pool = (
                    enforced_candidates(dim, batch[0])
                    if enforced_order is not None
                    else [t for t in q if t is not batch[0]]
                )
                for t in pool:
                    if len(batch) >= fusion_limit or total >= sat_bytes:
                        break
                    batch.append(t)
                    total += t.wire_bytes
        for t in batch:
            q.remove(t)
        if enforced_order is not None:
            enforced_pos[dim] += len(batch)
        return batch

    def enforced_candidates(dim: int, first: StageTask) -> list[StageTask]:
        """Ops that may fuse after ``first`` without violating the order."""
        order = enforced_order[dim]
        pos = enforced_pos[dim] + 1
        ready_ids = {t.op_id: t for t in queues[dim] if t is not first}
        out = []
        while pos < len(order) and order[pos] in ready_ids:
            out.append(ready_ids[order[pos]])
            pos += 1
        return out

    def try_start(dim: int, now: float) -> None:
        if busy_until[dim] > now:
            return
        if flt is not None:
            if dim_down[dim]:
                return  # fully-out dim: queued work waits on RetryPolicy
        batch = select_batch(dim, now)
        if not batch:
            return
        if adm is not None:
            for t in batch:
                if not adm_first_svc[t.group]:
                    adm_first_svc[t.group] = True
                    adm.on_serving(t.group, now)
        bw = topology.dims[dim].aggr_bw_bytes
        a = max(t.fixed_delay for t in batch)
        wire = sum(t.wire_bytes for t in batch)
        occupy = wire / bw  # dim is a BW resource; steps pipeline
        if jitter:
            occupy *= 1.0 + jitter * rng.random()
        if straggler[dim]:
            occupy *= rng.lognormvariate(0.0, straggler[dim])
        if flt is not None:
            f = cur_factor[dim]
            if f < 1.0:
                occupy = occupy / f  # degraded effective BW
            bs = cur_sigma[dim]
            if bs > 0.0:
                occupy *= rng.lognormvariate(0.0, bs)
        if chk and dim_services[dim]:
            check_service_start(dim, now, dim_services[dim][-1][1],
                                "reference")
        free_at = now + occupy
        busy_until[dim] = free_at
        dim_busy[dim] += occupy
        dim_wire[dim] += wire
        for t in batch:
            dim_order[dim].append(t.op_id)
        svc = _Service(
            sid=next(seq), dim=dim, start=now, end=free_at,
            rate=(wire / occupy) if occupy > 0 else float("inf"),
            batch=batch, svc_idx=len(dim_services[dim]))
        groups_served = tuple(sorted({t.group for t in batch}))
        dim_services[dim].append(ServiceInterval(now, free_at, groups_served))
        if trc is not None:
            trc.service_start(dim, now, free_at,
                              tuple(t.op_id for t in batch), groups_served,
                              batch[0].tenant, wire)
            if arbiter is not None:
                trc.grant(dim, now, batch[0].tenant, len(batch), wire)
        services[svc.sid] = svc
        inflight[dim] = svc
        if arbiter is not None:
            arbiter.on_served(dim, batch, now)
        # Chunk stages complete A after their data drains (latency term).
        heapq.heappush(events, (free_at, next(seq), "free", (dim, svc.sid)))
        heapq.heappush(events, (free_at + a, next(seq), "done", (dim, svc.sid)))

    def maybe_preempt(dim: int, cand: StageTask, now: float) -> None:
        """Split the in-flight service at chunk granularity if the arbiter
        rules the candidate should not wait behind it.  Chunks whose data
        already started draining complete; the rest requeue (no lost bytes).
        """
        svc = inflight[dim]
        if svc is None or len(svc.batch) <= 1:
            return
        if not arbiter.should_preempt(dim, svc.batch[0], cand, now):
            return
        elapsed_bytes = (now - svc.start) * svc.rate
        keep = [svc.batch[0]]
        acc = svc.batch[0].wire_bytes
        for t in svc.batch[1:]:
            if acc >= elapsed_bytes:  # this chunk has not started draining
                break
            keep.append(t)
            acc += t.wire_bytes
        cut = svc.batch[len(keep):]
        if not cut:
            return
        new_end = svc.start + acc / svc.rate
        cut_wire = sum(t.wire_bytes for t in cut)
        dim_busy[dim] -= svc.end - new_end
        dim_wire[dim] -= cut_wire
        busy_until[dim] = new_end
        cut_ids = {t.op_id for t in cut}
        dim_order[dim] = [o for o in dim_order[dim] if o not in cut_ids]
        s0 = dim_services[dim][svc.svc_idx][0]
        dim_services[dim][svc.svc_idx] = ServiceInterval(
            s0, new_end, tuple(sorted({t.group for t in keep})))
        if trc is not None:
            trc.service_preempt(dim, svc.svc_idx, now, new_end, len(keep),
                                tuple(t.op_id for t in cut), cut_wire,
                                penalty)
        services.pop(svc.sid)
        svc.sid = next(seq)
        svc.end = new_end
        svc.batch = keep
        services[svc.sid] = svc
        a = max(t.fixed_delay for t in keep)
        heapq.heappush(events, (new_end, next(seq), "free", (dim, svc.sid)))
        heapq.heappush(events, (new_end + a, next(seq), "done", (dim, svc.sid)))
        if penalty > 0:
            # Re-arm latency: preempted chunks re-arrive after the penalty
            # (the arrival hook fires at their re-arm ready event).
            for t in cut:
                push_ready(t, now + penalty)
        else:
            for t in cut:
                queues[dim].append(t)
                if trc_enq is not None:
                    trc_enq(dim)
                    trc_enq_t(now)
                if on_enq is not None:
                    on_enq(dim, t.tenant, now)
                if flt is not None:
                    flt_enq(t, now)
        arbiter.on_preempted(dim, cut, now)

    makespan = max(issue_times) if issue_times else 0.0
    while events:
        now, _, kind, payload = heapq.heappop(events)
        # NB: stale events (from preempted services) must not advance the
        # makespan — their timestamps no longer correspond to real work.
        if kind == "ready":
            task: StageTask = payload  # type: ignore[assignment]
            if flt is not None and group_failed[task.group]:
                continue  # abandoned work must not advance the makespan
            if adm is not None:
                g = task.group
                if group_shed[g]:
                    continue  # shed work must not advance the makespan
                if not adm_started[g]:
                    adm_started[g] = True
                    victims = adm.on_ready(g, now)
                    if victims is not None:
                        if victims:
                            adm_apply(victims, now)
                        if group_shed[g]:
                            continue  # the arrival itself was shed
                        if trc is not None:
                            trc.admit(g, now)
            makespan = max(makespan, now)
            if flt is not None:
                group_started[task.group] = True
            if pending_since[task.dim] is None:
                pending_since[task.dim] = now
            queues[task.dim].append(task)
            if trc_enq is not None:
                trc_enq(task.dim)
                trc_enq_t(now)
            if on_enq is not None:
                on_enq(task.dim, task.tenant, now)
            if flt is not None:
                flt_enq(task, now)
            if (arbiter is not None and getattr(arbiter, "preemption", False)
                    and busy_until[task.dim] > now):
                maybe_preempt(task.dim, task, now)
            try_start(task.dim, now)
            if chk and not use_enforced and (
                    flt is None or not dim_down[task.dim]):
                check_work_conserving(
                    task.dim, now, len(queues[task.dim]),
                    busy_until[task.dim], inflight[task.dim], "reference")
        elif kind == "free":
            dim, sid = payload  # type: ignore[misc]
            if sid not in services:
                continue  # stale: service was preempted and rescheduled
            makespan = max(makespan, now)
            if inflight[dim] is not None and inflight[dim].sid == sid:
                inflight[dim] = None
            if not queues[dim] and pending_since[dim] is not None:
                activity[dim].append((pending_since[dim], now))
                pending_since[dim] = None
            try_start(dim, now)
            if chk and not use_enforced and (
                    flt is None or not dim_down[dim]):
                check_work_conserving(dim, now, len(queues[dim]),
                                      busy_until[dim], inflight[dim],
                                      "reference")
        elif kind == "done":  # chunk's next stage becomes ready
            dim, sid = payload  # type: ignore[misc]
            svc = services.pop(sid, None)
            if svc is None:
                continue  # stale: service was preempted and rescheduled
            makespan = max(makespan, now)
            for t in svc.batch:
                if flt is not None and group_failed[t.group]:
                    continue  # failed mid-flight: chain abandoned
                if adm is not None and group_shed[t.group]:
                    continue  # shed mid-flight: chain abandoned
                nxt = (t.chunk_id, t.stage_idx + 1)
                if nxt in tasks:
                    push_ready(tasks[nxt], now)
                    continue
                if group_finish[t.group] < now:  # chunk chain retired
                    group_finish[t.group] = now
                    if arbiter is not None:
                        arbiter.on_group_finish(
                            t.group, t.tenant, now - resolved_issue[t.group])
                if use_deps:
                    chains_left[t.group] -= 1
                    if not chains_left[t.group]:
                        complete_group(t.group, now)
        elif flt is not None and kind == "fault":
            flt_boundary(payload, now)
        else:  # timeout (only scheduled when flt is armed)
            if flt is not None:
                task, ep = payload  # type: ignore[misc]
                flt_timeout(task, ep, now)

    for dim in range(num_dims):
        if pending_since[dim] is not None:  # pragma: no cover - safety
            activity[dim].append((pending_since[dim], makespan))

    if use_deps:
        for g in range(n_groups):
            if (n_parents[g] > 0 and (flt is None or not group_failed[g])
                    and (adm is None or not group_shed[g])):
                raise ValueError(
                    f"dependency cycle: group {g} never became eligible")
        if group_finish:
            # Trailing compute nodes finish after the last network event.
            makespan = max(makespan, max(group_finish))

    if chk:
        check_final(
            engine="reference", num_dims=num_dims,
            tasks=((op, t.dim, t.wire_bytes, t.tenant, t.group)
                   for op, t in tasks.items()),
            dim_wire=dim_wire, dim_busy=dim_busy, dim_order=dim_order,
            dim_services=dim_services, group_finish=group_finish,
            resolved_issue=resolved_issue, makespan=makespan,
            enforced=use_enforced, arbiter=arbiter, served_base=served_base,
            failed=(frozenset(g for g, _ in failed_log)
                    if flt is not None else None),
            shed=(frozenset(g for g, _ in shed_log)
                  if adm is not None else None))

    res = SimResult(makespan, dim_busy, dim_wire, activity, dim_order,
                    dim_services, resolved_issue, group_finish,
                    list(streams), list(tenants), group_wire)
    if flt is not None:
        res.failed_groups = failed_log
        res.group_retries = group_retries
    if adm is not None:
        res.shed_groups = shed_log
    if trc is not None:
        trc.finalize(res, topology)
    return res


# ---------------------------------------------------------------------------
# Indexed engine — struct-of-arrays tasks + indexed priority queues.
# ---------------------------------------------------------------------------
def _simulate_indexed(
    topology: Topology,
    chunk_groups: list[list[Chunk]],
    *,
    issue_times: list[float],
    priorities: list[int],
    intra: str,
    fusion: bool,
    fusion_limit: int,
    enforced_order: list[list[OpId]] | None,
    jitter: float,
    seed: int,
    tenants: list[str],
    streams: list[str],
    arbiter,
    penalty: float,
    task_arrays: TaskArrays | None = None,
    deps: list[tuple[int, ...]] | None = None,
    dep_delay: list[float] | None = None,
    chk: bool = False,
    tracer=None,
    faults=None,
    replanner=None,
    admission=None,
) -> SimResult:
    """Same semantics as :func:`_simulate_reference`, near-linear cost.

    Tasks live in preallocated parallel arrays (struct-of-arrays) addressed
    by integer handles; each dimension's ready queue is an indexed priority
    queue — a binary heap whose entries embed the discipline key, so a
    service start pops its batch in O(batch x log n) instead of sorting the
    whole queue and removing served tasks one by one.  Under an arbiter the
    queue is a per-(dim, tenant) bucket of heaps: quantum batching pops the
    winning tenant's bucket, and preemption pushes cut chunks back into it.

    Bit-equivalence with the reference engine is by construction: the
    tie-break counter (``seq``) and the jitter RNG are consumed in exactly
    the same order, heap keys replicate the reference sort keys (every key
    ends in the unique arrival seq, so total order is identical), and float
    accumulations run in the same sequence.
    """
    import random

    rng = random.Random(seed)
    lm = LatencyModel.for_topology(topology)
    tbl = lm.stage_tables
    num_dims = topology.num_dims
    n_groups = len(chunk_groups)

    # ---- struct-of-arrays task storage (integer handles) -------------------
    ta = task_arrays
    if ta is None:
        ta = build_task_arrays(lm, chunk_groups, priorities, tenants)
    n_tasks = ta.n_tasks
    t_chunk = ta.chunk
    t_stage = ta.stage
    t_dim = ta.dim
    t_wire = ta.wire
    t_fixed = ta.fixed
    t_group = ta.group
    t_prio = ta.prio
    t_tenant = ta.tenant
    t_last = ta.last
    first_handles = ta.first_handles
    # group_wire is returned inside SimResult — copy so a shared TaskArrays
    # (replayed across a batch of scenarios) can't be mutated via a result.
    group_wire = list(ta.group_wire)
    t_arr = [0] * n_tasks      # arrival seq (assigned when readied; per run)

    # ---- per-dim state ------------------------------------------------------
    busy_until = [0.0] * num_dims
    dim_busy = [0.0] * num_dims
    dim_wire = [0.0] * num_dims
    # Served op ids, one list per service (parallel to dim_services) — a
    # preemption replaces its own service's list instead of filtering the
    # whole per-dim history (which made preemption storms quadratic).  The
    # flat per-dim order is concatenated at the end; a preempted service is
    # always the tail segment of its dim's history at split time, so the
    # concatenation equals the reference engine's incremental filtering.
    svc_ops: list[list[list[OpId]]] = [[] for _ in range(num_dims)]
    dim_services: list[list[ServiceInterval]] = [[] for _ in range(num_dims)]
    activity: list[list[tuple[float, float]]] = [[] for _ in range(num_dims)]
    pending_since: list[float | None] = [None] * num_dims
    enforced_pos = [0] * num_dims
    qlen = [0] * num_dims
    group_finish = [t for t in issue_times]
    resolved_issue = list(issue_times)       # dep mode: actual issue times
    straggler = [d.straggler_sigma for d in topology.dims]
    seq = itertools.count()
    services: dict[int, _Service] = {}
    inflight: list[_Service | None] = [None] * num_dims
    events: list[tuple] = []
    dim_bw = tbl.bw

    # Arrival hook (the fair-policy virtual-time clamp) + sanitizer baseline.
    on_enq = getattr(arbiter, "on_enqueued", None)
    served_base = (arbiter.served_snapshot()
                   if chk and hasattr(arbiter, "served_snapshot") else None)

    # Flight recorder (repro.obs.Tracer).  Hooks are append-only and never
    # consume seq/RNG, so armed runs stay bit-identical to untraced ones.
    trc = tracer
    if trc is not None:
        trc.begin(num_dims, n_groups, "indexed")
    trc_enq = trc.enq_dims.append if trc is not None else None
    trc_enq_t = trc.enq_times.append if trc is not None else None

    # Ready-queue index, one flavor per mode:
    #  * plain: per-dim heap keyed by the intra discipline;
    #  * arbiter: per-(dim, tenant) bucket heaps (quantum batching / preempt
    #    requeue pop and push per-tenant);
    #  * enforced: per-dim {op_id: handle} map (service order is dictated,
    #    so the "queue" only answers membership).
    use_arbiter = arbiter is not None
    use_enforced = enforced_order is not None
    scf = intra == "SCF"
    heaps: list[list] = [[] for _ in range(num_dims)]
    buckets: list[dict[str, list]] = [{} for _ in range(num_dims)]
    ready_map: list[dict[OpId, int]] = [{} for _ in range(num_dims)]
    if use_arbiter:
        arb_policy = arbiter.policy
        arb_fair = arb_policy in ("weighted-fair", "slo-aware")
        arb_quantum = max(1, getattr(arbiter, "quantum_chunks", 1))
        arb_preempt = getattr(arbiter, "preemption", False)
        arb_vt = arbiter.virtual_time
        # StageTask views handed to arbiter hooks (materialized lazily).
        views: list[StageTask | None] = [None] * n_tasks

        def view(hh: int) -> StageTask:
            v = views[hh]
            if v is None:
                v = views[hh] = StageTask(
                    chunk_id=t_chunk[hh], stage_idx=t_stage[hh],
                    dim=t_dim[hh], wire_bytes=t_wire[hh],
                    fixed_delay=t_fixed[hh], group=t_group[hh],
                    priority=t_prio[hh], tenant=t_tenant[hh])
            v.arrival_seq = t_arr[hh]
            return v

    def push_ready(hh: int, t: float) -> None:
        s = next(seq)
        t_arr[hh] = s
        heapq.heappush(events, (t, s, 0, hh))  # kind 0 = ready

    # -- lazy queue deletion (shared by faults and admission) ----------------
    # Queue membership under faults or admission uses lazy heap deletion:
    # ``t_inq`` plus the arrival seq embedded in every heap entry decide
    # whether an entry is alive (a purged/retried/shed handle's stale
    # entries are skipped on pop).  When neither is armed none of this
    # state exists and select_batch takes the branch-free fast path.
    flt = faults
    adm = admission
    lazyq = (flt is not None) or (adm is not None)
    if lazyq:
        t_inq = [False] * n_tasks  # currently queued?
        # Group -> contiguous handle range (build order groups handles).
        group_h0 = [n_tasks] * n_groups
        group_h1 = [0] * n_groups
        for hh in range(n_tasks):
            g = t_group[hh]
            if hh < group_h0[g]:
                group_h0[g] = hh
            group_h1[g] = hh + 1

        def q_alive(entry) -> bool:
            hh = entry[-1]
            return t_inq[hh] and entry[-2] == t_arr[hh]

    # -- fault injection (repro.faults) --------------------------------------
    # Mirrors the reference engine's fault block event-for-event (same seq
    # and RNG consumption order); when ``flt`` is None none of this state
    # exists and the engine is byte-for-byte the pre-fault engine.
    if flt is not None:
        flt_retry = flt.retry
        flt_bounds = flt.boundaries
        cur_factor = [1.0] * num_dims
        cur_sigma = [0.0] * num_dims
        dim_down = [False] * num_dims
        group_started = [False] * n_groups
        group_failed = [False] * n_groups
        group_retries = [0] * n_groups
        failed_log: list[tuple[int, float]] = []
        t_att = [0] * n_tasks      # retry attempts per op
        t_ep = [0] * n_tasks       # queue-residency epoch per op
        if replanner is not None:
            # Replanning rewrites stage tasks in place — copy the (possibly
            # shared/replayed) TaskArrays columns it touches.
            t_dim = list(t_dim)
            t_wire = list(t_wire)
            t_fixed = list(t_fixed)

        def flt_enq(hh: int, now: float) -> None:
            t_ep[hh] += 1
            if dim_down[t_dim[hh]]:
                heapq.heappush(events, (now + flt_retry.timeout_s,
                                        next(seq), 4, (hh, t_ep[hh])))

        def flt_queued(dim: int) -> list[int]:
            # Alive queued handles on ``dim`` in arrival order — the same
            # set and order as the reference engine's queue scan.
            if use_arbiter:
                entries = [e for heap in buckets[dim].values() for e in heap]
            else:
                entries = heaps[dim]
            out = [e[-1] for e in entries if q_alive(e)]
            out.sort(key=t_arr.__getitem__)
            return out

        def flt_fail(g0: int, now: float) -> None:
            work = [g0]
            while work:
                g = work.pop()
                if group_failed[g] or (adm is not None and group_shed[g]):
                    continue  # a shed group's work is already gone
                group_failed[g] = True
                failed_log.append((g, now))
                if trc is not None:
                    trc.group_failed(g, now)
                for hh in range(group_h0[g], group_h1[g]):
                    if t_inq[hh]:
                        t_inq[hh] = False
                        t_ep[hh] += 1
                        qlen[t_dim[hh]] -= 1
                if use_deps:
                    work.extend(dep_children[g])

        def flt_abort(dim: int, svc: _Service, now: float) -> None:
            nonlocal makespan
            elapsed_bytes = (now - svc.start) * svc.rate
            keep: list[int] = []
            acc = 0.0
            for hh in svc.batch:
                if acc + t_wire[hh] > elapsed_bytes:
                    break
                keep.append(hh)
                acc += t_wire[hh]
            cut = svc.batch[len(keep):]
            if not cut:
                return
            if now > makespan:
                makespan = now
            cut_wire = sum(t_wire[hh] for hh in cut)
            dim_busy[dim] -= svc.end - now
            dim_wire[dim] -= cut_wire
            busy_until[dim] = now
            svc_ops[dim][svc.svc_idx] = [(t_chunk[hh], t_stage[hh])
                                         for hh in keep]
            s0 = dim_services[dim][svc.svc_idx][0]
            groups_kept = (tuple(sorted({t_group[hh] for hh in keep}))
                           if keep
                           else dim_services[dim][svc.svc_idx].groups)
            dim_services[dim][svc.svc_idx] = ServiceInterval(
                s0, now, groups_kept)
            if trc is not None:
                trc.service_abort(dim, svc.svc_idx, now, len(keep),
                                  tuple((t_chunk[hh], t_stage[hh])
                                        for hh in cut), cut_wire)
            services.pop(svc.sid)
            if keep:
                svc.sid = next(seq)
                svc.end = now
                svc.batch = keep
                services[svc.sid] = svc
                a = max(t_fixed[hh] for hh in keep)
                heapq.heappush(events, (now, next(seq), 1, (dim, svc.sid)))
                heapq.heappush(events, (now + a, next(seq), 2,
                                        (dim, svc.sid)))
            else:
                inflight[dim] = None
            for hh in cut:
                if not group_failed[t_group[hh]]:
                    enqueue(hh, now)
            if use_arbiter:
                arbiter.on_preempted(dim, [view(hh) for hh in cut], now)

        def flt_outage_start(dim: int, now: float) -> None:
            for hh in flt_queued(dim):
                heapq.heappush(events, (now + flt_retry.timeout_s,
                                        next(seq), 4, (hh, t_ep[hh])))
            svc = inflight[dim]
            if svc is not None and svc.end > now:
                flt_abort(dim, svc, now)

        def flt_recover(dim: int, now: float) -> None:
            for hh in flt_queued(dim):
                t_ep[hh] += 1

        def flt_timeout(hh: int, ep: int, now: float) -> None:
            if (t_ep[hh] != ep or group_failed[t_group[hh]]
                    or not dim_down[t_dim[hh]]):
                return  # stale arm: the chunk moved, failed, or recovered
            att = t_att[hh] + 1
            t_att[hh] = att
            group_retries[t_group[hh]] += 1
            if att >= flt_retry.max_attempts:
                if trc is not None:
                    trc.retry(t_dim[hh], (t_chunk[hh], t_stage[hh]),
                              now, att, now)
                flt_fail(t_group[hh], now)
                return
            t_inq[hh] = False
            qlen[t_dim[hh]] -= 1
            delay = flt_retry.backoff_s * flt_retry.multiplier ** (att - 1)
            if flt_retry.jitter > 0.0:
                delay *= 1.0 + flt_retry.jitter * rng.random()
            if trc is not None:
                trc.retry(t_dim[hh], (t_chunk[hh], t_stage[hh]), now, att,
                          now + delay)
            push_ready(hh, now + delay)

        def flt_rerate(dim: int, svc: _Service, now: float,
                       scale: float) -> None:
            new_end = now + (svc.end - now) * scale
            dim_busy[dim] += new_end - svc.end
            busy_until[dim] = new_end
            svc.start = now - (now - svc.start) * scale
            svc.rate = svc.rate / scale
            iv = dim_services[dim][svc.svc_idx]
            dim_services[dim][svc.svc_idx] = ServiceInterval(
                iv.start, new_end, iv.groups)
            if trc is not None:
                trc.service_rerate(dim, svc.svc_idx, now, new_end, scale)
            services.pop(svc.sid)
            svc.sid = next(seq)
            svc.end = new_end
            services[svc.sid] = svc
            a = max(t_fixed[hh] for hh in svc.batch)
            heapq.heappush(events, (new_end, next(seq), 1, (dim, svc.sid)))
            heapq.heappush(events, (new_end + a, next(seq), 2,
                                    (dim, svc.sid)))

        def flt_replan(now: float) -> None:
            pend = [g for g in range(n_groups)
                    if not group_started[g] and not group_failed[g]
                    and (adm is None or not group_shed[g])
                    and chunk_groups[g]]
            if not pend:
                return
            pend.sort(key=lambda g: (resolved_issue[g], g))
            new_map = replanner(
                now, list(cur_factor),
                [(g, resolved_issue[g], chunk_groups[g]) for g in pend])
            applied = []
            for g in pend:
                new_chunks = new_map.get(g)
                if new_chunks is None:
                    continue
                old = chunk_groups[g]
                if len(new_chunks) != len(old):
                    raise ValueError(
                        f"replanner changed group {g}'s chunk count "
                        f"({len(old)} -> {len(new_chunks)})")
                gw = 0.0
                hh = group_h0[g]
                for oc, nc in zip(old, new_chunks):
                    if len(nc.schedule) != len(oc.schedule):
                        raise ValueError(
                            f"replanner changed group {g} chunk "
                            f"{oc.index}'s stage count")
                    dims_, wires_, fixeds_ = stage_sequence(
                        tbl, oc.size_bytes, nc.schedule)
                    for s in range(len(dims_)):
                        t_dim[hh] = dims_[s]
                        t_wire[hh] = wires_[s]
                        t_fixed[hh] = fixeds_[s]
                        gw += wires_[s]
                        hh += 1
                group_wire[g] = gw
                applied.append(g)
            if trc is not None and applied:
                trc.replan(now, tuple(applied), tuple(cur_factor))

        def flt_boundary(bi: int, now: float) -> None:
            b = flt_bounds[bi]
            d = b.dim
            old_f = cur_factor[d]
            cur_factor[d] = b.factor
            cur_sigma[d] = b.sigma
            if trc is not None:
                trc.fault(d, now, b.factor, b.sigma)
            if b.down_start:
                dim_down[d] = True
                flt_outage_start(d, now)
            elif b.down_end:
                dim_down[d] = False
                flt_recover(d, now)
            elif b.bw_change:
                svc = inflight[d]
                if svc is not None and svc.end > now:
                    flt_rerate(d, svc, now, old_f / b.factor)
            if replanner is not None and b.bw_change:
                flt_replan(now)
            if b.down_end:
                try_start(d, now)

        for bi in range(len(flt_bounds)):
            heapq.heappush(events, (flt_bounds[bi].t, next(seq), 3, bi))

    # -- admission control / load shedding (repro.fleet) ---------------------
    # Mirror of the reference engine's admission block (same call sites,
    # same event order; the controller consumes no seq/RNG).  Shed purges
    # flip ``t_inq`` (lazy heap deletion) instead of filtering queue lists.
    if adm is not None:
        adm.begin(n_groups, "indexed")
        group_shed = [False] * n_groups
        adm_started = [False] * n_groups   # first ready pop seen?
        adm_first_svc = [False] * n_groups  # first service seen?
        shed_log: list[tuple[int, float]] = []

        def adm_apply(victims, now: float) -> None:
            # Shed the victim groups, purge their queued chunks, and shed
            # dependents transitively (a gated dependent can never issue).
            work = list(victims)
            while work:
                g = work.pop()
                if group_shed[g] or (flt is not None and group_failed[g]):
                    continue
                group_shed[g] = True
                shed_log.append((g, now))
                if trc is not None:
                    trc.group_shed(g, now)
                for hh in range(group_h0[g], group_h1[g]):
                    if t_inq[hh]:
                        t_inq[hh] = False
                        qlen[t_dim[hh]] -= 1
                        if flt is not None:
                            t_ep[hh] += 1  # invalidate armed timeouts
                work.extend(dep_children[g])

    use_deps = deps is not None
    if use_deps:
        # Dependency-gated release — mirrors the reference engine exactly
        # (same release order, so the seq counter stays in lockstep).
        group_first: list[list[int]] = [[] for _ in range(n_groups)]
        for hh in first_handles:
            group_first[t_group[hh]].append(hh)
        dep_children: list[list[int]] = [[] for _ in range(n_groups)]
        n_parents = [len(preds) for preds in deps]
        for g, preds in enumerate(deps):
            for p in preds:
                dep_children[p].append(g)
        parent_fin = [0.0] * n_groups
        chains_left = [len(group_first[g]) for g in range(n_groups)]

        def complete_group(g: int, t: float) -> None:
            work = [(g, t)]
            while work:
                gg, tt = work.pop(0)
                if adm is not None:
                    adm.on_finish(gg, tt)
                for c in dep_children[gg]:
                    if trc is not None:
                        trc.dep_resolved(gg, c, tt)
                    if parent_fin[c] < tt:
                        parent_fin[c] = tt
                    n_parents[c] -= 1
                    if n_parents[c]:
                        continue
                    te = max(issue_times[c], parent_fin[c] + dep_delay[c])
                    resolved_issue[c] = te
                    if trc is not None:
                        trc.release(c, te)
                    if chains_left[c]:
                        for hh in group_first[c]:
                            push_ready(hh, te)
                    else:
                        group_finish[c] = te
                        work.append((c, te))

        for g in range(n_groups):
            if deps[g]:
                continue
            te = issue_times[g] + dep_delay[g]
            resolved_issue[g] = te
            if trc is not None:
                trc.release(g, te)
            if chains_left[g]:
                for hh in group_first[g]:
                    push_ready(hh, te)
            else:
                group_finish[g] = te
                complete_group(g, te)
    else:
        for hh in first_handles:
            push_ready(hh, issue_times[t_group[hh]])

    def enqueue(hh: int, now: float) -> None:
        dim = t_dim[hh]
        qlen[dim] += 1
        if trc_enq is not None:
            trc_enq(dim)
            trc_enq_t(now)
        if use_arbiter:
            b = buckets[dim]
            tn = t_tenant[hh]
            heap = b.get(tn)
            if heap is None:
                heap = b[tn] = []
            if arb_fair:
                heapq.heappush(heap, (t_wire[hh], t_arr[hh], hh))
            else:  # fifo / strict-priority order by arrival within a tenant
                heapq.heappush(heap, (t_arr[hh], hh))
            if on_enq is not None:
                on_enq(dim, tn, now)
        elif use_enforced:
            ready_map[dim][(t_chunk[hh], t_stage[hh])] = hh
        elif scf:
            heapq.heappush(heaps[dim],
                           (-t_prio[hh], t_wire[hh], t_arr[hh], hh))
        else:
            heapq.heappush(heaps[dim], (-t_prio[hh], t_arr[hh], hh))
        if lazyq:
            t_inq[hh] = True
        if flt is not None:
            flt_enq(hh, now)

    def select_batch(dim: int, now: float) -> list[int]:
        if not qlen[dim]:
            return []
        if use_arbiter:
            b = buckets[dim]
            if lazyq:
                # Lazy deletion: drop stale heads (purged/retried/shed
                # handles) so the head-peek below only sees alive entries.
                dead = []
                for tn, heap in b.items():
                    while heap and not q_alive(heap[0]):
                        heapq.heappop(heap)
                    if not heap:
                        dead.append(tn)
                for tn in dead:
                    del b[tn]
                if not b:
                    return []
            best_tn = None
            best_key = None
            # The reference sorts the whole queue by arbiter.order_key and
            # serves the head tenant; here the winning tenant is the min
            # over bucket heads of the same key (within a tenant the key is
            # static, so the bucket heap order equals the sorted order).
            for tn, heap in b.items():
                head = heap[0]
                if arb_fair:
                    key = (arb_vt(dim, tn), head[0], head[1])
                elif arb_policy == "strict-priority":
                    key = (-arbiter.spec(tn).priority, head[0])
                else:  # fifo
                    key = (head[0],)
                if best_key is None or key < best_key:
                    best_key, best_tn = key, tn
            heap = b[best_tn]
            batch = []
            while heap and len(batch) < arb_quantum:
                if lazyq:
                    if not q_alive(heap[0]):
                        heapq.heappop(heap)
                        continue
                batch.append(heapq.heappop(heap)[-1])
            if not heap:
                del b[best_tn]
            qlen[dim] -= len(batch)
            if lazyq:
                for hh in batch:
                    t_inq[hh] = False
            return batch
        if use_enforced:
            order = enforced_order[dim]
            pos = enforced_pos[dim]
            if pos >= len(order):
                return []
            rm = ready_map[dim]
            h0 = rm.get(order[pos])
            if h0 is None:
                return []  # idle until the mandated op arrives
            batch = [h0]
            if fusion:
                sat = t_fixed[h0] * dim_bw[dim]
                total = t_wire[h0]
                p = pos + 1
                while (total < sat and len(batch) < fusion_limit
                       and p < len(order) and order[p] in rm):
                    hh = rm[order[p]]
                    batch.append(hh)
                    total += t_wire[hh]
                    p += 1
            for hh in batch:
                del rm[(t_chunk[hh], t_stage[hh])]
            enforced_pos[dim] += len(batch)
            qlen[dim] -= len(batch)
            return batch
        heap = heaps[dim]
        if lazyq:
            while heap and not q_alive(heap[0]):
                heapq.heappop(heap)
            if not heap:
                return []
        h0 = heapq.heappop(heap)[-1]
        batch = [h0]
        if fusion:
            sat = t_fixed[h0] * dim_bw[dim]
            total = t_wire[h0]
            while heap and total < sat and len(batch) < fusion_limit:
                if lazyq:
                    if not q_alive(heap[0]):
                        heapq.heappop(heap)
                        continue
                hh = heapq.heappop(heap)[-1]
                batch.append(hh)
                total += t_wire[hh]
        qlen[dim] -= len(batch)
        if lazyq:
            for hh in batch:
                t_inq[hh] = False
        return batch

    def try_start(dim: int, now: float) -> None:
        if busy_until[dim] > now:
            return
        if flt is not None:
            if dim_down[dim]:
                return  # fully-out dim: queued work waits on RetryPolicy
        batch = select_batch(dim, now)
        if not batch:
            return
        if adm is not None:
            for hh in batch:
                if not adm_first_svc[t_group[hh]]:
                    adm_first_svc[t_group[hh]] = True
                    adm.on_serving(t_group[hh], now)
        a = 0.0
        wire = 0.0
        for hh in batch:
            if t_fixed[hh] > a:
                a = t_fixed[hh]
            wire += t_wire[hh]
        occupy = wire / dim_bw[dim]
        if jitter:
            occupy *= 1.0 + jitter * rng.random()
        if straggler[dim]:
            occupy *= rng.lognormvariate(0.0, straggler[dim])
        if flt is not None:
            f = cur_factor[dim]
            if f < 1.0:
                occupy = occupy / f  # degraded effective BW
            bs = cur_sigma[dim]
            if bs > 0.0:
                occupy *= rng.lognormvariate(0.0, bs)
        if chk and dim_services[dim]:
            check_service_start(dim, now, dim_services[dim][-1][1],
                                "indexed")
        free_at = now + occupy
        busy_until[dim] = free_at
        dim_busy[dim] += occupy
        dim_wire[dim] += wire
        ops = [(t_chunk[hh], t_stage[hh]) for hh in batch]
        svc_ops[dim].append(ops)
        svc = _Service(
            sid=next(seq), dim=dim, start=now, end=free_at,
            rate=(wire / occupy) if occupy > 0 else float("inf"),
            batch=batch, svc_idx=len(dim_services[dim]))
        groups_served = tuple(sorted({t_group[hh] for hh in batch}))
        dim_services[dim].append(ServiceInterval(now, free_at, groups_served))
        if trc is not None:
            # Share the engine's own op list — preemption replaces (never
            # mutates) the ``svc_ops`` entry, so the tracer's reference
            # stays a faithful snapshot without a per-service copy.
            trc.service_start(dim, now, free_at, ops, groups_served,
                              t_tenant[batch[0]], wire)
            if use_arbiter:
                trc.grant(dim, now, t_tenant[batch[0]], len(batch), wire)
        services[svc.sid] = svc
        inflight[dim] = svc
        if use_arbiter:
            arbiter.on_served(dim, [view(hh) for hh in batch], now)
        heapq.heappush(events, (free_at, next(seq), 1, (dim, svc.sid)))
        heapq.heappush(events, (free_at + a, next(seq), 2, (dim, svc.sid)))

    def maybe_preempt(dim: int, cand: int, now: float) -> None:
        svc = inflight[dim]
        if svc is None or len(svc.batch) <= 1:
            return
        if not arbiter.should_preempt(dim, view(svc.batch[0]), view(cand), now):
            return
        elapsed_bytes = (now - svc.start) * svc.rate
        keep = [svc.batch[0]]
        acc = t_wire[svc.batch[0]]
        for hh in svc.batch[1:]:
            if acc >= elapsed_bytes:  # this chunk has not started draining
                break
            keep.append(hh)
            acc += t_wire[hh]
        cut = svc.batch[len(keep):]
        if not cut:
            return
        new_end = svc.start + acc / svc.rate
        cut_wire = sum(t_wire[hh] for hh in cut)
        dim_busy[dim] -= svc.end - new_end
        dim_wire[dim] -= cut_wire
        busy_until[dim] = new_end
        svc_ops[dim][svc.svc_idx] = [(t_chunk[hh], t_stage[hh])
                                     for hh in keep]
        s0 = dim_services[dim][svc.svc_idx][0]
        dim_services[dim][svc.svc_idx] = ServiceInterval(
            s0, new_end, tuple(sorted({t_group[hh] for hh in keep})))
        if trc is not None:
            trc.service_preempt(dim, svc.svc_idx, now, new_end, len(keep),
                                tuple((t_chunk[hh], t_stage[hh])
                                      for hh in cut), cut_wire, penalty)
        services.pop(svc.sid)
        svc.sid = next(seq)
        svc.end = new_end
        svc.batch = keep
        services[svc.sid] = svc
        a = max(t_fixed[hh] for hh in keep)
        heapq.heappush(events, (new_end, next(seq), 1, (dim, svc.sid)))
        heapq.heappush(events, (new_end + a, next(seq), 2, (dim, svc.sid)))
        if penalty > 0:
            for hh in cut:
                push_ready(hh, now + penalty)
        else:
            for hh in cut:
                enqueue(hh, now)
        arbiter.on_preempted(dim, [view(hh) for hh in cut], now)

    makespan = max(issue_times) if issue_times else 0.0
    while events:
        now, _, kind, payload = heapq.heappop(events)
        if kind == 0:  # ready
            hh = payload
            if flt is not None and group_failed[t_group[hh]]:
                continue  # abandoned work must not advance the makespan
            if adm is not None:
                g = t_group[hh]
                if group_shed[g]:
                    continue  # shed work must not advance the makespan
                if not adm_started[g]:
                    adm_started[g] = True
                    victims = adm.on_ready(g, now)
                    if victims is not None:
                        if victims:
                            adm_apply(victims, now)
                        if group_shed[g]:
                            continue  # the arrival itself was shed
                        if trc is not None:
                            trc.admit(g, now)
            if now > makespan:
                makespan = now
            if flt is not None:
                group_started[t_group[hh]] = True
            dim = t_dim[hh]
            if pending_since[dim] is None:
                pending_since[dim] = now
            enqueue(hh, now)
            if use_arbiter and arb_preempt and busy_until[dim] > now:
                maybe_preempt(dim, hh, now)
            try_start(dim, now)
            if chk and not use_enforced and (
                    flt is None or not dim_down[dim]):
                check_work_conserving(dim, now, qlen[dim], busy_until[dim],
                                      inflight[dim], "indexed")
        elif kind == 1:  # free
            dim, sid = payload
            if sid not in services:
                continue  # stale: service was preempted and rescheduled
            if now > makespan:
                makespan = now
            cur = inflight[dim]
            if cur is not None and cur.sid == sid:
                inflight[dim] = None
            if not qlen[dim] and pending_since[dim] is not None:
                activity[dim].append((pending_since[dim], now))
                pending_since[dim] = None
            try_start(dim, now)
            if chk and not use_enforced and (
                    flt is None or not dim_down[dim]):
                check_work_conserving(dim, now, qlen[dim], busy_until[dim],
                                      inflight[dim], "indexed")
        elif kind == 2:  # done — chunk's next stage becomes ready
            dim, sid = payload
            svc = services.pop(sid, None)
            if svc is None:
                continue  # stale: service was preempted and rescheduled
            if now > makespan:
                makespan = now
            for hh in svc.batch:
                if flt is not None and group_failed[t_group[hh]]:
                    continue  # failed mid-flight: chain abandoned
                if adm is not None and group_shed[t_group[hh]]:
                    continue  # shed mid-flight: chain abandoned
                if not t_last[hh]:
                    push_ready(hh + 1, now)  # stages are contiguous handles
                    continue
                g = t_group[hh]
                if group_finish[g] < now:  # chunk chain retired
                    group_finish[g] = now
                    if use_arbiter:
                        arbiter.on_group_finish(
                            g, t_tenant[hh], now - resolved_issue[g])
                if use_deps:
                    chains_left[g] -= 1
                    if not chains_left[g]:
                        complete_group(g, now)
        elif flt is not None and kind == 3:  # fault boundary
            flt_boundary(payload, now)
        else:  # timeout (only scheduled when flt is armed)
            if flt is not None:
                hh, ep = payload
                flt_timeout(hh, ep, now)

    for dim in range(num_dims):
        if pending_since[dim] is not None:  # pragma: no cover - safety
            activity[dim].append((pending_since[dim], makespan))

    if use_deps:
        for g in range(n_groups):
            if (n_parents[g] > 0 and (flt is None or not group_failed[g])
                    and (adm is None or not group_shed[g])):
                raise ValueError(
                    f"dependency cycle: group {g} never became eligible")
        if group_finish:
            # Trailing compute nodes finish after the last network event.
            makespan = max(makespan, max(group_finish))

    dim_order: list[list[OpId]] = [
        [op for ops in svc_ops[dim] for op in ops] for dim in range(num_dims)]
    if chk:
        check_final(
            engine="indexed", num_dims=num_dims,
            tasks=(((t_chunk[h], t_stage[h]), t_dim[h], t_wire[h],
                    t_tenant[h], t_group[h]) for h in range(n_tasks)),
            dim_wire=dim_wire, dim_busy=dim_busy, dim_order=dim_order,
            dim_services=dim_services, group_finish=group_finish,
            resolved_issue=resolved_issue, makespan=makespan,
            enforced=use_enforced, arbiter=arbiter, served_base=served_base,
            failed=(frozenset(g for g, _ in failed_log)
                    if flt is not None else None),
            shed=(frozenset(g for g, _ in shed_log)
                  if adm is not None else None))
    res = SimResult(makespan, dim_busy, dim_wire, activity, dim_order,
                    dim_services, resolved_issue, group_finish,
                    list(streams), list(tenants), group_wire)
    if flt is not None:
        res.failed_groups = failed_log
        res.group_retries = group_retries
    if adm is not None:
        res.shed_groups = shed_log
    if trc is not None:
        trc.finalize(res, topology)
    return res


def simulate_scheduled(
    topology: Topology,
    collective: str,
    size_bytes: float,
    *,
    policy: str = "themis",
    chunks_per_collective: int = 64,
    intra: str = "SCF",
    fusion: bool = True,
    water_filling: bool = False,
    engine: str = "indexed",
    check_invariants: bool = False,
    tracer=None,
    faults=None,
    replan: bool = False,
) -> tuple[SimResult, list[Chunk]]:
    """Schedule one collective with ``policy`` and simulate it.

    ``faults``/``replan``: fault timeline and the graceful-degradation
    re-planning hook (built for this topology/policy when ``replan``).
    ``engine`` passes through to :func:`simulate` — ``"compiled"`` runs
    the cohort-vectorized fast path (bit-identical; falls back to indexed
    with the documented signal when ``tracer``/``faults`` are armed).
    """
    from repro.core.scheduler import schedule_collective

    if replan and faults is None:
        raise ValueError("replan=True requires faults")
    chunks = schedule_collective(
        topology,
        collective,
        size_bytes,
        chunks_per_collective,
        policy,
        water_filling=water_filling,
    )
    replanner = None
    if replan:
        from repro.faults.replan import make_replanner

        replanner = make_replanner(topology, policy)
    res = simulate(topology, [chunks], intra=intra, fusion=fusion,
                   engine=engine, check_invariants=check_invariants,
                   tracer=tracer, faults=faults, replanner=replanner)
    return res, chunks


def simulate_requests(
    topology: Topology,
    requests: list[CollectiveRequest],
    *,
    policy: str = "themis",
    chunks_per_collective: int = 64,
    intra: str = "SCF",
    fusion: bool = True,
    water_filling: bool = False,
    arbiter=None,
    preempt_penalty_s: float | None = None,
    engine: str = "indexed",
    scheduler=None,
    check_invariants: bool = False,
    tracer=None,
    faults=None,
    replan: bool = False,
) -> tuple[SimResult, list[list[Chunk]]]:
    """Online entry point: schedule and simulate an arrival-time-aware
    request stream.

    Requests are scheduled in issue order through one ``ThemisScheduler``
    whose Dim Load Tracker runs *across* requests (``schedule_request``), so
    each collective's chunk orders account for the residual load of every
    collective still in flight.  The returned chunk groups are indexed like
    ``requests``; ``SimResult.group_issue``/``group_finish`` give each
    request's service window.  For multi-tenant streams this is the
    *shared-tracker* mode (one fabric-wide load view); see
    ``repro.tenancy.simulate_fabric`` for per-tenant trackers and
    inter-tenant arbitration.

    ``scheduler`` — the scenario-reuse contract: pass a shared
    ``ThemisScheduler`` to keep its memo caches (exact; see
    ``ThemisScheduler.isolated_run``) warm across many calls.  Each call
    still schedules against a *fresh* load tracker and restores the
    caller's tracker on return, so back-to-back calls with one shared
    scheduler are bit-identical to calls with fresh schedulers and never
    leak tracker state between scenarios.  The scheduler must have been
    built for ``topology`` (scheduling with another topology's latency
    model was previously silently wrong; now it raises), and its policy
    overrides the ``policy`` argument.

    ``engine`` passes through to :func:`simulate` — ``"compiled"`` runs
    the cohort-vectorized fast path on the scheduled stream
    (bit-identical to indexed; scenarios it cannot serve, e.g. with an
    ``arbiter`` or ``tracer``, fall back with the documented signal).
    """
    from repro.core.scheduler import ThemisScheduler

    if replan and faults is None:
        raise ValueError("replan=True requires faults")
    if scheduler is None:
        lm = LatencyModel.for_topology(topology)
        sched_ctx = ThemisScheduler(lm, policy).isolated_run()
    else:
        if scheduler.latency_model.topology != topology:
            raise ValueError(
                "scheduler was built for topology "
                f"{scheduler.latency_model.topology.name!r}; reusing its "
                f"memos on {topology.name!r} is unspecified — build one "
                "scheduler per topology")
        sched_ctx = scheduler.isolated_run()
    with sched_ctx as sched:
        groups = sched.schedule_stream(
            requests, chunks_per_collective, water_filling=water_filling)
    replanner = None
    if replan:
        from repro.faults.replan import make_replanner

        replanner = make_replanner(
            topology, scheduler.policy if scheduler is not None else policy)
    res = simulate(
        topology,
        groups,
        issue_times=[r.issue_time for r in requests],
        priorities=[r.priority for r in requests],
        intra=intra,
        fusion=fusion,
        tenants=[r.tenant for r in requests],
        streams=[r.stream for r in requests],
        arbiter=arbiter,
        preempt_penalty_s=preempt_penalty_s,
        engine=engine,
        check_invariants=check_invariants,
        tracer=tracer,
        faults=faults,
        replanner=replanner,
    )
    return res, groups
