"""Event-driven multi-rail collective simulator (ASTRA-lite).

Models the 2xD-stage pipelined execution of chunked hierarchical collectives
on a multi-dimensional network (paper Sec. 2.3/5.1):

  * each network dimension is a serial bandwidth resource with a ready
    queue (FIFO or Smallest-Chunk-First discipline, Sec. 4.3);
  * a chunk's stage ops execute in schedule order (RS-before-AG is embedded
    in the schedule); a stage occupies its dimension for ``wire_bytes/BW``
    and *completes* (readying the chunk's next stage) after an additional
    fixed delay ``A_stage`` — successive chunks pipeline through a
    dimension's steps, so A is latency, not throughput (this matches
    Algorithm 1, which charges A_K once per collective in the tracker
    rather than per chunk);
  * optional small-chunk fusion: if a chunk op cannot saturate a dimension's
    BW (wire time < A), multiple ready ops are fused into one service
    (Sec. 4.3's provision, mirroring NCCL collective fusion);
  * optional enforced per-dim op order (Sec. 4.6.2 consistency) and random
    service-time jitter for consistency experiments.

The engine is *online and arrival-time-aware*: every collective (a "group"
of chunks) carries an issue time, so overlapping collectives — backprop
bucket streams, pipeline stages, multi-tenant jobs — contend for shared
dimensions exactly as they would on real hardware.  ``simulate_requests``
is the high-level entry: a stream of :class:`CollectiveRequest`s is
scheduled incrementally (``ThemisScheduler.schedule_request``, which keeps
the Dim Load Tracker running across requests) and simulated jointly.

Outputs makespan, per-dim busy time / wire bytes, BW utilization (the
paper's weighted-average metric), per-dim activity timelines (Fig. 9),
per-request completion times, and per-dim service logs attributing every
service interval to the requests it carried.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field

from repro.core.chunking import Chunk
from repro.core.latency_model import LatencyModel
from repro.core.requests import CollectiveRequest
from repro.topology import Topology

OpId = tuple[int, int]  # (chunk_id, stage_idx)

# One served batch on a dimension: (start, end, group ids carried).
ServiceInterval = tuple[float, float, tuple[int, ...]]


@dataclass
class StageTask:
    chunk_id: int
    stage_idx: int
    dim: int
    wire_bytes: float
    fixed_delay: float
    group: int = 0
    priority: int = 0
    arrival_seq: int = 0
    ready_time: float = 0.0

    @property
    def op_id(self) -> OpId:
        return (self.chunk_id, self.stage_idx)


@dataclass
class SimResult:
    makespan: float
    dim_busy: list[float]
    dim_wire_bytes: list[float]
    dim_activity: list[list[tuple[float, float]]]  # intervals w/ pending work
    dim_op_order: list[list[OpId]]                 # service order per dim
    # -- arrival-time-aware extensions ---------------------------------------
    dim_services: list[list[ServiceInterval]] = field(default_factory=list)
    group_issue: list[float] = field(default_factory=list)
    group_finish: list[float] = field(default_factory=list)

    def avg_bw_utilization(self, topology: Topology) -> float:
        """Weighted average BW utilization (weights = per-dim BW budget)."""
        if self.makespan <= 0:
            return 1.0
        total_bw = topology.total_bw_bytes
        moved = sum(self.dim_wire_bytes)
        return moved / (self.makespan * total_bw)

    def activity_rate(self, dim: int) -> float:
        if self.makespan <= 0:
            return 0.0
        return sum(e - s for s, e in self.dim_activity[dim]) / self.makespan

    def group_span(self, group: int) -> float:
        """Issue-to-completion latency of one collective."""
        return self.group_finish[group] - self.group_issue[group]

    def groups_interleave_on(self, dim: int) -> bool:
        """True if the service order on ``dim`` switches between distinct
        groups and back — i.e. collectives genuinely contend rather than
        running back-to-back.  A batch fusing several groups also counts."""
        seen_transitions: set[tuple[int, int]] = set()
        prev: int | None = None
        for _, _, groups in self.dim_services[dim]:
            if len(groups) > 1:
                return True
            g = groups[0]
            if prev is not None and g != prev:
                if (g, prev) in seen_transitions:
                    return True  # came back to an earlier group: A..B..A
                seen_transitions.add((prev, g))
            prev = g
        return False


def _build_tasks(
    latency_model: LatencyModel,
    chunks: list[Chunk],
    id_offset: int = 0,
    group: int = 0,
    priority: int = 0,
) -> dict[OpId, StageTask]:
    tasks: dict[OpId, StageTask] = {}
    for chunk in chunks:
        size = chunk.size_bytes
        cid = chunk.index + id_offset
        for s, (phase, dim) in enumerate(chunk.schedule):
            wire, size = latency_model.stage_wire_bytes(dim, phase, size)
            tasks[(cid, s)] = StageTask(
                chunk_id=cid,
                stage_idx=s,
                dim=dim,
                wire_bytes=wire,
                fixed_delay=latency_model.step_delay(dim, phase),
                group=group,
                priority=priority,
            )
    return tasks


def simulate(
    topology: Topology,
    chunk_groups: list[list[Chunk]],
    *,
    issue_times: list[float] | None = None,
    priorities: list[int] | None = None,
    intra: str = "SCF",
    fusion: bool = True,
    fusion_limit: int = 8,
    enforced_order: list[list[OpId]] | None = None,
    jitter: float = 0.0,
    seed: int = 0,
) -> SimResult:
    """Simulate one or more collectives (``chunk_groups``).

    ``issue_times``: per-group arrival time (seconds); default all 0.0.
        A group's chunks become ready only once its collective is issued,
        so staggered groups overlap and contend on shared dims.
    ``priorities``: per-group service priority (higher first within a dim's
        ready queue; default all equal).
    ``intra``: 'FIFO' | 'SCF' intra-dimension discipline (Sec. 4.3).
    ``fusion``: fuse ops that cannot individually saturate a dim's BW.
    ``enforced_order``: per-dim list of op ids that must be served in order
        (Sec. 4.6.2); a dim idles rather than serving out of turn.
    ``jitter``: multiplicative service-time noise amplitude (consistency
        experiments; deterministic given ``seed``).
    """
    import random

    rng = random.Random(seed)
    lm = LatencyModel(topology)
    num_dims = topology.num_dims
    n_groups = len(chunk_groups)
    if issue_times is None:
        issue_times = [0.0] * n_groups
    if priorities is None:
        priorities = [0] * n_groups
    if len(issue_times) != n_groups or len(priorities) != n_groups:
        raise ValueError("issue_times/priorities must match chunk_groups")

    tasks: dict[OpId, StageTask] = {}
    group_of_chunk: dict[int, int] = {}
    offset = 0
    for g, group in enumerate(chunk_groups):
        tasks.update(_build_tasks(lm, group, id_offset=offset, group=g,
                                  priority=priorities[g]))
        for c in group:
            group_of_chunk[c.index + offset] = g
        if group:
            offset += max(c.index for c in group) + 1

    # Chunk chains: stage s+1 becomes ready when stage s completes.
    chain_len: dict[int, int] = {}
    for cid, s in tasks:
        chain_len[cid] = max(chain_len.get(cid, 0), s + 1)

    queues: list[list[StageTask]] = [[] for _ in range(num_dims)]
    busy_until = [0.0] * num_dims
    dim_busy = [0.0] * num_dims
    dim_wire = [0.0] * num_dims
    dim_order: list[list[OpId]] = [[] for _ in range(num_dims)]
    dim_services: list[list[ServiceInterval]] = [[] for _ in range(num_dims)]
    activity: list[list[tuple[float, float]]] = [[] for _ in range(num_dims)]
    pending_since = [None] * num_dims  # type: list[float | None]
    enforced_pos = [0] * num_dims
    group_finish = [t for t in issue_times]  # empty groups finish at issue
    seq = itertools.count()

    # Event heap: (time, tiebreak, kind, payload)
    events: list[tuple[float, int, str, object]] = []

    def push_ready(task: StageTask, t: float) -> None:
        task.ready_time = t
        task.arrival_seq = next(seq)
        heapq.heappush(events, (t, task.arrival_seq, "ready", task))

    for cid in chain_len:
        push_ready(tasks[(cid, 0)], issue_times[group_of_chunk[cid]])

    def select_batch(dim: int, now: float) -> list[StageTask]:
        q = queues[dim]
        if not q:
            return []
        if enforced_order is not None:
            order = enforced_order[dim]
            pos = enforced_pos[dim]
            if pos >= len(order):
                return []
            want = order[pos]
            head = [t for t in q if t.op_id == want]
            if not head:
                return []  # idle until the mandated op arrives
            batch = [head[0]]
        else:
            if intra == "SCF":
                q.sort(key=lambda t: (-t.priority, t.wire_bytes, t.arrival_seq))
            else:  # FIFO
                q.sort(key=lambda t: (-t.priority, t.arrival_seq))
            batch = [q[0]]
        if fusion:
            bw = topology.dims[dim].aggr_bw_bytes
            sat_bytes = batch[0].fixed_delay * bw  # wire time < A  => unsaturated
            total = batch[0].wire_bytes
            if total < sat_bytes:
                pool = (
                    enforced_candidates(dim, batch[0])
                    if enforced_order is not None
                    else [t for t in q if t is not batch[0]]
                )
                for t in pool:
                    if len(batch) >= fusion_limit or total >= sat_bytes:
                        break
                    batch.append(t)
                    total += t.wire_bytes
        for t in batch:
            q.remove(t)
        if enforced_order is not None:
            enforced_pos[dim] += len(batch)
        return batch

    def enforced_candidates(dim: int, first: StageTask) -> list[StageTask]:
        """Ops that may fuse after ``first`` without violating the order."""
        order = enforced_order[dim]
        pos = enforced_pos[dim] + 1
        ready_ids = {t.op_id: t for t in queues[dim] if t is not first}
        out = []
        while pos < len(order) and order[pos] in ready_ids:
            out.append(ready_ids[order[pos]])
            pos += 1
        return out

    def try_start(dim: int, now: float) -> None:
        if busy_until[dim] > now:
            return
        batch = select_batch(dim, now)
        if not batch:
            return
        bw = topology.dims[dim].aggr_bw_bytes
        a = max(t.fixed_delay for t in batch)
        wire = sum(t.wire_bytes for t in batch)
        occupy = wire / bw  # dim is a BW resource; steps pipeline
        if jitter:
            occupy *= 1.0 + jitter * rng.random()
        free_at = now + occupy
        busy_until[dim] = free_at
        dim_busy[dim] += occupy
        dim_wire[dim] += wire
        for t in batch:
            dim_order[dim].append(t.op_id)
        dim_services[dim].append(
            (now, free_at, tuple(sorted({t.group for t in batch}))))
        # Chunk stages complete A after their data drains (latency term).
        heapq.heappush(events, (free_at, next(seq), "free", dim))
        heapq.heappush(events, (free_at + a, next(seq), "done", (dim, batch)))

    makespan = max(issue_times) if issue_times else 0.0
    while events:
        now, _, kind, payload = heapq.heappop(events)
        makespan = max(makespan, now)
        if kind == "ready":
            task: StageTask = payload  # type: ignore[assignment]
            if pending_since[task.dim] is None:
                pending_since[task.dim] = now
            queues[task.dim].append(task)
            try_start(task.dim, now)
        elif kind == "free":
            dim: int = payload  # type: ignore[assignment]
            if not queues[dim] and pending_since[dim] is not None:
                activity[dim].append((pending_since[dim], now))
                pending_since[dim] = None
            try_start(dim, now)
        else:  # done — chunk's next stage becomes ready
            dim, batch = payload  # type: ignore[misc]
            for t in batch:
                nxt = (t.chunk_id, t.stage_idx + 1)
                if nxt in tasks:
                    push_ready(tasks[nxt], now)
                elif group_finish[t.group] < now:  # chunk chain retired
                    group_finish[t.group] = now

    for dim in range(num_dims):
        if pending_since[dim] is not None:  # pragma: no cover - safety
            activity[dim].append((pending_since[dim], makespan))

    return SimResult(makespan, dim_busy, dim_wire, activity, dim_order,
                     dim_services, list(issue_times), group_finish)


def simulate_scheduled(
    topology: Topology,
    collective: str,
    size_bytes: float,
    *,
    policy: str = "themis",
    chunks_per_collective: int = 64,
    intra: str = "SCF",
    fusion: bool = True,
    water_filling: bool = False,
) -> tuple[SimResult, list[Chunk]]:
    """Schedule one collective with ``policy`` and simulate it."""
    from repro.core.scheduler import schedule_collective

    chunks = schedule_collective(
        topology,
        collective,
        size_bytes,
        chunks_per_collective,
        policy,
        water_filling=water_filling,
    )
    res = simulate(topology, [chunks], intra=intra, fusion=fusion)
    return res, chunks


def simulate_requests(
    topology: Topology,
    requests: list[CollectiveRequest],
    *,
    policy: str = "themis",
    chunks_per_collective: int = 64,
    intra: str = "SCF",
    fusion: bool = True,
    water_filling: bool = False,
) -> tuple[SimResult, list[list[Chunk]]]:
    """Online entry point: schedule and simulate an arrival-time-aware
    request stream.

    Requests are scheduled in issue order through one ``ThemisScheduler``
    whose Dim Load Tracker runs *across* requests (``schedule_request``), so
    each collective's chunk orders account for the residual load of every
    collective still in flight.  The returned chunk groups are indexed like
    ``requests``; ``SimResult.group_issue``/``group_finish`` give each
    request's service window.
    """
    from repro.core.scheduler import ThemisScheduler

    lm = LatencyModel(topology)
    sched = ThemisScheduler(lm, policy)
    order = sorted(range(len(requests)), key=lambda i: (requests[i].issue_time, i))
    groups: list[list[Chunk]] = [[] for _ in requests]
    for i in order:
        groups[i] = sched.schedule_request(
            requests[i], chunks_per_collective, water_filling=water_filling)
    res = simulate(
        topology,
        groups,
        issue_times=[r.issue_time for r in requests],
        priorities=[r.priority for r in requests],
        intra=intra,
        fusion=fusion,
    )
    return res, groups
