"""Runtime invariant sanitizer for the simulation engines.

``simulate(..., check_invariants=True)`` arms these checks inside the
event loop of *both* engines (indexed and reference), asserting the same
conservation / ordering / work-conservation theorems the offline SMT
prover (``repro.verify``) states over small instances — so the formal
model and the implementation are checked against each other, not just
against our intentions:

  * **bytes conservation** — every chunk stage is served exactly once
    (preempted chunks re-serve, never duplicate or vanish), and each dim's
    accumulated wire bytes / busy time equal the sum over its services;
  * **service ordering** — per-dim service intervals are disjoint and
    start-ordered (a service never begins before the previous one drains);
  * **work conservation** — a dim never sits idle while its ready queue is
    non-empty (checked at every event boundary; enforced-order runs are
    exempt by design — they idle on purpose waiting for the mandated op);
  * **progress / attribution** — every request finishes no earlier than
    its resolved issue time, the makespan covers every finish and service,
    and (under an arbiter) the arbiter's served-bytes ledger delta matches
    the engine's per-dim wire accounting exactly.

All checks are guarded by a single local flag in the engines, so the
default ``check_invariants=False`` path costs one predictable branch per
event (gated by ``benchmarks/verify_study.py``).  Violations raise
:class:`InvariantViolation` with enough context to reproduce.

Float tolerances: wire bytes and busy times are re-accumulated here in a
different order than the engines accumulate them (and preemption
subtracts then re-adds), so equality checks are relative to ~1e-9 —
anything beyond that is a genuine accounting bug, not float drift.
"""
from __future__ import annotations

import math
from typing import Iterable, Sequence

# (op_id, dim, wire_bytes, tenant[, group]) — one row per chunk stage.
# The trailing group element is optional (fault-aware engines pass it so
# failed groups' abandoned work can be exempted from the lost-chunk check).
TaskRow = tuple[tuple[int, int], int, float, str]

_REL = 1e-9
_ABS_T = 1e-12   # seconds
_ABS_B = 1e-3    # bytes


class InvariantViolation(AssertionError):
    """A runtime engine invariant failed (see module docstring)."""


def _close(a: float, b: float, abs_tol: float) -> bool:
    return math.isclose(a, b, rel_tol=_REL, abs_tol=abs_tol)


def check_work_conserving(dim: int, now: float, queue_len: int,
                          busy_until: float, inflight, engine: str) -> None:
    """Event-boundary check: ``dim`` must not be idle with a backlog.

    Called by both engines after a ready/free event settles.  A dim with
    queued work is either busy past ``now`` or has a service in flight
    (zero-occupancy services keep ``busy_until == now`` but set
    ``inflight`` until their free event fires).
    """
    if queue_len > 0 and busy_until <= now and inflight is None:
        raise InvariantViolation(
            f"[{engine}] work conservation violated on dim {dim} at "
            f"t={now:.9g}: {queue_len} task(s) queued but the dim is idle "
            f"(busy_until={busy_until:.9g}, no service in flight)")


def check_service_start(dim: int, now: float,
                        prev_end: float, engine: str) -> None:
    """A new service on ``dim`` must start at or after the previous one's
    (possibly preemption-shortened) end."""
    if now < prev_end - max(_ABS_T, _REL * abs(prev_end)):
        raise InvariantViolation(
            f"[{engine}] service overlap on dim {dim}: new service starts "
            f"at t={now:.9g} before previous end {prev_end:.9g}")


def check_final(
    *,
    engine: str,
    num_dims: int,
    tasks: Iterable[TaskRow],
    dim_wire: Sequence[float],
    dim_busy: Sequence[float],
    dim_order: Sequence[Sequence[tuple[int, int]]],
    dim_services: Sequence[Sequence[tuple]],
    group_finish: Sequence[float],
    resolved_issue: Sequence[float],
    makespan: float,
    enforced: bool = False,
    arbiter=None,
    served_base: dict | None = None,
    failed: frozenset | None = None,
    shed: frozenset | None = None,
) -> None:
    """End-of-run conservation / ordering / attribution checks (both
    engines call this with their own state; see module docstring).

    ``failed`` — the set of request groups the fault machinery marked
    failed (retry exhaustion).  A failed group's unserved stages are
    abandoned by design, so they are exempt from the lost-chunk check, and
    wire conservation is restated over the ops that actually served (their
    per-row wire bytes must still sum to the engine's accounting — the
    conservation theorem holds across re-rating, aborts and retries).

    ``shed`` — the groups the admission controller shed (demand-side
    losses, ``repro.fleet``).  Same exemptions as ``failed``, plus the
    progress checks: a shed group's stale ``group_finish`` entry is its
    static issue time, which can sit on either side of the makespan (a
    late-arriving request shed on arrival never advances the clock).
    """
    dead = (failed or frozenset()) | (shed or frozenset())
    # -- every chunk stage served exactly once (bytes cannot vanish or
    #    duplicate across preemption splits) ------------------------------
    expected_wire = [0.0] * num_dims
    expected_ops: dict[tuple[int, int], int] = {}
    op_wire: dict[tuple[int, int], float] = {}
    op_group: dict[tuple[int, int], int] = {}
    for row in tasks:
        op, dim, wire = row[0], row[1], row[2]
        expected_wire[dim] += wire
        expected_ops[op] = dim
        op_wire[op] = wire
        if len(row) > 4:
            op_group[op] = row[4]
    served_count: dict[tuple[int, int], int] = {}
    for dim in range(num_dims):
        for op in dim_order[dim]:
            served_count[op] = served_count.get(op, 0) + 1
            if served_count[op] > 1:
                raise InvariantViolation(
                    f"[{engine}] chunk stage {op} served "
                    f"{served_count[op]} times on dim {dim}")
            if expected_ops.get(op) != dim:
                raise InvariantViolation(
                    f"[{engine}] chunk stage {op} served on dim {dim} but "
                    f"belongs to dim {expected_ops.get(op)}")
    if not enforced:
        # Enforced-order runs may legitimately strand tasks whose mandated
        # slot never arrives, and a failed group's remaining work is
        # abandoned by design; everywhere else a missing op is a lost chunk.
        lost = [op for op in expected_ops
                if op not in served_count
                and (not dead or op_group.get(op) not in dead)]
        if lost:
            raise InvariantViolation(
                f"[{engine}] {len(lost)} chunk stage(s) never served "
                f"(lost chunks): {sorted(lost)[:8]}...")
        if dead:
            # Conservation over what actually drained: failed/shed groups'
            # unserved stages moved no bytes, so the expectation is the sum
            # of served ops' wire bytes per dim.
            expected_wire = [0.0] * num_dims
            for dim in range(num_dims):
                for op in dim_order[dim]:
                    expected_wire[dim] += op_wire[op]
        for dim in range(num_dims):
            if not _close(dim_wire[dim], expected_wire[dim], _ABS_B):
                raise InvariantViolation(
                    f"[{engine}] wire-byte conservation violated on dim "
                    f"{dim}: accounted {dim_wire[dim]!r} != sum of task "
                    f"wire bytes {expected_wire[dim]!r}")

    # -- per-dim service intervals: start-ordered, disjoint, and summing to
    #    the dim's busy time ---------------------------------------------
    for dim in range(num_dims):
        busy = 0.0
        prev_end = None
        for start, end, _groups in dim_services[dim]:
            if end < start - _ABS_T:
                raise InvariantViolation(
                    f"[{engine}] negative-length service on dim {dim}: "
                    f"[{start!r}, {end!r}]")
            if prev_end is not None and start < prev_end - max(
                    _ABS_T, _REL * abs(prev_end)):
                raise InvariantViolation(
                    f"[{engine}] overlapping services on dim {dim}: start "
                    f"{start!r} < previous end {prev_end!r}")
            prev_end = end
            busy += end - start
            if end > makespan + max(_ABS_T, _REL * abs(makespan)):
                raise InvariantViolation(
                    f"[{engine}] service on dim {dim} ends at {end!r} past "
                    f"the makespan {makespan!r}")
        if not _close(dim_busy[dim], busy, _ABS_T):
            raise InvariantViolation(
                f"[{engine}] busy-time accounting violated on dim {dim}: "
                f"{dim_busy[dim]!r} != sum of service lengths {busy!r}")

    # -- progress: finishes cover issues, makespan covers finishes ---------
    for g, (fin, iss) in enumerate(zip(group_finish, resolved_issue)):
        if dead and g in dead:
            continue  # never finished; its finish entry is a stale default
        if fin < iss - max(_ABS_T, _REL * abs(iss)):
            raise InvariantViolation(
                f"[{engine}] group {g} finished at {fin!r} before its "
                f"resolved issue time {iss!r}")
        if fin > makespan + max(_ABS_T, _REL * abs(makespan)):
            raise InvariantViolation(
                f"[{engine}] group {g} finishes at {fin!r} past the "
                f"makespan {makespan!r}")

    # -- arbiter ledger vs engine accounting ------------------------------
    if (arbiter is not None and served_base is not None
            and hasattr(arbiter, "served_snapshot") and not enforced):
        served_now = arbiter.served_snapshot()
        keys = set(served_base) | set(served_now)
        per_dim = [0.0] * num_dims
        for key in keys:
            dim = key[0]
            if dim < num_dims:
                per_dim[dim] += (served_now.get(key, 0.0)
                                 - served_base.get(key, 0.0))
        for dim in range(num_dims):
            if not _close(per_dim[dim], dim_wire[dim], _ABS_B):
                raise InvariantViolation(
                    f"[{engine}] arbiter served-bytes ledger disagrees with "
                    f"engine wire accounting on dim {dim}: ledger delta "
                    f"{per_dim[dim]!r} != dim_wire {dim_wire[dim]!r} (a "
                    f"preemption refund or double charge went missing)")
