"""Batch/fleet evaluation — amortize everything shared across scenarios.

A :class:`Scenario` is one independent (topology, request stream, policy,
arbiter, discipline, jitter seed) simulation — exactly the argument set of
:func:`repro.core.simulator.simulate_requests`.  :func:`simulate_batch`
runs N scenarios in one process and shares every piece of work that is a
pure function of a subset of the scenario fields:

  * **LatencyModel / StageTables** — memoized per topology
    (``LatencyModel.for_topology``), built once per distinct fabric no
    matter how many scenarios visit it;
  * **chunk schedules** — a scenario's chunk groups depend only on
    (topology, policy, requests, chunks_per_collective, water_filling).
    Scenarios differing in seed/jitter/discipline/arbiter (a robustness
    sweep, an arbiter ablation, a multi-seed scoring pass) share one
    scheduling pass through a pooled per-(topology, policy)
    ``ThemisScheduler`` whose memo caches stay warm across the whole batch
    (``ThemisScheduler.isolated_run`` keeps tracker state scenario-local);
  * **SoA task arrays** — built once per distinct chunk-group family with
    the vectorized builder below and replayed into every run
    (``simulate(task_arrays=...)``);
  * **per-(size, schedule) stage vectors** — the per-stage wire-factor /
    step-delay evaluation collapses to one scalar pass per equivalence
    class (:func:`repro.core.chunking.schedule_classes`) broadcast with
    numpy over all member chunks; the vectors are additionally shared
    across *topologies* with the same per-dim NPU counts and step delays,
    so a bandwidth-split search re-evaluates no stage math at all.

The event loop itself stays per-scenario and defaults to the unmodified
indexed engine, so every result is bit-identical to a standalone
``simulate_requests(..., engine="indexed")`` call — the equivalence suite
(``tests/test_engine_equiv.py``) and ``benchmarks/topo_search.py`` assert
this field-for-field.  ``Scenario.engine="compiled"`` swaps in the
cohort-vectorized fast path (``repro.core.engine_compiled``) per scenario;
its numpy path is bit-identical too, so batches mixing engines still
agree field-for-field, and scenarios the fast path cannot serve (tracer,
arbiter, faults) fall back to indexed with the documented signal.

Dependency-gated streams (``Scenario.traffic``, a
``repro.traffic.TrafficGraph``) ride the same machinery: the scheduling
pass and the vectorized task build are shared per graph family exactly
like request streams, and dependency resolution stays in the per-scenario
event loop — so pipeline and serving scenarios batch as cheaply as
training ones.
"""
from __future__ import annotations

from contextlib import nullcontext
from dataclasses import dataclass
from typing import Any, Callable, Iterable, Sequence

import numpy as np

from repro.core.chunking import Chunk, schedule_classes
from repro.core.latency_model import LatencyModel
from repro.core.requests import CollectiveRequest
from repro.core.scheduler import ThemisScheduler
from repro.obs.metrics import current_registry
from repro.core.simulator import (
    SimResult,
    TaskArrays,
    simulate,
    stage_sequence,
    task_arrays_fingerprint,
)
from repro.topology import Topology


@dataclass(frozen=True)
class Scenario:
    """One independent simulation of a request stream on a fabric.

    Mirrors :func:`repro.core.simulator.simulate_requests`; anything not a
    field here is shared batch machinery.  ``arbiter_factory`` (not an
    instance) because arbiters are stateful and each scenario must get a
    fresh one; ``label`` is free-form for reporting.

    ``traffic`` (a :class:`repro.traffic.TrafficGraph`, mutually exclusive
    with ``requests``) runs a *dependency-gated* stream instead of a
    fixed-time one: the scheduling pass walks the graph's estimated-issue
    order and the vectorized task build is reused unchanged, while
    dependency resolution stays in the per-scenario event loop
    (``simulate(deps=...)``).

    ``tracer_factory`` (not an instance — one :class:`repro.obs.Tracer`
    records exactly one run) arms the flight recorder on this scenario's
    simulation; retrieve the armed tracers via the factory's own records
    (e.g. ``lambda: traces.append(Tracer()) or traces[-1]``) or a closure
    per scenario.

    ``faults`` (a :class:`repro.faults.FaultSchedule`) injects a fault
    timeline into this scenario's run; ``replan=True`` additionally arms
    the Themis graceful-degradation hook (re-plans un-issued chunks at
    each BW fault boundary).  Faults are deliberately NOT part of
    :meth:`schedule_key` — the fault-free chunk schedules are what
    re-planning degrades from, so scenarios differing only in faults
    still share one scheduling pass and one task-array build.

    ``engine`` selects the event loop (``"indexed"`` default,
    ``"compiled"`` for the cohort-vectorized fast path, ``"reference"``
    for the oracle).  Like faults it is NOT part of :meth:`schedule_key`:
    engines share schedules and task arrays, which is exactly what makes
    a compiled-vs-indexed differential sweep cheap.
    """

    topology: Topology
    requests: tuple[CollectiveRequest, ...] = ()
    policy: str = "themis"
    chunks_per_collective: int = 64
    water_filling: bool = False
    intra: str = "SCF"
    fusion: bool = True
    fusion_limit: int = 8
    jitter: float = 0.0
    seed: int = 0
    arbiter_factory: Callable[[], Any] | None = None
    preempt_penalty_s: float | None = None
    label: str = ""
    traffic: Any | None = None   # repro.traffic.TrafficGraph
    tracer_factory: Callable[[], Any] | None = None
    faults: Any | None = None    # repro.faults.FaultSchedule
    replan: bool = False
    engine: str = "indexed"

    def __post_init__(self):
        object.__setattr__(self, "requests", tuple(self.requests))
        if self.traffic is not None and self.requests:
            raise ValueError(
                "pass either requests or traffic, not both")
        if self.traffic is None and not self.requests:
            raise ValueError("scenario needs requests or traffic")
        if self.replan and self.faults is None:
            raise ValueError("replan=True requires faults")

    def schedule_key(self) -> tuple:
        """Everything the chunk schedules are a function of."""
        return (self.topology, self.policy, self.requests, self.traffic,
                self.chunks_per_collective, self.water_filling)


def simulate_scenario(scenario: Scenario) -> SimResult:
    """Run one scenario standalone — the un-amortized reference path
    (fresh scheduler, scalar task build, no shared caches) every batch
    result must match bit-for-bit.  This is what a loop of individual
    ``simulate()`` calls does, and the baseline the fleet benchmark times
    ``simulate_batch`` against."""
    sc = scenario
    if sc.traffic is not None:
        from repro.traffic.engine import schedule_traffic

        groups = schedule_traffic(
            sc.topology, sc.traffic, policy=sc.policy,
            chunks_per_collective=sc.chunks_per_collective,
            water_filling=sc.water_filling)
        return _run_scenario(sc, groups, None)
    sched = ThemisScheduler(LatencyModel.for_topology(sc.topology), sc.policy)
    groups = sched.schedule_stream(
        sc.requests, sc.chunks_per_collective,
        water_filling=sc.water_filling)
    return _run_scenario(sc, groups, None)


class BatchCaches:
    """Cross-scenario caches; pass one instance to successive
    :func:`simulate_batch` calls (e.g. search rounds) to keep them warm."""

    _GROUP_CAP = 256        # scheduled chunk-group families
    _CLASS_CAP = 8192       # per-(size, schedule) stage vectors
    _SCHED_CAP = 64         # pooled schedulers — a topology search visits
    #                         hundreds of fabrics; memo reuse only pays
    #                         within one, so cap and clear like the rest

    def __init__(self) -> None:
        self._schedulers: dict[tuple, ThemisScheduler] = {}
        self._groups: dict[tuple, tuple[list[list[Chunk]], TaskArrays]] = {}
        self._class_vectors: dict[tuple, tuple] = {}

    # -- scheduling (shared across seeds/disciplines/arbiters) ---------------
    def _scheduler(self, topology: Topology, policy: str) -> ThemisScheduler:
        key = (topology, policy)
        got = self._schedulers.get(key)
        if got is None:
            if len(self._schedulers) >= self._SCHED_CAP:
                self._schedulers.pop(next(iter(self._schedulers)))
            got = self._schedulers[key] = ThemisScheduler(
                LatencyModel.for_topology(topology), policy)
        return got

    def groups_and_arrays(
        self, sc: Scenario
    ) -> tuple[list[list[Chunk]], TaskArrays]:
        key = sc.schedule_key()
        got = self._groups.get(key)
        if got is None:
            sched = self._scheduler(sc.topology, sc.policy)
            if sc.traffic is not None:
                from repro.traffic.engine import schedule_traffic

                groups = schedule_traffic(
                    sc.topology, sc.traffic, policy=sc.policy,
                    chunks_per_collective=sc.chunks_per_collective,
                    water_filling=sc.water_filling, scheduler=sched)
                pri = [n.priority for n in sc.traffic.nodes]
                ten = [n.tenant_tag for n in sc.traffic.nodes]
            else:
                with sched.isolated_run():
                    groups = sched.schedule_stream(
                        sc.requests, sc.chunks_per_collective,
                        water_filling=sc.water_filling)
                pri = [r.priority for r in sc.requests]
                ten = [r.tenant for r in sc.requests]
            ta = self._build_arrays(sc.topology, groups, pri, ten)
            if len(self._groups) >= self._GROUP_CAP:
                self._groups.pop(next(iter(self._groups)))
            got = self._groups[key] = (groups, ta)
        return got

    # -- vectorized SoA task build -------------------------------------------
    def _build_arrays(
        self,
        topology: Topology,
        chunk_groups: list[list[Chunk]],
        priorities: list[int],
        tenants: list[str],
    ) -> TaskArrays:
        lm = LatencyModel.for_topology(topology)
        reg = current_registry()
        with (reg.span("batch.build_task_arrays") if reg is not None
                else nullcontext()):
            return build_task_arrays_vectorized(lm, chunk_groups, priorities,
                                                tenants, self._class_vectors)


def _factor_key(tbl) -> tuple:
    """Stage vectors depend only on per-dim NPU counts (wire factors) and
    step delays — NOT on bandwidths — so a BW-split search shares them
    across every candidate topology."""
    return (tuple(tbl.npus), tuple(tbl.rs_step), tuple(tbl.ag_step))


def _class_stage_vectors(tbl, size_bytes: float, sched: tuple):
    """Per-stage (dims, wires, fixed delays) of one (size, schedule) class.

    Delegates the float math to the builders' single shared scalar loop
    (:func:`repro.core.simulator.stage_sequence`); it runs once per class
    and is broadcast over every member chunk, which is what makes the
    vectorized builder bit-identical to the scalar one.
    """
    dims, wires, fixeds = stage_sequence(tbl, size_bytes, sched)
    return (np.asarray(dims, dtype=np.int64),
            np.asarray(wires, dtype=np.float64),
            np.asarray(fixeds, dtype=np.float64))


def build_task_arrays_vectorized(
    latency_model: LatencyModel,
    chunk_groups: list[list[Chunk]],
    priorities: list[int],
    tenants: list[str],
    class_cache: dict | None = None,
) -> TaskArrays:
    """Numpy-assembled SoA build, bit-identical to
    :func:`repro.core.simulator.build_task_arrays`.

    Per-stage float math runs once per (size, schedule) equivalence class
    (memoized in ``class_cache`` across groups, scenarios, and — via
    :func:`_factor_key` — across same-shape topologies); numpy only
    gathers, repeats and concatenates the resulting vectors, so no float
    op differs from the scalar path.  ``group_wire`` is accumulated
    scalar-sequentially in task order because float addition is
    order-sensitive and the results must match the scalar build bit-for-
    bit.
    """
    tbl = latency_model.stage_tables
    cache = class_cache if class_cache is not None else {}
    fkey = _factor_key(tbl)
    n_groups = len(chunk_groups)

    chunk_parts: list[np.ndarray] = []
    stage_parts: list[np.ndarray] = []
    dim_parts: list[np.ndarray] = []
    wire_parts: list[np.ndarray] = []
    fixed_parts: list[np.ndarray] = []
    group_lens: list[int] = []      # tasks per group, for t_group/prio/tenant
    last_idx: list[np.ndarray] = []  # absolute handles of final stages
    first_parts: list[np.ndarray] = []
    group_wire = [0.0] * n_groups

    h = 0
    offset = 0
    for g, group in enumerate(chunk_groups):
        scheduled = [c for c in group if c.schedule]
        if not scheduled:
            group_lens.append(0)
            if group:
                offset += max(c.index for c in group) + 1
            continue
        classes, class_of = schedule_classes(scheduled)
        vecs = []
        for key in classes:
            ck = (fkey,) + key
            got = cache.get(ck)
            if got is None:
                if len(cache) >= BatchCaches._CLASS_CAP:
                    cache.pop(next(iter(cache)))
                got = cache[ck] = _class_stage_vectors(tbl, key[0], key[1])
            vecs.append(got)
        lens = {v[0].shape[0] for v in vecs}
        cids = np.fromiter((c.index + offset for c in scheduled),
                           dtype=np.int64, count=len(scheduled))
        sel = np.asarray(class_of, dtype=np.int64)
        if len(lens) == 1:
            # Uniform stage count (the norm: one collective per group) —
            # one fancy-index gather covers the whole group.
            L = lens.pop()
            dims_m = np.stack([v[0] for v in vecs])[sel]
            wires_m = np.stack([v[1] for v in vecs])[sel]
            fixed_m = np.stack([v[2] for v in vecs])[sel]
            n_chunks = len(scheduled)
            dim_parts.append(dims_m.ravel())
            wire_parts.append(wires_m.ravel())
            fixed_parts.append(fixed_m.ravel())
            chunk_parts.append(np.repeat(cids, L))
            stage_parts.append(np.tile(np.arange(L, dtype=np.int64), n_chunks))
            stage_counts = np.full(n_chunks, L, dtype=np.int64)
        else:  # pragma: no cover - mixed-length schedules in one group
            dim_parts.append(np.concatenate([vecs[c][0] for c in class_of]))
            wire_parts.append(np.concatenate([vecs[c][1] for c in class_of]))
            fixed_parts.append(np.concatenate([vecs[c][2] for c in class_of]))
            stage_counts = np.fromiter(
                (vecs[c][0].shape[0] for c in class_of), dtype=np.int64,
                count=len(class_of))
            chunk_parts.append(np.repeat(cids, stage_counts))
            stage_parts.append(np.concatenate(
                [np.arange(n, dtype=np.int64) for n in stage_counts]))
        n_tasks_g = int(stage_counts.sum())
        firsts = h + np.concatenate(
            ([0], np.cumsum(stage_counts[:-1]))) if len(stage_counts) else \
            np.empty(0, dtype=np.int64)
        first_parts.append(firsts)
        last_idx.append(firsts + stage_counts - 1)
        group_lens.append(n_tasks_g)
        # order-sensitive sequential sum — must equal the scalar `gw += wire`
        gw = 0.0
        for w in wire_parts[-1].tolist():
            gw += w
        group_wire[g] = gw
        h += n_tasks_g
        offset += max(c.index for c in group) + 1

    n_tasks = h
    if n_tasks:
        t_chunk = np.concatenate(chunk_parts).tolist()
        t_stage = np.concatenate(stage_parts).tolist()
        t_dim = np.concatenate(dim_parts).tolist()
        t_wire = np.concatenate(wire_parts).tolist()
        t_fixed = np.concatenate(fixed_parts).tolist()
        first_handles = np.concatenate(first_parts).astype(np.int64).tolist()
        t_last = np.zeros(n_tasks, dtype=bool)
        t_last[np.concatenate(last_idx).astype(np.int64)] = True
        t_last = t_last.tolist()
    else:
        t_chunk = t_stage = t_dim = []
        t_wire = t_fixed = []
        first_handles = []
        t_last = []
    t_group: list[int] = []
    t_prio: list[int] = []
    t_tenant: list[str] = []
    for g, n in enumerate(group_lens):
        if n:
            t_group.extend([g] * n)
            t_prio.extend([priorities[g]] * n)
            t_tenant.extend([tenants[g]] * n)
    return TaskArrays(n_tasks, t_chunk, t_stage, t_dim, t_wire, t_fixed,
                      t_group, t_prio, t_tenant, t_last, first_handles,
                      group_wire,
                      task_arrays_fingerprint(chunk_groups, priorities,
                                              tenants))


def _run_scenario(sc: Scenario, groups: list[list[Chunk]],
                  ta: TaskArrays) -> SimResult:
    arb = sc.arbiter_factory() if sc.arbiter_factory is not None else None
    trc = sc.tracer_factory() if sc.tracer_factory is not None else None
    replanner = None
    if sc.replan:
        from repro.faults.replan import make_replanner

        replanner = make_replanner(sc.topology, sc.policy)
    if sc.traffic is not None:
        kw = sc.traffic.sim_kwargs()
    else:
        kw = dict(
            issue_times=[r.issue_time for r in sc.requests],
            priorities=[r.priority for r in sc.requests],
            tenants=[r.tenant for r in sc.requests],
            streams=[r.stream for r in sc.requests])
    return simulate(
        sc.topology, groups,
        intra=sc.intra, fusion=sc.fusion, fusion_limit=sc.fusion_limit,
        jitter=sc.jitter, seed=sc.seed,
        arbiter=arb, preempt_penalty_s=sc.preempt_penalty_s,
        engine=sc.engine, task_arrays=ta, tracer=trc,
        faults=sc.faults, replanner=replanner, **kw)


def simulate_batch(
    scenarios: Sequence[Scenario] | Iterable[Scenario],
    *,
    caches: BatchCaches | None = None,
) -> list[SimResult]:
    """Run N independent scenarios with shared precomputation.

    Results are bit-identical to running each scenario standalone
    (:func:`simulate_scenario`, which honors ``Scenario.engine`` the same
    way); only the amortized work differs.  Pass a :class:`BatchCaches` to keep schedules, task
    arrays and stage vectors warm across successive batches (the topology
    search reuses one across rounds).
    """
    caches = caches if caches is not None else BatchCaches()
    results: list[SimResult] = []
    for sc in scenarios:
        groups, ta = caches.groups_and_arrays(sc)
        results.append(_run_scenario(sc, groups, ta))
    return results
