"""Splitter (paper Fig. 6 step 2) — divide a collective into chunks.

The paper uses equal-size chunks (default 64 per collective).  We also
provide a beyond-paper *water-filling* splitter: run the greedy scheduler
with a large number of virtual micro-chunks to estimate the fractional mass
each dimension-order should receive, then coalesce the micro-chunks into at
most ``chunks_per_collective`` real chunks of *unequal* sizes whose order
classes match the fractional solution.  This approaches the Ideal bound with
far fewer chunks (lower A-term overhead).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.latency_model import StageOp


@dataclass
class Chunk:
    """One schedulable unit of a collective."""

    index: int
    size_bytes: float
    # Ordered stages assigned by the scheduler; empty until scheduled.
    schedule: list[StageOp] = field(default_factory=list)


def split_equal(collective_bytes: float, chunks_per_collective: int) -> list[Chunk]:
    """Paper's Splitter: equal-size chunks."""
    if chunks_per_collective < 1:
        raise ValueError("chunks_per_collective must be >= 1")
    size = collective_bytes / chunks_per_collective
    return [Chunk(i, size) for i in range(chunks_per_collective)]


def schedule_classes(chunks: list[Chunk]) -> tuple[list[tuple[float, tuple]], list[int]]:
    """Group chunks by their (size, schedule) equivalence class.

    Two chunks with the same size and the same stage order produce *exactly*
    the same per-stage wire bytes and fixed delays, so the per-stage float
    evaluation only needs to run once per class.  Returns ``(classes,
    class_of_chunk)`` where ``classes[i]`` is the ``(size_bytes, schedule)``
    key of class *i* and ``class_of_chunk[j]`` is chunk *j*'s class index,
    in chunk order.  Equal-split collectives have a handful of classes (one
    per distinct dim order the scheduler emitted); the vectorized task
    builder (``repro.core.batch``) broadcasts each class's stage vectors
    across its members instead of re-deriving them chunk by chunk.
    """
    class_idx: dict[tuple, int] = {}
    classes: list[tuple[float, tuple]] = []
    class_of_chunk: list[int] = []
    for c in chunks:
        key = (c.size_bytes, tuple(c.schedule))
        got = class_idx.get(key)
        if got is None:
            got = class_idx[key] = len(classes)
            classes.append(key)
        class_of_chunk.append(got)
    return classes, class_of_chunk


def coalesce_by_order(
    micro_chunks: list[Chunk], max_chunks: int
) -> list[Chunk]:
    """Merge scheduled micro-chunks with identical stage orders.

    Used by the water-filling splitter: after greedily scheduling many tiny
    chunks, chunks sharing the same dimension order are mass-equivalent and
    can be fused into one larger chunk, preserving the per-dimension byte
    assignment exactly while reducing per-chunk fixed overhead.
    """
    groups: dict[tuple, Chunk] = {}
    for c in micro_chunks:
        key = tuple(c.schedule)
        if key in groups:
            groups[key].size_bytes += c.size_bytes
        else:
            groups[key] = Chunk(len(groups), c.size_bytes, list(c.schedule))
    merged = list(groups.values())
    merged.sort(key=lambda c: -c.size_bytes)
    if len(merged) > max_chunks:
        # Fold the smallest groups into the largest group of the same first
        # dimension (keeps per-dim loads close to the fractional solution).
        keep, spill = merged[:max_chunks], merged[max_chunks:]
        for s in spill:
            target = min(
                (k for k in keep if k.schedule and s.schedule
                 and k.schedule[0][1] == s.schedule[0][1]),
                key=lambda k: k.size_bytes,
                default=keep[-1],
            )
            target.size_bytes += s.size_bytes
        merged = keep
    for i, c in enumerate(merged):
        c.index = i
    return merged
