"""Splitter (paper Fig. 6 step 2) — divide a collective into chunks.

The paper uses equal-size chunks (default 64 per collective).  We also
provide a beyond-paper *water-filling* splitter: run the greedy scheduler
with a large number of virtual micro-chunks to estimate the fractional mass
each dimension-order should receive, then coalesce the micro-chunks into at
most ``chunks_per_collective`` real chunks of *unequal* sizes whose order
classes match the fractional solution.  This approaches the Ideal bound with
far fewer chunks (lower A-term overhead).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.latency_model import StageOp


@dataclass
class Chunk:
    """One schedulable unit of a collective."""

    index: int
    size_bytes: float
    # Ordered stages assigned by the scheduler; empty until scheduled.
    schedule: list[StageOp] = field(default_factory=list)


def split_equal(collective_bytes: float, chunks_per_collective: int) -> list[Chunk]:
    """Paper's Splitter: equal-size chunks."""
    if chunks_per_collective < 1:
        raise ValueError("chunks_per_collective must be >= 1")
    size = collective_bytes / chunks_per_collective
    return [Chunk(i, size) for i in range(chunks_per_collective)]


def coalesce_by_order(
    micro_chunks: list[Chunk], max_chunks: int
) -> list[Chunk]:
    """Merge scheduled micro-chunks with identical stage orders.

    Used by the water-filling splitter: after greedily scheduling many tiny
    chunks, chunks sharing the same dimension order are mass-equivalent and
    can be fused into one larger chunk, preserving the per-dimension byte
    assignment exactly while reducing per-chunk fixed overhead.
    """
    groups: dict[tuple, Chunk] = {}
    for c in micro_chunks:
        key = tuple(c.schedule)
        if key in groups:
            groups[key].size_bytes += c.size_bytes
        else:
            groups[key] = Chunk(len(groups), c.size_bytes, list(c.schedule))
    merged = list(groups.values())
    merged.sort(key=lambda c: -c.size_bytes)
    if len(merged) > max_chunks:
        # Fold the smallest groups into the largest group of the same first
        # dimension (keeps per-dim loads close to the fractional solution).
        keep, spill = merged[:max_chunks], merged[max_chunks:]
        for s in spill:
            target = min(
                (k for k in keep if k.schedule and s.schedule
                 and k.schedule[0][1] == s.schedule[0][1]),
                key=lambda k: k.size_bytes,
                default=keep[-1],
            )
            target.size_bytes += s.size_bytes
        merged = keep
    for i, c in enumerate(merged):
        c.index = i
    return merged
