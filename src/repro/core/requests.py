"""CollectiveRequest — the unit of work of the online scheduling API.

A request is one collective (AR/RS/AG) of a given size that becomes ready
at ``issue_time`` (seconds, simulation clock).  Backward-pass gradient
buckets, pipeline-stage activations, or multi-tenant jobs each map to a
stream of requests; requests whose service windows overlap contend for the
same network dimensions, which is where scheduling-policy differences
materialize (Rashidi et al. arXiv 2007.00156, Blink arXiv 1910.04940).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class CollectiveRequest:
    """One collective to be scheduled and simulated.

    ``priority`` breaks intra-dimension service ties (higher serves first);
    ``stream`` is a free-form tag identifying the issuing stream (e.g.
    "bwd-buckets", "mp-critical-path") used for reporting; ``tenant``
    identifies the job the request belongs to on a shared fabric — the
    :class:`repro.tenancy.FabricArbiter` arbitrates service between tenants
    and per-tenant metrics aggregate over it.
    """

    collective: str            # 'AR' | 'RS' | 'AG'
    size_bytes: float
    issue_time: float = 0.0
    priority: int = 0
    stream: str = "default"
    tenant: str = "default"

    def __post_init__(self):
        if self.collective not in ("AR", "RS", "AG"):
            raise ValueError(f"unsupported collective {self.collective!r}")
        if self.size_bytes < 0:
            raise ValueError("size_bytes must be >= 0")
        if self.issue_time < 0:
            raise ValueError("issue_time must be >= 0")
