"""Dim Load Tracker (paper Fig. 6 / Algorithm 1).

Maintains the accumulated predicted communication time ("load") each network
dimension has been assigned by the chunks scheduled so far.

Two operating modes:

  * **one-shot** (legacy, `reset()`): loads are re-initialized to each
    dimension's fixed delay ``A_K`` at the start of every collective
    (Sec. 4.4) — correct when collectives run back-to-back, one at a time.
  * **running** (arrival-time-aware, `advance_to()` + `begin_collective()`):
    the tracker keeps a wall-clock cursor; each dimension drains its pending
    load at one second of work per second of wall time, and a new request
    arriving at time *t* sees the *residual* loads of everything still in
    flight plus its own ``A_K``.  This is the paper Sec. 4.4 running-load
    view extended across overlapping collectives (backprop bucket streams),
    where scheduling-policy differences actually materialize.
"""
from __future__ import annotations

from repro.core.latency_model import LatencyModel


class DimLoadTracker:
    def __init__(self, latency_model: LatencyModel):
        self._lm = latency_model
        self._loads: list[float] = [0.0] * latency_model.topology.num_dims
        self._now: float = 0.0

    # -- one-shot mode (per-collective reset, Algorithm 1) ------------------
    def reset(self, collective: str) -> None:
        """Re-initialize loads to A_K of ``collective`` ('RS'|'AG'|'AR')."""
        self._loads = [
            self._lm.fixed_delay(k, collective)
            for k in range(self._lm.topology.num_dims)
        ]
        self._now = 0.0

    # -- running mode (arrival-time-aware, across collectives) --------------
    def advance_to(self, t: float) -> None:
        """Drain pending loads by the wall time elapsed since the last
        observation.  Each dimension is a serial resource working off its
        queue at unit rate, so ``dt`` seconds retire ``dt`` seconds of load
        (floored at zero for dims that went idle)."""
        dt = t - self._now
        if dt <= 0:
            return
        self._loads = [max(0.0, l - dt) for l in self._loads]
        self._now = t

    def begin_collective(self, collective: str) -> None:
        """Charge each dim's fixed delay A_K for a new collective *without*
        discarding residual loads of collectives still in flight."""
        for k in range(len(self._loads)):
            self._loads[k] += self._lm.fixed_delay(k, collective)

    @property
    def now(self) -> float:
        return self._now

    # -- shared ---------------------------------------------------------------
    def get_loads(self) -> list[float]:
        return list(self._loads)

    def update(self, new_load: dict[int, float]) -> None:
        for dim_idx, secs in new_load.items():
            self._loads[dim_idx] += secs

    def update_loads(self, deltas: list[float]) -> None:
        """Elementwise add of a dense per-dim load vector (the hot-path
        variant of :meth:`update`, fed by ``calc_loads_list``; adding the
        vector's 0.0 entries is a float no-op, so both paths agree bit-for-
        bit)."""
        loads = self._loads
        for k, v in enumerate(deltas):
            if v:
                loads[k] += v

    @property
    def imbalance(self) -> float:
        return max(self._loads) - min(self._loads)

    @property
    def min_load_dim(self) -> int:
        return min(range(len(self._loads)), key=self._loads.__getitem__)
