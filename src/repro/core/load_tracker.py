"""Dim Load Tracker (paper Fig. 6 / Algorithm 1).

Maintains the accumulated predicted communication time ("load") each network
dimension has been assigned by the chunks scheduled so far.  Reset at the
start of every collective; initialized with each dimension's fixed delay
``A_K`` for the requested collective type (Sec. 4.4).
"""
from __future__ import annotations

from repro.core.latency_model import LatencyModel


class DimLoadTracker:
    def __init__(self, latency_model: LatencyModel):
        self._lm = latency_model
        self._loads: list[float] = [0.0] * latency_model.topology.num_dims

    def reset(self, collective: str) -> None:
        """Re-initialize loads to A_K of ``collective`` ('RS'|'AG'|'AR')."""
        self._loads = [
            self._lm.fixed_delay(k, collective)
            for k in range(self._lm.topology.num_dims)
        ]

    def get_loads(self) -> list[float]:
        return list(self._loads)

    def update(self, new_load: dict[int, float]) -> None:
        for dim_idx, secs in new_load.items():
            self._loads[dim_idx] += secs

    @property
    def imbalance(self) -> float:
        return max(self._loads) - min(self._loads)

    @property
    def min_load_dim(self) -> int:
        return min(range(len(self._loads)), key=self._loads.__getitem__)
