"""Chunk-schedule consistency (paper Sec. 4.6).

Inter-dimension consistency (4.6.1) is structural in our implementation:
the Latency Model and Dim Load Tracker are deterministic pure functions of
offline parameters (A_K, B_K), so every NPU derives the *same* per-chunk
schedules.  (In the JAX integration this is even stronger — a single SPMD
program is compiled once and runs on all devices.)

Intra-dimension consistency (4.6.2): runtime variation could make chunks
ready in different orders on different NPUs and deadlock the collective.
Themis therefore simulates the execution offline (deterministically) and
fixes the per-dimension op order; at runtime every NPU serves ops in exactly
this order, idling rather than serving out of turn.  The order is computed
once per (collective, schedule) and reused across training iterations.
"""
from __future__ import annotations

from repro.core.chunking import Chunk
from repro.core.simulator import OpId, simulate
from repro.topology import Topology


def fix_intra_dim_order(
    topology: Topology,
    chunk_groups: list[list[Chunk]],
    *,
    intra: str = "SCF",
    fusion: bool = True,
) -> list[list[OpId]]:
    """Deterministic offline simulation -> per-dim mandated op order."""
    res = simulate(topology, chunk_groups, intra=intra, fusion=fusion)
    return res.dim_op_order


def verify_consistent_execution(
    topology: Topology,
    chunk_groups: list[list[Chunk]],
    *,
    intra: str = "SCF",
    jitter: float = 0.3,
    trials: int = 5,
) -> bool:
    """With the mandated order enforced, per-dim service order is identical
    across runs regardless of runtime jitter (deadlock-freedom argument)."""
    order = fix_intra_dim_order(topology, chunk_groups, intra=intra)
    for trial in range(trials):
        res = simulate(
            topology,
            chunk_groups,
            intra=intra,
            enforced_order=order,
            jitter=jitter,
            seed=trial + 1,
        )
        if res.dim_op_order != order:
            return False
    return True
