"""Compiled cohort engine — the ``engine="compiled"`` fast path.

The indexed engine (``repro.core.simulator._simulate_indexed``) is
near-linear but *loop-bound*: every stage-op costs a ready-event heap
push/pop on the global event heap, a keyed push into its dim's ready
heap, and a fused pop in ``select_batch`` — ~6 interpreted heap
operations per op.  This engine removes per-event Python from the fast
path by processing event **cohorts**:

  * **Cohort events.**  A service completion releases its whole batch's
    successor stages at one instant with *contiguous* arrival seqs (the
    indexed engine pushes them back-to-back, consuming consecutive
    ``seq`` values with nothing interleaved).  One heap entry
    ``(t, s0, READY, [handles])`` therefore represents the whole wave,
    and the global event heap shrinks from O(stage-ops) live entries to
    O(dims) — frees, dones, and pending cohorts.
  * **Struct-of-arrays precompute.**  Per-dim key uniformity, initial
    arrival cohorts, saturation caps and fused wire sums are derived
    from the :class:`~repro.core.simulator.TaskArrays` columns in fused
    numpy ops before the loop starts (the ``vector-zone`` sections,
    enforced by ``tools/lint_engine.py``).
  * **O(1) list queues.**  When every op targeting a dim shares one
    ``(priority, wire, fixed)`` key, the indexed engine's per-dim heap
    order degenerates to arrival-seq order; the queue becomes two
    append-only lists with head pointers (initial-stage arrivals sort
    before all chain arrivals because their seqs were assigned at
    setup), and ``select_batch`` is a slice whose wire sum and
    saturation cap were precomputed.  Heterogeneous dims keep the exact
    indexed heap keys.

**Bit-identity contract.**  The numpy-cohort path is bit-identical to
``engine="indexed"`` (which is itself bit-identical to the reference
oracle): the tie-break counter advances through the same values in the
same order (1 per readied stage, 3 per service), the jitter/straggler
RNG is drawn at the same points, and every float accumulation (batch
wire sums, ``dim_busy``/``dim_wire``) runs in the same sequence —
``SimResult.diff_fields`` returns ``[]`` against the indexed engine on
any eligible input.  ``tests/test_engine_equiv.py`` and the
``benchmarks/sched_perf.py`` 28-scenario matrix gate this.

**Eligibility and fallback.**  The compiled engine covers the
no-preemption fast path only: intra SCF/FIFO, fusion, priorities,
issue times, tenants/streams, jitter/straggler noise, and
dependency-gated release.  Features that preempt or instrument the
event loop — ``arbiter``, ``enforced_order``, ``faults``,
``admission``, ``tracer``, ``replanner``, ``check_invariants`` — fall
back to ``engine="indexed"`` automatically and silently (the same
duck-typed fallback pattern as the indexed engine's non-indexable
arbiters).  The single documented fallback signal is
:data:`LAST_FALLBACK` / :data:`FALLBACK_COUNTS` (and the
``simulate.compiled.fallback`` counter on an installed
:class:`repro.obs.metrics.MetricsRegistry`); no warning is emitted.

**Optional jax.jit lowering.**  :func:`wave_done_times` lowers the
inner no-preemption kernel (FIFO, fusion-off, rank-synchronous) to a
``jax.jit``-compiled segment scan.  Its results are *numeric*, not
bit-exact: XLA reorders float math, so agreement with the cohort engine
is within :data:`JIT_RTOL` (documented tolerance: 1e-4 relative, safe
for jax's default float32; ~1e-9 when ``jax_enable_x64`` is on).
"""
from __future__ import annotations

import gc
import heapq
import itertools
import random

import numpy as np

from repro.core.latency_model import LatencyModel
from repro.core.simulator import (
    ServiceInterval,
    SimResult,
    TaskArrays,
    build_task_arrays,
)
from repro.obs.metrics import current_registry
from repro.topology import Topology

# Documented numeric tolerance of the jax.jit wave kernel vs the cohort
# engine (relative).  float32-safe; see module docstring.
JIT_RTOL = 1e-4

# ---------------------------------------------------------------------------
# Fallback signal — the single documented channel (no warnings).
# ---------------------------------------------------------------------------
#: Reason string of the most recent compiled->indexed fallback in this
#: process, or None if none has happened (or since reset_fallbacks()).
LAST_FALLBACK: str | None = None
#: reason -> count of compiled->indexed fallbacks in this process.
FALLBACK_COUNTS: dict[str, int] = {}

# Keyword features outside the compiled fast path, in check order.
FAST_PATH_BLOCKERS = ("arbiter", "enforced_order", "faults", "admission",
                      "tracer", "replanner", "check_invariants")


def fast_path_blocker(*, arbiter=None, enforced_order=None, faults=None,
                      admission=None, tracer=None, replanner=None,
                      check_invariants: bool = False) -> str | None:
    """First requested feature the compiled fast path cannot serve, or
    None when ``engine="compiled"`` is eligible."""
    if arbiter is not None:
        return "arbiter"
    if enforced_order is not None:
        return "enforced_order"
    if faults is not None:
        return "faults"
    if admission is not None:
        return "admission"
    if tracer is not None:
        return "tracer"
    if replanner is not None:
        return "replanner"
    if check_invariants:
        return "check_invariants"
    return None


def record_fallback(reason: str) -> None:
    """Record a compiled->indexed fallback (deterministic, warning-free).

    Inspect :data:`LAST_FALLBACK` / :data:`FALLBACK_COUNTS`, or the
    ``simulate.compiled.fallback`` counters on an installed metrics
    registry."""
    global LAST_FALLBACK
    LAST_FALLBACK = reason
    FALLBACK_COUNTS[reason] = FALLBACK_COUNTS.get(reason, 0) + 1
    reg = current_registry()
    if reg is not None:
        reg.inc("simulate.compiled.fallback")
        reg.inc(f"simulate.compiled.fallback.{reason}")


def reset_fallbacks() -> None:
    """Clear the fallback signal (test isolation)."""
    global LAST_FALLBACK
    LAST_FALLBACK = None
    FALLBACK_COUNTS.clear()


def _as_list(col) -> list:
    """TaskArrays column as a plain Python list (scalar indexing in the
    event loop is ~5x faster on lists than on numpy arrays)."""
    if type(col) is list:
        return col
    if hasattr(col, "tolist"):
        return col.tolist()
    return list(col)


# Event kinds (tuple layout (t, seq, kind, payload); seqs are unique so
# kind/payload are never compared by the heap).
_READY, _FREE, _DONE = 0, 1, 2


def _np_cols(ta: TaskArrays) -> tuple:
    """Numpy views of the TaskArrays columns the precompute zones need
    (dim, wire, fixed, prio, group, last), cached on the TaskArrays'
    ``_np_cols`` slot.  Replays of one prebuilt TaskArrays (the
    batch/benchmark pattern) skip the O(n) list->array conversions;
    the cache dies with its TaskArrays."""
    cols = getattr(ta, "_np_cols", None)
    if cols is None:
        cols = (np.asarray(ta.dim, dtype=np.int64),
                np.asarray(ta.wire, dtype=np.float64),
                np.asarray(ta.fixed, dtype=np.float64),
                np.asarray(ta.prio, dtype=np.int64),
                np.asarray(ta.group, dtype=np.int64),
                np.asarray(ta.last, dtype=bool))
        try:
            ta._np_cols = cols
        except AttributeError:  # pragma: no cover - foreign container
            pass
    return cols


def _small_unique(a: np.ndarray) -> np.ndarray:
    """Sorted distinct values of ``a``, cheap when cardinality is small.

    Collective streams have a handful of distinct wire/priority values
    per dim; probing a prefix and verifying membership with a binary
    search is O(n log k) instead of np.unique's full O(n log n) sort."""
    if len(a) > 8192:
        head = np.unique(a[:4096])
        if len(head) < 1024:
            pos = np.searchsorted(head, a)
            pos[pos == len(head)] = len(head) - 1
            if bool((head[pos] == a).all()):
                return head
    return np.unique(a)


def simulate_compiled(
    topology: Topology,
    chunk_groups,
    *,
    issue_times: list[float],
    priorities: list[int],
    intra: str,
    fusion: bool,
    fusion_limit: int,
    jitter: float,
    seed: int,
    tenants: list[str],
    streams: list[str],
    task_arrays: TaskArrays | None = None,
    deps: list[tuple[int, ...]] | None = None,
    dep_delay: list[float] | None = None,
) -> SimResult:
    """Cohort-vectorized fast-path engine (see module docstring).

    Bit-identical to ``_simulate_indexed`` on every eligible input; the
    dispatcher (``simulate(engine="compiled")``) guarantees eligibility
    before calling this.

    The run pauses the cyclic garbage collector (restored on exit): the
    engine allocates millions of cohort payloads/batch slices that are
    provably acyclic, and generational scans of the struct-of-arrays
    columns would otherwise dominate at 10M+ stage-ops.
    """
    gc_was = gc.isenabled()
    if gc_was:
        gc.disable()
    try:
        return _run_compiled(
            topology, chunk_groups, issue_times=issue_times,
            priorities=priorities, intra=intra, fusion=fusion,
            fusion_limit=fusion_limit, jitter=jitter, seed=seed,
            tenants=tenants, streams=streams, task_arrays=task_arrays,
            deps=deps, dep_delay=dep_delay)
    finally:
        if gc_was:
            gc.enable()


def _run_compiled(
    topology: Topology,
    chunk_groups,
    *,
    issue_times: list[float],
    priorities: list[int],
    intra: str,
    fusion: bool,
    fusion_limit: int,
    jitter: float,
    seed: int,
    tenants: list[str],
    streams: list[str],
    task_arrays: TaskArrays | None = None,
    deps: list[tuple[int, ...]] | None = None,
    dep_delay: list[float] | None = None,
) -> SimResult:
    rng = random.Random(seed)
    lm = LatencyModel.for_topology(topology)
    tbl = lm.stage_tables
    num_dims = topology.num_dims
    n_groups = len(chunk_groups)

    ta = task_arrays
    if ta is None:
        ta = build_task_arrays(lm, chunk_groups, priorities, tenants)
    n_tasks = ta.n_tasks
    t_chunk = _as_list(ta.chunk)
    t_stage = _as_list(ta.stage)
    t_dim = _as_list(ta.dim)
    t_wire = _as_list(ta.wire)
    t_fixed = _as_list(ta.fixed)
    t_group = _as_list(ta.group)
    t_prio = _as_list(ta.prio)
    t_last = _as_list(ta.last)
    first_handles = _as_list(ta.first_handles)
    group_wire = list(ta.group_wire)

    busy_until = [0.0] * num_dims
    dim_busy = [0.0] * num_dims
    dim_wire = [0.0] * num_dims
    svc_batches: list[list[list[int]]] = [[] for _ in range(num_dims)]
    # Shared (chunk, stage) tuples, cached on the TaskArrays: building 10M
    # tuples on the event loop's fragmented heap is 3-5x slower than on a
    # fresh one, and replays of a prebuilt TaskArrays reuse them outright.
    pairs = getattr(ta, "_pairs", None)
    if pairs is None:
        pairs = list(zip(t_chunk, t_stage))
        try:
            ta._pairs = pairs
        except AttributeError:  # pragma: no cover - foreign container
            pass
    activity: list[list[tuple[float, float]]] = [[] for _ in range(num_dims)]
    pending_since: list[float | None] = [None] * num_dims
    group_finish = [t for t in issue_times]
    resolved_issue = list(issue_times)
    straggler = [d.straggler_sigma for d in topology.dims]
    dim_bw = tbl.bw
    scf = intra == "SCF"
    use_deps = deps is not None
    n_first = len(first_handles)

    # ---- SoA precompute: uniformity + initial cohorts ----------------------
    # lint: vector-zone-begin  (fused numpy ops only; no per-event mutation)
    dim_np, wire_np, fixed_np, prio_np, group_np, last_np = _np_cols(ta)
    if n_first and not use_deps:
        first_np = np.asarray(first_handles, dtype=np.int64)
        issue_np = np.asarray(issue_times, dtype=np.float64)
        init_times = issue_np[group_np[first_np]]
        sorted_issue = bool((init_times[1:] >= init_times[:-1]).all())
        # Runs of equal emission time become one arrival cohort each; the
        # run's seqs are contiguous by construction (setup assigns seq
        # 0..n_first-1 in handle order, exactly like the indexed engine).
        brk = np.flatnonzero(init_times[1:] != init_times[:-1]) + 1
        run_starts = np.concatenate(([0], brk))
        run_ends = np.concatenate((brk, [n_first]))
        cohort_t = init_times[run_starts]
        # Processing order is heap-pop order (t, s0); a stable lexsort is
        # the identity when issue times are already non-decreasing.
        order = np.lexsort((run_starts, cohort_t))
    else:
        sorted_issue = True
        order = np.empty(0, dtype=np.int64)
        run_starts = run_ends = cohort_t = order
    # lint: vector-zone-end

    if n_first and not use_deps:
        init_t = cohort_t[order].tolist()
        init_s = run_starts[order].tolist()
        init_h = [first_handles[s:e]
                  for s, e in zip(run_starts[order].tolist(),
                                  run_ends[order].tolist())]
    else:
        init_t = []
        init_s = []
        init_h = []

    # ---- size-class list queues --------------------------------------------
    # A dim's ready heap pops by (-prio, wire, arr) under SCF / (-prio, arr)
    # under FIFO.  Grouping the dim's ops into *classes* — one per distinct
    # key prefix — turns the heap into a fixed scan over per-class FIFO
    # lists: pop order is class-key order, then arrival-seq order within a
    # class.  Arrival order splits into two append-only lists per class
    # (initial stages carry setup seqs 0..n_first-1, which sort before every
    # dynamically assigned seq), so a pop is a head-pointer bump.  This is
    # valid only when queue-pop order provably equals arrival order per
    # class: no dep-gated (future-time, out-of-seq) releases, initial
    # arrivals emitted in non-decreasing time order, uniform per-dim fixed
    # delay (the saturation threshold and the batch's max), and a bounded
    # class count (the per-service scan is O(classes)).
    list_ok = (not use_deps) and sorted_issue
    # Discovery is pure ta-column + intra-policy data, so its result is
    # cached per TaskArrays keyed by the SCF flag (replays skip ~10 full
    # column passes); everything cached is treated as immutable.
    cached = None
    if list_ok:
        cc = getattr(ta, "_cls_cache", None)
        if isinstance(cc, dict):
            cached = cc.get(scf)
    if cached is not None:
        qmode, cls_slots, cls_np, cls_w, cls_fastf, n_slots, uni_fx_l = cached
    else:
        qmode = [False] * num_dims
        cls_slots = [[] for _ in range(num_dims)]
        cls_np = np.zeros(n_tasks, dtype=np.int64)
        cls_w = []      # per-slot uniform wire (fast slots)
        cls_fastf = []  # per-slot: wire uniform within class?
        uni_fx_l = [0.0] * num_dims  # uniform per-dim fixed delay
        n_slots = 0
    if list_ok and cached is None:
        # lint: vector-zone-begin  (class discovery is fused numpy)
        for d in range(num_dims):
            idx = np.flatnonzero(dim_np == d)
            if not len(idx):
                qmode[d] = True
                continue
            fx0 = float(fixed_np[idx[0]])
            if not (fixed_np[idx] == fx0).all():
                continue
            # Rank (-prio, wire) lexicographically via two 1-D uniques
            # (np.unique(axis=0) row-sorts through a void view — far too
            # slow at 10M ops).  Composite rank = prio_rank * n_wire +
            # wire_rank preserves the heap's lexicographic class order.
            wvals = wire_np[idx]
            npr = -prio_np[idx]
            pr_uniq = _small_unique(npr)
            pr_rank = np.searchsorted(pr_uniq, npr)
            if scf:
                w_uniq = _small_unique(wvals)
                nk = len(pr_uniq) * len(w_uniq)
                if nk > 4096:
                    continue
                comp = pr_rank * len(w_uniq) + np.searchsorted(w_uniq, wvals)
            else:
                nk = len(pr_uniq)
                if nk > 4096:
                    continue
                comp = pr_rank
            # occupancy + dense renumber via bincount (no O(n log n) sort)
            present = np.flatnonzero(np.bincount(comp, minlength=nk))
            nc = len(present)
            if nc > 64:
                continue
            remap = np.zeros(nk, dtype=np.int64)
            remap[present] = np.arange(nc)
            inv = remap[comp]
            if scf:
                wu = np.zeros(nc)
                wu[inv] = wvals          # uniform within class by key
                fastmask = np.ones(nc, dtype=bool)
            else:
                wmin = np.full(nc, np.inf)
                wmax = np.full(nc, -np.inf)
                np.minimum.at(wmin, inv, wvals)
                np.maximum.at(wmax, inv, wvals)
                fastmask = wmin == wmax
                wu = wmin
            cls_np[idx] = n_slots + inv
            cls_slots[d] = list(range(n_slots, n_slots + nc))
            cls_w.extend(wu.tolist())        # lint: allow (<=64 classes/dim)
            cls_fastf.extend(bool(b) for b in fastmask)  # lint: allow (<=64)
            n_slots += nc
            qmode[d] = True
            uni_fx_l[d] = fx0
        # lint: vector-zone-end
        try:
            if not isinstance(getattr(ta, "_cls_cache", None), dict):
                ta._cls_cache = {}
            ta._cls_cache[scf] = (qmode, cls_slots, cls_np, cls_w,
                                  cls_fastf, n_slots, uni_fx_l)
        except AttributeError:  # pragma: no cover - foreign container
            pass
    # Scalar class lookups happen only on slow paths and sub-cohort-size
    # payloads; indexing the numpy array there beats materializing 10M
    # fresh int objects per run (a measurable page-fault tax at scale).
    cls_of = cls_np
    # Pre-split each initial cohort into per-class segments (class slot,
    # handle list): the bulk arrival branch then routes a whole cohort with
    # one extend per class and no per-handle scan.  Only within-class order
    # is observable (queues are per-class), and a stable argsort preserves
    # it.
    if n_first and not use_deps and all(qmode):
        # lint: vector-zone-begin  (per-cohort class splits)
        cls_first = cls_np[first_np]
        init_parts = []
        for s, e in zip(run_starts[order].tolist(),
                        run_ends[order].tolist()):
            seg = cls_first[s:e]
            c0 = seg[0]
            if bool((seg == c0).all()):
                init_parts.append(  # lint: allow (one tuple per cohort)
                    ((int(c0), first_handles[s:e]),))
            else:
                o2 = np.argsort(seg, kind="stable")
                segs = seg[o2]
                hs = first_np[s:e][o2]
                b2 = np.flatnonzero(segs[1:] != segs[:-1]) + 1
                bounds = [0, *b2.tolist(), len(segs)]
                init_parts.append(tuple(  # lint: allow (one per cohort)
                    (int(segs[bounds[j]]),
                     hs[bounds[j]:bounds[j + 1]].tolist())
                    for j in range(len(bounds) - 1)))
        # lint: vector-zone-end
    else:
        init_parts = None
    need_arr = use_deps or not all(qmode)
    t_arr = [0] * n_tasks if need_arr else None
    if need_arr and not use_deps:
        for i, hh in enumerate(first_handles):
            t_arr[hh] = i

    # Saturation threshold for list-mode dims (uniform fixed delay is
    # recorded by class discovery; zero for dims with no ops).
    sat_d = [uni_fx_l[d] * dim_bw[d] if qmode[d] else 0.0
             for d in range(num_dims)]

    # Per-slot O(1) batch tables: from a fresh batch, a wire-uniform class
    # stops growing at cls_cap[s] ops (the first k where the sequential
    # total reaches saturation or fusion_limit); cls_wsum[s][k] is the
    # k-fold sequential float sum from 0.0 — bit-for-bit the indexed
    # engine's `wire += t_wire[hh]` accumulation.
    cls_cap = [0] * n_slots
    cls_wsum: list[list[float]] = [[0.0]] * n_slots
    for d in range(num_dims):
        sat = sat_d[d]
        for s in cls_slots[d]:
            if not cls_fastf[s]:
                continue
            w = cls_w[s]
            kcap = 1
            tot = w
            if fusion:
                while tot < sat and kcap < fusion_limit:
                    tot += w
                    kcap += 1
            acc = 0.0
            ws = [0.0]
            for _ in range(kcap):
                acc += w
                ws.append(acc)
            cls_cap[s] = kcap
            cls_wsum[s] = ws

    qi_c: list[list[int]] = [[] for _ in range(n_slots)]  # initial stages
    hi_c = [0] * n_slots
    qd_c: list[list[int]] = [[] for _ in range(n_slots)]  # chain stages
    hd_c = [0] * n_slots
    # Per-service scan order: class-key order, initial before dynamic.
    # Entry: (queue list, head array, slot, fresh-batch cap (0 = scalar
    # path), fresh-batch wire sums).
    scan_d: list[list[tuple]] = [
        [entry for s in cls_slots[d]
         for entry in ((qi_c[s], hi_c, s, cls_cap[s], cls_wsum[s]),
                       (qd_c[s], hd_c, s, cls_cap[s], cls_wsum[s]))]
        for d in range(num_dims)
    ]
    all_q = all(qmode) and not use_deps
    # Count of dims whose pending-interval clock is unset.  When it is zero
    # AND every dim is busy past `now`, an arrival cohort cannot trigger
    # try_start or touch pending_since — it reduces to pure queue appends
    # (the bulk fast path).  Dims that never receive an op are excluded:
    # their clock stays None forever, and parking their busy_until at +inf
    # keeps them out of the all-busy min().
    used_dims = np.zeros(num_dims, dtype=bool)
    used_dims[dim_np] = True
    n_pend_none = int(used_dims.sum())
    for d in range(num_dims):
        if not used_dims[d]:
            busy_until[d] = float("inf")
    heaps: list[list] = [[] for _ in range(num_dims)]     # exact indexed keys
    events: list[tuple] = []
    heappush = heapq.heappush
    heappop = heapq.heappop
    sq = n_first if not use_deps else 0  # tie-break counter (int, not itertools)
    makespan = max(issue_times) if issue_times else 0.0

    svc_start: list[list[float]] = [[] for _ in range(num_dims)]
    svc_end: list[list[float]] = [[] for _ in range(num_dims)]
    rng_random = rng.random
    rng_logn = rng.lognormvariate
    cap_limit = fusion_limit if fusion else 1

    # Hot state rides in as default args (locals, not closure cells).
    def try_start(d: int, now: float, busy_until=busy_until, qmode=qmode,
                  scan_d=scan_d, sat_d=sat_d, t_wire=t_wire, t_fixed=t_fixed,
                  dim_bw=dim_bw, heaps=heaps, events=events,
                  svc_batches=svc_batches, svc_start=svc_start,
                  svc_end=svc_end, dim_busy=dim_busy, dim_wire=dim_wire,
                  straggler=straggler, uni_fx_l=uni_fx_l, jitter=jitter,
                  fusion=fusion, fusion_limit=fusion_limit,
                  cap=cap_limit, heappush=heappush, heappop=heappop,
                  rng_random=rng_random, rng_logn=rng_logn) -> None:
        nonlocal sq
        if busy_until[d] > now:
            return
        if qmode[d]:
            # Replicates the indexed fusion loop over the class scan: the
            # first op fixes the saturation threshold; further ops join
            # while the *sequential* wire total stays below it and under
            # fusion_limit (float accumulation order = batch order,
            # bit-for-bit).  A wire-uniform class feeding a fresh batch
            # takes the O(1) precomputed-table path.
            batch = None
            total = 0.0
            k = 0
            sat = sat_d[d]
            for ql, harr, slot, kc, ws in scan_d[d]:
                h = harr[slot]
                n = len(ql)
                if h >= n:
                    continue
                if kc and not k:
                    avail = n - h
                    if avail >= kc:
                        # fresh batch saturates (or hits the limit) inside
                        # this class: slice + table lookup, no per-op work
                        h += kc
                        batch = ql[h - kc:h]
                        harr[slot] = h
                        if h > 65536 and h + h > n:  # amortized-O(1) halving
                            del ql[:h]
                            harr[slot] = 0
                        k = kc
                        total = ws[kc]
                        break
                    # class drained before any stop condition: take all,
                    # keep scanning from the running total
                    batch = ql[h:]
                    harr[slot] = n
                    if n > 65536:  # fully drained: always safe to clear
                        del ql[:n]
                        harr[slot] = 0
                    k = avail
                    total = ws[avail]
                    continue
                h0 = h
                if kc:
                    # wire-uniform class joining a non-empty batch (k > 0
                    # here: a fresh batch was handled above).  Adding the
                    # cached class wire is the same float add as
                    # t_wire[ql[h]] — no per-item indexing.
                    w = ws[1]
                    lim = h + cap - k
                    if lim > n:
                        lim = n
                    while h < lim and total < sat:
                        total += w
                        h += 1
                    k += h - h0
                else:
                    if not k:
                        hh = ql[h]
                        total = t_wire[hh]
                        k = 1
                        h += 1
                    while h < n and k < cap and total < sat:
                        total += t_wire[ql[h]]
                        k += 1
                        h += 1
                if batch is None:
                    batch = ql[h0:h]
                else:
                    batch += ql[h0:h]
                harr[slot] = h
                if h > 65536 and h + h > n:  # amortized-O(1) halving
                    del ql[:h]
                    harr[slot] = 0
                if k >= cap or total >= sat:
                    break
            if batch is None:
                return
            wire = total
            a = uni_fx_l[d]
        else:
            heap = heaps[d]
            if not heap:
                return
            h0 = heappop(heap)[-1]
            batch = [h0]
            if fusion:
                sat = t_fixed[h0] * dim_bw[d]
                total = t_wire[h0]
                while heap and total < sat and len(batch) < fusion_limit:
                    hh = heappop(heap)[-1]
                    batch.append(hh)
                    total += t_wire[hh]
            a = 0.0
            wire = 0.0
            for hh in batch:
                f = t_fixed[hh]
                if f > a:
                    a = f
                wire += t_wire[hh]
        occupy = wire / dim_bw[d]
        if jitter:
            occupy *= 1.0 + jitter * rng_random()
        if straggler[d]:
            occupy *= rng_logn(0.0, straggler[d])
        free_at = now + occupy
        busy_until[d] = free_at
        dim_busy[d] += occupy
        dim_wire[d] += wire
        svc_batches[d].append(batch)
        svc_start[d].append(now)
        svc_end[d].append(free_at)
        sid = sq               # indexed seq order: sid, free seq, done seq
        sq = sid + 3
        heappush(events, (free_at, sid + 1, _FREE, d))
        heappush(events, (free_at + a, sid + 2, _DONE, batch))

    # ---- dependency machinery (heap mode only) -----------------------------
    if use_deps:
        # Emission-run buffer: consecutive push_ready calls at one time t
        # get contiguous seqs in the indexed engine; buffer them into one
        # cohort and flush when the time changes (or the handler ends).
        run_t = 0.0
        run_h: list[int] = []

        def flush_run() -> None:
            nonlocal sq, run_h
            if run_h:
                s0 = sq
                i = s0
                for hh in run_h:
                    t_arr[hh] = i
                    i += 1
                sq = i
                heappush(events, (run_t, s0, _READY, run_h))
                run_h = []

        def emit(hh: int, t: float) -> None:
            nonlocal run_t
            if run_h and run_t == t:  # same-source float; exact by design
                run_h.append(hh)
            else:
                flush_run()
                run_t = t
                run_h.append(hh)

        group_first: list[list[int]] = [[] for _ in range(n_groups)]
        for hh in first_handles:
            group_first[t_group[hh]].append(hh)
        dep_children: list[list[int]] = [[] for _ in range(n_groups)]
        n_parents = [len(preds) for preds in deps]
        for g, preds in enumerate(deps):
            for p in preds:
                dep_children[p].append(g)
        parent_fin = [0.0] * n_groups
        chains_left = [len(group_first[g]) for g in range(n_groups)]

        def complete_group(g: int, t: float) -> None:
            work = [(g, t)]
            while work:
                gg, tt = work.pop(0)
                for c in dep_children[gg]:
                    if parent_fin[c] < tt:
                        parent_fin[c] = tt
                    n_parents[c] -= 1
                    if n_parents[c]:
                        continue
                    te = max(issue_times[c], parent_fin[c] + dep_delay[c])
                    resolved_issue[c] = te
                    if chains_left[c]:
                        for hh in group_first[c]:
                            emit(hh, te)
                    else:
                        group_finish[c] = te
                        work.append((c, te))

        for g in range(n_groups):
            if deps[g]:
                continue
            te = issue_times[g] + dep_delay[g]
            resolved_issue[g] = te
            if chains_left[g]:
                for hh in group_first[g]:
                    emit(hh, te)
            else:
                group_finish[g] = te
                complete_group(g, te)
        flush_run()

    # ---- the cohort event loop ---------------------------------------------
    t_dim_l = t_dim
    t_last_l = t_last
    t_group_l = t_group
    cls_get = cls_of.__getitem__
    ip = 0
    n_ip = len(init_t)
    ev = events
    while ev or ip < n_ip:
        if ip < n_ip:
            # merge pre-sorted initial cohorts against the dynamic heap
            if ev:
                e0 = ev[0]
                take_init = (init_t[ip], init_s[ip]) < (e0[0], e0[1])
            else:
                take_init = True
            if take_init:
                now = init_t[ip]
                if now > makespan:
                    makespan = now
                if all_q and not n_pend_none and now < min(busy_until):
                    # bulk fast path: every dim busy + pending — no
                    # try_start can fire, no pending clock can change.
                    # Each pre-split class segment lands as one C-level
                    # extend (within-class order is cohort order).
                    for s_c, hs in init_parts[ip]:
                        qi_c[s_c].extend(hs)
                else:
                    for hh in init_h[ip]:
                        d = t_dim_l[hh]
                        if pending_since[d] is None:
                            pending_since[d] = now
                            n_pend_none -= 1
                        if qmode[d]:
                            qi_c[cls_of[hh]].append(hh)
                        elif scf:
                            heappush(heaps[d], (-t_prio[hh], t_wire[hh],
                                                t_arr[hh], hh))
                        else:
                            heappush(heaps[d], (-t_prio[hh], t_arr[hh], hh))
                        if busy_until[d] <= now:
                            try_start(d, now)
                ip += 1
                if ip == n_ip:
                    # No further initial arrivals: splice each class's
                    # remaining initial items onto the front of its chain
                    # queue (in place — the queue objects are captured by
                    # scan entries and arrival sites) and halve the scan.
                    for s in range(n_slots):
                        qio = qi_c[s]
                        qd_c[s][:hd_c[s]] = qio[hi_c[s]:]
                        hd_c[s] = 0
                        qio.clear()
                        hi_c[s] = 0
                    for d in range(num_dims):
                        scan_d[d][:] = [
                            (qd_c[s], hd_c, s, cls_cap[s], cls_wsum[s])
                            for s in cls_slots[d]]
                continue
        e = heappop(ev)
        now = e[0]
        kind = e[2]
        if kind == _READY:
            if now > makespan:
                makespan = now
            b = e[3]
            if all_q and not n_pend_none and now < min(busy_until):
                # bulk fast path (see the initial-cohort branch)
                if type(b) is list:
                    cs = set(map(cls_get, b))
                    if len(cs) == 1:
                        qd_c[cs.pop()].extend(b)
                    else:
                        for hh in b:
                            qd_c[cls_of[hh]].append(hh)
                else:
                    # numpy cohort: route per class with masked slices.
                    # Queues are per-class, so only within-class order is
                    # observable — and a boolean mask preserves it.
                    cl = cls_np[b]
                    c0 = cl[0]
                    if (cl == c0).all():
                        qd_c[c0].extend(b.tolist())
                    else:
                        for s in dict.fromkeys(cl.tolist()):
                            qd_c[s].extend(b[cl == s].tolist())
            else:
                if type(b) is not list:
                    b = b.tolist()
                for hh in b:
                    d = t_dim_l[hh]
                    if pending_since[d] is None:
                        pending_since[d] = now
                        n_pend_none -= 1
                    if qmode[d]:
                        qd_c[cls_of[hh]].append(hh)
                    elif scf:
                        heappush(heaps[d], (-t_prio[hh], t_wire[hh],
                                            t_arr[hh], hh))
                    else:
                        heappush(heaps[d], (-t_prio[hh], t_arr[hh], hh))
                    if busy_until[d] <= now:
                        try_start(d, now)
        elif kind == _FREE:
            d = e[3]
            if now > makespan:
                makespan = now
            if pending_since[d] is not None:
                if qmode[d]:
                    empty = True
                    for ql, harr, slot, _kc, _ws in scan_d[d]:
                        if harr[slot] < len(ql):
                            empty = False
                            break
                else:
                    empty = not heaps[d]
                if empty:
                    activity[d].append((pending_since[d], now))
                    pending_since[d] = None
                    n_pend_none += 1
            try_start(d, now)
        else:  # _DONE — the batch's next stages become ready as one cohort
            if now > makespan:
                makespan = now
            if use_deps:
                for hh in e[3]:
                    if not t_last_l[hh]:
                        emit(hh + 1, now)
                        continue
                    g = t_group_l[hh]
                    if group_finish[g] < now:
                        group_finish[g] = now
                    chains_left[g] -= 1
                    if not chains_left[g]:
                        complete_group(g, now)
                flush_run()
            else:
                b = e[3]
                if all_q and len(b) >= 24:
                    # numpy successor construction: one gather on the
                    # last-stage mask replaces the per-handle listcomp.
                    # group_finish is a max-fold, so retire order within
                    # the cohort is unobservable.  all_q implies every dim
                    # is list-mode, so no t_arr bookkeeping is needed.
                    bn = np.asarray(b)
                    m = last_np[bn]
                    if m.any():
                        for hh in bn[m].tolist():
                            g = t_group_l[hh]
                            if group_finish[g] < now:
                                group_finish[g] = now
                        nxtn = bn[~m]
                        nxtn += 1
                    else:
                        nxtn = bn + 1
                    nn = len(nxtn)
                    if nn:
                        s0 = sq
                        sq = s0 + nn
                        heappush(ev, (now, s0, _READY, nxtn))
                    continue
                nxt = [hh + 1 for hh in b if not t_last_l[hh]]
                if len(nxt) != len(b):  # some chunk chains just retired
                    for hh in b:
                        if t_last_l[hh]:
                            g = t_group_l[hh]
                            if group_finish[g] < now:
                                group_finish[g] = now
                if nxt:
                    s0 = sq
                    if need_arr:
                        i = s0
                        for hh in nxt:
                            t_arr[hh] = i
                            i += 1
                    sq = s0 + len(nxt)
                    heappush(ev, (now, s0, _READY, nxt))

    for d in range(num_dims):
        if pending_since[d] is not None:  # pragma: no cover - safety
            activity[d].append((pending_since[d], makespan))

    if use_deps:
        for g in range(n_groups):
            if n_parents[g] > 0:
                raise ValueError(
                    f"dependency cycle: group {g} never became eligible")
        if group_finish:
            makespan = max(makespan, max(group_finish))

    # ---- finalize: materialize per-dim op order + service intervals --------
    # lint: vector-zone-begin  (bulk materialization; no per-event mutation)
    chain = itertools.chain.from_iterable
    pget = pairs.__getitem__
    tg_get = t_group.__getitem__
    dim_order = [list(map(pget, chain(svc_batches[d])))
                 for d in range(num_dims)]
    dim_services = [
        [ServiceInterval(s, e, tuple(sorted(set(map(tg_get, b)))))
         for s, e, b in zip(svc_start[d], svc_end[d], svc_batches[d])]
        for d in range(num_dims)
    ]
    # lint: vector-zone-end
    return SimResult(makespan, dim_busy, dim_wire, activity, dim_order,
                     dim_services, resolved_issue, group_finish,
                     list(streams), list(tenants), group_wire)


# ---------------------------------------------------------------------------
# Optional jax.jit lowering of the inner no-preemption kernel
# ---------------------------------------------------------------------------
def jit_available() -> bool:
    """Can the jax.jit wave kernel run in this environment?"""
    try:
        import jax  # noqa: F401
    except Exception:  # pragma: no cover - jax is baked into the image
        return False
    return True


_WAVE_KERNEL = None


def _get_wave_kernel():
    global _WAVE_KERNEL
    if _WAVE_KERNEL is not None:
        return _WAVE_KERNEL
    import jax
    import jax.numpy as jnp
    from jax import lax

    def kernel(issue, occ, fx, dims):
        C, R = occ.shape
        idx = jnp.arange(C)
        arrive = issue
        for r in range(R):  # R is static; unrolled under jit
            d = dims[:, r]
            order = jnp.lexsort((idx, arrive, d))
            d_s = d[order]
            a_s = arrive[order]
            o_s = occ[:, r][order]
            new_seg = jnp.concatenate(
                (jnp.ones(1, dtype=bool), d_s[1:] != d_s[:-1]))

            def step(prev_free, x):
                a_i, o_i, fresh = x
                start = jnp.where(fresh, a_i, jnp.maximum(a_i, prev_free))
                free = start + o_i
                return free, free

            _, free_s = lax.scan(step, jnp.float32(0.0).astype(a_s.dtype),
                                 (a_s, o_s, new_seg))
            done_s = free_s + fx[:, r][order]
            inv = jnp.zeros_like(order).at[order].set(idx)
            arrive = done_s[inv]
        return arrive

    _WAVE_KERNEL = jax.jit(kernel)
    return _WAVE_KERNEL


def wave_done_times(issue_times, occupy, fixed, dims):
    """jax.jit-lowered rank-synchronous wave kernel (no preemption).

    Inputs: ``issue_times`` (C,), ``occupy``/``fixed`` (C, R) floats and
    ``dims`` (C, R) ints — chunk c's rank-r stage occupies dim
    ``dims[c, r]`` for ``occupy[c, r]`` seconds and completes
    ``fixed[c, r]`` later.  Each rank is served FIFO per dim (arrival
    time, then chunk index) — the cohort engine's semantics when fusion
    is off, priorities are flat, and rank barriers hold (wave-
    synchronous streams: uniform sizes, shared issue instant).

    Returns the (C,) final done times as numpy.  Numeric, not bit-exact:
    agreement with :func:`simulate_compiled` is within :data:`JIT_RTOL`
    relative (see module docstring).
    """
    import jax.numpy as jnp

    kernel = _get_wave_kernel()
    out = kernel(jnp.asarray(issue_times), jnp.asarray(occupy),
                 jnp.asarray(fixed), jnp.asarray(dims, dtype=jnp.int32))
    return np.asarray(out)


def wave_arrays(topology: Topology, chunk_groups, issue_times):
    """Build :func:`wave_done_times` inputs from chunk groups.

    Requires every chunk to have the same number of stages (a wave-
    shaped stream); raises ValueError otherwise.  Occupy times are
    wire/bw per stage — the no-jitter service time of an unfused batch
    of one.
    """
    lm = LatencyModel.for_topology(topology)
    ta = build_task_arrays(lm, chunk_groups,
                           [0] * len(chunk_groups),
                           ["default"] * len(chunk_groups))
    # lint: vector-zone-begin  (pure numpy reshape of the SoA columns)
    lens = np.diff(np.asarray(
        ta.first_handles + [ta.n_tasks], dtype=np.int64))
    if len(lens) and not (lens == lens[0]).all():
        raise ValueError("wave kernel needs equal stage counts per chunk")
    R = int(lens[0]) if len(lens) else 0
    C = len(ta.first_handles)
    dims = np.asarray(ta.dim, dtype=np.int64).reshape(C, R)
    wire = np.asarray(ta.wire, dtype=np.float64).reshape(C, R)
    fixed = np.asarray(ta.fixed, dtype=np.float64).reshape(C, R)
    bw = np.asarray(LatencyModel.for_topology(topology).stage_tables.bw)
    occupy = wire / bw[dims]
    issue = np.asarray(issue_times, dtype=np.float64)[
        np.asarray(ta.group, dtype=np.int64)[
            np.asarray(ta.first_handles, dtype=np.int64)]]
    # lint: vector-zone-end
    return issue, occupy, fixed, dims
