"""BW-provisioning analysis for network designers (paper Sec. 6.3).

For any two dimensions K < L, compare BW(dimK) against
``P_K * P_{K+1} * ... * P_{L-1} * BW(dimL)``:

  * Just-Enough      (==): baseline scheduling already saturates both dims.
  * Over-Provisioned  (<): baseline strands dimL bandwidth; Themis recovers it.
  * Under-Provisioned (>): no chunk schedule can fully drive both dims —
                           a design point to prohibit.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.topology import Topology


@dataclass(frozen=True)
class PairVerdict:
    dim_k: int
    dim_l: int
    ratio: float      # BW(dimK) / (prod(P_K..P_{L-1}) * BW(dimL))
    verdict: str      # 'just-enough' | 'over-provisioned' | 'under-provisioned'


def classify_pair(topology: Topology, k: int, l: int, tol: float = 0.05) -> PairVerdict:
    assert k < l
    prod = 1
    for i in range(k, l):
        prod *= topology.dims[i].npus
    ratio = topology.dims[k].aggr_bw_bytes / (prod * topology.dims[l].aggr_bw_bytes)
    if abs(ratio - 1.0) <= tol:
        verdict = "just-enough"
    elif ratio < 1.0:
        verdict = "over-provisioned"  # dimL has excess BW baseline wastes
    else:
        verdict = "under-provisioned"
    return PairVerdict(k, l, ratio, verdict)


def analyze(topology: Topology, tol: float = 0.05) -> list[PairVerdict]:
    out = []
    for k in range(topology.num_dims):
        for l in range(k + 1, topology.num_dims):
            out.append(classify_pair(topology, k, l, tol))
    return out


def baseline_utilization_bound(topology: Topology) -> float:
    """Closed-form baseline avg BW utilization for a large All-Reduce.

    Baseline loads: n_K = (P_K - 1)/P_K * S / prod(P_1..P_{K-1}); makespan is
    the slowest dim; utilization = sum(n_K) / (T * sum(BW)).
    """
    s = 1.0
    shrink = 1.0
    n = []
    for d in topology.dims:
        n.append((d.npus - 1) / d.npus * s * shrink)
        shrink /= d.npus
    t = max(nk / d.aggr_bw_bytes for nk, d in zip(n, topology.dims))
    return sum(n) / (t * topology.total_bw_bytes)


def themis_utilization_bound(topology: Topology) -> float:
    """Fractional (water-filling) utilization bound for Themis.

    Upper-bounded by 1.0; below 1.0 when some pair is under-provisioned such
    that no schedule can keep every dim busy (Sec. 6.3).  Computed by greedy
    fractional assignment with many micro-chunks.
    """
    from repro.core.scheduler import schedule_collective
    from repro.core.latency_model import LatencyModel

    lm = LatencyModel(topology)
    chunks = schedule_collective(topology, "AR", 1e9, 2048, "themis")
    loads = {k: 0.0 for k in range(topology.num_dims)}
    for c in chunks:
        for k, secs in lm.calc_loads(c.size_bytes, c.schedule).items():
            loads[k] += secs
    t = max(loads.values())
    moved = sum(
        loads[k] * topology.dims[k].aggr_bw_bytes for k in loads
    )
    return moved / (t * topology.total_bw_bytes)
