"""Themis Scheduler — paper Algorithm 1, plus beyond-paper variants.

Policies:
  * ``baseline``      — static multi-rail hierarchical order (Sec. 2.3):
                        RS dim1..dimD then AG dimD..dim1, same for all chunks.
  * ``themis``        — Algorithm 1: greedy per-chunk order by sorted dim
                        loads (ascending for RS, descending for AG), with the
                        threshold guard reverting to baseline order; for AR
                        the AG order is the reverse of the RS order (line 8).
  * ``themis_indep_ag`` (beyond paper) — exploits the full (D! x D!) space of
                        Observation 1: after committing a chunk's RS loads,
                        the AG order is re-derived from the *updated* loads
                        instead of being forced to reverse(RS).
  * ``lookahead``     (beyond paper) — evaluates all D! RS orders for each
                        chunk and commits the one minimizing the projected
                        makespan (max dim load).  D <= 4 keeps this <= 24
                        candidates per chunk.
  * ``themis_guarded`` (beyond paper) — greedy, but a chunk's reordered
                        schedule is committed only if its projected makespan
                        beats the baseline order's.  Fixes the greedy's
                        overshoot on *just-enough* provisioned networks
                        (starting RS on a slow dim loads it with the full
                        un-shrunk chunk) at 2 evaluations per chunk.

All policies return the same artifact: a list of ``Chunk``s whose
``schedule`` is the ordered list of (phase, dim) stage ops.
"""
from __future__ import annotations

import contextlib
import itertools
from dataclasses import dataclass
from typing import Callable, Iterator, Sequence

from repro.core.chunking import Chunk, coalesce_by_order, split_equal
from repro.core.latency_model import LatencyModel, StageOp
from repro.core.load_tracker import DimLoadTracker
from repro.core.requests import CollectiveRequest
from repro.obs.metrics import ScheduleDecision, current_registry
from repro.topology import Phase, Topology

POLICIES = ("baseline", "themis", "themis_indep_ag", "lookahead",
            "themis_guarded")

# Threshold = predicted runtime of an RS/AG of size chunk/16 on the dim with
# the lowest current load (paper Sec. 5.3).
THRESHOLD_DIVISOR = 16.0


def baseline_order(num_dims: int, collective: str) -> list[StageOp]:
    """Sec. 2.3 static schedule: RS dim1->dimD, AG dimD->dim1."""
    rs = [(Phase.RS, k) for k in range(num_dims)]
    ag = [(Phase.AG, k) for k in reversed(range(num_dims))]
    if collective == "RS":
        return rs
    if collective == "AG":
        return ag
    return rs + ag


def _collective_of(chunks: Sequence[Chunk]) -> str | None:
    """Recover the collective kind from scheduled chunks (RS-only, AG-only
    or both phases -> AR).  ``None`` if no chunk carries a schedule."""
    for c in chunks:
        if c.schedule:
            phases = {phase for phase, _ in c.schedule}
            if len(phases) == 2:
                return "AR"
            return "RS" if Phase.RS in phases else "AG"
    return None


def _sorted_dims(loads: Sequence[float], descending: bool) -> list[int]:
    # Stable sort; ties resolve to lower dim index (deterministic across
    # NPUs — required for Sec. 4.6.1 inter-dim schedule consistency).
    return sorted(range(len(loads)), key=lambda k: (loads[k],), reverse=descending)


@dataclass
class ThemisScheduler:
    """Implements SCHEDULE_COLLECTIVE / SCHEDULER.SCHEDULE of Algorithm 1.

    ``tracker`` may be supplied to share one Dim Load Tracker between
    several scheduler instances — the cross-tenant Themis mode
    (``repro.tenancy``) gives every tenant's scheduler the same fabric-wide
    tracker so each tenant's chunk orders steer around *other tenants'*
    residual loads, not just their own.

    ``metrics`` (a :class:`repro.obs.MetricsRegistry`) turns on decision
    logging, memo-cache hit/miss counters and span timers; ``None``
    (default) adopts the process-global registry if one is installed
    (``repro.obs.enable_global``, the ``benchmarks/run.py --trace`` path)
    and otherwise disables instrumentation — every call site is guarded,
    so the off path costs one branch per event.
    """

    latency_model: LatencyModel
    policy: str = "themis"
    tracker: DimLoadTracker | None = None
    metrics: object | None = None

    # Caches are bounded: equal-size chunk runs produce a handful of distinct
    # (size, schedule) pairs, but adversarial streams with many distinct
    # sizes must not grow memory without bound.
    _CACHE_CAP = 4096

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}; want {POLICIES}")
        if self.tracker is None:
            self.tracker = DimLoadTracker(self.latency_model)
        if self.metrics is None:
            self.metrics = current_registry()
        # Last greedy decision's memo signature / hit flag, captured only
        # while a registry is installed (feeds the per-request decision log).
        self._last_sig: tuple = ()
        self._last_hit = False
        # (chunk_bytes, schedule) -> dense per-dim load delta.  Exact: the
        # delta a schedule adds is independent of the current loads.
        self._delta_cache: dict[tuple, list[float]] = {}
        # Rank-signature memo for the greedy order (see _greedy_order).
        self._greedy_cache: dict[tuple, tuple[StageOp, ...]] = {}
        # (min_dim, chunk_bytes) -> Sec. 5.3 threshold.
        self._thr_cache: dict[tuple[int, float], float] = {}
        # collective -> the D! lookahead candidate schedules.
        self._cand_cache: dict[str, list[tuple[StageOp, ...]]] = {}

    def _stage_deltas(self, chunk_bytes: float, sched) -> list[float]:
        """Per-dim load vector one chunk adds via ``sched`` (memoized)."""
        key = (chunk_bytes, tuple(sched))
        got = self._delta_cache.get(key)
        reg = self.metrics
        if reg is not None:
            reg.inc("scheduler.delta_cache.hit" if got is not None
                    else "scheduler.delta_cache.miss")
        if got is None:
            if len(self._delta_cache) >= self._CACHE_CAP:
                self._delta_cache.clear()
            got = self._delta_cache[key] = self.latency_model.calc_loads_list(
                chunk_bytes, sched)
        return got

    @contextlib.contextmanager
    def isolated_run(self) -> Iterator["ThemisScheduler"]:
        """Scope one scenario's scheduling on a shared scheduler.

        The reuse contract: memo caches (`_stage_deltas`, greedy orders,
        thresholds, lookahead candidates) are *exact* — they depend only on
        the latency model — so sharing one scheduler across many scenarios
        is free and decision-identical.  Tracker state is *not* shareable:
        it accumulates each scheduled chunk's load.  Inside this context the
        scheduler runs against a fresh :class:`DimLoadTracker`; on exit the
        caller's tracker (including an injected cross-tenant shared tracker)
        is restored untouched, so scenarios never observe each other's
        loads and the caller's state survives.  Used by
        ``simulate_requests(scheduler=...)`` and ``core.batch``.
        """
        prev = self.tracker
        self.tracker = DimLoadTracker(self.latency_model)
        try:
            yield self
        finally:
            self.tracker = prev

    def schedule_stream(
        self,
        requests: Sequence[CollectiveRequest],
        chunks_per_collective: int,
        *,
        water_filling: bool = False,
    ) -> list[list[Chunk]]:
        """Schedule a request stream in global issue order (ties broken by
        list position), returning chunk groups indexed like ``requests``.
        The single definition of the stream-scheduling contract —
        ``simulate_requests`` and ``repro.core.batch`` both call this, so
        batch results cannot drift from standalone runs."""
        order = sorted(range(len(requests)),
                       key=lambda i: (requests[i].issue_time, i))
        groups: list[list[Chunk]] = [[] for _ in requests]
        for i in order:
            groups[i] = self.schedule_request(
                requests[i], chunks_per_collective,
                water_filling=water_filling)
        return groups

    # -- public API -----------------------------------------------------------
    def schedule_collective(
        self,
        collective: str,
        collective_bytes: float,
        chunks_per_collective: int,
        *,
        water_filling: bool = False,
    ) -> list[Chunk]:
        """Returns chunks with their stage schedules (Algorithm 1).

        One-shot mode: the tracker is reset per collective (Sec. 4.4) —
        correct when collectives run back-to-back.  For overlapping
        collectives use :meth:`schedule_request`.
        """
        if collective not in ("AR", "RS", "AG"):
            raise ValueError(f"unsupported collective {collective}")
        reg = self.metrics
        with (reg.span("scheduler.schedule_pass") if reg is not None
                else contextlib.nullcontext()):
            self.tracker.reset(collective)
            chunks = self._split_and_schedule(
                collective, collective_bytes, chunks_per_collective,
                water_filling=water_filling)
        if reg is not None:
            reg.inc("scheduler.collectives_scheduled")
        return chunks

    def schedule_request(
        self,
        request: CollectiveRequest,
        chunks_per_collective: int,
        *,
        water_filling: bool = False,
    ) -> list[Chunk]:
        """Incremental path for overlapping collectives (Sec. 4.4's
        running-load view extended across requests).

        Instead of resetting the Dim Load Tracker per collective, the
        tracker's clock advances to the request's issue time (draining loads
        already served) and the request's A_K is *added* — so a bucket
        issued mid-backprop sees the residual contention of every collective
        still in flight and is steered around it.
        """
        reg = self.metrics
        with (reg.span("scheduler.schedule_pass") if reg is not None
                else contextlib.nullcontext()):
            self.tracker.advance_to(request.issue_time)
            self.tracker.begin_collective(request.collective)
            chunks = self._split_and_schedule(
                request.collective, request.size_bytes,
                chunks_per_collective, water_filling=water_filling)
        if reg is not None:
            reg.inc("scheduler.requests_scheduled")
            reg.log_decision(ScheduleDecision(
                collective=request.collective,
                tenant=request.tenant,
                policy=self.policy,
                chunk_order=(tuple(dim for _, dim in chunks[0].schedule)
                             if chunks else ()),
                rank_signature=self._last_sig,
                cache_hit=self._last_hit,
                num_chunks=len(chunks)))
        return chunks

    def replan_degraded(
        self,
        pending: Sequence[tuple[int, float, Sequence[Chunk]]],
        bw_factors: Sequence[float],
        *,
        bw_floor: float = 1e-6,
    ) -> dict[int, list[Chunk]]:
        """Graceful-degradation hook: recompute pending chunks' dim orders
        against post-fault per-dim bandwidth (the fault-injection fabric's
        re-planning half of the ROADMAP closed-loop item).

        ``pending`` lists not-yet-started request groups as
        ``(group_id, issue_time, chunks)`` in issue order; ``bw_factors``
        is the current per-dim BW multiplier vector (0 == fully out,
        clamped to ``bw_floor``).  The chunk *partition* is preserved —
        same count, sizes and stage counts per chunk — only the dim orders
        are recomputed, by this scheduler's policy, on the degraded
        topology with a fresh load tracker replayed over the pending
        groups.  Deterministic and RNG-free, so the two engines stay in
        lockstep.  Returns ``{group_id: replanned chunks}``.
        """
        from repro.faults.replan import degraded_topology

        topo = degraded_topology(
            self.latency_model.topology, bw_factors, floor=bw_floor)
        sched = ThemisScheduler(LatencyModel.for_topology(topo), self.policy)
        out: dict[int, list[Chunk]] = {}
        for group_id, issue_time, chunks in pending:
            kind = _collective_of(chunks)
            if kind is None:  # nothing scheduled in this group — skip
                continue
            sched.tracker.advance_to(issue_time)
            sched.tracker.begin_collective(kind)
            replanned = []
            for c in chunks:
                nc = Chunk(c.index, c.size_bytes)
                if c.schedule:
                    nc.schedule = sched._schedule_chunk(kind, c.size_bytes)
                replanned.append(nc)
            out[group_id] = replanned
        return out

    def _split_and_schedule(
        self,
        collective: str,
        collective_bytes: float,
        chunks_per_collective: int,
        *,
        water_filling: bool,
    ) -> list[Chunk]:
        if collective == "AG":
            # Collective size convention (paper Sec. 2.3 / footnote 7): the
            # size is the large end — the gathered result.  Chunks start at
            # the pre-gather per-NPU resident size.
            collective_bytes = collective_bytes / self.latency_model.topology.total_npus
        if water_filling and self.policy != "baseline":
            micro = split_equal(collective_bytes, max(1024, 8 * chunks_per_collective))
            for chunk in micro:
                chunk.schedule = self._schedule_chunk(collective, chunk.size_bytes)
            return coalesce_by_order(micro, chunks_per_collective)
        chunks = split_equal(collective_bytes, chunks_per_collective)
        for chunk in chunks:
            chunk.schedule = self._schedule_chunk(collective, chunk.size_bytes)
        return chunks

    # -- Algorithm 1 SCHEDULER.SCHEDULE ---------------------------------------
    def _schedule_chunk(self, collective: str, chunk_bytes: float) -> list[StageOp]:
        d = self.latency_model.topology.num_dims
        if self.policy == "baseline":
            sched = baseline_order(d, collective)
        elif self.policy == "lookahead":
            sched = self._lookahead_order(collective, chunk_bytes)
        elif self.policy == "themis_guarded":
            sched = self._pick_by_projection(
                collective, chunk_bytes,
                [self._greedy_order(collective, chunk_bytes),
                 baseline_order(d, collective)])
        else:
            sched = self._greedy_order(collective, chunk_bytes)
        self.tracker.update_loads(self._stage_deltas(chunk_bytes, sched))
        return sched

    def _below_threshold(self, loads: Sequence[float], chunk_bytes: float) -> bool:
        min_dim = min(range(len(loads)), key=loads.__getitem__)
        threshold = self._thr_cache.get((min_dim, chunk_bytes))
        if threshold is None:
            wire, _ = self.latency_model.stage_wire_bytes(
                min_dim, Phase.RS, chunk_bytes / THRESHOLD_DIVISOR
            )
            if len(self._thr_cache) >= self._CACHE_CAP:
                self._thr_cache.clear()
            threshold = self._thr_cache[(min_dim, chunk_bytes)] = (
                self.latency_model.wire_time(min_dim, wire))
        return max(loads) - min(loads) < threshold

    def _greedy_order(self, collective: str, chunk_bytes: float) -> list[StageOp]:
        """Algorithm 1 greedy order, memoized on the *load-rank signature*.

        Outside the independent-AG variant the greedy output is a pure
        function of (collective, below-threshold flag, sorted dim
        permutation) — so equal-size chunk runs reuse the schedule until the
        dim ranking flips, which is what makes water_filling's >=1024
        micro-chunk pass cheap.  ``themis_indep_ag``'s AG pass depends on
        the load *values* (not just ranks), so it is recomputed each time
        (its RS-delta lookup still hits ``_stage_deltas``).
        """
        d = self.latency_model.topology.num_dims
        loads = self.tracker.get_loads()
        below = self._below_threshold(loads, chunk_bytes)
        if (self.policy == "themis_indep_ag" and collective == "AR"
                and not below):
            rs_dims = _sorted_dims(loads, descending=False)
            rs = [(Phase.RS, k) for k in rs_dims]
            delta = self._stage_deltas(chunk_bytes, rs)
            ag_loads = [loads[k] + delta[k] for k in range(d)]
            ag = [(Phase.AG, k) for k in _sorted_dims(ag_loads, descending=True)]
            return rs + ag
        if below:
            sig = (collective, True)
        elif collective == "AG":
            sig = (collective, False, tuple(_sorted_dims(loads, descending=True)))
        else:  # RS and AR need the ascending permutation only
            sig = (collective, False, tuple(_sorted_dims(loads, descending=False)))
        got = self._greedy_cache.get(sig)
        reg = self.metrics
        if reg is not None:
            reg.inc("scheduler.greedy_cache.hit" if got is not None
                    else "scheduler.greedy_cache.miss")
            self._last_sig = sig
            self._last_hit = got is not None
        if got is None:
            if below:
                sched = baseline_order(d, collective)
            elif collective == "RS":
                sched = [(Phase.RS, k) for k in sig[2]]
            elif collective == "AG":
                sched = [(Phase.AG, k) for k in sig[2]]
            else:  # AR: AG = reverse(RS) (Alg. 1 line 8)
                sched = ([(Phase.RS, k) for k in sig[2]]
                         + [(Phase.AG, k) for k in reversed(sig[2])])
            if len(self._greedy_cache) >= self._CACHE_CAP:
                self._greedy_cache.clear()
            got = self._greedy_cache[sig] = tuple(sched)
        return list(got)

    def _pick_by_projection(
        self, collective: str, chunk_bytes: float,
        candidates: list[list[StageOp]],
    ) -> list[StageOp]:
        loads = self.tracker.get_loads()
        best = None
        for cand in candidates:
            delta = self._stage_deltas(chunk_bytes, cand)
            proj = [a + b for a, b in zip(loads, delta)]
            key = (max(proj), sum(proj))
            if best is None or key < best[0]:
                best = (key, cand)
        return best[1]

    def _candidate_orders(self, collective: str) -> list[tuple[StageOp, ...]]:
        """All D! candidate schedules of ``collective`` (memoized)."""
        got = self._cand_cache.get(collective)
        if got is None:
            d = self.latency_model.topology.num_dims
            cands: list[tuple[StageOp, ...]] = []
            for perm in itertools.permutations(range(d)):
                if collective == "RS":
                    cand = [(Phase.RS, k) for k in perm]
                elif collective == "AG":
                    cand = [(Phase.AG, k) for k in perm]
                else:
                    cand = [(Phase.RS, k) for k in perm] + [
                        (Phase.AG, k) for k in reversed(perm)
                    ]
                cands.append(tuple(cand))
            got = self._cand_cache[collective] = cands
        return got

    def _lookahead_order(self, collective: str, chunk_bytes: float) -> list[StageOp]:
        """D! enumeration with memoized per-candidate load deltas: after the
        first chunk of a size, each candidate evaluation is a vector add +
        max — the winner itself depends on the current load values, so it is
        re-picked per chunk (rank-only memoization would change decisions)."""
        loads = self.tracker.get_loads()
        best: tuple[tuple[float, float], tuple[StageOp, ...]] | None = None
        for cand in self._candidate_orders(collective):
            delta = self._stage_deltas(chunk_bytes, cand)
            proj = [a + b for a, b in zip(loads, delta)]
            key = (max(proj), sum(proj))
            if best is None or key < best[0]:
                best = (key, cand)
        assert best is not None
        return list(best[1])


def schedule_collective(
    topology: Topology,
    collective: str,
    collective_bytes: float,
    chunks_per_collective: int = 64,
    policy: str = "themis",
    *,
    water_filling: bool = False,
) -> list[Chunk]:
    """Convenience wrapper: build model+scheduler and schedule one collective."""
    sched = ThemisScheduler(LatencyModel.for_topology(topology), policy)
    return sched.schedule_collective(
        collective,
        collective_bytes,
        chunks_per_collective,
        water_filling=water_filling,
    )
