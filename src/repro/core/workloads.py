"""End-to-end training-iteration models for the paper's four workloads
(Sec. 5.2 / Fig. 12): ResNet-152, GNMT, DLRM, Transformer-1T.

Each workload is reduced to the quantities ASTRA-SIM consumes:
  * compute time per iteration from roofline FP16 on an A100-class NPU
    (312 TFLOP/s, paper Sec. 5.1),
  * the stream of *exposed* communication operations: per-tensor/bucket
    data-parallel gradient All-Reduces at the end of back-propagation, and
    per-layer model-parallel collectives on the critical path (T-1T).

Parallelization matches Sec. 5.2: ResNet-152/GNMT pure DP; DLRM DP for MLPs
with model-parallel embeddings whose All-to-All overlaps with compute (not
exposed); Transformer-1T Megatron-style MP over the first network dims up
to 128 NPUs + ZeRO-2 DP over the remaining dims (DP collectives therefore
see a single network dimension, where baseline == Themis, as the paper
notes).

Structural parameters (layer shapes, sequence lengths) are documented
assumptions — the paper does not publish them — chosen to land in the
communication-bound regime the paper targets ("high ratio of communication
to compute").
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.latency_model import LatencyModel
from repro.core.requests import CollectiveRequest
from repro.core.simulator import SimResult, simulate_requests
from repro.topology import NetworkDim, Topology

A100_FP16_FLOPS = 312e12  # roofline FP16 (paper Sec. 5.1)
FP16 = 2  # bytes


# --------------------------------------------------------------------------
# Workload definitions
# --------------------------------------------------------------------------
@dataclass
class CommOp:
    """One exposed collective in the iteration timeline."""

    collective: str            # 'AR' | 'RS' | 'AG'
    size_bytes: float
    count: int = 1             # how many times per iteration (serialized)
    scope: str = "dp"          # 'dp' -> DP dims, 'mp' -> MP dims
    batched: bool = False      # True: all `count` issued together (one sync)


@dataclass
class Workload:
    name: str
    compute_fwd_s: float
    compute_bwd_s: float
    comm_ops: list[CommOp] = field(default_factory=list)
    mp_npus: int = 1           # model-parallel group size (leading dims)
    # Per-bucket gradient bytes, input->output order, for the overlap engine
    # (None: buckets are equal splits of the fused DP collectives).
    dp_buckets: list[float] | None = None

    @property
    def compute_s(self) -> float:
        return self.compute_fwd_s + self.compute_bwd_s


def resnet152_param_buckets() -> list[float]:
    """Per-bucket fp16 gradient bytes for ResNet-152 (bottleneck v1.5).

    Exact conv/fc tensor sizes (~60.2M params) bucketed per stage-block —
    gradient AR is issued per block as back-propagation retires it.
    """
    blocks = [(3, 64), (8, 128), (36, 256), (3, 512)]
    buckets: list[float] = []
    in_ch = 64
    params_conv1 = 7 * 7 * 3 * 64
    buckets.append(params_conv1 * FP16)
    for n_blocks, planes in blocks:
        out_ch = planes * 4
        for b in range(n_blocks):
            p = in_ch * planes            # 1x1 reduce
            p += 3 * 3 * planes * planes  # 3x3
            p += planes * out_ch          # 1x1 expand
            if b == 0:
                p += in_ch * out_ch       # downsample projection
            p += 2 * (planes * 2 + out_ch)  # BN scale/shift (approx)
            buckets.append(p * FP16)
            in_ch = out_ch
    buckets.append((2048 * 1000 + 1000) * FP16)  # fc
    return buckets


def make_resnet152(batch_per_npu: int = 32) -> Workload:
    """ResNet-152 pure-DP: one fused gradient AR at the end of bwd
    (Sec. 6.2: 'NPUs communicate their locally computed weight gradients
    through All-Reduce')."""
    buckets = resnet152_param_buckets()
    grad_bytes = sum(buckets)                    # ~120 MB fp16
    flops_fwd = 11.58e9 * batch_per_npu          # 11.58 GFLOPs/img fwd
    return Workload(
        name="ResNet-152",
        compute_fwd_s=flops_fwd / A100_FP16_FLOPS,
        compute_bwd_s=2 * flops_fwd / A100_FP16_FLOPS,
        comm_ops=[CommOp("AR", grad_bytes, count=1, scope="dp", batched=True)],
        dp_buckets=buckets,
    )


def make_gnmt(batch_per_npu: int = 128, seq_len: int = 20) -> Workload:
    """GNMT: 8-layer enc + 8-layer dec LSTM (1024 units), 32k vocab."""
    h, vocab = 1024, 32 * 1024
    lstm_layer = 4 * (h * h + h * h + 2 * h)      # i,f,g,o gates (x & h)
    params = 16 * lstm_layer + 3 * vocab * h + 2 * h * h  # ~235M
    tokens = batch_per_npu * seq_len
    flops_fwd = 2 * params * tokens
    return Workload(
        name="GNMT",
        compute_fwd_s=flops_fwd / A100_FP16_FLOPS,
        compute_bwd_s=2 * flops_fwd / A100_FP16_FLOPS,
        comm_ops=[CommOp("AR", params * FP16, count=1, scope="dp", batched=True)],
    )


def make_dlrm(batch_per_npu: int = 512) -> Workload:
    """DLRM (production-scale MLPs, per [53]/[49]-style configs).

    Embedding tables are model-parallel; their All-to-All overlaps with
    bottom-MLP compute and is not exposed (paper Sec. 6.2).  Exposed comm =
    one fused DP gradient AR of the MLP tensors.  MLP widths are sized to a
    production-scale ~50M dense params so the collective (~100 MB fp16)
    falls in the paper's stated workload-collective range (Sec. 6.1:
    100 MB - 1 GB 'covers our target workloads collectives').
    """
    bottom = [(2048, 4096), (4096, 2048), (2048, 1024)]
    top = [(4096, 4096), (4096, 2048), (2048, 1024), (1024, 512), (512, 1)]
    tensors = [(i * o + o) * FP16 for i, o in bottom + top]
    params = sum(t // FP16 for t in tensors)  # ~50M
    flops_fwd = 2 * params * batch_per_npu
    return Workload(
        name="DLRM",
        compute_fwd_s=flops_fwd / A100_FP16_FLOPS,
        compute_bwd_s=2 * flops_fwd / A100_FP16_FLOPS,
        comm_ops=[CommOp("AR", sum(tensors), count=1, scope="dp", batched=True)],
    )


def make_transformer_1t(
    batch_per_replica: int = 16, seq: int = 2048, total_npus: int = 1024
) -> Workload:
    """Transformer-1T: h=25600, L=128 (12*h^2*L ~= 1.007T params).

    Megatron MP over the first dims up to 128 NPUs; ZeRO-2 DP over the rest.
    Exposed MP comm: one activation AR per MP region x 2 regions (attn/MLP)
    x fwd+bwd per layer (4 AR/layer).  Exposed DP comm (ZeRO-2): grad RS +
    param AG of the per-MP-shard parameters on the last dim only.
    """
    h, layers = 25600, 128
    mp = 128
    dp = total_npus // mp
    params = 12 * h * h * layers
    act_ar = batch_per_replica * seq * h * FP16
    shard_bytes = params / mp * FP16
    tokens_global = batch_per_replica * dp * seq
    flops_total = 6 * params * tokens_global
    compute_per_npu = flops_total / total_npus / A100_FP16_FLOPS
    return Workload(
        name="Transformer-1T",
        compute_fwd_s=compute_per_npu / 3,
        compute_bwd_s=2 * compute_per_npu / 3,
        comm_ops=[
            CommOp("AR", act_ar, count=4 * layers, scope="mp"),
            CommOp("RS", shard_bytes, count=1, scope="dp", batched=True),
            CommOp("AG", shard_bytes, count=1, scope="dp", batched=True),
        ],
        mp_npus=mp,
    )


ALL_WORKLOADS = {
    "resnet152": make_resnet152,
    "gnmt": make_gnmt,
    "dlrm": make_dlrm,
    "transformer_1t": make_transformer_1t,
}


# --------------------------------------------------------------------------
# Iteration-time engine
# --------------------------------------------------------------------------
def split_topology(topology: Topology, mp_npus: int) -> tuple[Topology, Topology]:
    """Split dims into (MP sub-topology, DP sub-topology) with the MP group
    covering the first ``mp_npus`` NPUs (paper Sec. 5.2).

    If the MP boundary falls inside a dimension, that dimension is split
    into two logical sub-dimensions sharing the same fabric (e.g. 2D 16x64
    with MP=128 -> MP over 16x8, DP over the remaining 8-way groups).
    """
    if mp_npus <= 1:
        return Topology(topology.name + "-mp", ()), topology
    mp_dims: list[NetworkDim] = []
    dp_dims: list[NetworkDim] = []
    prod = 1
    for d in topology.dims:
        if prod >= mp_npus:
            dp_dims.append(d)
            continue
        if prod * d.npus <= mp_npus:
            mp_dims.append(d)
            prod *= d.npus
        else:
            inner = mp_npus // prod  # boundary dim splits into inner x outer
            outer = d.npus // inner
            if inner > 1:
                mp_dims.append(NetworkDim(inner, d.topo, d.link_gbps,
                                          d.links_per_npu, d.step_latency_s,
                                          d.straggler_sigma))
            if outer > 1:
                dp_dims.append(NetworkDim(outer, d.topo, d.link_gbps,
                                          d.links_per_npu, d.step_latency_s,
                                          d.straggler_sigma))
            prod *= d.npus
    return (
        Topology(topology.name + "-mp", tuple(mp_dims)),
        Topology(topology.name + "-dp", tuple(dp_dims)),
    )


@dataclass
class IterationResult:
    compute_s: float
    exposed_dp_s: float
    exposed_mp_s: float

    @property
    def total_s(self) -> float:
        return self.compute_s + self.exposed_dp_s + self.exposed_mp_s


def _sim_request_stream(
    topology: Topology,
    requests: list[CollectiveRequest],
    policy: str,
    chunks_per_collective: int,
    intra: str,
) -> SimResult | None:
    """Schedule + simulate an arrival-time-aware request stream (one
    incremental scheduler across requests: Sec. 4.4's running-load view)."""
    if topology.num_dims == 0 or not requests:
        return None
    res, _ = simulate_requests(
        topology, requests, policy=policy,
        chunks_per_collective=chunks_per_collective, intra=intra)
    return res


def _sim_stream(
    topology: Topology,
    ops: list[CommOp],
    policy: str,
    chunks_per_collective: int,
    intra: str,
) -> float:
    """Simulate a batch of collectives issued together (one sync point)."""
    reqs = [CollectiveRequest(op.collective, op.size_bytes) for op in ops]
    res = _sim_request_stream(topology, reqs, policy, chunks_per_collective, intra)
    return 0.0 if res is None else res.makespan


def dp_bucket_requests(
    workload: Workload, n_buckets: int, bwd_s: float | None = None
) -> list[CollectiveRequest]:
    """Backprop gradient-bucket stream for the overlap engine.

    Buckets retire as back-propagation sweeps output->input, so bucket *i*
    (of *n*, in retirement order) issues at ``bwd_s * (i+1)/n`` with t=0 the
    start of the backward pass.  Gradient collectives (AR/RS) are bucketed;
    ZeRO-style param All-Gathers depend on the optimizer step and issue at
    the end of the backward pass.  Uses the workload's published per-tensor
    bucket sizes when available (``dp_buckets``), else equal splits.
    """
    if bwd_s is None:
        bwd_s = workload.compute_bwd_s
    reqs: list[CollectiveRequest] = []
    for op in workload.comm_ops:
        if op.scope != "dp":
            continue
        if op.collective == "AG":
            for _ in range(op.count):
                reqs.append(CollectiveRequest(
                    "AG", op.size_bytes, issue_time=bwd_s, stream="dp-ag"))
            continue
        for _ in range(op.count):
            if workload.dp_buckets and op.batched:
                # retirement order = reversed layer order, rescaled to the
                # op's size (dp_buckets describe the full gradient set)
                total = sum(workload.dp_buckets)
                sizes = [b / total * op.size_bytes
                         for b in reversed(workload.dp_buckets)]
                sizes = _coalesce_buckets(sizes, n_buckets)
            else:
                sizes = [op.size_bytes / n_buckets] * n_buckets
            n = len(sizes)
            for i, b in enumerate(sizes):
                reqs.append(CollectiveRequest(
                    op.collective, b, issue_time=bwd_s * (i + 1) / n,
                    stream="bwd-buckets"))
    return reqs


def _coalesce_buckets(sizes: list[float], n_buckets: int) -> list[float]:
    """Merge adjacent per-tensor sizes into exactly ``n_buckets`` buckets,
    preserving retirement order (mirrors DDP gradient bucketing).

    Mass-preserving with a stable bucket count: the per-bucket target is
    recomputed from the *remaining* mass (so one huge tensor overshooting an
    early bucket does not starve the later ones), a bucket closes on the
    boundary that lands closest to its target, and a bucket is force-closed
    when the tensors left are just enough to give every remaining bucket
    one — so skewed size distributions can neither drop a trailing
    zero-mass bucket nor collapse the count below ``n_buckets``.
    """
    if n_buckets < 1:
        raise ValueError("n_buckets must be >= 1")
    if len(sizes) <= n_buckets:
        return list(sizes)
    out: list[float] = []
    acc = 0.0
    n_acc = 0
    mass_left = sum(sizes)
    target = mass_left / n_buckets
    for i, s in enumerate(sizes):
        tensors_left = len(sizes) - i          # including s
        buckets_left = n_buckets - len(out)    # including the open bucket
        close = n_acc > 0 and buckets_left > 1 and (
            tensors_left <= buckets_left  # must leave >= 1 tensor per bucket
            or abs(acc - target) <= abs(acc + s - target)
        )
        if close:
            out.append(acc)
            mass_left -= acc
            acc = 0.0
            n_acc = 0
            target = mass_left / (n_buckets - len(out))
        acc += s
        n_acc += 1
    out.append(acc)
    return out


def calibrate_compute(
    workload: Workload,
    topologies: list[Topology],
    target_ideal_speedup: float,
    *,
    chunks_per_collective: int = 64,
) -> float:
    """Solve for the compute time that matches the paper's *Ideal* speedup.

    The paper does not publish per-workload compute times or bucket layout;
    collective *sizes* follow from the published model structures, but the
    compute:comm mix is the one free scalar.  We bisect the compute time so
    that mean_topologies[(C + comm_baseline)/(C + comm_ideal)] equals the
    paper's reported Ideal end-to-end speedup (Sec. 6.2: 1.54 / 1.32 / 1.33 /
    1.26).  Themis speedups then remain genuine predictions to validate
    against the paper's 1.49 / 1.30 / 1.30 / 1.25.  Returns calibrated C and
    mutates the workload's fwd/bwd split (1:2) in place.
    """
    pairs = []
    for topo in topologies:
        b = iteration_time(workload, topo, "baseline", intra="FIFO",
                           chunks_per_collective=chunks_per_collective)
        i = iteration_time(workload, topo, "ideal")
        pairs.append((b.exposed_dp_s + b.exposed_mp_s, i.exposed_dp_s + i.exposed_mp_s))

    def ideal_avg(c: float) -> float:
        return sum((c + cb) / (c + ci) for cb, ci in pairs) / len(pairs)

    lo, hi = 0.0, max(cb for cb, _ in pairs) * 100 + 1.0
    if ideal_avg(lo) < target_ideal_speedup:  # even zero compute can't reach
        c = lo
    else:
        for _ in range(80):
            mid = 0.5 * (lo + hi)
            if ideal_avg(mid) > target_ideal_speedup:
                lo = mid
            else:
                hi = mid
        c = 0.5 * (lo + hi)
    workload.compute_fwd_s = c / 3.0
    workload.compute_bwd_s = 2.0 * c / 3.0
    return c


def iteration_time(
    workload: Workload,
    topology: Topology,
    policy: str = "themis",
    *,
    chunks_per_collective: int = 64,
    intra: str = "SCF",
    overlap_buckets: int = 0,
) -> IterationResult:
    """Total iteration latency = compute + exposed comm (paper Sec. 6.2).

    ``overlap_buckets > 0`` enables the arrival-time-aware engine: DP
    gradient collectives split into that many buckets issued progressively
    during the backward pass (``dp_bucket_requests``), overlap with compute,
    and contend with each other on shared dims; the exposed DP time is then
    whatever communication drains *after* back-propagation finishes.  The
    default (0) keeps the paper's one-sync-point model: everything issues
    together at the end of the backward pass.
    """
    mp_topo, dp_topo = split_topology(topology, workload.mp_npus)
    if policy == "ideal":
        dp_lm = LatencyModel(dp_topo) if dp_topo.num_dims else None
        mp_lm = LatencyModel(mp_topo) if mp_topo.num_dims else None
        exposed_dp = sum(
            dp_lm.ideal_time(o.collective, o.size_bytes) * o.count
            for o in workload.comm_ops
            if o.scope == "dp" and dp_lm
        )
        exposed_mp = sum(
            mp_lm.ideal_time(o.collective, o.size_bytes) * o.count
            for o in workload.comm_ops
            if o.scope == "mp" and mp_lm
        )
        return IterationResult(workload.compute_s, exposed_dp, exposed_mp)

    if overlap_buckets > 0:
        # Bucketed backprop stream: buckets issue as bwd retires them and
        # only the tail that drains after bwd ends is exposed.
        reqs = dp_bucket_requests(workload, overlap_buckets)
        res = _sim_request_stream(dp_topo, reqs, policy,
                                  chunks_per_collective, intra)
        bwd_end = workload.compute_bwd_s
        finish = max(res.group_finish) if res else bwd_end
        exposed_dp = max(0.0, finish - bwd_end)
    else:
        # DP collectives: all buckets ready at end of bwd -> one batched
        # stream at a single sync point.
        dp_ops = [o for o in workload.comm_ops if o.scope == "dp"]
        dp_stream: list[CommOp] = []
        for o in dp_ops:
            dp_stream.extend([CommOp(o.collective, o.size_bytes)] * o.count)
        exposed_dp = _sim_stream(dp_topo, dp_stream, policy,
                                 chunks_per_collective, intra)

    # MP collectives: on the layer critical path -> serialized, simulate one
    # instance and multiply by count.
    exposed_mp = 0.0
    for o in workload.comm_ops:
        if o.scope != "mp":
            continue
        one = _sim_stream(mp_topo, [CommOp(o.collective, o.size_bytes)], policy,
                          chunks_per_collective, intra)
        exposed_mp += one * o.count
    return IterationResult(workload.compute_s, exposed_dp, exposed_mp)
