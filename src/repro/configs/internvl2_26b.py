"""internvl2-26b [arXiv:2404.16821; hf] — InternViT + InternLM2 backbone.

Backbone: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
The InternViT vision frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings (batch, num_patches, d_model) that are
prepended to the text sequence.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    rope_theta=1e6,
    num_patches=256,
)

REDUCED = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256, num_patches=16,
)

register(CONFIG, REDUCED)
