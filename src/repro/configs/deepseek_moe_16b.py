"""deepseek-moe-16b [arXiv:2401.06066; hf].

28L d_model=2048 16H (GQA kv=16 == MHA) moe_d_ff=1408 vocab=102400,
2 shared + 64 routed experts top-6 (fine-grained expert segmentation).
First layer uses a dense FFN (d_ff=10944) per the released model; we model
all layers MoE + shared experts, matching the dominant structure.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    rope_theta=1e4,
    num_experts=64,
    experts_per_token=6,
    num_shared_experts=2,
    moe_d_ff=1408,
)

REDUCED = CONFIG.replace(
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=96,
    moe_d_ff=96,
    vocab_size=256,
    num_experts=8,
    experts_per_token=2,
    num_shared_experts=1,
)

register(CONFIG, REDUCED)
