"""xlstm-1.3b [arXiv:2405.04517] — sLSTM + mLSTM blocks.

48 blocks d_model=2048 4H vocab=50304, d_ff=0 (blocks carry their own
up-projections; proj_factor=2).  xLSTM[7:1] ratio: one sLSTM block per
8 blocks (6 sLSTM + 42 mLSTM).  Constant-size recurrent state ->
runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,
    proj_factor=2.0,
)

REDUCED = CONFIG.replace(
    num_layers=16,                 # two periods of (7 mLSTM + 1 sLSTM)
    d_model=64, num_heads=4, num_kv_heads=4, vocab_size=256,
)

register(CONFIG, REDUCED)
