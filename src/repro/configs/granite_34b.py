"""granite-34b [arXiv:2405.04324; hf] — llama-arch code model.

88L d_model=6144 48H (MQA: kv=1) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    rope_theta=1e4,
    gated_mlp=False,      # GPT-BigCode-style plain MLP (keeps params ~34B)
)

REDUCED = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=1, d_ff=128,
    vocab_size=256,
)

register(CONFIG, REDUCED)
