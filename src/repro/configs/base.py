"""Config system: model architecture, input shapes, parallelism.

Every assigned architecture provides a ``ModelConfig`` (exact published
dims) plus a ``reduced()`` variant for CPU smoke tests.  Input shapes are
the four assigned cells (train_4k / prefill_32k / decode_32k / long_500k)
with per-arch applicability.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal

Family = Literal["dense", "moe", "vlm", "audio", "hybrid", "ssm"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // num_heads
    qkv_bias: bool = False
    gated_mlp: bool = True             # SwiGLU; False -> plain GELU MLP
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # hybrid (recurrentgemma): repeating block pattern + tail
    block_pattern: tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    d_rnn: int = 0
    conv_width: int = 4
    local_window: int = 0                 # sliding-window size for local attn

    # ssm (xlstm)
    slstm_every: int = 0                  # 1 sLSTM per this many blocks
    proj_factor: float = 2.0              # mLSTM up-projection factor

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    num_frames: int = 1500                # stub frontend: precomputed frames

    # vlm
    num_patches: int = 0                  # stub frontend: precomputed patches

    # numerics / runtime
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    kv_quant: bool = False                # int8 KV cache (+bf16 scales)
    remat: bool = True
    remat_policy: str = "full"            # "full" (save nothing) | "dots"
    attention_impl: str = "reference"     # "reference" | "pallas"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state is O(1)/O(window) in context length."""
        return self.family in ("hybrid", "ssm")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def applicable_shapes(cfg: ModelConfig) -> list[ShapeConfig]:
    """The assigned shape cells this architecture actually runs.

    ``long_500k`` requires sub-quadratic attention (DESIGN.md
    §Arch-applicability); it is skipped for pure full-attention archs.
    """
    shapes = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        shapes.append(LONG_500K)
    return shapes


@dataclass(frozen=True)
class ParallelConfig:
    """Mesh axes and policy switches for the distributed runtime."""

    data: int = 1
    model: int = 1
    pods: int = 1
    fsdp: bool = False                 # shard params over data axis too
    seq_sharding: bool = False         # sequence parallelism between blocks
    zero: int = 1                      # ZeRO stage for optimizer states (0-2)
    dp_sync: str = "gspmd"             # "gspmd" | "hier_baseline" | "themis"
    chunks_per_collective: int = 16    # Themis chunking of the grad buffer
    compression: str = "none"          # "none" | "int8"
    remat_policy: str = "dots"         # "none" | "dots" | "full"

    @property
    def mesh_shape(self) -> tuple[int, ...]:
        return (self.pods, self.data, self.model) if self.pods > 1 else (self.data, self.model)

    @property
    def mesh_axes(self) -> tuple[str, ...]:
        return ("pod", "data", "model") if self.pods > 1 else ("data", "model")


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    seed: int = 0
    microbatch: int = 0                # 0 = no gradient accumulation
    checkpoint_every: int = 50
    checkpoint_dir: str = "checkpoints"
    keep_checkpoints: int = 3


# -- registry ---------------------------------------------------------------
_REGISTRY: dict[str, "ArchSpec"] = {}


@dataclass(frozen=True)
class ArchSpec:
    config: ModelConfig
    reduced: ModelConfig


def register(config: ModelConfig, reduced: ModelConfig) -> ArchSpec:
    spec = ArchSpec(config, reduced)
    _REGISTRY[config.name] = spec
    return spec


def get_arch(name: str, *, reduced: bool = False) -> ModelConfig:
    import repro.configs  # noqa: F401  (trigger registration)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    spec = _REGISTRY[name]
    return spec.reduced if reduced else spec.config


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)
