"""Architecture registry — importing this package registers all configs."""
from repro.configs import (  # noqa: F401
    deepseek_moe_16b,
    granite_34b,
    internvl2_26b,
    llama3_8b,
    qwen2_5_14b,
    qwen2_5_3b,
    qwen3_moe_235b_a22b,
    recurrentgemma_2b,
    whisper_medium,
    xlstm_1_3b,
)
from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    ModelConfig,
    ParallelConfig,
    ShapeConfig,
    TrainConfig,
    applicable_shapes,
    get_arch,
    list_archs,
)
