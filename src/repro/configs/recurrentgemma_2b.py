"""recurrentgemma-2b [arXiv:2402.19427; hf] — Griffin: RG-LRU + local attn.

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000.  Block pattern:
two RG-LRU residual blocks then one local-attention block (1:2 attn:rec),
sliding window 2048.  Sub-quadratic -> runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    rope_theta=1e4,
    block_pattern=("rec", "rec", "attn"),
    d_rnn=2560,
    conv_width=4,
    local_window=2048,
    tie_embeddings=True,
)

REDUCED = CONFIG.replace(
    num_layers=5,                      # one full period + tail (rec, rec)
    d_model=64, num_heads=4, num_kv_heads=1, head_dim=16, d_ff=128,
    vocab_size=256, d_rnn=64, local_window=32,
)

register(CONFIG, REDUCED)
