"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-30B-A3B family; hf].

94L d_model=4096 64H (GQA kv=4) moe_d_ff=1536 vocab=151936, MoE 128 experts
top-8.  235B total / ~22B active params.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,                 # dense fallback width (unused; all-MoE layers)
    vocab_size=151936,
    rope_theta=1e6,
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=1536,
    qkv_bias=False,
    param_dtype="bfloat16",   # fp32 params+opt alone exceed v5e HBM at 256 chips
)

REDUCED = CONFIG.replace(
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    moe_d_ff=96,
    vocab_size=256,
    num_experts=8,
    experts_per_token=2,
)

register(CONFIG, REDUCED)
