"""whisper-medium [arXiv:2212.04356] — encoder-decoder, conv frontend stub.

24L enc + 24L dec, d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=51865.
The conv1d/mel frontend is a STUB: ``input_specs()`` provides precomputed
frame embeddings (batch, 1500, d_model) as the encoder input.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,                 # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    is_encoder_decoder=True,
    num_frames=1500,
    rope_theta=1e4,                # (whisper uses learned abs pos; we use RoPE-free sinusoidal)
)

REDUCED = CONFIG.replace(
    num_layers=2, encoder_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256, num_frames=30,
)

register(CONFIG, REDUCED)
