from repro.data.pipeline import Prefetcher, SyntheticLM  # noqa: F401
