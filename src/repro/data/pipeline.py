"""Synthetic, deterministic, resumable token pipeline with host prefetch.

Production shape: each host materializes only its slice of the global batch
(``jax.make_array_from_process_local_data`` in multi-process deployments);
on a single process we device_put with the global NamedSharding.  The
stream is seeded and step-indexed, so checkpoint resume is exact: the
manifest records (seed, next_step).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.sharding.specs import batch_pspec


@dataclass
class SyntheticLM:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.integers(
            0, self.vocab_size, (self.global_batch, self.seq_len + 1),
            dtype=np.int32,
        )
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Prefetcher:
    """Background-thread prefetch + device transfer (straggler hiding)."""

    def __init__(self, dataset: SyntheticLM, mesh: Mesh, start_step: int = 0,
                 depth: int = 2, extras: dict | None = None):
        self.dataset = dataset
        self.mesh = mesh
        self.extras = extras or {}
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _shard(self, batch: dict) -> dict:
        out = {}
        for k, v in {**batch, **self.extras}.items():
            sh = NamedSharding(
                self.mesh, batch_pspec(v.shape, self.mesh, v.shape[0])
            )
            out[k] = jax.device_put(v, sh)
        return out

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._shard(self.dataset.batch_at(step))),
                            timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
