"""Counters, span timers, and scheduler decision logs.

A :class:`MetricsRegistry` is the sink ``ThemisScheduler`` (and the batch
runner) report into: monotonically increasing counters (memo-cache
hits/misses, schedule passes), wall-clock span timers around expensive
phases (schedule passes, vectorized task builds), and a bounded log of
per-request :class:`ScheduleDecision` records (chosen chunk order +
load-rank signature) — the "why did the scheduler pick this order"
answer the ISSUE asks for.

Instrumented code holds a registry that may be ``None`` (the default) and
guards every call site on it, mirroring the tracer's zero-overhead
contract.  For CLI surfacing (``benchmarks/run.py --trace``) there is a
process-global registry — :func:`enable_global` / :func:`current_registry`
— so benchmarks that construct schedulers internally get instrumented
without threading a parameter through every entry point.

Wall-clock timing lives here (and only here): the engine/scheduler lint
forbids ``perf_counter`` in `repro.core`/`repro.tenancy`, so spans are
measured behind this module boundary.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any


@dataclass(frozen=True)
class ScheduleDecision:
    """One scheduler choice: which chunk order a request got and why."""

    collective: str          # "AR" / "RS" / "AG"
    tenant: str
    policy: str
    chunk_order: tuple[int, ...]   # dim visit order of the first chunk
    rank_signature: tuple    # load-rank memo key the order was derived from
    cache_hit: bool          # served from the greedy-order memo?
    num_chunks: int


@dataclass
class MetricsRegistry:
    """Counters + span timers + a bounded decision log.

    ``max_decisions`` bounds the decision log (FIFO eviction) so long
    sweeps can leave a registry enabled without unbounded growth.
    """

    max_decisions: int = 10_000
    counters: dict[str, int] = field(default_factory=dict)
    spans: dict[str, list[float]] = field(default_factory=dict)
    decisions: list[ScheduleDecision] = field(default_factory=list)

    # -- counters ------------------------------------------------------------
    def inc(self, name: str, delta: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + delta

    # -- span timers ---------------------------------------------------------
    @contextmanager
    def span(self, name: str):
        """Time a with-block on the wall clock; durations accumulate per
        span name (seconds)."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.spans.setdefault(name, []).append(
                time.perf_counter() - t0)

    # -- decision log --------------------------------------------------------
    def log_decision(self, decision: ScheduleDecision) -> None:
        self.decisions.append(decision)
        if len(self.decisions) > self.max_decisions:
            del self.decisions[: len(self.decisions) - self.max_decisions]

    # -- reporting -----------------------------------------------------------
    def snapshot(self) -> dict[str, Any]:
        """JSON-friendly dump: counters, span aggregates, decision count."""
        return {
            "counters": dict(sorted(self.counters.items())),
            "spans": {
                name: {
                    "count": len(times),
                    "total_s": sum(times),
                    "max_s": max(times),
                }
                for name, times in sorted(self.spans.items())
            },
            "decisions": len(self.decisions),
        }

    def report_rows(self) -> list[str]:
        """Human-readable summary lines for CLI output."""
        rows = []
        for name, v in sorted(self.counters.items()):
            rows.append(f"  counter  {name:<40s} {v}")
        for name, times in sorted(self.spans.items()):
            rows.append(
                f"  span     {name:<40s} n={len(times)} "
                f"total={sum(times) * 1e3:.2f}ms "
                f"max={max(times) * 1e3:.3f}ms")
        rows.append(f"  decisions logged: {len(self.decisions)}")
        return rows


# -- process-global registry (CLI surfacing) ---------------------------------
_GLOBAL: MetricsRegistry | None = None


def enable_global(max_decisions: int = 10_000) -> MetricsRegistry:
    """Install (and return) a process-global registry.  Schedulers built
    afterwards with ``metrics=None`` pick it up."""
    global _GLOBAL
    _GLOBAL = MetricsRegistry(max_decisions=max_decisions)
    return _GLOBAL


def disable_global() -> None:
    global _GLOBAL
    _GLOBAL = None


def current_registry() -> MetricsRegistry | None:
    """The process-global registry, or ``None`` when metrics are off."""
    return _GLOBAL
