"""Observability: event tracing, BW timelines, and scheduler metrics.

Off by default and zero-overhead when disabled — instrumented code pays
one ``if trc is not None`` / ``if reg is not None`` branch per event and
nothing else, and an armed tracer never perturbs results (hooks are
append-only; they consume no tie-break sequence numbers and no jitter
RNG draws, so traced runs are bit-identical to untraced ones — asserted
by ``benchmarks/obs_study.py`` and ``tests/test_engine_equiv.py``).

    from repro.obs import Tracer, BwTimeline
    trc = Tracer()
    res = simulate(topo, groups, tracer=trc)
    trc.save("run.trace.json")            # open in https://ui.perfetto.dev
    tl = BwTimeline.from_tracer(trc)
    shares = tl.per_dim_shares(window=0.05)
"""
from repro.obs.metrics import (
    MetricsRegistry,
    ScheduleDecision,
    current_registry,
    disable_global,
    enable_global,
)
from repro.obs.timeline import BwTimeline
from repro.obs.tracer import Tracer, parse_chrome_trace

__all__ = [
    "Tracer",
    "parse_chrome_trace",
    "BwTimeline",
    "MetricsRegistry",
    "ScheduleDecision",
    "enable_global",
    "disable_global",
    "current_registry",
]
