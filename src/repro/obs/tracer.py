"""Fabric flight recorder — event capture for the simulation engines.

A :class:`Tracer` is handed to ``simulate(..., tracer=...)`` and records
the time-resolved story a :class:`~repro.core.simulator.SimResult`'s
scalar aggregates flatten away: every chunk-service start/finish/preempt,
arbiter grant and requeue, ready-queue arrival, dependency-edge
resolution, and group release, plus the run's final bookkeeping
(``finalize``).  Fig. 9's per-dim activity and Fig. 11's utilization are
*derived views* of this record (:class:`repro.obs.timeline.BwTimeline`),
as is the Chrome ``trace_event`` export (:meth:`Tracer.to_chrome_trace`)
viewable in Perfetto / ``chrome://tracing``.

Design constraints (the engines' contract):

  * **zero overhead when absent** — every engine call site is guarded by
    an ``if trc is not None`` branch (enforced by ``tools/lint_engine.py``);
    the disabled path costs one branch per event, same pattern as
    ``check_invariants``;
  * **bit-identical results when armed** — hooks only append to Python
    lists; they never consume the tie-break counter or the jitter RNG, so
    a traced run's ``SimResult`` equals the untraced run field-for-field
    (gated by ``benchmarks/obs_study.py`` and ``tests/test_engine_equiv``);
  * **no simulator imports** — the tracer treats op ids and results as
    duck-typed data, so ``repro.core`` may import ``repro.obs`` without a
    cycle.

Hot hooks append plain lists/tuples; all derivation (per-dim wire sums,
Chrome JSON, timelines) happens after the run.  One ``Tracer`` records
exactly one run: ``begin`` raises on reuse.
"""
from __future__ import annotations

import json
from array import array
from typing import Any

# Per-service record layout (mutable list — preemption amends in place):
#   [start, end, ops, groups, tenant, wire_bytes]
SVC_START, SVC_END, SVC_OPS, SVC_GROUPS, SVC_TENANT, SVC_WIRE = range(6)


class Tracer:
    """Records one simulation run's event stream (see module docstring).

    Attributes populated during the run (all simulated-time floats):

    ``services``
        Per-dim lists of ``[start, end, ops, groups, tenant, wire]``
        records, parallel to ``SimResult.dim_services``.  ``ops`` is the
        served ``(chunk_id, stage_idx)`` tuple in service order;
        ``tenant`` is the granted (head) tenant — exact attribution under
        an arbiter, whose batches are same-tenant; a fused mixed-tenant
        batch in single-job mode is charged to its head.  Preemption
        shortens the record in place (end, ops, wire all amended), so at
        end of run the records describe what actually drained.
    ``grants``
        Arbiter grant decisions: ``(dim, t, tenant, n_chunks, wire)`` —
        one per service start while an arbiter is installed.
    ``preempts``
        Service splits: ``(dim, svc_idx, t, new_end, cut_ops, cut_wire,
        penalty)``; the cut chunks requeue (``penalty == 0``) or re-arm
        ``penalty`` seconds later.
    ``enqueues``
        Ready-queue arrivals ``(dim, t)`` — one per chunk stage entering
        a dim's queue, including preemption requeues.  Combined with
        service batch sizes this yields exact queue-depth timelines.
        (Stored as two typed arrays — ``array`` appends allocate no
        GC-tracked objects, which keeps the hottest hook off the cyclic
        collector's ledger; ``enqueues`` is a materializing property.)
    ``releases``
        Dependency-gated group releases ``(group, t)`` — the instant a
        group's predecessors resolved and it became eligible (dependency
        mode only; fixed-time issues are inputs, not events).
    ``dep_edges``
        Dependency-edge resolutions ``(parent, child, t)`` — one per
        graph edge, at the parent's full-finish instant.  These become
        Perfetto flow arrows.
    """

    __slots__ = ("engine", "num_dims", "n_groups", "services", "grants",
                 "preempts", "enq_dims", "enq_times", "releases", "dep_edges",
                 "faults", "aborts", "rerates", "retries", "group_fails",
                 "replans", "sheds", "admits",
                 "makespan", "dim_bw", "dim_wire", "dim_busy",
                 "dim_activity", "group_issue", "group_finish",
                 "group_streams", "group_tenants", "topology_name",
                 "finished", "_armed")

    def __init__(self) -> None:
        self.engine: str | None = None
        self.num_dims = 0
        self.n_groups = 0
        self.services: list[list[list]] = []
        self.grants: list[tuple] = []
        self.preempts: list[tuple] = []
        self.enq_dims = array("i")
        self.enq_times = array("d")
        self.releases: list[tuple[int, float]] = []
        self.dep_edges: list[tuple[int, int, float]] = []
        # Fault-injection events (populated only when simulate(faults=...)):
        self.faults: list[tuple[int, float, float, float]] = []
        self.aborts: list[tuple[int, int, float, int, tuple, float]] = []
        self.rerates: list[tuple[int, int, float, float, float]] = []
        self.retries: list[tuple[int, tuple, float, int, float]] = []
        self.group_fails: list[tuple[int, float]] = []
        self.replans: list[tuple[float, tuple, tuple]] = []
        # Admission events (populated only when simulate(admission=...)):
        self.sheds: list[tuple[int, float]] = []
        self.admits: list[tuple[int, float]] = []
        # finalize() snapshots:
        self.makespan = 0.0
        self.dim_bw: list[float] = []
        self.dim_wire: list[float] = []
        self.dim_busy: list[float] = []
        self.dim_activity: list[list[tuple[float, float]]] = []
        self.group_issue: list[float] = []
        self.group_finish: list[float] = []
        self.group_streams: list[str] = []
        self.group_tenants: list[str] = []
        self.topology_name = ""
        self.finished = False
        self._armed = False

    # -- engine-facing hooks (hot; every call site is branch-guarded) --------
    def begin(self, num_dims: int, n_groups: int, engine: str) -> None:
        """Arm the tracer for one run.  A Tracer records exactly one
        simulation; re-arming raises (build a fresh one per run)."""
        if self._armed:
            raise RuntimeError(
                "Tracer already used; one Tracer records one simulate() run")
        self._armed = True
        self.engine = engine
        self.num_dims = num_dims
        self.n_groups = n_groups
        self.services = [[] for _ in range(num_dims)]

    def service_start(self, dim: int, start: float, end: float, ops,
                      groups: tuple, tenant: str, wire: float) -> None:
        # ``ops`` may be the engine's own op list, shared by reference —
        # the engines never mutate a served list in place (preemption
        # *replaces* their copy; ``service_preempt`` reslices ours).
        self.services[dim].append([start, end, ops, groups, tenant, wire])

    def enqueue(self, dim: int, t: float) -> None:
        self.enq_dims.append(dim)
        self.enq_times.append(t)

    def service_preempt(self, dim: int, svc_idx: int, now: float,
                        new_end: float, n_keep: int, cut_ops: tuple,
                        cut_wire: float, penalty: float) -> None:
        rec = self.services[dim][svc_idx]
        rec[SVC_END] = new_end
        rec[SVC_OPS] = rec[SVC_OPS][:n_keep]
        rec[SVC_WIRE] = rec[SVC_WIRE] - cut_wire
        self.preempts.append(
            (dim, svc_idx, now, new_end, cut_ops, cut_wire, penalty))

    def grant(self, dim: int, now: float, tenant: str, n_chunks: int,
              wire: float) -> None:
        self.grants.append((dim, now, tenant, n_chunks, wire))

    def release(self, group: int, t: float) -> None:
        self.releases.append((group, t))

    # -- fault-injection hooks (armed only via simulate(faults=...)) ---------
    def fault(self, dim: int, t: float, factor: float, sigma: float) -> None:
        """A fault boundary took effect: ``dim`` now runs at ``factor`` x
        nominal BW with ``sigma`` extra straggler noise."""
        self.faults.append((dim, t, factor, sigma))

    def service_abort(self, dim: int, svc_idx: int, now: float,
                      n_keep: int, cut_ops: tuple, cut_wire: float) -> None:
        """An outage cut an in-flight service; like ``service_preempt`` the
        record is amended in place to what actually drained."""
        rec = self.services[dim][svc_idx]
        rec[SVC_END] = now
        rec[SVC_OPS] = rec[SVC_OPS][:n_keep]
        rec[SVC_WIRE] = rec[SVC_WIRE] - cut_wire
        self.aborts.append((dim, svc_idx, now, n_keep, cut_ops, cut_wire))

    def service_rerate(self, dim: int, svc_idx: int, now: float,
                       new_end: float, scale: float) -> None:
        """A BW change re-rated an in-flight service (drained bytes
        conserved; the remainder finishes at ``new_end``)."""
        rec = self.services[dim][svc_idx]
        rec[SVC_END] = new_end
        self.rerates.append((dim, svc_idx, now, new_end, scale))

    def retry(self, dim: int, op, now: float, attempt: int,
              resume_at: float) -> None:
        """A queued chunk on a down dim timed out.  ``resume_at > now`` is
        a backoff re-arrival; ``resume_at == now`` is the final attempt
        (the group fails)."""
        self.retries.append((dim, op, now, attempt, resume_at))

    def group_failed(self, group: int, t: float) -> None:
        self.group_fails.append((group, t))

    def replan(self, t: float, groups: tuple, factors: tuple) -> None:
        """The graceful-degradation hook rewrote ``groups``'s un-issued
        chunk schedules against per-dim BW ``factors``."""
        self.replans.append((t, groups, factors))

    # -- admission hooks (armed only via simulate(admission=...)) ------------
    def group_shed(self, group: int, t: float) -> None:
        """The admission controller shed ``group`` (demand-side loss —
        distinct from ``group_failed``, which is a fabric-side loss)."""
        self.sheds.append((group, t))

    def admit(self, group: int, t: float) -> None:
        """The admission controller admitted ``group``'s unit at its first
        ready event (recorded once per unit, on the deciding group)."""
        self.admits.append((group, t))

    def dep_resolved(self, parent: int, child: int, t: float) -> None:
        self.dep_edges.append((parent, child, t))

    def finalize(self, result: Any, topology: Any) -> None:
        """Snapshot the run's final bookkeeping (called once by the engine
        after it assembles its ``SimResult``; not a hot path)."""
        self.makespan = result.makespan
        self.dim_bw = [d.aggr_bw_bytes for d in topology.dims]
        self.dim_wire = list(result.dim_wire_bytes)
        self.dim_busy = list(result.dim_busy)
        self.dim_activity = [list(a) for a in result.dim_activity]
        self.group_issue = list(result.group_issue)
        self.group_finish = list(result.group_finish)
        self.group_streams = list(result.group_streams)
        self.group_tenants = list(result.group_tenants)
        self.topology_name = getattr(topology, "name", "")
        self.finished = True

    # -- derived views -------------------------------------------------------
    @property
    def enqueues(self) -> list[tuple[int, float]]:
        """Ready-queue arrivals as ``(dim, t)`` tuples, in event order."""
        return list(zip(self.enq_dims, self.enq_times))

    def service_wire(self) -> list[float]:
        """Per-dim wire bytes re-derived from the service records, in
        record order — must match ``SimResult.dim_wire_bytes`` to float
        precision (the obs_study gate)."""
        out = []
        for dim in range(self.num_dims):
            acc = 0.0
            for rec in self.services[dim]:
                acc += rec[SVC_WIRE]
            out.append(acc)
        return out

    def service_busy(self) -> list[float]:
        """Per-dim busy time re-derived from service records."""
        out = []
        for dim in range(self.num_dims):
            acc = 0.0
            for rec in self.services[dim]:
                acc += rec[SVC_END] - rec[SVC_START]
            out.append(acc)
        return out

    def ops_served(self, dim: int) -> list:
        """Flat served-op order on ``dim`` — equals
        ``SimResult.dim_op_order[dim]``."""
        return [op for rec in self.services[dim] for op in rec[SVC_OPS]]

    def event_counts(self) -> dict[str, int]:
        return {
            "services": sum(len(s) for s in self.services),
            "grants": len(self.grants),
            "preempts": len(self.preempts),
            "enqueues": len(self.enq_times),
            "releases": len(self.releases),
            "dep_edges": len(self.dep_edges),
            "faults": len(self.faults),
            "aborts": len(self.aborts),
            "rerates": len(self.rerates),
            "retries": len(self.retries),
            "group_fails": len(self.group_fails),
            "replans": len(self.replans),
            "sheds": len(self.sheds),
            "admits": len(self.admits),
            "groups": self.n_groups,
        }

    # -- Chrome trace_event export -------------------------------------------
    def to_chrome_trace(self) -> dict:
        """Export as a Chrome ``trace_event`` JSON object (open in Perfetto
        or ``chrome://tracing``).

        Layout: pid 0 is the *requests* track — one lane (tid) per stream
        tag, one complete event per group spanning issue→finish; pid
        ``1+dim`` is one track per network dimension — one lane per
        tenant, one complete event per service (args: ops, wire bytes,
        groups carried), instant events for preemption splits and arbiter
        grants.  Dependency releases are flow arrows (``ph: s/f``) from
        the parent group's span to the child's.  Timestamps are simulated
        microseconds.
        """
        if not self.finished:
            raise RuntimeError(
                "trace export needs a finished run (simulate() calls "
                "finalize); arm the tracer via simulate(..., tracer=...)")
        M = 1e6  # simulated seconds -> trace microseconds
        evs: list[dict] = []

        def meta(pid: int, name: str) -> None:
            evs.append({"ph": "M", "pid": pid, "tid": 0,
                        "name": "process_name", "args": {"name": name}})

        def lane(pid: int, lanes: dict[str, int], tag: str) -> int:
            tid = lanes.get(tag)
            if tid is None:
                tid = lanes[tag] = len(lanes) + 1
                evs.append({"ph": "M", "pid": pid, "tid": tid,
                            "name": "thread_name", "args": {"name": tag}})
            return tid

        # pid 0: request groups, one lane per stream
        meta(0, f"requests ({self.topology_name})")
        stream_lanes: dict[str, int] = {}
        streams = self.group_streams or ["default"] * self.n_groups
        tenants = self.group_tenants or ["default"] * self.n_groups
        group_tid: dict[int, int] = {}
        for g in range(self.n_groups):
            tid = lane(0, stream_lanes, streams[g])
            group_tid[g] = tid
            iss, fin = self.group_issue[g], self.group_finish[g]
            evs.append({"ph": "X", "pid": 0, "tid": tid, "ts": iss * M,
                        "dur": max(fin - iss, 0.0) * M, "name": f"g{g}",
                        "cat": "group",
                        "args": {"tenant": tenants[g], "stream": streams[g],
                                 "issue_s": iss, "finish_s": fin}})
        # flow arrows: parent group finish -> child group release
        for i, (parent, child, t) in enumerate(self.dep_edges):
            common = {"cat": "dep", "name": "dep", "id": i, "pid": 0}
            evs.append({"ph": "s", "tid": group_tid[parent], "ts": t * M,
                        **common})
            evs.append({"ph": "f", "bp": "e", "tid": group_tid[child],
                        "ts": t * M, **common})

        # pid 1+dim: one track per dimension, one lane per tenant
        for dim in range(self.num_dims):
            pid = 1 + dim
            bw = self.dim_bw[dim] if dim < len(self.dim_bw) else 0.0
            meta(pid, f"dim{dim} (BW={bw / 1e9:.1f} GB/s)")
            tenant_lanes: dict[str, int] = {}
            for rec in self.services[dim]:
                tid = lane(pid, tenant_lanes, rec[SVC_TENANT])
                evs.append({
                    "ph": "X", "pid": pid, "tid": tid,
                    "ts": rec[SVC_START] * M,
                    "dur": (rec[SVC_END] - rec[SVC_START]) * M,
                    "name": f"svc x{len(rec[SVC_OPS])}", "cat": "service",
                    "args": {"ops": len(rec[SVC_OPS]),
                             "wire_bytes": rec[SVC_WIRE],
                             "groups": list(rec[SVC_GROUPS])}})
            for (d, svc_idx, t, new_end, cut_ops, cut_wire, pen) \
                    in self.preempts:
                if d != dim:
                    continue
                tenant = self.services[dim][svc_idx][SVC_TENANT]
                tid = lane(pid, tenant_lanes, tenant)
                evs.append({"ph": "i", "pid": pid, "tid": tid, "ts": t * M,
                            "s": "t", "name": "preempt", "cat": "preempt",
                            "args": {"cut_ops": len(cut_ops),
                                     "cut_wire_bytes": cut_wire,
                                     "penalty_s": pen}})
            for (d, t, tenant, n_chunks, wire) in self.grants:
                if d != dim:
                    continue
                tid = lane(pid, tenant_lanes, tenant)
                evs.append({"ph": "i", "pid": pid, "tid": tid, "ts": t * M,
                            "s": "t", "name": "grant", "cat": "grant",
                            "args": {"chunks": n_chunks,
                                     "wire_bytes": wire}})
            # Fault-injection instants (tid 0 — they affect the whole dim).
            for (d, t, factor, sigma) in self.faults:
                if d != dim:
                    continue
                evs.append({"ph": "i", "pid": pid, "tid": 0, "ts": t * M,
                            "s": "t", "name": f"fault f={factor:g}",
                            "cat": "fault",
                            "args": {"bw_factor": factor,
                                     "extra_sigma": sigma}})
            for (d, svc_idx, t, n_keep, cut_ops, cut_wire) in self.aborts:
                if d != dim:
                    continue
                evs.append({"ph": "i", "pid": pid, "tid": 0, "ts": t * M,
                            "s": "t", "name": "abort", "cat": "abort",
                            "args": {"kept_ops": n_keep,
                                     "cut_ops": len(cut_ops),
                                     "cut_wire_bytes": cut_wire}})
            for (d, svc_idx, t, new_end, scale) in self.rerates:
                if d != dim:
                    continue
                evs.append({"ph": "i", "pid": pid, "tid": 0, "ts": t * M,
                            "s": "t", "name": "rerate", "cat": "rerate",
                            "args": {"new_end_s": new_end,
                                     "rate_scale": scale}})
            for (d, op, t, attempt, resume_at) in self.retries:
                if d != dim:
                    continue
                evs.append({"ph": "i", "pid": pid, "tid": 0, "ts": t * M,
                            "s": "t", "name": f"retry #{attempt}",
                            "cat": "retry",
                            "args": {"op": list(op), "attempt": attempt,
                                     "resume_at_s": resume_at}})
        # Global (pid 0) fault instants: group failures and re-plans.
        for (g, t) in self.group_fails:
            evs.append({"ph": "i", "pid": 0, "tid": group_tid.get(g, 0),
                        "ts": t * M, "s": "t", "name": f"g{g} failed",
                        "cat": "group_fail", "args": {"group": g}})
        for (t, groups, factors) in self.replans:
            evs.append({"ph": "i", "pid": 0, "tid": 0, "ts": t * M,
                        "s": "g", "name": f"replan x{len(groups)}",
                        "cat": "replan",
                        "args": {"groups": list(groups),
                                 "bw_factors": list(factors)}})
        # Admission instants: shed / admitted requests on their lanes.
        for (g, t) in self.sheds:
            evs.append({"ph": "i", "pid": 0, "tid": group_tid.get(g, 0),
                        "ts": t * M, "s": "t", "name": f"g{g} shed",
                        "cat": "shed", "args": {"group": g}})
        for (g, t) in self.admits:
            evs.append({"ph": "i", "pid": 0, "tid": group_tid.get(g, 0),
                        "ts": t * M, "s": "t", "name": f"g{g} admitted",
                        "cat": "admit", "args": {"group": g}})
        return {"traceEvents": evs, "displayTimeUnit": "ms",
                "otherData": {"engine": self.engine,
                              "topology": self.topology_name,
                              "makespan_s": self.makespan}}

    def save(self, path) -> None:
        """Write the Chrome trace JSON to ``path`` (open in Perfetto)."""
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)


def parse_chrome_trace(source) -> dict[str, Any]:
    """Parse an exported trace (path or dict) back into summary counts —
    the round-trip check: counts must match the recording ``SimResult``'s
    bookkeeping.

    Returns ``{"groups": n, "services_per_dim": {dim: n}, "services": n,
    "preempts": n, "grants": n, "flows": n, "dims": n, "faults": n,
    "aborts": n, "rerates": n, "retries": n, "group_fails": n,
    "replans": n, "sheds": n, "admits": n}``.
    """
    if isinstance(source, dict):
        obj = source
    else:
        with open(source) as f:
            obj = json.load(f)
    groups = 0
    per_dim: dict[int, int] = {}
    preempts = grants = flows = 0
    faults = aborts = rerates = retries = group_fails = replans = 0
    sheds = admits = 0
    for ev in obj["traceEvents"]:
        cat = ev.get("cat")
        if cat == "group":
            groups += 1
        elif cat == "service":
            dim = ev["pid"] - 1
            per_dim[dim] = per_dim.get(dim, 0) + 1
        elif cat == "preempt":
            preempts += 1
        elif cat == "grant":
            grants += 1
        elif cat == "dep" and ev.get("ph") == "s":
            flows += 1
        elif cat == "fault":
            faults += 1
        elif cat == "abort":
            aborts += 1
        elif cat == "rerate":
            rerates += 1
        elif cat == "retry":
            retries += 1
        elif cat == "group_fail":
            group_fails += 1
        elif cat == "replan":
            replans += 1
        elif cat == "shed":
            sheds += 1
        elif cat == "admit":
            admits += 1
    return {"groups": groups, "services_per_dim": per_dim,
            "services": sum(per_dim.values()), "preempts": preempts,
            "grants": grants, "flows": flows,
            "faults": faults, "aborts": aborts, "rerates": rerates,
            "retries": retries, "group_fails": group_fails,
            "replans": replans, "sheds": sheds, "admits": admits,
            "dims": (max(per_dim) + 1) if per_dim else 0}
