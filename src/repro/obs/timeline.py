"""Windowed bandwidth/queue/share timelines derived from a trace.

:class:`BwTimeline` is the canonical time-resolved view of one simulation
run — the input the ROADMAP's closed-loop contention-aware scheduler will
consume (observed per-dim BW shares fed back into ``ThemisScheduler``),
and the single implementation of the interval math the Fig. 9 / Fig. 11
benchmarks used to hand-roll.

Two constructors, two fidelity levels:

  * :meth:`BwTimeline.from_result` — scalar aggregates only (per-dim wire
    bytes, busy time, activity intervals, makespan).  Enough for the
    paper's figures: ``avg_bw_utilization`` and ``activity_rate`` are the
    *same expressions* as ``SimResult``'s, so ported benchmarks stay
    numerically identical.
  * :meth:`BwTimeline.from_tracer` — full event fidelity from a
    :class:`~repro.obs.tracer.Tracer`: windowed per-dim utilization,
    per-tenant BW shares (``per_dim_shares``), and queue-depth series.

A service drains wire bytes uniformly over its interval (exactly the
engines' service model), so windowed byte attribution is overlap-weighted
and integrates back to the per-dim totals to float precision — the
``benchmarks/obs_study.py`` gate asserts both ends against
``SimResult.avg_bw_utilization`` / ``dim_busy``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.obs.tracer import (
    SVC_END,
    SVC_OPS,
    SVC_START,
    SVC_TENANT,
    SVC_WIRE,
    Tracer,
)


@dataclass
class BwTimeline:
    """Time-resolved per-dim bandwidth view of one simulation run."""

    num_dims: int
    makespan: float
    dim_bw: list[float]                 # bytes/s per dim
    dim_wire: list[float]               # total wire bytes per dim
    dim_busy: list[float]               # total busy seconds per dim
    activity: list[list[tuple[float, float]]]  # pending-work intervals
    # Full-fidelity fields (tracer-backed only):
    services: list[list[list]] | None = None   # Tracer.services layout
    enqueues: list[tuple[int, float]] = field(default_factory=list)

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_result(cls, result, topology) -> "BwTimeline":
        """Build from a ``SimResult`` (aggregate fidelity; no windowed
        share/queue series — record a trace for those)."""
        return cls(
            num_dims=topology.num_dims,
            makespan=result.makespan,
            dim_bw=[d.aggr_bw_bytes for d in topology.dims],
            dim_wire=list(result.dim_wire_bytes),
            dim_busy=list(result.dim_busy),
            activity=[list(a) for a in result.dim_activity],
        )

    @classmethod
    def from_tracer(cls, tracer: Tracer) -> "BwTimeline":
        """Build from a finished :class:`Tracer` (full event fidelity)."""
        if not tracer.finished:
            raise ValueError(
                "tracer has not recorded a finished run; pass it to "
                "simulate(..., tracer=...) first")
        return cls(
            num_dims=tracer.num_dims,
            makespan=tracer.makespan,
            dim_bw=list(tracer.dim_bw),
            dim_wire=list(tracer.dim_wire),
            dim_busy=list(tracer.dim_busy),
            activity=[list(a) for a in tracer.dim_activity],
            services=tracer.services,
            enqueues=tracer.enqueues,
        )

    # -- aggregate metrics (the SimResult expressions, verbatim) -------------
    def avg_bw_utilization(self) -> float:
        """Weighted-average BW utilization (weights = per-dim BW budget) —
        the paper's Fig. 11 metric; identical expression to
        ``SimResult.avg_bw_utilization``."""
        if self.makespan <= 0:
            return 0.0
        total_bw = sum(self.dim_bw)
        moved = sum(self.dim_wire)
        return moved / (self.makespan * total_bw)

    def dim_utilization(self, dim: int) -> float:
        """One dimension's BW utilization over the whole run."""
        if self.makespan <= 0 or self.dim_bw[dim] <= 0:
            return 0.0
        return self.dim_wire[dim] / (self.makespan * self.dim_bw[dim])

    def activity_rate(self, dim: int) -> float:
        """Fraction of the makespan ``dim`` had pending work — the Fig. 9
        metric; identical expression to ``SimResult.activity_rate``."""
        if self.makespan <= 0:
            return 0.0
        return sum(e - s for s, e in self.activity[dim]) / self.makespan

    # -- windowed series (tracer-backed) -------------------------------------
    def windows(self, window: float) -> list[tuple[float, float]]:
        """Half-open windows ``[t, min(t+window, makespan))`` tiling the
        run.  The final window is truncated at the makespan so rates stay
        normalized by actual covered time."""
        if window <= 0:
            raise ValueError("window must be > 0 seconds")
        out = []
        t = 0.0
        while t < self.makespan:
            out.append((t, min(t + window, self.makespan)))
            t += window
        return out or [(0.0, 0.0)]

    def _need_services(self) -> list[list[list]]:
        if self.services is None:
            raise ValueError(
                "windowed series need per-service events; build this "
                "timeline with BwTimeline.from_tracer(...)")
        return self.services

    def per_dim_utilization(self, window: float) -> list[list[float]]:
        """``[dim][window]`` BW utilization: bytes drained in the window
        (uniform-drain overlap weighting) over the window's capacity.
        Sums back to :meth:`dim_utilization` exactly (up to float order).
        """
        services = self._need_services()
        wins = self.windows(window)
        out: list[list[float]] = []
        for dim in range(self.num_dims):
            cap = self.dim_bw[dim]
            vals = []
            for (w0, w1) in wins:
                span = w1 - w0
                vals.append(0.0 if span <= 0 or cap <= 0 else
                            self._drained(services[dim], w0, w1) /
                            (span * cap))
            out.append(vals)
        return out

    def per_dim_shares(
        self, window: float
    ) -> dict[str, list[list[float]]]:
        """Per-tenant observed BW share: ``{tenant: [dim][window]}`` where
        each entry is the fraction of the dim's capacity that tenant's
        services drained in the window.  This is the feedback signal the
        closed-loop controller consumes (ROADMAP: observed per-dim BW
        shares -> scheduler), and the time-resolved version of
        ``repro.tenancy.metrics``' aggregate shares.

        Attribution is by granted (head) tenant — exact under an arbiter,
        whose service batches are same-tenant by construction.
        """
        services = self._need_services()
        wins = self.windows(window)
        tenants = sorted({rec[SVC_TENANT]
                          for per_dim in services for rec in per_dim})
        out = {t: [[0.0] * len(wins) for _ in range(self.num_dims)]
               for t in tenants}
        for dim in range(self.num_dims):
            cap = self.dim_bw[dim]
            for rec in services[dim]:
                rows = out[rec[SVC_TENANT]][dim]
                for w, (w0, w1) in enumerate(wins):
                    span = w1 - w0
                    if span <= 0 or cap <= 0:
                        continue
                    got = _overlap_bytes(rec, w0, w1)
                    if got:
                        rows[w] += got / (span * cap)
        return out

    def queue_depth(self, window: float) -> list[list[float]]:
        """``[dim][window]`` time-averaged ready-queue depth, integrated
        from enqueue events (+1) and service starts (−batch size)."""
        services = self._need_services()
        wins = self.windows(window)
        out: list[list[float]] = []
        for dim in range(self.num_dims):
            deltas = [(t, 1) for (d, t) in self.enqueues if d == dim]
            deltas += [(rec[SVC_START], -len(rec[SVC_OPS]))
                       for rec in services[dim]]
            # Enqueues settle before the dequeue at the same timestamp
            # (the engine enqueues, then starts a service).
            deltas.sort(key=lambda p: (p[0], -p[1]))
            out.append(_integrate_depth(deltas, wins))
        return out

    @staticmethod
    def _drained(recs: list[list], w0: float, w1: float) -> float:
        acc = 0.0
        for rec in recs:
            acc += _overlap_bytes(rec, w0, w1)
        return acc


def _overlap_bytes(rec: list, w0: float, w1: float) -> float:
    """Bytes of one service draining inside ``[w0, w1)`` under the
    engines' uniform-drain service model."""
    s, e, wire = rec[SVC_START], rec[SVC_END], rec[SVC_WIRE]
    lo, hi = max(s, w0), min(e, w1)
    if hi <= lo:
        return 0.0
    if e <= s:  # zero-length service (zero-wire stages): all-or-nothing
        return wire if w0 <= s < w1 else 0.0
    return wire * (hi - lo) / (e - s)


def _integrate_depth(deltas: list[tuple[float, int]],
                     wins: list[tuple[float, float]]) -> list[float]:
    """Time-average a step function (given as sorted (t, delta) events)
    over each window."""
    out = []
    i0 = 0
    for (w0, w1) in wins:
        span = w1 - w0
        if span <= 0:
            out.append(0.0)
            continue
        # depth entering the window = sum of deltas strictly before w0
        depth = 0
        area = 0.0
        t = w0
        j = 0
        while j < len(deltas) and deltas[j][0] < w0:
            depth += deltas[j][1]
            j += 1
        while j < len(deltas) and deltas[j][0] < w1:
            ts, d = deltas[j]
            area += depth * (ts - t)
            depth += d
            t = ts
            j += 1
        area += depth * (w1 - t)
        out.append(area / span)
        i0 = i0  # windows are independent; rescan keeps code simple
    return out
