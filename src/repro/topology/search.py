"""Bandwidth-partition / dim-order topology search (LIBRA-flavored).

LIBRA (arXiv 2109.11762) tunes how a fixed total network budget is split
across the dimensions of a multi-dimensional fabric for a target workload;
ForestColl (arXiv 2402.06787) generalizes schedule+topology co-search.
This module brings the same loop in-process over our simulator: enumerate
and locally refine **BW splits** (what fraction of the per-NPU bandwidth
budget each dimension gets) and **dim orderings** (which physical dimension
sits at which level of the hierarchy) for a fixed shape — NPU counts,
per-dim physical topology and step latencies are preserved, so every
candidate is ``make_tpu_pod_topology``/Table-2 compatible and spends
exactly the same total bandwidth.

Candidates are scored by simulating the target workload's actual request
stream (``repro.core.batch.simulate_batch``; multi-seed jitter scoring
shares one scheduling pass per candidate), with **sound early pruning**: a
candidate whose per-dim busy-time lower bound
(:meth:`~repro.core.latency_model.LatencyModel.dim_lower_bounds` — no
schedule can put fewer bytes on a dim) already exceeds the best simulated
makespan can never win and is skipped without simulation.  The result
carries the best candidate and the Pareto front over (makespan,
BW-utilization) of everything evaluated.

The search is fully deterministic for a fixed config: enumeration order,
refinement mutations and tie-breaks are value-based, and the only
randomness (service jitter) is seeded per scenario.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field

from repro.core.batch import BatchCaches, Scenario, simulate_batch
from repro.core.latency_model import LatencyModel
from repro.core.requests import CollectiveRequest

from .topology import GBPS, NetworkDim, Topology


def bw_split_topology(
    base: Topology,
    fractions: tuple[float, ...],
    perm: tuple[int, ...] | None = None,
    name: str | None = None,
) -> Topology:
    """Re-split ``base``'s total per-NPU BW budget across its dimensions.

    ``fractions[pos]`` is the share of ``base.total_bw_bytes`` given to the
    dimension at hierarchy position ``pos``; ``perm[pos]`` names which base
    dimension sits there (identity by default).  NPU counts, physical
    topology kinds, per-NPU link counts and step latencies are preserved —
    only ``link_gbps`` is rescaled — so the candidate spends exactly the
    base budget and remains compatible with everything a hand-built
    topology works with.
    """
    if perm is None:
        perm = tuple(range(base.num_dims))
    if len(fractions) != base.num_dims or len(perm) != base.num_dims:
        raise ValueError("fractions/perm must have one entry per dimension")
    if sorted(perm) != list(range(base.num_dims)):
        raise ValueError(f"perm must permute dim indices, got {perm}")
    if any(f <= 0 for f in fractions):
        raise ValueError("every dimension needs a positive BW fraction")
    budget = base.total_bw_bytes
    dims = []
    for pos, bi in enumerate(perm):
        d = base.dims[bi]
        link_gbps = fractions[pos] * budget / (d.links_per_npu * GBPS)
        dims.append(NetworkDim(d.npus, d.topo, link_gbps, d.links_per_npu,
                               d.step_latency_s, d.straggler_sigma))
    if name is None:
        frac_s = "-".join(f"{f:.4g}" for f in fractions)
        name = f"{base.name}|bw[{frac_s}]|perm{''.join(map(str, perm))}"
    return Topology(name, tuple(dims))


def enumerate_bw_shares(num_dims: int, granularity: int) -> list[tuple[int, ...]]:
    """All splits of ``granularity`` budget units into positive per-dim
    shares (compositions), in lexicographic order — the deterministic
    round-0 grid of the search."""
    if granularity < num_dims:
        raise ValueError("granularity must be >= num_dims (every dim needs "
                         "a positive share)")
    out: list[tuple[int, ...]] = []

    def rec(prefix: list[int], remaining: int, dims_left: int) -> None:
        if dims_left == 1:
            out.append(tuple(prefix + [remaining]))
            return
        for s in range(1, remaining - dims_left + 2):
            rec(prefix + [s], remaining - s, dims_left - 1)

    rec([], granularity, num_dims)
    return out


def stream_lower_bound(
    topology: Topology, requests: list[CollectiveRequest]
) -> float:
    """Sound lower bound on the simulated makespan of ``requests``.

    max of (a) every dim's total busy-time bound (sum of per-request
    minimal wire bytes over the dim's BW — dims are serial resources),
    and (b) every request's ``issue_time + ideal_time`` (work conservation
    across the whole fabric).  Fusion, arbiters, preemption, jitter and
    A-delays can only add time, never remove wire bytes, so no simulated
    schedule beats this — the pruning certificate of the search.
    """
    lm = LatencyModel.for_topology(topology)
    busy = [0.0] * topology.num_dims
    per_request = 0.0
    for r in requests:
        for k, lb in enumerate(lm.dim_lower_bounds(r.collective,
                                                   r.size_bytes)):
            busy[k] += lb
        t = r.issue_time + lm.ideal_time(r.collective, r.size_bytes)
        if t > per_request:
            per_request = t
    dim_bound = max(busy) if busy else 0.0
    return max(dim_bound, per_request)


@dataclass(frozen=True)
class SearchConfig:
    """Knobs of :func:`search_topologies` (all deterministic)."""

    granularity: int = 8            # round-0 BW grid: shares of budget/g
    rounds: int = 2                 # local-refinement rounds after the grid
    top_k: int = 4                  # survivors mutated per round
    seeds: tuple[int, ...] = (0,)   # scoring seeds (jitter robustness)
    jitter: float = 0.0             # service-time jitter during scoring
    policy: str = "themis"
    chunks_per_collective: int = 16
    water_filling: bool = False
    intra: str = "SCF"
    fusion: bool = True
    search_dim_orders: bool = True  # also permute hierarchy positions
    max_candidates_per_round: int = 256
    prune: bool = True              # lower-bound pruning on/off (ablation)
    arbiter_factory: object = None  # fresh inter-tenant arbiter per scenario

    def __post_init__(self):
        if not self.seeds:
            raise ValueError("seeds must name at least one scoring seed")
        if self.rounds < 0 or self.top_k < 1:
            raise ValueError("rounds must be >= 0 and top_k >= 1")


@dataclass(frozen=True)
class CandidateScore:
    """One evaluated candidate: mean-over-seeds makespan + utilization."""

    topology: Topology
    shares: tuple[int, ...]         # integer BW shares (of ``denom`` units)
    denom: int
    perm: tuple[int, ...]
    makespan: float
    bw_utilization: float
    lower_bound: float

    @property
    def fractions(self) -> tuple[float, ...]:
        return tuple(s / self.denom for s in self.shares)


@dataclass
class SearchResult:
    default: CandidateScore         # the base topology, scored identically
    best: CandidateScore            # min mean makespan over everything run
    pareto: list[CandidateScore]    # min makespan / max utilization front
    evaluated: list[CandidateScore] = field(repr=False, default_factory=list)
    pruned: int = 0                 # candidates skipped via lower bound
    scenarios_run: int = 0          # simulations executed (candidates x seeds)

    @property
    def improvement(self) -> float:
        """default/best makespan ratio (> 1: the search won)."""
        return self.default.makespan / self.best.makespan


# Candidates scored per simulate_batch call inside a round — small enough
# that an early good makespan prunes the round's tail, large enough to keep
# the batch amortization.
_SCORE_CHUNK = 8


def _norm_key(shares: tuple[int, ...], denom: int,
              perm: tuple[int, ...]) -> tuple:
    """Dedupe key: (2,14)/16 is the same split as (1,7)/8."""
    g = math.gcd(denom, *shares)
    return (tuple(s // g for s in shares), denom // g, perm)


def _apportion(fractions: tuple[float, ...], granularity: int) -> tuple[int, ...]:
    """Integer shares summing exactly to ``granularity`` (largest-remainder,
    every dim >= 1) — mutating these always conserves the BW budget."""
    d = len(fractions)
    raw = [f * granularity for f in fractions]
    shares = [max(1, int(r)) for r in raw]
    rema = sorted(range(d), key=lambda k: (raw[k] - int(raw[k]), k),
                  reverse=True)
    i = 0
    while sum(shares) < granularity:
        shares[rema[i % d]] += 1
        i += 1
    while sum(shares) > granularity:
        k = max(range(d), key=lambda k: (shares[k], k))
        if shares[k] <= 1:  # pragma: no cover - granularity >= num_dims
            break
        shares[k] -= 1
    return tuple(shares)


def _pareto_front(scores: list[CandidateScore]) -> list[CandidateScore]:
    """Non-dominated set: minimize makespan, maximize BW utilization."""
    ordered = sorted(scores, key=lambda c: (c.makespan, -c.bw_utilization))
    front: list[CandidateScore] = []
    best_util = float("-inf")
    for c in ordered:
        if c.bw_utilization > best_util:
            front.append(c)
            best_util = c.bw_utilization
    return front


def search_topologies(
    base: Topology,
    requests: list[CollectiveRequest],
    config: SearchConfig = SearchConfig(),
    *,
    caches: BatchCaches | None = None,
) -> SearchResult:
    """Search BW splits x dim orders of ``base`` for ``requests``.

    Round 0 scores the full share grid (pruned by lower bound against the
    incumbent best makespan); each refinement round doubles the share
    resolution around the ``top_k`` survivors (move one finer-grained BW
    unit between every dim pair; swap adjacent hierarchy positions) and
    re-scores.  All candidate scoring goes through one shared
    :class:`~repro.core.batch.BatchCaches`, so stage vectors and schedules
    are amortized across the entire search.
    """
    cfg = config
    reqs = tuple(requests)
    caches = caches if caches is not None else BatchCaches()
    d = base.num_dims

    def score_batch(cands: list[tuple[tuple[int, ...], int, tuple[int, ...],
                                      Topology, float]]
                    ) -> list[CandidateScore]:
        scenarios = []
        for _, _, _, topo, _ in cands:
            for seed in cfg.seeds:
                scenarios.append(Scenario(
                    topo, reqs, policy=cfg.policy,
                    chunks_per_collective=cfg.chunks_per_collective,
                    water_filling=cfg.water_filling, intra=cfg.intra,
                    fusion=cfg.fusion, jitter=cfg.jitter, seed=seed,
                    arbiter_factory=cfg.arbiter_factory))
        results = simulate_batch(scenarios, caches=caches)
        out = []
        n_seeds = len(cfg.seeds)
        for i, (shares, denom, perm, topo, lb) in enumerate(cands):
            runs = results[i * n_seeds:(i + 1) * n_seeds]
            mk = sum(r.makespan for r in runs) / n_seeds
            util = sum(r.avg_bw_utilization(topo) for r in runs) / n_seeds
            out.append(CandidateScore(topo, shares, denom, perm, mk, util,
                                      lb))
        return out

    # -- the default fabric, scored under identical conditions ---------------
    # base_shares is the apportioned *description* of the default's split
    # (refinement mutates it budget-exactly); the grid candidate with the
    # same shares is a distinct on-grid fabric and is still evaluated.
    budget = base.total_bw_bytes
    base_shares = _apportion(
        tuple(dd.aggr_bw_bytes / budget for dd in base.dims),
        cfg.granularity)
    default = score_batch(
        [(base_shares, cfg.granularity, tuple(range(d)), base,
          stream_lower_bound(base, list(reqs)))])[0]

    evaluated: list[CandidateScore] = [default]
    incumbent = default.makespan
    pruned = 0
    scenarios_run = len(cfg.seeds)
    # Candidates become "seen" only once actually processed (simulated or
    # lower-bound-pruned); a candidate cut by max_candidates_per_round may
    # legitimately reappear in a later refinement round.
    seen: set[tuple] = set()

    perms = (list(itertools.permutations(range(d)))
             if cfg.search_dim_orders else [tuple(range(d))])

    def run_round(pool: list[tuple[tuple[int, ...], int, tuple[int, ...]]]
                  ) -> None:
        nonlocal incumbent, pruned, scenarios_run
        cands = []
        for shares, denom, perm in pool:
            topo = bw_split_topology(
                base, tuple(s / denom for s in shares), perm)
            cands.append((shares, denom, perm,
                          stream_lower_bound(topo, list(reqs)), topo))
        # Evaluate cheapest-looking first so the incumbent tightens early,
        # scoring in sub-batches so a makespan found early in the round
        # prunes the round's own tail (shared ``caches`` keep successive
        # simulate_batch calls warm, so chunking costs nothing).
        cands.sort(key=lambda c: (c[3], c[0], c[2]))
        cands = cands[:cfg.max_candidates_per_round]
        i = 0
        while i < len(cands):
            batch = []
            while i < len(cands) and len(batch) < _SCORE_CHUNK:
                shares, denom, perm, lb, topo = cands[i]
                i += 1
                seen.add(_norm_key(shares, denom, perm))
                if cfg.prune and lb >= incumbent:
                    # sound to retire forever: the incumbent only improves
                    pruned += 1
                    continue
                batch.append((shares, denom, perm, topo, lb))
            for cs in score_batch(batch):
                evaluated.append(cs)
                scenarios_run += len(cfg.seeds)
                if cs.makespan < incumbent:
                    incumbent = cs.makespan

    def add_candidate(pool, pool_keys, shares, denom, perm) -> None:
        key = _norm_key(shares, denom, perm)
        if key not in seen and key not in pool_keys:
            pool_keys.add(key)
            pool.append((shares, denom, perm))

    # -- round 0: the share grid x dim orders --------------------------------
    grid: list[tuple[tuple[int, ...], int, tuple[int, ...]]] = []
    grid_keys: set[tuple] = set()
    for shares in enumerate_bw_shares(d, cfg.granularity):
        for perm in perms:
            add_candidate(grid, grid_keys, shares, cfg.granularity, perm)
    run_round(grid)

    # -- refinement rounds: double resolution around the survivors -----------
    for _ in range(cfg.rounds):
        ranked = sorted(evaluated, key=lambda c: (c.makespan, c.shares,
                                                  c.perm))
        pool: list[tuple[tuple[int, ...], int, tuple[int, ...]]] = []
        pool_keys: set[tuple] = set()
        for cs in ranked[:cfg.top_k]:
            denom = cs.denom * 2
            shares = tuple(s * 2 for s in cs.shares)
            for i in range(d):
                for j in range(d):
                    if i == j or shares[i] <= 1:
                        continue
                    moved = list(shares)
                    moved[i] -= 1
                    moved[j] += 1
                    add_candidate(pool, pool_keys, tuple(moved), denom,
                                  cs.perm)
            for i in range(d - 1):  # adjacent hierarchy swaps
                p = list(cs.perm)
                p[i], p[i + 1] = p[i + 1], p[i]
                add_candidate(pool, pool_keys, shares, denom, tuple(p))
        if not pool:
            break
        run_round(pool)

    best = min(evaluated, key=lambda c: (c.makespan, c.shares, c.perm))
    return SearchResult(
        default=default, best=best, pareto=_pareto_front(evaluated),
        evaluated=evaluated, pruned=pruned, scenarios_run=scenarios_run)
