"""Multi-dimensional network topologies (paper Table 2 + TPU pod models).

A ``Topology`` is an ordered list of ``NetworkDim``.  Dim 1 is the innermost
(highest-BW) dimension.  Bandwidths are *uni-directional aggregate* GB/s per
NPU for that dimension (paper's "Aggr BW/NPU", converted from Gb/s), and
``step_latency_s`` is the minimum NPU-to-NPU message latency on that
dimension (paper's "Network Latency").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

from .algorithms import ALGO_BY_KIND, CollectiveAlgorithm, TopoKind

GBPS = 1e9 / 8  # 1 Gb/s in bytes/s


@dataclass(frozen=True)
class NetworkDim:
    """One dimension of a hierarchical NPU network.

    ``straggler_sigma`` models service-time stragglers on this dimension:
    every service interval is multiplied by a lognormal(0, sigma) draw
    (median 1, heavy right tail — the classic DCN tail-latency shape).
    0.0 (default) keeps the dimension deterministic; the draw is seeded by
    ``simulate(seed=...)`` so runs are reproducible.
    """

    npus: int                      # peers participating on this dim (P_i)
    topo: TopoKind                 # physical topology of this dim
    link_gbps: float               # per-link uni-directional BW (Gb/s)
    links_per_npu: int             # links each NPU contributes to this dim
    step_latency_s: float          # min NPU->NPU message latency (s)
    straggler_sigma: float = 0.0   # lognormal service-straggler sigma

    @property
    def aggr_bw_bytes(self) -> float:
        """Aggregate uni-directional BW per NPU on this dim, bytes/s."""
        return self.link_gbps * self.links_per_npu * GBPS

    @property
    def algorithm(self) -> CollectiveAlgorithm:
        return ALGO_BY_KIND[self.topo]


@dataclass(frozen=True)
class Topology:
    name: str
    dims: tuple[NetworkDim, ...]

    @property
    def num_dims(self) -> int:
        return len(self.dims)

    @property
    def total_npus(self) -> int:
        n = 1
        for d in self.dims:
            n *= d.npus
        return n

    @property
    def total_bw_bytes(self) -> float:
        """Sum of per-NPU aggregate BW across all dims (for Ideal/util)."""
        return sum(d.aggr_bw_bytes for d in self.dims)

    def size_str(self) -> str:
        return "x".join(str(d.npus) for d in self.dims)


def _dim(npus, topo, link_gbps, links, lat_ns, straggler=0.0) -> NetworkDim:
    return NetworkDim(npus, topo, link_gbps, links, lat_ns * 1e-9, straggler)


SW = TopoKind.SWITCH
FC = TopoKind.FULLY_CONNECTED
RING = TopoKind.RING


def make_table2_topologies() -> dict[str, Topology]:
    """The six 1024-NPU next-gen topologies of paper Table 2."""
    t = {}
    t["2D-SW_SW"] = Topology(
        "2D-SW_SW",
        (
            _dim(16, SW, 200, 6, 700),
            _dim(64, SW, 800, 1, 1700),
        ),
    )
    t["3D-SW_SW_SW_homo"] = Topology(
        "3D-SW_SW_SW_homo",
        (
            _dim(16, SW, 200, 4, 700),
            _dim(8, SW, 200, 4, 700),
            _dim(8, SW, 800, 1, 1700),
        ),
    )
    t["3D-SW_SW_SW_hetero"] = Topology(
        "3D-SW_SW_SW_hetero",
        (
            _dim(16, SW, 200, 8, 700),
            _dim(8, SW, 200, 4, 700),
            _dim(8, SW, 400, 1, 1700),
        ),
    )
    t["3D-FC_Ring_SW"] = Topology(
        "3D-FC_Ring_SW",
        (
            _dim(8, FC, 200, 7, 700),
            _dim(16, RING, 200, 4, 700),
            _dim(8, SW, 400, 1, 1700),
        ),
    )
    t["4D-Ring_SW_SW_SW"] = Topology(
        "4D-Ring_SW_SW_SW",
        (
            _dim(4, RING, 1000, 2, 20),
            _dim(4, SW, 200, 8, 700),
            _dim(8, SW, 200, 4, 700),
            _dim(8, SW, 400, 1, 1700),
        ),
    )
    t["4D-Ring_FC_Ring_SW"] = Topology(
        "4D-Ring_FC_Ring_SW",
        (
            _dim(4, RING, 1500, 2, 20),
            _dim(8, FC, 200, 7, 700),
            _dim(4, RING, 200, 6, 700),
            _dim(8, SW, 800, 1, 1700),
        ),
    )
    return t


def make_current_topology() -> Topology:
    """Today's 2D system used as the paper's 'current' reference (Sec. 3):
    1200 Gb/s intra-node vs 100 Gb/s NIC."""
    return Topology(
        "current-2D",
        (
            _dim(16, SW, 200, 6, 700),
            _dim(64, SW, 100, 1, 1700),
        ),
    )


def make_tpu_pod_topology(
    pods: int = 2, data: int = 16, model: int = 16,
    *, dcn_straggler_sigma: float = 0.0,
) -> Topology:
    """TPU-v5e-flavored hierarchy used by the JAX integration layer.

    dim1: `model` axis — ICI ring, ~50 GB/s/link (2 links usable per axis).
    dim2: `data` axis  — ICI ring on the second mesh axis.
    dim3: `pod` axis   — DCN through NICs (~200 Gb/s per host).

    Dims are ordered innermost-first like the paper.

    ``dcn_straggler_sigma``: lognormal straggler sigma on the DCN pod
    dimension (ICI dims stay deterministic) — cross-pod collectives ride
    a shared datacenter network whose tail is what Sec. 4.6's schedule-
    consistency experiments care about.  Seeded via ``simulate(seed=...)``.
    """
    if dcn_straggler_sigma < 0:
        raise ValueError("dcn_straggler_sigma must be >= 0")
    if dcn_straggler_sigma and pods <= 1:
        raise ValueError(
            "dcn_straggler_sigma needs a DCN dimension (pods > 1); a "
            "single-pod topology would silently ignore it")
    dims = []
    if model > 1:
        dims.append(_dim(model, RING, 400, 2, 1000))   # 50 GB/s * 2 links
    if data > 1:
        dims.append(_dim(data, RING, 400, 2, 1000))
    if pods > 1:
        dims.append(_dim(pods, SW, 200, 1, 20000,      # DCN NIC
                         straggler=dcn_straggler_sigma))
    name = f"tpu-{pods}x{data}x{model}"
    if dcn_straggler_sigma and pods > 1:
        name += f"-dcnjit{dcn_straggler_sigma:g}"
    return Topology(name, tuple(dims))


ALL_TOPOLOGIES: dict[str, Topology] = {
    **make_table2_topologies(),
    "current-2D": make_current_topology(),
}
