"""Per-dimension topology-aware collective algorithms (paper Table 1).

Each network dimension runs a basic, contention-free collective algorithm
chosen by its physical topology:

    Ring            -> ring algorithm            (P-1 steps for RS/AG)
    FullyConnected  -> direct algorithm          (1 step)
    Switch          -> halving-doubling          (log2(P) steps)

For a chunk whose per-NPU resident size is ``S`` bytes *before* the stage,
all three algorithms move ``n = (P-1)/P * S`` bytes per NPU on that
dimension for either Reduce-Scatter or All-Gather (bandwidth-optimal), and
the chunk shrinks (RS) or grows (AG) by ``P`` after the stage.  They differ
in the number of serialized steps, which feeds the fixed-latency term
``A_K = steps * step_latency`` of the paper's latency model (Sec. 4.4).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from enum import Enum


class Phase(str, Enum):
    RS = "RS"  # Reduce-Scatter
    AG = "AG"  # All-Gather


class TopoKind(str, Enum):
    RING = "Ring"
    FULLY_CONNECTED = "FullyConnected"
    SWITCH = "Switch"


@dataclass(frozen=True)
class CollectiveAlgorithm:
    """Cost model of the basic collective used on one network dimension."""

    kind: TopoKind

    def steps(self, npus: int, phase: Phase) -> int:
        """Number of serialized network steps for one RS or AG stage."""
        if npus <= 1:
            return 0
        if self.kind == TopoKind.RING:
            return npus - 1
        if self.kind == TopoKind.FULLY_CONNECTED:
            return 1
        # Halving-doubling on a switch.
        return int(math.ceil(math.log2(npus)))

    def bytes_on_wire(self, npus: int, chunk_bytes: float) -> float:
        """Bytes each NPU sends on this dimension for one RS/AG stage.

        ``chunk_bytes`` is the per-NPU resident size *before* the stage
        (paper's chunk-size convention, Sec. 2.3).
        """
        if npus <= 1:
            return 0.0
        return (npus - 1) / npus * chunk_bytes


RING = CollectiveAlgorithm(TopoKind.RING)
DIRECT = CollectiveAlgorithm(TopoKind.FULLY_CONNECTED)
HALVING_DOUBLING = CollectiveAlgorithm(TopoKind.SWITCH)

ALGO_BY_KIND = {
    TopoKind.RING: RING,
    TopoKind.FULLY_CONNECTED: DIRECT,
    TopoKind.SWITCH: HALVING_DOUBLING,
}
