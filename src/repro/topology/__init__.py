from .algorithms import (
    ALGO_BY_KIND,
    DIRECT,
    HALVING_DOUBLING,
    RING,
    CollectiveAlgorithm,
    Phase,
    TopoKind,
)
from .topology import (
    ALL_TOPOLOGIES,
    GBPS,
    NetworkDim,
    Topology,
    make_current_topology,
    make_table2_topologies,
    make_tpu_pod_topology,
)


def __getattr__(name):
    # repro.topology.search imports repro.core (batch scoring), which imports
    # repro.topology — a lazy attribute breaks the would-be cycle while
    # keeping ``from repro.topology import search_topologies`` working.
    _search_names = {
        "CandidateScore", "SearchConfig", "SearchResult",
        "bw_split_topology", "enumerate_bw_shares", "search_topologies",
        "stream_lower_bound",
    }
    if name in _search_names:
        from . import search

        return getattr(search, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "ALGO_BY_KIND",
    "ALL_TOPOLOGIES",
    "GBPS",
    "CandidateScore",
    "CollectiveAlgorithm",
    "DIRECT",
    "HALVING_DOUBLING",
    "NetworkDim",
    "Phase",
    "RING",
    "SearchConfig",
    "SearchResult",
    "TopoKind",
    "Topology",
    "bw_split_topology",
    "enumerate_bw_shares",
    "make_current_topology",
    "make_table2_topologies",
    "make_tpu_pod_topology",
    "search_topologies",
    "stream_lower_bound",
]
