from .algorithms import (
    ALGO_BY_KIND,
    DIRECT,
    HALVING_DOUBLING,
    RING,
    CollectiveAlgorithm,
    Phase,
    TopoKind,
)
from .topology import (
    ALL_TOPOLOGIES,
    GBPS,
    NetworkDim,
    Topology,
    make_current_topology,
    make_table2_topologies,
    make_tpu_pod_topology,
)

__all__ = [
    "ALGO_BY_KIND",
    "ALL_TOPOLOGIES",
    "GBPS",
    "CollectiveAlgorithm",
    "DIRECT",
    "HALVING_DOUBLING",
    "NetworkDim",
    "Phase",
    "RING",
    "TopoKind",
    "Topology",
    "make_current_topology",
    "make_table2_topologies",
    "make_tpu_pod_topology",
]
