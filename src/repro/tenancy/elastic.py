"""SLO-debt elastic tenant weights (the PR-2 slo-aware follow-on).

The base ``slo-aware`` policy boosts a tenant's weight from its
*instantaneous* running-mean slowdown — a memoryless controller that
reacts the moment the mean crosses the SLO and releases the moment it
dips back, so under bursty open-loop load the boost flaps on and off
with every burst.  :class:`SloDebtArbiter` replaces that with a debted
integrator: each finished request deposits its SLO *excess* (observed
slowdown minus the SLO target, clamped at zero) into a sliding horizon,
the accumulated debt sets a boost target, and the applied boost moves
toward the target through an EMA with a relative deadband — hysteresis
and damping, so weights track sustained violation and ignore noise.

The subclass acts only through :meth:`effective_weight` (it runs as
``weighted-fair`` and never overrides ``order_key``), so it stays on the
indexed engine's fast arbiter path and is consulted identically by both
engines — differential bit-identity is preserved by construction.
"""
from __future__ import annotations

from typing import Iterable, Mapping

from repro.tenancy.arbiter import FabricArbiter
from repro.tenancy.tenants import TenantSpec

__all__ = ["SloDebtArbiter"]


class SloDebtArbiter(FabricArbiter):
    """Weighted-fair arbiter whose weights integrate SLO debt.

    Parameters
    ----------
    horizon_s:
        Sliding window over which per-request SLO excess accumulates;
        observations older than ``horizon_s`` (by the arbiter's event
        pseudo-clock) are forgotten.
    gain:
        Boost target is ``1 + gain * debt`` (debt = summed excess
        slowdown inside the horizon), clamped at ``max_boost``.
    alpha:
        EMA damping toward the target per update (1.0 = undamped).
    deadband:
        Relative dead zone: boost updates smaller than
        ``deadband * current`` are dropped — the hysteresis that stops
        weight oscillation under alternating bursts.
    """

    def __init__(
        self,
        specs: Iterable[TenantSpec] = (),
        *,
        horizon_s: float = 50.0,
        gain: float = 1.0,
        max_boost: float = 8.0,
        alpha: float = 0.3,
        deadband: float = 0.05,
        isolated_latency: Mapping[str, float] | None = None,
        preemption: bool = True,
        quantum_chunks: int = 8,
        preempt_penalty_s: float = 0.0,
        vt_clamp: bool = True,
    ):
        if horizon_s <= 0:
            raise ValueError("horizon_s must be > 0")
        if gain < 0 or max_boost < 1:
            raise ValueError("gain must be >= 0 and max_boost >= 1")
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        if deadband < 0:
            raise ValueError("deadband must be >= 0")
        super().__init__(
            "weighted-fair", specs, preemption=preemption,
            quantum_chunks=quantum_chunks,
            isolated_latency=isolated_latency,
            preempt_penalty_s=preempt_penalty_s, vt_clamp=vt_clamp)
        self.horizon_s = horizon_s
        self.gain = gain
        self.max_boost = max_boost
        self.alpha = alpha
        self.deadband = deadband
        # on_group_finish carries no timestamp, so the arbiter keeps a
        # monotone pseudo-clock fed by the timestamped hooks — both
        # engines call them at identical event times, so the clock (and
        # everything derived from it) is engine-independent.
        self._now = 0.0
        # tenant -> {group: (finish pseudo-time, slowdown)}
        self._obs: dict[str, dict[int, tuple[float, float]]] = {}
        self._boost: dict[str, float] = {}

    # -- timestamped hooks feed the pseudo-clock -----------------------------
    def on_enqueued(self, dim: int, tenant: str, now: float) -> None:
        if now > self._now:
            self._now = now
        super().on_enqueued(dim, tenant, now)
        self._update_boost(tenant)

    def on_served(self, dim: int, batch, now: float) -> None:
        if now > self._now:
            self._now = now
        super().on_served(dim, batch, now)

    def on_group_finish(self, group: int, tenant: str,
                        latency: float) -> None:
        super().on_group_finish(group, tenant, latency)
        iso = self.isolated_latency.get(tenant)
        slo = self.spec(tenant).slo_slowdown
        if not iso or slo is None:
            return
        self._obs.setdefault(tenant, {})[group] = (self._now,
                                                   latency / iso)
        self._update_boost(tenant)

    # -- the debted integrator ----------------------------------------------
    def debt(self, tenant: str) -> float:
        """Summed SLO excess inside the horizon (0.0 = meeting SLO)."""
        slo = self.spec(tenant).slo_slowdown
        obs = self._obs.get(tenant)
        if slo is None or not obs:
            return 0.0
        cutoff = self._now - self.horizon_s
        return sum(max(0.0, sd - slo) for t, sd in obs.values()
                   if t >= cutoff)

    def boost(self, tenant: str) -> float:
        """The damped boost currently applied to ``tenant``'s weight."""
        return self._boost.get(tenant, 1.0)

    def _update_boost(self, tenant: str) -> None:
        if self.spec(tenant).slo_slowdown is None:
            return
        obs = self._obs.get(tenant)
        if obs:
            cutoff = self._now - self.horizon_s
            stale = [g for g, (t, _) in obs.items() if t < cutoff]
            for g in stale:
                del obs[g]
        target = min(1.0 + self.gain * self.debt(tenant), self.max_boost)
        cur = self._boost.get(tenant, 1.0)
        new = cur + self.alpha * (target - cur)
        if abs(new - cur) < self.deadband * cur:
            return
        self._boost[tenant] = new

    def effective_weight(self, tenant: str) -> float:
        return (max(self.spec(tenant).weight, 1e-12)
                * self._boost.get(tenant, 1.0))

    def discipline_state(self) -> dict:
        state = super().discipline_state()
        state["discipline"] = "slo-debt"
        state["boosts"] = dict(sorted(self._boost.items()))
        state["horizon_s"] = self.horizon_s
        return state
