"""Tenants: workloads sharing one multi-dimensional fabric.

A :class:`TenantSpec` describes a tenant's share contract — scheduling
weight, optional strict priority, an SLO expressed as the maximum
acceptable slowdown versus running alone, and its arrival offset on the
shared fabric.  A :class:`TenantJob` binds a spec to a workload and emits
its traffic in either representation:

  * :meth:`TenantJob.requests` — the fixed-time backprop bucket stream
    (``dp_bucket_requests``) over many iterations, as tenant-tagged
    :class:`~repro.core.requests.CollectiveRequest`s (open-loop: iteration
    starts are clocked by a fixed period regardless of contention);
  * :meth:`TenantJob.traffic` — a dependency-gated
    :class:`~repro.traffic.TrafficGraph` (closed-loop training by default;
    any graph via ``traffic_builder`` — e.g. a *serving* prefill/decode
    tenant, which has no training workload at all), namespaced and tagged
    with the tenant's name so :func:`tenant_traffic` can merge many
    tenants onto one fabric under the existing arbiters.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import TYPE_CHECKING, Callable, Iterable

from repro.core.requests import CollectiveRequest
from repro.core.workloads import Workload, dp_bucket_requests

if TYPE_CHECKING:  # pragma: no cover - import cycle guard for type hints
    from repro.traffic.ir import TrafficGraph


@dataclass(frozen=True)
class TenantSpec:
    """Share contract of one tenant on the shared fabric.

    ``weight``        — weighted-fair share (bytes-weighted max-min).
    ``priority``      — strict-priority rank (higher preempts lower).
    ``slo_slowdown``  — max acceptable slowdown vs. running alone
                        (None: best-effort, no SLO).
    ``arrival_offset_s`` — when the tenant's first iteration starts.
    ``iterations``    — how many training iterations to emit.
    ``n_buckets``     — gradient buckets per iteration.
    """

    name: str
    weight: float = 1.0
    priority: int = 0
    slo_slowdown: float | None = None
    arrival_offset_s: float = 0.0
    iterations: int = 1
    n_buckets: int = 8

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError("weight must be > 0")
        if self.slo_slowdown is not None and self.slo_slowdown < 1.0:
            raise ValueError("slo_slowdown is a slowdown factor; must be >= 1")
        if self.iterations < 1:
            raise ValueError("iterations must be >= 1")
        if self.n_buckets < 1:
            raise ValueError("n_buckets must be >= 1")


@dataclass
class TenantJob:
    """A tenant running a workload on the shared fabric.

    With a training ``workload``, :meth:`requests` emits the gradient
    bucket stream per iteration, tagged with the tenant's name: iteration
    *i*'s backward pass starts at
    ``arrival_offset + i * period + compute_fwd``; its buckets issue
    progressively through the backward pass exactly as in the single-job
    overlap engine.  ``iteration_gap_s`` overrides the period between
    iteration starts (default: the workload's full compute time —
    communication-bound tenants then overlap their own iterations too).

    ``traffic_builder`` makes the tenant's traffic an arbitrary
    dependency-gated graph instead (see :meth:`traffic`) — serving tenants
    pass e.g. ``lambda job: serving_traffic(...)`` and need no training
    workload.
    """

    spec: TenantSpec
    workload: Workload | None = None
    iteration_gap_s: float | None = None
    traffic_builder: Callable[["TenantJob"], "TrafficGraph"] | None = None

    def _require_workload(self) -> Workload:
        if self.workload is None:
            raise ValueError(
                f"tenant {self.spec.name!r} has no training workload; "
                "give it one or use traffic() with a traffic_builder")
        return self.workload

    @property
    def period_s(self) -> float:
        if self.iteration_gap_s is not None:
            return self.iteration_gap_s
        return self._require_workload().compute_s

    def requests(self) -> list[CollectiveRequest]:
        out: list[CollectiveRequest] = []
        base = dp_bucket_requests(self._require_workload(),
                                  self.spec.n_buckets)
        for it in range(self.spec.iterations):
            t0 = (self.spec.arrival_offset_s + it * self.period_s
                  + self.workload.compute_fwd_s)
            for r in base:
                out.append(replace(
                    r,
                    issue_time=t0 + r.issue_time,
                    priority=self.spec.priority,
                    tenant=self.spec.name,
                    stream=f"{self.spec.name}/it{it}/{r.stream}",
                ))
        return out

    def traffic(self) -> "TrafficGraph":
        """The tenant's dependency-gated traffic graph.

        ``traffic_builder(self)`` when given, else the closed-loop
        :func:`~repro.traffic.training_traffic` re-expression of this
        tenant's training stream (``iteration_gap_s`` becomes the
        iteration-start floor).  Either way the graph is namespaced under
        the tenant's name, its requests tagged/prioritized per the spec,
        and shifted by the arrival offset — ready to merge with other
        tenants via :func:`tenant_traffic`.
        """
        from repro.traffic.builders import training_traffic
        from repro.traffic.ir import retag

        if self.traffic_builder is not None:
            g = self.traffic_builder(self)
        else:
            g = training_traffic(
                self._require_workload(), n_buckets=self.spec.n_buckets,
                iterations=self.spec.iterations,
                min_period_s=self.iteration_gap_s)
        s = self.spec
        return retag(g, name_prefix=f"{s.name}/", tenant=s.name,
                     stream_prefix=f"{s.name}/", priority=s.priority,
                     start_offset_s=s.arrival_offset_s)


def tenant_traffic(jobs: Iterable[TenantJob]) -> "TrafficGraph":
    """Merge every tenant's traffic graph into one fabric-wide graph —
    training and serving tenants mix freely; run it with
    ``repro.traffic.simulate_traffic(..., arbiter=FabricArbiter(...))``."""
    from repro.traffic.ir import merge_graphs

    return merge_graphs(*(job.traffic() for job in jobs))


def synthetic_requests(
    name: str,
    collective: str,
    size_bytes: float,
    count: int,
    gap_s: float = 0.0,
    start_s: float = 0.0,
    priority: int = 0,
) -> list[CollectiveRequest]:
    """A synthetic tenant stream: ``count`` equal collectives, ``gap_s``
    apart, starting at ``start_s`` — handy for arbiter tests and studies
    that do not need a full workload model."""
    return [
        CollectiveRequest(collective, size_bytes,
                          issue_time=start_s + i * gap_s,
                          priority=priority, stream=name, tenant=name)
        for i in range(count)
    ]
