"""Tenants: workloads sharing one multi-dimensional fabric.

A :class:`TenantSpec` describes a tenant's share contract — scheduling
weight, optional strict priority, an SLO expressed as the maximum
acceptable slowdown versus running alone, and its arrival offset on the
shared fabric.  A :class:`TenantJob` binds a spec to a training
:class:`~repro.core.workloads.Workload` and emits that workload's backprop
bucket stream (``dp_bucket_requests``) over many iterations as
tenant-tagged :class:`~repro.core.requests.CollectiveRequest`s, which the
fabric layer (:mod:`repro.tenancy.fabric`) schedules and simulates jointly.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

from repro.core.requests import CollectiveRequest
from repro.core.workloads import Workload, dp_bucket_requests


@dataclass(frozen=True)
class TenantSpec:
    """Share contract of one tenant on the shared fabric.

    ``weight``        — weighted-fair share (bytes-weighted max-min).
    ``priority``      — strict-priority rank (higher preempts lower).
    ``slo_slowdown``  — max acceptable slowdown vs. running alone
                        (None: best-effort, no SLO).
    ``arrival_offset_s`` — when the tenant's first iteration starts.
    ``iterations``    — how many training iterations to emit.
    ``n_buckets``     — gradient buckets per iteration.
    """

    name: str
    weight: float = 1.0
    priority: int = 0
    slo_slowdown: float | None = None
    arrival_offset_s: float = 0.0
    iterations: int = 1
    n_buckets: int = 8

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError("weight must be > 0")
        if self.slo_slowdown is not None and self.slo_slowdown < 1.0:
            raise ValueError("slo_slowdown is a slowdown factor; must be >= 1")
        if self.iterations < 1:
            raise ValueError("iterations must be >= 1")
        if self.n_buckets < 1:
            raise ValueError("n_buckets must be >= 1")


@dataclass
class TenantJob:
    """A tenant running a training workload: emits the workload's gradient
    bucket stream per iteration, tagged with the tenant's name.

    Iteration *i*'s backward pass starts at
    ``arrival_offset + i * period + compute_fwd``; its buckets issue
    progressively through the backward pass exactly as in the single-job
    overlap engine.  ``iteration_gap_s`` overrides the period between
    iteration starts (default: the workload's full compute time —
    communication-bound tenants then overlap their own iterations too).
    """

    spec: TenantSpec
    workload: Workload
    iteration_gap_s: float | None = None

    @property
    def period_s(self) -> float:
        if self.iteration_gap_s is not None:
            return self.iteration_gap_s
        return self.workload.compute_s

    def requests(self) -> list[CollectiveRequest]:
        out: list[CollectiveRequest] = []
        base = dp_bucket_requests(self.workload, self.spec.n_buckets)
        for it in range(self.spec.iterations):
            t0 = (self.spec.arrival_offset_s + it * self.period_s
                  + self.workload.compute_fwd_s)
            for r in base:
                out.append(replace(
                    r,
                    issue_time=t0 + r.issue_time,
                    priority=self.spec.priority,
                    tenant=self.spec.name,
                    stream=f"{self.spec.name}/it{it}/{r.stream}",
                ))
        return out


def synthetic_requests(
    name: str,
    collective: str,
    size_bytes: float,
    count: int,
    gap_s: float = 0.0,
    start_s: float = 0.0,
    priority: int = 0,
) -> list[CollectiveRequest]:
    """A synthetic tenant stream: ``count`` equal collectives, ``gap_s``
    apart, starting at ``start_s`` — handy for arbiter tests and studies
    that do not need a full workload model."""
    return [
        CollectiveRequest(collective, size_bytes,
                          issue_time=start_s + i * gap_s,
                          priority=priority, stream=name, tenant=name)
        for i in range(count)
    ]
