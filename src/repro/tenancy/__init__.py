"""Multi-tenant fabric scheduling (beyond paper).

Themis (Sec. 4.4) balances dimension loads *within* one job's collectives;
this package grows the arrival-time-aware engine into a shared-fabric
multi-tenant subsystem: tenants wrap workload request streams with share
contracts (weight / priority / SLO), a :class:`FabricArbiter` arbitrates
per-dimension service between tenants (fifo, strict-priority,
weighted-fair, slo-aware) with chunk-granularity preemption, and the
cross-tenant Themis mode shares one fabric-wide Dim Load Tracker so every
tenant's chunk orders steer around the other tenants' residual loads.
"""
from repro.tenancy.arbiter import ARBITER_POLICIES, FabricArbiter
from repro.tenancy.elastic import SloDebtArbiter
from repro.tenancy.fabric import (
    isolated_latencies,
    schedule_tenant_requests,
    simulate_fabric,
)
from repro.tenancy.metrics import (
    TenantReport,
    fairness_index,
    jain_index,
    mean_slowdown,
    slo_violations,
    tenant_reports,
)
from repro.tenancy.tenants import (
    TenantJob,
    TenantSpec,
    synthetic_requests,
    tenant_traffic,
)

__all__ = [
    "ARBITER_POLICIES",
    "FabricArbiter",
    "SloDebtArbiter",
    "TenantJob",
    "TenantReport",
    "TenantSpec",
    "fairness_index",
    "isolated_latencies",
    "jain_index",
    "mean_slowdown",
    "schedule_tenant_requests",
    "simulate_fabric",
    "slo_violations",
    "synthetic_requests",
    "tenant_reports",
    "tenant_traffic",
]
