"""Fabric arbiter: inter-tenant queue disciplines with preemptive service.

The arbiter is the pluggable per-dimension discipline the simulator
(:func:`repro.core.simulator.simulate`) consults when multiple tenants'
chunk stages are ready on one network dimension:

  * ``fifo``            — tenant-blind arrival order (the do-nothing
                          baseline every shared fabric starts from).
  * ``strict-priority`` — higher :attr:`TenantSpec.priority` always first;
                          preempts in-flight lower-priority service.
  * ``weighted-fair``   — bytes-weighted max-min per dimension, deficit-
                          counter style: each (dim, tenant) pair accrues
                          virtual time ``bytes / weight`` as its chunks are
                          served, and the tenant with the smallest virtual
                          time is served next, so over any backlogged
                          interval tenants receive bandwidth proportional
                          to their weights.
  * ``slo-aware``       — weighted-fair whose effective weight is boosted
                          by ``observed_slowdown / slo`` once a tenant's
                          running slowdown (vs. its isolated latency)
                          exceeds its SLO target.

Preemption: when a tenant whose virtual time trails the in-flight tenant's
(or whose strict priority exceeds it) becomes ready, the simulator splits
the in-flight multi-chunk service at chunk granularity — chunks whose data
has not started draining return to the queue (``on_preempted`` refunds
their bytes), so a small latency-sensitive tenant never waits behind a
1 GB collective's full service.
"""
from __future__ import annotations

from typing import Iterable, Mapping

from repro.tenancy.tenants import TenantSpec

ARBITER_POLICIES = ("fifo", "strict-priority", "weighted-fair", "slo-aware")


class FabricArbiter:
    """Per-dim inter-tenant discipline + preemption policy.

    Duck-typed against the simulator's hooks: ``order_key``,
    ``should_preempt``, ``on_served``, ``on_preempted``,
    ``on_group_finish``, plus the ``preemption`` / ``quantum_chunks``
    attributes.

    ``isolated_latency`` maps tenant -> mean isolated request latency
    (seconds), the reference the slo-aware policy measures slowdown
    against; tenants absent from the map are treated as meeting their SLO.

    ``preempt_penalty_s`` is the re-arm latency a preemption charges: the
    chunks cut from an in-flight service only become ready again that many
    seconds after the split (modeling the cost of tearing down and
    re-issuing the collective).  0.0 — the default, for backward
    compatibility — keeps splits free.
    """

    def __init__(
        self,
        policy: str,
        specs: Iterable[TenantSpec] = (),
        *,
        preemption: bool = True,
        quantum_chunks: int = 8,
        isolated_latency: Mapping[str, float] | None = None,
        preempt_penalty_s: float = 0.0,
    ):
        if policy not in ARBITER_POLICIES:
            raise ValueError(
                f"unknown arbiter policy {policy!r}; want {ARBITER_POLICIES}")
        if quantum_chunks < 1:
            raise ValueError("quantum_chunks must be >= 1")
        if preempt_penalty_s < 0:
            raise ValueError("preempt_penalty_s must be >= 0")
        self.policy = policy
        self.specs: dict[str, TenantSpec] = {s.name: s for s in specs}
        # FIFO never reorders, so preempting would be pure overhead.
        self.preemption = preemption and policy != "fifo"
        self.quantum_chunks = quantum_chunks
        self.preempt_penalty_s = preempt_penalty_s
        self.isolated_latency = dict(isolated_latency or {})
        self._served: dict[tuple[int, str], float] = {}  # (dim, tenant) -> bytes
        # Virtual time accrues *at service time* (bytes / weight-then), so a
        # later slo-aware weight boost rescales only future service, not the
        # tenant's whole served history.
        self._vt: dict[tuple[int, str], float] = {}
        self._inflight_inc: dict[int, dict] = {}  # dim -> {op_id: vt inc}
        self._latency: dict[str, dict[int, float]] = {}  # tenant -> {group: s}
        self._lat_sum: dict[str, float] = {}  # running sum of _latency values
        self._preempt_count = 0

    # -- tenant lookups ------------------------------------------------------
    def spec(self, tenant: str) -> TenantSpec:
        # order_key runs in the simulator hot loop: cache default specs for
        # unregistered tenants instead of allocating one per lookup
        got = self.specs.get(tenant)
        if got is None:
            got = self.specs[tenant] = TenantSpec(tenant)
        return got

    def effective_weight(self, tenant: str) -> float:
        w = max(self.spec(tenant).weight, 1e-12)
        if self.policy == "slo-aware":
            w *= self.slo_boost(tenant)
        return w

    def observed_slowdown(self, tenant: str) -> float | None:
        """Running mean request latency over the isolated reference."""
        iso = self.isolated_latency.get(tenant)
        lats = self._latency.get(tenant)
        if not iso or not lats:
            return None
        return (self._lat_sum[tenant] / len(lats)) / iso

    def slo_boost(self, tenant: str) -> float:
        slo = self.spec(tenant).slo_slowdown
        slowdown = self.observed_slowdown(tenant)
        if slo is None or slowdown is None:
            return 1.0
        return max(1.0, slowdown / slo)

    def virtual_time(self, dim: int, tenant: str) -> float:
        return self._vt.get((dim, tenant), 0.0)

    # -- simulator hooks -----------------------------------------------------
    def order_key(self, task, dim: int, now: float):
        if self.policy == "fifo":
            return (task.arrival_seq,)
        if self.policy == "strict-priority":
            return (-self.spec(task.tenant).priority, task.arrival_seq)
        # weighted-fair / slo-aware: smallest virtual time first; SCF-style
        # size tiebreak within a tenant keeps short chunks from idling.
        return (self.virtual_time(dim, task.tenant),
                task.wire_bytes, task.arrival_seq)

    def should_preempt(self, dim: int, running, candidate, now: float) -> bool:
        if self.policy == "fifo" or running.tenant == candidate.tenant:
            return False
        if self.policy == "strict-priority":
            return (self.spec(candidate.tenant).priority
                    > self.spec(running.tenant).priority)
        # Fair policies: preempt only if the candidate tenant would *still*
        # trail the running tenant after receiving one chunk of service —
        # the one-chunk hysteresis stops equal-share tenants thrashing.
        vt_cand = (self.virtual_time(dim, candidate.tenant)
                   + candidate.wire_bytes / self.effective_weight(candidate.tenant))
        return vt_cand < self.virtual_time(dim, running.tenant)

    def on_served(self, dim: int, batch, now: float) -> None:
        incs = self._inflight_inc[dim] = {}
        for t in batch:
            key = (dim, t.tenant)
            self._served[key] = self._served.get(key, 0.0) + t.wire_bytes
            inc = t.wire_bytes / self.effective_weight(t.tenant)
            self._vt[key] = self._vt.get(key, 0.0) + inc
            incs[t.op_id] = inc

    def on_preempted(self, dim: int, cut, now: float) -> None:
        # Refund exactly the virtual time charged when the service started
        # (the weight may have changed since; the charge must round-trip).
        self._preempt_count += 1
        incs = self._inflight_inc.get(dim, {})
        for t in cut:
            key = (dim, t.tenant)
            self._served[key] -= t.wire_bytes
            self._vt[key] -= incs.pop(t.op_id, 0.0)

    def on_group_finish(self, group: int, tenant: str, latency: float) -> None:
        # Chunk chains of one request retire progressively; keeping the
        # latest observation per group converges to the request's latency.
        lats = self._latency.setdefault(tenant, {})
        self._lat_sum[tenant] = (self._lat_sum.get(tenant, 0.0)
                                 + latency - lats.get(group, 0.0))
        lats[group] = latency

    # -- reporting -----------------------------------------------------------
    @property
    def preempt_count(self) -> int:
        return self._preempt_count

    def served_bytes(self, tenant: str) -> float:
        return sum(v for (d, t), v in self._served.items() if t == tenant)
