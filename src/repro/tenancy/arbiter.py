"""Fabric arbiter: inter-tenant queue disciplines with preemptive service.

The arbiter is the pluggable per-dimension discipline the simulator
(:func:`repro.core.simulator.simulate`) consults when multiple tenants'
chunk stages are ready on one network dimension:

  * ``fifo``            — tenant-blind arrival order (the do-nothing
                          baseline every shared fabric starts from).
  * ``strict-priority`` — higher :attr:`TenantSpec.priority` always first;
                          preempts in-flight lower-priority service.
  * ``weighted-fair``   — bytes-weighted max-min per dimension, deficit-
                          counter style: each (dim, tenant) pair accrues
                          virtual time ``bytes / weight`` as its chunks are
                          served, and the tenant with the smallest virtual
                          time is served next, so over any backlogged
                          interval tenants receive bandwidth proportional
                          to their weights.
  * ``slo-aware``       — weighted-fair whose effective weight is boosted
                          by ``observed_slowdown / slo`` once a tenant's
                          running slowdown (vs. its isolated latency)
                          exceeds its SLO target.

Preemption: when a tenant whose virtual time trails the in-flight tenant's
(or whose strict priority exceeds it) becomes ready, the simulator splits
the in-flight multi-chunk service at chunk granularity — chunks whose data
has not started draining return to the queue (``on_preempted`` refunds
their bytes), so a small latency-sensitive tenant never waits behind a
1 GB collective's full service.

Virtual-time staleness: a (dim, tenant) virtual time only advances while
the tenant is served, so a tenant that goes idle keeps a *stale* clock —
far behind tenants that kept consuming (it then monopolizes the dim on
re-arrival to "catch up" on service it never queued for), or far ahead of
a newcomer starting at 0 (it is then starved until the newcomer catches
up).  The fix is the start-time-fair-queuing clamp (``vt_clamp``, default
on): each dim tracks a virtual-time *floor* — the start tag of its most
recent service — and an arriving task raises its tenant's virtual time to
that floor (``on_enqueued``).  For continuously backlogged tenants the
clamp is a no-op (a backlogged tenant's clock is never behind the start
tag of a service that beat it), so only idle→busy transitions are
affected.  ``repro.verify`` proves the bounded-slowdown property with the
clamp on and extracts the monopolization counterexample with it off.
"""
from __future__ import annotations

from typing import Iterable, Mapping

from repro.tenancy.tenants import TenantSpec

ARBITER_POLICIES = ("fifo", "strict-priority", "weighted-fair", "slo-aware")


class FabricArbiter:
    """Per-dim inter-tenant discipline + preemption policy.

    Duck-typed against the simulator's hooks: ``order_key``,
    ``should_preempt``, ``on_served``, ``on_preempted``,
    ``on_group_finish``, plus the ``preemption`` / ``quantum_chunks``
    attributes.

    ``isolated_latency`` maps tenant -> mean isolated request latency
    (seconds), the reference the slo-aware policy measures slowdown
    against; tenants absent from the map are treated as meeting their SLO.

    ``preempt_penalty_s`` is the re-arm latency a preemption charges: the
    chunks cut from an in-flight service only become ready again that many
    seconds after the split (modeling the cost of tearing down and
    re-issuing the collective).  0.0 — the default, for backward
    compatibility — keeps splits free.

    ``vt_clamp`` enables the fair-policy virtual-time floor clamp (see the
    module docstring); turn it off only to reproduce the pre-fix staleness
    behavior (the ``repro.verify`` counterexamples pin it).
    """

    def __init__(
        self,
        policy: str,
        specs: Iterable[TenantSpec] = (),
        *,
        preemption: bool = True,
        quantum_chunks: int = 8,
        isolated_latency: Mapping[str, float] | None = None,
        preempt_penalty_s: float = 0.0,
        vt_clamp: bool = True,
    ):
        if policy not in ARBITER_POLICIES:
            raise ValueError(
                f"unknown arbiter policy {policy!r}; want {ARBITER_POLICIES}")
        if quantum_chunks < 1:
            raise ValueError("quantum_chunks must be >= 1")
        if preempt_penalty_s < 0:
            raise ValueError("preempt_penalty_s must be >= 0")
        self.policy = policy
        self.specs: dict[str, TenantSpec] = {s.name: s for s in specs}
        # FIFO never reorders, so preempting would be pure overhead.
        self.preemption = preemption and policy != "fifo"
        self.quantum_chunks = quantum_chunks
        self.preempt_penalty_s = preempt_penalty_s
        self.vt_clamp = vt_clamp
        self.isolated_latency = dict(isolated_latency or {})
        self._served: dict[tuple[int, str], float] = {}  # (dim, tenant) -> bytes
        # Virtual time accrues *at service time* (bytes / weight-then), so a
        # later slo-aware weight boost rescales only future service, not the
        # tenant's whole served history.
        self._vt: dict[tuple[int, str], float] = {}
        # Per-dim virtual-time floor: the start tag (pre-increment virtual
        # time) of the dim's most recent service — the SFQ v(t) an arriving
        # tenant's clock is clamped up to (see module docstring).
        self._vt_floor: dict[int, float] = {}
        self._inflight_inc: dict[int, dict] = {}  # dim -> {op_id: vt inc}
        self._latency: dict[str, dict[int, float]] = {}  # tenant -> {group: s}
        self._lat_sum: dict[str, float] = {}  # running sum of _latency values
        self._preempt_count = 0

    # -- tenant lookups ------------------------------------------------------
    def spec(self, tenant: str) -> TenantSpec:
        # order_key runs in the simulator hot loop: cache default specs for
        # unregistered tenants instead of allocating one per lookup
        got = self.specs.get(tenant)
        if got is None:
            got = self.specs[tenant] = TenantSpec(tenant)
        return got

    def effective_weight(self, tenant: str) -> float:
        w = max(self.spec(tenant).weight, 1e-12)
        if self.policy == "slo-aware":
            w *= self.slo_boost(tenant)
        return w

    def observed_slowdown(self, tenant: str) -> float | None:
        """Running mean request latency over the isolated reference."""
        iso = self.isolated_latency.get(tenant)
        lats = self._latency.get(tenant)
        if not iso or not lats:
            return None
        return (self._lat_sum[tenant] / len(lats)) / iso

    def slo_boost(self, tenant: str) -> float:
        slo = self.spec(tenant).slo_slowdown
        slowdown = self.observed_slowdown(tenant)
        if slo is None or slowdown is None:
            return 1.0
        return max(1.0, slowdown / slo)

    def virtual_time(self, dim: int, tenant: str) -> float:
        return self._vt.get((dim, tenant), 0.0)

    def vt_floor(self, dim: int) -> float:
        """The dim's SFQ virtual clock: start tag of its latest service."""
        return self._vt_floor.get(dim, 0.0)

    # -- simulator hooks -----------------------------------------------------
    def on_enqueued(self, dim: int, tenant: str, now: float) -> None:
        """A task of ``tenant`` joined ``dim``'s ready queue.

        Fair policies clamp the tenant's virtual time up to the dim's floor
        so an idle period neither banks catch-up credit (stale-low clock →
        monopolization) nor penalizes the tenant against newcomers
        (stale-high clock → starvation).  No-op for continuously backlogged
        tenants — their clock is never below the floor (the simulator
        always serves the minimum clock, so a backlogged tenant's clock is
        at least the start tag of any service that beat it).
        """
        if not self.vt_clamp or self.policy in ("fifo", "strict-priority"):
            return
        floor = self._vt_floor.get(dim)
        if floor is None:
            return
        key = (dim, tenant)
        if self._vt.get(key, 0.0) < floor:
            self._vt[key] = floor
    def order_key(self, task, dim: int, now: float):
        if self.policy == "fifo":
            return (task.arrival_seq,)
        if self.policy == "strict-priority":
            return (-self.spec(task.tenant).priority, task.arrival_seq)
        # weighted-fair / slo-aware: smallest virtual time first; SCF-style
        # size tiebreak within a tenant keeps short chunks from idling.
        return (self.virtual_time(dim, task.tenant),
                task.wire_bytes, task.arrival_seq)

    def should_preempt(self, dim: int, running, candidate, now: float) -> bool:
        if self.policy == "fifo" or running.tenant == candidate.tenant:
            return False
        if self.policy == "strict-priority":
            return (self.spec(candidate.tenant).priority
                    > self.spec(running.tenant).priority)
        # Fair policies: preempt only if the candidate tenant would *still*
        # trail the running tenant after receiving one chunk of service —
        # the one-chunk hysteresis stops equal-share tenants thrashing.
        vt_cand = (self.virtual_time(dim, candidate.tenant)
                   + candidate.wire_bytes / self.effective_weight(candidate.tenant))
        return vt_cand < self.virtual_time(dim, running.tenant)

    def on_served(self, dim: int, batch, now: float) -> None:
        # Advance the dim's virtual clock to this service's start tag (the
        # served tenant's pre-increment virtual time) — monotone, because
        # the simulator always serves the minimum clock and clamps only
        # raise clocks toward the floor.
        self._vt_floor[dim] = self._vt.get((dim, batch[0].tenant), 0.0)
        incs = self._inflight_inc[dim] = {}
        for t in batch:
            key = (dim, t.tenant)
            self._served[key] = self._served.get(key, 0.0) + t.wire_bytes
            inc = t.wire_bytes / self.effective_weight(t.tenant)
            self._vt[key] = self._vt.get(key, 0.0) + inc
            incs[t.op_id] = inc

    def on_preempted(self, dim: int, cut, now: float) -> None:
        # Refund exactly the virtual time charged when the service started
        # (the weight may have changed since; the charge must round-trip).
        self._preempt_count += 1
        incs = self._inflight_inc.get(dim, {})
        for t in cut:
            key = (dim, t.tenant)
            self._served[key] -= t.wire_bytes
            self._vt[key] -= incs.pop(t.op_id, 0.0)

    def on_group_finish(self, group: int, tenant: str, latency: float) -> None:
        # Chunk chains of one request retire progressively; keeping the
        # latest observation per group converges to the request's latency.
        lats = self._latency.setdefault(tenant, {})
        self._lat_sum[tenant] = (self._lat_sum.get(tenant, 0.0)
                                 + latency - lats.get(group, 0.0))
        lats[group] = latency

    # -- reporting / introspection -------------------------------------------
    @property
    def preempt_count(self) -> int:
        return self._preempt_count

    def served_bytes(self, tenant: str) -> float:
        return sum(v for (d, t), v in self._served.items() if t == tenant)

    def served_snapshot(self) -> dict[tuple[int, str], float]:
        """Copy of the per-(dim, tenant) served-bytes ledger.  The runtime
        invariant sanitizer (``simulate(check_invariants=True)``) snapshots
        this at simulation start and checks the per-dim served delta against
        the engine's wire-byte accounting at the end."""
        return dict(self._served)

    def discipline_state(self) -> dict:
        """Structured snapshot of the discipline's internal state — what the
        SMT encoder (``repro.verify.encode``) mirrors and the sanitizer
        cross-checks.  Keys are JSON-friendly (tuple keys stringified)."""
        return {
            "policy": self.policy,
            "preemption": self.preemption,
            "quantum_chunks": self.quantum_chunks,
            "preempt_penalty_s": self.preempt_penalty_s,
            "vt_clamp": self.vt_clamp,
            "virtual_time": {f"{d}/{t}": v
                             for (d, t), v in sorted(self._vt.items())},
            "vt_floor": dict(sorted(self._vt_floor.items())),
            "served_bytes": {f"{d}/{t}": v
                             for (d, t), v in sorted(self._served.items())},
            "preempt_count": self._preempt_count,
        }
