"""Shared-fabric entry points: cross-tenant Themis scheduling + joint
simulation under an inter-tenant arbiter.

Two load-tracking modes for the Themis chunk scheduler:

  * **shared tracker** (default, the cross-tenant Themis) — every tenant's
    :class:`~repro.core.scheduler.ThemisScheduler` shares one fabric-wide
    :class:`~repro.core.load_tracker.DimLoadTracker`, so a tenant's chunk
    orders steer around the residual loads *other tenants* have placed on
    each dimension;
  * **per-tenant trackers** (the ablation) — each tenant schedules against
    only its own load view, blind to the rest of the fabric.
"""
from __future__ import annotations

from repro.core.chunking import Chunk
from repro.core.latency_model import LatencyModel
from repro.core.load_tracker import DimLoadTracker
from repro.core.requests import CollectiveRequest
from repro.core.scheduler import ThemisScheduler
from repro.core.simulator import SimResult, simulate
from repro.topology import Topology


def schedule_tenant_requests(
    topology: Topology,
    requests: list[CollectiveRequest],
    *,
    policy: str = "themis",
    shared_tracker: bool = True,
    chunks_per_collective: int = 64,
    water_filling: bool = False,
) -> list[list[Chunk]]:
    """Schedule a multi-tenant request stream in global issue order.

    Each tenant gets its own ``ThemisScheduler``; with ``shared_tracker``
    they all observe (and charge) one fabric-wide Dim Load Tracker, so the
    tracker's clock advances monotonically through the merged stream and a
    request sees every tenant's in-flight residual load.  Without it, each
    tenant's tracker only ever sees that tenant's own requests.
    """
    lm = LatencyModel.for_topology(topology)
    shared = DimLoadTracker(lm) if shared_tracker else None
    schedulers: dict[str, ThemisScheduler] = {}
    groups: list[list[Chunk]] = [[] for _ in requests]
    order = sorted(range(len(requests)),
                   key=lambda i: (requests[i].issue_time, i))
    for i in order:
        r = requests[i]
        sched = schedulers.get(r.tenant)
        if sched is None:
            sched = ThemisScheduler(lm, policy, tracker=shared)
            schedulers[r.tenant] = sched
        groups[i] = sched.schedule_request(
            r, chunks_per_collective, water_filling=water_filling)
    return groups


def simulate_fabric(
    topology: Topology,
    requests: list[CollectiveRequest],
    *,
    policy: str = "themis",
    shared_tracker: bool = True,
    arbiter=None,
    chunks_per_collective: int = 64,
    intra: str = "SCF",
    fusion: bool = True,
    water_filling: bool = False,
    engine: str = "indexed",
    check_invariants: bool = False,
    tracer=None,
    faults=None,
    replan: bool = False,
) -> tuple[SimResult, list[list[Chunk]]]:
    """Schedule and simulate a multi-tenant stream on one shared fabric.

    ``arbiter`` (a :class:`~repro.tenancy.arbiter.FabricArbiter`) supplies
    the inter-tenant per-dim discipline and preemption; ``None`` falls back
    to the single-job ``intra`` discipline, i.e. tenants share dims but no
    policy arbitrates between them.  Its ``preempt_penalty_s`` sets the
    re-arm latency preempted chunks pay before requeueing.  ``engine``
    selects the simulator engine (see :func:`repro.core.simulator.simulate`);
    ``"compiled"`` is bit-identical on arbiter-free streams and falls back
    to indexed (documented signal) when an arbiter or tracer is armed.
    ``tracer`` arms the flight recorder (:class:`repro.obs.Tracer`) on the
    joint simulation — tenant lanes in the exported trace come from the
    request tags.  ``faults`` (a :class:`repro.faults.FaultSchedule`)
    injects a fault timeline; ``replan=True`` additionally arms Themis
    graceful degradation.
    """
    if replan and faults is None:
        raise ValueError("replan=True requires faults")
    replanner = None
    if replan:
        from repro.faults.replan import make_replanner

        replanner = make_replanner(topology, policy)
    groups = schedule_tenant_requests(
        topology, requests, policy=policy, shared_tracker=shared_tracker,
        chunks_per_collective=chunks_per_collective,
        water_filling=water_filling)
    res = simulate(
        topology,
        groups,
        issue_times=[r.issue_time for r in requests],
        priorities=[r.priority for r in requests],
        intra=intra,
        fusion=fusion,
        tenants=[r.tenant for r in requests],
        streams=[r.stream for r in requests],
        arbiter=arbiter,
        engine=engine,
        check_invariants=check_invariants,
        tracer=tracer,
        faults=faults,
        replanner=replanner,
    )
    return res, groups


def isolated_latencies(
    topology: Topology,
    requests: list[CollectiveRequest],
    *,
    policy: str = "themis",
    chunks_per_collective: int = 64,
    intra: str = "SCF",
    fusion: bool = True,
) -> dict[str, list[float]]:
    """Per-tenant isolated reference: each tenant's stream simulated alone
    on the full fabric (same arrival pattern, no contention).  Returns
    tenant -> per-request issue-to-finish latencies in that tenant's
    request order — the denominator of every slowdown/SLO metric.
    """
    by_tenant: dict[str, list[CollectiveRequest]] = {}
    for r in requests:
        by_tenant.setdefault(r.tenant, []).append(r)
    out: dict[str, list[float]] = {}
    for tenant, reqs in by_tenant.items():
        res, _ = simulate_fabric(
            topology, reqs, policy=policy, shared_tracker=True,
            chunks_per_collective=chunks_per_collective, intra=intra,
            fusion=fusion)
        out[tenant] = [res.group_finish[i] - res.group_issue[i]
                       for i in range(len(reqs))]
    return out
