"""Per-tenant fairness / SLO metrics over a joint fabric simulation.

Slowdown is measured per request — joint issue-to-finish latency over the
same request's latency when the tenant runs alone — then averaged per
tenant; Jain's fairness index over per-tenant slowdowns summarizes how
evenly contention is shared (1.0 = all tenants degrade equally).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Sequence

from repro.core.requests import CollectiveRequest
from repro.core.simulator import SimResult
from repro.tenancy.tenants import TenantSpec


def jain_index(xs: Sequence[float]) -> float:
    """Jain's fairness index: (sum x)^2 / (n * sum x^2), in (0, 1]."""
    xs = [x for x in xs]
    if not xs:
        return 1.0
    sq = sum(x * x for x in xs)
    if sq == 0:
        return 1.0
    return sum(xs) ** 2 / (len(xs) * sq)


@dataclass(frozen=True)
class TenantReport:
    tenant: str
    n_requests: int
    finish_s: float            # last request drained
    mean_latency_s: float
    mean_slowdown: float | None   # None when no isolated reference
    slo_slowdown: float | None
    slo_violated: bool | None
    wire_bytes: float
    bw_share: float            # fraction of all wire bytes moved


def tenant_reports(
    res: SimResult,
    requests: list[CollectiveRequest],
    isolated: Mapping[str, list[float]] | None = None,
    specs: Mapping[str, TenantSpec] | None = None,
) -> dict[str, TenantReport]:
    """Aggregate a joint run into per-tenant reports.

    ``isolated`` maps tenant -> per-request isolated latencies in that
    tenant's request order (see
    :func:`repro.tenancy.fabric.isolated_latencies`).
    """
    isolated = isolated or {}
    specs = specs or {}
    # aggregation (finish / latency / wire) comes from the SimResult helper;
    # only the per-request slowdown ratios need the request ordering
    stats = res.stream_stats(by="tenant")
    members: dict[str, list[int]] = {}
    for g, r in enumerate(requests):
        members.setdefault(r.tenant, []).append(g)
    total_wire = sum(s.wire_bytes for s in stats.values()) or 1.0
    out: dict[str, TenantReport] = {}
    for tenant, gs in members.items():
        st = stats[tenant]
        iso = isolated.get(tenant)
        slowdown = None
        if iso and len(iso) == len(gs):
            lats = [res.group_finish[g] - res.group_issue[g] for g in gs]
            ratios = [l / i for l, i in zip(lats, iso) if i > 0]
            slowdown = sum(ratios) / len(ratios) if ratios else None
        spec = specs.get(tenant)
        slo = spec.slo_slowdown if spec else None
        out[tenant] = TenantReport(
            tenant=tenant,
            n_requests=st.n,
            finish_s=st.finish,
            mean_latency_s=st.latency_mean,
            mean_slowdown=slowdown,
            slo_slowdown=slo,
            slo_violated=(None if slowdown is None or slo is None
                          else slowdown > slo),
            wire_bytes=st.wire_bytes,
            bw_share=st.wire_bytes / total_wire,
        )
    return out


def fairness_index(reports: Mapping[str, TenantReport]) -> float | None:
    """Jain's index over per-tenant mean slowdowns (needs references)."""
    sd = [r.mean_slowdown for r in reports.values()]
    if any(s is None for s in sd):
        return None
    return jain_index([s for s in sd if s is not None])


def mean_slowdown(reports: Mapping[str, TenantReport]) -> float | None:
    sd = [r.mean_slowdown for r in reports.values()]
    if not sd or any(s is None for s in sd):
        return None
    return sum(sd) / len(sd)


def slo_violations(reports: Mapping[str, TenantReport]) -> int:
    return sum(1 for r in reports.values() if r.slo_violated)
