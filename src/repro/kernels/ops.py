"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute in ``interpret=True`` mode — the
kernel body runs through the Pallas interpreter for correctness validation.
On TPU they lower to Mosaic.  ``flash_attention`` installs a
``jax.custom_vjp`` whose backward recomputes attention blockwise in XLA
(the standard recompute-based flash backward data-flow), so the kernel can
be used in training code.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import rglru as _rg
from repro.kernels import rmsnorm as _rn
from repro.models.common import flash_attention_xla


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal=True, window=0, q_offset=0):
    if q_offset:
        # Decode-style offsets take the XLA path (kernel assumes offset 0).
        return flash_attention_xla(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=_interpret())


def _fa_fwd(q, k, v, causal, window, q_offset):
    out = flash_attention(q, k, v, causal, window, q_offset)
    return out, (q, k, v)


def _fa_bwd(causal, window, q_offset, res, g):
    q, k, v = res

    def f(q, k, v):
        return flash_attention_xla(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


def rglru_scan(a, b, h0=None):
    return _rg.rglru_scan(a, b, h0, interpret=_interpret())


def rmsnorm(x, w, eps=1e-6):
    return _rn.rmsnorm(x, w, eps, interpret=_interpret())
