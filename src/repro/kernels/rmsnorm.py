"""Pallas TPU fused RMSNorm: one HBM read, fp32 mean-square in VMEM."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # (rows, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(
    x: jax.Array, w: jax.Array, eps: float = 1e-6, *,
    block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = False,
) -> jax.Array:
    """x: (..., D); w: (D,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    nb = (rows + pad) // br
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out[:rows].reshape(orig_shape)
