"""Pallas TPU flash attention (forward): blockwise online softmax.

TPU adaptation of the GPU flash-attention algorithm: instead of warp-level
softmax reductions in shared memory, the kernel streams K/V blocks
HBM->VMEM under an explicit BlockSpec tiling, keeps the running
(max, denom, accumulator) for one q-block in VMEM scratch across the
innermost grid dimension, and sizes blocks so the working set
(bq x d + 2 x bk x d + bq x bk fp32) fits VMEM with MXU-aligned (128) tiles.

Grid: (batch, q_head, num_q_blocks, num_kv_blocks) — kv innermost so the
scratch carries across it; GQA is folded into the K/V BlockSpec index map
(kv head = q head // group size).  Supports causal + sliding-window masks.
Backward is recompute-based via ``jax.custom_vjp`` in ops.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: int,
                 block_q: int, block_k: int, nk: int, seq_k: int):
    """One (q-block, kv-block) step; scratch persists over the kv grid dim."""
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                          # (bq, bk)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_k
    if causal:
        mask = jnp.logical_and(mask, qpos >= kpos)
    if window > 0:
        mask = jnp.logical_and(mask, kpos > qpos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int = 0,
    block_q: int = DEFAULT_BLOCK_Q, block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """q: (B,S,H,d); k,v: (B,T,KV,d) -> (B,S,H,d)."""
    b, sq, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    groups = h // kvh
    scale = 1.0 / math.sqrt(d)

    bq = min(block_q, sq)
    bk = min(block_k, t)
    pad_q = (-sq) % bq
    pad_k = (-t) % bk
    qt = jnp.moveaxis(q, 2, 1)                         # (B,H,S,d)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = (sq + pad_q) // bq
    nk = (t + pad_k) // bk

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, nk=nk, seq_k=t,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, i, j: (b_, h_ // groups, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, i, j: (b_, h_ // groups, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq + pad_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :sq]
    return jnp.moveaxis(out, 1, 2)
