"""Pallas TPU RG-LRU linear-recurrence scan kernel.

GPU implementations scan with warp shuffles; on TPU the natural shape is a
*channel-parallel, time-sequential* kernel: grid over (batch, channel
blocks, time blocks), each step loading an (bt x bc) tile of the
coefficient arrays into VMEM and iterating time rows with the running
hidden state h (bc,) held in VMEM scratch across the time-block grid
dimension.  Channels are fully vectorized on the VPU lanes (block 128+).

Computes h_t = a_t * h_{t-1} + b_t given precomputed per-step (a, b)
(the gate math stays in XLA where it fuses with the surrounding matmuls).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_C = 256
DEFAULT_BLOCK_T = 256


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, h_ref, *, block_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = h0_ref[0]

    a = a_ref[0]                                       # (bt, bc) fp32
    b = b_ref[0]
    h = h_ref[...]                                     # (bc,)

    def body(t, carry):
        h_prev, out = carry
        h_t = a[t] * h_prev + b[t]
        out = jax.lax.dynamic_update_index_in_dim(out, h_t, t, 0)
        return h_t, out

    h_last, out = jax.lax.fori_loop(
        0, block_t, body, (h, jnp.zeros_like(a))
    )
    o_ref[0] = out.astype(o_ref.dtype)
    h_ref[...] = h_last


def rglru_scan(
    a: jax.Array, b: jax.Array, h0: jax.Array | None = None, *,
    block_c: int = DEFAULT_BLOCK_C, block_t: int = DEFAULT_BLOCK_T,
    interpret: bool = False,
) -> jax.Array:
    """a, b: (B, S, C) fp32; h0: (B, C) or None -> h: (B, S, C)."""
    bsz, s, c = a.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, c), jnp.float32)
    bc = min(block_c, c)
    bt = min(block_t, s)
    pad_c = (-c) % bc
    pad_t = (-s) % bt
    if pad_c or pad_t:
        a = jnp.pad(a, ((0, 0), (0, pad_t), (0, pad_c)))
        b = jnp.pad(b, ((0, 0), (0, pad_t), (0, pad_c)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_c)))
    nc = (c + pad_c) // bc
    nt = (s + pad_t) // bt

    kernel = functools.partial(_rglru_kernel, block_t=bt)
    out = pl.pallas_call(
        kernel,
        grid=(bsz, nc, nt),
        in_specs=[
            pl.BlockSpec((1, bt, bc), lambda b_, ci, ti: (b_, ti, ci)),
            pl.BlockSpec((1, bt, bc), lambda b_, ci, ti: (b_, ti, ci)),
            pl.BlockSpec((1, bc), lambda b_, ci, ti: (b_, ci)),
        ],
        out_specs=pl.BlockSpec((1, bt, bc), lambda b_, ci, ti: (b_, ti, ci)),
        out_shape=jax.ShapeDtypeStruct((bsz, s + pad_t, c + pad_c), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bc,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return out[:, :s, :c]
