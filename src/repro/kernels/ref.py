"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import naive_attention


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """Materialized-scores attention — the kernel oracle."""
    return naive_attention(q, k, v, causal=causal, window=window)


def rglru_scan_ref(a, b, h0=None):
    """Sequential linear recurrence h_t = a_t h_{t-1} + b_t."""
    bsz, s, c = a.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, c), jnp.float32)

    def step(h, ab):
        a_t, b_t = ab
        h2 = a_t * h + b_t
        return h2, h2

    _, hs = jax.lax.scan(step, h0, (a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)


def rmsnorm_ref(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w
