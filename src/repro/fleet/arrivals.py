"""Open-loop arrival processes for the serving fleet.

Everything upstream of this module is *closed-loop*: a fixed tenant set
issues a fixed request list and the only question is how fast the fabric
drains it.  The planetary-scale serving regime is open-loop — users keep
arriving whether or not the fabric is keeping up — so offered load is an
exogenous *process*, not a list.  This module provides the seeded,
deterministic generators that turn a rate profile into concrete arrival
times:

- :class:`PoissonArrivals` — homogeneous Poisson (exponential gaps);
- :class:`DiurnalArrivals` — sinusoidally modulated Poisson via thinning
  (peak-hour / trough-hour daily cycle);
- :class:`MMPPArrivals` — Markov-modulated Poisson (bursty: cycles
  through states with different rates and exponential dwell times);
- :class:`TraceArrivals` — replay of an explicit timestamp trace.

All generators are **stateless**: ``times()`` constructs a fresh
``random.Random(seed)`` on every call, so the same generator object
yields bit-identical streams when called twice (the determinism the
differential engine tests rely on).

:func:`fleet_traffic` assembles a multi-tenant traffic graph by feeding
each tenant's arrival times into the ``serving_traffic`` builder and
merging the per-tenant graphs; :func:`fleet_tenant_specs` derives the
matching arbiter share contracts.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field

from repro.traffic.builders import serving_traffic
from repro.traffic.ir import TrafficGraph, merge_graphs, retag
from repro.tenancy.tenants import TenantSpec

__all__ = [
    "ArrivalProcess",
    "PoissonArrivals",
    "DiurnalArrivals",
    "MMPPArrivals",
    "TraceArrivals",
    "FleetTenant",
    "fleet_traffic",
    "fleet_tenant_specs",
]


def _check_bounds(n, horizon_s) -> None:
    if n is None and horizon_s is None:
        raise ValueError("times() needs n=, horizon_s=, or both")
    if n is not None and n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    if horizon_s is not None and horizon_s < 0:
        raise ValueError(f"horizon_s must be >= 0, got {horizon_s}")


class ArrivalProcess:
    """Base class: a seeded, re-callable arrival-time generator."""

    def times(self, *, n: int | None = None,
              horizon_s: float | None = None) -> list[float]:
        """Arrival times (seconds, ascending), bounded by count/horizon.

        At least one of ``n`` (max arrivals) and ``horizon_s`` (max time
        past ``start_s``) must be given.  Calling twice with the same
        bounds returns bit-identical lists.
        """
        raise NotImplementedError


@dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Homogeneous Poisson arrivals at ``rate_rps`` requests/second."""

    rate_rps: float
    seed: int = 0
    start_s: float = 0.0

    def __post_init__(self) -> None:
        if self.rate_rps <= 0:
            raise ValueError(f"rate_rps must be > 0, got {self.rate_rps}")

    def times(self, *, n: int | None = None,
              horizon_s: float | None = None) -> list[float]:
        _check_bounds(n, horizon_s)
        rng = random.Random(self.seed)
        out: list[float] = []
        t = self.start_s
        end = None if horizon_s is None else self.start_s + horizon_s
        while n is None or len(out) < n:
            t += rng.expovariate(self.rate_rps)
            if end is not None and t > end:
                break
            out.append(t)
        return out


@dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Sinusoidally modulated Poisson (daily peak/trough cycle).

    Instantaneous rate ``rate_rps * (1 + amplitude*sin(2π(t-phase)/period))``
    realized by thinning a homogeneous process at the peak rate — the
    standard exact method for inhomogeneous Poisson simulation.
    """

    rate_rps: float
    amplitude: float = 0.5
    period_s: float = 86400.0
    phase_s: float = 0.0
    seed: int = 0
    start_s: float = 0.0

    def __post_init__(self) -> None:
        if self.rate_rps <= 0:
            raise ValueError(f"rate_rps must be > 0, got {self.rate_rps}")
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError(
                f"amplitude must be in [0, 1), got {self.amplitude}")
        if self.period_s <= 0:
            raise ValueError(f"period_s must be > 0, got {self.period_s}")

    def rate_at(self, t: float) -> float:
        """Instantaneous arrival rate at absolute time ``t``."""
        phase = 2.0 * math.pi * (t - self.phase_s) / self.period_s
        return self.rate_rps * (1.0 + self.amplitude * math.sin(phase))

    def times(self, *, n: int | None = None,
              horizon_s: float | None = None) -> list[float]:
        _check_bounds(n, horizon_s)
        rng = random.Random(self.seed)
        peak = self.rate_rps * (1.0 + self.amplitude)
        out: list[float] = []
        t = self.start_s
        end = None if horizon_s is None else self.start_s + horizon_s
        while n is None or len(out) < n:
            t += rng.expovariate(peak)
            if end is not None and t > end:
                break
            if rng.random() * peak <= self.rate_at(t):
                out.append(t)
        return out


@dataclass(frozen=True)
class MMPPArrivals(ArrivalProcess):
    """Markov-modulated Poisson: bursty arrivals via cycling rate states.

    The process visits ``rates[k]`` for an exponential dwell with mean
    ``dwell_s[k]``, then moves to the next state cyclically.  Within a
    state, arrivals are Poisson at that state's rate; candidate gaps
    that cross a state boundary are truncated and redrawn at the new
    rate — exact for Poisson by memorylessness.  A two-state
    (calm, burst) configuration is the classic bursty-traffic model.
    """

    rates: tuple[float, ...]
    dwell_s: tuple[float, ...]
    seed: int = 0
    start_s: float = 0.0

    def __post_init__(self) -> None:
        if len(self.rates) < 2:
            raise ValueError("MMPP needs >= 2 states")
        if len(self.dwell_s) != len(self.rates):
            raise ValueError(
                f"dwell_s has {len(self.dwell_s)} entries for "
                f"{len(self.rates)} rates")
        if any(r < 0 for r in self.rates):
            raise ValueError(f"rates must be >= 0, got {self.rates}")
        if not any(r > 0 for r in self.rates):
            raise ValueError("at least one state rate must be > 0")
        if any(d <= 0 for d in self.dwell_s):
            raise ValueError(f"dwell_s must be > 0, got {self.dwell_s}")

    def times(self, *, n: int | None = None,
              horizon_s: float | None = None) -> list[float]:
        _check_bounds(n, horizon_s)
        rng = random.Random(self.seed)
        out: list[float] = []
        t = self.start_s
        end = None if horizon_s is None else self.start_s + horizon_s
        state = 0
        state_end = t + rng.expovariate(1.0 / self.dwell_s[0])
        while n is None or len(out) < n:
            rate = self.rates[state]
            if rate <= 0.0:
                # Silent state: no arrivals until the next transition.
                t = state_end
            else:
                cand = t + rng.expovariate(rate)
                if cand <= state_end:
                    if end is not None and cand > end:
                        break
                    out.append(cand)
                    t = cand
                    continue
                t = state_end
            if end is not None and t > end:
                break
            state = (state + 1) % len(self.rates)
            state_end = t + rng.expovariate(1.0 / self.dwell_s[state])
        return out


@dataclass(frozen=True)
class TraceArrivals(ArrivalProcess):
    """Replay an explicit, ascending timestamp trace."""

    trace: tuple[float, ...]
    start_s: float = 0.0

    def __post_init__(self) -> None:
        if any(b < a for a, b in zip(self.trace, self.trace[1:])):
            raise ValueError("trace timestamps must be ascending")

    def times(self, *, n: int | None = None,
              horizon_s: float | None = None) -> list[float]:
        _check_bounds(n, horizon_s)
        out = [self.start_s + t for t in self.trace]
        if horizon_s is not None:
            end = self.start_s + horizon_s
            out = [t for t in out if t <= end]
        if n is not None:
            out = out[:n]
        return out


@dataclass(frozen=True)
class FleetTenant:
    """One serving tenant: an arrival process plus per-request costs.

    ``serving`` holds the keyword arguments forwarded to
    ``serving_traffic`` (prefill/decode bytes and seconds, gen_tokens,
    ...) — everything except ``n_requests``/``arrival_times``/``name``,
    which :func:`fleet_traffic` supplies from the arrival process.
    """

    name: str
    arrivals: ArrivalProcess
    serving: dict = field(default_factory=dict)
    priority: int = 0
    weight: float = 1.0
    slo_slowdown: float | None = None


def fleet_traffic(tenants, *, horizon_s: float | None = None,
                  max_requests: int | None = None) -> TrafficGraph:
    """Merge each tenant's open-loop request chains into one graph.

    Every request chain is its own weakly-connected component, which is
    what makes a request the natural admission/shedding unit downstream.
    Tenants with no arrivals inside the bounds contribute nothing.
    """
    graphs = []
    for ft in tenants:
        arrival_times = ft.arrivals.times(n=max_requests,
                                          horizon_s=horizon_s)
        if not arrival_times:
            continue
        g = serving_traffic(name=ft.name, arrival_times=arrival_times,
                            **ft.serving)
        graphs.append(retag(g, tenant=ft.name, priority=ft.priority,
                            stream_prefix=f"{ft.name}/"))
    if not graphs:
        raise ValueError("no tenant produced arrivals inside the bounds")
    return merge_graphs(*graphs)


def fleet_tenant_specs(tenants) -> list[TenantSpec]:
    """Arbiter share contracts matching :func:`fleet_traffic` tags."""
    return [TenantSpec(name=ft.name, weight=ft.weight,
                       priority=ft.priority, slo_slowdown=ft.slo_slowdown)
            for ft in tenants]
