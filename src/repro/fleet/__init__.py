"""Overload-resilient serving fleet (beyond paper).

The demand-side half of robustness: where ``repro.faults`` (PR 8)
breaks the *fabric* mid-run, this package breaks the *load* — open-loop
seeded arrival processes (Poisson / diurnal / MMPP-bursty / trace
replay) feed ``serving_traffic`` request chains past saturation, an
:class:`AdmissionController` in front of both engines sheds what the
fabric cannot serve (``SimResult.shed_groups``, distinct from
``failed_groups``), and :class:`~repro.tenancy.elastic.SloDebtArbiter`
re-weights tenants from accumulated slowdown *debt* over a horizon
instead of the instantaneous slo-aware boost.  ``benchmarks/
fleet_study.py`` sweeps offered load through and past the knee.
"""
from repro.fleet.admission import (
    ADMISSION_POLICIES,
    AdmissionController,
    calibrate_admission,
    unit_of_group,
)
from repro.fleet.arrivals import (
    ArrivalProcess,
    DiurnalArrivals,
    FleetTenant,
    MMPPArrivals,
    PoissonArrivals,
    TraceArrivals,
    fleet_tenant_specs,
    fleet_traffic,
)

__all__ = [
    "ADMISSION_POLICIES",
    "AdmissionController",
    "ArrivalProcess",
    "DiurnalArrivals",
    "FleetTenant",
    "MMPPArrivals",
    "PoissonArrivals",
    "TraceArrivals",
    "calibrate_admission",
    "fleet_tenant_specs",
    "fleet_traffic",
    "unit_of_group",
]
