"""Admission control and load shedding in front of the engines.

Closed-loop runs always finish; open-loop runs past saturation queue
unboundedly unless something says *no*.  :class:`AdmissionController`
is that something: a bounded admission queue over request *units*
(weakly-connected components of the traffic graph — one serving
request chain each) with three deterministic policies:

- ``reject-newest``     — queue full ⇒ the arriving unit is shed;
- ``shed-lowest-priority`` — queue full ⇒ the lowest-priority queued
  unit (ties → latest arrival) is shed to make room; degenerates to
  reject-newest among equals;
- ``deadline-aware``    — queued units past their deadline are expired,
  and an arrival whose projected queueing delay (backlog × estimated
  service time) already exceeds the deadline is dropped at the door.

The engines drive the controller at three deterministic points — a
unit's first ready-event pop (the admission decision), its first chunk
entering service, and each chunk-group completion — always in event-time
order and identically on both engines, so shed sets are bit-identical
indexed vs reference.  The controller consumes no RNG and no sequence
numbers.  Shed victims are always pure queue residents (no chunk served
yet), so the engines only purge queues — nothing in flight is killed.

Capacity is expressed in *admitted units resident at once*;
:func:`calibrate_admission` derives it (and the per-unit service-time
estimate the deadline policy needs) from a traced at-capacity run's
``BwTimeline`` — closing the observe→actuate loop the ROADMAP asks for.
"""
from __future__ import annotations

from repro.obs.timeline import BwTimeline

__all__ = ["ADMISSION_POLICIES", "AdmissionController", "unit_of_group",
           "calibrate_admission"]

ADMISSION_POLICIES = ("reject-newest", "shed-lowest-priority",
                      "deadline-aware")

_UNKNOWN, _QUEUED, _SERVING, _SHED, _DONE = range(5)


def unit_of_group(graph) -> tuple[list[int], dict[int, int]]:
    """Map each chunk-group (graph node) to its request unit.

    Units are the weakly-connected components of the dependency graph —
    after ``merge_graphs`` each serving request chain is exactly one
    component.  Returns ``(unit_of, unit_priority)`` where ``unit_of[g]``
    is the unit id of group ``g`` (node order) and ``unit_priority`` maps
    unit id → the max priority over its *request* nodes (compute-only
    gates carry no tenant priority and are neutral; a unit with no
    request nodes gets 0).
    """
    n = len(graph.nodes)
    parent = list(range(n))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for i, preds in enumerate(graph.deps_idx):
        for p in preds:
            ra, rb = find(i), find(p)
            if ra != rb:
                parent[ra] = rb
    roots: dict[int, int] = {}
    unit_of = []
    unit_priority: dict[int, int] = {}
    has_req: set[int] = set()
    for i in range(n):
        r = find(i)
        u = roots.setdefault(r, len(roots))
        unit_of.append(u)
        node = graph.nodes[i]
        if node.request is None:
            unit_priority.setdefault(u, 0)
        else:
            pr = node.priority
            if u not in has_req or pr > unit_priority[u]:
                unit_priority[u] = pr
            has_req.add(u)
    return unit_of, unit_priority


class AdmissionController:
    """Bounded admission queue with deterministic shed policies.

    Parameters
    ----------
    unit_of:
        ``unit_of[g]`` → unit id for every chunk-group ``g`` (see
        :func:`unit_of_group`).  Groups of one unit are admitted or shed
        together.
    policy:
        One of :data:`ADMISSION_POLICIES`.
    capacity:
        Max units resident (admitted, not yet finished) at once.
    unit_priority:
        Required for ``shed-lowest-priority``: unit id → priority
        (higher = more important).
    deadline_s / est_service_s:
        Required for ``deadline-aware``: per-unit queueing deadline and
        the estimated service time used to project the backlog delay.
    """

    def __init__(self, unit_of, *, policy: str = "reject-newest",
                 capacity: int = 8, unit_priority=None,
                 deadline_s: float | None = None,
                 est_service_s: float | None = None) -> None:
        if policy not in ADMISSION_POLICIES:
            raise ValueError(
                f"unknown admission policy {policy!r}; "
                f"pick from {ADMISSION_POLICIES}")
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if policy == "shed-lowest-priority" and unit_priority is None:
            raise ValueError(
                "shed-lowest-priority needs unit_priority= (unit -> prio)")
        if policy == "deadline-aware" and (
                deadline_s is None or est_service_s is None):
            raise ValueError(
                "deadline-aware needs deadline_s= and est_service_s=")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        if est_service_s is not None and est_service_s <= 0:
            raise ValueError(
                f"est_service_s must be > 0, got {est_service_s}")
        self.unit_of = list(unit_of)
        self.policy = policy
        self.capacity = capacity
        self.unit_priority = dict(unit_priority or {})
        self.deadline_s = deadline_s
        self.est_service_s = est_service_s
        self._n_units = (max(self.unit_of) + 1) if self.unit_of else 0
        self._groups_of: list[list[int]] = [[] for _ in
                                            range(self._n_units)]
        for g, u in enumerate(self.unit_of):
            self._groups_of[u].append(g)
        self._reset()

    # -- engine-facing hooks (deterministic, no RNG / seq consumption) --

    def begin(self, n_groups: int, engine: str) -> None:
        """Engine handshake at run start: validate sizes and reset all
        per-run state so one controller drives many runs (and both
        engines of a differential pair) identically."""
        if n_groups != len(self.unit_of):
            raise ValueError(
                f"admission unit_of covers {len(self.unit_of)} groups "
                f"but the run has {n_groups}")
        self.engine = engine
        self._reset()

    def _reset(self) -> None:
        n = self._n_units
        self._state = [_UNKNOWN] * n
        self._remaining = [len(gs) for gs in self._groups_of]
        self._done = [False] * len(self.unit_of)
        self._arrive_t = [0.0] * n
        self._arrive_ord = [-1] * n
        self._n_arrived = 0
        self._occupancy = 0
        self.n_admitted = 0
        self.n_shed = 0
        self.shed_units: list[int] = []

    def on_ready(self, g: int, now: float):
        """Admission decision at group ``g``'s first ready pop.

        Returns ``None`` when the owning unit was already decided (the
        group rides that decision), an empty tuple to admit with no
        victims, or a non-empty tuple of chunk-group ids the engine must
        shed (which may include ``g``'s own unit).
        """
        u = self.unit_of[g]
        if self._state[u] != _UNKNOWN:
            return None
        self._arrive_t[u] = now
        self._arrive_ord[u] = self._n_arrived
        self._n_arrived += 1
        shed: list[int] = []

        def shed_unit(v: int) -> None:
            if self._state[v] == _QUEUED:
                self._occupancy -= 1
            self._state[v] = _SHED
            self.n_shed += 1
            self.shed_units.append(v)
            shed.extend(gg for gg in self._groups_of[v]
                        if not self._done[gg])

        if self.policy == "deadline-aware":
            # Expire queued units already past their deadline (unit-id
            # order — deterministic, engine-independent), then project
            # the arrival's queueing delay off the remaining backlog and
            # drop at the door if it already blows the deadline.  The
            # queue may run past ``capacity`` while the projected wait
            # stays inside the deadline — the bound is time, not slots.
            for v in range(self._n_units):
                if (self._state[v] == _QUEUED
                        and self._arrive_t[v] + self.deadline_s <= now):
                    shed_unit(v)
            backlog = self._occupancy - self.capacity + 1
            admit = backlog * self.est_service_s <= self.deadline_s
        elif self._occupancy < self.capacity:
            admit = True
        elif self.policy == "shed-lowest-priority":
            pool = [v for v in range(self._n_units)
                    if self._state[v] == _QUEUED]
            victim = min(
                pool + [u],
                key=lambda v: (self.unit_priority.get(v, 0),
                               -self._arrive_ord[v]))
            admit = victim != u
            if admit:
                shed_unit(victim)
        else:
            admit = False
        if admit:
            self._state[u] = _QUEUED
            self._occupancy += 1
            self.n_admitted += 1
        else:
            shed_unit(u)
        return tuple(shed)

    def on_serving(self, g: int, now: float) -> None:
        """First chunk of ``g`` entered service."""
        u = self.unit_of[g]
        if self._state[u] == _QUEUED:
            self._state[u] = _SERVING

    def on_finish(self, g: int, now: float) -> None:
        """Chunk-group ``g`` completed (idempotent per group)."""
        if self._done[g]:
            return
        self._done[g] = True
        u = self.unit_of[g]
        self._remaining[u] -= 1
        if self._remaining[u] == 0 and self._state[u] in (_QUEUED,
                                                          _SERVING):
            self._occupancy -= 1
            self._state[u] = _DONE


def calibrate_admission(timeline: BwTimeline, *, window_s: float,
                        n_requests: int, target_depth: float = 1.0,
                        chunks_per_unit: float = 1.0) -> dict[str, float]:
    """Derive admission parameters from a traced at-capacity run.

    ``timeline`` is the ``BwTimeline`` of a run *at* (not past)
    saturation.  Capacity comes from the peak windowed queue depth
    scaled to ``target_depth`` (depth 1.0 ⇒ admit what the observed
    fabric kept busy); ``est_service_s`` from makespan / requests; the
    busiest dim's share concentration is reported for diagnostics.
    ``chunks_per_unit`` converts the timeline's chunk-stage queue depth
    into request units (chunks per collective × wire collectives per
    request) — the controller's capacity is expressed in units.
    Returns kwargs for :class:`AdmissionController` (``capacity``,
    ``est_service_s``) plus ``peak_depth`` / ``busiest_dim_share``.
    """
    if n_requests < 1:
        raise ValueError("n_requests must be >= 1")
    if chunks_per_unit <= 0:
        raise ValueError("chunks_per_unit must be > 0")
    depth = timeline.queue_depth(window_s)
    peak = max((max(col) for col in depth if col), default=0.0)
    peak /= chunks_per_unit
    shares = timeline.per_dim_shares(window_s)
    busiest = 0.0
    for cols in shares.values():
        for col in cols:
            if col:
                busiest = max(busiest, max(col))
    return {
        "capacity": max(1, int(round(peak * target_depth))),
        "est_service_s": timeline.makespan / n_requests,
        "peak_depth": peak,
        "busiest_dim_share": busiest,
    }
