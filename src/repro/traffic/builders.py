"""Traffic-graph builders: training buckets, 1F1B pipelines, serving.

Three workload families expressed in the one IR:

  * :func:`training_traffic` — the dependency-gated re-expression of
    ``repro.core.workloads.dp_bucket_requests``: a forward-compute node, a
    backward-compute spine whose segments gate the gradient buckets as
    back-propagation retires them, and a per-iteration optimizer barrier
    that makes multi-iteration streams *closed-loop* (contention slows the
    next iteration's start — the fixed-gap ``TenantJob.requests`` stream
    cannot express that).
  * :func:`pipeline_traffic` — 1F1B pipeline-parallel stage streams:
    per-stage compute nodes serialized in the 1F1B op order, activation /
    gradient boundary transfers gated on the producing stage's compute.
  * :func:`serving_traffic` — prefill/decode chains: prefill is a burst of
    collectives gated on the prompt's compute; decode is a long dependency
    chain of small collectives, one per generated token, each gated on the
    previous token's comm plus the per-token compute.
    :func:`serving_costs_from_arch` derives the per-token byte/compute
    numbers from the repo's model configs (``repro.configs``) and the
    analytic roofline behind ``launch/serve.py``'s programs.

Builders emit tenant-neutral graphs; bind them to a tenant with
``repro.traffic.retag`` or ``repro.tenancy.TenantJob``.
"""
from __future__ import annotations

from dataclasses import replace as _dc_replace

from repro.core.requests import CollectiveRequest
from repro.core.workloads import Workload, dp_bucket_requests
from repro.traffic.ir import TrafficGraph, TrafficNode


def training_traffic(
    workload: Workload,
    *,
    n_buckets: int = 8,
    iterations: int = 1,
    start_s: float = 0.0,
    step_s: float = 0.0,
    min_period_s: float | None = None,
    name: str | None = None,
) -> TrafficGraph:
    """Dependency-gated training-iteration stream.

    Per iteration: a gate node (earliest-start floor), a forward-compute
    node, a backward spine of compute segments (one per distinct bucket
    retirement time of :func:`~repro.core.workloads.dp_bucket_requests`),
    the gradient-bucket requests each gated on its spine segment, and a
    ``step`` barrier (``step_s`` of optimizer compute) depending on every
    request — the next iteration's forward starts only once all gradients
    (and ZeRO param gathers) of this one have drained.  With no contention
    the bucket issue times equal the fixed-time stream's exactly.

    ``min_period_s`` floors iteration *i*'s start at
    ``start_s + i * min_period_s`` (an input pipeline that cannot deliver
    batches faster); default: purely closed-loop.
    """
    if iterations < 1:
        raise ValueError("iterations must be >= 1")
    if step_s < 0:
        raise ValueError("step_s must be >= 0")
    nm = name or workload.name
    base = dp_bucket_requests(workload, n_buckets)
    times = sorted({r.issue_time for r in base})
    nodes: list[TrafficNode] = []
    prev_barrier: str | None = None
    for it in range(iterations):
        gate = f"{nm}/it{it}/start"
        floor = start_s + it * min_period_s if min_period_s else (
            start_s if it == 0 else 0.0)
        nodes.append(TrafficNode(
            gate, deps=(prev_barrier,) if prev_barrier else (),
            start_s=floor))
        fwd = f"{nm}/it{it}/fwd"
        nodes.append(TrafficNode(fwd, compute_s=workload.compute_fwd_s,
                                 deps=(gate,)))
        spine_of: dict[float, str] = {}
        prev_seg, t_prev = fwd, 0.0
        for k, t in enumerate(times):
            seg = f"{nm}/it{it}/bwd{k}"
            nodes.append(TrafficNode(seg, compute_s=t - t_prev,
                                     deps=(prev_seg,)))
            spine_of[t] = seg
            prev_seg, t_prev = seg, t
        req_names = []
        for j, r in enumerate(base):
            rn = f"{nm}/it{it}/{r.stream}{j}"
            nodes.append(TrafficNode(
                rn, request=_dc_replace(r, issue_time=0.0),
                deps=(spine_of[r.issue_time],)))
            req_names.append(rn)
        barrier = f"{nm}/it{it}/step"
        nodes.append(TrafficNode(barrier, compute_s=step_s,
                                 deps=tuple(req_names) + (prev_seg,)))
        prev_barrier = barrier
    return TrafficGraph(tuple(nodes))


def _1f1b_order(stages: int, microbatches: int, s: int):
    """Stage ``s``'s op sequence under the non-interleaved 1F1B schedule:
    ``min(M, S - s)`` warmup forwards, then alternating 1B1F, then the
    cooldown backwards."""
    warmup = min(microbatches, stages - s)
    ops = [("F", m) for m in range(warmup)]
    b = 0
    for f in range(warmup, microbatches):
        ops.append(("B", b))
        b += 1
        ops.append(("F", f))
    while b < microbatches:
        ops.append(("B", b))
        b += 1
    return ops


def pipeline_traffic(
    *,
    stages: int,
    microbatches: int,
    fwd_s: float,
    bwd_s: float,
    act_bytes: float,
    grad_bytes: float | None = None,
    collective: str = "AG",
    grad_ar_bytes: float = 0.0,
    n_grad_buckets: int = 1,
    start_s: float = 0.0,
    name: str = "pp",
) -> TrafficGraph:
    """1F1B pipeline-parallel stage streams.

    Per (stage, microbatch): a forward compute node (gated on the previous
    op in the stage's 1F1B order *and* the upstream activation transfer), an
    activation-boundary request after it (stream ``pp-act``), a backward
    compute node (gated on the downstream gradient transfer), and a
    gradient-boundary request (stream ``pp-grad``).  Boundary transfers are
    modeled as their bandwidth-equivalent collective on the fabric
    (``collective``, default AG) — the simulator is a collective engine, so
    a stage-boundary P2P rides the same dims with the same byte volume.
    ``grad_ar_bytes > 0`` appends each stage's data-parallel gradient
    all-reduce (``n_grad_buckets`` buckets, stream ``pp-dp``) after its last
    backward — the pipeline-over-DP mix of Megatron-style training.
    Transfers hang *off* the compute chain (async sends): a stage's next op
    never waits for its own outbound transfer, only consumers wait.
    """
    if stages < 1 or microbatches < 1:
        raise ValueError("stages and microbatches must be >= 1")
    if fwd_s < 0 or bwd_s < 0:
        raise ValueError("fwd_s/bwd_s must be >= 0")
    if grad_bytes is None:
        grad_bytes = act_bytes
    if n_grad_buckets < 1:
        raise ValueError("n_grad_buckets must be >= 1")
    S, M = stages, microbatches
    nodes: list[TrafficNode] = []
    for s in range(S):
        prev: str | None = None
        for kind, m in _1f1b_order(S, M, s):
            if kind == "F":
                node = f"{name}/s{s}/f{m}"
                deps = [prev] if prev else []
                if s > 0:
                    deps.append(f"{name}/s{s - 1}/act{m}")
                nodes.append(TrafficNode(
                    node, compute_s=fwd_s, deps=tuple(deps),
                    start_s=start_s if not deps else 0.0,
                    stream="pp-compute"))
                if s < S - 1:
                    nodes.append(TrafficNode(
                        f"{name}/s{s}/act{m}",
                        request=CollectiveRequest(collective, act_bytes,
                                                  stream="pp-act"),
                        deps=(node,)))
            else:
                node = f"{name}/s{s}/b{m}"
                gate = (f"{name}/s{s + 1}/grad{m}" if s < S - 1
                        else f"{name}/s{s}/f{m}")
                deps = [prev] if prev else []
                if gate not in deps:
                    deps.append(gate)
                nodes.append(TrafficNode(node, compute_s=bwd_s,
                                         deps=tuple(deps),
                                         stream="pp-compute"))
                if s > 0:
                    nodes.append(TrafficNode(
                        f"{name}/s{s}/grad{m}",
                        request=CollectiveRequest(collective, grad_bytes,
                                                  stream="pp-grad"),
                        deps=(node,)))
            prev = node
    if grad_ar_bytes > 0:
        for s in range(S):
            last_b = f"{name}/s{s}/b{M - 1}"
            for j in range(n_grad_buckets):
                nodes.append(TrafficNode(
                    f"{name}/s{s}/dp-ar{j}",
                    request=CollectiveRequest(
                        "AR", grad_ar_bytes / n_grad_buckets,
                        stream="pp-dp"),
                    deps=(last_b,)))
    return TrafficGraph(tuple(nodes))


def serving_traffic(
    *,
    prefill_bytes: float,
    decode_bytes: float,
    prefill_s: float,
    decode_s: float,
    gen_tokens: int,
    n_requests: int = 1,
    arrival_gap_s: float = 0.0,
    start_s: float = 0.0,
    prefill_ops: int = 4,
    collective: str = "AG",
    name: str = "serve",
    arrival_times: "list[float] | None" = None,
) -> TrafficGraph:
    """Serving prefill/decode chains.

    Per request ``r`` (arriving at ``start_s + r * arrival_gap_s``): a
    prefill compute node, then a *burst* of ``prefill_ops`` collectives
    (stream ``prefill``) splitting ``prefill_bytes`` and issued together,
    then ``gen_tokens`` decode steps — a *chain* of small collectives
    (stream ``decode``), token ``t`` gated on token ``t-1``'s comm plus
    ``decode_s`` of per-token compute.  Decode comm latency percentiles
    (``SimResult.stream_stats()['decode'].latency_p99``) are the serving
    SLO metric.

    ``arrival_times`` switches the fixed-gap arrival grid to an explicit
    per-request timestamp list (the open-loop fleet path: seeded arrival
    processes from ``repro.fleet`` hand their draws in here).  When
    given, it overrides ``n_requests``/``arrival_gap_s``/``start_s``.
    """
    if arrival_times is not None:
        arrival_times = list(arrival_times)
        if not arrival_times:
            raise ValueError("arrival_times must be non-empty")
        n_requests = len(arrival_times)
    if gen_tokens < 0 or n_requests < 1:
        raise ValueError("gen_tokens must be >= 0, n_requests >= 1")
    ops = max(1, prefill_ops)
    nodes: list[TrafficNode] = []
    for r in range(n_requests):
        base = f"{name}/r{r}"
        gate = f"{base}/prefill-compute"
        arrive = (arrival_times[r] if arrival_times is not None
                  else start_s + r * arrival_gap_s)
        nodes.append(TrafficNode(gate, compute_s=prefill_s,
                                 start_s=arrive,
                                 stream="prefill-compute"))
        burst = []
        for j in range(ops):
            nm = f"{base}/prefill{j}"
            nodes.append(TrafficNode(
                nm,
                request=CollectiveRequest(collective, prefill_bytes / ops,
                                          stream="prefill"),
                deps=(gate,)))
            burst.append(nm)
        prev = tuple(burst)
        for t in range(gen_tokens):
            nm = f"{base}/decode{t}"
            nodes.append(TrafficNode(
                nm,
                request=CollectiveRequest(collective, decode_bytes,
                                          stream="decode"),
                compute_s=decode_s,
                deps=prev))
            prev = (nm,)
    return TrafficGraph(tuple(nodes))


def serving_costs_from_arch(
    arch: str,
    *,
    batch: int = 8,
    prompt_len: int = 1024,
    tp: int = 8,
    flops_per_npu: float = 312e12,
    reduced: bool = False,
) -> dict[str, float]:
    """Per-request serving cost model from the repo's config registry.

    Collective bytes come from ``launch/roofline.analytic_collective_bytes``
    (the per-axis wire-byte model behind the ``launch/serve.py`` programs:
    2 tensor-parallel collectives per layer, one token per decode step);
    compute times from ``analytic_fwd_flops`` at ``flops_per_npu`` per NPU
    across the ``tp`` group.  Returns the kwargs
    :func:`serving_traffic` needs: ``prefill_bytes`` / ``decode_bytes`` /
    ``prefill_s`` / ``decode_s``.
    """
    from repro.configs import ParallelConfig, ShapeConfig, get_arch
    from repro.launch.roofline import (
        analytic_collective_bytes,
        analytic_fwd_flops,
    )

    cfg = get_arch(arch, reduced=reduced)
    par = ParallelConfig(data=1, model=tp)
    axes = {"model": tp, "data": 1}
    pre = analytic_collective_bytes(
        cfg, ShapeConfig("traffic", prompt_len, batch, "prefill"), 0, par,
        axes)
    dec = analytic_collective_bytes(
        cfg, ShapeConfig("traffic", prompt_len, batch, "decode"), 0, par,
        axes)
    agg_flops = tp * flops_per_npu
    return {
        "prefill_bytes": pre.get("model", 0.0),
        "decode_bytes": dec.get("model", 0.0),
        "prefill_s": analytic_fwd_flops(cfg, batch, prompt_len) / agg_flops,
        "decode_s": analytic_fwd_flops(cfg, batch, 1, context=prompt_len)
        / agg_flops,
    }
