"""Dependency-aware traffic IR.

A :class:`TrafficGraph` is a DAG of :class:`TrafficNode`s — the common
representation for every request stream the scheduler and simulator
consume.  A node is either a *compute* node (``request is None``: a pure
delay that exists to gate its dependents — a pipeline stage's forward
pass, a decode step's matmuls) or a *request* node carrying one
:class:`~repro.core.requests.CollectiveRequest`.  Edges say "this node
becomes eligible once those nodes have finished"; ``compute_s`` adds a
delay between the gating event and the node's own issue.

Timing semantics (implemented by ``repro.core.simulator.simulate(deps=...)``
and mirrored by :meth:`TrafficGraph.estimate_times`):

  * a **root** node (no deps) issues at ``start_s + compute_s``;
  * a **dependent** node issues at
    ``max(start_s, latest-predecessor-finish + compute_s)`` — ``start_s``
    is a floor (e.g. a request's external arrival time), the predecessors
    are the data dependencies;
  * a compute node *finishes* at its issue instant (its duration is the
    ``compute_s`` already charged); a request node finishes when the
    simulator retires its collective.

Fixed-time streams are the degenerate case: every node a root with
``compute_s == 0`` (see :func:`from_requests`) — scheduling and simulation
of such a graph are bit-identical to the plain ``simulate_requests`` path,
which is what lets one engine serve training buckets, pipeline stage
streams and serving prefill/decode chains alike.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, replace as _dc_replace

from repro.core.requests import CollectiveRequest


@dataclass(frozen=True)
class TrafficNode:
    """One vertex of a traffic graph.

    ``stream`` / ``tenant`` override the reporting tags; by default a
    request node inherits its request's tags and a compute node reports as
    stream ``"compute"`` under tenant ``"default"``.
    """

    name: str
    request: CollectiveRequest | None = None
    compute_s: float = 0.0
    deps: tuple[str, ...] = ()
    start_s: float = 0.0
    stream: str | None = None
    tenant: str | None = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("node name must be non-empty")
        if self.compute_s < 0:
            raise ValueError("compute_s must be >= 0")
        if self.start_s < 0:
            raise ValueError("start_s must be >= 0")
        if not isinstance(self.deps, tuple):
            object.__setattr__(self, "deps", tuple(self.deps))
        if (self.request is not None and self.request.issue_time
                and self.request.issue_time != self.start_s):
            raise ValueError(
                f"node {self.name!r}: request.issue_time "
                f"{self.request.issue_time} disagrees with start_s "
                f"{self.start_s} — the graph honors start_s only; zero the "
                "request's issue_time or use from_requests()")

    @property
    def is_compute(self) -> bool:
        return self.request is None

    @property
    def stream_tag(self) -> str:
        if self.stream is not None:
            return self.stream
        return self.request.stream if self.request is not None else "compute"

    @property
    def tenant_tag(self) -> str:
        if self.tenant is not None:
            return self.tenant
        return self.request.tenant if self.request is not None else "default"

    @property
    def priority(self) -> int:
        return self.request.priority if self.request is not None else 0


@dataclass(frozen=True)
class TrafficGraph:
    """A validated DAG of traffic nodes.

    Node order is the *group* order everywhere downstream: group ``i`` of a
    ``SimResult`` produced from this graph is ``nodes[i]``.  Construction
    validates name uniqueness, resolves dependency names to indices, and
    topologically sorts (rejecting cycles), so forward references between
    nodes are allowed.
    """

    nodes: tuple[TrafficNode, ...]

    def __post_init__(self):
        if not isinstance(self.nodes, tuple):
            object.__setattr__(self, "nodes", tuple(self.nodes))
        index: dict[str, int] = {}
        for i, n in enumerate(self.nodes):
            if n.name in index:
                raise ValueError(f"duplicate node name {n.name!r}")
            index[n.name] = i
        deps_idx = []
        for n in self.nodes:
            try:
                deps_idx.append(tuple(index[d] for d in n.deps))
            except KeyError as e:
                raise ValueError(
                    f"node {n.name!r} depends on unknown node "
                    f"{e.args[0]!r}") from None
        # Kahn's algorithm; min-heap makes the order deterministic.
        n_par = [len(d) for d in deps_idx]
        children: list[list[int]] = [[] for _ in self.nodes]
        for i, ds in enumerate(deps_idx):
            for p in ds:
                children[p].append(i)
        heap = [i for i, k in enumerate(n_par) if k == 0]
        heapq.heapify(heap)
        order: list[int] = []
        while heap:
            i = heapq.heappop(heap)
            order.append(i)
            for c in children[i]:
                n_par[c] -= 1
                if n_par[c] == 0:
                    heapq.heappush(heap, c)
        if len(order) != len(self.nodes):
            stuck = [self.nodes[i].name
                     for i, k in enumerate(n_par) if k > 0]
            raise ValueError(f"dependency cycle involving {stuck[:5]}")
        object.__setattr__(self, "_index", index)
        object.__setattr__(self, "_deps_idx", tuple(deps_idx))
        object.__setattr__(self, "_topo_order", tuple(order))

    # -- structure ------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.nodes)

    @property
    def deps_idx(self) -> tuple[tuple[int, ...], ...]:
        """Per-node predecessor indices (simulate()'s ``deps`` argument)."""
        return self._deps_idx

    @property
    def topo_order(self) -> tuple[int, ...]:
        return self._topo_order

    def index_of(self, name: str) -> int:
        return self._index[name]

    def node(self, name: str) -> TrafficNode:
        return self.nodes[self._index[name]]

    @property
    def n_requests(self) -> int:
        return sum(1 for n in self.nodes if n.request is not None)

    # -- simulate() adapters --------------------------------------------------
    def sim_kwargs(self) -> dict:
        """The per-group keyword arguments ``simulate()`` needs to run this
        graph's chunk groups dependency-gated (everything but the groups)."""
        return dict(
            issue_times=[n.start_s for n in self.nodes],
            priorities=[n.priority for n in self.nodes],
            tenants=[n.tenant_tag for n in self.nodes],
            streams=[n.stream_tag for n in self.nodes],
            deps=list(self._deps_idx),
            dep_delay_s=[n.compute_s for n in self.nodes],
        )

    def estimate_times(self, latency_model=None):
        """Deterministic contention-free (issue, finish) estimates.

        Request durations use ``latency_model.ideal_time`` (no queueing);
        compute nodes finish at their issue instant.  These estimates only
        order the *scheduling* pass (and advance the Dim Load Tracker) —
        simulated issue times come from the event loop, which resolves
        dependencies against actual finishes.
        """
        n = len(self.nodes)
        est_issue = [0.0] * n
        est_finish = [0.0] * n
        for i in self._topo_order:
            node = self.nodes[i]
            ds = self._deps_idx[i]
            if ds:
                base = max(est_finish[p] for p in ds)
                t = max(node.start_s, base + node.compute_s)
            else:
                t = node.start_s + node.compute_s
            est_issue[i] = t
            dur = 0.0
            if node.request is not None and latency_model is not None:
                dur = latency_model.ideal_time(node.request.collective,
                                               node.request.size_bytes)
            est_finish[i] = t + dur
        return est_issue, est_finish


def from_requests(
    requests, prefix: str = "req",
) -> TrafficGraph:
    """Wrap a fixed-time request stream as a dependency-free graph.

    The result schedules and simulates bit-identically to passing
    ``requests`` straight to ``simulate_requests`` (the differential suite
    pins this), so callers can migrate to the IR without perturbing
    existing results.
    """
    return TrafficGraph(tuple(
        TrafficNode(f"{prefix}{i}", request=r, start_s=r.issue_time)
        for i, r in enumerate(requests)))


def merge_graphs(*graphs: TrafficGraph) -> TrafficGraph:
    """Concatenate graphs into one (e.g. one per tenant).  Node names must
    be globally unique — namespace them with :func:`retag` first."""
    nodes: list[TrafficNode] = []
    for g in graphs:
        nodes.extend(g.nodes)
    return TrafficGraph(tuple(nodes))


def retag(
    graph: TrafficGraph,
    *,
    name_prefix: str = "",
    tenant: str | None = None,
    stream_prefix: str = "",
    priority: int | None = None,
    start_offset_s: float = 0.0,
) -> TrafficGraph:
    """A copy of ``graph`` with namespaced names and re-tagged ownership —
    how a tenant-neutral builder output is bound to one tenant's share
    contract (see ``repro.tenancy.TenantJob.traffic``)."""
    if start_offset_s < 0:
        raise ValueError("start_offset_s must be >= 0")
    nodes = []
    for n in graph.nodes:
        req = n.request
        stream = n.stream
        # The node-level tag wins over the request's in tenant_tag, so the
        # override must land on both or a builder-set node tenant survives.
        tenant_tag = tenant if tenant is not None else n.tenant
        if req is not None:
            kw = {}
            if req.issue_time:
                # The graph honors start_s (shifted below); drop the stale
                # embedded time so the node-level validation stays true.
                kw["issue_time"] = 0.0
            if tenant is not None:
                kw["tenant"] = tenant
            if priority is not None:
                kw["priority"] = priority
            if stream_prefix:
                kw["stream"] = stream_prefix + (
                    stream if stream is not None else req.stream)
                stream = None
            if kw:
                req = _dc_replace(req, **kw)
        elif stream_prefix:
            stream = stream_prefix + n.stream_tag
        nodes.append(_dc_replace(
            n,
            name=name_prefix + n.name,
            deps=tuple(name_prefix + d for d in n.deps),
            request=req,
            start_s=n.start_s + start_offset_s,
            stream=stream,
            tenant=tenant_tag,
        ))
    return TrafficGraph(tuple(nodes))
