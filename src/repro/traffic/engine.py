"""Schedule and simulate traffic graphs on the collective engine.

Scheduling a dependency-gated stream has a chicken-and-egg problem: the
Themis chunk orders depend on each request's issue time, but with
dependencies the issue times are an *output* of the simulation.  The
resolution mirrors how the real system behaves — requests arrive online:

  * the **scheduling pass** walks request nodes in a deterministic
    estimated-issue order (:meth:`TrafficGraph.estimate_times`: dependency
    resolution against contention-free ``ideal_time`` durations) through
    ``ThemisScheduler.schedule_request``, so the Dim Load Tracker's
    running-load view advances exactly as in the fixed-time path;
  * the **simulation pass** (``simulate(deps=...)``) gates each group's
    release on its predecessors' *actual* finish times — dependency
    resolution stays in the event loop, where contention lives.

For a dependency-free graph the estimates are exact, the scheduling order
equals ``ThemisScheduler.schedule_stream``'s, and results are bit-identical
to ``simulate_requests`` (pinned by the differential suite).
"""
from __future__ import annotations

from dataclasses import replace as _dc_replace

from repro.core.chunking import Chunk
from repro.core.latency_model import LatencyModel
from repro.core.simulator import SimResult, simulate
from repro.topology import Topology

from repro.traffic.ir import TrafficGraph


def schedule_traffic(
    topology: Topology,
    graph: TrafficGraph,
    *,
    policy: str = "themis",
    chunks_per_collective: int = 64,
    water_filling: bool = False,
    scheduler=None,
) -> list[list[Chunk]]:
    """Chunk-schedule every request node of ``graph`` (estimated-issue
    order, one incremental scheduler), returning chunk groups indexed like
    ``graph.nodes`` (compute nodes get an empty group).

    ``scheduler`` follows the ``simulate_requests`` reuse contract: a
    shared ``ThemisScheduler`` keeps its memo caches warm across calls but
    schedules against a scenario-local tracker (``isolated_run``).
    """
    from repro.core.scheduler import ThemisScheduler

    lm = LatencyModel.for_topology(topology)
    est_issue, _ = graph.estimate_times(lm)
    if scheduler is None:
        sched_ctx = ThemisScheduler(lm, policy).isolated_run()
    else:
        if scheduler.latency_model.topology != topology:
            raise ValueError(
                "scheduler was built for topology "
                f"{scheduler.latency_model.topology.name!r}; reusing its "
                f"memos on {topology.name!r} is unspecified — build one "
                "scheduler per topology")
        sched_ctx = scheduler.isolated_run()
    groups: list[list[Chunk]] = [[] for _ in graph.nodes]
    order = sorted(
        (i for i, n in enumerate(graph.nodes) if n.request is not None),
        key=lambda i: (est_issue[i], i))
    with sched_ctx as sched:
        for i in order:
            req = _dc_replace(graph.nodes[i].request,
                              issue_time=est_issue[i])
            groups[i] = sched.schedule_request(
                req, chunks_per_collective, water_filling=water_filling)
    return groups


def simulate_traffic(
    topology: Topology,
    graph: TrafficGraph,
    *,
    policy: str = "themis",
    chunks_per_collective: int = 64,
    intra: str = "SCF",
    fusion: bool = True,
    water_filling: bool = False,
    jitter: float = 0.0,
    seed: int = 0,
    arbiter=None,
    preempt_penalty_s: float | None = None,
    engine: str = "indexed",
    scheduler=None,
    check_invariants: bool = False,
    tracer=None,
    faults=None,
    replan: bool = False,
    admission=None,
) -> tuple[SimResult, list[list[Chunk]]]:
    """Schedule and simulate a traffic graph — the dependency-aware
    counterpart of ``simulate_requests``.

    ``tracer`` arms the flight recorder (:class:`repro.obs.Tracer`); on a
    dependency-gated graph the exported Chrome trace carries flow arrows
    for every resolved dependency edge.

    ``faults`` (a :class:`repro.faults.FaultSchedule`) injects a fault
    timeline; ``replan=True`` additionally arms Themis graceful
    degradation (re-plan un-issued chunks at each BW fault boundary).

    ``admission`` (a :class:`repro.fleet.AdmissionController`) puts an
    admission/shedding gate in front of the engines — shed requests land
    in ``SimResult.shed_groups`` (traffic graphs always carry deps, the
    admission prerequisite).

    The returned ``SimResult`` is indexed like ``graph.nodes``:
    ``group_issue`` holds each node's *resolved* issue time, so
    ``stream_stats()`` latencies measure eligibility-to-finish (queueing +
    service) per request — the right denominator for serving SLOs.
    Multi-tenant graphs run under ``arbiter`` exactly like request streams
    (the per-dim inter-tenant disciplines and preemption are downstream of
    release, so they compose with dependency gating unchanged).

    ``engine="compiled"`` runs the cohort-vectorized fast path; dependency
    gating is on its supported surface, so dep-heavy serving graphs get
    the speedup bit-identically (arbiter/tracer/faults/admission scenarios
    fall back to indexed with the documented signal).
    """
    if replan and faults is None:
        raise ValueError("replan=True requires faults")
    replanner = None
    if replan:
        from repro.faults.replan import make_replanner

        replanner = make_replanner(topology, policy)
    groups = schedule_traffic(
        topology, graph, policy=policy,
        chunks_per_collective=chunks_per_collective,
        water_filling=water_filling, scheduler=scheduler)
    res = simulate(
        topology, groups, intra=intra, fusion=fusion, jitter=jitter,
        seed=seed, arbiter=arbiter, preempt_penalty_s=preempt_penalty_s,
        engine=engine, check_invariants=check_invariants, tracer=tracer,
        faults=faults, replanner=replanner, admission=admission,
        **graph.sim_kwargs())
    return res, groups
