"""Dependency-aware traffic subsystem.

One IR for every request stream the engine serves: fixed-time training
buckets, 1F1B pipeline stage streams, and serving prefill/decode chains —
:class:`TrafficNode`/:class:`TrafficGraph` express "this collective issues
when those finish plus this much compute", the builders generate the three
workload families, and :func:`simulate_traffic` runs a graph through the
incremental Themis scheduler and the dependency-gated simulator engines.
"""
from repro.traffic.builders import (
    pipeline_traffic,
    serving_costs_from_arch,
    serving_traffic,
    training_traffic,
)
from repro.traffic.engine import schedule_traffic, simulate_traffic
from repro.traffic.ir import (
    TrafficGraph,
    TrafficNode,
    from_requests,
    merge_graphs,
    retag,
)

__all__ = [
    "TrafficGraph",
    "TrafficNode",
    "from_requests",
    "merge_graphs",
    "pipeline_traffic",
    "retag",
    "schedule_traffic",
    "serving_costs_from_arch",
    "serving_traffic",
    "simulate_traffic",
    "training_traffic",
]
