"""Paper Fig. 10: BW utilization vs chunks-per-collective (4..512),
100MB AR on 3D-SW_SW_SW_hetero and 4D-Ring_FC_Ring_SW."""
from benchmarks.common import row, timed
from repro.core.simulator import simulate_scheduled
from repro.topology import make_table2_topologies

CPCS = [4, 8, 16, 32, 64, 128, 256, 512]


def run():
    rows = []
    topos = make_table2_topologies()
    for name in ("3D-SW_SW_SW_hetero", "4D-Ring_FC_Ring_SW"):
        topo = topos[name]
        for policy, intra in (("baseline", "FIFO"), ("themis", "FIFO"),
                              ("themis", "SCF")):
            utils = []
            us_tot = 0.0
            for cpc in CPCS:
                (res, _), us = timed(
                    simulate_scheduled, topo, "AR", 100e6, policy=policy,
                    chunks_per_collective=cpc, intra=intra)
                utils.append(res.avg_bw_utilization(topo))
                us_tot += us
            vals = " ".join(f"{c}:{u*100:.1f}%" for c, u in zip(CPCS, utils))
            rows.append(row(f"fig10/{name}/{policy}+{intra}",
                            us_tot / len(CPCS), vals))
    return rows
