"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``us_per_call`` is the wall time
of the underlying simulator/compile call; ``derived`` carries the metric the
paper reports (speedups, utilizations, roofline terms).
"""
import sys


def main() -> None:
    from benchmarks import (
        beyond_paper,
        kernels_bench,
        fig8_allreduce,
        fig9_activity,
        fig10_chunks,
        fig11_utilization,
        fig12_workloads,
        insights_study,
        overlap_study,
        roofline_table,
        sched_perf,
        tenancy_study,
    )
    from benchmarks.common import print_rows

    mods = [
        ("fig8", fig8_allreduce),
        ("fig9", fig9_activity),
        ("fig10", fig10_chunks),
        ("fig11", fig11_utilization),
        ("fig12", fig12_workloads),
        ("overlap", overlap_study),
        ("tenancy", tenancy_study),
        ("sched_perf", sched_perf),
        ("insights", insights_study),
        ("beyond", beyond_paper),
        ("roofline", roofline_table),
        ("kernels", kernels_bench),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, mod in mods:
        if only and name != only:
            continue
        print_rows(mod.run())


if __name__ == "__main__":
    main()
