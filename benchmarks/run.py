"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``us_per_call`` is the wall time
of the underlying simulator/compile call; ``derived`` carries the metric the
paper reports (speedups, utilizations, roofline terms).

``--profile`` wraps the selected studies in cProfile and prints the top-20
cumulative-time hotspots after the CSV — the profile-then-vectorize
workflow: find the hot loop before optimizing it (see ``repro.core.batch``
for the pass that came out of it).

``--trace`` installs the process-global observability registry
(``repro.obs.enable_global``) before the studies run and prints its
counters, span timers, and scheduler decision-log size afterwards — the
flight-recorder view of what the schedulers actually did (memo-cache
hit rates, schedule-pass / task-build wall time).
"""
import sys


def main() -> None:
    from benchmarks import (
        beyond_paper,
        faults_study,
        fleet_study,
        kernels_bench,
        fig8_allreduce,
        fig9_activity,
        fig10_chunks,
        fig11_utilization,
        fig12_workloads,
        insights_study,
        obs_study,
        overlap_study,
        roofline_table,
        sched_perf,
        tenancy_study,
        topo_search,
        traffic_study,
        verify_study,
    )
    from benchmarks.common import print_rows

    mods = [
        ("fig8", fig8_allreduce),
        ("fig9", fig9_activity),
        ("fig10", fig10_chunks),
        ("fig11", fig11_utilization),
        ("fig12", fig12_workloads),
        ("overlap", overlap_study),
        ("tenancy", tenancy_study),
        ("sched_perf", sched_perf),
        ("obs", obs_study),
        ("topo_search", topo_search),
        ("traffic", traffic_study),
        ("verify", verify_study),
        ("faults", faults_study),
        ("fleet", fleet_study),
        ("insights", insights_study),
        ("beyond", beyond_paper),
        ("roofline", roofline_table),
        ("kernels", kernels_bench),
    ]
    import inspect

    flags = [a for a in sys.argv[1:] if a.startswith("--")]
    unknown = [f for f in flags if f not in ("--profile", "--quick",
                                             "--trace")]
    if unknown:
        raise SystemExit(
            f"unknown flag(s) {unknown}; supported: --profile, --quick, "
            f"--trace")
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    profile = "--profile" in flags
    quick = "--quick" in flags
    trace = "--trace" in flags
    only = args[0] if args else None

    def run_selected() -> None:
        print("name,us_per_call,derived")
        for name, mod in mods:
            if only and name != only:
                continue
            if quick:
                if "quick" not in inspect.signature(mod.run).parameters:
                    raise SystemExit(
                        f"study {name!r} has no quick mode; drop --quick")
                print_rows(mod.run(quick=True))
            else:
                print_rows(mod.run())

    registry = None
    if trace:
        from repro.obs import enable_global

        registry = enable_global()

    if profile:
        import cProfile
        import pstats

        prof = cProfile.Profile()
        prof.enable()
        run_selected()
        prof.disable()
        print("\n# --profile: top-20 cumulative hotspots")
        pstats.Stats(prof).sort_stats("cumulative").print_stats(20)
    else:
        run_selected()

    if registry is not None:
        from repro.obs import disable_global

        print("\n# --trace: scheduler metrics (repro.obs.MetricsRegistry)")
        for line in registry.report_rows():
            print(line)
        disable_global()


if __name__ == "__main__":
    main()
