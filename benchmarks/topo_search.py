"""Fleet-batch + topology-search study.

Three parts, all emitted into ``BENCH_topo_search.json``:

  * **equivalence gate** — a pinned scenario grid across scheduling
    policies x intra disciplines x arbiters x jitter seeds, each run via
    ``simulate_batch`` and standalone (``simulate_scenario``, the
    un-amortized ``engine="indexed"`` path); every ``SimResult`` field
    must be **bit-identical**.  Any mismatch raises, failing CI.
  * **fleet throughput** — a topology-search scoring batch (candidate BW
    splits x jitter seeds, water-filling schedules) of >= 64 scenarios,
    timed through ``simulate_batch`` vs a loop of individual
    ``simulate()`` calls.  The batch path shares the scheduling pass and
    SoA task build across each candidate's seeds; the full run asserts
    >= 5x scenarios/sec (quick mode backstops at >= 3x — sub-second
    timings on shared CI runners are too noisy for the tight gate).
  * **search study** — the LIBRA-style searcher over 2D and 3D fabrics
    for a ResNet-152 gradient-bucket burst and a two-tenant mix; asserts
    the searched fabric beats the hand-built default's makespan on >= 1
    workload, and reports the policy contrast (the searched-split surplus
    under static baseline scheduling vs Themis — Themis recovers most of
    a bad split, the paper's Sec. 6.3 robustness story, quantified).

Run standalone (``python -m benchmarks.topo_search [--quick]``) or via
``python -m benchmarks.run topo_search``.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

from benchmarks.common import row
from repro.core.batch import BatchCaches, Scenario, simulate_batch, simulate_scenario
from repro.core.requests import CollectiveRequest
from repro.core.workloads import dp_bucket_requests, make_resnet152
from repro.tenancy import FabricArbiter, TenantSpec, synthetic_requests
from repro.topology import (
    SearchConfig,
    bw_split_topology,
    enumerate_bw_shares,
    make_table2_topologies,
    make_tpu_pod_topology,
    search_topologies,
)

MB = 1e6
OUT_JSON = Path(__file__).resolve().parents[1] / "BENCH_topo_search.json"


def _assert_equal(res_a, res_b, label: str) -> None:
    bad = res_a.diff_fields(res_b)
    if bad:
        raise AssertionError(
            f"batch equivalence violated on {label}: fields {bad} differ "
            f"between simulate_batch and standalone engine='indexed'")


def _resnet_burst(n_buckets: int) -> tuple[CollectiveRequest, ...]:
    """ResNet-152 gradient buckets issued as one sync batch (comm-bound)."""
    return tuple(CollectiveRequest("AR", r.size_bytes)
                 for r in dp_bucket_requests(make_resnet152(), n_buckets))


def _resnet_stream(n_buckets: int) -> tuple[CollectiveRequest, ...]:
    """The overlap-engine bucket stream (issues spread through backprop)."""
    return tuple(dp_bucket_requests(make_resnet152(), n_buckets))


def _tenant_mix() -> tuple[CollectiveRequest, ...]:
    """Two tenants on one fabric: ResNet buckets + a periodic AR stream."""
    heavy = [CollectiveRequest(r.collective, r.size_bytes,
                               issue_time=r.issue_time, tenant="train",
                               stream=r.stream)
             for r in dp_bucket_requests(make_resnet152(), 6)]
    light = synthetic_requests("svc", "AR", 6 * MB, 6, gap_s=4e-4)
    return tuple(sorted(heavy + light,
                        key=lambda r: (r.issue_time, r.tenant)))


# ---------------------------------------------------------------------------
# Equivalence gate: simulate_batch vs standalone indexed engine
# ---------------------------------------------------------------------------
def equivalence_gate(quick: bool) -> list[str]:
    topos = make_table2_topologies()
    specs = [TenantSpec("train", weight=2.0),
             TenantSpec("svc", weight=1.0, priority=1, slo_slowdown=1.5)]
    scenarios: list[tuple[str, Scenario]] = []
    policies = ("themis", "baseline") if quick else (
        "themis", "baseline", "themis_guarded")
    for tname in ("2D-SW_SW", "3D-SW_SW_SW_hetero"):
        topo = topos[tname]
        reqs = _resnet_stream(6)
        for policy in policies:
            for intra in ("SCF", "FIFO"):
                for jitter, seed in ((0.0, 0), (0.1, 3)):
                    scenarios.append((
                        f"{tname}/{policy}/{intra}/j{jitter}s{seed}",
                        Scenario(topo, reqs, policy=policy,
                                 chunks_per_collective=8, intra=intra,
                                 jitter=jitter, seed=seed)))
        mix = _tenant_mix()
        for arb_policy in ("weighted-fair", "slo-aware"):
            scenarios.append((
                f"{tname}/arbiter:{arb_policy}",
                Scenario(topo, mix, chunks_per_collective=8,
                         arbiter_factory=lambda p=arb_policy: FabricArbiter(
                             p, specs, quantum_chunks=4))))
    batch = simulate_batch([sc for _, sc in scenarios])
    for (label, sc), rb in zip(scenarios, batch):
        _assert_equal(rb, simulate_scenario(sc), label)
    return [label for label, _ in scenarios]


# ---------------------------------------------------------------------------
# Fleet throughput: search-scoring batch vs looped simulate()
# ---------------------------------------------------------------------------
def fleet_throughput(quick: bool) -> dict:
    base = make_tpu_pod_topology(2, 8, 8)
    n_buckets, chunks = (4, 8) if quick else (8, 16)
    reqs = _resnet_burst(n_buckets)
    # >= 64 *distinct candidate fabrics* (the acceptance criterion's unit),
    # each scored under 8 jitter seeds — the robust-scoring setting the
    # searcher itself uses.  The batch path computes every candidate's
    # scheduling pass and SoA build once and replays them across that
    # candidate's seeds; the loop baseline repeats them per scenario.
    n_candidates, n_seeds = 64, 8
    granularity = 13  # C(12, 2) = 66 positive 3-dim splits
    shares = enumerate_bw_shares(base.num_dims, granularity)
    assert len(shares) >= n_candidates
    cand_topos = [
        bw_split_topology(base, tuple(s / granularity for s in sh))
        for sh in shares[:n_candidates]
    ]
    scenarios = [
        Scenario(topo, reqs, chunks_per_collective=chunks,
                 water_filling=True, jitter=0.05, seed=seed)
        for topo in cand_topos for seed in range(n_seeds)
    ]
    assert len({sc.topology for sc in scenarios}) >= 64

    t0 = time.perf_counter()
    res_loop = [simulate_scenario(sc) for sc in scenarios]
    t_loop = time.perf_counter() - t0

    t0 = time.perf_counter()
    res_batch = simulate_batch(scenarios, caches=BatchCaches())
    t_batch = time.perf_counter() - t0

    for i, (rb, rl) in enumerate(zip(res_batch, res_loop)):
        _assert_equal(rb, rl, f"throughput scenario {i}")
    speedup = t_loop / t_batch
    out = {
        "n_scenarios": len(scenarios),
        "n_candidates": n_candidates,
        "seeds_per_candidate": n_seeds,
        "n_requests": len(reqs),
        "chunks_per_collective": chunks,
        "water_filling": True,
        "loop_s": t_loop,
        "batch_s": t_batch,
        "scenarios_per_sec_loop": len(scenarios) / t_loop,
        "scenarios_per_sec_batch": len(scenarios) / t_batch,
        "speedup": speedup,
        "bit_identical": True,
    }
    floor = 3.0 if quick else 5.0
    if speedup < floor:
        raise AssertionError(
            f"fleet batch speedup {speedup:.2f}x < {floor}x over looped "
            f"simulate() at {len(scenarios)} scenarios")
    return out


# ---------------------------------------------------------------------------
# Search study: does the searched fabric beat the hand-built default?
# ---------------------------------------------------------------------------
def _search_one(label, base, reqs, cfg) -> dict:
    t0 = time.perf_counter()
    res = search_topologies(base, list(reqs), cfg)
    return {
        "label": label,
        "base": base.name,
        "policy": cfg.policy,
        "default_makespan_s": res.default.makespan,
        "best_makespan_s": res.best.makespan,
        "improvement": res.improvement,
        "beats_default": res.best.makespan < res.default.makespan,
        "best_shares": list(res.best.shares),
        "best_denom": res.best.denom,
        "best_perm": list(res.best.perm),
        "best_bw_utilization": res.best.bw_utilization,
        "evaluated": len(res.evaluated),
        "pruned": res.pruned,
        "scenarios_run": res.scenarios_run,
        "pareto": [
            {"makespan_s": c.makespan, "bw_utilization": c.bw_utilization,
             "shares": list(c.shares), "denom": c.denom,
             "perm": list(c.perm)}
            for c in res.pareto
        ],
        "search_s": time.perf_counter() - t0,
    }


def search_study(quick: bool) -> dict:
    topos = make_table2_topologies()
    rounds, top_k = (1, 3) if quick else (2, 4)
    chunks = 8 if quick else 16
    burst = _resnet_burst(6 if quick else 8)
    runs = [
        _search_one(
            "resnet152-burst/3D-tpu-pod/themis",
            make_tpu_pod_topology(2, 8, 8), burst,
            SearchConfig(granularity=6, rounds=rounds, top_k=top_k,
                         chunks_per_collective=chunks)),
        _search_one(
            "resnet152-burst/2D-SW_SW/themis",
            topos["2D-SW_SW"], burst,
            SearchConfig(granularity=8, rounds=rounds, top_k=top_k,
                         chunks_per_collective=chunks)),
        _search_one(
            "tenant-mix/2D-SW_SW/themis",
            topos["2D-SW_SW"], _tenant_mix(),
            SearchConfig(granularity=8, rounds=rounds, top_k=top_k,
                         chunks_per_collective=chunks)),
    ]
    # Policy contrast: the same 2D search under static baseline scheduling.
    # The searched-split surplus is much larger when the schedule cannot
    # adapt — Themis absorbs most of a bad BW split (Sec. 6.3).
    contrast = _search_one(
        "resnet152-burst/2D-SW_SW/baseline",
        topos["2D-SW_SW"], burst,
        SearchConfig(granularity=8, rounds=rounds, top_k=top_k,
                     chunks_per_collective=chunks, policy="baseline"))
    out = {
        "workloads": runs,
        "baseline_policy_contrast": contrast,
        "any_beats_default": any(r["beats_default"] for r in runs),
    }
    if not out["any_beats_default"]:
        raise AssertionError(
            "topology search failed to beat the hand-built default fabric "
            "on every benchmark workload")
    return out


def run(quick: bool = False):
    report: dict = {"mode": "quick" if quick else "full"}
    rows = []

    checked = equivalence_gate(quick)
    report["equivalence"] = {"scenarios": checked, "ok": True}
    rows.append(row("topo_search/equivalence", 0.0,
                    f"{len(checked)} scenarios bit-identical"))

    tp = fleet_throughput(quick)
    report["throughput"] = tp
    rows.append(row(
        f"topo_search/throughput/{tp['n_scenarios']}scenarios",
        tp["batch_s"] / tp["n_scenarios"] * 1e6,
        f"speedup={tp['speedup']:.1f}x "
        f"batch={tp['scenarios_per_sec_batch']:.1f}/s "
        f"loop={tp['scenarios_per_sec_loop']:.1f}/s"))

    ss = search_study(quick)
    report["search"] = ss
    for r in ss["workloads"]:
        rows.append(row(
            f"topo_search/search/{r['label']}", r["search_s"] * 1e6,
            f"improvement={r['improvement']:.3f}x "
            f"evaluated={r['evaluated']} pruned={r['pruned']}"))
    c = ss["baseline_policy_contrast"]
    rows.append(row(
        f"topo_search/search/{c['label']}", c["search_s"] * 1e6,
        f"improvement={c['improvement']:.3f}x (static schedule; Themis "
        f"contrast)"))

    OUT_JSON.write_text(json.dumps(report, indent=2) + "\n")
    rows.append(row("topo_search/json", 0.0, f"json={OUT_JSON.name}"))
    return rows


def main() -> None:
    quick = "--quick" in sys.argv[1:]
    print("name,us_per_call,derived")
    for name, us, derived in run(quick=quick):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
