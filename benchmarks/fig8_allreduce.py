"""Paper Fig. 8: total AR communication time, baseline vs Themis+FIFO vs
Themis+SCF, sizes 100MB-1GB across the six Table-2 topologies."""
from benchmarks.common import row, timed
from repro.core.simulator import simulate_scheduled
from repro.topology import make_table2_topologies

MB = 1e6
SIZES = [100, 250, 500, 750, 1000]


def run():
    rows = []
    speed_f, speed_s = [], []
    for name, topo in make_table2_topologies().items():
        for s in SIZES:
            (rb, _), us = timed(simulate_scheduled, topo, "AR", s * MB,
                                policy="baseline", intra="FIFO")
            rf, _ = simulate_scheduled(topo, "AR", s * MB, policy="themis",
                                       intra="FIFO")
            rs, _ = simulate_scheduled(topo, "AR", s * MB, policy="themis",
                                       intra="SCF")
            speed_f.append(rb.makespan / rf.makespan)
            speed_s.append(rb.makespan / rs.makespan)
            rows.append(row(
                f"fig8/{name}/{s}MB", us,
                f"base={rb.makespan*1e3:.2f}ms themis_fifo={rf.makespan*1e3:.2f}ms "
                f"themis_scf={rs.makespan*1e3:.2f}ms speedup={rb.makespan/rs.makespan:.2f}x"))
    n = len(speed_s)
    rows.append(row("fig8/SUMMARY", 0.0,
                    f"avg_speedup_fifo={sum(speed_f)/n:.2f}x(paper:1.58) "
                    f"avg_speedup_scf={sum(speed_s)/n:.2f}x(paper:1.72) "
                    f"max_scf={max(speed_s):.2f}x(paper:2.70)"))
    return rows
