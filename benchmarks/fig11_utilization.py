"""Paper Fig. 11: average BW utilization vs AR size (all topologies).

Utilization comes from the observability timeline API
(``repro.obs.BwTimeline``) — ``BwTimeline.from_result`` evaluates the
same weighted-average expression as ``SimResult.avg_bw_utilization``, so
the reported numbers are unchanged.
"""
import statistics

from benchmarks.common import row, timed
from repro.core.simulator import simulate_scheduled
from repro.obs import BwTimeline
from repro.topology import make_table2_topologies

MB = 1e6
SIZES = [100, 250, 500, 750, 1000]


def run():
    rows = []
    per_policy = {}
    for policy, intra in (("baseline", "FIFO"), ("themis", "FIFO"),
                          ("themis", "SCF")):
        utils = []
        us_tot = 0.0
        for name, topo in make_table2_topologies().items():
            for s in SIZES:
                (res, _), us = timed(simulate_scheduled, topo, "AR", s * MB,
                                     policy=policy, intra=intra)
                utils.append(BwTimeline.from_result(res, topo)
                             .avg_bw_utilization())
                us_tot += us
        per_policy[f"{policy}+{intra}"] = statistics.mean(utils)
        rows.append(row(f"fig11/{policy}+{intra}", us_tot / len(utils),
                        f"avg_util={statistics.mean(utils)*100:.2f}%"))
    rows.append(row(
        "fig11/SUMMARY", 0.0,
        f"baseline={per_policy['baseline+FIFO']*100:.1f}%(paper:56.31) "
        f"themis_fifo={per_policy['themis+FIFO']*100:.1f}%(paper:87.67) "
        f"themis_scf={per_policy['themis+SCF']*100:.1f}%(paper:95.14)"))
    return rows
