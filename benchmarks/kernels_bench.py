"""Kernel micro-benchmarks: Pallas (interpret) vs XLA path vs oracle wall
time at small shapes (CPU container — correctness/structure, not TPU perf),
plus the analytic VMEM working set per BlockSpec tile."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed


def _vmem_bytes_flash(bq, bk, d):
    # q tile + k/v tiles + scores + scratch (m, l, acc) in fp32
    return 4 * (bq * d + 2 * bk * d + bq * bk + 2 * bq + bq * d)


def run():
    from repro.kernels import ref
    from repro.models.common import flash_attention_xla

    rng = np.random.default_rng(0)
    rows = []
    b, s, h, kv, d = 1, 256, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)

    f_xla = jax.jit(lambda q, k, v: flash_attention_xla(
        q, k, v, causal=True, block_q=128, block_k=128))
    f_xla(q, k, v).block_until_ready()
    _, us = timed(lambda: f_xla(q, k, v).block_until_ready(), repeat=5)
    rows.append(row("kernels/flash_xla_fwd_256", us,
                    f"vmem_tile={_vmem_bytes_flash(128, 128, d)/1024:.0f}KiB "
                    "(target: fits 16MiB VMEM)"))

    f_ref = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    f_ref(q, k, v).block_until_ready()
    _, us = timed(lambda: f_ref(q, k, v).block_until_ready(), repeat=5)
    rows.append(row("kernels/naive_ref_fwd_256", us, "O(S^2) oracle"))

    a = jnp.asarray(rng.uniform(0.5, 0.99, (2, 512, 256)), jnp.float32)
    bb = jnp.asarray(rng.standard_normal((2, 512, 256)), jnp.float32)
    g_ref = jax.jit(lambda a, bb: ref.rglru_scan_ref(a, bb))
    g_ref(a, bb).block_until_ready()
    _, us = timed(lambda: g_ref(a, bb).block_until_ready(), repeat=5)
    rows.append(row("kernels/rglru_ref_512x256", us, "lax.scan oracle"))

    x = jnp.asarray(rng.standard_normal((1024, 512)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((512,)), jnp.float32)
    r_ref = jax.jit(lambda x, w: ref.rmsnorm_ref(x, w))
    r_ref(x, w).block_until_ready()
    _, us = timed(lambda: r_ref(x, w).block_until_ready(), repeat=10)
    rows.append(row("kernels/rmsnorm_ref_1024x512", us, "fused oracle"))
    return rows
