"""Roofline table: read the dry-run artifacts and emit per-cell terms."""
import glob
import json
import os


def run():
    rows = []
    files = sorted(glob.glob(os.path.join("runs", "dryrun", "*_gspmd.json")))
    for f in files:
        d = json.load(open(f))
        if d.get("status") == "skipped":
            rows.append((f"roofline/{d['arch']}/{d['shape']}", 0.0,
                         "SKIP " + d["reason"]))
            continue
        if d.get("status") != "ok":
            rows.append((f"roofline/{d['arch']}/{d['shape']}", 0.0, "FAIL"))
            continue
        if "pod=2" in d["mesh"]:
            continue  # roofline table is single-pod (multi-pod proves scale)
        r = d["roofline"]
        rows.append((
            f"roofline/{d['arch']}/{d['shape']}",
            d.get("compile_s", 0) * 1e6,
            f"compute={r['compute_s']*1e3:.3f}ms memory={r['memory_s']*1e3:.3f}ms "
            f"collective={r['collective_s']*1e3:.3f}ms dominant={r['dominant']} "
            f"frac={r['roofline_fraction']:.3f} "
            f"useful={r['useful_ratio']:.3f} "
            f"mem/dev={d['memory']['per_device_total_gib']}GiB"))
    return rows
