"""Traffic study: dependency-gated streams on the shared fabric.

Four parts, all emitted into ``BENCH_traffic.json``:

  * **equivalence gate** — dependency-gated scenarios (pipeline 1F1B,
    serving chains, mixed tenants, DCN stragglers) simulated by both
    engines and through ``simulate_batch``; every ``SimResult`` field must
    be bit-identical, and a fixed-time stream routed through the traffic IR
    must reproduce the plain ``simulate_requests`` result byte-for-byte.
  * **mixed tenancy** — a training tenant (closed-loop multi-iteration
    ResNet-152 buckets) and a serving tenant (prefill burst + decode
    chains, costs derived from the llama3-8b config) share a TPU-pod
    fabric under >= 2 arbiter policies via ``simulate_batch``; reports
    decode p50/p95/p99, prefill p99, and the training slowdown vs running
    alone.
  * **DCN jitter** — the same mixed scenario with a lognormal straggler
    distribution on the pod dimension (``make_tpu_pod_topology``'s
    ``dcn_straggler_sigma``), multi-seed: decode tail vs sigma.
  * **long-stream scaling** — the standing fleet benchmark: one scenario
    family (multi-iteration training + a decode tenant) grown to ~1M
    stage-ops; a log-log fit of indexed-engine wall time vs stage-ops must
    stay <= 1.2 (quick mode backstops at 1.6 — its small points are too
    noisy on shared runners, matching ``sched_perf``'s convention).  Each
    size also runs ``engine="compiled"`` — dependency gating is on the
    cohort engine's fast path — asserting bit-identity and recording the
    compiled wall time, throughput, and fitted exponent alongside
    (headline compiled-vs-indexed gates live in ``sched_perf``'s
    dep-free compiled tier; here the dep-resolution heap keeps the
    speedup modest, so it is recorded, not gated).

Run standalone (``python -m benchmarks.traffic_study [--quick]``) or via
``python -m benchmarks.run traffic``.
"""
from __future__ import annotations

import json
import math
import sys
from pathlib import Path

from benchmarks.common import row, timed_best
from repro.core.batch import BatchCaches, Scenario, simulate_batch
from repro.core.requests import CollectiveRequest
from repro.core.simulator import simulate, simulate_requests
from repro.core.workloads import make_resnet152
from repro.tenancy import FabricArbiter, TenantJob, TenantSpec, tenant_traffic
from repro.topology import make_tpu_pod_topology
from repro.traffic import (
    from_requests,
    pipeline_traffic,
    serving_costs_from_arch,
    serving_traffic,
    simulate_traffic,
)

MB = 1e6
OUT_JSON = Path(__file__).resolve().parents[1] / "BENCH_traffic.json"


def _assert_equal(res_a, res_b, label: str) -> None:
    bad = res_a.diff_fields(res_b)
    if bad:
        raise AssertionError(
            f"traffic equivalence violated on {label}: fields {bad} differ")


def _stage_ops(groups) -> int:
    return sum(len(c.schedule) for grp in groups for c in grp)


def _serving_job(costs, *, gen_tokens: int, n_requests: int,
                 arrival_gap_s: float) -> TenantJob:
    return TenantJob(
        TenantSpec("serve", weight=2.0, slo_slowdown=1.5),
        traffic_builder=lambda job: serving_traffic(
            gen_tokens=gen_tokens, n_requests=n_requests,
            arrival_gap_s=arrival_gap_s, **costs))


def _mixed_graph(costs, *, iterations: int, gen_tokens: int,
                 n_requests: int, arrival_gap_s: float = 2e-3,
                 n_buckets: int = 16):
    train = TenantJob(
        TenantSpec("train", weight=1.0, iterations=iterations,
                   n_buckets=n_buckets),
        make_resnet152())
    serve = _serving_job(costs, gen_tokens=gen_tokens,
                         n_requests=n_requests, arrival_gap_s=arrival_gap_s)
    return tenant_traffic([train, serve]), [train.spec, serve.spec]


# ---------------------------------------------------------------------------
# Equivalence gate
# ---------------------------------------------------------------------------
def equivalence_gate(costs, quick: bool) -> list[str]:
    checked: list[str] = []
    topo = make_tpu_pod_topology(2, 8, 8)

    # fixed-time stream through the IR == plain simulate_requests, exactly
    reqs = [CollectiveRequest(["AR", "RS", "AG"][i % 3],
                              (4 + 7 * (i % 5)) * MB, issue_time=i * 1.1e-4,
                              priority=i % 2, stream=f"s{i % 2}")
            for i in range(14)]
    r_plain, _ = simulate_requests(topo, reqs, chunks_per_collective=8)
    r_graph, _ = simulate_traffic(topo, from_requests(reqs),
                                  chunks_per_collective=8)
    _assert_equal(r_graph, r_plain, "fixed-time-ir-vs-simulate_requests")
    checked.append("fixed-time-ir-vs-simulate_requests")

    graphs = {
        "pipeline-1f1b": pipeline_traffic(
            stages=4, microbatches=6, fwd_s=1e-3, bwd_s=2e-3,
            act_bytes=8 * MB, grad_ar_bytes=60 * MB, n_grad_buckets=4),
        "serving-chains": serving_traffic(
            gen_tokens=12, n_requests=3, arrival_gap_s=1.5e-3, **costs),
    }
    mixed, specs = _mixed_graph(costs, iterations=2, gen_tokens=8,
                                n_requests=2)
    jit_topo = make_tpu_pod_topology(2, 8, 8, dcn_straggler_sigma=0.4)
    cases = [("plain", topo, None, 0.0, 0),
             ("arbiter:weighted-fair", topo,
              lambda: FabricArbiter("weighted-fair", specs), 0.0, 0),
             ("dcn-straggler", jit_topo, None, 0.05, 3)]
    graphs["mixed-tenant"] = mixed
    for gname, graph in graphs.items():
        for cname, t, factory, jitter, seed in cases:
            kw = dict(chunks_per_collective=6, jitter=jitter, seed=seed)
            ri, _ = simulate_traffic(t, graph, engine="indexed",
                                     arbiter=factory() if factory else None,
                                     **kw)
            rr, _ = simulate_traffic(t, graph, engine="reference",
                                     arbiter=factory() if factory else None,
                                     **kw)
            label = f"{gname}/{cname}"
            _assert_equal(ri, rr, label)
            # batch layer must replay the identical result
            sc = Scenario(t, traffic=graph, chunks_per_collective=6,
                          jitter=jitter, seed=seed, arbiter_factory=factory)
            rb = simulate_batch([sc])[0]
            _assert_equal(rb, ri, label + "/batch")
            checked.append(label)
            if quick:
                break
    return checked


# ---------------------------------------------------------------------------
# Mixed training + serving tenancy under arbiter policies
# ---------------------------------------------------------------------------
def mixed_tenancy(costs, quick: bool) -> dict:
    topo = make_tpu_pod_topology(2, 8, 8)
    iterations = 2 if quick else 3
    gen_tokens = 16 if quick else 32
    graph, specs = _mixed_graph(costs, iterations=iterations,
                                gen_tokens=gen_tokens, n_requests=3)

    # Isolated references: each tenant alone on the full fabric.
    train_alone = TenantJob(TenantSpec("train", iterations=iterations,
                                      n_buckets=16), make_resnet152())
    res_train, _ = simulate_traffic(topo, train_alone.traffic(),
                                    chunks_per_collective=16)
    train_iso = res_train.finish_time()
    serve_alone = _serving_job(costs, gen_tokens=gen_tokens, n_requests=3,
                               arrival_gap_s=2e-3)
    res_serve, _ = simulate_traffic(topo, serve_alone.traffic(),
                                    chunks_per_collective=16)
    decode_iso = res_serve.stream_stats()["serve/decode"]
    iso_lat = {"serve": decode_iso.latency_mean,
               "train": train_iso / max(1, iterations)}

    policies = ("fifo", "weighted-fair") if quick else (
        "fifo", "weighted-fair", "slo-aware")
    scenarios = [
        Scenario(topo, traffic=graph, chunks_per_collective=16,
                 arbiter_factory=(lambda p=pol: FabricArbiter(
                     p, specs, isolated_latency=iso_lat)),
                 label=pol)
        for pol in policies
    ]
    caches = BatchCaches()
    results = simulate_batch(scenarios, caches=caches)
    out: dict = {
        "topology": topo.name,
        "iterations": iterations,
        "gen_tokens": gen_tokens,
        "train_isolated_finish_s": train_iso,
        "decode_isolated_p99_s": decode_iso.latency_p99,
        "policies": {},
    }
    for sc, res in zip(scenarios, results):
        dec = res.stream_stats()["serve/decode"]
        pre = res.stream_stats()["serve/prefill"]
        train_fin = res.stream_stats(by="tenant")["train"].finish
        out["policies"][sc.label] = {
            "decode_p50_s": dec.latency_p50,
            "decode_p95_s": dec.latency_p95,
            "decode_p99_s": dec.latency_p99,
            "prefill_p99_s": pre.latency_p99,
            "train_finish_s": train_fin,
            "train_slowdown": train_fin / train_iso,
        }
    return out


# ---------------------------------------------------------------------------
# DCN straggler sweep
# ---------------------------------------------------------------------------
def dcn_jitter(costs, quick: bool) -> dict:
    sigmas = (0.0, 0.5) if quick else (0.0, 0.25, 0.5)
    seeds = range(2) if quick else range(4)
    iterations = 2
    gen_tokens = 12 if quick else 24
    out: dict = {"sigmas": {}}
    caches = BatchCaches()
    for sigma in sigmas:
        topo = make_tpu_pod_topology(2, 8, 8, dcn_straggler_sigma=sigma)
        graph, specs = _mixed_graph(costs, iterations=iterations,
                                    gen_tokens=gen_tokens, n_requests=2)
        scenarios = [
            Scenario(topo, traffic=graph, chunks_per_collective=8,
                     seed=seed,
                     arbiter_factory=(lambda: FabricArbiter(
                         "weighted-fair", specs)))
            for seed in seeds
        ]
        results = simulate_batch(scenarios, caches=caches)
        p99s = [r.stream_stats()["serve/decode"].latency_p99
                for r in results]
        fins = [r.finish_time() for r in results]
        out["sigmas"][str(sigma)] = {
            "decode_p99_mean_s": sum(p99s) / len(p99s),
            "decode_p99_max_s": max(p99s),
            "finish_mean_s": sum(fins) / len(fins),
            "seeds": len(list(seeds)),
        }
    base = out["sigmas"]["0.0"]["decode_p99_mean_s"]
    worst = out["sigmas"][str(sigmas[-1])]["decode_p99_mean_s"]
    out["tail_inflation"] = worst / base if base else 0.0
    return out


# ---------------------------------------------------------------------------
# Long-stream scaling (standing fleet benchmark)
# ---------------------------------------------------------------------------
def _fit_exponent(points: list[tuple[int, float]]) -> float:
    xs = [math.log(p[0]) for p in points]
    ys = [math.log(p[1]) for p in points]
    n = len(xs)
    mx, my = sum(xs) / n, sum(ys) / n
    num = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
    den = sum((x - mx) ** 2 for x in xs)
    return num / den


def long_stream(costs, quick: bool) -> dict:
    """Multi-iteration training + decode tenant grown to ~1M stage-ops.

    The scheduling pass and vectorized task build run once per size through
    ``BatchCaches``; the timed quantity is the dependency-gated indexed
    event loop (the thing whose scaling the gate protects).
    """
    sizes = ((2, 60), (4, 120), (8, 240)) if quick else (
        (10, 150), (30, 450), (80, 1200), (160, 2400))
    topo = make_tpu_pod_topology(2, 8, 8)
    caches = BatchCaches()
    pts = []
    cpts = []
    detail = []
    for iterations, gen_tokens in sizes:
        graph, _ = _mixed_graph(costs, iterations=iterations,
                                gen_tokens=gen_tokens, n_requests=2,
                                arrival_gap_s=1e-3)
        sc = Scenario(topo, traffic=graph, chunks_per_collective=32)
        groups, ta = caches.groups_and_arrays(sc)
        kw = graph.sim_kwargs()
        repeat = 3 if ta.n_tasks <= 60_000 else 1
        res, secs = timed_best(
            simulate, topo, groups, task_arrays=ta, engine="indexed",
            repeat=repeat, **kw)
        # compiled leg: one untimed warmup (populates the per-TaskArrays
        # caches + fingerprint validation) doubling as the identity check
        res_c = simulate(topo, groups, task_arrays=ta, engine="compiled",
                         **kw)
        bad = res.diff_fields(res_c)
        if bad:
            raise AssertionError(
                f"long-stream: compiled fields {bad} differ from indexed "
                f"at {ta.n_tasks} stage-ops")
        res_c = None
        _, secs_c = timed_best(
            simulate, topo, groups, task_arrays=ta, engine="compiled",
            repeat=max(repeat, 2), **kw)
        assert ta.n_tasks == _stage_ops(groups)
        pts.append((ta.n_tasks, secs))
        cpts.append((ta.n_tasks, secs_c))
        detail.append({"iterations": iterations, "gen_tokens": gen_tokens,
                       "stage_ops": ta.n_tasks, "indexed_s": secs,
                       "compiled_s": secs_c,
                       "compiled_stage_ops_per_sec": ta.n_tasks / secs_c,
                       "compiled_bit_equivalent": True,
                       "makespan_s": res.makespan})
    exp = _fit_exponent(pts)
    limit = 1.6 if quick else 1.2
    ok = exp <= limit
    if not ok:
        raise AssertionError(
            f"long-stream scaling exponent {exp:.3f} > {limit}")
    return {"points": detail, "exponent": exp, "limit": limit, "ok": ok,
            "compiled_exponent": _fit_exponent(cpts),
            "compiled_speedup_largest": pts[-1][1] / cpts[-1][1],
            "largest_stage_ops": pts[-1][0]}


def run(quick: bool = False):
    costs = serving_costs_from_arch("llama3-8b", batch=4, prompt_len=512,
                                    tp=8)
    report: dict = {"mode": "quick" if quick else "full",
                    "serving_costs": costs}
    rows = []

    checked = equivalence_gate(costs, quick)
    report["equivalence"] = {"scenarios": checked, "ok": True}
    rows.append(row("traffic/equivalence", 0.0,
                    f"{len(checked)} dependency-gated scenarios "
                    "bit-identical"))

    mt = mixed_tenancy(costs, quick)
    report["mixed_tenant"] = mt
    for pol, stats in mt["policies"].items():
        rows.append(row(
            f"traffic/mixed/{pol}", stats["decode_p99_s"] * 1e6,
            f"decode_p99={stats['decode_p99_s'] * 1e3:.3f}ms "
            f"train_slowdown={stats['train_slowdown']:.3f}"))

    dj = dcn_jitter(costs, quick)
    report["dcn_jitter"] = dj
    rows.append(row(
        "traffic/dcn_jitter", 0.0,
        f"decode_p99 tail inflation {dj['tail_inflation']:.2f}x at "
        f"sigma={list(dj['sigmas'])[-1]}"))

    ls = long_stream(costs, quick)
    report["long_stream"] = ls
    rows.append(row(
        "traffic/long_stream", ls["points"][-1]["indexed_s"] * 1e6,
        f"exponent={ls['exponent']:.3f} "
        f"largest={ls['largest_stage_ops']} stage-ops"))
    rows.append(row(
        "traffic/long_stream/compiled",
        ls["points"][-1]["compiled_s"] * 1e6,
        f"exponent={ls['compiled_exponent']:.3f} "
        f"speedup={ls['compiled_speedup_largest']:.2f}x bit-identical"))

    OUT_JSON.write_text(json.dumps(report, indent=2) + "\n")
    rows.append(row("traffic/json", 0.0, f"json={OUT_JSON.name}"))
    return rows


def main() -> None:
    quick = "--quick" in sys.argv[1:]
    print("name,us_per_call,derived")
    for name, us, derived in run(quick=quick):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
