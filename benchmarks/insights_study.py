"""Paper Sec. 6.3: BW-provisioning scenarios per topology + util bounds."""
from benchmarks.common import row, timed
from repro.core.insights import (
    analyze,
    baseline_utilization_bound,
    themis_utilization_bound,
)
from repro.topology import make_current_topology, make_table2_topologies


def run():
    rows = []
    topos = dict(make_table2_topologies())
    topos["current-2D"] = make_current_topology()
    for name, topo in topos.items():
        (verdicts, us) = timed(analyze, topo)
        worst = max(verdicts, key=lambda v: abs(v.ratio - 1.0))
        bb = baseline_utilization_bound(topo)
        tb = themis_utilization_bound(topo)
        rows.append(row(
            f"insights/{name}", us,
            f"baseline_bound={bb*100:.1f}% themis_bound={tb*100:.1f}% "
            f"worst_pair=dim{worst.dim_k+1}/dim{worst.dim_l+1}:"
            f"{worst.verdict}(ratio={worst.ratio:.3f})"))
    return rows
