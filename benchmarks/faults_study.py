"""Fault-injection chaos study: differential engine testing under faults
plus the Themis graceful-degradation (re-planning) payoff.

Three parts, emitted into ``BENCH_faults.json``:

  * **identity** — the fault-free pin: with ``faults=None`` (and with an
    *empty* ``FaultSchedule``, which compiles to zero boundaries) both
    engines must produce field-for-field identical simulation results —
    the fault machinery consumes no event sequence numbers and no RNG
    draws unless a fault actually fires.  The only permitted delta for
    the armed-but-empty schedule is the retry-accounting field itself
    (all zeros).
  * **chaos** — randomized differential scenarios across (scheduling
    policy x intra discipline x arbiter discipline x fault mix): each
    scenario draws a seeded random fault timeline (BW degradations, dim
    outages with retry/timeout, link flaps, straggler bursts) and runs it
    through BOTH engines with the runtime invariant sanitizer armed
    (``check_invariants=True``).  Any field diff or invariant violation
    fails the study — this is the fault fabric's equivalence oracle.
  * **sweep** — makespan inflation vs degradation severity, with and
    without re-planning: a staggered all-reduce stream hits a mid-stream
    BW degradation on its fat dim; Themis re-planning re-schedules the
    un-issued chunk orders against the degraded per-dim BW (Algorithm 1:
    a slow dim placed late in the RS order carries ~P-times less wire
    traffic).  The study asserts re-planning recovers at least **1.15x**
    makespan at the harshest severity — the acceptance gate.

Run standalone (``python -m benchmarks.faults_study [--quick]``) or via
``python -m benchmarks.run faults``.
"""
from __future__ import annotations

import json
import random
import sys
from pathlib import Path

from benchmarks.common import row, timed
from repro.core.requests import CollectiveRequest
from repro.core.simulator import simulate_requests
from repro.faults import (
    BwDegradation,
    DimOutage,
    FaultSchedule,
    LinkFlap,
    RetryPolicy,
    StragglerBurst,
)
from repro.tenancy import FabricArbiter, TenantSpec
from repro.topology import make_table2_topologies

MB = 1e6
OUT_JSON = Path(__file__).resolve().parents[1] / "BENCH_faults.json"

# The re-planning payoff the acceptance gate demands at the harshest
# severity of the sweep (no-replan makespan / replan makespan).
REPLAN_GATE = 1.15


def _topo():
    return make_table2_topologies()["2D-SW_SW"]


# -- part 1: fault-free identity ---------------------------------------------

def identity_part(quick: bool) -> tuple[dict, list]:
    topo = _topo()
    reqs = [CollectiveRequest("AR", 8.0 * MB, issue_time=i * 2e-4)
            for i in range(4 if quick else 8)]

    def run_once(eng, faults):
        return simulate_requests(topo, reqs, chunks_per_collective=8,
                                 engine=eng, check_invariants=True,
                                 faults=faults)

    (base_idx, _), us = timed(run_once, "indexed", None)
    (base_ref, _), _ = timed(run_once, "reference", None)
    if base_idx.diff_fields(base_ref):
        raise AssertionError(
            f"fault-free engines diverge: {base_idx.diff_fields(base_ref)}")
    for eng, base in (("indexed", base_idx), ("reference", base_ref)):
        (empty, _), _ = timed(run_once, eng, FaultSchedule())
        # Arming an (empty) schedule legitimately turns on retry
        # accounting (`group_retries` becomes per-group zeros); every
        # simulation field must still be bit-identical.
        diff = [f for f in base.diff_fields(empty) if f != "group_retries"]
        if diff:
            raise AssertionError(
                f"empty FaultSchedule changed {eng} results: {diff}")
        if any(empty.group_retries) or empty.failed_groups:
            raise AssertionError(
                f"empty FaultSchedule produced retries/failures on {eng}")
    out = {"engines_identical": True, "empty_schedule_identical": True}
    rows = [row("faults/identity", us,
                "faults=None and FaultSchedule() bit-identical, "
                "both engines")]
    return out, rows


# -- part 2: randomized chaos differentials ----------------------------------

def _random_faults(rng: random.Random, horizon: float) -> FaultSchedule:
    """One seeded random fault mix on a 2-dim fabric: per dim at most one
    BW-family event (degradation / outage / flap) plus an optional
    straggler burst — always a valid (non-overlapping) timeline."""
    events = []
    for dim in (0, 1):
        kind = rng.choice(("degrade", "outage", "flap", "none"))
        t0 = rng.uniform(0.1, 0.5) * horizon
        if kind == "degrade":
            events.append(BwDegradation(
                dim=dim, start=t0, end=t0 + rng.uniform(0.2, 0.5) * horizon,
                factor=rng.uniform(0.1, 0.8)))
        elif kind == "outage":
            events.append(DimOutage(
                dim=dim, start=t0, end=t0 + rng.uniform(0.05, 0.2) * horizon))
        elif kind == "flap":
            down = rng.uniform(0.02, 0.06) * horizon
            events.append(LinkFlap(
                dim=dim, start=t0, down_s=down,
                period_s=down + rng.uniform(0.05, 0.15) * horizon,
                count=rng.randint(1, 3)))
        if rng.random() < 0.5:
            s0 = rng.uniform(0.0, 0.4) * horizon
            events.append(StragglerBurst(
                dim=dim, start=s0, end=s0 + rng.uniform(0.2, 0.6) * horizon,
                sigma=rng.uniform(0.05, 0.4)))
    retry = RetryPolicy(timeout_s=rng.uniform(0.02, 0.08) * horizon,
                        backoff_s=rng.uniform(0.01, 0.03) * horizon,
                        max_attempts=rng.choice((3, 8)))
    return FaultSchedule(events=tuple(events), retry=retry)


def chaos_part(quick: bool) -> tuple[dict, list]:
    topo = _topo()
    horizon = 2e-3
    policies = ("themis", "baseline")
    intras = ("SCF", "FIFO")
    arbiters = (None, "weighted-fair", "strict-priority")
    specs = [TenantSpec("a", weight=1.0), TenantSpec("b", weight=3.0,
                                                     priority=5)]
    n_scn = 24
    scenarios = []
    for i in range(n_scn):
        scenarios.append((policies[i % 2], intras[(i // 2) % 2],
                          arbiters[(i // 4) % 3], 1000 + i))

    results = []
    n_retries = n_failed = n_replans = 0
    for policy, intra, arb_policy, seed in scenarios:
        rng = random.Random(seed)
        faults = _random_faults(rng, horizon)
        reqs = [CollectiveRequest(
            "AR", (2.0 if quick else 6.0) * MB, issue_time=i * 2e-4,
            tenant="a" if i % 3 else "b")
            for i in range(6 if quick else 10)]
        replan = bool(seed % 2) and policy == "themis"

        def run_once(eng):
            arb = (FabricArbiter(arb_policy, specs, quantum_chunks=4,
                                 preemption=True)
                   if arb_policy is not None else None)
            return simulate_requests(
                topo, reqs, policy=policy, chunks_per_collective=8,
                intra=intra, arbiter=arb, engine=eng,
                check_invariants=True, faults=faults, replan=replan)

        (res_i, _), _ = timed(run_once, "indexed")
        (res_r, _), _ = timed(run_once, "reference")
        diff = res_i.diff_fields(res_r)
        if diff:
            raise AssertionError(
                f"engines diverged under faults (policy={policy}, "
                f"intra={intra}, arbiter={arb_policy}, seed={seed}): {diff}")
        n_retries += sum(res_i.group_retries)
        n_failed += len(res_i.failed_groups)
        results.append({
            "policy": policy, "intra": intra, "arbiter": arb_policy,
            "seed": seed, "replan": replan,
            "makespan": res_i.makespan,
            "retries": sum(res_i.group_retries),
            "failed_groups": len(res_i.failed_groups),
            "identical": True,
        })
    out = {"n_scenarios": n_scn, "all_identical": True,
           "total_retries": n_retries, "total_failed_groups": n_failed,
           "scenarios": results}
    rows = [row("faults/chaos", 0.0,
                f"scenarios={n_scn} identical=all retries={n_retries} "
                f"failed_groups={n_failed} sanitizer=armed")]
    return out, rows


# -- part 3: degradation sweep + re-planning gate ----------------------------

def sweep_part(quick: bool) -> tuple[dict, list]:
    topo = _topo()
    n_groups, n_chunks, size = 6, 16, float(1 << 26)
    reqs = [CollectiveRequest("AR", size, issue_time=i * 1e-4)
            for i in range(n_groups)]

    def run_once(faults, replan):
        res, _ = simulate_requests(
            topo, reqs, chunks_per_collective=n_chunks,
            engine="indexed", check_invariants=True,
            faults=faults, replan=replan)
        return res

    clean = run_once(None, False).makespan
    factors = (0.5, 0.1) if quick else (0.7, 0.5, 0.25, 0.1)
    points = []
    rows = []
    worst_speedup = None
    for f in factors:
        faults = FaultSchedule(events=(
            BwDegradation(dim=1, start=1.5e-4, end=1.0, factor=f),))
        plain, us = timed(run_once, faults, False)
        replanned = run_once(faults, True)
        speedup = plain.makespan / replanned.makespan
        points.append({
            "factor": f,
            "makespan_clean": clean,
            "makespan_no_replan": plain.makespan,
            "makespan_replan": replanned.makespan,
            "inflation_no_replan": plain.makespan / clean,
            "inflation_replan": replanned.makespan / clean,
            "replan_speedup": speedup,
        })
        rows.append(row(
            f"faults/sweep/factor={f}", us,
            f"inflation={plain.makespan / clean:.2f}x "
            f"replan={replanned.makespan / clean:.2f}x "
            f"speedup={speedup:.2f}x"))
        worst_speedup = speedup  # factors descend: last = harshest
    if worst_speedup is None or worst_speedup < REPLAN_GATE:
        raise AssertionError(
            f"re-planning gate failed: {worst_speedup} < {REPLAN_GATE}x at "
            f"factor={factors[-1]}")
    out = {"factors": list(factors), "points": points,
           "gate": REPLAN_GATE, "worst_severity_speedup": worst_speedup,
           "gate_passed": True}
    rows.append(row("faults/replan_gate", 0.0,
                    f"speedup={worst_speedup:.2f}x >= {REPLAN_GATE}x"))
    return out, rows


def run(quick: bool = False):
    identity, rows = identity_part(quick)
    chaos, chaos_rows = chaos_part(quick)
    sweep, sweep_rows = sweep_part(quick)
    rows += chaos_rows + sweep_rows
    report = {
        "quick": quick,
        "identity": identity,
        "chaos": chaos,
        "sweep": sweep,
        "checks": {
            "fault_free_identity": True,
            "chaos_engines_identical": True,
            "replan_gate_passed": True,
        },
    }
    OUT_JSON.write_text(json.dumps(report, indent=2) + "\n")
    rows.append(row("faults/json", 0.0, f"json={OUT_JSON.name}"))
    return rows


def main() -> None:
    quick = "--quick" in sys.argv[1:]
    from benchmarks.common import print_rows

    print("name,us_per_call,derived")
    print_rows(run(quick=quick))


if __name__ == "__main__":
    main()
