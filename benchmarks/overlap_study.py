"""Overlap study: arrival-time-aware backprop bucket streams.

Sweeps bucket counts x scheduling policies x topologies for a calibrated
(communication-bound, Sec. 6.2) ResNet-152 gradient exchange where buckets
issue progressively during the backward pass and contend in flight.
Reports, per cell: the DP comm makespan (issue of first bucket -> last
bucket drained), the exposed (post-bwd) tail, and whether distinct bucket
collectives interleaved on any dimension — the contention signature that
an all-issued-at-t=0 model cannot produce.
"""
from benchmarks.common import row, timed
from repro.core.simulator import simulate_requests
from repro.core.workloads import (
    ALL_WORKLOADS,
    calibrate_compute,
    dp_bucket_requests,
    split_topology,
)
from repro.topology import make_table2_topologies

TOPO_NAMES = ("2D-SW_SW", "3D-SW_SW_SW_homo", "4D-Ring_FC_Ring_SW")
BUCKETS = (1, 4, 8, 16)
POLICIES = (("baseline", "FIFO"), ("themis", "SCF"), ("themis_guarded", "SCF"))


def run():
    topos = make_table2_topologies()
    w = ALL_WORKLOADS["resnet152"]()
    calibrate_compute(w, list(topos.values()), 1.54)
    bwd = w.compute_bwd_s
    rows = []
    for tname in TOPO_NAMES:
        _, dp_topo = split_topology(topos[tname], w.mp_npus)
        for nb in BUCKETS:
            reqs = dp_bucket_requests(w, nb)
            per_policy = []
            us_tot = 0.0
            for policy, intra in POLICIES:
                (res, _), us = timed(simulate_requests, dp_topo, reqs,
                                     policy=policy, intra=intra,
                                     chunks_per_collective=64)
                us_tot += us
                stats = res.stream_stats()  # per-stream aggregation
                makespan = max(s.finish for s in stats.values())
                exposed = max(0.0, makespan - bwd)
                inter = sum(res.groups_interleave_on(k)
                            for k in range(dp_topo.num_dims))
                bucket_lat = stats["bwd-buckets"].latency_mean
                per_policy.append(
                    f"{policy}: makespan={makespan*1e3:.3f}ms "
                    f"exposed={exposed*1e3:.3f}ms "
                    f"bucket_lat={bucket_lat*1e3:.3f}ms "
                    f"interleaved_dims={inter}/{dp_topo.num_dims}")
            rows.append(row(
                f"overlap/{tname}/buckets={nb}", us_tot / len(POLICIES),
                " | ".join(per_policy)))
    return rows
