"""Shared benchmark utilities: timing + CSV rows."""
from __future__ import annotations

import time


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeat * 1e6
    return out, us


def timed_best(fn, *args, repeat: int = 1, **kw):
    """Like :func:`timed` but returns the best-of-``repeat`` wall time in
    *seconds* — for scaling fits, where the minimum is the noise-robust
    estimator."""
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def row(name: str, us: float, derived) -> tuple[str, float, str]:
    return (name, us, derived)


def print_rows(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
