"""Shared benchmark utilities: timing + CSV rows."""
from __future__ import annotations

import time


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeat * 1e6
    return out, us


def row(name: str, us: float, derived) -> tuple[str, float, str]:
    return (name, us, derived)


def print_rows(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
