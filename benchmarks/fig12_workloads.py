"""Paper Fig. 12: end-to-end training-iteration time for ResNet-152, GNMT,
DLRM, Transformer-1T: baseline vs Themis+SCF vs Ideal across topologies.

Compute time per workload is calibrated so the *Ideal* speedup matches the
paper's reported Ideal (1.54/1.32/1.33/1.26) — collective sizes follow the
published model structures; Themis's speedup is then a genuine prediction
validated against the paper's 1.49/1.30/1.30/1.25 (see EXPERIMENTS.md).
"""
import statistics

from benchmarks.common import row, timed
from repro.core.workloads import ALL_WORKLOADS, calibrate_compute, iteration_time
from repro.topology import make_table2_topologies

PAPER = {
    "resnet152": (1.49, 2.25, 1.54),
    "gnmt": (1.30, 1.78, 1.32),
    "dlrm": (1.30, 1.77, 1.33),
    "transformer_1t": (1.25, 1.53, 1.26),
}

# Gradient buckets for the arrival-time-aware variant (DDP-style bucketing).
OVERLAP_BUCKETS = 8


def run():
    rows = []
    topos = list(make_table2_topologies().values())
    for wname, maker in ALL_WORKLOADS.items():
        w = maker()
        pa, pm, pi = PAPER[wname]
        calibrate_compute(w, topos, pi)
        sp, spi, spo = [], [], []
        us_tot = 0.0
        for topo in topos:
            (b, us) = timed(iteration_time, w, topo, "baseline", intra="FIFO")
            t = iteration_time(w, topo, "themis", intra="SCF")
            i = iteration_time(w, topo, "ideal")
            # arrival-time-aware variant: buckets issue during bwd and
            # overlap (paper's deployment reality; Sec. 2 motivation)
            bo = iteration_time(w, topo, "baseline", intra="FIFO",
                                overlap_buckets=OVERLAP_BUCKETS)
            to = iteration_time(w, topo, "themis", intra="SCF",
                                overlap_buckets=OVERLAP_BUCKETS)
            sp.append(b.total_s / t.total_s)
            spi.append(b.total_s / i.total_s)
            spo.append(bo.total_s / to.total_s)
            us_tot += us
            rows.append(row(
                f"fig12/{wname}/{topo.name}", us,
                f"base={b.total_s*1e3:.2f}ms themis={t.total_s*1e3:.2f}ms "
                f"ideal={i.total_s*1e3:.2f}ms "
                f"overlap{OVERLAP_BUCKETS}: base={bo.total_s*1e3:.2f}ms "
                f"themis={to.total_s*1e3:.2f}ms "
                f"exposed_comm: {100*(b.total_s-b.compute_s)/b.total_s:.0f}%->"
                f"{100*(t.total_s-t.compute_s)/t.total_s:.0f}%"))
        rows.append(row(
            f"fig12/{wname}/SUMMARY", us_tot / len(topos),
            f"themis_avg={statistics.mean(sp):.2f}x(paper:{pa}) "
            f"themis_max={max(sp):.2f}x(paper:{pm}) "
            f"ideal_avg={statistics.mean(spi):.2f}x(paper:{pi}) "
            f"overlap_themis_avg={statistics.mean(spo):.2f}x"))
    return rows
