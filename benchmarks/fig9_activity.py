"""Paper Fig. 9: per-dimension activity rates, 1GB AR on 3D-SW_SW_SW_homo.

Activity rates come from the observability timeline API
(``repro.obs.BwTimeline``) — the canonical time-resolved view — rather
than ad-hoc interval math; ``BwTimeline.from_result`` evaluates the same
expression as ``SimResult.activity_rate``, so the reported numbers are
unchanged.
"""
from benchmarks.common import row, timed
from repro.core.simulator import simulate_scheduled
from repro.obs import BwTimeline
from repro.topology import make_table2_topologies


def run():
    topo = make_table2_topologies()["3D-SW_SW_SW_homo"]
    rows = []
    for policy, intra in (("baseline", "FIFO"), ("themis", "FIFO"),
                          ("themis", "SCF")):
        (res, _), us = timed(simulate_scheduled, topo, "AR", 1e9,
                             policy=policy, intra=intra)
        tl = BwTimeline.from_result(res, topo)
        rates = " ".join(
            f"dim{k+1}={tl.activity_rate(k)*100:.1f}%"
            for k in range(topo.num_dims))
        rows.append(row(f"fig9/{policy}+{intra}", us, rates))
    return rows
