"""Observability study: the flight recorder must be free when off and
faithful when on.

Three parts, all emitted into ``BENCH_obs.json``:

  * **bit-identity gate** — the same pinned scenario matrix as
    ``sched_perf`` (scheduling policies x intra disciplines x arbiter
    policies x topologies, both engines), each scenario simulated twice:
    untraced and with a :class:`repro.obs.Tracer` armed.  Every
    ``SimResult`` field must be **bit-identical** — tracer hooks are
    append-only observers and may never perturb the event loop (no extra
    ``seq`` draws, no RNG consumption).  Any mismatch raises.
  * **fidelity gate** — per scenario, the trace must reproduce the
    engine's own bookkeeping: ``Tracer.service_wire()`` vs
    ``SimResult.dim_wire_bytes``, ``Tracer.service_busy()`` vs
    ``dim_busy``, ``ops_served`` vs ``dim_op_order`` (exact), and
    ``BwTimeline`` utilizations vs ``avg_bw_utilization`` /
    ``activity_rate``.  Wire/busy checks use ``math.isclose`` at
    ``rel_tol=1e-12``: preemption amends a service record with one fused
    ``(w - cut)`` subtraction where the engine does ``+= w`` then
    ``-= cut``, so the sums agree to ulps, not bits.  The windowed
    ``BwTimeline.per_dim_utilization`` series must also integrate back to
    the aggregate per-dim utilization, and the Chrome ``trace_event``
    export must round-trip through :func:`repro.obs.parse_chrome_trace`
    with event counts matching the ``SimResult`` bookkeeping.
  * **overhead gate** — the long AR stream (``sched_perf``'s headline
    shape) timed untraced vs traced on the indexed engine; best-of-N with
    re-measure retries (wall-clock on shared runners is noisy), asserting
    traced <= 1.10x untraced.  "Zero overhead when disabled" is the lint
    rule (``tools/lint_engine.py``: every tracer call in an engine hot
    loop sits behind a guard branch); this gate bounds the *enabled* cost.

Run standalone (``python -m benchmarks.obs_study [--quick]``) or via
``python -m benchmarks.run obs``.  Also writes ``obs_sample.trace.json``
— a Perfetto-loadable sample trace from the arbiter scenario.
"""
from __future__ import annotations

import json
import math
import sys
from pathlib import Path

from benchmarks.common import row, timed_best
from repro.core.requests import CollectiveRequest
from repro.core.simulator import simulate_requests
from repro.obs import BwTimeline, Tracer, parse_chrome_trace
from repro.tenancy import (
    FabricArbiter,
    TenantSpec,
    simulate_fabric,
    synthetic_requests,
)
from repro.topology import make_table2_topologies

MB = 1e6
OUT_JSON = Path(__file__).resolve().parents[1] / "BENCH_obs.json"
OUT_TRACE = Path(__file__).resolve().parents[1] / "obs_sample.trace.json"
OVERHEAD_LIMIT = 1.10


def _assert_bit_identical(res_plain, res_traced, label: str) -> None:
    bad = res_traced.diff_fields(res_plain)
    if bad:
        raise AssertionError(
            f"tracing perturbed the simulation on {label}: fields {bad} "
            f"differ between traced and untraced runs")


def _isclose(a: float, b: float) -> bool:
    return math.isclose(a, b, rel_tol=1e-12, abs_tol=1e-12)


def _check_fidelity(trc: Tracer, res, topo, label: str) -> None:
    """Trace-derived aggregates must match the engine's own bookkeeping."""
    wire = trc.service_wire()
    busy = trc.service_busy()
    for d in range(topo.num_dims):
        if not _isclose(wire[d], res.dim_wire_bytes[d]):
            raise AssertionError(
                f"{label}: dim{d} trace wire {wire[d]!r} != engine "
                f"{res.dim_wire_bytes[d]!r}")
        if not _isclose(busy[d], res.dim_busy[d]):
            raise AssertionError(
                f"{label}: dim{d} trace busy {busy[d]!r} != engine "
                f"{res.dim_busy[d]!r}")
        if trc.ops_served(d) != res.dim_op_order[d]:
            raise AssertionError(
                f"{label}: dim{d} trace op order diverges from engine")

    tl = BwTimeline.from_tracer(trc)
    if not _isclose(tl.avg_bw_utilization(), res.avg_bw_utilization(topo)):
        raise AssertionError(
            f"{label}: timeline avg_bw_utilization "
            f"{tl.avg_bw_utilization()!r} != SimResult "
            f"{res.avg_bw_utilization(topo)!r}")
    for d in range(topo.num_dims):
        if not _isclose(tl.activity_rate(d), res.activity_rate(d)):
            raise AssertionError(
                f"{label}: dim{d} timeline activity_rate != SimResult")

    # Windowed series must integrate back to the aggregate utilization.
    if res.makespan > 0:
        win = res.makespan / 7.0
        per_dim = tl.per_dim_utilization(win)
        wins = tl.windows(win)
        for d in range(topo.num_dims):
            integ = sum(u * (w1 - w0)
                        for u, (w0, w1) in zip(per_dim[d], wins))
            want = tl.dim_utilization(d) * res.makespan
            if not math.isclose(integ, want, rel_tol=1e-9, abs_tol=1e-12):
                raise AssertionError(
                    f"{label}: dim{d} windowed utilization integrates to "
                    f"{integ!r}, aggregate says {want!r}")

    # Chrome export must round-trip with counts matching the bookkeeping.
    parsed = parse_chrome_trace(trc.to_chrome_trace())
    n_groups = len(res.group_finish)
    if parsed["groups"] != n_groups:
        raise AssertionError(
            f"{label}: trace export has {parsed['groups']} group events, "
            f"SimResult finished {n_groups} groups")
    for d in range(topo.num_dims):
        if parsed["services_per_dim"].get(d, 0) != len(res.dim_services[d]):
            raise AssertionError(
                f"{label}: trace export dim{d} service count "
                f"{parsed['services_per_dim'].get(d, 0)} != "
                f"{len(res.dim_services[d])}")
    if parsed["preempts"] != len(trc.preempts):
        raise AssertionError(f"{label}: preempt instants lost in export")


# ---------------------------------------------------------------------------
# Bit-identity + fidelity gate (sched_perf's scenario matrix, traced)
# ---------------------------------------------------------------------------
def tracing_gate(topos, quick: bool) -> list[str]:
    checked: list[str] = []
    topo_names = ("2D-SW_SW", "3D-SW_SW_SW_hetero")
    policies = ("baseline", "themis") if quick else (
        "baseline", "themis", "themis_indep_ag", "lookahead",
        "themis_guarded")

    for tname in topo_names:
        topo = topos[tname]
        for policy in policies:
            for intra in ("SCF", "FIFO"):
                reqs = [CollectiveRequest(["AR", "RS", "AG"][i % 3],
                                          (4 + 9 * (i % 4)) * MB,
                                          issue_time=i * 1.3e-4,
                                          priority=i % 2)
                        for i in range(18)]
                for eng in ("indexed", "reference"):
                    rp, _ = simulate_requests(topo, reqs, policy=policy,
                                              chunks_per_collective=8,
                                              intra=intra, engine=eng)
                    trc = Tracer()
                    rt, _ = simulate_requests(topo, reqs, policy=policy,
                                              chunks_per_collective=8,
                                              intra=intra, engine=eng,
                                              tracer=trc)
                    label = f"{tname}/{policy}/{intra}/{eng}"
                    _assert_bit_identical(rp, rt, label)
                    _check_fidelity(trc, rt, topo, label)
                checked.append(f"{tname}/{policy}/{intra}")
        # arbiter policies (multi-tenant engine, incl. preemption)
        specs = [TenantSpec("heavy", weight=1.0),
                 TenantSpec("light", weight=1.0, priority=1,
                            slo_slowdown=1.5)]
        reqs = (synthetic_requests("heavy", "AR", 200 * MB, 2)
                + synthetic_requests("light", "AR", 8 * MB, 6,
                                     gap_s=0.0004, start_s=0.0002))
        for arb_policy in ("fifo", "strict-priority", "weighted-fair",
                           "slo-aware"):
            for eng in ("indexed", "reference"):
                arb = FabricArbiter(arb_policy, specs,
                                    isolated_latency={"light": 0.001})
                rp, _ = simulate_fabric(topo, reqs, arbiter=arb,
                                        chunks_per_collective=8, engine=eng)
                arb = FabricArbiter(arb_policy, specs,
                                    isolated_latency={"light": 0.001})
                trc = Tracer()
                rt, _ = simulate_fabric(topo, reqs, arbiter=arb,
                                        chunks_per_collective=8, engine=eng,
                                        tracer=trc)
                label = f"{tname}/arbiter:{arb_policy}/{eng}"
                _assert_bit_identical(rp, rt, label)
                _check_fidelity(trc, rt, topo, label)
            checked.append(f"{tname}/arbiter:{arb_policy}")
    return checked


# ---------------------------------------------------------------------------
# Sample trace for the artifact upload (Perfetto-loadable)
# ---------------------------------------------------------------------------
def write_sample_trace(topos) -> dict:
    topo = topos["2D-SW_SW"]
    specs = [TenantSpec("heavy", weight=1.0),
             TenantSpec("light", weight=1.0, priority=1, slo_slowdown=1.5)]
    reqs = (synthetic_requests("heavy", "AR", 200 * MB, 2)
            + synthetic_requests("light", "AR", 8 * MB, 6,
                                 gap_s=0.0004, start_s=0.0002))
    arb = FabricArbiter("weighted-fair", specs,
                        isolated_latency={"light": 0.001})
    trc = Tracer()
    res, _ = simulate_fabric(topo, reqs, arbiter=arb,
                             chunks_per_collective=8, tracer=trc)
    trc.save(OUT_TRACE)
    return {
        "path": OUT_TRACE.name,
        "scenario": "2D-SW_SW/arbiter:weighted-fair",
        "events": trc.event_counts(),
        "makespan_s": res.makespan,
    }


# ---------------------------------------------------------------------------
# Overhead gate: traced vs untraced on the long stream
# ---------------------------------------------------------------------------
def overhead(topos, quick: bool) -> dict:
    n_req, n_chunk = (64, 16) if quick else (256, 64)
    topo = topos["3D-SW_SW_SW_homo"]
    reqs = [CollectiveRequest("AR", 20.0 * MB, issue_time=i * 1e-4)
            for i in range(n_req)]

    def run_plain():
        return simulate_requests(topo, reqs, chunks_per_collective=n_chunk,
                                 engine="indexed")

    def run_traced():
        trc = Tracer()
        out = simulate_requests(topo, reqs, chunks_per_collective=n_chunk,
                                engine="indexed", tracer=trc)
        return out, trc

    ratio = float("inf")
    t_plain = t_traced = float("inf")
    attempts = 0
    # Re-measure on a miss, keeping the best-of-all-attempts wall time on
    # each side: sub-second wall times on shared runners see scheduler
    # noise well above the 10% budget we are gating, and the minimum is
    # the noise-robust estimator (same rationale as ``timed_best``).
    for attempts in range(1, 6):
        (res_plain, _), tp = timed_best(run_plain, repeat=3)
        ((res_traced, _), trc), tt = timed_best(run_traced, repeat=3)
        t_plain = min(t_plain, tp)
        t_traced = min(t_traced, tt)
        ratio = t_traced / t_plain
        if ratio <= OVERHEAD_LIMIT:
            break
    _assert_bit_identical(res_plain, res_traced,
                          f"overhead {n_req}x{n_chunk}")
    _check_fidelity(trc, res_traced, topo, f"overhead {n_req}x{n_chunk}")
    if ratio > OVERHEAD_LIMIT:
        raise AssertionError(
            f"tracing overhead {ratio:.3f}x > {OVERHEAD_LIMIT}x on "
            f"{n_req}x{n_chunk} stream after {attempts} attempts")
    return {
        "n_requests": n_req,
        "chunks_per_collective": n_chunk,
        "untraced_s": t_plain,
        "traced_s": t_traced,
        "overhead_x": ratio,
        "attempts": attempts,
        "events": trc.event_counts(),
    }


def run(quick: bool = False):
    topos = make_table2_topologies()
    report: dict = {"mode": "quick" if quick else "full",
                    "overhead_limit_x": OVERHEAD_LIMIT}
    rows = []

    checked = tracing_gate(topos, quick)
    report["tracing"] = {"scenarios": checked, "ok": True}
    rows.append(row("obs/tracing", 0.0,
                    f"{len(checked)} scenarios bit-identical+faithful "
                    f"(both engines)"))

    sample = write_sample_trace(topos)
    report["sample_trace"] = sample
    rows.append(row("obs/sample_trace", 0.0,
                    f"{sample['path']} services="
                    f"{sample['events'].get('services', 0)} "
                    f"preempts={sample['events'].get('preempts', 0)}"))

    oh = overhead(topos, quick)
    report["overhead"] = oh
    rows.append(row(
        f"obs/overhead/{oh['n_requests']}x{oh['chunks_per_collective']}",
        oh["traced_s"] * 1e6,
        f"overhead={oh['overhead_x']:.3f}x "
        f"plain={oh['untraced_s']:.4f}s traced={oh['traced_s']:.4f}s"))

    OUT_JSON.write_text(json.dumps(report, indent=2) + "\n")
    rows.append(row("obs/json", 0.0, f"json={OUT_JSON.name}"))
    return rows


def main() -> None:
    quick = "--quick" in sys.argv[1:]
    print("name,us_per_call,derived")
    for name, us, derived in run(quick=quick):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
